"""L2: decoder-only transformer with the paper's 7-matrix layer anatomy.

Every layer owns exactly the matrices GradES monitors (paper Fig. 1):
attention projections Wq, Wk, Wv, Wo and SwiGLU MLP Wgate, Wup, Wdown —
the same anatomy as the Qwen3 family the paper fine-tunes. Pre-RMSNorm,
learned positional embeddings, untied LM head.

Parameters are a flat ``dict[name -> array]`` whose names/shapes come from
``layout.base_param_specs`` so the python model, the manifest, and the rust
coordinator all agree on one ordering.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import Config


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def attention(params, prefix: str, x, n_heads: int, causal: bool, layer: int):
    """Multi-head attention using the per-matrix weights GradES monitors."""
    B, T, D = x.shape
    hd = D // n_heads
    q = x @ params[f"{prefix}.{layer}.attn.q"]
    k = x @ params[f"{prefix}.{layer}.attn.k"]
    v = x @ params[f"{prefix}.{layer}.attn.v"]

    def split(t):
        return t.reshape(B, T, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = split(q), split(k), split(v)
    scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
    if causal:
        mask = jnp.tril(jnp.ones((T, T), jnp.bool_))
        scores = jnp.where(mask, scores, jnp.float32(-1e9))
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = (probs @ v).transpose(0, 2, 1, 3).reshape(B, T, D)
    return ctx @ params[f"{prefix}.{layer}.attn.o"]


def mlp(params, prefix: str, x, layer: int):
    """SwiGLU: down( silu(x·Wgate) ⊙ (x·Wup) )."""
    gate = jax.nn.silu(x @ params[f"{prefix}.{layer}.mlp.gate"])
    up = x @ params[f"{prefix}.{layer}.mlp.up"]
    return (gate * up) @ params[f"{prefix}.{layer}.mlp.down"]


def tower(params, prefix: str, x, n_layers: int, n_heads: int, causal: bool):
    for layer in range(n_layers):
        h = rms_norm(x, params[f"{prefix}.{layer}.ln1"])
        x = x + attention(params, prefix, h, n_heads, causal, layer)
        h = rms_norm(x, params[f"{prefix}.{layer}.ln2"])
        x = x + mlp(params, prefix, h, layer)
    return x


def lm_logits(params, cfg: Config, tokens):
    """tokens i32[B,T] → logits f32[B,T,V]."""
    m = cfg.model
    T = tokens.shape[1]
    x = params["tok_emb"][tokens] + params["pos_emb"][:T]
    x = tower(params, "lang", x, m.n_layers, m.n_heads, causal=True)
    x = rms_norm(x, params["ln_f"])
    return x @ params["lm_head"]


def vlm_logits(params, cfg: Config, patches, tokens):
    """LLaVA-style two-tower: vision prefix tokens + causal text decoder.

    patches f32[B,P,patch_dim], tokens i32[B,T] → logits f32[B,T,V] over the
    text positions only. Vision tower is bidirectional (ViT-like); its 7
    matrices per layer carry tower="vision" in the manifest, which is how
    the coordinator reproduces the paper's §6.3 vision-vs-language
    convergence split.
    """
    m = cfg.model
    P = patches.shape[1]
    T = tokens.shape[1]
    v = patches @ params["vis_in"] + params["vis_pos"][:P]
    v = tower(params, "vis", v, m.n_vision_layers, m.n_vision_heads, causal=False)
    v = rms_norm(v, params["vis_ln_f"])
    prefix = v @ params["vis_proj"]  # [B,P,D]

    t = params["tok_emb"][tokens]
    x = jnp.concatenate([prefix, t], axis=1) + params["pos_emb"][: P + T]
    x = tower(params, "lang", x, m.n_layers, m.n_heads, causal=True)
    x = rms_norm(x, params["ln_f"])
    return x[:, P:] @ params["lm_head"]


def token_loss(logits, targets):
    """(Σ CE over valid targets, Σ valid count). targets < 0 are padding."""
    valid = (targets >= 0).astype(jnp.float32)
    safe = jnp.maximum(targets, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * valid), jnp.sum(valid)


def init_params(cfg: Config, specs, key):
    """Seeded init per ParamSpec.init kind (B of LoRA starts at zero)."""
    out = {}
    for s in specs:
        key, sub = jax.random.split(key)
        if s.init in ("embed", "head"):
            val = 0.02 * jax.random.normal(sub, s.shape, jnp.float32)
        elif s.init == "matrix":
            fan_in = s.shape[0]
            val = jax.random.normal(sub, s.shape, jnp.float32) / jnp.sqrt(jnp.float32(fan_in))
        elif s.init == "ones":
            val = jnp.ones(s.shape, jnp.float32)
        elif s.init == "zeros":
            val = jnp.zeros(s.shape, jnp.float32)
        elif s.init == "lora_a":
            val = 0.05 * jax.random.normal(sub, s.shape, jnp.float32)
        elif s.init == "lora_b":
            val = jnp.zeros(s.shape, jnp.float32)
        else:
            raise ValueError(s.init)
        out[s.name] = val
    return out
