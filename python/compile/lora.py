"""LoRA adaptation (paper §3.2).

W_adapted = W_frozen + (alpha/r) · A @ B per monitored matrix (Eq. 2, in
x@W layout). GradES monitors the *pair*: G = ‖∇A‖₁-stats + ‖∇B‖₁-stats
(Eq. 3); freezing a component stops updates to both A and B while the
merged weight still participates in the forward/backward graph.
"""

from __future__ import annotations

from .configs import Config


def merge_lora(trainable: dict, frozen: dict, cfg: Config, components) -> dict:
    """Materialize adapted weights: frozen base + scaled A@B per component.

    A/B are looked up in either dict: the attn_frozen graph variant moves
    stop_gradient'ed adapters to the frozen side.
    """
    scale = cfg.train.lora_alpha / cfg.train.lora_rank
    lookup = {**frozen, **trainable}
    params = dict(frozen)
    for c in components:
        a_name, b_name = c.tensors
        wname = a_name[: -len(".lora_a")]
        params[wname] = frozen[wname] + scale * (lookup[a_name] @ lookup[b_name])
        params.pop(a_name, None)
        params.pop(b_name, None)
    return params
