"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest asserts both the Pallas
(interpret=True) kernels and the fused-XLA fast path match these exactly
(up to float tolerance) across shape/dtype sweeps.
"""

import jax.numpy as jnp


def grad_stats_ref(g, g_prev):
    """GradES Eq. 1 statistics for one gradient tensor.

    Returns (gdiff, gabs) scalars:
      gdiff = ‖g − g_prev‖₁  (element-wise L1 of the difference)
      gabs  = ‖g‖₁           (element-wise L1)
    """
    g = g.astype(jnp.float32)
    g_prev = g_prev.astype(jnp.float32)
    return jnp.sum(jnp.abs(g - g_prev)), jnp.sum(jnp.abs(g))


def masked_adamw_ref(p, g, m, v, mask, lr, beta1, beta2, eps, wd, t):
    """Freeze-aware AdamW update for one tensor.

    ``mask`` is 1.0 while the component is active, 0.0 once GradES froze it.
    Frozen tensors keep p/m/v bit-identical — the same semantics as setting
    ``requires_grad=False`` in the paper's PyTorch implementation (gradients
    still flow *through* the weight; its own update is skipped).
    """
    p = p.astype(jnp.float32)
    g = g.astype(jnp.float32)
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1**t)
    v_hat = v_new / (1.0 - beta2**t)
    update = m_hat / (jnp.sqrt(v_hat) + eps) + wd * p
    p_new = p - lr * update
    return (
        mask * p_new + (1.0 - mask) * p,
        mask * m_new + (1.0 - mask) * m,
        mask * v_new + (1.0 - mask) * v,
    )


def masked_sgd_ref(p, g, mom, mask, lr, momentum, wd):
    """Freeze-aware SGD(+momentum, +decoupled weight decay)."""
    p = p.astype(jnp.float32)
    g = g.astype(jnp.float32)
    mom_new = momentum * mom + g
    p_new = p - lr * (mom_new + wd * p)
    return (
        mask * p_new + (1.0 - mask) * p,
        mask * mom_new + (1.0 - mask) * mom,
    )
