"""Pallas kernel: fused freeze-aware optimizer update.

One HBM pass applies the whole AdamW step — moment updates, bias
correction, decoupled weight decay, and the GradES freeze mask — where an
unfused implementation costs ~6 separate elementwise passes over p/g/m/v.

Scalars (mask, lr, t, …) arrive as a small f32 vector broadcast to every
grid step via a BlockSpec that revisits block (0,) — the TPU idiom for
SMEM-resident scalars. ``interpret=True`` as everywhere (Mosaic
custom-calls cannot run on the CPU plugin).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128

# scalar vector layout
S_MASK, S_LR, S_BETA1, S_BETA2, S_EPS, S_WD, S_T, S_MOMENTUM = range(8)
N_SCALARS = 8


def _adamw_kernel(s_ref, p_ref, g_ref, m_ref, v_ref, po_ref, mo_ref, vo_ref):
    mask = s_ref[S_MASK]
    lr = s_ref[S_LR]
    beta1 = s_ref[S_BETA1]
    beta2 = s_ref[S_BETA2]
    eps = s_ref[S_EPS]
    wd = s_ref[S_WD]
    t = s_ref[S_T]
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...]
    v = v_ref[...]
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1**t)
    v_hat = v_new / (1.0 - beta2**t)
    p_new = p - lr * (m_hat / (jnp.sqrt(v_hat) + eps) + wd * p)
    po_ref[...] = mask * p_new + (1.0 - mask) * p
    mo_ref[...] = mask * m_new + (1.0 - mask) * m
    vo_ref[...] = mask * v_new + (1.0 - mask) * v


def _sgd_kernel(s_ref, p_ref, g_ref, mom_ref, po_ref, momo_ref):
    mask = s_ref[S_MASK]
    lr = s_ref[S_LR]
    wd = s_ref[S_WD]
    momentum = s_ref[S_MOMENTUM]
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    mom = mom_ref[...]
    mom_new = momentum * mom + g
    p_new = p - lr * (mom_new + wd * p)
    po_ref[...] = mask * p_new + (1.0 - mask) * p
    momo_ref[...] = mask * mom_new + (1.0 - mask) * mom


def _as_2d(x):
    if x.ndim == 1:
        return x.reshape(1, -1)
    if x.ndim == 2:
        return x
    return x.reshape(x.shape[0], -1)


def _tiled_elementwise(kernel, scalars, tensors, n_out, block_rows):
    """Run an elementwise kernel over row-tiles of same-shape 2D tensors."""
    shape0 = tensors[0].shape
    t2 = [_as_2d(t) for t in tensors]
    m, n = t2[0].shape
    bm = min(block_rows, m)
    padded = m
    if m % bm:
        pad = bm - m % bm
        t2 = [jnp.pad(t, ((0, pad), (0, 0))) for t in t2]
        padded = m + pad
    grid = (padded // bm,)
    tile = pl.BlockSpec((bm, n), lambda i: (i, 0))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((N_SCALARS,), lambda i: (0,))] + [tile] * len(t2),
        out_specs=[tile] * n_out,
        out_shape=[jax.ShapeDtypeStruct((padded, n), jnp.float32)] * n_out,
        interpret=True,
    )(scalars, *t2)
    return [o[:m].reshape(shape0) for o in outs]


def _scalars(mask, lr, beta1=0.0, beta2=0.0, eps=0.0, wd=0.0, t=1.0, momentum=0.0):
    return jnp.stack([
        jnp.asarray(x, jnp.float32)
        for x in (mask, lr, beta1, beta2, eps, wd, t, momentum)
    ])


@functools.partial(jax.jit, static_argnames=("block_rows",))
def masked_adamw(p, g, m, v, mask, lr, beta1, beta2, eps, wd, t,
                 block_rows: int = DEFAULT_BLOCK_ROWS):
    """Fused freeze-aware AdamW via Pallas → (p', m', v')."""
    s = _scalars(mask, lr, beta1, beta2, eps, wd, t)
    po, mo, vo = _tiled_elementwise(_adamw_kernel, s, [p, g, m, v], 3, block_rows)
    return po, mo, vo


@functools.partial(jax.jit, static_argnames=("block_rows",))
def masked_sgd(p, g, mom, mask, lr, momentum, wd,
               block_rows: int = DEFAULT_BLOCK_ROWS):
    """Fused freeze-aware SGD(+momentum) via Pallas → (p', mom')."""
    s = _scalars(mask, lr, wd=wd, momentum=momentum)
    po, momo = _tiled_elementwise(_sgd_kernel, s, [p, g, mom], 2, block_rows)
    return po, momo
