"""Pallas kernel: fused GradES gradient statistics (the monitoring hot-spot).

Computes, in ONE pass over HBM, both statistics GradES needs per monitored
matrix (paper Eq. 1 + §3.1):

    gdiff = Σᵢⱼ |g_t[i,j] − g_{t−1}[i,j]|     (convergence metric)
    gabs  = Σᵢⱼ |g_t[i,j]|                    (§3.1 alternative metric)

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper reads gradients
materialized by PyTorch autograd on GPU; here the reduction is tiled for
VMEM — grid over row-tiles, both partial sums accumulated into (1,1)
output blocks that map to the same block every grid step (the canonical
TPU reduction pattern). Fusing the two stats halves HBM traffic vs two
separate reductions; the kernel is VPU/bandwidth-bound (no MXU).

``interpret=True`` everywhere: real-TPU lowering emits Mosaic custom-calls
the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-tile. VMEM budget = 2 tensors · block_rows · n · 4B; for
# n ≤ 2048 and block_rows = 128 that is ≤ 2 MiB — well inside the ~16 MiB
# VMEM of a TPU core with headroom for double-buffering.
DEFAULT_BLOCK_ROWS = 128


def _grad_stats_kernel(g_ref, p_ref, diff_ref, abs_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        diff_ref[0, 0] = 0.0
        abs_ref[0, 0] = 0.0

    g = g_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    diff_ref[0, 0] += jnp.sum(jnp.abs(g - p))
    abs_ref[0, 0] += jnp.sum(jnp.abs(g))


def _as_2d(x):
    if x.ndim == 1:
        return x.reshape(1, -1)
    if x.ndim == 2:
        return x
    return x.reshape(x.shape[0], -1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def grad_stats(g, g_prev, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Fused (‖g−g_prev‖₁, ‖g‖₁) via Pallas. Returns two f32 scalars."""
    g2, p2 = _as_2d(g), _as_2d(g_prev)
    assert g2.shape == p2.shape, (g2.shape, p2.shape)
    m, n = g2.shape
    bm = min(block_rows, m)
    # Pad rows to a multiple of the tile: |0−0| contributes nothing.
    if m % bm:
        pad = bm - m % bm
        g2 = jnp.pad(g2, ((0, pad), (0, 0)))
        p2 = jnp.pad(p2, ((0, pad), (0, 0)))
        m += pad
    diff, gabs = pl.pallas_call(
        _grad_stats_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,
    )(g2, p2)
    return diff[0, 0], gabs[0, 0]


def grad_stats_xla(g, g_prev):
    """Fast-path equivalent (XLA fuses this into one pass too)."""
    g = g.astype(jnp.float32)
    g_prev = g_prev.astype(jnp.float32)
    return jnp.sum(jnp.abs(g - g_prev)), jnp.sum(jnp.abs(g))
