"""L1 Pallas kernels (interpret=True) + pure-jnp oracles.

``impl(kind)`` returns the kernel bundle selected by a config's
``train.kernel_impl``: "pallas" (the TPU-shaped kernels, interpret mode on
CPU) or "xla" (semantically identical jnp fast path that XLA fuses).
Both are pytest-asserted equal to ``ref.py``.
"""

from . import grad_stats as _gs
from . import masked_update as _mu
from . import ref


class _PallasImpl:
    name = "pallas"
    grad_stats = staticmethod(_gs.grad_stats)
    masked_adamw = staticmethod(_mu.masked_adamw)
    masked_sgd = staticmethod(_mu.masked_sgd)


class _XlaImpl:
    name = "xla"
    grad_stats = staticmethod(_gs.grad_stats_xla)
    masked_adamw = staticmethod(ref.masked_adamw_ref)
    masked_sgd = staticmethod(ref.masked_sgd_ref)


def impl(kind: str):
    if kind == "pallas":
        return _PallasImpl
    if kind == "xla":
        return _XlaImpl
    raise ValueError(f"unknown kernel impl {kind!r}")
