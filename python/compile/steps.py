"""Step-function factory: the flat-state executables the coordinator runs.

Builds, per config:
  init(seed i32[1])                                   → state f32[S]
  train_step(state, tokens, targets[, patches], ctrl) → state f32[S]
  eval_step(state, tokens, targets[, patches])        → f32[2] (Σloss, Σcnt)
  probe(state)                                        → f32[M] metrics prefix

The train step embeds the full GradES data path (paper Alg. 1 lines 6–16):
compute grads, per-component Eq.-1 stats via the L1 kernel, freeze-masked
optimizer update, prev-grad carry — while the freeze *decisions* (lines
7–11, grace period, τ, termination) live in the rust coordinator, which
feeds the mask back through ``ctrl``.

``variant="attn_frozen"`` wraps every attention weight in stop_gradient:
XLA then genuinely omits those dW matmuls from the backward graph — the
compute-saving tier the coordinator's scheduler switches to once GradES
froze all attention components (the paper's Fig. 4a observation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernels, model
from .configs import Config
from .layout import CTRL_PAD, METRIC_PAD, Layout
from .lora import merge_lora


def unpack(state, layout: Layout, offsets: dict, names) -> dict:
    out = {}
    for name in names:
        s = layout.spec(name)
        off = offsets[name]
        out[name] = state[off : off + s.size].reshape(s.shape)
    return out


def _forward_params(trainable: dict, frozen: dict, layout: Layout) -> dict:
    cfg = layout.cfg
    if cfg.train.method == "lora":
        return merge_lora(trainable, frozen, cfg, layout.components)
    return {**frozen, **trainable}


def _logits_loss(params, cfg: Config, tokens, targets, patches):
    if cfg.model.kind == "vlm":
        logits = model.vlm_logits(params, cfg, patches, tokens)
    else:
        logits = model.lm_logits(params, cfg, tokens)
    return model.token_loss(logits, targets)


def make_init(cfg: Config, layout: Layout):
    """Assemble the initial state by ONE concatenation in layout order.

    (Perf: a dynamic-update-slice per tensor made XLA's compile of the init
    graph super-linear in tensor count — 3–5 min for LoRA configs. The
    layout is contiguous in spec order, so a single concat is equivalent
    and compiles in seconds. See EXPERIMENTS.md §Perf.)
    """

    def init(seed):
        # ONE fused RNG draw for every random parameter (a split + normal
        # per tensor made XLA compile time super-linear in tensor count for
        # LoRA layouts), then per-tensor deterministic scaling.
        key = jax.random.PRNGKey(seed[0])
        total_rand = sum(s.size for s in layout.specs)
        noise = jax.random.normal(key, (total_rand,), jnp.float32)
        parts = [jnp.zeros((layout.metrics_len,), jnp.float32)]
        off = 0
        for s in layout.specs:
            chunk = noise[off : off + s.size]
            off += s.size
            if s.init in ("embed", "head"):
                val = 0.02 * chunk
            elif s.init == "matrix":
                val = chunk / jnp.sqrt(jnp.float32(s.shape[0]))
            elif s.init == "lora_a":
                val = 0.05 * chunk
            elif s.init == "ones":
                val = jnp.ones((s.size,), jnp.float32)
            elif s.init in ("zeros", "lora_b"):
                val = jnp.zeros((s.size,), jnp.float32)
            else:
                raise ValueError(s.init)
            parts.append(val)
        tail = layout.state_len - layout.metrics_len - total_rand
        parts.append(jnp.zeros((tail,), jnp.float32))  # opt slots + prev
        return jnp.concatenate(parts)

    return init


def make_train_step(cfg: Config, layout: Layout, variant: str = "full"):
    kern = kernels.impl(cfg.train.kernel_impl)
    train_names = [s.name for s in layout.trainable_specs()]
    frozen_names = [s.name for s in layout.specs if not s.trainable]
    monitored = layout.monitored_specs()
    comp_of = {s.name: s.component for s in monitored}

    def step(state, tokens, targets, patches, ctrl):
        t = ctrl[0]
        lr = ctrl[1]
        wd_scale = ctrl[2]
        mask = ctrl[CTRL_PAD : CTRL_PAD + layout.n_components]

        trainable = unpack(state, layout, layout.param_offsets, train_names)
        frozen = unpack(state, layout, layout.param_offsets, frozen_names)

        if variant == "attn_frozen":
            # Backward graph genuinely skips attention dW matmuls.
            for name in list(trainable):
                spec = layout.spec(name)
                if spec.component is not None and \
                        layout.components[spec.component].group == "attention":
                    frozen = {**frozen, name: jax.lax.stop_gradient(trainable[name])}
                    del trainable[name]

        def loss_fn(tr):
            params = _forward_params({**tr, **{}}, {**frozen}, layout)
            loss_sum, count = _logits_loss(params, cfg, tokens, targets, patches)
            return loss_sum / jnp.maximum(count, 1.0), (loss_sum, count)

        grads, (loss_sum, count) = jax.grad(loss_fn, has_aux=True)(trainable)

        # --- GradES Eq. 1 statistics per component (L1 kernel) ---
        prev = unpack(state, layout, layout.prev_offsets,
                      [s.name for s in monitored if s.name in grads])
        gdiff = jnp.zeros((layout.n_components,), jnp.float32)
        gabs = jnp.zeros((layout.n_components,), jnp.float32)
        for name, g in grads.items():
            c = comp_of.get(name)
            if c is None or name not in prev:
                continue
            d, a = kern.grad_stats(g, prev[name])
            gdiff = gdiff.at[c].add(d)
            gabs = gabs.at[c].add(a)

        global_gnorm = sum(jnp.sum(jnp.abs(g)) for g in grads.values())

        # --- freeze-masked optimizer update + prev-grad carry ---
        # New values per tensor; the state is reassembled by ONE concat in
        # layout order (a DUS per tensor made XLA compile super-linear in
        # tensor count — see EXPERIMENTS.md §Perf).
        new_params = {}
        new_opt: dict = {slot: {} for slot in layout.opt_offsets}
        new_prev = {}
        for name, g in grads.items():
            s = layout.spec(name)
            c = comp_of.get(name)
            mval = mask[c] if c is not None else jnp.float32(1.0)
            p = trainable[name]
            wd = cfg.train.weight_decay * wd_scale
            if cfg.train.optimizer == "adamw":
                moff = layout.opt_offsets["m"][name]
                voff = layout.opt_offsets["v"][name]
                m = state[moff : moff + s.size].reshape(s.shape)
                v = state[voff : voff + s.size].reshape(s.shape)
                pn, mn, vn = kern.masked_adamw(
                    p, g, m, v, mval, lr, cfg.train.beta1, cfg.train.beta2,
                    cfg.train.eps, wd, t)
                new_opt["m"][name] = mn
                new_opt["v"][name] = vn
            else:
                momoff = layout.opt_offsets["mom"][name]
                mom = state[momoff : momoff + s.size].reshape(s.shape)
                pn, momn = kern.masked_sgd(p, g, mom, mval, lr, cfg.train.momentum, wd)
                new_opt["mom"][name] = momn
            new_params[name] = pn
            if name in prev:
                # Store ∇W_t for the next step's Eq. 1 (Alg. 1 line 16);
                # frozen components stop being monitored so keep theirs.
                new_prev[name] = mval * g.reshape(-1) + (1.0 - mval) * state[
                    layout.prev_offsets[name] : layout.prev_offsets[name] + s.size]

        metrics = jnp.concatenate([
            jnp.stack([loss_sum, count, global_gnorm, jnp.float32(0.0)]),
            gdiff,
            gabs,
        ])
        parts = [metrics]
        for s in layout.specs:  # params region, spec order
            if s.name in new_params:
                parts.append(new_params[s.name].reshape(-1))
            else:
                off = layout.param_offsets[s.name]
                parts.append(state[off : off + s.size])
        for slot in layout.opt_offsets:  # opt slots, spec order per slot
            for s in layout.specs:
                if not s.trainable:
                    continue
                if s.name in new_opt[slot]:
                    parts.append(new_opt[slot][s.name].reshape(-1))
                else:
                    off = layout.opt_offsets[slot][s.name]
                    parts.append(state[off : off + s.size])
        for s in layout.specs:  # prev-grad region, spec order
            if s.trainable and s.component is not None:
                if s.name in new_prev:
                    parts.append(new_prev[s.name].reshape(-1))
                else:
                    off = layout.prev_offsets[s.name]
                    parts.append(state[off : off + s.size])
        return jnp.concatenate(parts)

    if cfg.model.kind == "vlm":
        return lambda state, tokens, targets, patches, ctrl: step(
            state, tokens, targets, patches, ctrl)
    return lambda state, tokens, targets, ctrl: step(state, tokens, targets, None, ctrl)


def make_eval_step(cfg: Config, layout: Layout):
    all_names = [s.name for s in layout.specs]

    def ev(state, tokens, targets, patches):
        stored = unpack(state, layout, layout.param_offsets, all_names)
        trainable = {s.name: stored[s.name] for s in layout.trainable_specs()}
        frozen = {s.name: stored[s.name] for s in layout.specs if not s.trainable}
        params = _forward_params(trainable, frozen, layout)
        loss_sum, count = _logits_loss(params, cfg, tokens, targets, patches)
        return jnp.stack([loss_sum, count])

    if cfg.model.kind == "vlm":
        return lambda state, tokens, targets, patches: ev(state, tokens, targets, patches)
    return lambda state, tokens, targets: ev(state, tokens, targets, None)


def make_eval_rows(cfg: Config, layout: Layout):
    """Per-row losses for multiple-choice scoring: → f32[2B] =
    concat(per-row loss_sum, per-row valid count). Each row is one MC
    option; the rust harness argmins mean NLL across an option group."""
    all_names = [s.name for s in layout.specs]

    def ev(state, tokens, targets, patches):
        stored = unpack(state, layout, layout.param_offsets, all_names)
        trainable = {s.name: stored[s.name] for s in layout.trainable_specs()}
        frozen = {s.name: stored[s.name] for s in layout.specs if not s.trainable}
        params = _forward_params(trainable, frozen, layout)
        if cfg.model.kind == "vlm":
            logits = model.vlm_logits(params, cfg, patches, tokens)
        else:
            logits = model.lm_logits(params, cfg, tokens)
        valid = (targets >= 0).astype(jnp.float32)
        safe = jnp.maximum(targets, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return jnp.concatenate([jnp.sum(nll * valid, axis=1), jnp.sum(valid, axis=1)])

    if cfg.model.kind == "vlm":
        return lambda state, tokens, targets, patches: ev(state, tokens, targets, patches)
    return lambda state, tokens, targets: ev(state, tokens, targets, None)


def make_probe(cfg: Config, layout: Layout):
    def probe(state):
        return state[: layout.metrics_len]

    return probe
