"""Config loading shared by the AOT exporter and tests.

The same ``configs/*.toml`` files drive both the python compile path (model
shapes, method, optimizer) and the rust coordinator ([run]/[grades]/[es]/
[data] sections, which python ignores).
"""

from __future__ import annotations

import dataclasses
import pathlib

try:
    import tomllib
except ModuleNotFoundError:  # Python < 3.11
    import tomli as tomllib  # type: ignore[no-redef]

REPO_ROOT = pathlib.Path(__file__).resolve().parents[2]
CONFIG_DIR = REPO_ROOT / "configs"
ARTIFACT_DIR = REPO_ROOT / "artifacts"

ATTN_KINDS = ("q", "k", "v", "o")
MLP_KINDS = ("gate", "up", "down")
COMPONENT_KINDS = ATTN_KINDS + MLP_KINDS


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    kind: str  # "lm" | "vlm"
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int
    # vlm-only (zero for lm)
    n_patches: int = 0
    patch_dim: int = 0
    d_vision: int = 0
    n_vision_layers: int = 0
    n_vision_heads: int = 0
    d_vision_ff: int = 0

    def __post_init__(self):
        assert self.kind in ("lm", "vlm"), self.kind
        assert self.d_model % self.n_heads == 0
        if self.kind == "vlm":
            assert self.n_patches > 0 and self.patch_dim > 0
            assert self.d_vision % self.n_vision_heads == 0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def vision_head_dim(self) -> int:
        return self.d_vision // self.n_vision_heads


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    batch_size: int
    seq_len: int
    optimizer: str  # "adamw" | "sgd"
    method: str  # "fp" | "lora"
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    momentum: float = 0.9  # sgd
    lora_rank: int = 4
    lora_alpha: float = 8.0
    kernel_impl: str = "xla"  # "xla" | "pallas"

    def __post_init__(self):
        assert self.optimizer in ("adamw", "sgd"), self.optimizer
        assert self.method in ("fp", "lora"), self.method
        assert self.kernel_impl in ("xla", "pallas"), self.kernel_impl


@dataclasses.dataclass(frozen=True)
class Config:
    name: str
    model: ModelConfig
    train: TrainConfig
    raw: dict

    @property
    def artifact_dir(self) -> pathlib.Path:
        return ARTIFACT_DIR / self.name


def _model_from_dict(d: dict, v: dict | None) -> ModelConfig:
    v = v or {}
    return ModelConfig(
        kind=d.get("kind", "lm"),
        vocab_size=d["vocab_size"],
        d_model=d["d_model"],
        n_layers=d["n_layers"],
        n_heads=d["n_heads"],
        d_ff=d["d_ff"],
        max_seq=d["max_seq"],
        n_patches=v.get("n_patches", 0),
        patch_dim=v.get("patch_dim", 0),
        d_vision=v.get("d_vision", 0),
        n_vision_layers=v.get("n_vision_layers", 0),
        n_vision_heads=v.get("n_vision_heads", 1),
        d_vision_ff=v.get("d_vision_ff", 0),
    )


def load_config(path: str | pathlib.Path) -> Config:
    path = pathlib.Path(path)
    with open(path, "rb") as f:
        raw = tomllib.load(f)
    model = _model_from_dict(raw["model"], raw.get("vlm"))
    t = raw["train"]
    train = TrainConfig(
        batch_size=t["batch_size"],
        seq_len=t["seq_len"],
        optimizer=t.get("optimizer", "adamw"),
        method=t.get("method", "fp"),
        weight_decay=t.get("weight_decay", 0.01),
        beta1=t.get("beta1", 0.9),
        beta2=t.get("beta2", 0.999),
        eps=t.get("eps", 1e-8),
        momentum=t.get("momentum", 0.9),
        lora_rank=t.get("lora_rank", 4),
        lora_alpha=t.get("lora_alpha", 8.0),
        kernel_impl=t.get("kernel_impl", "xla"),
    )
    name = raw.get("name", path.stem)
    assert model.max_seq >= train.seq_len, "seq_len exceeds max_seq"
    return Config(name=name, model=model, train=train, raw=raw)


def load_by_name(name: str) -> Config:
    return load_config(CONFIG_DIR / f"{name}.toml")


def all_config_paths() -> list[pathlib.Path]:
    return sorted(CONFIG_DIR.glob("*.toml"))
