"""AOT exporter: jax → HLO *text* artifacts + manifest.json per config.

HLO text (never ``.serialize()``): xla_extension 0.5.1 rejects jax≥0.5
protos with 64-bit instruction ids; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:
  python -m compile.aot                 # export every configs/*.toml
  python -m compile.aot --config lm-tiny-fp [--force]

Exports are skipped when the artifact dir is newer than the config and the
compile/ sources (make-style staleness check), so ``make artifacts`` is a
no-op on an unchanged tree.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, steps
from .configs import Config
from .layout import CTRL_PAD, METRIC_PAD, Layout, build_layout, flops_summary

COMPILE_DIR = pathlib.Path(__file__).resolve().parent


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _arg_specs(cfg: Config, layout: Layout, which: str):
    m, t = cfg.model, cfg.train
    f32, i32 = jnp.float32, jnp.int32
    state = jax.ShapeDtypeStruct((layout.state_len,), f32)
    tokens = jax.ShapeDtypeStruct((t.batch_size, t.seq_len), i32)
    targets = jax.ShapeDtypeStruct((t.batch_size, t.seq_len), i32)
    ctrl = jax.ShapeDtypeStruct((layout.ctrl_len,), f32)
    patches = jax.ShapeDtypeStruct((t.batch_size, m.n_patches, m.patch_dim), f32)
    if which == "init":
        return [jax.ShapeDtypeStruct((1,), i32)]
    if which == "probe":
        return [state]
    if which == "train":
        if m.kind == "vlm":
            return [state, tokens, targets, patches, ctrl]
        return [state, tokens, targets, ctrl]
    if which == "eval":
        if m.kind == "vlm":
            return [state, tokens, targets, patches]
        return [state, tokens, targets]
    raise ValueError(which)


def build_manifest(cfg: Config, layout: Layout, executables: dict) -> dict:
    m, t = cfg.model, cfg.train
    input_names = {
        "init": ["seed"],
        "probe": ["state"],
        "train": (["state", "tokens", "targets", "patches", "ctrl"]
                  if m.kind == "vlm" else ["state", "tokens", "targets", "ctrl"]),
        "eval": (["state", "tokens", "targets", "patches"]
                 if m.kind == "vlm" else ["state", "tokens", "targets"]),
    }
    return {
        "name": cfg.name,
        "kind": m.kind,
        "method": t.method,
        "optimizer": t.optimizer,
        "kernel_impl": t.kernel_impl,
        "batch_size": t.batch_size,
        "seq_len": t.seq_len,
        "vocab_size": m.vocab_size,
        "model": {
            "d_model": m.d_model, "n_layers": m.n_layers, "n_heads": m.n_heads,
            "d_ff": m.d_ff, "max_seq": m.max_seq,
            "n_patches": m.n_patches, "patch_dim": m.patch_dim,
            "d_vision": m.d_vision, "n_vision_layers": m.n_vision_layers,
        },
        "state_len": layout.state_len,
        "metrics_len": layout.metrics_len,
        "ctrl_len": layout.ctrl_len,
        "n_components": layout.n_components,
        "metrics": {
            "loss_sum": 0, "token_count": 1, "global_gnorm": 2,
            "gdiff_offset": METRIC_PAD,
            "gabs_offset": layout.gabs_offset,
        },
        "ctrl": {"step": 0, "lr": 1, "wd_scale": 2, "mask_offset": CTRL_PAD},
        "components": [
            {
                "idx": c.idx, "name": c.name, "layer": c.layer, "kind": c.kind,
                "group": c.group, "tower": c.tower, "n_params": c.n_params,
                "tensors": list(c.tensors),
            }
            for c in layout.components
        ],
        "params": [
            {
                "name": s.name, "shape": list(s.shape),
                "offset": layout.param_offsets[s.name],
                "trainable": s.trainable, "component": s.component,
            }
            for s in layout.specs
        ],
        "n_params_total": sum(s.size for s in layout.specs),
        "n_params_trainable": sum(s.size for s in layout.trainable_specs()),
        "flops": flops_summary(cfg, layout),
        "executables": executables,
        "inputs": input_names,
    }


def export_config(cfg: Config, force: bool = False) -> bool:
    out_dir = cfg.artifact_dir
    stamp = out_dir / "manifest.json"
    src_mtime = max(
        p.stat().st_mtime
        for p in [*COMPILE_DIR.rglob("*.py"),
                  configs.CONFIG_DIR / f"{cfg.name}.toml"]
    )
    if not force and stamp.exists() and stamp.stat().st_mtime >= src_mtime:
        print(f"[aot] {cfg.name}: up to date")
        return False
    out_dir.mkdir(parents=True, exist_ok=True)
    layout = build_layout(cfg)

    fns = {
        "init": steps.make_init(cfg, layout),
        "train_step": steps.make_train_step(cfg, layout, "full"),
        "train_step_attn_frozen": steps.make_train_step(cfg, layout, "attn_frozen"),
        "eval_step": steps.make_eval_step(cfg, layout),
        "eval_rows": steps.make_eval_rows(cfg, layout),
        "probe": steps.make_probe(cfg, layout),
    }
    which = {"init": "init", "train_step": "train", "train_step_attn_frozen": "train",
             "eval_step": "eval", "eval_rows": "eval", "probe": "probe"}
    executables = {}
    for name, fn in fns.items():
        specs = _arg_specs(cfg, layout, which[name])
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        (out_dir / fname).write_text(text)
        executables[name] = fname
        print(f"[aot] {cfg.name}/{fname}: {len(text)/1e6:.2f} MB")

    manifest = build_manifest(cfg, layout, executables)
    stamp.write_text(json.dumps(manifest, indent=1))
    print(f"[aot] {cfg.name}: state_len={layout.state_len} "
          f"components={layout.n_components} params={manifest['n_params_total']}")
    return True


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", help="config name (default: all)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    if args.config:
        cfgs = [configs.load_by_name(args.config)]
    else:
        cfgs = [configs.load_config(p) for p in configs.all_config_paths()]
    for cfg in cfgs:
        export_config(cfg, force=args.force)
    return 0


if __name__ == "__main__":
    sys.exit(main())
