"""State-vector layout: parameter specs, component registry, offsets.

Everything the model owns lives in one flat ``f32[S]`` vector so the rust
coordinator can keep it on-device across steps (see DESIGN.md — the xla
crate returns multi-output tuples as one undecomposable buffer, so every
executable is single-input-state → single-output-state):

    state = [ metrics M | params | opt slot(s) | prev_grads ]

* ``metrics`` = [loss_sum, token_count, global_gnorm, pad, Gdiff[C], Gabs[C]]
* ``params``  = every tensor (trainable or frozen) in spec order
* opt slots   = adamw: (m, v) per *trainable* tensor; sgd: momentum slot
* prev_grads  = one slot per *monitored* tensor (GradES Eq. 1 carry)

The GradES *component* is the paper's unit of freezing: one of the 7
projection matrices {q,k,v,o,gate,up,down} in one layer (for LoRA, the
(A, B) pair adapting that matrix — Eq. 3 sums both gradients).
"""

from __future__ import annotations

import dataclasses
import math

from . import configs
from .configs import ATTN_KINDS, COMPONENT_KINDS, Config

METRIC_PAD = 4  # [loss_sum, token_count, global_gnorm, reserved]
CTRL_PAD = 4  # [step, lr, wd_scale, reserved]


@dataclasses.dataclass(frozen=True)
class Component:
    idx: int
    name: str  # e.g. "language.3.up"
    layer: int
    kind: str  # q|k|v|o|gate|up|down
    group: str  # "attention" | "mlp"
    tower: str  # "language" | "vision"
    tensors: tuple[str, ...]  # param names whose grads this component monitors
    n_params: int


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple[int, ...]
    trainable: bool
    component: int | None  # component idx if monitored
    init: str  # embed|matrix|ones|zeros|lora_a|lora_b|head

    @property
    def size(self) -> int:
        return math.prod(self.shape)


def _tower_specs(prefix: str, n_layers: int, d: int, d_ff: int, specs, comps, tower: str):
    """Append one transformer tower's per-layer specs + components."""
    kind_shapes = {
        "q": (d, d),
        "k": (d, d),
        "v": (d, d),
        "o": (d, d),
        "gate": (d, d_ff),
        "up": (d, d_ff),
        "down": (d_ff, d),
    }
    for layer in range(n_layers):
        specs.append(ParamSpec(f"{prefix}.{layer}.ln1", (d,), True, None, "ones"))
        for kind in ("q", "k", "v", "o"):
            cidx = len(comps)
            name = f"{prefix}.{layer}.attn.{kind}"
            comps.append(
                Component(cidx, f"{tower}.{layer}.{kind}", layer, kind, "attention",
                          tower, (name,), math.prod(kind_shapes[kind]))
            )
            specs.append(ParamSpec(name, kind_shapes[kind], True, cidx, "matrix"))
        specs.append(ParamSpec(f"{prefix}.{layer}.ln2", (d,), True, None, "ones"))
        for kind in ("gate", "up", "down"):
            cidx = len(comps)
            name = f"{prefix}.{layer}.mlp.{kind}"
            comps.append(
                Component(cidx, f"{tower}.{layer}.{kind}", layer, kind, "mlp",
                          tower, (name,), math.prod(kind_shapes[kind]))
            )
            specs.append(ParamSpec(name, kind_shapes[kind], True, cidx, "matrix"))


def base_param_specs(cfg: Config) -> tuple[list[ParamSpec], list[Component]]:
    """Full-parameter specs + component registry for lm or vlm."""
    m = cfg.model
    specs: list[ParamSpec] = []
    comps: list[Component] = []
    if m.kind == "vlm":
        specs.append(ParamSpec("vis_in", (m.patch_dim, m.d_vision), True, None, "matrix"))
        specs.append(ParamSpec("vis_pos", (m.n_patches, m.d_vision), True, None, "embed"))
        _tower_specs("vis", m.n_vision_layers, m.d_vision, m.d_vision_ff, specs, comps, "vision")
        specs.append(ParamSpec("vis_ln_f", (m.d_vision,), True, None, "ones"))
        specs.append(ParamSpec("vis_proj", (m.d_vision, m.d_model), True, None, "matrix"))
    specs.append(ParamSpec("tok_emb", (m.vocab_size, m.d_model), True, None, "embed"))
    total_seq = m.max_seq + (m.n_patches if m.kind == "vlm" else 0)
    specs.append(ParamSpec("pos_emb", (total_seq, m.d_model), True, None, "embed"))
    _tower_specs("lang", m.n_layers, m.d_model, m.d_ff, specs, comps, "language")
    specs.append(ParamSpec("ln_f", (m.d_model,), True, None, "ones"))
    specs.append(ParamSpec("lm_head", (m.d_model, m.vocab_size), True, None, "head"))
    return specs, comps


def lora_param_specs(cfg: Config) -> tuple[list[ParamSpec], list[Component]]:
    """LoRA: base params frozen; per-component (A, B) adapters trainable.

    For matrix W: [d_in, d_out], A: [d_in, r], B: [r, d_out]; the adapted
    weight is W + (alpha/r) · A @ B (Eq. 2 transposed to x@W layout).
    """
    base, comps = base_param_specs(cfg)
    r = cfg.train.lora_rank
    specs = [dataclasses.replace(s, trainable=False, component=None) for s in base]
    name_to_spec = {s.name: s for s in base}
    new_comps: list[Component] = []
    for c in comps:
        (wname,) = c.tensors
        d_in, d_out = name_to_spec[wname].shape
        a_name, b_name = f"{wname}.lora_a", f"{wname}.lora_b"
        new_comps.append(dataclasses.replace(
            c, tensors=(a_name, b_name), n_params=r * (d_in + d_out)))
        specs.append(ParamSpec(a_name, (d_in, r), True, c.idx, "lora_a"))
        specs.append(ParamSpec(b_name, (r, d_out), True, c.idx, "lora_b"))
    return specs, new_comps


@dataclasses.dataclass(frozen=True)
class Layout:
    cfg: Config
    specs: tuple[ParamSpec, ...]
    components: tuple[Component, ...]
    metrics_len: int
    ctrl_len: int
    param_offsets: dict  # name -> offset in flat state
    opt_offsets: dict  # slot -> {name -> offset}; slots: "m","v" or "mom"
    prev_offsets: dict  # name -> offset (monitored tensors only)
    state_len: int

    @property
    def n_components(self) -> int:
        return len(self.components)

    @property
    def gdiff_offset(self) -> int:
        return METRIC_PAD

    @property
    def gabs_offset(self) -> int:
        return METRIC_PAD + self.n_components

    @property
    def mask_offset(self) -> int:
        return CTRL_PAD

    def trainable_specs(self) -> list[ParamSpec]:
        return [s for s in self.specs if s.trainable]

    def monitored_specs(self) -> list[ParamSpec]:
        return [s for s in self.specs if s.trainable and s.component is not None]

    def spec(self, name: str) -> ParamSpec:
        return next(s for s in self.specs if s.name == name)


def build_layout(cfg: Config) -> Layout:
    if cfg.train.method == "lora":
        specs, comps = lora_param_specs(cfg)
    else:
        specs, comps = base_param_specs(cfg)
    n_c = len(comps)
    metrics_len = METRIC_PAD + 2 * n_c
    ctrl_len = CTRL_PAD + n_c

    off = metrics_len
    param_offsets = {}
    for s in specs:
        param_offsets[s.name] = off
        off += s.size

    opt_slots = ("m", "v") if cfg.train.optimizer == "adamw" else ("mom",)
    opt_offsets: dict = {slot: {} for slot in opt_slots}
    for slot in opt_slots:
        for s in specs:
            if s.trainable:
                opt_offsets[slot][s.name] = off
                off += s.size

    prev_offsets = {}
    for s in specs:
        if s.trainable and s.component is not None:
            prev_offsets[s.name] = off
            off += s.size

    return Layout(
        cfg=cfg,
        specs=tuple(specs),
        components=tuple(comps),
        metrics_len=metrics_len,
        ctrl_len=ctrl_len,
        param_offsets=param_offsets,
        opt_offsets=opt_offsets,
        prev_offsets=prev_offsets,
        state_len=off,
    )


def flops_summary(cfg: Config, layout: Layout) -> dict:
    """Analytic per-token matmul FLOPs, component-resolved.

    For x@W with W:[a,b]: fwd = 2ab/token, bwd dX = 2ab, bwd dW = 2ab.
    Attention score/context matmuls add 4·T·d per layer per token. The rust
    FLOPs model composes these with the live freeze state.
    """
    m = cfg.model
    per_component_fwd = {}
    for c in layout.components:
        f = 0
        for t in c.tensors:
            f += 2 * layout.spec(t).size
        per_component_fwd[c.name] = f
    lang_attn_quad = 4 * cfg.train.seq_len * m.d_model * m.n_layers
    vis_attn_quad = 0
    if m.kind == "vlm":
        vis_attn_quad = 4 * m.n_patches * m.d_vision * m.n_vision_layers
    head = 2 * m.d_model * m.vocab_size
    embed_proj = 2 * m.patch_dim * m.d_vision + 2 * m.d_vision * m.d_model if m.kind == "vlm" else 0
    comp_total = sum(per_component_fwd.values())
    fwd_per_token = comp_total + lang_attn_quad + vis_attn_quad + head + embed_proj
    return {
        "fwd_per_token": fwd_per_token,
        "bwd_dx_per_token": fwd_per_token,  # symmetric estimate
        "per_component_fwd": per_component_fwd,  # dW cost per token == this
        "attn_quadratic_per_token": lang_attn_quad + vis_attn_quad,
        "head_per_token": head,
    }
