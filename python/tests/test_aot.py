"""AOT export invariants: manifests agree with layouts, HLO text loadable."""

import json

import pytest

from compile import configs
from compile.aot import build_manifest
from compile.layout import build_layout


@pytest.mark.parametrize("name", ["lm-tiny-fp", "lm-tiny-lora", "vlm-tiny-fp"])
def test_manifest_matches_layout(name):
    cfg = configs.load_by_name(name)
    layout = build_layout(cfg)
    man = build_manifest(cfg, layout, {})
    assert man["state_len"] == layout.state_len
    assert man["n_components"] == layout.n_components
    assert man["metrics_len"] == layout.metrics_len
    assert len(man["params"]) == len(layout.specs)
    # param offsets strictly increasing and inside the state
    offsets = [p["offset"] for p in man["params"]]
    assert offsets == sorted(offsets)
    assert offsets[0] == layout.metrics_len
    for p in man["params"]:
        import math
        assert p["offset"] + math.prod(p["shape"]) <= layout.state_len


def test_manifest_component_tensor_names_exist():
    cfg = configs.load_by_name("lm-tiny-lora")
    layout = build_layout(cfg)
    man = build_manifest(cfg, layout, {})
    param_names = {p["name"] for p in man["params"]}
    for c in man["components"]:
        for t in c["tensors"]:
            assert t in param_names
        assert c["tensors"][0].endswith(".lora_a")


def test_flops_positive_and_monotone_in_scale():
    tiny = configs.load_by_name("lm-tiny-fp")
    small = configs.load_by_name("lm-small-fp")
    ft = build_manifest(tiny, build_layout(tiny), {})["flops"]
    fs = build_manifest(small, build_layout(small), {})["flops"]
    assert 0 < ft["fwd_per_token"] < fs["fwd_per_token"]


def test_exported_artifacts_consistent_with_source(tmp_path):
    """If artifacts exist on disk, their manifests must round-trip as JSON
    and agree with a freshly built layout."""
    for name in ["lm-tiny-fp"]:
        cfg = configs.load_by_name(name)
        mpath = cfg.artifact_dir / "manifest.json"
        if not mpath.exists():
            pytest.skip("artifacts not built")
        man = json.loads(mpath.read_text())
        layout = build_layout(cfg)
        assert man["state_len"] == layout.state_len
        assert man["n_components"] == layout.n_components
        for exe in man["executables"].values():
            text = (cfg.artifact_dir / exe).read_text()
            assert text.startswith("HloModule"), exe


def test_vlm_manifest_has_towers():
    cfg = configs.load_by_name("vlm-tiny-fp")
    layout = build_layout(cfg)
    man = build_manifest(cfg, layout, {})
    towers = {c["tower"] for c in man["components"]}
    assert towers == {"vision", "language"}
    n_vis = sum(1 for c in man["components"] if c["tower"] == "vision")
    assert n_vis == 7 * cfg.model.n_vision_layers
