"""L1 kernel correctness: Pallas (interpret) and fused-XLA vs ref oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py. This is
the core correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import grad_stats as gs
from compile.kernels import masked_update as mu
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(dtype)
    return jnp.asarray(x)


shapes_2d = st.tuples(st.integers(1, 300), st.integers(1, 65))
dtypes = st.sampled_from([np.float32, jnp.bfloat16])


@settings(max_examples=25, deadline=None)
@given(shape=shapes_2d, dtype=dtypes, seed=st.integers(0, 2**31 - 1))
def test_grad_stats_pallas_matches_ref(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    g = rand(rng, shape, dtype)
    p = rand(rng, shape, dtype)
    d_ref, a_ref = ref.grad_stats_ref(g, p)
    d, a = gs.grad_stats(g, p)
    np.testing.assert_allclose(d, d_ref, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(a, a_ref, rtol=2e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(shape=shapes_2d, seed=st.integers(0, 2**31 - 1))
def test_grad_stats_xla_matches_ref(shape, seed):
    rng = np.random.default_rng(seed)
    g = rand(rng, shape, np.float32)
    p = rand(rng, shape, np.float32)
    d_ref, a_ref = ref.grad_stats_ref(g, p)
    d, a = gs.grad_stats_xla(g, p)
    np.testing.assert_allclose(d, d_ref, rtol=1e-6)
    np.testing.assert_allclose(a, a_ref, rtol=1e-6)


@pytest.mark.parametrize("shape", [(7,), (1, 1), (128, 64), (129, 3), (4, 2, 6)])
def test_grad_stats_shape_classes(shape):
    rng = np.random.default_rng(0)
    g = rand(rng, shape, np.float32)
    p = rand(rng, shape, np.float32)
    d_ref, a_ref = ref.grad_stats_ref(g, p)
    d, a = gs.grad_stats(g, p)
    np.testing.assert_allclose(d, d_ref, rtol=2e-5)
    np.testing.assert_allclose(a, a_ref, rtol=2e-5)


def test_grad_stats_zero_diff():
    g = jnp.ones((130, 7))  # forces row padding
    d, a = gs.grad_stats(g, g)
    assert float(d) == 0.0
    np.testing.assert_allclose(a, 130 * 7, rtol=1e-6)


@pytest.mark.parametrize("block_rows", [32, 128, 512])
def test_grad_stats_block_shape_invariant(block_rows):
    rng = np.random.default_rng(3)
    g = rand(rng, (257, 33), np.float32)
    p = rand(rng, (257, 33), np.float32)
    d, a = gs.grad_stats(g, p, block_rows=block_rows)
    d_ref, a_ref = ref.grad_stats_ref(g, p)
    np.testing.assert_allclose(d, d_ref, rtol=2e-5)
    np.testing.assert_allclose(a, a_ref, rtol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    shape=shapes_2d,
    mask=st.sampled_from([0.0, 1.0]),
    t=st.integers(1, 1000),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_adamw_matches_ref(shape, mask, t, seed):
    rng = np.random.default_rng(seed)
    p = rand(rng, shape, np.float32)
    g = rand(rng, shape, np.float32)
    m = rand(rng, shape, np.float32) * 0.1
    v = jnp.abs(rand(rng, shape, np.float32)) * 0.01
    args = (p, g, m, v, mask, 1e-3, 0.9, 0.999, 1e-8, 0.01, float(t))
    p1, m1, v1 = mu.masked_adamw(*args)
    p2, m2, v2 = ref.masked_adamw_ref(*args)
    np.testing.assert_allclose(p1, p2, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(m1, m2, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(v1, v2, rtol=2e-5, atol=1e-6)


def test_masked_adamw_frozen_is_identity():
    rng = np.random.default_rng(1)
    p = rand(rng, (65, 33), np.float32)
    g = rand(rng, (65, 33), np.float32)
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    p1, m1, v1 = mu.masked_adamw(p, g, m, v, 0.0, 1e-2, 0.9, 0.999, 1e-8, 0.1, 1.0)
    np.testing.assert_array_equal(p1, p)
    np.testing.assert_array_equal(m1, m)
    np.testing.assert_array_equal(v1, v)


@settings(max_examples=15, deadline=None)
@given(shape=shapes_2d, mask=st.sampled_from([0.0, 1.0]), seed=st.integers(0, 2**31 - 1))
def test_masked_sgd_matches_ref(shape, mask, seed):
    rng = np.random.default_rng(seed)
    p = rand(rng, shape, np.float32)
    g = rand(rng, shape, np.float32)
    mom = rand(rng, shape, np.float32) * 0.1
    args = (p, g, mom, mask, 1e-2, 0.9, 0.01)
    p1, m1 = mu.masked_sgd(*args)
    p2, m2 = ref.masked_sgd_ref(*args)
    np.testing.assert_allclose(p1, p2, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(m1, m2, rtol=2e-5, atol=1e-6)


def test_adamw_bias_correction_direction():
    """First step with zero moments must move p against the gradient sign."""
    p = jnp.zeros((8, 8))
    g = jnp.ones((8, 8))
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    p1, _, _ = mu.masked_adamw(p, g, m, v, 1.0, 1e-3, 0.9, 0.999, 1e-8, 0.0, 1.0)
    assert float(jnp.max(p1)) < 0.0
    np.testing.assert_allclose(p1, -1e-3 * jnp.ones_like(p), rtol=1e-3)
