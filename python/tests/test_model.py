"""L2 model/layout correctness: shapes, loss, masking, LoRA, VLM."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, steps
from compile.layout import build_layout
from compile.lora import merge_lora

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def cfg():
    return configs.load_by_name("lm-tiny-fp")


@pytest.fixture(scope="module")
def layout(cfg):
    return build_layout(cfg)


@pytest.fixture(scope="module")
def params(cfg, layout):
    return model.init_params(cfg, layout.specs, jax.random.PRNGKey(0))


def test_layout_component_registry(cfg, layout):
    assert layout.n_components == 7 * cfg.model.n_layers
    kinds = [c.kind for c in layout.components[:7]]
    assert kinds == ["q", "k", "v", "o", "gate", "up", "down"]
    groups = {c.group for c in layout.components}
    assert groups == {"attention", "mlp"}


def test_layout_offsets_disjoint(layout):
    """Every region occupies a unique, gap-free span of the state."""
    spans = []
    for s in layout.specs:
        spans.append((layout.param_offsets[s.name], s.size))
    for slot in layout.opt_offsets.values():
        for name, off in slot.items():
            spans.append((off, layout.spec(name).size))
    for name, off in layout.prev_offsets.items():
        spans.append((off, layout.spec(name).size))
    spans.sort()
    pos = layout.metrics_len
    for off, size in spans:
        assert off == pos, f"gap/overlap at {off} (expected {pos})"
        pos += size
    assert pos == layout.state_len


def test_lm_logits_shape(cfg, params):
    B, T = 3, 17
    tokens = jnp.zeros((B, T), jnp.int32)
    logits = model.lm_logits(params, cfg, tokens)
    assert logits.shape == (B, T, cfg.model.vocab_size)


def test_causality(cfg, params):
    """Changing a future token must not affect past logits."""
    T = 12
    rng = np.random.default_rng(0)
    t1 = jnp.asarray(rng.integers(0, cfg.model.vocab_size, (1, T)), jnp.int32)
    t2 = t1.at[0, -1].set((int(t1[0, -1]) + 1) % cfg.model.vocab_size)
    l1 = model.lm_logits(params, cfg, t1)
    l2 = model.lm_logits(params, cfg, t2)
    np.testing.assert_allclose(l1[:, : T - 1], l2[:, : T - 1], atol=1e-5)
    assert not np.allclose(l1[:, -1], l2[:, -1])


def test_token_loss_masks_padding():
    logits = jnp.zeros((1, 4, 10))
    targets = jnp.array([[1, 2, -1, -1]], jnp.int32)
    loss, count = model.token_loss(logits, targets)
    assert float(count) == 2.0
    np.testing.assert_allclose(loss, 2 * np.log(10), rtol=1e-5)


def test_loss_decreases_under_sgd_steps(cfg, layout):
    """Full train step must reduce loss on a repeated batch."""
    init = jax.jit(steps.make_init(cfg, layout))
    step = jax.jit(steps.make_train_step(cfg, layout))
    state = init(jnp.array([7], jnp.int32))
    rng = np.random.default_rng(0)
    B, T = cfg.train.batch_size, cfg.train.seq_len
    tokens = jnp.asarray(rng.integers(0, cfg.model.vocab_size, (B, T)), jnp.int32)
    ctrl = np.zeros(layout.ctrl_len, np.float32)
    ctrl[1] = 3e-3
    ctrl[2] = 1.0
    ctrl[4:] = 1.0
    losses = []
    for t in range(1, 9):
        ctrl[0] = t
        state = step(state, tokens, tokens, jnp.asarray(ctrl))
        losses.append(float(state[0] / state[1]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_lora_merge_identity_when_b_zero(cfg, layout):
    lcfg = dataclasses.replace(
        cfg, train=dataclasses.replace(cfg.train, method="lora"))
    llayout = build_layout(lcfg)
    p = model.init_params(lcfg, llayout.specs, jax.random.PRNGKey(1))
    trainable = {s.name: p[s.name] for s in llayout.trainable_specs()}
    frozen = {s.name: p[s.name] for s in llayout.specs if not s.trainable}
    merged = merge_lora(trainable, frozen, lcfg, llayout.components)
    # B init = 0 → adapted weights equal the base weights
    for c in llayout.components:
        wname = c.tensors[0][: -len(".lora_a")]
        np.testing.assert_array_equal(merged[wname], frozen[wname])
    # adapters must not leak into the merged forward params
    assert not any(k.endswith(".lora_a") or k.endswith(".lora_b") for k in merged)


def test_vlm_logits_shape():
    vcfg = configs.load_by_name("vlm-tiny-fp")
    vlayout = build_layout(vcfg)
    p = model.init_params(vcfg, vlayout.specs, jax.random.PRNGKey(2))
    B, P, T = 2, vcfg.model.n_patches, 9
    patches = jnp.zeros((B, P, vcfg.model.patch_dim))
    tokens = jnp.zeros((B, T), jnp.int32)
    logits = model.vlm_logits(p, vcfg, patches, tokens)
    assert logits.shape == (B, T, vcfg.model.vocab_size)


def test_vlm_vision_affects_text_logits():
    vcfg = configs.load_by_name("vlm-tiny-fp")
    vlayout = build_layout(vcfg)
    p = model.init_params(vcfg, vlayout.specs, jax.random.PRNGKey(3))
    B, P, T = 1, vcfg.model.n_patches, 5
    tokens = jnp.zeros((B, T), jnp.int32)
    l0 = model.vlm_logits(p, vcfg, jnp.zeros((B, P, vcfg.model.patch_dim)), tokens)
    l1 = model.vlm_logits(p, vcfg, jnp.ones((B, P, vcfg.model.patch_dim)), tokens)
    assert not np.allclose(l0, l1)


def test_vocab_partition_matches_rust_expectations(cfg):
    """vocab_size in configs must be >= 128 (rust Vocab::build contract)."""
    for path in configs.all_config_paths():
        c = configs.load_config(path)
        assert c.model.vocab_size >= 128
