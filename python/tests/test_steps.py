"""Step-function semantics: freeze masking, Eq. 1 stats, variants, probe."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, steps
from compile.layout import METRIC_PAD, build_layout

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def env():
    cfg = configs.load_by_name("lm-tiny-fp")
    layout = build_layout(cfg)
    init = jax.jit(steps.make_init(cfg, layout))
    step = jax.jit(steps.make_train_step(cfg, layout))
    state = init(jnp.array([42], jnp.int32))
    rng = np.random.default_rng(0)
    B, T = cfg.train.batch_size, cfg.train.seq_len
    tokens = jnp.asarray(rng.integers(0, cfg.model.vocab_size, (B, T)), jnp.int32)
    return cfg, layout, step, state, tokens


def ctrl_vec(layout, t=1.0, lr=1e-3, mask=1.0):
    c = np.zeros(layout.ctrl_len, np.float32)
    c[0], c[1], c[2] = t, lr, 1.0
    c[4:] = mask
    return jnp.asarray(c)


def test_mask_zero_freezes_everything_but_other_params(env):
    cfg, layout, step, state, tokens = env
    s1 = step(state, tokens, tokens, ctrl_vec(layout, mask=0.0))
    for spec in layout.monitored_specs():
        off = layout.param_offsets[spec.name]
        assert bool(jnp.all(s1[off : off + spec.size] == state[off : off + spec.size])), spec.name
    # non-monitored params (embeddings, norms, head) still update
    emb = layout.spec("tok_emb")
    off = layout.param_offsets["tok_emb"]
    assert bool(jnp.any(s1[off : off + emb.size] != state[off : off + emb.size]))


def test_gdiff_first_step_equals_gabs(env):
    """prev_grads start at zero, so Gdiff(1) == Gabs(1) exactly."""
    cfg, layout, step, state, tokens = env
    s1 = step(state, tokens, tokens, ctrl_vec(layout))
    C = layout.n_components
    gdiff = s1[METRIC_PAD : METRIC_PAD + C]
    gabs = s1[layout.gabs_offset : layout.gabs_offset + C]
    np.testing.assert_allclose(gdiff, gabs, rtol=1e-6)


def test_gdiff_second_step_smaller_than_sum(env):
    """Gdiff(2) = |g2 - g1| ≤ |g2| + |g1| and typically ≪ on the same batch."""
    cfg, layout, step, state, tokens = env
    s1 = step(state, tokens, tokens, ctrl_vec(layout, t=1))
    s2 = step(s1, tokens, tokens, ctrl_vec(layout, t=2))
    C = layout.n_components
    gdiff2 = np.asarray(s2[METRIC_PAD : METRIC_PAD + C])
    gabs2 = np.asarray(s2[layout.gabs_offset : layout.gabs_offset + C])
    gabs1 = np.asarray(s1[layout.gabs_offset : layout.gabs_offset + C])
    assert (gdiff2 <= gabs2 + gabs1 + 1e-4).all()
    # same batch twice → consecutive grads correlated → diff < abs sum / 2
    assert gdiff2.mean() < (gabs1 + gabs2).mean() / 2


def test_prev_grad_not_updated_when_frozen(env):
    cfg, layout, step, state, tokens = env
    s1 = step(state, tokens, tokens, ctrl_vec(layout, t=1))
    # freeze component 0 and step again: its prev_grad slot must not move
    c0_tensors = layout.components[0].tensors
    ctrl = np.asarray(ctrl_vec(layout, t=2)).copy()
    ctrl[4 + 0] = 0.0
    s2 = step(s1, tokens, tokens, jnp.asarray(ctrl))
    for name in c0_tensors:
        off = layout.prev_offsets[name]
        size = layout.spec(name).size
        np.testing.assert_array_equal(s2[off : off + size], s1[off : off + size])


def test_probe_returns_metrics_prefix(env):
    cfg, layout, step, state, tokens = env
    probe = jax.jit(steps.make_probe(cfg, layout))
    s1 = step(state, tokens, tokens, ctrl_vec(layout))
    np.testing.assert_array_equal(probe(s1), s1[: layout.metrics_len])


def test_eval_step_matches_train_loss_metrics(env):
    """eval_step on the same params/batch reproduces the train-step loss
    computed *before* the update — so compare against a zero-lr step."""
    cfg, layout, step, state, tokens = env
    ev = jax.jit(steps.make_eval_step(cfg, layout))
    s1 = step(state, tokens, tokens, ctrl_vec(layout, lr=0.0))
    out = ev(state, tokens, tokens)
    np.testing.assert_allclose(out[0], s1[0], rtol=1e-5)
    np.testing.assert_allclose(out[1], s1[1], rtol=1e-6)


def test_eval_rows_sums_to_eval_step(env):
    cfg, layout, step, state, tokens = env
    ev = jax.jit(steps.make_eval_step(cfg, layout))
    rows = jax.jit(steps.make_eval_rows(cfg, layout))
    total = ev(state, tokens, tokens)
    per_row = rows(state, tokens, tokens)
    B = cfg.train.batch_size
    np.testing.assert_allclose(jnp.sum(per_row[:B]), total[0], rtol=1e-5)
    np.testing.assert_allclose(jnp.sum(per_row[B:]), total[1], rtol=1e-6)


def test_attn_frozen_variant_consistency(env):
    """attn-frozen step == full step with attention mask entries zeroed."""
    cfg, layout, step, state, tokens = env
    stepf = jax.jit(steps.make_train_step(cfg, layout, "attn_frozen"))
    ctrl = np.asarray(ctrl_vec(layout, t=1)).copy()
    for c in layout.components:
        if c.group == "attention":
            ctrl[4 + c.idx] = 0.0
    s_masked = step(state, tokens, tokens, jnp.asarray(ctrl))
    s_variant = stepf(state, tokens, tokens, ctrl_vec(layout, t=1))
    # parameters must agree (metrics differ: variant reports 0 for attn)
    off0 = layout.metrics_len
    np.testing.assert_allclose(
        s_masked[off0:], s_variant[off0:], rtol=2e-4, atol=2e-6
    )


def test_sgd_step_runs():
    base = configs.load_by_name("lm-tiny-sgd")
    layout = build_layout(base)
    init = jax.jit(steps.make_init(base, layout))
    step = jax.jit(steps.make_train_step(base, layout))
    state = init(jnp.array([1], jnp.int32))
    tokens = jnp.zeros((base.train.batch_size, base.train.seq_len), jnp.int32)
    c = np.zeros(layout.ctrl_len, np.float32)
    c[0], c[1], c[2] = 1.0, 1e-2, 1.0
    c[4:] = 1.0
    s1 = step(state, tokens, tokens, jnp.asarray(c))
    assert float(s1[1]) > 0


def test_lora_only_adapters_update():
    cfg = configs.load_by_name("lm-tiny-lora")
    layout = build_layout(cfg)
    init = jax.jit(steps.make_init(cfg, layout))
    step = jax.jit(steps.make_train_step(cfg, layout))
    state = init(jnp.array([5], jnp.int32))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(
        rng.integers(0, cfg.model.vocab_size, (cfg.train.batch_size, cfg.train.seq_len)),
        jnp.int32,
    )
    s1 = step(state, tokens, tokens, ctrl_vec(layout, lr=1e-2))
    for spec in layout.specs:
        off = layout.param_offsets[spec.name]
        same = bool(jnp.all(s1[off : off + spec.size] == state[off : off + spec.size]))
        if spec.trainable:
            assert not same, f"{spec.name} should have moved"
        else:
            assert same, f"{spec.name} is frozen base but moved"
