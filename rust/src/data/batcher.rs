//! Sequence packing + epoch iteration: sentences → fixed-shape `Batch`es.

use crate::data::corpus::Sentence;
use crate::runtime::session::Batch;
use crate::util::rng::Rng;

/// Pack sentences densely into rows of `seq_len`; next-token targets.
/// Rows are independent documents (no cross-row continuation); remainder
/// positions are PAD (-1) in the targets so they don't contribute loss.
pub fn pack_rows(sentences: &[Sentence], seq_len: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
    let mut rows = Vec::new();
    let mut cur: Vec<i32> = Vec::with_capacity(seq_len + 1);
    for s in sentences {
        if cur.len() + s.ids.len() > seq_len + 1 {
            if cur.len() >= 2 {
                rows.push(finish_row(&cur, seq_len));
            }
            cur.clear();
        }
        // sentence longer than a row: truncate
        if s.ids.len() > seq_len + 1 {
            cur.extend(&s.ids[..seq_len + 1]);
        } else {
            cur.extend(&s.ids);
        }
    }
    if cur.len() >= 2 {
        rows.push(finish_row(&cur, seq_len));
    }
    rows
}

fn finish_row(ids: &[i32], seq_len: usize) -> (Vec<i32>, Vec<i32>) {
    // tokens = ids[..-1], targets = ids[1..], padded to seq_len
    let n = ids.len().min(seq_len + 1);
    let mut tokens = vec![0i32; seq_len];
    let mut targets = vec![-1i32; seq_len];
    for i in 0..n - 1 {
        tokens[i] = ids[i];
        targets[i] = ids[i + 1];
    }
    (tokens, targets)
}

/// Infinite shuffled-epoch batch iterator over packed rows.
pub struct BatchIter {
    rows: Vec<(Vec<i32>, Vec<i32>)>,
    order: Vec<usize>,
    pos: usize,
    batch_size: usize,
    rng: Rng,
    /// Completed passes over the row set.
    pub epoch: usize,
}

impl BatchIter {
    /// Iterator over `rows` with a seeded shuffle per epoch.
    pub fn new(rows: Vec<(Vec<i32>, Vec<i32>)>, batch_size: usize, seed: u64) -> Self {
        assert!(!rows.is_empty(), "no rows to batch");
        let order: Vec<usize> = (0..rows.len()).collect();
        let mut it =
            Self { rows, order, pos: 0, batch_size, rng: Rng::new(seed), epoch: 0 };
        it.rng.shuffle(&mut it.order);
        it
    }

    /// Next `batch_size` rows (reshuffling at epoch boundaries).
    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch_size * self.rows[0].0.len());
        let mut targets = Vec::with_capacity(tokens.capacity());
        for _ in 0..self.batch_size {
            if self.pos >= self.order.len() {
                self.pos = 0;
                self.epoch += 1;
                self.rng.shuffle(&mut self.order);
            }
            let (t, y) = &self.rows[self.order[self.pos]];
            tokens.extend_from_slice(t);
            targets.extend_from_slice(y);
            self.pos += 1;
        }
        Batch { tokens, targets, patches: Vec::new() }
    }

    /// Packed row count (one epoch = this many rows).
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }
}

/// Fixed (non-shuffled) eval batches covering all rows once, zero-padding
/// the last batch with fully-masked rows.
pub fn eval_batches(
    rows: &[(Vec<i32>, Vec<i32>)],
    batch_size: usize,
    seq_len: usize,
) -> Vec<Batch> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < rows.len() {
        let mut tokens = Vec::with_capacity(batch_size * seq_len);
        let mut targets = Vec::with_capacity(batch_size * seq_len);
        for b in 0..batch_size {
            if let Some((t, y)) = rows.get(i + b) {
                tokens.extend_from_slice(t);
                targets.extend_from_slice(y);
            } else {
                tokens.extend(std::iter::repeat(0).take(seq_len));
                targets.extend(std::iter::repeat(-1).take(seq_len));
            }
        }
        out.push(Batch { tokens, targets, patches: Vec::new() });
        i += batch_size;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::generate;
    use crate::data::vocab::Vocab;

    #[test]
    fn packing_shapes() {
        let v = Vocab::build(256).unwrap();
        let ss = generate(&v, 1, 40);
        let rows = pack_rows(&ss, 48);
        assert!(!rows.is_empty());
        for (t, y) in &rows {
            assert_eq!(t.len(), 48);
            assert_eq!(y.len(), 48);
            // next-token alignment where targets valid
            for i in 0..47 {
                if y[i] >= 0 && y[i + 1] >= 0 {
                    assert_eq!(t[i + 1], y[i]);
                }
            }
        }
    }

    #[test]
    fn batch_iter_cycles_epochs() {
        let v = Vocab::build(256).unwrap();
        let ss = generate(&v, 1, 10);
        let rows = pack_rows(&ss, 32);
        let n = rows.len();
        let mut it = BatchIter::new(rows, 4, 9);
        for _ in 0..(n + 3) {
            let b = it.next_batch();
            assert_eq!(b.tokens.len(), 4 * 32);
        }
        assert!(it.epoch >= 1);
    }

    #[test]
    fn eval_batches_cover_all() {
        let rows: Vec<_> = (0..5).map(|i| (vec![i; 8], vec![i; 8])).collect();
        let bs = eval_batches(&rows, 2, 8);
        assert_eq!(bs.len(), 3);
        assert_eq!(bs[2].targets[8..], vec![-1i32; 8][..]); // padded row
    }
}
