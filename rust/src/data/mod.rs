//! Synthetic data substrates: grammar corpus, scene images, batching.

pub mod batcher;
pub mod corpus;
pub mod multimodal;
pub mod vocab;

use anyhow::Result;

use crate::config::RepoConfig;
use crate::runtime::manifest::Manifest;
use crate::runtime::session::Batch;

/// Everything a training run needs: train iterator + fixed val batches.
pub struct Dataset {
    /// Shuffled-epoch training iterator.
    pub train: batcher::BatchIter,
    /// Fixed validation batches.
    pub val: Vec<Batch>,
    /// The vocabulary both splits draw from.
    pub vocab: vocab::Vocab,
}

/// The reusable (config, data-section)-determined part of an LM dataset:
/// packed training rows + fixed validation batches + vocab. Everything
/// here depends only on `cfg.data` and the manifest shapes, so one build
/// serves every grid cell that mutates other config sections (τ, α,
/// metric, granularity, …); each run takes a *fresh* shuffled iterator
/// via [`lm_train_iter`], which is what keeps cached and uncached builds
/// on identical batch streams.
pub struct LmRows {
    /// Packed training rows (pre-shuffle).
    pub train_rows: Vec<(Vec<i32>, Vec<i32>)>,
    /// Fixed validation batches.
    pub val: Vec<Batch>,
    /// The vocabulary both splits draw from.
    pub vocab: vocab::Vocab,
}

/// Build the *fine-tuning* LM rows: a small, lexically domain-shifted
/// corpus (flatter Zipf, fresh seed) — small enough to overfit, which is
/// the regime where early stopping pays off. Benchmarks sample the general
/// distribution, so overfitting here hurts measured accuracy.
pub fn build_lm_rows(cfg: &RepoConfig, manifest: &Manifest) -> Result<LmRows> {
    let vocab = vocab::Vocab::build(manifest.vocab_size)?;
    let train_s =
        corpus::generate_shifted(&vocab, cfg.data.seed ^ 0xff17, cfg.data.train_sentences, 0.4);
    let val_s =
        corpus::generate_shifted(&vocab, cfg.data.seed ^ 0x5eed, cfg.data.val_sentences, 0.4);
    let train_rows = batcher::pack_rows(&train_s, manifest.seq_len);
    let val_rows = batcher::pack_rows(&val_s, manifest.seq_len);
    Ok(LmRows {
        train_rows,
        val: batcher::eval_batches(&val_rows, manifest.batch_size, manifest.seq_len),
        vocab,
    })
}

/// A fresh epoch-shuffled training iterator over prebuilt rows — the
/// single source of truth for the train-stream seed, shared by the
/// one-shot [`build_lm`] path and the scheduler's per-config row cache.
pub fn lm_train_iter(
    rows: &LmRows,
    cfg: &RepoConfig,
    manifest: &Manifest,
) -> batcher::BatchIter {
    batcher::BatchIter::new(rows.train_rows.clone(), manifest.batch_size, cfg.run.seed ^ 0xba7c)
}

/// Build the *fine-tuning* LM dataset (rows + fresh iterator in one call).
pub fn build_lm(cfg: &RepoConfig, manifest: &Manifest) -> Result<Dataset> {
    let rows = build_lm_rows(cfg, manifest)?;
    let train = lm_train_iter(&rows, cfg, manifest);
    Ok(Dataset { train, val: rows.val, vocab: rows.vocab })
}

/// Build the *pretraining* LM dataset: the broad general-distribution
/// corpus (4x the fine-tune size, no validation split needed).
pub fn build_lm_pretrain(cfg: &RepoConfig, manifest: &Manifest) -> Result<Dataset> {
    let vocab = vocab::Vocab::build(manifest.vocab_size)?;
    let n = cfg.data.train_sentences * 4;
    let train_s = corpus::generate(&vocab, cfg.data.seed, n);
    let val_s = corpus::generate(&vocab, cfg.data.seed ^ 0x11, cfg.data.val_sentences);
    let train_rows = batcher::pack_rows(&train_s, manifest.seq_len);
    let val_rows = batcher::pack_rows(&val_s, manifest.seq_len);
    Ok(Dataset {
        train: batcher::BatchIter::new(train_rows, manifest.batch_size, cfg.run.seed ^ 0x9d),
        val: batcher::eval_batches(&val_rows, manifest.batch_size, manifest.seq_len),
        vocab,
    })
}

/// VLM pretraining dataset (bigger scene sample, general distribution).
pub fn build_vlm_pretrain(cfg: &RepoConfig, manifest: &Manifest) -> Result<VlmDataset> {
    let mut big = cfg.clone();
    big.data.train_sentences *= 4;
    big.data.seed ^= 0x77;
    build_vlm(&big, manifest)
}

/// VLM dataset: scene/caption pairs packed to fixed shapes.
pub struct VlmDataset {
    /// Pre-packed training batches (cycled in order).
    pub train: Vec<Batch>,
    /// Fixed validation batches.
    pub val: Vec<Batch>,
    /// The caption vocabulary.
    pub vocab: vocab::Vocab,
    /// Scene shape parameters (benchmarks reuse them).
    pub scene_cfg: multimodal::SceneConfig,
}

/// Build the VLM fine-tuning dataset (scenes + captions, packed).
pub fn build_vlm(cfg: &RepoConfig, manifest: &Manifest) -> Result<VlmDataset> {
    let vocab = vocab::Vocab::build(manifest.vocab_size)?;
    let scene_cfg =
        multimodal::SceneConfig::for_model(manifest.n_patches, manifest.patch_dim, &vocab);
    let n_train = cfg.data.train_sentences;
    let n_val = cfg.data.val_sentences;
    let mk = |seed: u64, n: usize| -> Vec<Batch> {
        let exs = multimodal::generate(&scene_cfg, &vocab, seed, n);
        pack_vlm_batches(&exs, manifest)
    };
    Ok(VlmDataset {
        train: mk(cfg.data.seed, n_train),
        val: mk(cfg.data.seed ^ 0x5eed, n_val),
        vocab,
        scene_cfg,
    })
}

/// Pack scene examples into fixed-shape VLM batches (one example per row;
/// caption targets padded with -1).
pub fn pack_vlm_batches(exs: &[multimodal::SceneExample], m: &Manifest) -> Vec<Batch> {
    let (bsz, t) = (m.batch_size, m.seq_len);
    let patch_len = m.n_patches * m.patch_dim;
    let mut out = Vec::new();
    let mut i = 0;
    while i < exs.len() {
        let mut batch = Batch::default();
        for b in 0..bsz {
            if let Some(ex) = exs.get(i + b) {
                batch.patches.extend_from_slice(&ex.patches);
                let ids = &ex.caption;
                let n = ids.len().min(t + 1);
                let mut tokens = vec![0i32; t];
                let mut targets = vec![-1i32; t];
                for k in 0..n.saturating_sub(1) {
                    tokens[k] = ids[k];
                    targets[k] = ids[k + 1];
                }
                batch.tokens.extend_from_slice(&tokens);
                batch.targets.extend_from_slice(&targets);
            } else {
                batch.patches.extend(std::iter::repeat(0.0).take(patch_len));
                batch.tokens.extend(std::iter::repeat(0).take(t));
                batch.targets.extend(std::iter::repeat(-1).take(t));
            }
        }
        out.push(batch);
        i += bsz;
    }
    out
}
