//! Synthetic vision-language data: shape-scene "images" + captions.
//!
//! An image is a G×G patch grid (n_patches = G²). Each scene places 1–3
//! objects (color, shape) in distinct cells; the patch vector encodes
//! one-hot color + one-hot shape + occupancy with additive noise — the
//! float analogue of a pre-patchified ViT input. The caption lists each
//! object as `COLOR SHAPE POSITION .` in raster order.
//!
//! This substrate exercises exactly the code path the paper's VLM
//! experiments need: a slower-converging vision tower consuming dense
//! float patches alongside the language decoder (paper §6.3).

use crate::data::vocab::{Vocab, BOS, EOS, PERIOD};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// One placed object: (color, shape) at a grid cell.
pub struct Object {
    /// Color index (< `SceneConfig::n_colors`).
    pub color: usize,
    /// Shape index (< `SceneConfig::n_shapes`).
    pub shape: usize,
    /// Patch-grid cell the object occupies.
    pub cell: usize,
}

#[derive(Debug, Clone)]
/// A full scene: objects in raster order on a G×G grid.
pub struct Scene {
    /// Objects, sorted by cell (raster order).
    pub objects: Vec<Object>,
    /// Grid side length G.
    pub grid: usize,
}

#[derive(Debug, Clone)]
/// Scene-generation shape parameters, derived from the manifest.
pub struct SceneConfig {
    /// Patches per image (G²).
    pub n_patches: usize,
    /// Feature size of one patch vector.
    pub patch_dim: usize,
    /// Distinct colors (≤ caption color words).
    pub n_colors: usize,
    /// Distinct shapes (≤ caption shape words).
    pub n_shapes: usize,
    /// Additive patch noise amplitude.
    pub noise: f32,
}

impl SceneConfig {
    /// Config fitting the manifest's patch shape and the vocab's caption words.
    pub fn for_model(n_patches: usize, patch_dim: usize, vocab: &Vocab) -> Self {
        let n_colors = (vocab.colors.len as usize).min(patch_dim / 3).max(2);
        let n_shapes = (vocab.shapes.len as usize).min(patch_dim / 3).max(2);
        SceneConfig { n_patches, patch_dim, n_colors, n_shapes, noise: 0.05 }
    }

    /// Grid side length G = √n_patches.
    pub fn grid(&self) -> usize {
        (self.n_patches as f64).sqrt() as usize
    }
}

/// Sample a scene with 1–3 objects in distinct cells.
pub fn gen_scene(cfg: &SceneConfig, r: &mut Rng) -> Scene {
    let n_obj = 1 + r.below(3.min(cfg.n_patches));
    let mut cells: Vec<usize> = (0..cfg.n_patches).collect();
    r.shuffle(&mut cells);
    let mut objects: Vec<Object> = (0..n_obj)
        .map(|i| Object {
            color: r.below(cfg.n_colors),
            shape: r.below(cfg.n_shapes),
            cell: cells[i],
        })
        .collect();
    objects.sort_by_key(|o| o.cell); // raster order for caption determinism
    Scene { objects, grid: cfg.grid() }
}

/// Render the scene to flat patches `[n_patches * patch_dim]`.
pub fn render(cfg: &SceneConfig, scene: &Scene, r: &mut Rng) -> Vec<f32> {
    let mut out = vec![0f32; cfg.n_patches * cfg.patch_dim];
    for x in out.iter_mut() {
        *x = cfg.noise * r.gauss() as f32;
    }
    for o in &scene.objects {
        let base = o.cell * cfg.patch_dim;
        out[base + o.color] += 1.0; // color one-hot
        out[base + cfg.n_colors + o.shape] += 1.0; // shape one-hot
        out[base + cfg.n_colors + cfg.n_shapes] += 1.0; // occupancy
    }
    out
}

/// Quadrant (0..4) of a cell — the caption's position word.
pub fn quadrant(cell: usize, grid: usize) -> usize {
    let (row, col) = (cell / grid, cell % grid);
    let top = row < grid / 2;
    let left = col < grid.div_ceil(2);
    match (top, left) {
        (true, true) => 0,
        (true, false) => 1,
        (false, true) => 2,
        (false, false) => 3,
    }
}

/// Ground-truth caption token ids.
pub fn caption(vocab: &Vocab, scene: &Scene) -> Vec<i32> {
    let mut ids = vec![BOS];
    for o in &scene.objects {
        ids.push(vocab.colors.get(o.color));
        ids.push(vocab.shapes.get(o.shape));
        ids.push(vocab.positions.get(quadrant(o.cell, scene.grid)));
        ids.push(PERIOD);
    }
    ids.push(EOS);
    ids
}

/// Caption with one attribute of one object corrupted.
/// `what` ∈ {"color", "shape", "position"}.
pub fn corrupt_caption(
    vocab: &Vocab,
    cfg: &SceneConfig,
    scene: &Scene,
    what: &str,
    r: &mut Rng,
) -> Vec<i32> {
    let mut s2 = scene.clone();
    let i = r.below(s2.objects.len());
    match what {
        "color" => {
            let old = s2.objects[i].color;
            s2.objects[i].color = (old + 1 + r.below(cfg.n_colors - 1)) % cfg.n_colors;
        }
        "shape" => {
            let old = s2.objects[i].shape;
            s2.objects[i].shape = (old + 1 + r.below(cfg.n_shapes - 1)) % cfg.n_shapes;
        }
        "position" => {
            // move to a cell in a different quadrant
            let g = s2.grid;
            let old_q = quadrant(s2.objects[i].cell, g);
            for _ in 0..64 {
                let cell = r.below(g * g);
                if quadrant(cell, g) != old_q
                    && !s2.objects.iter().any(|o| o.cell == cell)
                {
                    s2.objects[i].cell = cell;
                    break;
                }
            }
            s2.objects.sort_by_key(|o| o.cell);
        }
        _ => panic!("unknown corruption {what}"),
    }
    caption(vocab, &s2)
}

/// A full (patches, caption) example.
pub struct SceneExample {
    /// Rendered patch features `[n_patches * patch_dim]`.
    pub patches: Vec<f32>,
    /// Ground-truth caption token ids.
    pub caption: Vec<i32>,
    /// The underlying scene (for corruptions).
    pub scene: Scene,
}

/// Generate `n` (patches, caption) examples from `seed`.
pub fn generate(cfg: &SceneConfig, vocab: &Vocab, seed: u64, n: usize) -> Vec<SceneExample> {
    let mut r = Rng::new(seed);
    (0..n)
        .map(|_| {
            let scene = gen_scene(cfg, &mut r);
            let patches = render(cfg, &scene, &mut r);
            let caption = caption(vocab, &scene);
            SceneExample { patches, caption, scene }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (SceneConfig, Vocab) {
        let v = Vocab::build(256).unwrap();
        (SceneConfig::for_model(16, 24, &v), v)
    }

    #[test]
    fn render_shapes() {
        let (cfg, v) = setup();
        let ex = generate(&cfg, &v, 3, 10);
        for e in &ex {
            assert_eq!(e.patches.len(), 16 * 24);
            assert_eq!(e.caption[0], BOS);
            assert_eq!(*e.caption.last().unwrap(), EOS);
            assert_eq!(e.caption.len(), 2 + 4 * e.scene.objects.len());
        }
    }

    #[test]
    fn occupied_cells_have_signal() {
        let (cfg, v) = setup();
        let ex = &generate(&cfg, &v, 5, 1)[0];
        for o in &ex.scene.objects {
            let base = o.cell * cfg.patch_dim;
            assert!(ex.patches[base + cfg.n_colors + cfg.n_shapes] > 0.5);
        }
    }

    #[test]
    fn corruption_differs_from_truth() {
        let (cfg, v) = setup();
        let mut r = Rng::new(11);
        let scene = gen_scene(&cfg, &mut r);
        let truth = caption(&v, &scene);
        for what in ["color", "shape", "position"] {
            let bad = corrupt_caption(&v, &cfg, &scene, what, &mut r);
            assert_ne!(truth, bad, "{what} corruption must change the caption");
        }
    }

    #[test]
    fn quadrants_partition_grid() {
        let mut counts = [0usize; 4];
        for c in 0..16 {
            counts[quadrant(c, 4)] += 1;
        }
        assert_eq!(counts, [4, 4, 4, 4]);
    }
}
