//! Word-class vocabulary for the synthetic grammar (shared by the corpus
//! generator and the benchmark suites).
//!
//! The vocabulary is partitioned into part-of-speech classes with two
//! agreement genders (A/B). Token ids are assigned deterministically inside
//! the model's vocab budget, so the same config always yields the same ids.

use anyhow::{ensure, Result};

pub const PAD: i32 = -1; // target padding (masked from the loss)
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const PERIOD: i32 = 3;
pub const FIRST_WORD: i32 = 8; // ids below this are reserved/special

/// A contiguous id range [start, start+len).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Range {
    pub start: i32,
    pub len: i32,
}

impl Range {
    pub fn get(&self, i: usize) -> i32 {
        assert!((i as i32) < self.len);
        self.start + i as i32
    }

    pub fn contains(&self, id: i32) -> bool {
        id >= self.start && id < self.start + self.len
    }

    pub fn ids(&self) -> impl Iterator<Item = i32> + '_ {
        self.start..self.start + self.len
    }
}

/// The word classes of the grammar. Gender A/B drives agreement rules.
#[derive(Debug, Clone)]
pub struct Vocab {
    pub vocab_size: usize,
    pub det_a: Range,
    pub det_b: Range,
    pub adj_a: Range,
    pub adj_b: Range,
    pub noun_a: Range,
    pub noun_b: Range,
    /// verbs preferring class-A / class-B objects (selectional restriction)
    pub verb_a: Range,
    pub verb_b: Range,
    pub adv: Range,
    /// VLM caption words
    pub colors: Range,
    pub shapes: Range,
    pub positions: Range,
}

impl Vocab {
    /// Partition `vocab_size` ids into the class ranges. Class sizes scale
    /// with the budget so bigger configs get richer vocabularies.
    pub fn build(vocab_size: usize) -> Result<Self> {
        ensure!(vocab_size >= 128, "vocab_size must be >= 128, got {vocab_size}");
        let budget = (vocab_size as i32) - FIRST_WORD;
        // weights roughly proportional to natural class sizes
        let unit = budget / 32;
        let small = unit.max(2);
        let big = (unit * 5).max(8);
        let mut next = FIRST_WORD;
        let mut take = |len: i32| {
            let r = Range { start: next, len };
            next += len;
            r
        };
        let v = Vocab {
            vocab_size,
            det_a: take(small),
            det_b: take(small),
            adj_a: take(small * 2),
            adj_b: take(small * 2),
            noun_a: take(big),
            noun_b: take(big),
            verb_a: take(big / 2),
            verb_b: take(big / 2),
            adv: take(small * 2),
            colors: take(small),
            shapes: take(small),
            positions: take(small),
        };
        ensure!(next <= vocab_size as i32, "vocab partition overflow: {next} > {vocab_size}");
        Ok(v)
    }

    pub fn gender_of_noun(&self, id: i32) -> Option<char> {
        if self.noun_a.contains(id) {
            Some('a')
        } else if self.noun_b.contains(id) {
            Some('b')
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitions_fit() {
        for vs in [128, 256, 512, 1024, 4096] {
            let v = Vocab::build(vs).unwrap();
            assert!(v.positions.start + v.positions.len <= vs as i32);
            // ranges are disjoint and ordered
            assert!(v.det_a.start >= FIRST_WORD);
            assert!(v.det_b.start >= v.det_a.start + v.det_a.len);
            assert!(v.noun_a.len >= 8);
        }
    }

    #[test]
    fn gender_lookup() {
        let v = Vocab::build(256).unwrap();
        assert_eq!(v.gender_of_noun(v.noun_a.get(0)), Some('a'));
        assert_eq!(v.gender_of_noun(v.noun_b.get(0)), Some('b'));
        assert_eq!(v.gender_of_noun(BOS), None);
    }

    #[test]
    fn rejects_tiny_vocab() {
        assert!(Vocab::build(64).is_err());
    }
}
