//! Word-class vocabulary for the synthetic grammar (shared by the corpus
//! generator and the benchmark suites).
//!
//! The vocabulary is partitioned into part-of-speech classes with two
//! agreement genders (A/B). Token ids are assigned deterministically inside
//! the model's vocab budget, so the same config always yields the same ids.

use anyhow::{ensure, Result};

/// Target padding id (masked from the loss).
pub const PAD: i32 = -1; // target padding (masked from the loss)
/// Beginning-of-sentence id.
pub const BOS: i32 = 1;
/// End-of-sentence id.
pub const EOS: i32 = 2;
/// Sentence-final period id.
pub const PERIOD: i32 = 3;
/// First non-reserved word id.
pub const FIRST_WORD: i32 = 8; // ids below this are reserved/special

/// A contiguous id range [start, start+len).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Range {
    /// First id in the range.
    pub start: i32,
    /// Number of ids.
    pub len: i32,
}

impl Range {
    /// The `i`-th id (panics past the end).
    pub fn get(&self, i: usize) -> i32 {
        assert!((i as i32) < self.len);
        self.start + i as i32
    }

    /// Is `id` inside the range?
    pub fn contains(&self, id: i32) -> bool {
        id >= self.start && id < self.start + self.len
    }

    /// All ids, in order.
    pub fn ids(&self) -> impl Iterator<Item = i32> + '_ {
        self.start..self.start + self.len
    }
}

/// The word classes of the grammar. Gender A/B drives agreement rules.
#[derive(Debug, Clone)]
pub struct Vocab {
    /// The model's vocab budget the classes were fit into.
    pub vocab_size: usize,
    /// Class-A determiners.
    pub det_a: Range,
    /// Class-B determiners.
    pub det_b: Range,
    /// Class-A adjectives.
    pub adj_a: Range,
    /// Class-B adjectives.
    pub adj_b: Range,
    /// Class-A (gender-A) nouns.
    pub noun_a: Range,
    /// Class-B (gender-B) nouns.
    pub noun_b: Range,
    /// verbs preferring class-A / class-B objects (selectional restriction)
    pub verb_a: Range,
    /// Verbs selecting class-B objects.
    pub verb_b: Range,
    /// Adverbs (halves associate with the two verb classes).
    pub adv: Range,
    /// VLM caption words
    pub colors: Range,
    /// VLM caption shape words.
    pub shapes: Range,
    /// VLM caption position words.
    pub positions: Range,
}

impl Vocab {
    /// Partition `vocab_size` ids into the class ranges. Class sizes scale
    /// with the budget so bigger configs get richer vocabularies.
    pub fn build(vocab_size: usize) -> Result<Self> {
        ensure!(vocab_size >= 128, "vocab_size must be >= 128, got {vocab_size}");
        let budget = (vocab_size as i32) - FIRST_WORD;
        // weights roughly proportional to natural class sizes
        let unit = budget / 32;
        let small = unit.max(2);
        let big = (unit * 5).max(8);
        let mut next = FIRST_WORD;
        let mut take = |len: i32| {
            let r = Range { start: next, len };
            next += len;
            r
        };
        let v = Vocab {
            vocab_size,
            det_a: take(small),
            det_b: take(small),
            adj_a: take(small * 2),
            adj_b: take(small * 2),
            noun_a: take(big),
            noun_b: take(big),
            verb_a: take(big / 2),
            verb_b: take(big / 2),
            adv: take(small * 2),
            colors: take(small),
            shapes: take(small),
            positions: take(small),
        };
        ensure!(next <= vocab_size as i32, "vocab partition overflow: {next} > {vocab_size}");
        Ok(v)
    }

    /// 'a'/'b' for noun ids, None otherwise.
    pub fn gender_of_noun(&self, id: i32) -> Option<char> {
        if self.noun_a.contains(id) {
            Some('a')
        } else if self.noun_b.contains(id) {
            Some('b')
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitions_fit() {
        for vs in [128, 256, 512, 1024, 4096] {
            let v = Vocab::build(vs).unwrap();
            assert!(v.positions.start + v.positions.len <= vs as i32);
            // ranges are disjoint and ordered
            assert!(v.det_a.start >= FIRST_WORD);
            assert!(v.det_b.start >= v.det_a.start + v.det_a.len);
            assert!(v.noun_a.len >= 8);
        }
    }

    #[test]
    fn gender_lookup() {
        let v = Vocab::build(256).unwrap();
        assert_eq!(v.gender_of_noun(v.noun_a.get(0)), Some('a'));
        assert_eq!(v.gender_of_noun(v.noun_b.get(0)), Some('b'));
        assert_eq!(v.gender_of_noun(BOS), None);
    }

    #[test]
    fn rejects_tiny_vocab() {
        assert!(Vocab::build(64).is_err());
    }
}
