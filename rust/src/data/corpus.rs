//! Synthetic probabilistic-grammar corpus (the fine-tuning dataset).
//!
//! Sentences follow the template
//!     DET ADJ? NOUN VERB DET ADJ? NOUN ADV? .
//! with hard agreement rules a model must learn:
//!   * determiner/adjective gender agrees with its noun (A vs B),
//!   * verbs select the gender of their *object* noun,
//!   * adverbs associate with the verb's class,
//!   * lexical skew: Zipf-ish word frequencies within each class.
//!
//! The training split is intentionally small (config `[data]`) so extended
//! training overfits — the regime where early stopping pays off.

use crate::data::vocab::{Vocab, BOS, EOS, PERIOD};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
/// One generated sentence as token ids (BOS … EOS).
pub struct Sentence {
    /// Token ids including BOS/period/EOS.
    pub ids: Vec<i32>,
}

/// Sentence generator over a [`Vocab`]'s class ranges.
pub struct GrammarGen<'v> {
    /// The word classes sentences draw from.
    pub vocab: &'v Vocab,
    /// Zipf exponent for intra-class word choice.
    pub zipf: f64,
}

impl<'v> GrammarGen<'v> {
    /// The default head-skewed generator (zipf 1.1).
    pub fn new(vocab: &'v Vocab) -> Self {
        Self { vocab, zipf: 1.1 }
    }

    /// Tail-biased generator (rare-word suite): negative exponent inverts
    /// the Zipf ranking so the long tail dominates.
    pub fn rare(vocab: &'v Vocab) -> Self {
        Self { vocab, zipf: -1.1 }
    }

    /// Head-only generator (frequent-word suite).
    pub fn frequent(vocab: &'v Vocab) -> Self {
        Self { vocab, zipf: 3.0 }
    }

    fn zipf_pick(&self, r: &mut Rng, range: crate::data::vocab::Range) -> i32 {
        let n = range.len as usize;
        let weights: Vec<f64> =
            (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(self.zipf)).collect();
        range.get(r.weighted(&weights))
    }

    /// One grammatical sentence (token ids, starts with BOS, ends EOS).
    pub fn sentence(&self, r: &mut Rng) -> Sentence {
        let v = self.vocab;
        let mut ids = vec![BOS];
        // subject NP
        let subj_gender = r.chance(0.5);
        let (det_s, adj_s, noun_s) = if subj_gender {
            (v.det_a, v.adj_a, v.noun_a)
        } else {
            (v.det_b, v.adj_b, v.noun_b)
        };
        ids.push(self.zipf_pick(r, det_s));
        if r.chance(0.5) {
            ids.push(self.zipf_pick(r, adj_s));
        }
        ids.push(self.zipf_pick(r, noun_s));
        // verb selects object gender
        let obj_gender_a = r.chance(0.5);
        let verb_range = if obj_gender_a { v.verb_a } else { v.verb_b };
        let verb = self.zipf_pick(r, verb_range);
        ids.push(verb);
        // object NP agrees with the verb's selectional class
        let (det_o, adj_o, noun_o) = if obj_gender_a {
            (v.det_a, v.adj_a, v.noun_a)
        } else {
            (v.det_b, v.adj_b, v.noun_b)
        };
        ids.push(self.zipf_pick(r, det_o));
        if r.chance(0.5) {
            ids.push(self.zipf_pick(r, adj_o));
        }
        ids.push(self.zipf_pick(r, noun_o));
        // adverb associated with verb class: first half of adv for verb_a
        if r.chance(0.4) {
            let half = (v.adv.len / 2).max(1);
            let idx = if obj_gender_a { r.below(half as usize) } else { half as usize + r.below((v.adv.len - half) as usize) };
            ids.push(v.adv.get(idx));
        }
        ids.push(PERIOD);
        ids.push(EOS);
        Sentence { ids }
    }

    /// Corrupt one rule in a sentence (used by benchmark distractors).
    /// `rule` ∈ {"det", "adj", "verb_obj", "adv"}.
    pub fn corrupt(&self, r: &mut Rng, s: &Sentence, rule: &str) -> Sentence {
        let v = self.vocab;
        let mut ids = s.ids.clone();
        match rule {
            "det" => {
                // swap a determiner to the opposite gender
                for id in ids.iter_mut() {
                    if v.det_a.contains(*id) {
                        *id = self.zipf_pick(r, v.det_b);
                        break;
                    }
                    if v.det_b.contains(*id) {
                        *id = self.zipf_pick(r, v.det_a);
                        break;
                    }
                }
            }
            "adj" => {
                let mut done = false;
                for id in ids.iter_mut() {
                    if v.adj_a.contains(*id) {
                        *id = self.zipf_pick(r, v.adj_b);
                        done = true;
                        break;
                    }
                    if v.adj_b.contains(*id) {
                        *id = self.zipf_pick(r, v.adj_a);
                        done = true;
                        break;
                    }
                }
                if !done {
                    return self.corrupt(r, s, "det");
                }
            }
            "verb_obj" => {
                // swap the *object noun* gender, violating verb selection
                let mut seen = 0;
                for id in ids.iter_mut() {
                    if v.noun_a.contains(*id) || v.noun_b.contains(*id) {
                        seen += 1;
                        if seen == 2 {
                            *id = if v.noun_a.contains(*id) {
                                self.zipf_pick(r, v.noun_b)
                            } else {
                                self.zipf_pick(r, v.noun_a)
                            };
                            break;
                        }
                    }
                }
            }
            "det2" => {
                // corrupt the *object* determiner — a long-range agreement
                // violation (distance from the selecting verb).
                let mut seen = 0;
                for id in ids.iter_mut() {
                    if v.det_a.contains(*id) || v.det_b.contains(*id) {
                        seen += 1;
                        if seen == 2 {
                            *id = if v.det_a.contains(*id) {
                                self.zipf_pick(r, v.det_b)
                            } else {
                                self.zipf_pick(r, v.det_a)
                            };
                            break;
                        }
                    }
                }
            }
            "swap" => {
                // word-order violation: swap two adjacent interior tokens
                if ids.len() >= 5 {
                    let i = 1 + r.below(ids.len() - 4);
                    ids.swap(i, i + 1);
                    if ids == s.ids {
                        ids.swap(1, 2);
                    }
                }
            }
            "adv" => {
                let half = (v.adv.len / 2).max(1);
                let mut done = false;
                for id in ids.iter_mut() {
                    if v.adv.contains(*id) {
                        let local = *id - v.adv.start;
                        *id = if local < half {
                            v.adv.get(half as usize + r.below((v.adv.len - half) as usize))
                        } else {
                            v.adv.get(r.below(half as usize))
                        };
                        done = true;
                        break;
                    }
                }
                if !done {
                    return self.corrupt(r, s, "verb_obj");
                }
            }
            _ => panic!("unknown corruption rule {rule}"),
        }
        Sentence { ids }
    }
}

/// Generate `n` sentences from a fresh fork of `seed`.
pub fn generate(vocab: &Vocab, seed: u64, n: usize) -> Vec<Sentence> {
    let mut r = Rng::new(seed);
    let g = GrammarGen::new(vocab);
    (0..n).map(|_| g.sentence(&mut r)).collect()
}

/// Domain-shifted sample: same grammar rules, different lexical skew
/// (the fine-tuning distribution).
pub fn generate_shifted(vocab: &Vocab, seed: u64, n: usize, zipf: f64) -> Vec<Sentence> {
    let mut r = Rng::new(seed);
    let mut g = GrammarGen::new(vocab);
    g.zipf = zipf;
    (0..n).map(|_| g.sentence(&mut r)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab() -> Vocab {
        Vocab::build(256).unwrap()
    }

    #[test]
    fn sentences_well_formed() {
        let v = vocab();
        let ss = generate(&v, 7, 50);
        for s in &ss {
            assert_eq!(s.ids[0], BOS);
            assert_eq!(*s.ids.last().unwrap(), EOS);
            assert_eq!(s.ids[s.ids.len() - 2], PERIOD);
            assert!(s.ids.len() >= 7 && s.ids.len() <= 11, "{:?}", s.ids);
            assert!(s.ids.iter().all(|&id| id >= 0 && (id as usize) < v.vocab_size));
        }
    }

    #[test]
    fn agreement_holds() {
        let v = vocab();
        let ss = generate(&v, 9, 200);
        for s in &ss {
            // first det gender must match first noun gender
            let det = s.ids.iter().find(|&&id| v.det_a.contains(id) || v.det_b.contains(id)).unwrap();
            let noun = s.ids.iter().find(|&&id| v.noun_a.contains(id) || v.noun_b.contains(id)).unwrap();
            assert_eq!(v.det_a.contains(*det), v.noun_a.contains(*noun));
        }
    }

    #[test]
    fn corruption_changes_exactly_one_token() {
        let v = vocab();
        let mut r = Rng::new(3);
        let g = GrammarGen::new(&v);
        for rule in ["det", "verb_obj"] {
            let s = g.sentence(&mut r);
            let c = g.corrupt(&mut r, &s, rule);
            let diffs = s.ids.iter().zip(&c.ids).filter(|(a, b)| a != b).count();
            assert_eq!(diffs, 1, "rule {rule}");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let v = vocab();
        let a = generate(&v, 5, 10);
        let b = generate(&v, 5, 10);
        assert_eq!(a.iter().map(|s| s.ids.clone()).collect::<Vec<_>>(),
                   b.iter().map(|s| s.ids.clone()).collect::<Vec<_>>());
    }
}
