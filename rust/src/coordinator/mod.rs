//! The paper's L3 contribution: GradES monitoring + freeze coordination,
//! the stopping-method zoo (classic ES, evidence-based, spectral,
//! instance-dependent), and the training event loop that composes them
//! with the AOT runtime.

pub mod classic_es;
pub mod eb;
pub mod flops;
pub mod freeze;
pub mod grades;
pub mod instance;
pub mod lr;
pub mod metrics;
pub mod scheduler;
pub mod spectral;
pub mod trainer;
pub mod warmstart;
