//! The paper's L3 contribution: GradES monitoring + freeze coordination,
//! the classic-ES baseline, and the training event loop that composes them
//! with the AOT runtime.

pub mod classic_es;
pub mod flops;
pub mod freeze;
pub mod grades;
pub mod lr;
pub mod metrics;
pub mod scheduler;
pub mod trainer;
pub mod warmstart;
