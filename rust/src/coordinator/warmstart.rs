//! Warm-start substrate: pretrain a base model once, then fine-tune it
//! under every method — the paper's setting (it fine-tunes pretrained
//! checkpoints; LoRA on a random base is meaningless since the frozen
//! embeddings carry no structure).
//!
//! A checkpoint is the host copy of a trained FP state vector. Fine-tuning
//! runs (FP or LoRA, any stopping method) start from `init()` and then
//! overwrite every *base* parameter present in the checkpoint, mapped **by
//! parameter name** across manifests (the LoRA layout stores the same base
//! tensors at different offsets, plus fresh A/B adapters).

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{ensure, Context, Result};

use crate::config::RepoConfig;
use crate::coordinator::lr::CosineSchedule;
use crate::coordinator::trainer::{run_source_and_keep, StoppingMethod, TrainerOptions};
use crate::data;
use crate::runtime::artifact::{Bundle, Client};
use crate::runtime::backend::Backend;
use crate::runtime::manifest::Manifest;
use crate::runtime::pipeline::{FixedCycle, PipelineOptions, Prefetcher};
use crate::runtime::session::{decode_checkpoint, Session};

/// Named parameter values extracted from a trained state.
pub struct BaseCheckpoint {
    /// Tensor name → values to copy into a fresh state.
    pub params: HashMap<String, Vec<f32>>,
    /// Where the checkpoint came from (logging only).
    pub source: String,
}

impl BaseCheckpoint {
    /// Extract all parameters from a state vector via its manifest.
    pub fn from_state(manifest: &Manifest, state: &[f32]) -> Result<Self> {
        ensure!(state.len() == manifest.state_len, "state length mismatch");
        let mut params = HashMap::new();
        for p in &manifest.params {
            params.insert(p.name.clone(), state[p.offset..p.offset + p.size()].to_vec());
        }
        Ok(BaseCheckpoint { params, source: manifest.name.clone() })
    }

    /// Overwrite a session's matching base parameters (by name) in place.
    /// Tensors absent from the checkpoint (LoRA A/B) keep their init.
    pub fn apply(&self, session: &mut Session) -> Result<usize> {
        let manifest = session.manifest();
        let mut state = session.state_to_host()?;
        let mut applied = 0usize;
        for p in &manifest.params {
            if let Some(vals) = self.params.get(&p.name) {
                ensure!(
                    vals.len() == p.size(),
                    "shape mismatch for {} ({} vs {})",
                    p.name,
                    vals.len(),
                    p.size()
                );
                state[p.offset..p.offset + p.size()].copy_from_slice(vals);
                applied += 1;
            }
        }
        session.state_from_host(&state)?;
        Ok(applied)
    }
}

/// The checkpoint disk-cache key includes the backend: host and XLA
/// layouts are bit-compatible *by design* (same `state_len`), so without
/// the label a host-pretrained base would silently warm-start later XLA
/// runs (or vice versa) — the length guard below cannot tell them apart.
fn cache_path(config_name: &str, steps: usize, backend: &str) -> PathBuf {
    crate::config::repo_root()
        .join("results")
        .join("checkpoints")
        .join(format!("{config_name}_{steps}_{backend}.bin"))
}

/// Pretrain (or load a cached) FP base checkpoint for `config_name`.
/// Pretraining uses the *pretrain* corpus seed (the fine-tune corpus is a
/// domain-shifted subset — see `data::build_lm_finetune`).
pub fn pretrain_checkpoint(
    client: &Client,
    config_name: &str,
    steps: usize,
) -> Result<BaseCheckpoint> {
    let bundle = Bundle::by_name(client, config_name)
        .with_context(|| format!("pretrain artifact {config_name}"))?;
    pretrain_checkpoint_with(&bundle, config_name, steps)
}

/// [`pretrain_checkpoint`] over an already-built engine — the scheduler
/// path, where engines come from a shared cache (see
/// `runtime::backend::EngineCache`) and must not be rebuilt per pretrain
/// job. Backend-generic: a host-backend pretrain produces a checkpoint a
/// host fine-tune consumes (the layouts match the XLA ones bit-for-bit,
/// but trajectories differ across backends, so the disk cache is only
/// reused when the state length matches).
pub fn pretrain_checkpoint_with(
    backend: &dyn Backend,
    config_name: &str,
    steps: usize,
) -> Result<BaseCheckpoint> {
    let manifest = backend.manifest();
    let path = cache_path(config_name, steps, backend.name());
    if path.exists() {
        // corrupt/stale caches (truncated write, layout change) are not
        // fatal — fall through and retrain below
        if let Ok((_, state)) = decode_checkpoint(&std::fs::read(&path)?) {
            if state.len() == manifest.state_len {
                let mut ck = BaseCheckpoint::from_state(manifest, &state)?;
                ck.source = format!("{config_name} (cached)");
                return Ok(ck);
            }
        }
    }
    let cfg = RepoConfig::by_name(config_name)?;
    let ds = data::build_lm_pretrain(&cfg, manifest)?;
    let opts = TrainerOptions {
        method: StoppingMethod::None,
        total_steps: steps,
        seed: cfg.run.seed as i32,
        probe_every: usize::MAX,
        elide_frozen: false,
        truncate_frozen_prefix: false,
        final_validation: false,
        warm_start: None,
        pipeline: PipelineOptions::default(),
        async_eval: Default::default(),
    };
    // reuse the same cosine schedule semantics as a real pretrain run
    let _ = CosineSchedule::new(cfg.run.lr, cfg.run.warmup_frac, steps);
    let mut source = Prefetcher::spawn(ds.train, opts.pipeline.prefetch_batches);
    let trained = run_source_and_keep(backend, &cfg, &opts, &mut source, &[])?;
    trained.session.save_checkpoint(&path)?;
    let state = trained.session.state_to_host()?;
    BaseCheckpoint::from_state(manifest, &state)
}

/// VLM variant of `pretrain_checkpoint`.
pub fn pretrain_vlm_checkpoint(
    client: &Client,
    config_name: &str,
    steps: usize,
) -> Result<BaseCheckpoint> {
    let bundle = Bundle::by_name(client, config_name)?;
    pretrain_vlm_checkpoint_with(&bundle, config_name, steps)
}

/// [`pretrain_vlm_checkpoint`] over an already-built engine (the
/// scheduler path — see [`pretrain_checkpoint_with`]).
pub fn pretrain_vlm_checkpoint_with(
    backend: &dyn Backend,
    config_name: &str,
    steps: usize,
) -> Result<BaseCheckpoint> {
    let manifest = backend.manifest();
    let path = cache_path(config_name, steps, backend.name());
    if path.exists() {
        if let Ok((_, state)) = decode_checkpoint(&std::fs::read(&path)?) {
            if state.len() == manifest.state_len {
                return BaseCheckpoint::from_state(manifest, &state);
            }
        }
    }
    let cfg = RepoConfig::by_name(config_name)?;
    let ds = data::build_vlm_pretrain(&cfg, manifest)?;
    let opts = TrainerOptions {
        method: StoppingMethod::None,
        total_steps: steps,
        seed: cfg.run.seed as i32,
        probe_every: usize::MAX,
        elide_frozen: false,
        truncate_frozen_prefix: false,
        final_validation: false,
        warm_start: None,
        pipeline: PipelineOptions::default(),
        async_eval: Default::default(),
    };
    let mut source =
        Prefetcher::spawn(FixedCycle::new(ds.train), opts.pipeline.prefetch_batches);
    let trained = run_source_and_keep(backend, &cfg, &opts, &mut source, &[])?;
    trained.session.save_checkpoint(&path)?;
    let state = trained.session.state_to_host()?;
    BaseCheckpoint::from_state(manifest, &state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::grades::tests::fake_manifest;
    use crate::runtime::manifest::ParamInfo;

    #[test]
    fn from_state_extracts_by_offset() {
        let mut m = fake_manifest(1);
        m.state_len = 10;
        m.params = vec![
            ParamInfo {
                name: "a".into(),
                shape: vec![2, 2],
                offset: 2,
                trainable: true,
                component: None,
            },
            ParamInfo {
                name: "b".into(),
                shape: vec![3],
                offset: 6,
                trainable: false,
                component: None,
            },
        ];
        let state: Vec<f32> = (0..10).map(|x| x as f32).collect();
        let ck = BaseCheckpoint::from_state(&m, &state).unwrap();
        assert_eq!(ck.params["a"], vec![2.0, 3.0, 4.0, 5.0]);
        assert_eq!(ck.params["b"], vec![6.0, 7.0, 8.0]);
    }
}
