//! Evidence-based stopping — Mahsereci & Lassner's validation-free EB
//! criterion (arXiv:1703.09580) adapted to per-component freezing.
//!
//! The original criterion stops *all* of training once the mini-batch
//! gradient is statistically indistinguishable from sampling noise:
//! with per-parameter gradient mean `g_k` and variance estimate `Σ̂_k`,
//! stop when the evidence
//!
//! ```text
//! e = 1 − (1/|D|) Σ_k g_k² / Σ̂_k  >  0
//! ```
//!
//! Here the test runs *per monitored component* (GradES granularity), so
//! a converged projection matrix freezes while the rest keeps training —
//! and like GradES it needs **zero validation passes**: every input is a
//! statistic the train step already produces.
//!
//! Two evidence estimators, picked by the layout:
//!
//! * **Exact** (`[eb] gvar = true`): the host layout carries a gvar
//!   block, `gvar[c] = Σ_k g_k² / (½(g_k − g_k^prev)² + ε)` with the
//!   step-local difference ½(g−prev)² as the variance proxy, and
//!   `e[c] = 1 − gvar[c]/n_params(c)`.
//! * **Fallback** (any pre-existing layout): only the Eq. 1 scalars
//!   exist, so the per-parameter ratio is approximated from them as
//!   `e[c] = 1 − 2·(Gabs[c]/Gdiff[c])²`. Both agree on the stopping
//!   point: once the gradient is pure noise, consecutive draws are
//!   independent and `E|g − prev|² = 2·E g²`, driving either estimate
//!   to ≈ 0 from below.

use crate::config::EbConfig;
use crate::coordinator::freeze::{FreezeReason, FreezeState};
use crate::runtime::manifest::Manifest;

/// Per-component EB evidence test over the probed gradient statistics.
pub struct EbCriterion {
    /// The `[eb]` settings this criterion runs under.
    pub cfg: EbConfig,
    grace_steps: usize,
    above_count: Vec<usize>,
    /// Component parameter counts (the evidence normalizer).
    n_params: Vec<usize>,
    /// False for runs under other methods (observe() is then a no-op).
    pub enabled: bool,
}

impl EbCriterion {
    /// Criterion over the manifest's components for a `total_steps` run.
    pub fn new(cfg: &EbConfig, manifest: &Manifest, total_steps: usize) -> Self {
        EbCriterion {
            grace_steps: ((total_steps as f64) * cfg.alpha).ceil() as usize,
            above_count: vec![0; manifest.n_components],
            n_params: manifest.components.iter().map(|c| c.n_params).collect(),
            cfg: cfg.clone(),
            enabled: true,
        }
    }

    /// ⌈alpha·T⌉ — no freeze decisions before this step.
    pub fn grace_steps(&self) -> usize {
        self.grace_steps
    }

    /// Component `c`'s evidence from a probed metrics prefix: exact from
    /// the gvar block when the layout has one, otherwise the Gdiff/Gabs
    /// fallback. Large negative while the gradient carries signal,
    /// crossing 0 as it degenerates to noise.
    pub fn evidence(&self, manifest: &Manifest, metrics: &[f32], c: usize) -> f64 {
        if let Some(go) = manifest.gvar_offset {
            let n = self.n_params[c].max(1) as f64;
            1.0 - metrics[go + c] as f64 / n
        } else {
            let gabs = metrics[manifest.gabs_offset + c] as f64;
            let gdiff = (metrics[manifest.gdiff_offset + c] as f64).max(1e-30);
            let r = gabs / gdiff;
            1.0 - 2.0 * r * r
        }
    }

    /// Observe step `t`'s metrics; freeze every component whose evidence
    /// has exceeded the margin for `patience + 1` consecutive probes.
    /// Returns the number of components newly frozen.
    pub fn observe(
        &mut self,
        t: usize,
        manifest: &Manifest,
        metrics: &[f32],
        freeze: &mut FreezeState,
    ) -> usize {
        if !self.enabled || t <= self.grace_steps {
            return 0;
        }
        let mut newly = 0usize;
        for c in 0..freeze.n() {
            if freeze.is_frozen(c) {
                continue;
            }
            // an elided/omitted component probes all-zero stats — no
            // observation, not evidence of convergence
            if metrics[manifest.gabs_offset + c] == 0.0
                && metrics[manifest.gdiff_offset + c] == 0.0
            {
                continue;
            }
            let e = self.evidence(manifest, metrics, c);
            if e > self.cfg.margin {
                self.above_count[c] += 1;
                if self.above_count[c] > self.cfg.patience {
                    freeze.freeze(c, t, FreezeReason::Evidence, e);
                    newly += 1;
                }
            } else {
                self.above_count[c] = 0;
            }
        }
        newly
    }

    /// Stop when every monitored component is frozen (as in Alg. 1).
    pub fn should_terminate(&self, freeze: &FreezeState) -> bool {
        self.enabled && freeze.n() > 0 && freeze.all_frozen()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::grades::tests::fake_manifest;

    fn cfg(margin: f64, alpha: f64, patience: usize) -> EbConfig {
        EbConfig { gvar: false, alpha, margin, patience }
    }

    fn metrics(m: &Manifest, gdiff: f32, gabs: f32) -> Vec<f32> {
        let mut out = vec![0f32; m.metrics_len];
        for c in 0..m.n_components {
            out[m.gdiff_offset + c] = gdiff;
            out[m.gabs_offset + c] = gabs;
        }
        out
    }

    #[test]
    fn fallback_evidence_is_negative_while_signal_dominates() {
        let m = fake_manifest(1);
        let eb = EbCriterion::new(&cfg(0.0, 0.0, 0), &m, 100);
        // signal regime: the gradient barely changes step to step
        let mx = metrics(&m, 0.1, 1.0);
        assert!(eb.evidence(&m, &mx, 0) < 0.0);
        // noise regime: |g − prev| ≈ √2·|g| ⇒ evidence ≈ 0; push past it
        let mx = metrics(&m, 2.0, 1.0);
        assert!(eb.evidence(&m, &mx, 0) > 0.0);
    }

    #[test]
    fn exact_evidence_uses_the_gvar_block() {
        let mut m = fake_manifest(1);
        let n = m.n_components;
        m.gvar_offset = Some(m.metrics_len);
        m.metrics_len += n;
        let mut mx = metrics(&m, 1.0, 1.0);
        mx.resize(m.metrics_len, 0.0);
        // gvar sum = 2·n_params ⇒ e = 1 − 2 = −1 (signal); = 0.5·n_params ⇒ 0.5
        let np = m.components[0].n_params as f32;
        let eb = EbCriterion::new(&cfg(0.0, 0.0, 0), &m, 100);
        mx[m.gvar_offset.unwrap()] = 2.0 * np;
        assert!((eb.evidence(&m, &mx, 0) - (-1.0)).abs() < 1e-9);
        mx[m.gvar_offset.unwrap()] = 0.5 * np;
        assert!((eb.evidence(&m, &mx, 0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn grace_period_and_patience_gate_freezing() {
        let m = fake_manifest(1);
        let mut eb = EbCriterion::new(&cfg(0.0, 0.5, 1), &m, 100);
        let mut fs = FreezeState::new(m.n_components);
        let noisy = metrics(&m, 2.0, 1.0);
        assert_eq!(eb.observe(50, &m, &noisy, &mut fs), 0); // grace
        assert_eq!(eb.observe(51, &m, &noisy, &mut fs), 0); // patience 1
        assert_eq!(eb.observe(52, &m, &noisy, &mut fs), m.n_components);
        assert!(eb.should_terminate(&fs));
    }

    #[test]
    fn signal_resets_patience_and_elided_stats_are_skipped() {
        let m = fake_manifest(1);
        let mut eb = EbCriterion::new(&cfg(0.0, 0.0, 1), &m, 100);
        let mut fs = FreezeState::new(m.n_components);
        let noisy = metrics(&m, 2.0, 1.0);
        let signal = metrics(&m, 0.1, 1.0);
        let zeros = metrics(&m, 0.0, 0.0);
        assert_eq!(eb.observe(1, &m, &noisy, &mut fs), 0);
        assert_eq!(eb.observe(2, &m, &signal, &mut fs), 0); // reset
        assert_eq!(eb.observe(3, &m, &zeros, &mut fs), 0); // no observation
        assert_eq!(eb.observe(4, &m, &noisy, &mut fs), 0); // count = 1 again
        assert_eq!(eb.observe(5, &m, &noisy, &mut fs), m.n_components);
    }
}
