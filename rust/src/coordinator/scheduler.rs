//! Executable-variant scheduler.
//!
//! A static XLA graph cannot skip a single matrix's dW matmul at runtime,
//! so the compute tier of GradES's savings is realized by hot-swapping to
//! pre-compiled graph variants. The shipped variant set exploits the
//! paper's Fig. 4a observation (attention converges 2–3× earlier than
//! MLP): once *every* attention component is frozen, switch to
//! `train_step_attn_frozen`, whose backward pass genuinely omits all
//! attention weight-gradient matmuls.

use crate::coordinator::freeze::FreezeState;
use crate::runtime::manifest::Manifest;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// Which pre-compiled train-step graph a step executes.
pub enum Variant {
    /// The full backward graph (every dW matmul present).
    Full,
    /// Backward graph with all attention dW matmuls removed.
    AttnFrozen,
}

#[derive(Debug, Default)]
/// Hot-swaps the train-step executable once attention froze.
pub struct VariantScheduler {
    attn_components: Vec<usize>,
    /// Step the swap happened at (None = still on the full graph).
    pub swapped_at: Option<usize>,
    /// Swapping enabled (GradES runs only; off for baselines).
    pub enabled: bool,
}

impl VariantScheduler {
    /// Scheduler over the manifest's attention components.
    pub fn new(manifest: &Manifest, enabled: bool) -> Self {
        VariantScheduler {
            attn_components: manifest.components_where(|c| c.group == "attention"),
            swapped_at: None,
            enabled,
        }
    }

    /// Pick the variant for step `t` given the current freeze state.
    /// Monotone: once swapped, never swaps back (frozen components with
    /// the default config never unfreeze; the dynamic-unfreeze extension
    /// disables the scheduler instead).
    pub fn pick(&mut self, t: usize, freeze: &FreezeState) -> Variant {
        if !self.enabled || self.attn_components.is_empty() {
            return Variant::Full;
        }
        if self.swapped_at.is_some() {
            return Variant::AttnFrozen;
        }
        let all_attn_frozen =
            self.attn_components.iter().all(|&c| freeze.is_frozen(c));
        if all_attn_frozen {
            self.swapped_at = Some(t);
            Variant::AttnFrozen
        } else {
            Variant::Full
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::freeze::FreezeReason;
    use crate::coordinator::grades::tests::fake_manifest;

    #[test]
    fn swaps_when_all_attention_frozen() {
        let m = fake_manifest(2);
        let mut s = VariantScheduler::new(&m, true);
        let mut fs = FreezeState::new(m.n_components);
        assert_eq!(s.pick(1, &fs), Variant::Full);
        for c in &m.components {
            if c.group == "attention" {
                fs.freeze(c.idx, 5, FreezeReason::Converged, 0.0);
            }
        }
        assert_eq!(s.pick(6, &fs), Variant::AttnFrozen);
        assert_eq!(s.swapped_at, Some(6));
        // monotone
        assert_eq!(s.pick(7, &fs), Variant::AttnFrozen);
    }

    #[test]
    fn disabled_never_swaps() {
        let m = fake_manifest(1);
        let mut s = VariantScheduler::new(&m, false);
        let mut fs = FreezeState::new(m.n_components);
        for c in 0..m.n_components {
            fs.freeze(c, 1, FreezeReason::Converged, 0.0);
        }
        assert_eq!(s.pick(2, &fs), Variant::Full);
    }
}
