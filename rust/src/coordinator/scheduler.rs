//! Freeze-aware step planning: which per-component dW matmuls a train
//! step computes, and how each engine realizes that plan.
//!
//! GradES's compute tier used to be one coarse hot swap: a boolean
//! `attn_frozen` that flipped to the `train_step_attn_frozen` graph once
//! *every* attention component froze. This module generalizes it into a
//! first-class [`StepPlan`] — the set of component dW matmuls to *omit*
//! — derived every step from the [`FreezeState`] by a [`StepPlanner`].
//! Lowering is per-engine:
//!
//! * the **host engine** honors a plan exactly: every omitted matrix
//!   skips its dW matmul, Eq. 1 gdiff/gabs statistics, prev-grad carry
//!   and optimizer slot update; plans carrying the opt-in truncation
//!   grant additionally stop the backward sweep below a fully-frozen
//!   layer *prefix* (`runtime::host_backend`);
//! * the **XLA engine** lowers a plan to the nearest *sound*
//!   pre-compiled graph variant from a data-driven [`VariantLattice`]
//!   (a variant is sound for a plan iff the variant's omitted set ⊆ the
//!   plan's omitted set). Today's lattice holds the two shipped graphs
//!   (`train_step`, `train_step_attn_frozen`); artifacts may declare
//!   more via the manifest's `variants` table without touching the
//!   trainer.
//!
//! The soundness rule that makes all of this trajectory-preserving:
//! **a plan may only omit frozen components** (omitted ⊆ frozen). A
//! frozen component's masked update is a bit-exact no-op, so omitting
//! the work that feeds it changes nothing the trajectory can see except
//! the component's own (already-ignored) logged statistics.

use crate::config::GradesConfig;
use crate::coordinator::freeze::FreezeState;
use crate::runtime::manifest::Manifest;

// ---------------------------------------------------------------------------
// StepPlan
// ---------------------------------------------------------------------------

/// One step's execution plan: which monitored components' dW matmuls
/// (and the dependent Eq. 1 statistics, prev-grad carry and optimizer
/// slot update) to **omit**. An all-active plan reproduces the full
/// graph bitwise.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StepPlan {
    omit: Vec<bool>,
    n_omitted: usize,
    truncate: bool,
}

impl StepPlan {
    /// The full-compute plan over `n` components (nothing omitted).
    pub fn all_active(n: usize) -> Self {
        StepPlan { omit: vec![false; n], n_omitted: 0, truncate: false }
    }

    /// Plan over `n` components omitting exactly `omitted` (indices may
    /// repeat; out-of-range indices panic).
    pub fn omitting(n: usize, omitted: &[usize]) -> Self {
        let mut plan = Self::all_active(n);
        for &c in omitted {
            if !plan.omit[c] {
                plan.omit[c] = true;
                plan.n_omitted += 1;
            }
        }
        plan
    }

    /// The ideal freeze-aware plan: omit exactly the frozen components.
    pub fn from_freeze(freeze: &FreezeState) -> Self {
        let omitted: Vec<usize> = (0..freeze.n()).filter(|&c| freeze.is_frozen(c)).collect();
        Self::omitting(freeze.n(), &omitted)
    }

    /// Monitored component count the plan covers.
    pub fn n(&self) -> usize {
        self.omit.len()
    }

    /// Does the plan omit component `c`'s dW work?
    pub fn omits(&self, c: usize) -> bool {
        self.omit[c]
    }

    /// Number of omitted components.
    pub fn n_omitted(&self) -> usize {
        self.n_omitted
    }

    /// True when nothing is omitted (the full graph).
    pub fn is_all_active(&self) -> bool {
        self.n_omitted == 0
    }

    /// Omitted component indices, ascending.
    pub fn omitted(&self) -> Vec<usize> {
        (0..self.n()).filter(|&c| self.omit[c]).collect()
    }

    /// The soundness rule: every omitted component is frozen.
    pub fn is_sound(&self, freeze: &FreezeState) -> bool {
        self.n() == freeze.n() && (0..self.n()).all(|c| !self.omit[c] || freeze.is_frozen(c))
    }

    /// Allow the backward-sweep truncation below a fully omitted layer
    /// *prefix* (the AutoFreeze-style whole-layer rule). This is a
    /// **trajectory-changing** capability grant — the truncated layers'
    /// norm scales and the embeddings are held instead of updated — so
    /// it is opt-in (`TrainerOptions::truncate_frozen_prefix`) and never
    /// set by default. Engines that cannot truncate (XLA) ignore it.
    pub fn with_truncation(mut self) -> Self {
        self.truncate = true;
        self
    }

    /// May the engine truncate the backward sweep below a fully omitted
    /// layer prefix?
    pub fn truncates(&self) -> bool {
        self.truncate
    }

    /// Is this plan weaker-or-equal to `other` — omitted set a subset,
    /// and no capability (truncation) granted that `other` withheld?
    /// What a sound per-engine lowering must satisfy relative to the
    /// requested plan.
    pub fn is_subset_of(&self, other: &StepPlan) -> bool {
        self.n() == other.n()
            && (0..self.n()).all(|c| !self.omit[c] || other.omit[c])
            && (!self.truncate || other.truncate)
    }
}

// ---------------------------------------------------------------------------
// StepPlanner
// ---------------------------------------------------------------------------

/// Counters the planner keeps for reporting (`TrainOutcome::plan`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanStats {
    /// First step whose plan omitted anything.
    pub first_elision_step: Option<usize>,
    /// First step whose plan omitted *every* attention component — on
    /// the XLA lattice this is where the `train_step_attn_frozen`
    /// lowering becomes reachable (the old variant-scheduler swap step).
    pub attn_swap_step: Option<usize>,
    /// Steps planned with a non-empty omitted set.
    pub elided_steps: usize,
    /// Steps whose plan re-included a previously omitted component
    /// (dynamic unfreezing downgraded the plan).
    pub downgrades: usize,
    /// Largest omitted-set size any step planned.
    pub max_omitted: usize,
}

/// Derives each step's [`StepPlan`] from the freeze mask. Subsumes the
/// old `VariantScheduler`: where that struct monotonically latched one
/// boolean once all attention froze, the planner re-derives the omitted
/// set every step — so dynamic unfreezing (§8) *downgrades* the plan
/// instead of leaving a stale elision in place.
#[derive(Debug)]
pub struct StepPlanner {
    n: usize,
    attn_components: Vec<usize>,
    /// Elision enabled (GradES runs only; baselines plan all-active).
    pub enabled: bool,
    /// Grant the backward-sweep truncation capability on derived plans
    /// (see [`StepPlan::with_truncation`]; off by default because it
    /// changes the trajectory once a layer prefix fully froze).
    pub truncate: bool,
    prev_omit: Vec<bool>,
    /// Reporting counters.
    pub stats: PlanStats,
}

impl StepPlanner {
    /// Planner over the manifest's components. `enabled = false` plans
    /// all-active forever (baseline methods, A/B harnesses).
    pub fn new(manifest: &Manifest, enabled: bool) -> Self {
        StepPlanner {
            n: manifest.n_components,
            attn_components: manifest.components_where(|c| c.group == "attention"),
            enabled,
            truncate: false,
            prev_omit: vec![false; manifest.n_components],
            stats: PlanStats::default(),
        }
    }

    /// Planner for a training run under `[grades]` settings. Identical
    /// to [`StepPlanner::new`] except that when dynamic unfreezing can
    /// actually fire (`unfreeze_factor > 0` with the `l1_abs` metric —
    /// the only metric the monitor reactivates on), elision is disabled:
    /// an omitted component reports `Gabs = 0`, which would starve the
    /// rebound signal and make unfreezing impossible. Correctness over
    /// savings, warn-free: the run simply plans all-active.
    pub fn for_run(
        manifest: &Manifest,
        grades: &GradesConfig,
        enabled: bool,
    ) -> anyhow::Result<Self> {
        // parsed through the monitor's own metric table so the two can
        // never disagree on which spellings mean Gabs-monitoring
        let unfreeze_live = grades.unfreeze_factor > 0.0
            && crate::coordinator::grades::Metric::parse(&grades.metric)?
                == crate::coordinator::grades::Metric::L1Abs;
        Ok(Self::new(manifest, enabled && !unfreeze_live))
    }

    /// Derive step `t`'s plan: omit exactly the frozen components.
    /// Sound by construction (omitted ⊆ frozen) and non-monotone — a
    /// component unfrozen since the last step re-enters the plan.
    pub fn plan(&mut self, t: usize, freeze: &FreezeState) -> StepPlan {
        if !self.enabled {
            return StepPlan::all_active(self.n);
        }
        let mut plan = StepPlan::from_freeze(freeze);
        if self.truncate {
            plan = plan.with_truncation();
        }
        if (0..self.n).any(|c| self.prev_omit[c] && !plan.omits(c)) {
            self.stats.downgrades += 1;
        }
        if !plan.is_all_active() {
            self.stats.elided_steps += 1;
            self.stats.first_elision_step.get_or_insert(t);
            self.stats.max_omitted = self.stats.max_omitted.max(plan.n_omitted());
            if self.stats.attn_swap_step.is_none()
                && !self.attn_components.is_empty()
                && self.attn_components.iter().all(|&c| plan.omits(c))
            {
                self.stats.attn_swap_step = Some(t);
            }
        }
        self.prev_omit.clear();
        self.prev_omit.extend((0..self.n).map(|c| plan.omits(c)));
        plan
    }
}

// ---------------------------------------------------------------------------
// VariantLattice — the XLA engine's lowering table
// ---------------------------------------------------------------------------

/// One pre-compiled train-step graph variant: its executable key and the
/// component dW matmuls its backward graph omits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VariantDef {
    /// Executable key in the manifest (`train_step`, `train_step_attn_frozen`, …).
    pub key: String,
    /// Omitted component indices, ascending.
    pub omit: Vec<usize>,
}

/// The set of train-step variants an artifact ships, ordered ⊆-wise by
/// what each omits (a lattice under set inclusion, with the full graph
/// as bottom). Built from manifest data so future artifacts slot in new
/// variants without touching the trainer or the session.
#[derive(Debug, Clone)]
pub struct VariantLattice {
    /// All variants; index 0 is always the full graph (empty omit set).
    pub variants: Vec<VariantDef>,
}

impl VariantLattice {
    /// Build from explicit variant definitions. The full graph (empty
    /// omitted set) is required — it is the sound lowering of last
    /// resort for every plan.
    pub fn new(mut variants: Vec<VariantDef>) -> anyhow::Result<Self> {
        for v in variants.iter_mut() {
            v.omit.sort_unstable();
            v.omit.dedup();
        }
        // full graph first, then ascending omitted-set size (determinism)
        variants.sort_by(|a, b| a.omit.len().cmp(&b.omit.len()).then(a.key.cmp(&b.key)));
        anyhow::ensure!(
            variants.first().map_or(false, |v| v.omit.is_empty()),
            "variant lattice has no full (empty-omit) train-step graph"
        );
        Ok(VariantLattice { variants })
    }

    /// Derive the lattice from a manifest: one variant per `train_step*`
    /// executable key. Omitted sets come from the manifest's optional
    /// `variants` table (component *names* per key); the two shipped
    /// keys have built-in definitions (`train_step` omits nothing,
    /// `train_step_attn_frozen` omits every attention component). An
    /// unknown key without a `variants` entry is an error — a silent
    /// guess here could execute the wrong graph.
    pub fn from_manifest(m: &Manifest) -> anyhow::Result<Self> {
        let mut variants = Vec::new();
        for key in m.executables.keys() {
            if !key.starts_with("train_step") {
                continue;
            }
            let omit = if let Some(names) = m.variants.get(key) {
                names
                    .iter()
                    .map(|n| {
                        m.components
                            .iter()
                            .find(|c| &c.name == n)
                            .map(|c| c.idx)
                            .ok_or_else(|| {
                                anyhow::anyhow!(
                                    "manifest variant {key:?} omits unknown component {n:?}"
                                )
                            })
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?
            } else if key == "train_step" {
                Vec::new()
            } else if key == "train_step_attn_frozen" {
                m.components_where(|c| c.group == "attention")
            } else {
                anyhow::bail!(
                    "train-step executable {key:?} has no built-in omitted set; declare \
                     it in the manifest's `variants` table"
                );
            };
            variants.push(VariantDef { key: key.clone(), omit });
        }
        // a declared variant that the collection loop above skipped —
        // key misspelled, or attached to a non-train-step executable —
        // would be silently dropped (plans would lower to the full graph
        // and the promised savings never materialize); refuse instead
        for key in m.variants.keys() {
            anyhow::ensure!(
                key.starts_with("train_step") && m.executables.contains_key(key),
                "manifest `variants` entry {key:?} names no train_step* executable (typo?)"
            );
        }
        Self::new(variants)
    }

    /// Lower a plan to the nearest sound variant: the variant with the
    /// largest omitted set that is a subset of the plan's omitted set.
    /// Always succeeds (the full graph is sound for every plan). Returns
    /// the variant index.
    pub fn lower_index(&self, plan: &StepPlan) -> usize {
        let mut best = 0;
        for (i, v) in self.variants.iter().enumerate() {
            if v.omit.len() > self.variants[best].omit.len()
                && v.omit.iter().all(|&c| plan.omits(c))
            {
                best = i;
            }
        }
        best
    }

    /// Lower a plan to its nearest sound variant definition.
    pub fn lower(&self, plan: &StepPlan) -> &VariantDef {
        &self.variants[self.lower_index(plan)]
    }

    /// The variant whose omitted set equals the plan's exactly, if any
    /// (how the XLA engine maps an already-lowered plan back to its
    /// executable).
    pub fn exact_index(&self, plan: &StepPlan) -> Option<usize> {
        self.variants.iter().position(|v| {
            v.omit.len() == plan.n_omitted() && v.omit.iter().all(|&c| plan.omits(c))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::freeze::FreezeReason;
    use crate::coordinator::grades::tests::fake_manifest;

    fn grades_cfg(metric: &str, unfreeze: f64) -> GradesConfig {
        GradesConfig {
            metric: metric.into(),
            alpha: 0.1,
            tau: 0.5,
            tau_vision: f64::NAN,
            tau_language: f64::NAN,
            patience: 0,
            unfreeze_factor: unfreeze,
            granularity: "matrix".into(),
        }
    }

    #[test]
    fn plan_accessors_and_soundness() {
        let mut fs = FreezeState::new(4);
        let full = StepPlan::all_active(4);
        assert!(full.is_all_active() && full.n_omitted() == 0 && full.is_sound(&fs));
        let p = StepPlan::omitting(4, &[1, 3, 3]);
        assert_eq!(p.n_omitted(), 2);
        assert!(p.omits(1) && p.omits(3) && !p.omits(0));
        assert_eq!(p.omitted(), vec![1, 3]);
        assert!(!p.is_sound(&fs), "omitting active components is unsound");
        fs.freeze(1, 1, FreezeReason::Manual, 0.0);
        fs.freeze(3, 1, FreezeReason::Manual, 0.0);
        assert!(p.is_sound(&fs));
        assert!(full.is_subset_of(&p) && !p.is_subset_of(&full));
        assert!(p.is_subset_of(&p));
    }

    #[test]
    fn planner_omits_exactly_the_frozen_set() {
        let m = fake_manifest(2);
        let mut planner = StepPlanner::new(&m, true);
        let mut fs = FreezeState::new(m.n_components);
        assert!(planner.plan(1, &fs).is_all_active());
        fs.freeze(2, 2, FreezeReason::Converged, 0.0);
        fs.freeze(9, 2, FreezeReason::Converged, 0.0);
        let p = planner.plan(3, &fs);
        assert_eq!(p.omitted(), vec![2, 9]);
        assert!(p.is_sound(&fs));
        assert_eq!(planner.stats.first_elision_step, Some(3));
        assert_eq!(planner.stats.max_omitted, 2);
        assert_eq!(planner.stats.attn_swap_step, None);
    }

    #[test]
    fn planner_records_attn_swap_when_all_attention_omitted() {
        // the generalized analogue of the old VariantScheduler swap test
        let m = fake_manifest(2);
        let mut planner = StepPlanner::new(&m, true);
        let mut fs = FreezeState::new(m.n_components);
        assert!(planner.plan(1, &fs).is_all_active());
        for c in &m.components {
            if c.group == "attention" {
                fs.freeze(c.idx, 5, FreezeReason::Converged, 0.0);
            }
        }
        let p = planner.plan(6, &fs);
        assert!(!p.is_all_active());
        assert_eq!(planner.stats.attn_swap_step, Some(6));
        planner.plan(7, &fs);
        assert_eq!(planner.stats.attn_swap_step, Some(6), "swap step is first-hit");
    }

    #[test]
    fn disabled_planner_never_elides() {
        let m = fake_manifest(1);
        let mut planner = StepPlanner::new(&m, false);
        let mut fs = FreezeState::new(m.n_components);
        for c in 0..m.n_components {
            fs.freeze(c, 1, FreezeReason::Converged, 0.0);
        }
        assert!(planner.plan(2, &fs).is_all_active());
        assert_eq!(planner.stats, PlanStats::default());
    }

    #[test]
    fn unfreeze_downgrades_the_plan() {
        let m = fake_manifest(1);
        let mut planner = StepPlanner::new(&m, true);
        let mut fs = FreezeState::new(m.n_components);
        fs.freeze(0, 1, FreezeReason::Converged, 0.0);
        assert!(planner.plan(2, &fs).omits(0));
        fs.unfreeze(0, 3, FreezeReason::Reactivated, 1.0);
        let p = planner.plan(3, &fs);
        assert!(!p.omits(0), "stale elision survived an unfreeze");
        assert!(p.is_sound(&fs));
        assert_eq!(planner.stats.downgrades, 1);
    }

    #[test]
    fn for_run_disables_elision_when_unfreeze_needs_live_stats() {
        let m = fake_manifest(1);
        let mut fs = FreezeState::new(m.n_components);
        fs.freeze(0, 1, FreezeReason::Converged, 0.0);
        // unfreeze can only fire on the l1_abs metric: elision off
        let mut live = StepPlanner::for_run(&m, &grades_cfg("l1_abs", 2.0), true).unwrap();
        assert!(live.plan(2, &fs).is_all_active());
        // with the default metric the unfreeze rule never fires: elide
        let mut diff = StepPlanner::for_run(&m, &grades_cfg("l1_diff", 2.0), true).unwrap();
        assert!(diff.plan(2, &fs).omits(0));
        // and unfreeze disabled entirely: elide
        let mut off = StepPlanner::for_run(&m, &grades_cfg("l1_abs", 0.0), true).unwrap();
        assert!(off.plan(2, &fs).omits(0));
    }

    #[test]
    fn lattice_from_manifest_holds_the_two_shipped_variants() {
        let mut m = fake_manifest(2);
        m.executables.insert("train_step".into(), "train_step.hlo.txt".into());
        m.executables
            .insert("train_step_attn_frozen".into(), "train_step_attn_frozen.hlo.txt".into());
        m.executables.insert("probe".into(), "probe.hlo.txt".into()); // ignored
        let lat = VariantLattice::from_manifest(&m).unwrap();
        assert_eq!(lat.variants.len(), 2);
        assert_eq!(lat.variants[0].key, "train_step");
        assert!(lat.variants[0].omit.is_empty());
        assert_eq!(lat.variants[1].key, "train_step_attn_frozen");
        assert_eq!(lat.variants[1].omit, m.components_where(|c| c.group == "attention"));
    }

    #[test]
    fn lattice_lowering_is_sound_and_maximal() {
        let m = fake_manifest(2);
        let attn = m.components_where(|c| c.group == "attention");
        let lat = VariantLattice::new(vec![
            VariantDef { key: "train_step".into(), omit: vec![] },
            VariantDef { key: "train_step_attn_frozen".into(), omit: attn.clone() },
        ])
        .unwrap();
        // plan omits nothing → full graph
        assert_eq!(lat.lower(&StepPlan::all_active(m.n_components)).key, "train_step");
        // plan omits attention plus extra mlp components → attn variant
        let mut omitted = attn.clone();
        omitted.push(4); // an mlp component
        let p = StepPlan::omitting(m.n_components, &omitted);
        let v = lat.lower(&p);
        assert_eq!(v.key, "train_step_attn_frozen");
        assert!(v.omit.iter().all(|&c| p.omits(c)), "lowering must be sound");
        // plan omits a strict subset of attention → must fall back to full
        let partial = StepPlan::omitting(m.n_components, &attn[..attn.len() - 1]);
        assert_eq!(lat.lower(&partial).key, "train_step");
        // exact lookups
        assert_eq!(lat.exact_index(&StepPlan::omitting(m.n_components, &attn)), Some(1));
        assert_eq!(lat.exact_index(&StepPlan::all_active(m.n_components)), Some(0));
        assert_eq!(lat.exact_index(&p), None);
    }

    #[test]
    fn lattice_requires_a_full_graph_and_rejects_unknown_keys() {
        assert!(VariantLattice::new(vec![VariantDef {
            key: "train_step_attn_frozen".into(),
            omit: vec![0],
        }])
        .is_err());
        let mut m = fake_manifest(1);
        m.executables.insert("train_step".into(), "a".into());
        m.executables.insert("train_step_mystery".into(), "b".into());
        let err = VariantLattice::from_manifest(&m).unwrap_err().to_string();
        assert!(err.contains("train_step_mystery"), "{err}");
        // …unless the manifest declares its omitted set by component name
        m.variants.insert(
            "train_step_mystery".into(),
            vec![m.components[0].name.clone(), m.components[1].name.clone()],
        );
        let lat = VariantLattice::from_manifest(&m).unwrap();
        assert_eq!(lat.variants.len(), 2);
        assert_eq!(lat.variants[1].omit, vec![0, 1]);
        // a variants entry whose key names no executable is a typo, not
        // a silent no-op
        m.variants.insert("train_step_typo".into(), vec![m.components[0].name.clone()]);
        let err = VariantLattice::from_manifest(&m).unwrap_err().to_string();
        assert!(err.contains("train_step_typo"), "{err}");
    }
}
