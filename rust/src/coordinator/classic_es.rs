//! Classic validation-loss early stopping — the paper's "+ES" baseline.
//!
//! Validation runs every `check_interval_frac·T` steps (the paper uses 5%)
//! and requires a full forward pass over the whole validation set — the
//! overhead that makes FP+ES *slower* than the no-ES baseline in Table 4.
//! Training stops when the loss fails to improve by `min_delta` for
//! `patience` consecutive checks.

use crate::config::EsConfig;

#[derive(Debug, Clone)]
/// Patience-based validation-loss early stopping state.
pub struct ClassicEs {
    /// The `[es]` settings this rule runs under.
    pub cfg: EsConfig,
    /// Steps between checks (⌈check_interval_frac·T⌉).
    pub check_interval: usize,
    best: f64,
    bad_checks: usize,
    /// Validation checks recorded so far.
    pub checks_run: usize,
    /// Wall-clock seconds spent inside validation (Table 4 overhead).
    pub validation_secs: f64,
    /// False for non-ES runs (due() is then never true).
    pub enabled: bool,
}

impl ClassicEs {
    /// Early stopping over a `total_steps` budget.
    pub fn new(cfg: &EsConfig, total_steps: usize) -> Self {
        let check_interval =
            ((total_steps as f64) * cfg.check_interval_frac).ceil().max(1.0) as usize;
        ClassicEs {
            cfg: cfg.clone(),
            check_interval,
            best: f64::INFINITY,
            bad_checks: 0,
            checks_run: 0,
            validation_secs: 0.0,
            enabled: true,
        }
    }

    /// A rule that never checks and never stops (baseline runs).
    pub fn disabled(cfg: &EsConfig) -> Self {
        let mut es = Self::new(cfg, usize::MAX / 2);
        es.enabled = false;
        es
    }

    /// Is step `t` a validation checkpoint? Step 0 is never one: the
    /// model has not been updated yet, and a check there would burn a
    /// patience-window slot (and a full validation pass) on the
    /// untrained model.
    pub fn due(&self, t: usize) -> bool {
        self.enabled && t > 0 && t % self.check_interval == 0
    }

    /// Record a validation loss; returns true when training should stop.
    pub fn record(&mut self, val_loss: f64, secs: f64) -> bool {
        if !self.enabled {
            return false;
        }
        self.checks_run += 1;
        self.validation_secs += secs;
        if val_loss < self.best - self.cfg.min_delta {
            self.best = val_loss;
            self.bad_checks = 0;
        } else {
            self.bad_checks += 1;
        }
        self.bad_checks >= self.cfg.patience
    }

    /// Best validation loss seen so far.
    pub fn best_loss(&self) -> f64 {
        self.best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> EsConfig {
        EsConfig { check_interval_frac: 0.05, patience: 3, min_delta: 0.001 }
    }

    #[test]
    fn interval_from_fraction() {
        let es = ClassicEs::new(&cfg(), 200);
        assert_eq!(es.check_interval, 10);
        assert!(es.due(10));
        assert!(!es.due(11));
    }

    #[test]
    fn step_zero_is_never_due() {
        // Regression: `0 % k == 0` made the rule demand a validation
        // pass before the first optimizer step, consuming one patience
        // slot on the untrained model.
        let es = ClassicEs::new(&cfg(), 200);
        assert!(!es.due(0));
        assert!(es.due(es.check_interval));
    }

    #[test]
    fn stops_after_patience_bad_checks() {
        let mut es = ClassicEs::new(&cfg(), 100);
        assert!(!es.record(1.0, 0.1));
        assert!(!es.record(0.9, 0.1)); // improvement
        assert!(!es.record(0.9, 0.1)); // bad 1 (< min_delta improvement)
        assert!(!es.record(0.95, 0.1)); // bad 2
        assert!(es.record(0.91, 0.1)); // bad 3 → stop
        assert_eq!(es.checks_run, 5);
        assert!((es.validation_secs - 0.5).abs() < 1e-9);
    }

    #[test]
    fn improvement_resets_patience() {
        let mut es = ClassicEs::new(&cfg(), 100);
        es.record(1.0, 0.0);
        es.record(1.0, 0.0); // bad 1
        es.record(1.0, 0.0); // bad 2
        assert!(!es.record(0.5, 0.0)); // improvement resets
        assert!(!es.record(0.51, 0.0)); // bad 1
        assert!(!es.record(0.51, 0.0)); // bad 2
        assert!(es.record(0.51, 0.0)); // bad 3
    }

    #[test]
    fn disabled_never_stops() {
        let mut es = ClassicEs::disabled(&cfg());
        assert!(!es.due(10));
        assert!(!es.record(1.0, 0.0));
    }
}
