//! The GradES monitor — paper Algorithm 1, lines 3–11 + extensions.
//!
//! Consumes the per-component gradient statistics the train step wrote into
//! the metrics prefix (Eq. 1 `Gdiff[c] = ‖∇W_t − ∇W_{t−1}‖₁` and the §3.1
//! alternative `Gabs[c] = ‖∇W_t‖₁`) and decides which components to freeze:
//!
//! * grace period: monitoring starts at `⌈α·T⌉` (Alg. 1 line 3),
//! * τ per component — with tower-specific overrides for VLMs (App. C
//!   Table 10: vision vs language thresholds),
//! * optional patience (§8 future work): require `patience+1` consecutive
//!   sub-τ observations before freezing,
//! * optional dynamic unfreezing (§8): if a frozen component's observed
//!   metric rebounds above `unfreeze_factor·τ`, reactivate it,
//! * optional layer granularity (AutoFreeze-style ablation baseline).

use crate::config::GradesConfig;
use crate::coordinator::freeze::{layer_groups, FreezeReason, FreezeState};
use crate::runtime::manifest::Manifest;
use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// Which gradient statistic drives freezing decisions.
pub enum Metric {
    /// Eq. 1: ‖∇W_t − ∇W_{t−1}‖₁ (the paper's default).
    L1Diff,
    /// §3.1 alternative: ‖∇W_t‖₁.
    L1Abs,
    /// Update-change metric: Eq. 1 scaled by lr(t)/lr_base and normalized
    /// by the component's grace-period baseline — our usability extension
    /// (§8 hints at automatic threshold selection). The paper's own Fig. 1
    /// decay "reflects the cosine learning rate schedule" (§6.2): in its
    /// fine-tuning regime raw gradients shrink with the schedule; training
    /// from scratch they need not, so we measure the *parameter update*
    /// change lr_t·∇W directly. τ becomes scale-free ("freeze when the
    /// update-change falls to τ× its baseline"), transferable across model
    /// sizes where the paper needed per-model manual τ (Table 9 spans
    /// 0.001–6.4).
    L1DiffRel,
}

impl Metric {
    /// Parse a `[grades] metric` string — the single source of truth
    /// for metric spellings (the monitor and the step planner's
    /// unfreeze-liveness gate must never disagree on what `l1_abs` is).
    /// Unknown values are a hard config error: a typo like `l1diff_rel`
    /// used to fall back silently to [`Metric::L1Diff`] and change the
    /// experiment being run.
    pub fn parse(s: &str) -> Result<Metric> {
        match s {
            "l1_diff" => Ok(Metric::L1Diff),
            "l1_abs" => Ok(Metric::L1Abs),
            "l1_diff_rel" => Ok(Metric::L1DiffRel),
            other => bail!(
                "unknown [grades] metric {other:?} (expected l1_diff, l1_abs or l1_diff_rel)"
            ),
        }
    }
}

/// Algorithm 1's monitoring loop: per-component convergence tests
/// over the probed gradient statistics.
pub struct GradesMonitor {
    /// The `[grades]` settings this monitor runs under.
    pub cfg: GradesConfig,
    /// Parsed `cfg.metric`.
    pub metric: Metric,
    grace_steps: usize,
    taus: Vec<f64>,
    below_count: Vec<usize>,
    /// Per-step freeze-candidate bitmap, reused across observe() calls
    /// (indexed lookups keep the layer-granularity rule O(n), not O(n²)).
    candidate: Vec<bool>,
    layer_mode: bool,
    layers: Vec<Vec<usize>>,
    /// Per-component running mean of the metric over the second half of
    /// the grace period (the L1DiffRel denominator).
    baseline_sum: Vec<f64>,
    baseline_n: usize,
    /// False for baseline runs (observe() is then a no-op).
    pub enabled: bool,
}

impl GradesMonitor {
    /// Monitor over the manifest's components for a `total_steps` run.
    /// Errors on an unknown `[grades] metric` spelling.
    pub fn new(cfg: &GradesConfig, manifest: &Manifest, total_steps: usize) -> Result<Self> {
        let metric = Metric::parse(&cfg.metric)?;
        // Per-component τ with tower overrides (paper Table 10). Both
        // overrides are VLM-only: tower labels on an LM manifest are
        // incidental and must not let a stray `tau_vision`/`tau_language`
        // key retarget τ.
        let taus = manifest
            .components
            .iter()
            .map(|c| {
                let t = match c.tower.as_str() {
                    "vision" if !cfg.tau_vision.is_nan() && manifest.is_vlm() => cfg.tau_vision,
                    "language" if !cfg.tau_language.is_nan() && manifest.is_vlm() => {
                        cfg.tau_language
                    }
                    _ => cfg.tau,
                };
                t
            })
            .collect();
        Ok(GradesMonitor {
            metric,
            grace_steps: ((total_steps as f64) * cfg.alpha).ceil() as usize,
            taus,
            below_count: vec![0; manifest.n_components],
            candidate: vec![false; manifest.n_components],
            layer_mode: cfg.granularity == "layer",
            layers: layer_groups(manifest),
            baseline_sum: vec![0.0; manifest.n_components],
            baseline_n: 0,
            cfg: cfg.clone(),
            enabled: true,
        })
    }

    /// A disabled monitor (baseline methods run the same trainer loop).
    pub fn disabled(manifest: &Manifest) -> Self {
        let cfg = GradesConfig {
            metric: "l1_diff".into(),
            alpha: 1.0,
            tau: 0.0,
            tau_vision: f64::NAN,
            tau_language: f64::NAN,
            patience: 0,
            unfreeze_factor: 0.0,
            granularity: "matrix".into(),
        };
        let mut m = Self::new(&cfg, manifest, usize::MAX)
            .expect("disabled-monitor config is statically valid");
        m.enabled = false;
        m
    }

    /// ⌈α·T⌉ — no decisions before this step (Alg. 1 line 3).
    pub fn grace_steps(&self) -> usize {
        self.grace_steps
    }

    /// Component `c`'s effective threshold (tower overrides applied).
    pub fn tau(&self, c: usize) -> f64 {
        self.taus[c]
    }

    /// Select the raw metric vector from the probed metrics prefix.
    pub fn metric_values<'m>(
        &self,
        manifest: &Manifest,
        metrics: &'m [f32],
    ) -> &'m [f32] {
        let (off, n) = match self.metric {
            Metric::L1Abs => (manifest.gabs_offset, manifest.n_components),
            _ => (manifest.gdiff_offset, manifest.n_components),
        };
        &metrics[off..off + n]
    }

    /// Per-component baseline (L1DiffRel denominator; 1.0 otherwise).
    pub fn baseline(&self, c: usize) -> f64 {
        if self.metric == Metric::L1DiffRel && self.baseline_n > 0 {
            (self.baseline_sum[c] / self.baseline_n as f64).max(1e-12)
        } else {
            1.0
        }
    }

    /// Observe step `t`'s metrics and update the freeze state.
    /// Returns the number of components newly frozen this step.
    /// `lr_scale` = lr(t)/lr_base (used by the L1DiffRel update metric;
    /// pass 1.0 for the raw-paper metrics).
    pub fn observe(
        &mut self,
        t: usize,
        manifest: &Manifest,
        metrics: &[f32],
        lr_scale: f64,
        freeze: &mut FreezeState,
    ) -> usize {
        if !self.enabled {
            return 0;
        }
        let scale = if self.metric == Metric::L1DiffRel { lr_scale } else { 1.0 };
        // accumulate the rel-metric baseline over the grace period's
        // second half (past warmup transients, before decisions start)
        if t <= self.grace_steps {
            if self.metric == Metric::L1DiffRel && 2 * t > self.grace_steps {
                let raw = self.metric_values(manifest, metrics);
                for c in 0..self.baseline_sum.len() {
                    self.baseline_sum[c] += raw[c] as f64 * scale;
                }
                self.baseline_n += 1;
            }
            return 0;
        }
        let raw = self.metric_values(manifest, metrics);
        let values: Vec<f64> = (0..raw.len())
            .map(|c| raw[c] as f64 * scale / self.baseline(c))
            .collect();
        let mut newly = 0usize;

        // dynamic unfreezing (extension; default off)
        if self.cfg.unfreeze_factor > 0.0 {
            for c in 0..freeze.n() {
                if freeze.is_frozen(c)
                    && values[c] > self.cfg.unfreeze_factor * self.taus[c]
                    // Gdiff of a frozen component is stale (its prev-grad
                    // carry stopped); use Gabs which is always fresh.
                    && self.metric == Metric::L1Abs
                {
                    freeze.unfreeze(c, t, FreezeReason::Reactivated, values[c]);
                    self.below_count[c] = 0;
                }
            }
        }

        // per-component convergence test (Alg. 1 lines 8–11)
        self.candidate.fill(false);
        for c in 0..freeze.n() {
            if freeze.is_frozen(c) {
                continue;
            }
            if values[c] < self.taus[c] {
                self.below_count[c] += 1;
                if self.below_count[c] > self.cfg.patience {
                    self.candidate[c] = true;
                }
            } else {
                self.below_count[c] = 0;
            }
        }

        if self.layer_mode {
            // AutoFreeze-style: a layer freezes only as a whole
            for group in &self.layers {
                let all_ready = group.iter().all(|&c| freeze.is_frozen(c) || self.candidate[c]);
                if all_ready {
                    for &c in group {
                        if !freeze.is_frozen(c) {
                            freeze.freeze(c, t, FreezeReason::LayerRule, values[c]);
                            newly += 1;
                        }
                    }
                }
            }
        } else {
            for (c, &ready) in self.candidate.iter().enumerate() {
                if ready {
                    freeze.freeze(c, t, FreezeReason::Converged, values[c]);
                    newly += 1;
                }
            }
        }
        newly
    }

    /// Alg. 1 line 17–18: stop when every monitored component is frozen.
    pub fn should_terminate(&self, freeze: &FreezeState) -> bool {
        self.enabled && freeze.n() > 0 && freeze.all_frozen()
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::config::GradesConfig;
    use crate::runtime::manifest::{Component, FlopsInfo, Manifest};
    use std::collections::BTreeMap;

    pub fn fake_manifest(n_layers: usize) -> Manifest {
        let kinds = ["q", "k", "v", "o", "gate", "up", "down"];
        let mut components = Vec::new();
        for l in 0..n_layers {
            for k in kinds {
                components.push(Component {
                    idx: components.len(),
                    name: format!("language.{l}.{k}"),
                    layer: l,
                    kind: k.to_string(),
                    group: if matches!(k, "q" | "k" | "v" | "o") {
                        "attention".into()
                    } else {
                        "mlp".into()
                    },
                    tower: "language".into(),
                    n_params: 64,
                    tensors: vec![format!("lang.{l}.{k}")],
                });
            }
        }
        let n = components.len();
        Manifest {
            name: "fake".into(),
            kind: "lm".into(),
            method: "fp".into(),
            optimizer: "adamw".into(),
            kernel_impl: "xla".into(),
            batch_size: 8,
            seq_len: 16,
            vocab_size: 256,
            n_patches: 0,
            patch_dim: 0,
            state_len: 1000,
            metrics_len: 4 + 2 * n,
            ctrl_len: 4 + n,
            n_components: n,
            gdiff_offset: 4,
            gabs_offset: 4 + n,
            gvar_offset: None,
            ctrl_mask_offset: 4,
            components,
            params: vec![],
            n_params_total: 0,
            n_params_trainable: 0,
            flops: FlopsInfo {
                fwd_per_token: 0.0,
                bwd_dx_per_token: 0.0,
                per_component_fwd: BTreeMap::new(),
                attn_quadratic_per_token: 0.0,
                head_per_token: 0.0,
            },
            executables: BTreeMap::new(),
            variants: BTreeMap::new(),
        }
    }

    fn cfg(tau: f64, alpha: f64) -> GradesConfig {
        GradesConfig {
            metric: "l1_diff".into(),
            alpha,
            tau,
            tau_vision: f64::NAN,
            tau_language: f64::NAN,
            patience: 0,
            unfreeze_factor: 0.0,
            granularity: "matrix".into(),
        }
    }

    fn metrics_with_gdiff(m: &Manifest, values: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; m.metrics_len];
        out[m.gdiff_offset..m.gdiff_offset + values.len()].copy_from_slice(values);
        out
    }

    #[test]
    fn grace_period_blocks_freezing() {
        let m = fake_manifest(1);
        let mut mon = GradesMonitor::new(&cfg(1.0, 0.5), &m, 100).unwrap();
        let mut fs = FreezeState::new(m.n_components);
        let metrics = metrics_with_gdiff(&m, &vec![0.0001; m.n_components]);
        assert_eq!(mon.observe(50, &m, &metrics, 1.0, &mut fs), 0); // t <= 50
        assert_eq!(mon.observe(51, &m, &metrics, 1.0, &mut fs), m.n_components);
        assert!(mon.should_terminate(&fs));
    }

    #[test]
    fn only_sub_tau_components_freeze() {
        let m = fake_manifest(1);
        let mut mon = GradesMonitor::new(&cfg(0.5, 0.0), &m, 100).unwrap();
        let mut fs = FreezeState::new(m.n_components);
        let mut vals = vec![1.0f32; m.n_components];
        vals[2] = 0.1;
        vals[5] = 0.4;
        let metrics = metrics_with_gdiff(&m, &vals);
        assert_eq!(mon.observe(1, &m, &metrics, 1.0, &mut fs), 2);
        assert!(fs.is_frozen(2) && fs.is_frozen(5));
        assert!(!mon.should_terminate(&fs));
    }

    #[test]
    fn patience_delays_freeze() {
        let m = fake_manifest(1);
        let mut c = cfg(0.5, 0.0);
        c.patience = 2;
        let mut mon = GradesMonitor::new(&c, &m, 100).unwrap();
        let mut fs = FreezeState::new(m.n_components);
        let metrics = metrics_with_gdiff(&m, &vec![0.1; m.n_components]);
        assert_eq!(mon.observe(1, &m, &metrics, 1.0, &mut fs), 0);
        assert_eq!(mon.observe(2, &m, &metrics, 1.0, &mut fs), 0);
        assert_eq!(mon.observe(3, &m, &metrics, 1.0, &mut fs), m.n_components);
    }

    #[test]
    fn patience_resets_on_rebound() {
        let m = fake_manifest(1);
        let mut c = cfg(0.5, 0.0);
        c.patience = 1;
        let mut mon = GradesMonitor::new(&c, &m, 100).unwrap();
        let mut fs = FreezeState::new(m.n_components);
        let low = metrics_with_gdiff(&m, &vec![0.1; m.n_components]);
        let high = metrics_with_gdiff(&m, &vec![2.0; m.n_components]);
        assert_eq!(mon.observe(1, &m, &low, 1.0, &mut fs), 0);
        assert_eq!(mon.observe(2, &m, &high, 1.0, &mut fs), 0); // reset
        assert_eq!(mon.observe(3, &m, &low, 1.0, &mut fs), 0); // count=1 again
        assert_eq!(mon.observe(4, &m, &low, 1.0, &mut fs), m.n_components);
    }

    #[test]
    fn layer_granularity_waits_for_whole_layer() {
        let m = fake_manifest(2);
        let mut c = cfg(0.5, 0.0);
        c.granularity = "layer".into();
        let mut mon = GradesMonitor::new(&c, &m, 100).unwrap();
        let mut fs = FreezeState::new(m.n_components);
        // layer 0 fully below τ except component 3; layer 1 fully below
        let mut vals = vec![0.1f32; m.n_components];
        vals[3] = 2.0;
        let metrics = metrics_with_gdiff(&m, &vals);
        let newly = mon.observe(1, &m, &metrics, 1.0, &mut fs);
        assert_eq!(newly, 7); // only layer 1 froze
        assert!(!fs.is_frozen(0));
        assert!(fs.is_frozen(7));
    }

    #[test]
    fn candidate_bitmap_resets_between_steps() {
        // Regression for the per-step candidate state: a component that
        // was sub-τ with patience pending must not stay a candidate after
        // its metric rebounds (the bitmap is cleared every observe()).
        let m = fake_manifest(2);
        let mut c = cfg(0.5, 0.0);
        c.granularity = "layer".into();
        c.patience = 0;
        let mut mon = GradesMonitor::new(&c, &m, 100).unwrap();
        let mut fs = FreezeState::new(m.n_components);
        // step 1: layer 0 almost ready (comp 3 high) → nothing freezes
        let mut vals = vec![0.1f32; m.n_components];
        vals[3] = 2.0;
        for v in vals.iter_mut().skip(7) {
            *v = 2.0; // layer 1 all high
        }
        assert_eq!(mon.observe(1, &m, &metrics_with_gdiff(&m, &vals), 1.0, &mut fs), 0);
        // step 2: only comp 3 is low — the layer must still not freeze,
        // because step 1's candidates were discarded.
        let mut vals2 = vec![2.0f32; m.n_components];
        vals2[3] = 0.1;
        assert_eq!(mon.observe(2, &m, &metrics_with_gdiff(&m, &vals2), 1.0, &mut fs), 0);
        assert_eq!(fs.n_frozen(), 0);
    }

    #[test]
    fn disabled_monitor_never_freezes() {
        let m = fake_manifest(1);
        let mut mon = GradesMonitor::disabled(&m);
        let mut fs = FreezeState::new(m.n_components);
        let metrics = metrics_with_gdiff(&m, &vec![0.0; m.n_components]);
        assert_eq!(mon.observe(1_000_000, &m, &metrics, 1.0, &mut fs), 0);
        assert!(!mon.should_terminate(&fs));
    }

    #[test]
    fn unknown_metric_is_a_hard_error() {
        // Regression: `l1diff_rel` (note the missing underscore) used to
        // silently select L1Diff and change the experiment being run.
        assert!(Metric::parse("l1diff_rel").is_err());
        assert!(Metric::parse("").is_err());
        let m = fake_manifest(1);
        let mut c = cfg(0.5, 0.0);
        c.metric = "l1diff_rel".into();
        assert!(GradesMonitor::new(&c, &m, 100).is_err());
    }

    #[test]
    fn tower_tau_overrides_require_vlm_manifest() {
        // Regression: tau_vision used to apply without the is_vlm() guard
        // tau_language had, so a stray key retargeted τ on an LM manifest
        // that happened to carry a vision-labelled component.
        let mut m = fake_manifest(1);
        m.components[0].tower = "vision".into();
        let mut c = cfg(0.5, 0.0);
        c.tau_vision = 9.0;
        c.tau_language = 7.0;
        let mon = GradesMonitor::new(&c, &m, 100).unwrap();
        for i in 0..m.n_components {
            assert_eq!(mon.tau(i), 0.5, "LM manifest must ignore tower overrides");
        }
        m.kind = "vlm".into();
        let mon = GradesMonitor::new(&c, &m, 100).unwrap();
        assert_eq!(mon.tau(0), 9.0);
        assert_eq!(mon.tau(1), 7.0);
    }

    #[test]
    fn l1_abs_metric_selects_gabs() {
        let m = fake_manifest(1);
        let mut c = cfg(0.5, 0.0);
        c.metric = "l1_abs".into();
        let mon = GradesMonitor::new(&c, &m, 10).unwrap();
        let mut metrics = vec![0f32; m.metrics_len];
        metrics[m.gabs_offset] = 7.0;
        assert_eq!(mon.metric_values(&m, &metrics)[0], 7.0);
    }
}
