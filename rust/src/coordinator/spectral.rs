//! Spectral stopping — a per-component Marchenko–Pastur edge test on the
//! weight matrices themselves (arXiv:2510.16074 adapted to GradES's
//! per-matrix granularity).
//!
//! Random-matrix theory says an m×n matrix of pure i.i.d. noise has a
//! singular spectrum whose squared values fill the Marchenko–Pastur bulk
//! `[σ²(1−√γ)², σ²(1+√γ)²]` with aspect ratio `γ = min(m,n)/max(m,n)`.
//! Training pushes information into a handful of *spikes* above the bulk
//! edge `λ₊`; once a component's spectrum stops moving — the spikes have
//! stabilized and the bulk is static — further updates to that matrix are
//! noise-shaping, and it can freeze.
//!
//! Unlike GradES/EB this signal lives in the **weights**, not the
//! gradients, so scans pull the state to the host on their own (coarser)
//! cadence; the freeze decisions feed the same [`FreezeState`], so
//! `StepPlan` elision and backward truncation apply unchanged. Like
//! GradES it needs zero validation passes.
//!
//! The eigensolver is a dependency-free cyclic Jacobi iteration on the
//! Gram matrix of the smaller side — components are at most a few hundred
//! wide in the host configs, and LoRA components reduce to r×r Grams.

use crate::config::SpectralConfig;
use crate::coordinator::freeze::{FreezeReason, FreezeState};
use crate::runtime::manifest::Manifest;

/// Eigenvalues of a symmetric matrix (row-major, n×n) by cyclic Jacobi
/// rotations, ascending. Deterministic: fixed sweep order, fixed cap.
pub fn sym_eigenvalues(a: &[f64], n: usize) -> Vec<f64> {
    debug_assert_eq!(a.len(), n * n);
    let mut a = a.to_vec();
    if n == 0 {
        return Vec::new();
    }
    let frob: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
    let tol = 1e-12 * frob.max(1e-300);
    for _sweep in 0..64 {
        let mut off = 0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += a[p * n + q] * a[p * n + q];
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq.abs() <= tol / (n as f64) {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                // classic Jacobi rotation angle
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for k in 0..n {
                    let akp = a[k * n + p];
                    let akq = a[k * n + q];
                    a[k * n + p] = c * akp - s * akq;
                    a[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p * n + k];
                    let aqk = a[q * n + k];
                    a[p * n + k] = c * apk - s * aqk;
                    a[q * n + k] = s * apk + c * aqk;
                }
            }
        }
    }
    let mut eigs: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
    eigs.sort_by(|x, y| x.partial_cmp(y).unwrap());
    eigs
}

/// Eigenvalues of the (scaled) Gram matrix of a row-major `rows`×`cols`
/// weight, computed on the smaller side: `X·Xᵀ/cols` when `rows ≤ cols`,
/// `Xᵀ·X/rows` otherwise — the sample-covariance normalization the MP
/// law is stated for. Returns `(eigenvalues ascending, aspect ratio γ)`.
pub fn gram_spectrum(w: &[f32], rows: usize, cols: usize) -> (Vec<f64>, f64) {
    debug_assert_eq!(w.len(), rows * cols);
    let (k, l, transpose) = if rows <= cols { (rows, cols, false) } else { (cols, rows, true) };
    let gamma = k as f64 / l as f64;
    let mut g = vec![0f64; k * k];
    for i in 0..k {
        for j in i..k {
            let mut s = 0f64;
            if transpose {
                // columns i,j of X: stride `cols`
                for r in 0..rows {
                    s += w[r * cols + i] as f64 * w[r * cols + j] as f64;
                }
            } else {
                for c in 0..cols {
                    s += w[i * cols + c] as f64 * w[j * cols + c] as f64;
                }
            }
            s /= l as f64;
            g[i * k + j] = s;
            g[j * k + i] = s;
        }
    }
    (sym_eigenvalues(&g, k), gamma)
}

/// Marchenko–Pastur bulk edge `λ₊ = σ̂²(1+√γ)²` with the robust noise
/// estimate `σ̂² = median(λ)` (spikes are a small minority, so the
/// median sits inside the bulk).
pub fn mp_edge(eigs: &[f64], gamma: f64) -> f64 {
    if eigs.is_empty() {
        return 0.0;
    }
    let mid = eigs.len() / 2;
    let median = if eigs.len() % 2 == 1 {
        eigs[mid]
    } else {
        0.5 * (eigs[mid - 1] + eigs[mid])
    };
    median * (1.0 + gamma.sqrt()).powi(2)
}

/// Per-component spectral-drift stopping over weight pulls on a coarse
/// scan cadence.
pub struct SpectralEs {
    /// The `[spectral]` settings this rule runs under.
    pub cfg: SpectralConfig,
    grace_steps: usize,
    /// Steps between spectrum scans (⌈interval_frac·T⌉).
    pub scan_interval: usize,
    below_count: Vec<usize>,
    /// Last scan's concatenated per-tensor spectrum, per component.
    prev: Vec<Option<Vec<f64>>>,
    /// Spike count above the MP edge at the last scan, per component
    /// (reporting only — the learned-signal dimensionality).
    pub spikes: Vec<usize>,
    /// Spectrum scans executed so far.
    pub scans_run: usize,
    /// False for runs under other methods (scan() is then a no-op).
    pub enabled: bool,
}

impl SpectralEs {
    /// Rule over the manifest's components for a `total_steps` run.
    pub fn new(cfg: &SpectralConfig, manifest: &Manifest, total_steps: usize) -> Self {
        let scan_interval =
            ((total_steps as f64) * cfg.interval_frac).ceil().max(1.0) as usize;
        SpectralEs {
            grace_steps: ((total_steps as f64) * cfg.alpha).ceil() as usize,
            scan_interval,
            below_count: vec![0; manifest.n_components],
            prev: vec![None; manifest.n_components],
            spikes: vec![0; manifest.n_components],
            scans_run: 0,
            cfg: cfg.clone(),
            enabled: true,
        }
    }

    /// ⌈alpha·T⌉ — no scans before this step.
    pub fn grace_steps(&self) -> usize {
        self.grace_steps
    }

    /// Is step `t` a spectrum-scan step? (After the grace period, every
    /// `scan_interval` steps — weight pulls are too costly for every-step
    /// cadence.)
    pub fn due(&self, t: usize) -> bool {
        self.enabled && t > self.grace_steps && t % self.scan_interval == 0
    }

    /// Scan the host state at step `t`: per unfrozen component, compute
    /// the concatenated Gram spectrum of its tensors, count MP spikes,
    /// and freeze once the relative spectral drift between consecutive
    /// scans stays below τ for `patience + 1` scans. Returns the number
    /// of components newly frozen.
    pub fn scan(
        &mut self,
        t: usize,
        manifest: &Manifest,
        state: &[f32],
        freeze: &mut FreezeState,
    ) -> usize {
        if !self.enabled {
            return 0;
        }
        self.scans_run += 1;
        let mut newly = 0usize;
        for c in 0..freeze.n() {
            if freeze.is_frozen(c) {
                continue;
            }
            let mut spectrum = Vec::new();
            let mut spikes = 0usize;
            for p in manifest.params.iter().filter(|p| p.component == Some(c)) {
                if p.shape.len() != 2 {
                    continue;
                }
                let (rows, cols) = (p.shape[0], p.shape[1]);
                let w = &state[p.offset..p.offset + rows * cols];
                let (eigs, gamma) = gram_spectrum(w, rows, cols);
                let edge = mp_edge(&eigs, gamma);
                spikes += eigs.iter().filter(|&&e| e > edge).count();
                spectrum.extend(eigs);
            }
            self.spikes[c] = spikes;
            let drift = match &self.prev[c] {
                Some(prev) if prev.len() == spectrum.len() && !prev.is_empty() => {
                    let num: f64 =
                        prev.iter().zip(&spectrum).map(|(a, b)| (a - b).abs()).sum();
                    let den: f64 = prev.iter().map(|a| a.abs()).sum::<f64>().max(1e-30);
                    Some(num / den)
                }
                _ => None,
            };
            self.prev[c] = Some(spectrum);
            match drift {
                Some(d) if d < self.cfg.tau => {
                    self.below_count[c] += 1;
                    if self.below_count[c] > self.cfg.patience {
                        freeze.freeze(c, t, FreezeReason::Spectral, d);
                        newly += 1;
                    }
                }
                Some(_) => self.below_count[c] = 0,
                None => {}
            }
        }
        newly
    }

    /// Stop when every monitored component is frozen (as in Alg. 1).
    pub fn should_terminate(&self, freeze: &FreezeState) -> bool {
        self.enabled && freeze.n() > 0 && freeze.all_frozen()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamInfo;

    #[test]
    fn jacobi_matches_analytic_eigenvalues() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3
        let e = sym_eigenvalues(&[2.0, 1.0, 1.0, 2.0], 2);
        assert!((e[0] - 1.0).abs() < 1e-9 && (e[1] - 3.0).abs() < 1e-9);
        // diagonal passes through
        let e = sym_eigenvalues(&[5.0, 0.0, 0.0, -2.0], 2);
        assert!((e[0] + 2.0).abs() < 1e-12 && (e[1] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn gram_spectrum_handles_both_orientations() {
        // X = [[1,0,0],[0,2,0]] (2×3): XXᵀ/3 = diag(1/3, 4/3)
        let w = [1.0f32, 0.0, 0.0, 0.0, 2.0, 0.0];
        let (e, gamma) = gram_spectrum(&w, 2, 3);
        assert!((gamma - 2.0 / 3.0).abs() < 1e-12);
        assert!((e[0] - 1.0 / 3.0).abs() < 1e-9 && (e[1] - 4.0 / 3.0).abs() < 1e-9);
        // transposed layout must give the same spectrum
        let wt = [1.0f32, 0.0, 0.0, 2.0, 0.0, 0.0];
        let (et, gt) = gram_spectrum(&wt, 3, 2);
        assert!((gt - gamma).abs() < 1e-12);
        for (a, b) in e.iter().zip(&et) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn static_spectrum_freezes_and_drifting_spectrum_does_not() {
        let manifest = spectral_manifest();
        let cfg = SpectralConfig { alpha: 0.0, interval_frac: 0.1, tau: 0.05, patience: 0 };
        let mut sp = SpectralEs::new(&cfg, &manifest, 10);
        let mut fs = FreezeState::new(1);
        let mut state = vec![0f32; 4 + 16];
        for (i, v) in state[4..].iter_mut().enumerate() {
            *v = (i as f32 * 0.37).sin();
        }
        assert_eq!(sp.scan(1, &manifest, &state, &mut fs), 0); // first scan: baseline
        assert_eq!(sp.scan(2, &manifest, &state, &mut fs), 1); // static ⇒ freeze
        assert!(fs.is_frozen(0));
        assert!(sp.should_terminate(&fs));

        // drifting weights never freeze
        let mut sp = SpectralEs::new(&cfg, &manifest, 10);
        let mut fs = FreezeState::new(1);
        sp.scan(1, &manifest, &state, &mut fs);
        for v in state[4..].iter_mut() {
            *v *= 2.0; // spectrum scales ×4 ⇒ drift ≫ τ
        }
        assert_eq!(sp.scan(2, &manifest, &state, &mut fs), 0);
        assert!(!fs.is_frozen(0));
    }

    #[test]
    fn cadence_respects_grace_and_interval() {
        let manifest = spectral_manifest();
        let cfg = SpectralConfig { alpha: 0.5, interval_frac: 0.1, tau: 0.05, patience: 0 };
        let sp = SpectralEs::new(&cfg, &manifest, 100);
        assert!(!sp.due(50)); // grace
        assert!(!sp.due(55)); // off-cadence
        assert!(sp.due(60));
    }

    fn spectral_manifest() -> Manifest {
        let mut m = crate::coordinator::grades::tests::fake_manifest(1);
        // one monitored 4×4 tensor at offset 4 for component 0
        m.n_components = 1;
        m.components.truncate(1);
        m.params = vec![ParamInfo {
            name: "w".into(),
            shape: vec![4, 4],
            offset: 4,
            trainable: true,
            component: Some(0),
        }];
        m
    }
}
