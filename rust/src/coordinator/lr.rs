//! Learning-rate schedules. The paper trains with linear warmup + cosine
//! decay (§6.2 attributes the gradient-norm envelope to this schedule, and
//! App. B's convergence proof assumes it).

/// Warmup + cosine decay to zero.
#[derive(Debug, Clone)]
pub struct CosineSchedule {
    /// Peak LR reached at the end of warmup.
    pub base_lr: f64,
    /// Linear-warmup step count (⌈warmup_frac·T⌉).
    pub warmup_steps: usize,
    /// Budget T the cosine decays over.
    pub total_steps: usize,
}

impl CosineSchedule {
    /// Schedule over `total_steps` with `warmup_frac` linear warmup.
    pub fn new(base_lr: f64, warmup_frac: f64, total_steps: usize) -> Self {
        let warmup_steps = ((total_steps as f64) * warmup_frac).ceil() as usize;
        Self { base_lr, warmup_steps, total_steps }
    }

    /// LR at 1-based step t.
    pub fn lr(&self, t: usize) -> f64 {
        if self.total_steps == 0 {
            return self.base_lr;
        }
        if t <= self.warmup_steps && self.warmup_steps > 0 {
            return self.base_lr * t as f64 / self.warmup_steps as f64;
        }
        let progress = (t - self.warmup_steps) as f64
            / (self.total_steps - self.warmup_steps).max(1) as f64;
        let progress = progress.clamp(0.0, 1.0);
        0.5 * self.base_lr * (1.0 + (std::f64::consts::PI * progress).cos())
    }
}

/// Constant schedule (ablations).
#[derive(Debug, Clone)]
pub struct ConstantSchedule(pub f64);

impl ConstantSchedule {
    /// The constant LR, for any step.
    pub fn lr(&self, _t: usize) -> f64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_then_decay() {
        let s = CosineSchedule::new(1.0, 0.1, 100);
        assert!(s.lr(1) < s.lr(10));
        assert!((s.lr(10) - 1.0).abs() < 1e-9); // warmup peak
        assert!(s.lr(50) < 1.0);
        assert!(s.lr(100) < 1e-3); // decayed to ~0
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = CosineSchedule::new(3e-4, 0.05, 200);
        let mut prev = f64::INFINITY;
        for t in 10..=200 {
            let lr = s.lr(t);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
    }
}
