//! Instance-dependent early stopping — per-sample loss-rank exclusion
//! (arXiv:2502.07547), the natural dual of GradES's per-matrix exclusion.
//!
//! Where GradES stops *parameters* that have converged, instance-ES stops
//! *training examples* the model has mastered: on a check cadence the
//! current batch is scored per row, the lowest-loss fraction become
//! exclusion candidates, and rows that stay candidates for `patience + 1`
//! consecutive checks are excluded — their targets are masked to the
//! ignore index, so they stop contributing to the loss and every
//! gradient, exactly like a frozen matrix stops contributing dW work.
//! Training stops once `stop_frac` of all distinct rows seen are
//! excluded.
//!
//! Rows are identified by a hash of their token content, so sources that
//! recycle batches (`FixedCycle`, epoch wrap-around) accumulate per-row
//! statistics across epochs without any side channel through the data
//! pipeline. [`MaskingSource`] packages the same exclusion set as a
//! [`BatchSource`] combinator for pipelines that want masking applied on
//! the producer side (e.g. under a `Prefetcher`) instead of in the
//! trainer loop.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use crate::config::IesConfig;
use crate::runtime::pipeline::BatchSource;
use crate::runtime::session::Batch;

/// Stable identity of one training row: FNV-1a over its token ids.
pub fn row_key(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        h ^= t as u32 as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The shared set of excluded row keys (the trainer's rule and a
/// [`MaskingSource`] both hold a handle).
pub type Exclusions = Arc<Mutex<HashSet<u64>>>;

/// Mask every excluded row of `batch` in place (targets → the loss's
/// ignore index −1). Returns how many rows were masked.
pub fn mask_batch(batch: &mut Batch, seq_len: usize, excluded: &HashSet<u64>) -> usize {
    let rows = batch.tokens.len() / seq_len.max(1);
    let mut masked = 0usize;
    for r in 0..rows {
        let tok = &batch.tokens[r * seq_len..(r + 1) * seq_len];
        if excluded.contains(&row_key(tok)) {
            batch.targets[r * seq_len..(r + 1) * seq_len].fill(-1);
            masked += 1;
        }
    }
    masked
}

/// Per-sample loss-rank exclusion state.
pub struct InstanceEs {
    /// The `[ies]` settings this rule runs under.
    pub cfg: IesConfig,
    grace_steps: usize,
    /// Steps between exclusion checks (⌈check_interval_frac·T⌉).
    pub check_interval: usize,
    excluded: Exclusions,
    candidate_streak: HashMap<u64, usize>,
    seen: HashSet<u64>,
    /// Exclusion checks run so far (each scores one batch per row).
    pub checks_run: usize,
    /// False for runs under other methods (everything is then a no-op).
    pub enabled: bool,
}

impl InstanceEs {
    /// Rule over a `total_steps` budget.
    pub fn new(cfg: &IesConfig, total_steps: usize) -> Self {
        let check_interval =
            ((total_steps as f64) * cfg.check_interval_frac).ceil().max(1.0) as usize;
        InstanceEs {
            grace_steps: ((total_steps as f64) * cfg.alpha).ceil() as usize,
            check_interval,
            excluded: Arc::new(Mutex::new(HashSet::new())),
            candidate_streak: HashMap::new(),
            seen: HashSet::new(),
            checks_run: 0,
            cfg: cfg.clone(),
            enabled: true,
        }
    }

    /// ⌈alpha·T⌉ — no exclusions before this step.
    pub fn grace_steps(&self) -> usize {
        self.grace_steps
    }

    /// Is step `t` an exclusion-check step?
    pub fn due(&self, t: usize) -> bool {
        self.enabled && t > self.grace_steps && t % self.check_interval == 0
    }

    /// Record the distinct rows of a batch (the `stop_frac` denominator).
    pub fn note_rows(&mut self, batch: &Batch, seq_len: usize) {
        if !self.enabled {
            return;
        }
        let rows = batch.tokens.len() / seq_len.max(1);
        for r in 0..rows {
            self.seen.insert(row_key(&batch.tokens[r * seq_len..(r + 1) * seq_len]));
        }
    }

    /// Score one batch: `rows[r] = (loss_sum, token_count)` per row (from
    /// `Session::eval_rows`). The lowest-mean-loss `drop_frac` of still-
    /// active rows become candidates; rows candidate for `patience + 1`
    /// consecutive checks are excluded. Returns newly excluded rows.
    pub fn observe(&mut self, rows: &[(f64, f64)], batch: &Batch, seq_len: usize) -> usize {
        if !self.enabled {
            return 0;
        }
        self.checks_run += 1;
        let mut excluded = self.excluded.lock().unwrap();
        // (mean loss, key) over active rows, deterministically ordered
        let mut active: Vec<(f64, u64)> = Vec::with_capacity(rows.len());
        for (r, &(loss, count)) in rows.iter().enumerate() {
            let key = row_key(&batch.tokens[r * seq_len..(r + 1) * seq_len]);
            if !excluded.contains(&key) && count > 0.0 {
                active.push((loss / count, key));
            }
        }
        active.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n_cand = ((active.len() as f64) * self.cfg.drop_frac).floor() as usize;
        let candidates: HashSet<u64> = active[..n_cand].iter().map(|&(_, k)| k).collect();
        let mut newly = 0usize;
        for &(_, key) in &active {
            if candidates.contains(&key) {
                let streak = self.candidate_streak.entry(key).or_insert(0);
                *streak += 1;
                if *streak > self.cfg.patience {
                    excluded.insert(key);
                    newly += 1;
                }
            } else {
                self.candidate_streak.remove(&key);
            }
        }
        newly
    }

    /// Mask this batch's excluded rows in place; returns rows masked.
    pub fn mask(&self, batch: &mut Batch, seq_len: usize) -> usize {
        if !self.enabled {
            return 0;
        }
        mask_batch(batch, seq_len, &self.excluded.lock().unwrap())
    }

    /// Rows excluded so far.
    pub fn n_excluded(&self) -> usize {
        self.excluded.lock().unwrap().len()
    }

    /// Excluded fraction of all distinct rows seen (0 before any data).
    pub fn excluded_fraction(&self) -> f64 {
        if self.seen.is_empty() {
            0.0
        } else {
            self.n_excluded() as f64 / self.seen.len() as f64
        }
    }

    /// Stop once `stop_frac` of the distinct rows seen are excluded.
    pub fn should_stop(&self) -> bool {
        self.enabled && !self.seen.is_empty() && self.excluded_fraction() >= self.cfg.stop_frac
    }

    /// A handle to the exclusion set, for composing a [`MaskingSource`]
    /// over the same run.
    pub fn exclusions(&self) -> Exclusions {
        Arc::clone(&self.excluded)
    }
}

/// [`BatchSource`] combinator: passes the inner source through, masking
/// every excluded row's targets. Lets instance-ES compose with any
/// pipeline topology — the masking then happens on the producer side
/// (e.g. inside a `Prefetcher` worker) instead of the trainer loop.
pub struct MaskingSource<S> {
    inner: S,
    exclusions: Exclusions,
    seq_len: usize,
}

impl<S: BatchSource> MaskingSource<S> {
    /// Wrap `inner`, masking rows whose keys appear in `exclusions`.
    pub fn new(inner: S, exclusions: Exclusions, seq_len: usize) -> Self {
        MaskingSource { inner, exclusions, seq_len }
    }
}

impl<S: BatchSource> BatchSource for MaskingSource<S> {
    fn next_batch(&mut self) -> Batch {
        let mut b = self.inner.next_batch();
        mask_batch(&mut b, self.seq_len, &self.exclusions.lock().unwrap());
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T: usize = 4;

    fn cfg(drop_frac: f64, patience: usize, stop_frac: f64) -> IesConfig {
        IesConfig { alpha: 0.0, check_interval_frac: 0.1, drop_frac, patience, stop_frac }
    }

    fn batch(rows: &[[i32; T]]) -> Batch {
        Batch {
            tokens: rows.concat(),
            targets: rows.concat(),
            patches: Vec::new(),
        }
    }

    #[test]
    fn lowest_loss_rows_are_excluded_after_patience() {
        let mut ies = InstanceEs::new(&cfg(0.25, 1, 1.0), 100);
        let b = batch(&[[1, 2, 3, 4], [5, 6, 7, 8], [9, 10, 11, 12], [13, 14, 15, 16]]);
        ies.note_rows(&b, T);
        // row 0 is easiest (lowest mean loss): candidate both checks
        let rows = vec![(0.4, 4.0), (4.0, 4.0), (4.4, 4.0), (4.8, 4.0)];
        assert_eq!(ies.observe(&rows, &b, T), 0); // streak 1
        assert_eq!(ies.observe(&rows, &b, T), 1); // streak 2 > patience
        assert_eq!(ies.n_excluded(), 1);
        let mut masked = b.clone();
        assert_eq!(ies.mask(&mut masked, T), 1);
        assert!(masked.targets[..T].iter().all(|&t| t == -1));
        assert_eq!(&masked.targets[T..], &b.targets[T..]);
        assert_eq!(masked.tokens, b.tokens, "tokens must stay intact");
    }

    #[test]
    fn rank_shuffle_resets_the_streak() {
        let mut ies = InstanceEs::new(&cfg(0.25, 1, 1.0), 100);
        let b = batch(&[[1, 2, 3, 4], [5, 6, 7, 8], [9, 10, 11, 12], [13, 14, 15, 16]]);
        ies.note_rows(&b, T);
        let r0_low = vec![(0.4, 4.0), (4.0, 4.0), (4.4, 4.0), (4.8, 4.0)];
        let r1_low = vec![(4.0, 4.0), (0.4, 4.0), (4.4, 4.0), (4.8, 4.0)];
        assert_eq!(ies.observe(&r0_low, &b, T), 0);
        assert_eq!(ies.observe(&r1_low, &b, T), 0); // row 0 streak reset
        assert_eq!(ies.observe(&r0_low, &b, T), 0); // row 1 reset, row 0 streak 1
        assert_eq!(ies.n_excluded(), 0);
    }

    #[test]
    fn stop_fires_at_the_excluded_fraction() {
        let mut ies = InstanceEs::new(&cfg(0.5, 0, 0.5), 100);
        let b = batch(&[[1, 2, 3, 4], [5, 6, 7, 8], [9, 10, 11, 12], [13, 14, 15, 16]]);
        ies.note_rows(&b, T);
        let rows = vec![(0.4, 4.0), (0.8, 4.0), (4.4, 4.0), (4.8, 4.0)];
        assert!(!ies.should_stop());
        assert_eq!(ies.observe(&rows, &b, T), 2); // patience 0: immediate
        assert!((ies.excluded_fraction() - 0.5).abs() < 1e-12);
        assert!(ies.should_stop());
    }

    #[test]
    fn masking_source_composes_with_any_inner_source() {
        use crate::runtime::pipeline::FnSource;
        let mut ies = InstanceEs::new(&cfg(0.5, 0, 1.0), 100);
        let b = batch(&[[1, 2, 3, 4], [5, 6, 7, 8]]);
        ies.note_rows(&b, T);
        ies.observe(&[(0.1, 4.0), (9.0, 4.0)], &b, T); // excludes row 0
        let inner = b.clone();
        let mut src = MaskingSource::new(
            FnSource(move || inner.clone()),
            ies.exclusions(),
            T,
        );
        let out = src.next_batch();
        assert!(out.targets[..T].iter().all(|&t| t == -1));
        assert_eq!(&out.targets[T..], &b.targets[T..]);
    }
}
