//! Freeze-mask state: which components are frozen, when, and why.
//!
//! This is the coordinator's ground truth for Alg. 1's frozen set F. The
//! mask is serialized into the `ctrl` vector every step (1.0 = active,
//! 0.0 = frozen) and drives FLOPs accounting + the variant scheduler.

use crate::runtime::manifest::Manifest;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
/// Why a component's mask bit flipped.
pub enum FreezeReason {
    /// G_W(t) < τ after the grace period (GradES).
    Converged,
    /// Frozen as part of a layer-granularity decision (AutoFreeze ablation).
    LayerRule,
    /// Manually frozen or unfrozen (tests/experiments).
    Manual,
    /// Reactivated by the §8 dynamic-unfreezing rule: the monitored
    /// metric rebounded above `unfreeze_factor · τ`. (Unfreeze events
    /// used to be mislabeled `Converged` — the freeze-side reason.)
    Reactivated,
    /// The EB criterion's evidence bound crossed its margin (the
    /// gradient signal is indistinguishable from sampling noise).
    Evidence,
    /// The component's weight spectrum stopped drifting relative to its
    /// Marchenko–Pastur bulk (spectral stopping).
    Spectral,
}

impl FreezeReason {
    /// Short lowercase id for event logs.
    pub fn label(&self) -> &'static str {
        match self {
            FreezeReason::Converged => "converged",
            FreezeReason::LayerRule => "layer-rule",
            FreezeReason::Manual => "manual",
            FreezeReason::Reactivated => "reactivated",
            FreezeReason::Evidence => "evidence",
            FreezeReason::Spectral => "spectral",
        }
    }
}

#[derive(Debug, Clone)]
/// One mask transition, kept for event logs and tests.
pub struct FreezeEvent {
    /// Step the transition happened at.
    pub step: usize,
    /// Component index (manifest order).
    pub component: usize,
    /// New state: true = froze, false = unfroze.
    pub frozen: bool, // false = unfreeze event
    /// What triggered the transition.
    pub reason: FreezeReason,
    /// The monitored metric value at decision time.
    pub metric_value: f64,
}

#[derive(Debug, Clone)]
/// The frozen set F plus its ctrl-vector mask form.
pub struct FreezeState {
    frozen: Vec<bool>,
    frozen_since: Vec<Option<usize>>,
    /// Every freeze/unfreeze transition, in step order.
    pub events: Vec<FreezeEvent>,
    mask: Vec<f32>,
}

impl FreezeState {
    /// All-active state over `n_components` components.
    pub fn new(n_components: usize) -> Self {
        Self {
            frozen: vec![false; n_components],
            frozen_since: vec![None; n_components],
            events: Vec::new(),
            mask: vec![1.0; n_components],
        }
    }

    /// Number of monitored components.
    pub fn n(&self) -> usize {
        self.frozen.len()
    }

    /// Is component `c` currently frozen?
    pub fn is_frozen(&self, c: usize) -> bool {
        self.frozen[c]
    }

    /// Currently frozen component count.
    pub fn n_frozen(&self) -> usize {
        self.frozen.iter().filter(|&&f| f).count()
    }

    /// True when every component is frozen (Alg. 1 termination).
    pub fn all_frozen(&self) -> bool {
        self.n_frozen() == self.n()
    }

    /// Frozen share in [0, 1] (the Figure 3 series).
    pub fn frozen_fraction(&self) -> f64 {
        if self.n() == 0 {
            return 0.0;
        }
        self.n_frozen() as f64 / self.n() as f64
    }

    /// Freeze `c` (idempotent; records an event on the first call).
    pub fn freeze(&mut self, c: usize, step: usize, reason: FreezeReason, metric: f64) {
        if !self.frozen[c] {
            self.frozen[c] = true;
            self.frozen_since[c] = Some(step);
            self.mask[c] = 0.0;
            self.events.push(FreezeEvent {
                step,
                component: c,
                frozen: true,
                reason,
                metric_value: metric,
            });
        }
    }

    /// Reactivate `c` (idempotent; §8 dynamic-unfreezing extension).
    /// `reason` is recorded honestly in the event log —
    /// [`FreezeReason::Reactivated`] from the monitor's rebound rule,
    /// [`FreezeReason::Manual`] from tests and experiments.
    pub fn unfreeze(&mut self, c: usize, step: usize, reason: FreezeReason, metric: f64) {
        if self.frozen[c] {
            self.frozen[c] = false;
            self.frozen_since[c] = None;
            self.mask[c] = 1.0;
            self.events.push(FreezeEvent {
                step,
                component: c,
                frozen: false,
                reason,
                metric_value: metric,
            });
        }
    }

    /// The mask slice to copy into ctrl.
    pub fn mask(&self) -> &[f32] {
        &self.mask
    }

    /// True when every component satisfying `pred` is frozen (and at least
    /// one exists) — e.g. "all attention frozen" for the variant scheduler.
    pub fn all_frozen_where<F: Fn(usize) -> bool>(&self, pred: F) -> bool {
        let mut any = false;
        for c in 0..self.n() {
            if pred(c) {
                any = true;
                if !self.frozen[c] {
                    return false;
                }
            }
        }
        any
    }

    /// Freeze-time per component (None = never froze).
    pub fn frozen_since(&self, c: usize) -> Option<usize> {
        self.frozen_since[c]
    }
}

/// Group a mask decision at layer granularity (AutoFreeze-style baseline):
/// a candidate component may freeze only when *all* components of its layer
/// and tower are sub-threshold. Returns per-layer candidate lists.
pub fn layer_groups(manifest: &Manifest) -> Vec<Vec<usize>> {
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<(String, usize), Vec<usize>> = BTreeMap::new();
    for c in &manifest.components {
        groups.entry((c.tower.clone(), c.layer)).or_default().push(c.idx);
    }
    groups.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn freeze_unfreeze_roundtrip() {
        let mut f = FreezeState::new(4);
        assert_eq!(f.mask(), &[1.0; 4]);
        f.freeze(2, 10, FreezeReason::Converged, 0.01);
        assert!(f.is_frozen(2));
        assert_eq!(f.mask()[2], 0.0);
        assert_eq!(f.n_frozen(), 1);
        assert_eq!(f.frozen_since(2), Some(10));
        f.unfreeze(2, 12, FreezeReason::Reactivated, 0.2);
        assert!(!f.is_frozen(2));
        assert_eq!(f.mask()[2], 1.0);
        assert_eq!(f.events.len(), 2);
        assert_eq!(f.events[1].reason, FreezeReason::Reactivated);
        assert_eq!(f.events[1].reason.label(), "reactivated");
    }

    #[test]
    fn double_freeze_is_idempotent() {
        let mut f = FreezeState::new(2);
        f.freeze(0, 1, FreezeReason::Converged, 0.0);
        f.freeze(0, 2, FreezeReason::Converged, 0.0);
        assert_eq!(f.events.len(), 1);
    }

    #[test]
    fn all_frozen_where() {
        let mut f = FreezeState::new(4);
        f.freeze(0, 1, FreezeReason::Converged, 0.0);
        f.freeze(1, 1, FreezeReason::Converged, 0.0);
        assert!(f.all_frozen_where(|c| c < 2));
        assert!(!f.all_frozen_where(|c| c < 3));
        assert!(!f.all_frozen_where(|_| false)); // vacuous = false
    }
}
