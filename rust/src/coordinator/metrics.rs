//! Per-step training metrics log → CSV series for the paper's figures.

use std::path::Path;

use anyhow::Result;

use crate::runtime::manifest::Manifest;
use crate::runtime::pipeline::StepTimings;
use crate::util::csv::CsvWriter;

#[derive(Debug, Clone)]
/// One logged step's metrics.
pub struct StepRecord {
    /// 1-based optimizer step.
    pub step: usize,
    /// Mean train loss of the step's batch.
    pub loss: f64,
    /// Learning rate at the step.
    pub lr: f64,
    /// Global gradient norm from the probe.
    pub global_gnorm: f64,
    /// Share of components frozen after the step.
    pub frozen_fraction: f64,
    /// Eq. 1 per-component gradient-change norms (Fig. 1 series).
    pub gdiff: Vec<f32>,
    /// ‖∇W‖₁ per component (Fig. 4 series).
    pub gabs: Vec<f32>,
}

#[derive(Debug, Clone, Default)]
/// The full per-step + per-check log of one run.
pub struct MetricsLog {
    /// Probed steps, in order.
    pub records: Vec<StepRecord>,
    /// (check step, val loss) — for async checks the step is the
    /// *issue* step, whose parameters the loss describes.
    pub val_points: Vec<(usize, f64)>,
    /// Cumulative runtime breakdown for the run (upload/exec/probe/eval),
    /// filled in by the trainer when the run completes.
    pub timings: StepTimings,
}

impl MetricsLog {
    /// Log one probed step from the raw metrics prefix.
    pub fn record(
        &mut self,
        step: usize,
        lr: f64,
        frozen_fraction: f64,
        manifest: &Manifest,
        metrics: &[f32],
    ) {
        let count = metrics[1].max(1.0) as f64;
        self.records.push(StepRecord {
            step,
            loss: metrics[0] as f64 / count,
            lr,
            global_gnorm: metrics[2] as f64,
            frozen_fraction,
            gdiff: metrics[manifest.gdiff_offset..manifest.gdiff_offset + manifest.n_components]
                .to_vec(),
            gabs: metrics[manifest.gabs_offset..manifest.gabs_offset + manifest.n_components]
                .to_vec(),
        });
    }

    /// Log one validation result against its check step.
    pub fn record_val(&mut self, step: usize, val_loss: f64) {
        self.val_points.push((step, val_loss));
    }

    /// Loss of the last probed step (NaN when none).
    pub fn final_train_loss(&self) -> f64 {
        self.records.last().map(|r| r.loss).unwrap_or(f64::NAN)
    }

    /// Loss curve CSV: step,loss,lr,frozen_fraction,gnorm.
    pub fn write_loss_csv(&self, path: &Path) -> Result<()> {
        let mut w = CsvWriter::create(path, &["step", "loss", "lr", "frozen_fraction", "gnorm"])?;
        for r in &self.records {
            w.row(&[r.step as f64, r.loss, r.lr, r.frozen_fraction, r.global_gnorm])?;
        }
        w.flush()
    }

    /// Fig. 1 CSV: per-component Eq. 1 series for one layer.
    pub fn write_component_csv(
        &self,
        path: &Path,
        manifest: &Manifest,
        layer: usize,
        tower: &str,
    ) -> Result<()> {
        let comps: Vec<_> = manifest
            .components
            .iter()
            .filter(|c| c.layer == layer && c.tower == tower)
            .collect();
        let mut header = vec!["step".to_string()];
        header.extend(comps.iter().map(|c| format!("{}_{}", c.kind, c.idx)));
        let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
        let mut w = CsvWriter::create(path, &header_refs)?;
        for r in &self.records {
            let mut row = vec![r.step as f64];
            row.extend(comps.iter().map(|c| r.gdiff[c.idx] as f64));
            w.row(&row)?;
        }
        w.flush()
    }

    /// Fig. 4 CSV: group-mean |∇W| series (attention vs mlp, or towers).
    pub fn write_group_mean_csv(
        &self,
        path: &Path,
        _manifest: &Manifest,
        groups: &[(&str, Vec<usize>)],
    ) -> Result<()> {
        let mut header = vec!["step"];
        header.extend(groups.iter().map(|(n, _)| *n));
        let mut w = CsvWriter::create(path, &header)?;
        for r in &self.records {
            let mut row = vec![r.step as f64];
            for (_, idxs) in groups {
                let mean = if idxs.is_empty() {
                    0.0
                } else {
                    idxs.iter().map(|&i| r.gabs[i] as f64).sum::<f64>() / idxs.len() as f64
                };
                row.push(mean);
            }
            w.row(&row)?;
        }
        w.flush()
    }

    /// Runtime-breakdown JSON (perf trajectory): upload bytes/secs, exec,
    /// probe, eval — what the pipelined runtime is supposed to shrink.
    pub fn write_timings_json(&self, path: &Path) -> Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, crate::util::json::write(&self.timings.to_json()))?;
        Ok(())
    }

    /// Fig. 3 CSV: cumulative frozen fraction.
    pub fn write_frozen_csv(&self, path: &Path) -> Result<()> {
        let mut w = CsvWriter::create(path, &["step", "frozen_fraction"])?;
        for r in &self.records {
            w.row(&[r.step as f64, r.frozen_fraction])?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::grades::tests::fake_manifest;

    #[test]
    fn records_and_serializes() {
        let m = fake_manifest(1);
        let mut log = MetricsLog::default();
        let mut metrics = vec![0f32; m.metrics_len];
        metrics[0] = 20.0;
        metrics[1] = 10.0;
        metrics[m.gdiff_offset] = 3.0;
        log.record(1, 1e-3, 0.0, &m, &metrics);
        assert!((log.final_train_loss() - 2.0).abs() < 1e-9);
        let dir = std::env::temp_dir().join("grades_metrics_test");
        log.write_loss_csv(&dir.join("loss.csv")).unwrap();
        log.write_component_csv(&dir.join("comp.csv"), &m, 0, "language").unwrap();
        let text = std::fs::read_to_string(dir.join("comp.csv")).unwrap();
        assert!(text.starts_with("step,q_0,k_1,v_2,o_3,gate_4,up_5,down_6"));
        assert!(text.contains("1,3"));
    }
}
