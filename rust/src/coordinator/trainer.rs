//! The training event loop — Algorithm 1 end to end.
//!
//! One `Trainer::run` drives: batch sampling, ctrl assembly (LR schedule +
//! freeze mask), the AOT train step, the metrics probe, the stopping
//! rule, the step planner, FLOPs accounting and per-step logging. Every
//! method in the stopping zoo is this one loop with a different
//! [`StoppingMethod`] (the fp/lora split lives in the artifact): the
//! GradES monitor and the EB criterion read the probed gradient
//! statistics, spectral stopping pulls the weights on its own scan
//! cadence, classic ES runs validation passes, and instance-ES scores
//! the incoming batch per row and masks mastered examples out.
//!
//! Compute elision is plan-driven: each step the [`StepPlanner`] derives
//! a [`StepPlan`](crate::coordinator::scheduler::StepPlan) (omit every
//! frozen component's dW work) from the same
//! freeze state the ctrl mask is built from, the session lowers it to
//! what the engine can honor (exactly on the host engine; the nearest
//! sound pre-compiled variant on XLA) and the FLOPs counter prices both
//! the ideal plan (theoretical) and the lowered one (realized).
//!
//! The loop runs on the pipelined runtime (`runtime::pipeline`): batches
//! come from any [`BatchSource`] (wrap it in a `Prefetcher` to overlap
//! host-side packing with device execution), the next step's buffers are
//! staged while the current step runs (`PipelineOptions::upload_ahead`),
//! and the fixed validation set is uploaded once into a
//! [`DeviceBatchCache`] instead of per check. None of this changes the
//! trajectory: the batch consumed at step `t`, the ctrl vector, and every
//! executable invocation are identical with the pipeline on or off.
//!
//! A run is single-threaded with respect to the device: the session, its
//! bundle and every buffer stay on the calling thread (only host-side
//! batch production moves to the prefetch worker). When the experiment
//! scheduler runs jobs on a worker pool, the *whole* call into this
//! module happens while that worker holds the shared client's device
//! token — see `runtime::session`'s thread-safety contract. Warm starts
//! arrive as `Arc<BaseCheckpoint>` (plain host data), which is what lets
//! one pretrain job hand its checkpoint to concurrent dependents.

use anyhow::Result;

use crate::config::RepoConfig;
use crate::coordinator::classic_es::ClassicEs;
use crate::coordinator::eb::EbCriterion;
use crate::coordinator::flops::FlopsCounter;
use crate::coordinator::freeze::FreezeState;
use crate::coordinator::grades::GradesMonitor;
use crate::coordinator::instance::InstanceEs;
use crate::coordinator::spectral::SpectralEs;
use crate::coordinator::lr::CosineSchedule;
use crate::coordinator::metrics::MetricsLog;
use crate::coordinator::scheduler::{PlanStats, StepPlanner};
use crate::runtime::async_eval::{AsyncEvalOptions, AsyncEvalStats, AsyncValidator, EvalSnapshot};
use crate::runtime::backend::Backend;
use crate::runtime::pipeline::{
    BatchSource, DeviceBatchCache, FnSource, PipelineOptions, StepTimings,
};
use crate::runtime::session::{Batch, Session, UploadedBatch};
use crate::util::timer::Timer;

/// Which stopping rule a run trains under — the paper's three plus the
/// related-work zoo (evidence-based, spectral, instance-dependent).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoppingMethod {
    /// Train all T steps (the paper's "Full Parameter"/"LoRA" baselines).
    None,
    /// Validation-loss early stopping (+ES).
    ClassicEs,
    /// Gradient-based component early stopping (+GradES).
    GradEs,
    /// Evidence-based stopping from local gradient statistics
    /// (Mahsereci & Lassner; zero validation passes, like GradES).
    EbCriterion,
    /// Marchenko–Pastur spectral stopping on the weight matrices.
    SpectralEs,
    /// Instance-dependent ES: per-sample loss-rank exclusion.
    InstanceEs,
}

/// Every method, in the zoo's canonical report order.
pub const ALL_METHODS: [StoppingMethod; 6] = [
    StoppingMethod::None,
    StoppingMethod::ClassicEs,
    StoppingMethod::GradEs,
    StoppingMethod::EbCriterion,
    StoppingMethod::SpectralEs,
    StoppingMethod::InstanceEs,
];

impl StoppingMethod {
    /// The short id used in job ids, file names and the run manifest.
    pub fn label(&self) -> &'static str {
        match self {
            StoppingMethod::None => "base",
            StoppingMethod::ClassicEs => "es",
            StoppingMethod::GradEs => "grades",
            StoppingMethod::EbCriterion => "eb",
            StoppingMethod::SpectralEs => "spectral",
            StoppingMethod::InstanceEs => "ies",
        }
    }

    /// Inverse of [`StoppingMethod::label`] (also accepts "none").
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "base" | "none" => Some(Self::None),
            "es" => Some(Self::ClassicEs),
            "grades" => Some(Self::GradEs),
            "eb" => Some(Self::EbCriterion),
            "spectral" => Some(Self::SpectralEs),
            "ies" => Some(Self::InstanceEs),
            _ => None,
        }
    }
}

/// Why a training run ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopCause {
    /// The full step budget ran out (no early stop fired).
    BudgetExhausted,
    /// GradES froze every monitored component (Alg. 1 termination).
    AllComponentsFrozen,
    /// Classic ES: validation loss stalled for `patience` checks.
    ValidationPatience,
    /// Instance-ES: enough training rows were excluded as mastered.
    SamplesExhausted,
}

/// Everything one training run reports back to its driver.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    /// Optimizer steps actually executed (≤ the budget).
    pub steps_run: usize,
    /// Why the run ended.
    pub stop_cause: StopCause,
    /// Total wall-clock seconds of the run.
    pub wall_secs: f64,
    /// Seconds spent in validation passes (classic-ES overhead).
    pub validation_secs: f64,
    /// Seconds spent in monitor probes + decisions (GradES overhead).
    pub monitor_secs: f64,
    /// FLOPs accounting (spent vs dense-equivalent vs validation).
    pub flops: FlopsCounter,
    /// Per-step metrics log (loss/lr/gdiff/gabs series + val points).
    pub log: MetricsLog,
    /// Final per-component freeze state.
    pub freeze: FreezeState,
    /// Mean validation loss of the final parameters (NaN when skipped).
    pub final_val_loss: f64,
    /// First step whose plan omitted every attention component — where
    /// the XLA lowering reaches the attn-frozen graph (the old variant
    /// scheduler's swap step, preserved for reports and run manifests).
    pub variant_swap_step: Option<usize>,
    /// Step-planner counters (elided steps, downgrades, first elision).
    pub plan: PlanStats,
    /// Runtime breakdown: upload bytes/secs, exec, probe, eval.
    pub timings: StepTimings,
    /// Asynchronous-validation counters (passes issued / completed /
    /// force-drained / abandoned — see `runtime::async_eval`).
    pub async_eval: AsyncEvalStats,
}

/// Per-run knobs the drivers thread into [`run`] / [`run_source`].
pub struct TrainerOptions {
    /// Stopping rule this run trains under.
    pub method: StoppingMethod,
    /// Step budget T.
    pub total_steps: usize,
    /// Init RNG seed (the artifact's init executable consumes it).
    pub seed: i32,
    /// Probe cadence before the grace period (monitoring needs every-step
    /// probes only once freezing decisions are live).
    pub probe_every: usize,
    /// Derive freeze-aware step plans (per-matrix dW elision on the host
    /// engine, variant lowering on XLA). Off ⇒ every step plans
    /// all-active, reproducing the dense path bitwise.
    pub elide_frozen: bool,
    /// Grant plans the backward-sweep truncation capability: once a
    /// *prefix* of layers is fully frozen the host engine stops the
    /// sweep below it, holding those layers' norm scales and the
    /// embeddings (AutoFreeze-style whole-layer rule). Trajectory-
    /// changing once it engages, so off by default; XLA ignores it.
    pub truncate_frozen_prefix: bool,
    /// Also run a final validation pass at the end (for reporting).
    pub final_validation: bool,
    /// Pretrained base parameters applied after init (fine-tuning setting).
    pub warm_start: Option<std::sync::Arc<crate::coordinator::warmstart::BaseCheckpoint>>,
    /// Pipelined-runtime knobs (upload-ahead, prefetch depth used by
    /// callers that wrap their source in a `Prefetcher`).
    pub pipeline: PipelineOptions,
    /// Asynchronous chunked-validation knobs (`runtime::async_eval`).
    /// The default is [`AsyncEvalOptions::synchronous`], which drains
    /// every classic-ES check at its issue step — trajectories bitwise
    /// identical to the pre-async trainer.
    pub async_eval: AsyncEvalOptions,
}

impl TrainerOptions {
    /// The standard options for one (config, stopping-method) run.
    pub fn from_config(cfg: &RepoConfig, method: StoppingMethod) -> Self {
        TrainerOptions {
            method,
            total_steps: cfg.run.total_steps,
            seed: cfg.run.seed as i32,
            probe_every: 1,
            elide_frozen: matches!(
                method,
                StoppingMethod::GradEs
                    | StoppingMethod::EbCriterion
                    | StoppingMethod::SpectralEs
            ),
            truncate_frozen_prefix: false,
            final_validation: true,
            warm_start: None,
            pipeline: PipelineOptions::default(),
            async_eval: AsyncEvalOptions::default(),
        }
    }
}

/// The per-component freeze rule driving a run, dispatched per method.
/// All three share the freeze/plan machinery — they differ only in the
/// signal that decides a component has converged.
enum Monitor {
    /// Eq. 1 Gdiff threshold test (also the disabled stand-in).
    Grades(GradesMonitor),
    /// Evidence-based test over the same probed statistics.
    Eb(EbCriterion),
    /// Marchenko–Pastur test over weight spectra on a scan cadence.
    Spectral(SpectralEs),
}

impl Monitor {
    fn grace_steps(&self) -> usize {
        match self {
            Monitor::Grades(g) => g.grace_steps(),
            Monitor::Eb(e) => e.grace_steps(),
            Monitor::Spectral(s) => s.grace_steps(),
        }
    }

    /// Feed one probed metrics prefix. Spectral stopping ignores probes —
    /// its signal comes from `SpectralEs::scan` on its own cadence.
    fn observe(
        &mut self,
        t: usize,
        m: &crate::runtime::manifest::Manifest,
        metrics: &[f32],
        lr_scale: f64,
        freeze: &mut FreezeState,
    ) -> usize {
        match self {
            Monitor::Grades(g) => g.observe(t, m, metrics, lr_scale, freeze),
            Monitor::Eb(e) => e.observe(t, m, metrics, freeze),
            Monitor::Spectral(_) => 0,
        }
    }

    fn should_terminate(&self, freeze: &FreezeState) -> bool {
        match self {
            Monitor::Grades(g) => g.should_terminate(freeze),
            Monitor::Eb(e) => e.should_terminate(freeze),
            Monitor::Spectral(s) => s.should_terminate(freeze),
        }
    }
}

/// Run one training job. `next_batch` yields training batches;
/// `val_batches` is the fixed validation set.
pub fn run<F: FnMut() -> Batch>(
    backend: &dyn Backend,
    cfg: &RepoConfig,
    opts: &TrainerOptions,
    next_batch: F,
    val_batches: &[Batch],
) -> Result<TrainOutcome> {
    run_and_keep(backend, cfg, opts, next_batch, val_batches).map(|t| t.outcome)
}

/// Run and leave the trained session alive for downstream evaluation.
pub struct TrainedModel<'b> {
    /// The live session holding the final device state.
    pub session: Session<'b>,
    /// The run's report.
    pub outcome: TrainOutcome,
}

/// [`run`], returning the live session alongside the outcome.
pub fn run_and_keep<'b, F: FnMut() -> Batch>(
    backend: &'b dyn Backend,
    cfg: &RepoConfig,
    opts: &TrainerOptions,
    next_batch: F,
    val_batches: &[Batch],
) -> Result<TrainedModel<'b>> {
    run_source_and_keep(backend, cfg, opts, &mut FnSource(next_batch), val_batches)
}

/// [`run`] over any [`BatchSource`] (e.g. a `Prefetcher`).
pub fn run_source(
    backend: &dyn Backend,
    cfg: &RepoConfig,
    opts: &TrainerOptions,
    source: &mut dyn BatchSource,
    val_batches: &[Batch],
) -> Result<TrainOutcome> {
    run_source_and_keep(backend, cfg, opts, source, val_batches).map(|t| t.outcome)
}

/// [`run_source`], returning the live session alongside the outcome.
pub fn run_source_and_keep<'b>(
    backend: &'b dyn Backend,
    cfg: &RepoConfig,
    opts: &TrainerOptions,
    source: &mut dyn BatchSource,
    val_batches: &[Batch],
) -> Result<TrainedModel<'b>> {
    let m = backend.manifest();
    let mut session = Session::new(backend);
    session.init(opts.seed)?;
    if let Some(ck) = &opts.warm_start {
        ck.apply(&mut session)?;
    }
    // The fixed validation set goes device-resident once; every ES check
    // and the final pass below is then pure execution (no re-upload).
    let needs_val = !val_batches.is_empty()
        && (opts.final_validation || opts.method == StoppingMethod::ClassicEs);
    let val_cache = if needs_val {
        Some(DeviceBatchCache::upload(&session, val_batches)?)
    } else {
        None
    };

    let schedule = CosineSchedule::new(cfg.run.lr, cfg.run.warmup_frac, opts.total_steps);
    let mut monitor = match opts.method {
        StoppingMethod::GradEs => {
            Monitor::Grades(GradesMonitor::new(&cfg.grades, m, opts.total_steps)?)
        }
        StoppingMethod::EbCriterion => {
            Monitor::Eb(EbCriterion::new(&cfg.eb, m, opts.total_steps))
        }
        StoppingMethod::SpectralEs => {
            Monitor::Spectral(SpectralEs::new(&cfg.spectral, m, opts.total_steps))
        }
        _ => Monitor::Grades(GradesMonitor::disabled(m)),
    };
    let mut es = match opts.method {
        StoppingMethod::ClassicEs => ClassicEs::new(&cfg.es, opts.total_steps),
        _ => ClassicEs::disabled(&cfg.es),
    };
    // Instance-ES sits outside the Monitor dispatch: its unit of exclusion
    // is a training row, not a component, and it needs the raw batch
    // before upload — so it owns the batch path below.
    let mut ies = match opts.method {
        StoppingMethod::InstanceEs => Some(InstanceEs::new(&cfg.ies, opts.total_steps)),
        _ => None,
    };
    let mut freeze = FreezeState::new(m.n_components);
    // Freeze-aware step planning: omit every frozen component's dW work,
    // unless dynamic unfreezing needs the frozen components' statistics
    // kept live (see `StepPlanner::for_run`).
    let mut planner = StepPlanner::for_run(m, &cfg.grades, opts.elide_frozen)?;
    planner.truncate = opts.truncate_frozen_prefix;
    if opts.truncate_frozen_prefix && !planner.enabled {
        // the GRADES_JOBS-style rule: never stay silent about an
        // explicitly requested knob that cannot take effect
        static WARNED: std::sync::Once = std::sync::Once::new();
        WARNED.call_once(|| {
            eprintln!(
                "[trainer] backward truncation requested but step planning is \
                 disabled for this run (baseline method, or dynamic unfreezing on \
                 the l1_abs metric needs frozen components' statistics live); the \
                 backward sweep stays full"
            );
        });
    }
    // Chunked validation runtime: classic-ES checks pin a snapshot and
    // advance `chunk` eval batches per train step instead of stalling
    // the loop for a full pass. With the default synchronous options
    // every pass drains at its issue step — the pre-async behaviour,
    // bitwise (see `runtime::async_eval`).
    let mut validator: AsyncValidator<EvalSnapshot> =
        AsyncValidator::new(opts.async_eval, val_cache.as_ref().map_or(0, |c| c.len()));
    let mut flops = FlopsCounter::default();
    let mut log = MetricsLog::default();
    let mut ctrl = vec![0f32; m.ctrl_len];
    let wall = Timer::new();
    let mut monitor_secs = 0.0f64;
    let mut validation_secs = 0.0f64;
    let mut stop_cause = StopCause::BudgetExhausted;
    let mut steps_run = 0usize;
    // Upload-ahead staging slot: batch t+1's device buffers, copied while
    // step t executes. `None` ⇒ the upload happens on the critical path.
    let mut staged: Option<UploadedBatch> = None;

    for t in 1..=opts.total_steps {
        ctrl[0] = t as f32;
        ctrl[1] = schedule.lr(t) as f32;
        ctrl[2] = 1.0;
        ctrl[m.ctrl_mask_offset..m.ctrl_mask_offset + m.n_components]
            .copy_from_slice(freeze.mask());
        // The plan is derived from the same freeze state the ctrl mask
        // above was copied from, so omitted ⊆ frozen holds by
        // construction for this step's executed graph.
        let plan = planner.plan(t, &freeze);
        debug_assert!(plan.is_sound(&freeze));
        let io = if let Some(rule) = ies.as_mut() {
            // Instance-ES path: score and mask the batch *before* upload.
            // Upload-ahead staging is bypassed — a staged batch would be
            // masked against the exclusion set of one check earlier.
            debug_assert!(staged.is_none());
            let mut b = source.next_batch();
            rule.note_rows(&b, m.seq_len);
            if rule.due(t) {
                let mt = Timer::new();
                let rows = session.eval_rows(&b)?;
                rule.observe(&rows, &b, m.seq_len);
                monitor_secs += mt.secs();
            }
            rule.mask(&mut b, m.seq_len);
            session.upload_batch(&b)?
        } else {
            match staged.take() {
                Some(io) => io,
                None => session.upload_batch(&source.next_batch())?,
            }
        };
        let realized = session.train_step_uploaded(io, &ctrl, &plan)?;
        if opts.pipeline.upload_ahead && ies.is_none() && t < opts.total_steps {
            // PJRT dispatch is asynchronous: step t may still be executing
            // on device while this host→device copy proceeds. If the run
            // stops early the staged batch is dropped unused — metrics and
            // freeze decisions never see it.
            staged = Some(session.upload_batch(&source.next_batch())?);
            session.note_staged_upload();
        }
        steps_run = t;
        flops.record_step(m, &freeze, &realized);
        let in_monitor_window = t > monitor.grace_steps();
        if in_monitor_window || t % opts.probe_every == 0 || t == opts.total_steps {
            let mt = Timer::new();
            let metrics = session.probe()?;
            let lr_scale = schedule.lr(t) / cfg.run.lr.max(1e-30);
            monitor.observe(t, m, &metrics, lr_scale, &mut freeze);
            monitor_secs += mt.secs();
            log.record(t, schedule.lr(t) as f64, freeze.frozen_fraction(), m, &metrics);
        }
        if let Monitor::Spectral(sp) = &mut monitor {
            // Spectral scans run on their own (sparser) cadence: each one
            // pulls the weights to host and eigendecomposes per-component
            // Gram matrices, so they are far costlier than a probe.
            if sp.due(t) {
                let mt = Timer::new();
                let state = session.state_to_host()?;
                sp.scan(t, m, &state, &mut freeze);
                monitor_secs += mt.secs();
            }
        }
        if monitor.should_terminate(&freeze) {
            stop_cause = StopCause::AllComponentsFrozen;
            break;
        }
        if ies.as_ref().map_or(false, |r| r.should_stop()) {
            stop_cause = StopCause::SamplesExhausted;
            break;
        }
        if let Some(cache) = &val_cache {
            let due = es.due(t);
            if due || validator.in_flight().is_some() {
                let vt = Timer::new();
                let evals_before = validator.stats.chunk_evals;
                // Issue a pass when due, and advance any in-flight pass
                // by one chunk; results come back in issue order, each
                // evaluated against the snapshot pinned at its check
                // step (not the parameters training has since reached).
                let results = validator.on_step(
                    t,
                    due,
                    || session.snapshot(),
                    |snap, i| session.eval_batch_snapshot(snap, cache.get(i)),
                )?;
                let mut secs = vt.secs();
                validation_secs += secs;
                // FLOPs track the chunk evals actually executed this step
                // (not per applied result), so time and FLOPs agree even
                // when a pass is later abandoned. Synchronous checks run
                // the whole pass here — identical to the old accounting.
                flops.record_validation(m, validator.stats.chunk_evals - evals_before);
                let mut stop = false;
                for r in &results {
                    log.record_val(r.issued_at, r.val_loss);
                    if es.record(r.val_loss, secs) {
                        stop = true;
                    }
                    secs = 0.0;
                }
                if stop {
                    // Applied at step t ≤ issued_at + k: the bounded
                    // staleness the `--staleness` knob makes explicit.
                    stop_cause = StopCause::ValidationPatience;
                    break;
                }
            }
        }
    }

    // A pass still in flight here was overtaken by the end of training —
    // budget exhausted, or the monitor froze the whole matrix before the
    // stop signal arrived. Its result is discarded, never applied.
    validator.abandon();

    let final_val_loss = match (&val_cache, opts.final_validation) {
        (Some(cache), true) => session.eval_mean_loss_cached(cache)?,
        _ => f64::NAN,
    };

    let timings = session.timings();
    log.timings = timings;
    Ok(TrainedModel {
        session,
        outcome: TrainOutcome {
            steps_run,
            stop_cause,
            wall_secs: wall.secs(),
            validation_secs,
            monitor_secs,
            flops,
            log,
            freeze,
            final_val_loss,
            variant_swap_step: planner.stats.attn_swap_step,
            plan: planner.stats,
            timings,
            async_eval: validator.stats,
        },
    })
}
