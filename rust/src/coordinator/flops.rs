//! Frozen-aware analytic FLOPs accounting (the paper's Table 4/5 columns).
//!
//! Uses the manifest's per-token matmul costs. Three tiers of savings,
//! reported explicitly (DESIGN.md "Decisions & risks"):
//!   1. update savings — frozen components skip their optimizer update
//!      (realized in-graph via the mask; small),
//!   2. dW savings — a frozen component's weight-gradient matmul is
//!      skipped. Two ledgers track this tier since the step planner
//!      landed: **theoretical** (`spent`) prices the ideal per-matrix
//!      plan — every frozen component's dW gone, what the paper's
//!      dynamic autograd engine gets via `requires_grad=False` and what
//!      Table 4's FLOPs column measures — while **realized**
//!      (`realized_spent`) prices what the executing engine actually
//!      skipped: the full plan on the host engine, the nearest sound
//!      pre-compiled variant on XLA (see
//!      `coordinator::scheduler::VariantLattice`). The gap between the
//!      two is exactly the cost of the static-graph substrate.
//!   3. termination savings — steps never executed after all components
//!      froze (the dominant term, paper §5.2).

use crate::coordinator::freeze::FreezeState;
use crate::coordinator::scheduler::StepPlan;
use crate::runtime::manifest::Manifest;

#[derive(Debug, Clone, Default)]
/// Cumulative FLOPs ledger for one training run.
pub struct FlopsCounter {
    /// Theoretical frozen-aware FLOPs: every frozen component's dW
    /// matmul priced as skipped (the paper's idealized accounting).
    pub spent: f64,
    /// Engine-realized FLOPs: only the dW matmuls the executed plan
    /// actually omitted are priced as skipped. `realized_spent ≥ spent`,
    /// with equality when the engine honors every plan exactly (host).
    pub realized_spent: f64,
    /// What the same steps would have cost with nothing frozen.
    pub dense_equivalent: f64,
    /// FLOPs spent inside validation passes (classic-ES overhead).
    pub validation: f64,
    /// Train steps recorded.
    pub steps: usize,
}

impl FlopsCounter {
    /// Per-token forward cost (everything).
    pub fn fwd_per_token(m: &Manifest) -> f64 {
        m.flops.fwd_per_token
    }

    /// Dense train-step cost: fwd + dX + dW over all components
    /// (≈ 3× forward, the standard estimate).
    pub fn dense_step(m: &Manifest) -> f64 {
        let tokens = (m.batch_size * m.seq_len) as f64;
        let dw: f64 = m.flops.per_component_fwd.values().sum();
        tokens * (m.flops.fwd_per_token + m.flops.bwd_dx_per_token + dw)
    }

    /// Frozen-aware train-step cost: frozen components keep fwd + dX
    /// (gradients still flow *through* them — Alg. 1 line 15) but skip dW.
    pub fn step_cost(m: &Manifest, freeze: &FreezeState) -> f64 {
        Self::step_cost_where(m, |c| freeze.is_frozen(c))
    }

    /// Train-step cost under an execution plan: exactly the omitted
    /// components' dW matmuls are skipped.
    pub fn planned_step_cost(m: &Manifest, plan: &StepPlan) -> f64 {
        Self::step_cost_where(m, |c| plan.omits(c))
    }

    /// Shared pricing core: skip the dW of components matching `skipped`.
    fn step_cost_where<F: Fn(usize) -> bool>(m: &Manifest, skipped: F) -> f64 {
        let tokens = (m.batch_size * m.seq_len) as f64;
        let mut dw = 0.0;
        for c in &m.components {
            if !skipped(c.idx) {
                dw += m.flops.per_component_fwd.get(&c.name).copied().unwrap_or(0.0);
            }
        }
        tokens * (m.flops.fwd_per_token + m.flops.bwd_dx_per_token + dw)
    }

    /// Forward-only validation cost for `n_batches` batches.
    pub fn eval_cost(m: &Manifest, n_batches: usize) -> f64 {
        (n_batches * m.batch_size * m.seq_len) as f64 * m.flops.fwd_per_token
    }

    /// Account one train step: `freeze` prices the theoretical ledger,
    /// `realized` (the engine-lowered plan the step actually executed)
    /// prices the realized one.
    pub fn record_step(&mut self, m: &Manifest, freeze: &FreezeState, realized: &StepPlan) {
        self.spent += Self::step_cost(m, freeze);
        self.realized_spent += Self::planned_step_cost(m, realized);
        self.dense_equivalent += Self::dense_step(m);
        self.steps += 1;
    }

    /// Account one validation pass of `n_batches` forward-only batches.
    pub fn record_validation(&mut self, m: &Manifest, n_batches: usize) {
        let c = Self::eval_cost(m, n_batches);
        self.validation += c;
        self.spent += c;
        self.realized_spent += c;
    }

    /// Total accounted FLOPs (train + validation), theoretical ledger.
    pub fn total(&self) -> f64 {
        self.spent
    }

    /// FLOPs the ideal per-matrix plan saves vs dense execution.
    pub fn theoretical_savings(&self) -> f64 {
        self.dense_equivalent - (self.spent - self.validation)
    }

    /// FLOPs the executed plans actually saved vs dense execution.
    pub fn realized_savings(&self) -> f64 {
        self.dense_equivalent - (self.realized_spent - self.validation)
    }

    /// Share of the theoretical dW savings the engine realized, in
    /// [0, 1]; 1.0 when nothing was ever skippable (vacuously realized).
    pub fn realized_fraction(&self) -> f64 {
        let t = self.theoretical_savings();
        if t <= 0.0 {
            return 1.0;
        }
        (self.realized_savings() / t).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::freeze::FreezeReason;
    use crate::coordinator::grades::tests::fake_manifest;

    fn manifest_with_flops() -> Manifest {
        let mut m = fake_manifest(2);
        for c in &m.components {
            m.flops.per_component_fwd.insert(c.name.clone(), 100.0);
        }
        m.flops.fwd_per_token = 2000.0;
        m.flops.bwd_dx_per_token = 2000.0;
        m
    }

    #[test]
    fn freezing_reduces_step_cost_monotonically() {
        let m = manifest_with_flops();
        let mut fs = FreezeState::new(m.n_components);
        let dense = FlopsCounter::step_cost(&m, &fs);
        assert_eq!(dense, FlopsCounter::dense_step(&m));
        fs.freeze(0, 1, FreezeReason::Converged, 0.0);
        let one = FlopsCounter::step_cost(&m, &fs);
        assert!(one < dense);
        let tokens = (m.batch_size * m.seq_len) as f64;
        assert!((dense - one - tokens * 100.0).abs() < 1e-6);
        for c in 1..m.n_components {
            fs.freeze(c, 1, FreezeReason::Converged, 0.0);
        }
        let all = FlopsCounter::step_cost(&m, &fs);
        // all dW gone, fwd + dX remain (gradient flow preserved)
        assert!((all - tokens * 4000.0).abs() < 1e-6);
    }

    #[test]
    fn planned_cost_matches_frozen_cost_for_the_ideal_plan() {
        let m = manifest_with_flops();
        let mut fs = FreezeState::new(m.n_components);
        fs.freeze(1, 1, FreezeReason::Converged, 0.0);
        fs.freeze(5, 1, FreezeReason::Converged, 0.0);
        let ideal = StepPlan::omitting(m.n_components, &[1, 5]);
        assert_eq!(
            FlopsCounter::step_cost(&m, &fs),
            FlopsCounter::planned_step_cost(&m, &ideal)
        );
        // a coarser lowering realizes less
        let coarse = StepPlan::omitting(m.n_components, &[1]);
        assert!(
            FlopsCounter::planned_step_cost(&m, &coarse) > FlopsCounter::planned_step_cost(&m, &ideal)
        );
    }

    #[test]
    fn counter_accumulates_and_splits_realized_from_theoretical() {
        let m = manifest_with_flops();
        let mut fs = FreezeState::new(m.n_components);
        fs.freeze(0, 1, FreezeReason::Converged, 0.0);
        fs.freeze(1, 1, FreezeReason::Converged, 0.0);
        let mut c = FlopsCounter::default();
        // engine realized only component 0's elision (a coarse variant)
        c.record_step(&m, &fs, &StepPlan::omitting(m.n_components, &[0]));
        c.record_validation(&m, 3);
        assert_eq!(c.steps, 1);
        assert!(c.validation > 0.0);
        assert!(c.total() > c.validation);
        assert!(c.realized_spent > c.spent, "coarse lowering spends more than the ideal plan");
        assert!(c.theoretical_savings() > c.realized_savings());
        let frac = c.realized_fraction();
        assert!((0.0..=1.0).contains(&frac) && (frac - 0.5).abs() < 1e-9, "frac {frac}");
    }

    #[test]
    fn exact_lowering_realizes_everything() {
        let m = manifest_with_flops();
        let mut fs = FreezeState::new(m.n_components);
        fs.freeze(3, 1, FreezeReason::Converged, 0.0);
        let mut c = FlopsCounter::default();
        c.record_step(&m, &fs, &StepPlan::omitting(m.n_components, &[3]));
        assert_eq!(c.spent, c.realized_spent);
        assert_eq!(c.realized_fraction(), 1.0);
    }
}
