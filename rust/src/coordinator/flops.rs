//! Frozen-aware analytic FLOPs accounting (the paper's Table 4/5 columns).
//!
//! Uses the manifest's per-token matmul costs. Three tiers of savings,
//! reported explicitly (DESIGN.md "Decisions & risks"):
//!   1. update savings — frozen components skip their optimizer update
//!      (realized in-graph via the mask; small),
//!   2. dW savings — a frozen component's weight-gradient matmul is
//!      skipped. In our static-graph substrate this is *realized* only when
//!      the scheduler swaps to the attn-frozen variant; the accounting
//!      model reports the idealized per-matrix number the paper's dynamic
//!      autograd engine gets (requires_grad=False), which is what Table 4's
//!      FLOPs column measures.
//!   3. termination savings — steps never executed after all components
//!      froze (the dominant term, paper §5.2).

use crate::coordinator::freeze::FreezeState;
use crate::runtime::manifest::Manifest;

#[derive(Debug, Clone, Default)]
/// Cumulative FLOPs ledger for one training run.
pub struct FlopsCounter {
    /// Accounted FLOPs actually spent (frozen-aware).
    pub spent: f64,
    /// What the same steps would have cost with nothing frozen.
    pub dense_equivalent: f64,
    /// FLOPs spent inside validation passes (classic-ES overhead).
    pub validation: f64,
    /// Train steps recorded.
    pub steps: usize,
}

impl FlopsCounter {
    /// Per-token forward cost (everything).
    pub fn fwd_per_token(m: &Manifest) -> f64 {
        m.flops.fwd_per_token
    }

    /// Dense train-step cost: fwd + dX + dW over all components
    /// (≈ 3× forward, the standard estimate).
    pub fn dense_step(m: &Manifest) -> f64 {
        let tokens = (m.batch_size * m.seq_len) as f64;
        let dw: f64 = m.flops.per_component_fwd.values().sum();
        tokens * (m.flops.fwd_per_token + m.flops.bwd_dx_per_token + dw)
    }

    /// Frozen-aware train-step cost: frozen components keep fwd + dX
    /// (gradients still flow *through* them — Alg. 1 line 15) but skip dW.
    pub fn step_cost(m: &Manifest, freeze: &FreezeState) -> f64 {
        let tokens = (m.batch_size * m.seq_len) as f64;
        let mut dw = 0.0;
        for c in &m.components {
            if !freeze.is_frozen(c.idx) {
                dw += m.flops.per_component_fwd.get(&c.name).copied().unwrap_or(0.0);
            }
        }
        tokens * (m.flops.fwd_per_token + m.flops.bwd_dx_per_token + dw)
    }

    /// Forward-only validation cost for `n_batches` batches.
    pub fn eval_cost(m: &Manifest, n_batches: usize) -> f64 {
        (n_batches * m.batch_size * m.seq_len) as f64 * m.flops.fwd_per_token
    }

    /// Account one train step under the current freeze state.
    pub fn record_step(&mut self, m: &Manifest, freeze: &FreezeState) {
        self.spent += Self::step_cost(m, freeze);
        self.dense_equivalent += Self::dense_step(m);
        self.steps += 1;
    }

    /// Account one validation pass of `n_batches` forward-only batches.
    pub fn record_validation(&mut self, m: &Manifest, n_batches: usize) {
        let c = Self::eval_cost(m, n_batches);
        self.validation += c;
        self.spent += c;
    }

    /// Total accounted FLOPs (train + validation).
    pub fn total(&self) -> f64 {
        self.spent
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::freeze::FreezeReason;
    use crate::coordinator::grades::tests::fake_manifest;

    fn manifest_with_flops() -> Manifest {
        let mut m = fake_manifest(2);
        for c in &m.components {
            m.flops.per_component_fwd.insert(c.name.clone(), 100.0);
        }
        m.flops.fwd_per_token = 2000.0;
        m.flops.bwd_dx_per_token = 2000.0;
        m
    }

    #[test]
    fn freezing_reduces_step_cost_monotonically() {
        let m = manifest_with_flops();
        let mut fs = FreezeState::new(m.n_components);
        let dense = FlopsCounter::step_cost(&m, &fs);
        assert_eq!(dense, FlopsCounter::dense_step(&m));
        fs.freeze(0, 1, FreezeReason::Converged, 0.0);
        let one = FlopsCounter::step_cost(&m, &fs);
        assert!(one < dense);
        let tokens = (m.batch_size * m.seq_len) as f64;
        assert!((dense - one - tokens * 100.0).abs() < 1e-6);
        for c in 1..m.n_components {
            fs.freeze(c, 1, FreezeReason::Converged, 0.0);
        }
        let all = FlopsCounter::step_cost(&m, &fs);
        // all dW gone, fwd + dX remain (gradient flow preserved)
        assert!((all - tokens * 4000.0).abs() < 1e-6);
    }

    #[test]
    fn counter_accumulates() {
        let m = manifest_with_flops();
        let fs = FreezeState::new(m.n_components);
        let mut c = FlopsCounter::default();
        c.record_step(&m, &fs);
        c.record_validation(&m, 3);
        assert_eq!(c.steps, 1);
        assert!(c.validation > 0.0);
        assert!(c.total() > c.validation);
    }
}
