//! Procedural benchmark suites — the repo's analogue of the paper's eight
//! LM benchmarks (BoolQ…ARC-E), three VLM tasks (GQA/VQAv2/COCO-Cap) and
//! the six nanoVLM categories (Table 3).
//!
//! Every suite is multiple-choice: one grammatical/faithful option + 3
//! distractors corrupted under the suite's rule family. Accuracy = fraction
//! of questions where the model assigns the lowest mean NLL to the truth —
//! the same scoring harness shape as lm-eval.

use crate::data::corpus::GrammarGen;
use crate::data::multimodal::{self, SceneConfig};
use crate::data::vocab::Vocab;
use crate::util::rng::Rng;

/// Options per multiple-choice question.
pub const N_OPTIONS: usize = 4;

/// One multiple-choice question: N_OPTIONS token sequences (+ optional
/// shared image patches); `correct` indexes the faithful option.
pub struct McQuestion {
    /// Candidate token sequences (exactly `N_OPTIONS`).
    pub options: Vec<Vec<i32>>,
    /// VLM: the image all options share.
    pub patches: Option<Vec<f32>>,
    /// Index of the faithful option.
    pub correct: usize,
}

/// A named set of questions — one accuracy column.
pub struct Suite {
    /// Column name used in the tables.
    pub name: &'static str,
    /// The paper benchmark this column stands in for.
    pub paper_analogue: &'static str,
    /// The suite's questions.
    pub questions: Vec<McQuestion>,
}

fn mc_from_sentence(
    g: &GrammarGen,
    r: &mut Rng,
    rule: &str,
) -> McQuestion {
    let truth = g.sentence(r);
    let correct = r.below(N_OPTIONS);
    let mut options = Vec::with_capacity(N_OPTIONS);
    for i in 0..N_OPTIONS {
        if i == correct {
            options.push(truth.ids.clone());
        } else {
            // re-corrupt until distinct from the truth and prior options
            let mut c = g.corrupt(r, &truth, rule);
            for _ in 0..8 {
                if c.ids != truth.ids && !options.contains(&c.ids) {
                    break;
                }
                c = g.corrupt(r, &truth, rule);
            }
            options.push(c.ids);
        }
    }
    McQuestion { options, patches: None, correct }
}

/// The eight LM suites (column order matches Table 1's benchmarks).
pub fn lm_suites(vocab: &Vocab, seed: u64, n_questions: usize) -> Vec<Suite> {
    let defs: [(&'static str, &'static str, &'static str, &'static str); 8] = [
        ("AgreeDet", "BoolQ", "det", "std"),
        ("AgreeAdj", "PIQA", "adj", "std"),
        ("VerbSel", "SIQA", "verb_obj", "std"),
        ("LongRange", "HellaSwag", "det2", "std"),
        ("AdvAssoc", "WinoGrande", "adv", "std"),
        ("WordOrder", "OpenBookQA", "swap", "std"),
        ("RareComp", "ARC-C", "det", "rare"),
        ("FreqComp", "ARC-E", "det", "freq"),
    ];
    defs.iter()
        .enumerate()
        .map(|(si, (name, analogue, rule, gen_kind))| {
            let g = match *gen_kind {
                "rare" => GrammarGen::rare(vocab),
                "freq" => GrammarGen::frequent(vocab),
                _ => GrammarGen::new(vocab),
            };
            let mut r = Rng::new(seed ^ ((si as u64 + 1) * 0x9e37));
            let questions =
                (0..n_questions).map(|_| mc_from_sentence(&g, &mut r, rule)).collect();
            Suite { name, paper_analogue: analogue, questions }
        })
        .collect()
}

fn vlm_question(
    cfg: &SceneConfig,
    vocab: &Vocab,
    r: &mut Rng,
    what: &str,
) -> McQuestion {
    let scene = multimodal::gen_scene(cfg, r);
    let patches = multimodal::render(cfg, &scene, r);
    let truth = multimodal::caption(vocab, &scene);
    let correct = r.below(N_OPTIONS);
    let mut options = Vec::with_capacity(N_OPTIONS);
    for i in 0..N_OPTIONS {
        if i == correct {
            options.push(truth.clone());
        } else {
            let mut c = multimodal::corrupt_caption(vocab, cfg, &scene, what, r);
            for _ in 0..8 {
                if c != truth && !options.contains(&c) {
                    break;
                }
                c = multimodal::corrupt_caption(vocab, cfg, &scene, what, r);
            }
            options.push(c);
        }
    }
    McQuestion { options, patches: Some(patches), correct }
}

/// The three VLM suites of Table 2 (GQA / VQAv2 / COCO-Cap analogues).
pub fn vlm_suites(
    cfg: &SceneConfig,
    vocab: &Vocab,
    seed: u64,
    n_questions: usize,
) -> Vec<Suite> {
    let defs: [(&'static str, &'static str, &'static str); 3] = [
        ("ColorQA", "GQA", "color"),
        ("ShapeQA", "VQAv2", "shape"),
        ("CapMatch", "COCO Cap", "position"),
    ];
    defs.iter()
        .enumerate()
        .map(|(si, (name, analogue, what))| {
            let mut r = Rng::new(seed ^ ((si as u64 + 1) * 0x517c));
            let questions =
                (0..n_questions).map(|_| vlm_question(cfg, vocab, &mut r, what)).collect();
            Suite { name, paper_analogue: analogue, questions }
        })
        .collect()
}

/// The six nanoVLM-style categories of Table 3.
pub fn nanovlm_suites(
    cfg: &SceneConfig,
    vocab: &Vocab,
    seed: u64,
    n_questions: usize,
) -> Vec<Suite> {
    let defs: [(&'static str, &'static str, &'static str); 6] = [
        ("CoarsePerc", "Coarse Perception", "shape"),
        ("FinePerc", "Fine-grained Perception", "color"),
        ("InstReason", "Instance Reasoning", "position"),
        ("LogicReason", "Logical Reasoning", "order"),
        ("Count", "Math", "count"),
        ("SciTech", "Science & Technology", "combo"),
    ];
    defs.iter()
        .enumerate()
        .map(|(si, (name, analogue, what))| {
            let mut r = Rng::new(seed ^ ((si as u64 + 7) * 0x2a65));
            let questions = (0..n_questions)
                .map(|_| match *what {
                    "order" => vlm_order_question(cfg, vocab, &mut r),
                    "count" => vlm_count_question(cfg, vocab, &mut r),
                    "combo" => {
                        let what = if r.chance(0.5) { "color" } else { "shape" };
                        vlm_question(cfg, vocab, &mut r, what)
                    }
                    w => vlm_question(cfg, vocab, &mut r, w),
                })
                .collect();
            Suite { name, paper_analogue: analogue, questions }
        })
        .collect()
}

/// Logical-order distractor: object clauses permuted out of raster order.
fn vlm_order_question(cfg: &SceneConfig, vocab: &Vocab, r: &mut Rng) -> McQuestion {
    // need >= 2 objects for an order violation
    let (scene, patches) = loop {
        let s = multimodal::gen_scene(cfg, r);
        if s.objects.len() >= 2 {
            let p = multimodal::render(cfg, &s, r);
            break (s, p);
        }
    };
    let truth = multimodal::caption(vocab, &scene);
    let correct = r.below(N_OPTIONS);
    let mut options = Vec::with_capacity(N_OPTIONS);
    for i in 0..N_OPTIONS {
        if i == correct {
            options.push(truth.clone());
        } else {
            let mut s2 = scene.clone();
            // permute object order => clause order violates raster order
            loop {
                r.shuffle(&mut s2.objects);
                if s2.objects.iter().map(|o| o.cell).collect::<Vec<_>>()
                    != scene.objects.iter().map(|o| o.cell).collect::<Vec<_>>()
                {
                    break;
                }
            }
            // caption() sorts by cell; emit clauses manually to keep the
            // violated order
            let mut ids = vec![crate::data::vocab::BOS];
            for o in &s2.objects {
                ids.push(vocab.colors.get(o.color));
                ids.push(vocab.shapes.get(o.shape));
                ids.push(vocab.positions.get(multimodal::quadrant(o.cell, scene.grid)));
                ids.push(crate::data::vocab::PERIOD);
            }
            ids.push(crate::data::vocab::EOS);
            if ids == truth || options.contains(&ids) {
                // degenerate (identical attrs) — fall back to color corrupt
                options.push(multimodal::corrupt_caption(vocab, cfg, &scene, "color", r));
            } else {
                options.push(ids);
            }
        }
    }
    McQuestion { options, patches: Some(patches), correct }
}

/// Count distractor: a clause dropped or duplicated.
fn vlm_count_question(cfg: &SceneConfig, vocab: &Vocab, r: &mut Rng) -> McQuestion {
    let (scene, patches) = loop {
        let s = multimodal::gen_scene(cfg, r);
        if s.objects.len() >= 2 {
            let p = multimodal::render(cfg, &s, r);
            break (s, p);
        }
    };
    let truth = multimodal::caption(vocab, &scene);
    let correct = r.below(N_OPTIONS);
    let mut options = Vec::with_capacity(N_OPTIONS);
    for i in 0..N_OPTIONS {
        if i == correct {
            options.push(truth.clone());
            continue;
        }
        let mut s2 = scene.clone();
        if r.chance(0.5) {
            let k = r.below(s2.objects.len());
            s2.objects.remove(k);
        } else {
            let k = r.below(s2.objects.len());
            let mut dup = s2.objects[k];
            // duplicate into a free cell
            for _ in 0..64 {
                let cell = r.below(cfg.n_patches);
                if !s2.objects.iter().any(|o| o.cell == cell) {
                    dup.cell = cell;
                    s2.objects.push(dup);
                    break;
                }
            }
        }
        s2.objects.sort_by_key(|o| o.cell);
        let ids = multimodal::caption(vocab, &s2);
        if ids == truth || options.contains(&ids) {
            options.push(multimodal::corrupt_caption(vocab, cfg, &scene, "shape", r));
        } else {
            options.push(ids);
        }
    }
    McQuestion { options, patches: Some(patches), correct }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_suites_shape() {
        let v = Vocab::build(256).unwrap();
        let suites = lm_suites(&v, 42, 10);
        assert_eq!(suites.len(), 8);
        for s in &suites {
            assert_eq!(s.questions.len(), 10);
            for q in &s.questions {
                assert_eq!(q.options.len(), N_OPTIONS);
                assert!(q.correct < N_OPTIONS);
                // distractors differ from the truth
                let truth = &q.options[q.correct];
                let distinct =
                    q.options.iter().enumerate().filter(|(i, o)| *i != q.correct && *o != truth);
                assert!(distinct.count() >= 2, "suite {}", s.name);
            }
        }
    }

    #[test]
    fn vlm_suites_shape() {
        let v = Vocab::build(256).unwrap();
        let cfg = SceneConfig::for_model(16, 24, &v);
        for suites in [vlm_suites(&cfg, &v, 1, 6), nanovlm_suites(&cfg, &v, 1, 6)] {
            for s in &suites {
                for q in &s.questions {
                    assert!(q.patches.is_some());
                    assert_eq!(q.patches.as_ref().unwrap().len(), 16 * 24);
                    assert_eq!(q.options.len(), N_OPTIONS);
                }
            }
        }
    }

    #[test]
    fn deterministic() {
        let v = Vocab::build(256).unwrap();
        let a = lm_suites(&v, 7, 3);
        let b = lm_suites(&v, 7, 3);
        for (x, y) in a.iter().zip(&b) {
            for (qa, qb) in x.questions.iter().zip(&y.questions) {
                assert_eq!(qa.options, qb.options);
                assert_eq!(qa.correct, qb.correct);
            }
        }
    }
}
