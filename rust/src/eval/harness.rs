//! Multiple-choice scoring harness: packs MC options into fixed-shape
//! `eval_rows` batches and computes per-suite accuracy.
//!
//! Packing (host work) and uploading (host→device copies) are both
//! cacheable: a [`PackedSuite`] is built once per suite, and a
//! [`DeviceSuite`] pins its batches on device so repeated scoring — the
//! ablation grid scores the *same* suites for every (τ, α) cell — is pure
//! execution. The session state is a separate executable argument, so one
//! `DeviceSuite` serves any number of trained sessions on the client.

use anyhow::{ensure, Result};

use super::benchmarks::{McQuestion, Suite, N_OPTIONS};
use crate::runtime::manifest::Manifest;
use crate::runtime::session::{Batch, Session, UploadedBatch};

/// Convert a token sequence into an (tokens, targets) row of length T.
fn seq_to_row(ids: &[i32], t: usize) -> (Vec<i32>, Vec<i32>) {
    let n = ids.len().min(t + 1);
    let mut tokens = vec![0i32; t];
    let mut targets = vec![-1i32; t];
    for i in 0..n.saturating_sub(1) {
        tokens[i] = ids[i];
        targets[i] = ids[i + 1];
    }
    (tokens, targets)
}

/// One suite packed into fixed-shape `eval_rows` batches (done once; the
/// per-call packing cost was previously paid on every scoring pass).
pub struct PackedSuite {
    /// Suite name (table column).
    pub name: String,
    batches: Vec<Batch>,
    /// Correct-option index for each question, chunked per batch.
    corrects: Vec<Vec<usize>>,
}

impl PackedSuite {
    /// Pack `questions_per_batch = B / N_OPTIONS` questions per batch
    /// (each option one row; VLM rows replicate the question's patches),
    /// padding the final batch with fully-masked rows.
    pub fn pack(manifest: &Manifest, suite: &Suite) -> Result<Self> {
        let b = manifest.batch_size;
        let t = manifest.seq_len;
        ensure!(b % N_OPTIONS == 0, "batch_size {b} must be a multiple of {N_OPTIONS}");
        let qpb = b / N_OPTIONS;
        let is_vlm = manifest.is_vlm();
        let patch_len = manifest.n_patches * manifest.patch_dim;

        let mut batches = Vec::new();
        let mut corrects = Vec::new();
        let mut qi = 0usize;
        while qi < suite.questions.len() {
            let chunk: Vec<&McQuestion> =
                suite.questions[qi..(qi + qpb).min(suite.questions.len())].iter().collect();
            let mut batch = Batch::default();
            for q in &chunk {
                for opt in &q.options {
                    let (tok, tgt) = seq_to_row(opt, t);
                    batch.tokens.extend_from_slice(&tok);
                    batch.targets.extend_from_slice(&tgt);
                    if is_vlm {
                        batch.patches.extend_from_slice(q.patches.as_ref().unwrap());
                    }
                }
            }
            // pad out to full batch with masked rows
            let rows = chunk.len() * N_OPTIONS;
            for _ in rows..b {
                batch.tokens.extend(std::iter::repeat(0).take(t));
                batch.targets.extend(std::iter::repeat(-1).take(t));
                if is_vlm {
                    batch.patches.extend(std::iter::repeat(0.0).take(patch_len));
                }
            }
            batches.push(batch);
            corrects.push(chunk.iter().map(|q| q.correct).collect());
            qi += chunk.len();
        }
        Ok(PackedSuite { name: suite.name.to_string(), batches, corrects })
    }

    /// Pin this suite's batches on device (once per client); scoring
    /// through the result skips both packing and upload. The returned
    /// [`DeviceSuite`] is self-contained (it copies the small name /
    /// correct-answer tables), so caches can keep it without holding the
    /// `PackedSuite` alive.
    pub fn upload(&self, session: &Session) -> Result<DeviceSuite> {
        let ios = self
            .batches
            .iter()
            .map(|b| session.upload_batch(b))
            .collect::<Result<Vec<_>>>()?;
        Ok(DeviceSuite { name: self.name.clone(), corrects: self.corrects.clone(), ios })
    }

    /// Score with per-call uploads (one-shot use).
    pub fn score(&self, session: &Session) -> Result<f64> {
        let mut acc = Accuracy::default();
        for (batch, corrects) in self.batches.iter().zip(&self.corrects) {
            acc.tally(&session.eval_rows(batch)?, corrects);
        }
        Ok(acc.pct())
    }
}

/// A [`PackedSuite`] resident on device. Owns its device buffers and the
/// (small) host-side scoring tables; the session state is a separate
/// executable argument, so one `DeviceSuite` serves any number of trained
/// sessions on the same client.
pub struct DeviceSuite {
    name: String,
    corrects: Vec<Vec<usize>>,
    ios: Vec<UploadedBatch>,
}

impl DeviceSuite {
    /// Suite name (table column).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Pure-execution scoring — identical result to `PackedSuite::score`
    /// (same executable, same rows).
    pub fn score(&self, session: &Session) -> Result<f64> {
        let mut acc = Accuracy::default();
        for (io, corrects) in self.ios.iter().zip(&self.corrects) {
            acc.tally(&session.eval_rows_uploaded(io)?, corrects);
        }
        Ok(acc.pct())
    }
}

/// Argmin-over-options accuracy accumulator shared by both scoring paths.
#[derive(Default)]
struct Accuracy {
    correct: usize,
    total: usize,
}

impl Accuracy {
    fn tally(&mut self, per_row: &[(f64, f64)], corrects: &[usize]) {
        for (ci, &want) in corrects.iter().enumerate() {
            let mut best = (f64::INFINITY, 0usize);
            for o in 0..N_OPTIONS {
                let (loss, count) = per_row[ci * N_OPTIONS + o];
                let mean = if count > 0.0 { loss / count } else { f64::INFINITY };
                if mean < best.0 {
                    best = (mean, o);
                }
            }
            if best.1 == want {
                self.correct += 1;
            }
            self.total += 1;
        }
    }

    fn pct(&self) -> f64 {
        100.0 * self.correct as f64 / self.total.max(1) as f64
    }
}

/// Score one suite (packs on the fly — use [`PackedSuite`] to amortize).
pub fn score_suite(session: &Session, suite: &Suite) -> Result<f64> {
    PackedSuite::pack(session.manifest(), suite)?.score(session)
}

/// Accuracy per suite, in order, plus the average — one Table-1 row.
pub fn score_suites(session: &Session, suites: &[Suite]) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    let mut sum = 0.0;
    for s in suites {
        let acc = score_suite(session, s)?;
        sum += acc;
        out.push((s.name.to_string(), acc));
    }
    out.push(("Avg.".to_string(), sum / suites.len().max(1) as f64));
    Ok(out)
}

/// Device-cached variant of [`score_suites`] for repeated scoring runs.
pub fn score_device_suites(
    session: &Session,
    suites: &[DeviceSuite],
) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    let mut sum = 0.0;
    for s in suites {
        let acc = s.score(session)?;
        sum += acc;
        out.push((s.name().to_string(), acc));
    }
    out.push(("Avg.".to_string(), sum / suites.len().max(1) as f64));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seq_to_row_alignment() {
        let (tok, tgt) = seq_to_row(&[1, 5, 7, 2], 6);
        assert_eq!(tok, vec![1, 5, 7, 0, 0, 0]);
        assert_eq!(tgt, vec![5, 7, 2, -1, -1, -1]);
    }

    #[test]
    fn seq_to_row_truncates() {
        let ids: Vec<i32> = (0..20).collect();
        let (tok, tgt) = seq_to_row(&ids, 4);
        assert_eq!(tok, vec![0, 1, 2, 3]);
        assert_eq!(tgt, vec![1, 2, 3, 4]);
    }

    #[test]
    fn accuracy_argmin_over_mean_loss() {
        let mut acc = Accuracy::default();
        // q0: option 1 has lowest mean loss; q1: option 0 (count-masked
        // rows score +inf and can never win).
        let per_row = vec![
            (4.0, 2.0), (1.0, 2.0), (3.0, 2.0), (9.0, 0.0), // q0 → 1
            (0.5, 1.0), (2.0, 1.0), (2.0, 1.0), (2.0, 1.0), // q1 → 0
        ];
        acc.tally(&per_row, &[1, 0]);
        assert_eq!((acc.correct, acc.total), (2, 2));
        acc.tally(&per_row, &[0, 0]);
        assert_eq!((acc.correct, acc.total), (3, 4));
        assert!((acc.pct() - 75.0).abs() < 1e-12);
    }
}
