//! Multiple-choice scoring harness: packs MC options into fixed-shape
//! `eval_rows` batches and computes per-suite accuracy.

use anyhow::{ensure, Result};

use super::benchmarks::{McQuestion, Suite, N_OPTIONS};
use crate::runtime::session::{Batch, Session};

/// Convert a token sequence into an (tokens, targets) row of length T.
fn seq_to_row(ids: &[i32], t: usize) -> (Vec<i32>, Vec<i32>) {
    let n = ids.len().min(t + 1);
    let mut tokens = vec![0i32; t];
    let mut targets = vec![-1i32; t];
    for i in 0..n.saturating_sub(1) {
        tokens[i] = ids[i];
        targets[i] = ids[i + 1];
    }
    (tokens, targets)
}

/// Score one suite. Packs `questions_per_batch = B / N_OPTIONS` questions
/// per eval_rows call (each option one row; VLM rows replicate the
/// question's patches).
pub fn score_suite(session: &Session, suite: &Suite) -> Result<f64> {
    let m = &session.bundle.manifest;
    let b = m.batch_size;
    let t = m.seq_len;
    ensure!(b % N_OPTIONS == 0, "batch_size {b} must be a multiple of {N_OPTIONS}");
    let qpb = b / N_OPTIONS;
    let is_vlm = m.is_vlm();
    let patch_len = m.n_patches * m.patch_dim;

    let mut correct = 0usize;
    let mut total = 0usize;
    let mut qi = 0usize;
    while qi < suite.questions.len() {
        let chunk: Vec<&McQuestion> =
            suite.questions[qi..(qi + qpb).min(suite.questions.len())].iter().collect();
        let mut batch = Batch::default();
        for q in &chunk {
            for opt in &q.options {
                let (tok, tgt) = seq_to_row(opt, t);
                batch.tokens.extend_from_slice(&tok);
                batch.targets.extend_from_slice(&tgt);
                if is_vlm {
                    batch.patches.extend_from_slice(q.patches.as_ref().unwrap());
                }
            }
        }
        // pad out to full batch with masked rows
        let rows = chunk.len() * N_OPTIONS;
        for _ in rows..b {
            batch.tokens.extend(std::iter::repeat(0).take(t));
            batch.targets.extend(std::iter::repeat(-1).take(t));
            if is_vlm {
                batch.patches.extend(std::iter::repeat(0.0).take(patch_len));
            }
        }
        let per_row = session.eval_rows(&batch)?;
        for (ci, q) in chunk.iter().enumerate() {
            let mut best = (f64::INFINITY, 0usize);
            for o in 0..N_OPTIONS {
                let (loss, count) = per_row[ci * N_OPTIONS + o];
                let mean = if count > 0.0 { loss / count } else { f64::INFINITY };
                if mean < best.0 {
                    best = (mean, o);
                }
            }
            if best.1 == q.correct {
                correct += 1;
            }
            total += 1;
        }
        qi += chunk.len();
    }
    Ok(100.0 * correct as f64 / total.max(1) as f64)
}

/// Accuracy per suite, in order, plus the average — one Table-1 row.
pub fn score_suites(session: &Session, suites: &[Suite]) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    let mut sum = 0.0;
    for s in suites {
        let acc = score_suite(session, s)?;
        sum += acc;
        out.push((s.name.to_string(), acc));
    }
    out.push(("Avg.".to_string(), sum / suites.len().max(1) as f64));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seq_to_row_alignment() {
        let (tok, tgt) = seq_to_row(&[1, 5, 7, 2], 6);
        assert_eq!(tok, vec![1, 5, 7, 0, 0, 0]);
        assert_eq!(tgt, vec![5, 7, 2, -1, -1, -1]);
    }

    #[test]
    fn seq_to_row_truncates() {
        let ids: Vec<i32> = (0..20).collect();
        let (tok, tgt) = seq_to_row(&ids, 4);
        assert_eq!(tok, vec![0, 1, 2, 3]);
        assert_eq!(tgt, vec![1, 2, 3, 4]);
    }
}
