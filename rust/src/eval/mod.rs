//! Benchmark suites + multiple-choice scoring harness.

pub mod benchmarks;
pub mod harness;
