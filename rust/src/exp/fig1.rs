//! Figure 1 + Figure 4a: per-component gradient dynamics.
//!
//! Fig 1: element-wise L1 norms of the Eq. 1 gradient-change matrix for
//! the 7 matrices of one layer, with the τ line.
//! Fig 4a: mean |∇W|₁ for attention vs MLP groups over training — the
//! observation (MLP 2–3× higher, attention converges first) that motivates
//! component-level stopping.
//!
//! One monitor-off job (probe every step) through the scheduler. The job
//! is *ephemeral*: its value is the full per-step metrics log, which the
//! run manifest doesn't persist, so it always re-runs. Component metadata
//! comes from the artifact's manifest.json directly — no bundle compile
//! just to read the layer table.

use anyhow::{Context, Result};

use super::{plan, scheduler, write_result, ExpOptions};
use crate::config::RepoConfig;
use crate::report::figures::ascii_chart;
use crate::runtime::backend::manifest_for;

/// Run the monitor-off probe-every-step job and render Figures 1/4a.
pub fn run(opts: &ExpOptions, config_name: &str, layer: usize) -> Result<()> {
    let cfg = RepoConfig::by_name(config_name)?;
    // Artifact dir for XLA configs, synthesized layout for host ones —
    // the same resolution the runner's engine cache applies.
    let m = manifest_for(opts.backend, &cfg)
        .with_context(|| format!("resolving backend for {config_name}"))?;
    let (graph, job) = plan::fig1_plan(config_name)?;
    let runner = scheduler::DeviceRunner::new(opts);
    let mut report = scheduler::execute(&graph, &opts.scheduler(), &runner)?;
    report.require_ok(&graph)?;
    let outcome = report.take_result(job)?.outcome;

    // --- Fig 1: the 7 matrices of `layer` + τ line ---
    let comps: Vec<_> = m
        .components
        .iter()
        .filter(|c| c.layer == layer && c.tower == "language")
        .collect();
    let mut series: Vec<(String, Vec<(f64, f64)>)> = comps
        .iter()
        .map(|c| {
            let pts = outcome
                .log
                .records
                .iter()
                .map(|r| (r.step as f64, r.gdiff[c.idx] as f64))
                .collect();
            (format!("W_{}", c.kind), pts)
        })
        .collect();
    let tau_line: Vec<(f64, f64)> = outcome
        .log
        .records
        .iter()
        .map(|r| (r.step as f64, cfg.grades.tau))
        .collect();
    series.push(("tau".to_string(), tau_line));
    let borrowed: Vec<(&str, Vec<(f64, f64)>)> =
        series.iter().map(|(n, p)| (n.as_str(), p.clone())).collect();
    let f1 = format!(
        "## Figure 1 — ‖∇W_t − ∇W_(t-1)‖₁ per component, layer {layer} ({config_name})\n\n```\n{}```\n",
        ascii_chart("Eq.1 gradient-change norm (log y)", &borrowed, 72, 16, true)
    );
    outcome.log.write_component_csv(
        &opts.out_dir.join("fig1_components.csv"),
        &m,
        layer,
        "language",
    )?;

    // --- Fig 4a: attention vs MLP group means of |∇W|₁ ---
    let attn = m.components_where(|c| c.group == "attention");
    let mlp = m.components_where(|c| c.group == "mlp");
    let mean_pts = |idxs: &[usize]| -> Vec<(f64, f64)> {
        outcome
            .log
            .records
            .iter()
            .map(|r| {
                (
                    r.step as f64,
                    idxs.iter().map(|&i| r.gabs[i] as f64).sum::<f64>() / idxs.len() as f64,
                )
            })
            .collect()
    };
    let attn_pts = mean_pts(&attn);
    let mlp_pts = mean_pts(&mlp);
    // the paper's headline ratio: MLP grads ~2-3x attention grads
    let ratio: f64 = {
        let sum_ratio: f64 = attn_pts
            .iter()
            .zip(&mlp_pts)
            .filter(|((_, a), _)| *a > 0.0)
            .map(|((_, a), (_, m))| m / a)
            .sum();
        sum_ratio / attn_pts.len().max(1) as f64
    };
    let f4a = format!(
        "## Figure 4a — mean |∇W|₁: attention vs MLP ({config_name})\n\n\
         Mean MLP/attention gradient-norm ratio over training: **{ratio:.2}x** \
         (paper reports 2–3x).\n\n```\n{}```\n",
        ascii_chart(
            "mean |grad|_1 per group (log y)",
            &[("attention", attn_pts), ("mlp", mlp_pts)],
            72,
            14,
            true,
        )
    );
    // --- EB overlay: the evidence the EB criterion would read from the
    // same probes, e = 1 − 2·(Gabs/Gdiff)² per group mean. Negative while
    // the gradient carries signal, crossing 0 (the stop line) as it
    // degenerates to sampling noise — rendered linear, not log.
    let ev_pts = |idxs: &[usize]| -> Vec<(f64, f64)> {
        outcome
            .log
            .records
            .iter()
            .map(|r| {
                let e = idxs
                    .iter()
                    .map(|&i| {
                        let ratio = r.gabs[i] as f64 / (r.gdiff[i] as f64).max(1e-30);
                        1.0 - 2.0 * ratio * ratio
                    })
                    .sum::<f64>()
                    / idxs.len().max(1) as f64;
                (r.step as f64, e)
            })
            .collect()
    };
    let zero_line: Vec<(f64, f64)> =
        outcome.log.records.iter().map(|r| (r.step as f64, 0.0)).collect();
    let feb = format!(
        "## EB-criterion overlay — evidence per group ({config_name})\n\n\
         Mahsereci–Lassner evidence from the same Eq. 1 probes GradES reads \
         (fallback estimate, no gvar block): stop once a component's curve \
         crosses 0.\n\n```\n{}```\n",
        ascii_chart(
            "EB evidence 1 - 2(|g|/|dg|)^2 (linear y)",
            &[
                ("attention", ev_pts(&attn)),
                ("mlp", ev_pts(&mlp)),
                ("e=0", zero_line),
            ],
            72,
            14,
            false,
        )
    );
    outcome.log.write_group_mean_csv(
        &opts.out_dir.join("fig4a_groups.csv"),
        &m,
        &[("attention", attn), ("mlp", mlp)],
    )?;

    println!("\n{f1}\n{f4a}\n{feb}");
    write_result(opts, "fig1_components.md", &f1)?;
    write_result(opts, "fig4a_groups.md", &f4a)?;
    write_result(opts, "fig_eb_evidence.md", &feb)?;
    outcome.log.write_loss_csv(&opts.out_dir.join(format!("{config_name}_loss.csv")))?;
    Ok(())
}
