//! Fault-tolerant coordinator for multi-process grid execution.
//!
//! `grades repro --workers M` splits a run at the process boundary: this
//! module is the **coordinator** — it owns the [`JobGraph`], the
//! `run_manifest.json`, and all scheduling state — and `grades worker`
//! processes (see [`super::worker`]) execute jobs one at a time over the
//! newline-framed JSON protocol in [`super::wire`]. Each worker owns its
//! own `EngineCache` (host engine or PJRT client), so device work
//! parallelizes across processes instead of serializing behind the
//! in-process device token.
//!
//! # Robustness model
//!
//! Robustness is the design center, not a bolt-on:
//!
//! - **Leases + heartbeats.** An assigned job is a time-limited lease.
//!   The worker renews it by heartbeating every `heartbeat_ms`; the
//!   coordinator's tick loop treats a lease that reaches its deadline as
//!   a dead worker — the process is killed and its job requeued.
//! - **Bounded retry.** A failed attempt (clean `failed` frame, worker
//!   EOF/crash, expired lease, protocol garbage) sends the job into
//!   exponential backoff and later reassignment, up to
//!   [`RetryPolicy::max_attempts`] total executions; exhaustion marks
//!   the job failed and skips its transitive dependents, exactly like
//!   the in-process pool. Attempt counts and last-failure reasons are
//!   recorded in the manifest's fault ledger as they happen.
//! - **Stale-frame rejection.** Every `done`/`failed`/`heartbeat` frame
//!   is checked against the current lease owner: a late `done` from a
//!   presumed-dead worker whose job was already reassigned is ignored,
//!   so a job can never double-record.
//! - **Coordinator crash recovery.** The manifest is saved atomically
//!   after every completion, and scheduling state is *derived*, never
//!   persisted — a killed-and-restarted coordinator rebuilds from
//!   `run_manifest.json` through the same resume pre-pass as the
//!   in-process pool and re-runs only unfinished jobs.
//! - **Graceful degradation.** Graphs the protocol cannot carry
//!   (standalone eval jobs need in-memory weight handoff; ephemeral
//!   jobs need full metrics logs) and environments where no worker can
//!   be spawned fall back to the in-process pool: `--jobs N` semantics
//!   are unchanged.
//!
//! # Determinism
//!
//! A job's numbers depend only on its spec (the wire carries the full
//! spec, and warm starts replay through the warmstart disk cache), so a
//! distributed run's tables are byte-identical to `--jobs 1` — the fault
//! suite's core assertion, exercised end-to-end in
//! `tests/coordinator.rs` with `GRADES_FAULT` injection.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::plan::{JobGraph, JobId, JobKind};
use super::scheduler::{
    resume_prepass, FaultRecord, JobStatus, RetryPolicy, RunManifest, RunReport, SchedulerOptions,
};
use super::wire::{ToCoordinator, ToWorker, WireJob, WorkerInit};

// ---------------------------------------------------------------------------
// Options
// ---------------------------------------------------------------------------

/// Coordinator/worker runtime knobs (`SchedulerOptions::grid`).
#[derive(Debug, Clone)]
pub struct GridOptions {
    /// Command line spawned per worker. `None` means the current
    /// executable with a `worker` argument — the `grades worker`
    /// subcommand. Tests point this at `CARGO_BIN_EXE_grades`.
    pub worker_cmd: Option<Vec<String>>,
    /// Lease duration: a running job whose worker has not heartbeat for
    /// this long is presumed dead and requeued.
    pub lease_ms: u64,
    /// Heartbeat cadence workers are told to hold (must be well under
    /// `lease_ms`).
    pub heartbeat_ms: u64,
    /// How many *replacement* workers may be spawned over the run's
    /// lifetime (beyond the initial `--workers` pool) before the
    /// coordinator gives up on dead slots.
    pub max_respawns: usize,
    /// Fault-injection spec forwarded to workers as `GRADES_FAULT`
    /// (see [`super::fault::FaultSpec`]).
    pub fault: Option<String>,
    /// Run workers in deterministic mock mode (`GRADES_MOCK_JOBS=1`) —
    /// the fault-test harness; `None` for real execution.
    pub mock: Option<MockOptions>,
    /// Run-wide `[run].total_steps` override, forwarded in `init`.
    pub steps_override: Option<usize>,
    /// Questions per benchmark suite, forwarded in `init`.
    pub questions: usize,
    /// Benchmark-suite RNG seed, forwarded in `init`.
    pub bench_seed: u64,
}

impl Default for GridOptions {
    fn default() -> Self {
        GridOptions {
            worker_cmd: None,
            lease_ms: 10_000,
            heartbeat_ms: 2_500,
            max_respawns: 8,
            fault: None,
            mock: None,
            steps_override: None,
            questions: 32,
            bench_seed: 0xbe9c,
        }
    }
}

/// Mock-mode knobs for spawned workers (fault-injection tests only).
#[derive(Debug, Clone)]
pub struct MockOptions {
    /// Fixed per-job sleep, in milliseconds (gives leases something to
    /// expire over).
    pub sleep_ms: u64,
    /// Append-only execution log shared by all workers — how tests
    /// observe which process executed which job.
    pub log: Option<PathBuf>,
}

/// What [`try_execute`] did with the graph.
pub enum Dispatch {
    /// The coordinator runtime ran the graph to completion.
    Ran(RunReport),
    /// The graph or environment can't use worker processes; the caller
    /// should run on the in-process pool (the string says why).
    Fallback(String),
}

// ---------------------------------------------------------------------------
// Core state machine (no I/O — deterministic, unit-tested)
// ---------------------------------------------------------------------------

/// Lease/retry state of one job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JState {
    /// Blocked on unresolved dependencies.
    Waiting,
    /// Assignable.
    Ready,
    /// A failed attempt is cooling down; assignable once `until` passes.
    Backoff { until: Instant },
    /// Leased to `worker` until `deadline` (renewed by heartbeats).
    Running { worker: usize, deadline: Instant },
    /// Done / failed / skipped — a final status is recorded.
    Resolved,
}

/// State of one worker slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WState {
    /// Spawned, no `claim` yet.
    Starting,
    /// Ready for an assignment.
    Idle,
    /// Holds the lease on a job.
    Busy(JobId),
    /// Exited, crashed, or presumed dead (expired lease / protocol
    /// fault). Slots are never reused — replacements get fresh indices,
    /// which is what makes a `GRADES_FAULT` spec fire at most once.
    Dead,
}

/// What a failed attempt turned into.
enum AttemptOutcome {
    /// The job is in backoff and will be reassigned.
    Retry { attempt: usize },
    /// The retry budget is exhausted; the job (and its dependents) are
    /// resolved as failed/skipped.
    Exhausted { attempts: usize },
}

/// The coordinator's scheduling brain: job lease states, worker slots,
/// attempt counts, dependency bookkeeping. Pure state — every transition
/// takes `now` as an argument and performs no I/O, so the lease and race
/// edge cases are unit-testable without processes or clocks.
struct Core<'g> {
    graph: &'g JobGraph,
    children: Vec<Vec<JobId>>,
    retry: RetryPolicy,
    lease: Duration,
    statuses: Vec<Option<JobStatus>>,
    jstates: Vec<JState>,
    waiting: Vec<usize>,
    attempts: Vec<usize>,
    workers: Vec<WState>,
    remaining: usize,
}

impl<'g> Core<'g> {
    fn new(
        graph: &'g JobGraph,
        children: Vec<Vec<JobId>>,
        initial: Vec<Option<JobStatus>>,
        retry: RetryPolicy,
        lease: Duration,
    ) -> Self {
        let n = graph.len();
        let mut jstates = vec![JState::Waiting; n];
        let mut waiting = vec![0usize; n];
        let mut remaining = 0;
        for (i, spec) in graph.jobs.iter().enumerate() {
            if initial[i].is_some() {
                jstates[i] = JState::Resolved;
                continue;
            }
            remaining += 1;
            waiting[i] = spec.deps.iter().filter(|&&d| initial[d].is_none()).count();
            if waiting[i] == 0 {
                jstates[i] = JState::Ready;
            }
        }
        Core {
            graph,
            children,
            retry,
            lease,
            statuses: initial,
            jstates,
            waiting,
            attempts: vec![0; n],
            workers: Vec::new(),
            remaining,
        }
    }

    /// Register a new worker slot (fresh index, never reused).
    fn add_worker(&mut self) -> usize {
        self.workers.push(WState::Starting);
        self.workers.len() - 1
    }

    fn on_claim(&mut self, w: usize) {
        if matches!(self.workers[w], WState::Starting) {
            self.workers[w] = WState::Idle;
        }
    }

    /// Does worker `w` currently hold `job`'s lease? Gate for
    /// `done`/`failed` frames: a late frame from a presumed-dead worker
    /// whose job moved on fails this and is ignored.
    fn owns(&self, w: usize, job: JobId) -> bool {
        matches!(self.jstates[job], JState::Running { worker, .. } if worker == w)
    }

    /// Renew `job`'s lease (ignored unless `w` still owns it).
    fn on_heartbeat(&mut self, w: usize, job: JobId, now: Instant) {
        if self.owns(w, job) {
            self.jstates[job] = JState::Running { worker: w, deadline: now + self.lease };
        }
    }

    /// Release `w` back to the idle pool after its job resolved.
    fn finish_worker(&mut self, w: usize) {
        if matches!(self.workers[w], WState::Busy(_)) {
            self.workers[w] = WState::Idle;
        }
    }

    /// Record a final status and unblock (or transitively skip)
    /// dependents. Mirrors the in-process pool's `complete`.
    fn resolve(&mut self, id: JobId, status: JobStatus) {
        debug_assert!(self.statuses[id].is_none(), "job resolved twice");
        let failed = matches!(status, JobStatus::Failed(_));
        self.statuses[id] = Some(status);
        self.jstates[id] = JState::Resolved;
        self.remaining -= 1;
        if failed {
            let mut stack = self.children[id].clone();
            while let Some(c) = stack.pop() {
                if self.statuses[c].is_none() {
                    self.statuses[c] = Some(JobStatus::Skipped(format!(
                        "dependency {:?} failed",
                        self.graph.get(id).id
                    )));
                    self.jstates[c] = JState::Resolved;
                    self.remaining -= 1;
                    stack.extend(self.children[c].iter().copied());
                }
            }
        } else {
            for i in 0..self.children[id].len() {
                let c = self.children[id][i];
                if self.statuses[c].is_none() {
                    self.waiting[c] -= 1;
                    if self.waiting[c] == 0 {
                        self.jstates[c] = JState::Ready;
                    }
                }
            }
        }
    }

    /// One execution of `job` failed (clean error, dead worker, expired
    /// lease, protocol fault — all the same to the budget). Either backs
    /// the job off for a later reassignment or, with the budget spent,
    /// resolves it as failed.
    fn on_attempt_failed(&mut self, job: JobId, error: &str, now: Instant) -> AttemptOutcome {
        let a = self.attempts[job].max(1);
        if a >= self.retry.max_attempts.max(1) {
            self.resolve(job, JobStatus::Failed(error.to_string()));
            AttemptOutcome::Exhausted { attempts: a }
        } else {
            self.jstates[job] = JState::Backoff { until: now + self.retry.delay(a) };
            AttemptOutcome::Retry { attempt: a }
        }
    }

    /// Mark worker `w` dead; returns the job whose lease it held, if
    /// any, for the caller to route through [`Self::on_attempt_failed`].
    /// Idempotent — the eventual EOF after a kill is a no-op.
    fn on_worker_dead(&mut self, w: usize) -> Option<JobId> {
        let was = self.workers[w];
        self.workers[w] = WState::Dead;
        match was {
            WState::Busy(j) if self.owns(w, j) => Some(j),
            _ => None,
        }
    }

    /// Leases that have reached their deadline: (worker, job) pairs whose
    /// workers are presumed dead.
    fn expired(&self, now: Instant) -> Vec<(usize, JobId)> {
        (0..self.jstates.len())
            .filter_map(|j| match self.jstates[j] {
                JState::Running { worker, deadline } if deadline <= now => Some((worker, j)),
                _ => None,
            })
            .collect()
    }

    /// Pair ready jobs (plan order — determinism) with idle workers
    /// (slot order), starting their leases and burning an attempt each.
    fn assignments(&mut self, now: Instant) -> Vec<(usize, JobId, usize)> {
        for j in 0..self.jstates.len() {
            if let JState::Backoff { until } = self.jstates[j] {
                if until <= now {
                    self.jstates[j] = JState::Ready;
                }
            }
        }
        let mut idle: VecDeque<usize> = self
            .workers
            .iter()
            .enumerate()
            .filter(|(_, s)| matches!(s, WState::Idle))
            .map(|(w, _)| w)
            .collect();
        let mut out = Vec::new();
        for j in 0..self.jstates.len() {
            if idle.is_empty() {
                break;
            }
            if self.jstates[j] == JState::Ready {
                let w = idle.pop_front().expect("non-empty");
                self.attempts[j] += 1;
                self.jstates[j] = JState::Running { worker: w, deadline: now + self.lease };
                self.workers[w] = WState::Busy(j);
                out.push((w, j, self.attempts[j]));
            }
        }
        out
    }

    /// The next instant something is scheduled to happen (a lease
    /// expiring or a backoff ending), as a wait from `now`.
    fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.jstates
            .iter()
            .filter_map(|s| match s {
                JState::Running { deadline, .. } => Some(*deadline),
                JState::Backoff { until } => Some(*until),
                _ => None,
            })
            .min()
            .map(|d| d.saturating_duration_since(now))
    }

    fn finished(&self) -> bool {
        self.remaining == 0
    }

    /// Unresolved jobs not currently running — work that wants a worker
    /// now or later (drives the respawn decision).
    fn pending(&self) -> usize {
        self.jstates
            .iter()
            .filter(|s| matches!(s, JState::Waiting | JState::Ready | JState::Backoff { .. }))
            .count()
    }

    fn live_workers(&self) -> usize {
        self.workers.iter().filter(|s| !matches!(s, WState::Dead)).count()
    }

    fn idle_workers(&self) -> usize {
        self.workers.iter().filter(|s| matches!(s, WState::Idle)).count()
    }

    /// Resolve every unresolved job as failed with `reason` (terminal
    /// degradation: no workers left and no respawn budget).
    fn fail_all_unresolved(&mut self, reason: &str) {
        for j in 0..self.jstates.len() {
            if self.statuses[j].is_none() {
                self.resolve(j, JobStatus::Failed(reason.to_string()));
            }
        }
    }

    fn into_report(self) -> RunReport {
        RunReport {
            statuses: self
                .statuses
                .into_iter()
                .map(|s| s.expect("every job resolved"))
                .collect(),
        }
    }
}

// ---------------------------------------------------------------------------
// Worker processes and their reader threads
// ---------------------------------------------------------------------------

/// What a reader thread observed on one worker's stdout.
enum Event {
    /// One protocol line.
    Line(String),
    /// The pipe closed — the worker exited or crashed.
    Eof,
}

/// The shared event queue reader threads feed and the tick loop drains.
struct Events {
    q: Mutex<VecDeque<(usize, Event)>>,
    cv: Condvar,
}

impl Events {
    fn push(&self, slot: usize, ev: Event) {
        let mut q = self.q.lock().unwrap_or_else(|p| p.into_inner());
        q.push_back((slot, ev));
        drop(q);
        self.cv.notify_all();
    }

    /// Drain everything queued, waiting up to `timeout` when empty.
    fn drain(&self, timeout: Duration) -> Vec<(usize, Event)> {
        let mut q = self.q.lock().unwrap_or_else(|p| p.into_inner());
        if q.is_empty() {
            let (g, _) = self
                .cv
                .wait_timeout(q, timeout)
                .unwrap_or_else(|p| p.into_inner());
            q = g;
        }
        q.drain(..).collect()
    }
}

struct WorkerProc {
    child: Child,
    stdin: Option<ChildStdin>,
}

/// Spawn one worker process on `slot`, wire its stdout into `events`
/// through a reader thread, and send the `init` frame.
fn spawn_worker(
    slot: usize,
    opts: &SchedulerOptions,
    events: &Arc<Events>,
    readers: &mut Vec<std::thread::JoinHandle<()>>,
) -> Result<WorkerProc> {
    let default_cmd;
    let cmd: &[String] = match &opts.grid.worker_cmd {
        Some(c) => c,
        None => {
            let exe = std::env::current_exe().context("resolving current executable")?;
            default_cmd = vec![exe.to_string_lossy().into_owned(), "worker".to_string()];
            &default_cmd
        }
    };
    anyhow::ensure!(!cmd.is_empty(), "empty worker command");
    let mut command = Command::new(&cmd[0]);
    command
        .args(&cmd[1..])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        // worker diagnostics interleave with ours on stderr; stdout is
        // reserved for protocol frames
        .stderr(Stdio::inherit())
        .env("GRADES_WORKER_INDEX", slot.to_string())
        // the child's environment is set explicitly from the options —
        // never inherited — so tests and nested runs can't leak specs in
        .env_remove("GRADES_FAULT")
        .env_remove("GRADES_MOCK_JOBS")
        .env_remove("GRADES_MOCK_SLEEP_MS")
        .env_remove("GRADES_MOCK_LOG")
        .env_remove("GRADES_WORKERS")
        .env_remove("GRADES_JOBS");
    if let Some(f) = &opts.grid.fault {
        command.env("GRADES_FAULT", f);
    }
    if let Some(m) = &opts.grid.mock {
        command.env("GRADES_MOCK_JOBS", "1");
        command.env("GRADES_MOCK_SLEEP_MS", m.sleep_ms.to_string());
        if let Some(log) = &m.log {
            command.env("GRADES_MOCK_LOG", log.as_os_str());
        }
    }
    let mut child = command.spawn().with_context(|| format!("spawning worker {slot} ({:?})", cmd[0]))?;

    let stdout = child.stdout.take().expect("stdout piped");
    let ev = events.clone();
    readers.push(std::thread::spawn(move || {
        for line in BufReader::new(stdout).lines() {
            match line {
                Ok(l) => ev.push(slot, Event::Line(l)),
                Err(_) => break,
            }
        }
        ev.push(slot, Event::Eof);
    }));

    let mut proc = WorkerProc { stdin: child.stdin.take(), child };
    let init = ToWorker::Init(WorkerInit {
        steps_override: opts.grid.steps_override,
        questions: opts.grid.questions,
        bench_seed: opts.grid.bench_seed,
        backend: opts.backend,
        settings: opts.settings.clone(),
        heartbeat_ms: opts.grid.heartbeat_ms.max(1),
    });
    send(&mut proc, &init).with_context(|| format!("sending init to worker {slot}"))?;
    Ok(proc)
}

fn send(proc: &mut WorkerProc, frame: &ToWorker) -> std::io::Result<()> {
    let stdin = proc
        .stdin
        .as_mut()
        .ok_or_else(|| std::io::Error::from(std::io::ErrorKind::BrokenPipe))?;
    let mut line = frame.render();
    line.push('\n');
    stdin.write_all(line.as_bytes())?;
    stdin.flush()
}

/// SIGKILL + reap a worker (used for expired leases and protocol faults;
/// errors ignored — the process may already be gone).
fn kill_and_reap(mut proc: WorkerProc) {
    drop(proc.stdin.take());
    let _ = proc.child.kill();
    let _ = proc.child.wait();
}

/// Reap a worker that should be exiting on its own (shutdown sent /
/// stdin closed), escalating to SIGKILL if it lingers.
fn reap(mut proc: WorkerProc) {
    for _ in 0..100 {
        match proc.child.try_wait() {
            Ok(Some(_)) => return,
            Ok(None) => std::thread::sleep(Duration::from_millis(20)),
            Err(_) => break,
        }
    }
    let _ = proc.child.kill();
    let _ = proc.child.wait();
}

// ---------------------------------------------------------------------------
// The tick loop
// ---------------------------------------------------------------------------

/// Run `graph` on worker processes if it is distributable and at least
/// one worker can be spawned; otherwise report why so the caller can
/// fall back to the in-process pool. Never errors on worker trouble —
/// that is the runtime's whole job — only on coordinator-side bugs
/// (invalid graph).
pub fn try_execute(graph: &JobGraph, opts: &SchedulerOptions) -> Result<Dispatch> {
    graph.validate()?;
    // Distributable gate: the wire carries specs and summaries, not
    // in-memory weights or full metrics logs.
    for spec in &graph.jobs {
        if spec.kind == JobKind::Eval {
            return Ok(Dispatch::Fallback(format!(
                "job {:?} is a standalone eval job (needs in-memory weight handoff)",
                spec.id
            )));
        }
        if spec.kind == JobKind::Train && !spec.persist {
            return Ok(Dispatch::Fallback(format!(
                "job {:?} is ephemeral (its full metrics log cannot cross the wire)",
                spec.id
            )));
        }
    }

    let children = graph.children();
    let prepass = resume_prepass(graph, &children, opts);
    let lease = Duration::from_millis(opts.grid.lease_ms.max(1));
    let mut core = Core::new(graph, children, prepass.statuses, opts.retry, lease);
    let mut manifest = prepass.manifest;
    if core.finished() {
        // everything resumed from the manifest — no processes needed
        return Ok(Dispatch::Ran(core.into_report()));
    }

    let name_to_id: HashMap<&str, JobId> = graph
        .jobs
        .iter()
        .enumerate()
        .map(|(i, s)| (s.id.as_str(), i))
        .collect();
    let events = Arc::new(Events { q: Mutex::new(VecDeque::new()), cv: Condvar::new() });
    let mut readers = Vec::new();
    let mut procs: HashMap<usize, WorkerProc> = HashMap::new();

    let target = opts.workers.min(core.remaining).max(1);
    let mut spawn_failures = 0usize;
    for _ in 0..target {
        let slot = core.add_worker();
        match spawn_worker(slot, opts, &events, &mut readers) {
            Ok(p) => {
                procs.insert(slot, p);
            }
            Err(e) => {
                core.on_worker_dead(slot);
                spawn_failures += 1;
                eprintln!("[coordinator] worker {slot} failed to spawn: {e:#}");
            }
        }
    }
    if procs.is_empty() {
        for h in readers {
            let _ = h.join();
        }
        return Ok(Dispatch::Fallback(format!(
            "no worker processes could be spawned ({spawn_failures} attempt(s) failed)"
        )));
    }
    if opts.verbose {
        println!(
            "[coordinator] {} job(s) to run on {} worker process(es), lease {:?}",
            core.remaining,
            procs.len(),
            lease
        );
    }

    let budget = opts.retry.max_attempts.max(1);
    let mut respawns_used = 0usize;

    // One failed attempt: fault ledger + backoff-or-exhaust + logging.
    let fail_attempt = |core: &mut Core<'_>,
                        manifest: &mut RunManifest,
                        id: JobId,
                        error: String,
                        now: Instant| {
        let jid = &core.graph.get(id).id;
        manifest
            .faults
            .insert(jid.clone(), FaultRecord { attempts: core.attempts[id], last_error: error.clone() });
        if let Some(p) = &opts.manifest_path {
            let _ = manifest.save(p);
        }
        match core.on_attempt_failed(id, &error, now) {
            AttemptOutcome::Retry { attempt } => eprintln!(
                "[coordinator] {jid} attempt {attempt}/{budget} failed: {error}; will reassign"
            ),
            AttemptOutcome::Exhausted { attempts } => {
                eprintln!("[{jid}] FAILED after {attempts} attempt(s): {error}")
            }
        }
    };

    while !core.finished() {
        // 1. Drain worker frames (blocking up to the next lease/backoff
        //    deadline, capped so child death is never waited on long).
        let now = Instant::now();
        let timeout = core
            .next_deadline(now)
            .unwrap_or(Duration::from_millis(200))
            .min(Duration::from_millis(200))
            .max(Duration::from_millis(1));
        for (slot, ev) in events.drain(timeout) {
            let now = Instant::now();
            match ev {
                Event::Eof => {
                    if let Some(p) = procs.remove(&slot) {
                        reap(p);
                    }
                    if let Some(job) = core.on_worker_dead(slot) {
                        fail_attempt(
                            &mut core,
                            &mut manifest,
                            job,
                            format!("worker {slot} exited while running the job"),
                            now,
                        );
                    }
                }
                Event::Line(line) => match ToCoordinator::parse(&line) {
                    Err(e) => {
                        // Protocol fault: kill the worker, requeue its job.
                        eprintln!(
                            "[coordinator] worker {slot} sent a garbled frame ({e:#}); killing it"
                        );
                        if let Some(p) = procs.remove(&slot) {
                            kill_and_reap(p);
                        }
                        if let Some(job) = core.on_worker_dead(slot) {
                            fail_attempt(
                                &mut core,
                                &mut manifest,
                                job,
                                format!("worker {slot} protocol fault: {e:#}"),
                                now,
                            );
                        }
                    }
                    Ok(ToCoordinator::Hello { pid, index }) => {
                        if opts.verbose {
                            println!("[coordinator] worker {index} up (pid {pid})");
                        }
                    }
                    Ok(ToCoordinator::Claim) => core.on_claim(slot),
                    Ok(ToCoordinator::Heartbeat { job }) => {
                        if let Some(&id) = name_to_id.get(job.as_str()) {
                            core.on_heartbeat(slot, id, now);
                        }
                    }
                    Ok(ToCoordinator::Done { job, summary }) => {
                        let Some(&id) = name_to_id.get(job.as_str()) else {
                            continue;
                        };
                        if !core.owns(slot, id) {
                            // Late frame from a presumed-dead worker whose
                            // job was requeued: must not double-record.
                            eprintln!(
                                "[coordinator] ignoring stale done for {job:?} from worker {slot}"
                            );
                            continue;
                        }
                        core.finish_worker(slot);
                        let spec = graph.get(id);
                        let needs_summary = spec.kind == JobKind::Train && spec.persist;
                        if !needs_summary {
                            if manifest.faults.remove(&spec.id).is_some() {
                                if let Some(p) = &opts.manifest_path {
                                    let _ = manifest.save(p);
                                }
                            }
                            core.resolve(
                                id,
                                JobStatus::Done { result: None, summary: None, resumed: false },
                            );
                            if opts.verbose {
                                println!("[{}] done (worker {slot})", spec.id);
                            }
                            continue;
                        }
                        match summary {
                            None => fail_attempt(
                                &mut core,
                                &mut manifest,
                                id,
                                format!("worker {slot} sent done without the required summary"),
                                now,
                            ),
                            Some(mut sm) => {
                                sm.attempts = core.attempts[id];
                                match sm.to_result() {
                                    Err(e) => fail_attempt(
                                        &mut core,
                                        &mut manifest,
                                        id,
                                        format!("worker {slot} sent an unusable summary: {e:#}"),
                                        now,
                                    ),
                                    Ok(r) => {
                                        manifest.jobs.insert(spec.id.clone(), sm.clone());
                                        manifest.faults.remove(&spec.id);
                                        if let Some(p) = &opts.manifest_path {
                                            if let Err(e) = manifest.save(p) {
                                                eprintln!(
                                                    "[coordinator] run-manifest save failed: {e:#}"
                                                );
                                            }
                                        }
                                        if opts.verbose {
                                            println!("[{}] done (worker {slot})", spec.id);
                                        }
                                        core.resolve(
                                            id,
                                            JobStatus::Done {
                                                result: Some(r),
                                                summary: Some(sm),
                                                resumed: false,
                                            },
                                        );
                                    }
                                }
                            }
                        }
                    }
                    Ok(ToCoordinator::Failed { job, error }) => {
                        let Some(&id) = name_to_id.get(job.as_str()) else {
                            continue;
                        };
                        if !core.owns(slot, id) {
                            eprintln!(
                                "[coordinator] ignoring stale failure for {job:?} from worker {slot}"
                            );
                            continue;
                        }
                        core.finish_worker(slot);
                        fail_attempt(&mut core, &mut manifest, id, error, now);
                    }
                },
            }
        }
        if core.finished() {
            break;
        }

        // 2. Expire leases: presumed-dead workers are killed and their
        //    jobs requeued through the retry budget.
        let now = Instant::now();
        for (w, job) in core.expired(now) {
            eprintln!(
                "[coordinator] lease on {:?} expired; presuming worker {w} dead",
                graph.get(job).id
            );
            if let Some(p) = procs.remove(&w) {
                kill_and_reap(p);
            }
            core.on_worker_dead(w);
            fail_attempt(
                &mut core,
                &mut manifest,
                job,
                format!("lease expired (worker {w} stopped heartbeating)"),
                now,
            );
        }

        // 3. Respawn replacements while there is pending work beyond
        //    what idle workers cover, within the respawn budget.
        while core.live_workers() < target
            && core.pending() > core.idle_workers()
            && respawns_used < opts.grid.max_respawns
        {
            respawns_used += 1;
            let slot = core.add_worker();
            match spawn_worker(slot, opts, &events, &mut readers) {
                Ok(p) => {
                    if opts.verbose {
                        println!("[coordinator] spawned replacement worker {slot}");
                    }
                    procs.insert(slot, p);
                }
                Err(e) => {
                    core.on_worker_dead(slot);
                    eprintln!("[coordinator] replacement worker {slot} failed to spawn: {e:#}");
                }
            }
        }
        if core.live_workers() == 0 {
            core.fail_all_unresolved(
                "no live workers remain and the respawn budget is exhausted",
            );
            break;
        }

        // 4. Hand ready jobs to idle workers.
        let now = Instant::now();
        for (w, job, attempt) in core.assignments(now) {
            let frame = ToWorker::Assign { job: WireJob::from_graph(graph, job), attempt };
            let ok = match procs.get_mut(&w) {
                Some(p) => send(p, &frame).is_ok(),
                None => false,
            };
            if opts.verbose && ok {
                println!(
                    "[coordinator] assigned {:?} to worker {w} (attempt {attempt})",
                    graph.get(job).id
                );
            }
            if !ok {
                // The pipe died under us: treat like any dead worker.
                if let Some(p) = procs.remove(&w) {
                    kill_and_reap(p);
                }
                if let Some(j) = core.on_worker_dead(w) {
                    fail_attempt(
                        &mut core,
                        &mut manifest,
                        j,
                        format!("worker {w} rejected an assignment (pipe closed)"),
                        now,
                    );
                }
            }
        }
    }

    // Drain: ask the survivors to exit, then reap everything.
    for (_, mut p) in procs.drain() {
        let _ = send(&mut p, &ToWorker::Shutdown);
        reap(p);
    }
    for h in readers {
        let _ = h.join();
    }

    let report = core.into_report();
    if opts.verbose {
        let (ran, resumed, failed, skipped) = report.counts();
        println!(
            "[coordinator] done: {ran} ran, {resumed} resumed, {failed} failed, {skipped} skipped"
        );
    }
    Ok(Dispatch::Ran(report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::StoppingMethod;
    use crate::exp::plan::{EvalKind, JobSpec};

    fn train(id: &str) -> JobSpec {
        JobSpec::train(id, "fake-cfg", StoppingMethod::GradEs, EvalKind::None)
    }

    fn core_for(graph: &JobGraph, retry: RetryPolicy) -> Core<'_> {
        let children = graph.children();
        let initial = (0..graph.len()).map(|_| None).collect();
        Core::new(graph, children, initial, retry, Duration::from_millis(100))
    }

    fn done() -> JobStatus {
        JobStatus::Done { result: None, summary: None, resumed: false }
    }

    #[test]
    fn late_done_after_lease_expiry_does_not_double_record() {
        let mut g = JobGraph::new();
        g.add(train("a")).unwrap();
        let mut core = core_for(&g, RetryPolicy::default());
        let w0 = core.add_worker();
        let w1 = core.add_worker();
        core.on_claim(w0);
        core.on_claim(w1);

        let t0 = Instant::now();
        let a = core.assignments(t0);
        assert_eq!(a.len(), 1);
        let (w, job, attempt) = a[0];
        assert_eq!((w, job, attempt), (w0, 0, 1));

        // heartbeat renews the lease...
        core.on_heartbeat(w0, job, t0 + Duration::from_millis(50));
        assert!(core.expired(t0 + Duration::from_millis(120)).is_empty());

        // ...then the worker goes silent and the lease expires
        let t1 = t0 + Duration::from_millis(500);
        assert_eq!(core.expired(t1), vec![(w0, job)]);
        assert_eq!(core.on_worker_dead(w0), Some(job));
        assert!(matches!(
            core.on_attempt_failed(job, "lease expired", t1),
            AttemptOutcome::Retry { attempt: 1 }
        ));

        // the presumed-dead worker's late done is stale — no ownership
        assert!(!core.owns(w0, job));

        // after backoff the job reassigns to the other worker
        let t2 = t1 + RetryPolicy::default().delay(1);
        let a = core.assignments(t2);
        assert_eq!(a.len(), 1);
        assert_eq!((a[0].0, a[0].1, a[0].2), (w1, job, 2));
        assert!(core.owns(w1, job) && !core.owns(w0, job));

        core.finish_worker(w1);
        core.resolve(job, done());
        assert!(core.finished());
        assert_eq!(core.attempts[job], 2);
        // resolving twice would be a bug, and owns() now rejects everyone
        assert!(!core.owns(w0, job) && !core.owns(w1, job));
    }

    #[test]
    fn two_workers_racing_for_one_job_get_one_assignment() {
        let mut g = JobGraph::new();
        g.add(train("only")).unwrap();
        let mut core = core_for(&g, RetryPolicy::default());
        let w0 = core.add_worker();
        let w1 = core.add_worker();
        core.on_claim(w0);
        core.on_claim(w1);
        let now = Instant::now();
        let a = core.assignments(now);
        assert_eq!(a.len(), 1, "one job, one lease");
        // the losing worker stays idle; a second pass hands out nothing
        assert!(core.assignments(now).is_empty());
        assert_eq!(core.idle_workers(), 1);
    }

    #[test]
    fn retry_exhaustion_fails_the_job_and_skips_dependents() {
        let mut g = JobGraph::new();
        let pre = g.add(JobSpec::pretrain("pre", "fake-cfg")).unwrap();
        g.add(train("ft").warm(pre)).unwrap();
        let retry = RetryPolicy { max_attempts: 2, backoff_base_ms: 0, backoff_max_ms: 0 };
        let mut core = core_for(&g, retry);
        let w0 = core.add_worker();
        core.on_claim(w0);
        let t = Instant::now();

        for expect_attempt in 1..=2 {
            let a = core.assignments(t);
            assert_eq!(a.len(), 1);
            assert_eq!(a[0].2, expect_attempt);
            core.finish_worker(w0);
            match core.on_attempt_failed(pre, "boom", t) {
                AttemptOutcome::Retry { attempt } => assert_eq!(attempt, 1),
                AttemptOutcome::Exhausted { attempts } => assert_eq!(attempts, 2),
            }
        }
        assert!(core.finished(), "failure skips the dependent transitively");
        assert!(matches!(core.statuses[pre], Some(JobStatus::Failed(_))));
        assert!(matches!(core.statuses[1], Some(JobStatus::Skipped(_))));
    }

    #[test]
    fn dependents_unblock_only_after_the_dep_resolves() {
        let mut g = JobGraph::new();
        let pre = g.add(JobSpec::pretrain("pre", "fake-cfg")).unwrap();
        g.add(train("ft").warm(pre)).unwrap();
        let mut core = core_for(&g, RetryPolicy::default());
        let w0 = core.add_worker();
        core.on_claim(w0);
        let t = Instant::now();
        let a = core.assignments(t);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].1, pre, "only the pretrain is ready");
        // nothing else to hand out while the dep runs
        assert!(core.assignments(t).is_empty());
        core.finish_worker(w0);
        core.resolve(pre, done());
        let a = core.assignments(t);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].1, 1, "the train job unblocked");
    }

    #[test]
    fn worker_eof_without_a_lease_is_harmless() {
        let mut g = JobGraph::new();
        g.add(train("a")).unwrap();
        let mut core = core_for(&g, RetryPolicy::default());
        let w0 = core.add_worker();
        core.on_claim(w0);
        assert_eq!(core.on_worker_dead(w0), None);
        // idempotent: the post-kill EOF is a no-op too
        assert_eq!(core.on_worker_dead(w0), None);
        assert_eq!(core.live_workers(), 0);
        assert!(!core.finished());
        core.fail_all_unresolved("no live workers");
        assert!(core.finished());
        assert!(matches!(core.statuses[0], Some(JobStatus::Failed(_))));
    }
}
