//! Deterministic fault injection for the coordinator/worker runtime.
//!
//! `GRADES_FAULT=<worker>:<kind>@<nth>` makes worker `<worker>` (its
//! `GRADES_WORKER_INDEX`) misbehave on its `<nth>` assignment (1-based):
//!
//! - `panic`   — panic on the worker's main thread (exit 101, EOF).
//! - `hang`    — stop heartbeating and sleep forever; the coordinator's
//!   lease expiry kills and replaces the worker.
//! - `sigkill` — SIGKILL the worker's own process mid-job (no unwind, no
//!   `failed` frame — the hard-crash case).
//! - `garble`  — write a non-JSON line to stdout before executing; the
//!   coordinator treats it as a protocol fault.
//!
//! Replacement workers get fresh indices past the initial pool, so a
//! fault spec targets at most one process per run — which is what makes
//! the fault tests deterministic.
//!
//! The module also hosts the [`MockJobRunner`]: a deterministic,
//! engine-free job executor shared by the in-process pool (tests pass it
//! to `scheduler::execute`) and the worker binary's mock mode
//! (`GRADES_MOCK_JOBS=1`). Both paths derive every result from
//! [`mock_summary`], so a distributed run's tables are byte-identical to
//! an in-process `--jobs 1` run of the same plan — the fault suite's
//! core assertion.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Result};

use super::plan::{JobKind, JobSpec};
use super::scheduler::{job_settings, EvalPayload, JobRunner, JobSummary, RunnerOutput};
use crate::coordinator::warmstart::BaseCheckpoint;
use crate::runtime::backend::BackendChoice;

/// What an injected fault does to its worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic on the worker's main thread.
    Panic,
    /// Stop heartbeating and sleep forever (lease-expiry path).
    Hang,
    /// SIGKILL the worker's own process (hard-crash path).
    Sigkill,
    /// Emit a garbled protocol line (protocol-fault path).
    Garble,
}

impl FaultKind {
    /// Stable spec label.
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::Hang => "hang",
            FaultKind::Sigkill => "sigkill",
            FaultKind::Garble => "garble",
        }
    }

    /// Inverse of [`FaultKind::label`].
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "panic" => Some(FaultKind::Panic),
            "hang" => Some(FaultKind::Hang),
            "sigkill" => Some(FaultKind::Sigkill),
            "garble" => Some(FaultKind::Garble),
            _ => None,
        }
    }
}

/// A parsed `GRADES_FAULT` spec: worker `worker` misbehaves with `kind`
/// on its `nth` (1-based) job assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    /// Target worker index (`GRADES_WORKER_INDEX`).
    pub worker: usize,
    /// What the worker does.
    pub kind: FaultKind,
    /// 1-based assignment count that triggers the fault.
    pub nth: usize,
}

impl FaultSpec {
    /// Parse `"<worker>:<kind>@<nth>"` (e.g. `"0:sigkill@2"`).
    pub fn parse(s: &str) -> Result<Self> {
        let (worker, rest) = match s.split_once(':') {
            Some(p) => p,
            None => bail!("fault spec {s:?} is not <worker>:<kind>@<nth>"),
        };
        let (kind, nth) = match rest.split_once('@') {
            Some(p) => p,
            None => bail!("fault spec {s:?} is not <worker>:<kind>@<nth>"),
        };
        let kind = match FaultKind::parse(kind) {
            Some(k) => k,
            None => bail!("fault spec {s:?}: kind must be panic|hang|sigkill|garble"),
        };
        let spec = FaultSpec { worker: worker.parse()?, kind, nth: nth.parse()? };
        if spec.nth == 0 {
            bail!("fault spec {s:?}: assignment counts are 1-based");
        }
        Ok(spec)
    }

    /// Does this spec fire for `worker`'s `assignment`-th job?
    pub fn fires(&self, worker: usize, assignment: usize) -> bool {
        self.worker == worker && self.nth == assignment
    }

    /// Render back to the spec grammar.
    pub fn render(&self) -> String {
        format!("{}:{}@{}", self.worker, self.kind.label(), self.nth)
    }
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// A deterministic fake summary for `spec` — every field a pure function
/// of the job id, so any two executions (in-process or across worker
/// processes, before or after a retry) produce the same bytes.
pub fn mock_summary(spec: &JobSpec, settings: &str, backend: BackendChoice) -> JobSummary {
    let h = fnv1a(&spec.id);
    let steps_run = 10 + (h % 90) as usize;
    let acc = (h % 10_000) as f64 / 100.0;
    JobSummary {
        id: spec.id.clone(),
        config: spec.config.clone(),
        settings: job_settings(spec, settings, backend),
        backend: backend.resolve(&spec.config).label().to_string(),
        method: spec.method.label().to_string(),
        steps_run,
        stop_cause: "budget".to_string(),
        // fixed, not measured: byte-identity across runs is the point
        wall_secs: (h % 1000) as f64 / 100.0,
        validation_secs: 0.0,
        monitor_secs: 0.0,
        final_val_loss: (h % 400) as f64 / 100.0,
        variant_swap_step: None,
        flops_spent: 0.0,
        flops_realized: 0.0,
        flops_dense: 0.0,
        flops_validation: 0.0,
        flops_steps: steps_run,
        n_components: 4,
        frozen: Vec::new(),
        accuracies: vec![("Suite".to_string(), acc), ("Avg.".to_string(), acc)],
        frozen_series: vec![(1, 0.0), (steps_run, 0.5)],
        tower_gabs: None,
        val_checks: 0,
        attempts: 1,
    }
}

/// Append one line to the shared mock execution log (`O_APPEND`, so
/// concurrent workers interleave whole lines). The log is how the fault
/// tests observe *which process actually executed which job*.
pub fn append_mock_log(path: &Path, line: &str) {
    let r = std::fs::OpenOptions::new().append(true).create(true).open(path);
    if let Ok(mut f) = r {
        let _ = f.write_all(format!("{line}\n").as_bytes());
    }
}

/// Engine-free [`JobRunner`]: results are derived from [`mock_summary`]
/// only, with an optional fixed per-job sleep (to give leases something
/// to expire over) and an optional append-only execution log.
pub struct MockJobRunner {
    /// Run-wide settings fingerprint (must match the executing
    /// `SchedulerOptions::settings` for resume to work).
    pub settings: String,
    /// Backend recorded in the summaries.
    pub backend: BackendChoice,
    /// Fixed sleep per job, in milliseconds.
    pub sleep_ms: u64,
    /// Append-only execution log (one line per executed job).
    pub log: Option<PathBuf>,
}

impl MockJobRunner {
    /// A runner matching `settings`/`backend`, no sleep, no log.
    pub fn new(settings: impl Into<String>, backend: BackendChoice) -> Self {
        MockJobRunner { settings: settings.into(), backend, sleep_ms: 0, log: None }
    }
}

impl JobRunner for MockJobRunner {
    fn run(
        &self,
        spec: &JobSpec,
        _warm: Option<Arc<BaseCheckpoint>>,
        _eval_src: Option<Arc<EvalPayload>>,
    ) -> Result<RunnerOutput> {
        if let Some(p) = &self.log {
            append_mock_log(p, &spec.id);
        }
        if self.sleep_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(self.sleep_ms));
        }
        match spec.kind {
            JobKind::Pretrain => Ok(RunnerOutput {
                result: None,
                summary: None,
                checkpoint: Some(Arc::new(BaseCheckpoint {
                    params: Default::default(),
                    source: spec.id.clone(),
                })),
                eval_payload: None,
            }),
            JobKind::Train => {
                let summary = mock_summary(spec, &self.settings, self.backend);
                // the result is the summary's round trip, so the
                // in-process pool renders exactly what a coordinator
                // rebuilding results from wire summaries renders
                let result = summary.to_result()?;
                Ok(RunnerOutput {
                    result: Some(result),
                    summary: spec.persist.then_some(summary),
                    checkpoint: None,
                    eval_payload: None,
                })
            }
            JobKind::Eval => bail!("{}: mock runner does not execute eval jobs", spec.id),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::StoppingMethod;
    use crate::exp::plan::EvalKind;

    #[test]
    fn fault_spec_round_trips_and_rejects_junk() {
        for s in ["0:panic@1", "2:hang@3", "1:sigkill@2", "0:garble@1"] {
            assert_eq!(FaultSpec::parse(s).unwrap().render(), s);
        }
        let f = FaultSpec::parse("1:sigkill@2").unwrap();
        assert!(f.fires(1, 2));
        assert!(!f.fires(1, 1));
        assert!(!f.fires(0, 2));
        for bad in ["", "panic@1", "0:panic", "0:explode@1", "0:panic@0", "x:panic@1"] {
            assert!(FaultSpec::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn mock_summary_is_deterministic_and_round_trips() {
        let spec =
            JobSpec::train("grid/a", "lm-tiny-fp", StoppingMethod::GradEs, EvalKind::LmSuites);
        let a = mock_summary(&spec, "S", BackendChoice::Host);
        let b = mock_summary(&spec, "S", BackendChoice::Host);
        assert_eq!(a, b);
        let r = a.to_result().unwrap();
        assert_eq!(r.accuracies, a.accuracies);
        // distinct jobs get distinct numbers
        let other =
            JobSpec::train("grid/b", "lm-tiny-fp", StoppingMethod::GradEs, EvalKind::LmSuites);
        assert_ne!(mock_summary(&other, "S", BackendChoice::Host).accuracies, a.accuracies);
    }
}
