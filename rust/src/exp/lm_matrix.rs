//! Tables 1 & 4 + Figure 3: the full LM method matrix.
//!
//! Runs {FP, LoRA} × {base, +ES, +GradES} across the three model scales,
//! reporting per-suite accuracy (Table 1 shape), training time / speedup /
//! FLOPs (Table 4 shape) and the cumulative frozen-fraction series
//! (Figure 3 shape). The matrix is a [`plan::lm_matrix_plan`] job graph:
//! one pretrain job per scale feeds its checkpoint (`Arc`'d host data) to
//! the six fine-tuning jobs of that scale, completed cells persist to the
//! run manifest, and the Figure 3 series renders from the persisted
//! per-job summaries so a resumed matrix still draws complete curves.

use anyhow::Result;

use super::{method_label, plan, scheduler, write_result, ExpOptions, JobResult};
use crate::coordinator::trainer::StoppingMethod;
use crate::report::figures::ascii_chart;
use crate::report::table::{pct, sci, secs, speedup, Table};
use crate::util::csv::CsvWriter;

/// The three model scales of Tables 1/4 (display, fp config, lora config).
pub const SCALES: [(&str, &str, &str); 3] = [
    // (display name, fp config, lora config)
    ("lm-tiny (0.12M)", "lm-tiny-fp", "lm-tiny-lora"),
    ("lm-small (0.9M)", "lm-small-fp", "lm-small-lora"),
    ("lm-base (3.1M)", "lm-base-fp", "lm-base-lora"),
];

/// Everything the LM-matrix renderers consume.
pub struct MatrixResults {
    /// (scale display, artifact method, job)
    pub jobs: Vec<(String, String, JobResult)>,
    /// (scale display, frozen-fraction series) for the FP+GradES runs.
    pub fig3_series: Vec<(String, Vec<(f64, f64)>)>,
}

/// Execute the matrix plan and collect per-cell results.
pub fn run_matrix(opts: &ExpOptions, scales: &[(&str, &str, &str)]) -> Result<MatrixResults> {
    let (graph, slots) = plan::lm_matrix_plan(scales)?;
    let runner = scheduler::DeviceRunner::new(opts);
    let mut report = scheduler::execute(&graph, &opts.scheduler(), &runner)?;
    report.require_ok(&graph)?;
    // Figure 3 series come from the persisted summaries (exact for both
    // freshly-run and resumed jobs — the in-memory log is not persisted).
    let mut fig3_series = Vec::new();
    for (display, am, id) in &slots.jobs {
        if am == "fp" && graph.get(*id).method == StoppingMethod::GradEs {
            let s = report.summary(*id)?;
            let pts = s.frozen_series.iter().map(|&(t, f)| (t as f64, f)).collect();
            fig3_series.push((display.clone(), pts));
        }
    }
    let mut jobs = Vec::new();
    for (display, am, id) in slots.jobs {
        jobs.push((display, am, report.take_result(id)?));
    }
    Ok(MatrixResults { jobs, fig3_series })
}

/// Render Table 1 (accuracy per suite) from matrix results.
pub fn render_table1(res: &MatrixResults) -> String {
    let suite_names: Vec<String> = res.jobs[0].2.accuracies.iter().map(|a| a.0.clone()).collect();
    let mut header: Vec<String> = vec!["Model".into(), "Method".into()];
    header.extend(suite_names);
    let mut t = Table::new(header);
    for (display, am, job) in &res.jobs {
        let mut row = vec![display.clone(), method_label(am, job.method)];
        row.extend(job.accuracies.iter().map(|a| pct(a.1)));
        t.row(row);
    }
    let avg_col = t.header.len() - 1;
    t.bold_best_by(0, avg_col);
    format!(
        "## Table 1 — accuracy (%) per method across model scales\n\n\
         Suites are the paper-benchmark analogues: AgreeDet≈BoolQ, AgreeAdj≈PIQA, \
         VerbSel≈SIQA, LongRange≈HellaSwag, AdvAssoc≈WinoGrande, WordOrder≈OpenBookQA, \
         RareComp≈ARC-C, FreqComp≈ARC-E.\n\n{}",
        t.render()
    )
}

/// Render Table 4 (time/FLOPs/speedup) from the same runs.
pub fn render_table4(res: &MatrixResults) -> String {
    let mut t = Table::new(vec![
        "Model", "Method", "Time (s)", "Speedup", "Steps", "FLOPs", "FLOPs Ratio", "Val (s)",
        "Monitor (s)",
    ]);
    // baseline per scale = the FP base run
    let mut base_time = std::collections::BTreeMap::new();
    let mut base_flops = std::collections::BTreeMap::new();
    for (display, am, job) in &res.jobs {
        if am == "fp" && job.method == StoppingMethod::None {
            base_time.insert(display.clone(), job.outcome.wall_secs);
            base_flops.insert(display.clone(), job.outcome.flops.total());
        }
    }
    for (display, am, job) in &res.jobs {
        let bt = base_time.get(display).copied().unwrap_or(f64::NAN);
        let bf = base_flops.get(display).copied().unwrap_or(f64::NAN);
        t.row(vec![
            display.clone(),
            method_label(am, job.method),
            secs(job.outcome.wall_secs),
            speedup(bt / job.outcome.wall_secs),
            job.outcome.steps_run.to_string(),
            sci(job.outcome.flops.total()),
            format!("{:.2}x", job.outcome.flops.total() / bf),
            secs(job.outcome.validation_secs),
            format!("{:.2}", job.outcome.monitor_secs),
        ]);
    }
    format!(
        "## Table 4 — training time & FLOPs (speedups relative to FP base per scale)\n\n{}",
        t.render()
    )
}

/// Figure 3: frozen-fraction curves of the FP+GradES runs across scales.
pub fn render_fig3(res: &MatrixResults, opts: &ExpOptions) -> Result<String> {
    // CSV
    std::fs::create_dir_all(&opts.out_dir)?;
    let mut w = CsvWriter::create(opts.out_dir.join("fig3_frozen_fraction.csv"),
                                   &["scale", "step", "frozen_fraction"])?;
    for (name, pts) in &res.fig3_series {
        for (s, f) in pts {
            w.row(&[name.clone(), s.to_string(), f.to_string()])?;
        }
    }
    w.flush()?;
    let borrowed: Vec<(&str, Vec<(f64, f64)>)> =
        res.fig3_series.iter().map(|(n, p)| (n.as_str(), p.clone())).collect();
    Ok(format!(
        "## Figure 3 — cumulative frozen components during training\n\n```\n{}```\n",
        ascii_chart("frozen fraction vs step (FP+GradES)", &borrowed, 70, 14, false)
    ))
}

/// The combined driver: tables 1 & 4 + figure 3 from one set of runs.
pub fn run(opts: &ExpOptions, scales: &[(&str, &str, &str)]) -> Result<MatrixResults> {
    let res = run_matrix(opts, scales)?;
    let t1 = render_table1(&res);
    let t4 = render_table4(&res);
    let f3 = render_fig3(&res, opts)?;
    println!("\n{t1}\n{t4}\n{f3}");
    write_result(opts, "table1_accuracy.md", &t1)?;
    write_result(opts, "table4_efficiency.md", &t4)?;
    write_result(opts, "fig3_frozen.md", &f3)?;
    // Machine-readable dump for downstream analysis
    let mut w = CsvWriter::create(
        opts.out_dir.join("lm_matrix.csv"),
        &["scale", "artifact_method", "stopping", "steps", "wall_secs", "val_secs",
          "monitor_secs", "flops", "avg_acc"],
    )?;
    for (display, am, job) in &res.jobs {
        let avg = job.accuracies.last().map(|a| a.1).unwrap_or(f64::NAN);
        w.row(&[
            display.clone(),
            am.clone(),
            job.method.label().to_string(),
            job.outcome.steps_run.to_string(),
            format!("{:.3}", job.outcome.wall_secs),
            format!("{:.3}", job.outcome.validation_secs),
            format!("{:.3}", job.outcome.monitor_secs),
            format!("{:.3e}", job.outcome.flops.total()),
            format!("{avg:.2}"),
        ])?;
    }
    w.flush()?;
    Ok(res)
}
