//! The `grades worker` process: claims jobs from a coordinator over
//! stdio and executes them with its own engines.
//!
//! Stdout is reserved for protocol frames ([`super::wire`], one JSON
//! line each; diagnostics go to stderr), stdin delivers coordinator
//! frames. The worker sends `hello`, waits for `init`, then loops:
//! `claim` → `assign` → execute → `done`/`failed`. While a job runs, a
//! background thread heartbeats every `heartbeat_ms` so the coordinator
//! keeps the job's lease alive; a worker that stops heartbeating — hung,
//! crashed, SIGKILLed — loses the lease and the coordinator reassigns
//! the job elsewhere.
//!
//! Exit conditions: a `shutdown` frame, or EOF on stdin (the coordinator
//! died — orphaned workers must not outlive their run).
//!
//! Two execution modes:
//! - **Real** (default): a [`DeviceRunner`] with this process's own
//!   `EngineCache` — host engine or PJRT client per the `init` frame's
//!   backend policy. Warm starts replay through the warmstart disk
//!   cache (always a hit: the coordinator assigns a warm job only after
//!   its pretrain completed).
//! - **Mock** (`GRADES_MOCK_JOBS=1`): the deterministic, engine-free
//!   [`MockJobRunner`] — the fault-injection test harness.
//!
//! Deterministic fault injection (`GRADES_FAULT`, see [`super::fault`])
//! makes this process panic, hang, SIGKILL itself, or garble a frame on
//! its Nth assignment.

use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::fault::{FaultKind, FaultSpec, MockJobRunner};
use super::scheduler::{DeviceRunner, JobRunner};
use super::wire::{ToCoordinator, ToWorker, WireJob};
use super::ExpOptions;

/// Write one protocol frame to stdout (whole line under the lock, so
/// heartbeat-thread frames never interleave with main-thread frames).
fn send(frame: &ToCoordinator) -> std::io::Result<()> {
    let mut line = frame.render();
    line.push('\n');
    let mut out = std::io::stdout().lock();
    out.write_all(line.as_bytes())?;
    out.flush()
}

/// Write a deliberately garbled line (the `garble` fault).
fn send_garbage(n: usize) -> std::io::Result<()> {
    let mut out = std::io::stdout().lock();
    out.write_all(format!("@@@ injected garble on assignment {n}\n").as_bytes())?;
    out.flush()
}

fn env_usize(name: &str) -> Option<usize> {
    std::env::var(name).ok().and_then(|v| v.trim().parse().ok())
}

/// Carry out an injected fault. `Panic`/`Sigkill`/`Hang` never return.
fn enact(kind: FaultKind, n: usize, hb_enabled: &AtomicBool) {
    eprintln!("[worker] injecting fault {:?} on assignment {n}", kind.label());
    match kind {
        FaultKind::Panic => panic!("injected fault: panic on assignment {n}"),
        FaultKind::Hang => {
            // Stop renewing the lease but stay alive: the coordinator
            // must detect this via lease expiry, not EOF.
            hb_enabled.store(false, Ordering::SeqCst);
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        FaultKind::Sigkill => {
            // A hard crash: no unwind, no farewell frame. SIGKILL can't
            // be raised portably from std, so ask the system's kill(1);
            // abort() is the (SIGABRT) fallback — equally frame-less.
            let pid = std::process::id().to_string();
            let _ = std::process::Command::new("/bin/kill").args(["-9", &pid]).status();
            std::process::abort();
        }
        FaultKind::Garble => {
            if send_garbage(n).is_err() {
                std::process::exit(1);
            }
            // keep going: the coordinator kills us when it reads the line
        }
    }
}

/// Entry point for the `grades worker` subcommand. Returns when the
/// coordinator says shutdown or closes our stdin; errors only on a
/// broken protocol (unparseable coordinator frame, stdout gone).
pub fn run_worker() -> Result<()> {
    let index = env_usize("GRADES_WORKER_INDEX").unwrap_or(0);
    let fault = match std::env::var("GRADES_FAULT") {
        Ok(v) if !v.trim().is_empty() => {
            Some(FaultSpec::parse(v.trim()).context("parsing GRADES_FAULT")?)
        }
        _ => None,
    };
    let fault = fault.filter(|f| f.worker == index);

    send(&ToCoordinator::Hello { pid: std::process::id(), index })
        .context("sending hello")?;

    let stdin = std::io::stdin();
    let mut lines = stdin.lock().lines();

    // The first meaningful frame must be `init` — it carries everything
    // needed to build the execution options.
    let init = loop {
        let line = match lines.next() {
            Some(l) => l.context("reading init frame")?,
            None => return Ok(()), // coordinator gone before init
        };
        if line.trim().is_empty() {
            continue;
        }
        match ToWorker::parse(&line)? {
            ToWorker::Init(i) => break i,
            ToWorker::Shutdown => return Ok(()),
            ToWorker::Assign { .. } => bail!("assign frame before init"),
        }
    };

    let exp_opts = ExpOptions {
        steps_override: init.steps_override,
        questions: init.questions,
        bench_seed: init.bench_seed,
        backend: init.backend,
        // stdout belongs to the protocol; engine progress would corrupt
        // the frame stream
        verbose: false,
        ..ExpOptions::default()
    };
    let mock_mode = std::env::var("GRADES_MOCK_JOBS").map(|v| v == "1").unwrap_or(false);
    let device = if mock_mode { None } else { Some(DeviceRunner::new(&exp_opts)) };
    let mock = mock_mode.then(|| MockJobRunner {
        settings: init.settings.clone(),
        backend: init.backend,
        sleep_ms: env_usize("GRADES_MOCK_SLEEP_MS").unwrap_or(0) as u64,
        log: std::env::var("GRADES_MOCK_LOG").ok().map(std::path::PathBuf::from),
    });

    // Heartbeat thread: renews the lease on whatever job is current.
    // Detached on purpose — it dies with the process, which is exactly
    // the lease-expiry signal the coordinator listens for.
    let current: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
    let hb_enabled = Arc::new(AtomicBool::new(true));
    {
        let current = current.clone();
        let hb = hb_enabled.clone();
        let period = Duration::from_millis(init.heartbeat_ms.max(1));
        std::thread::spawn(move || loop {
            std::thread::sleep(period);
            if !hb.load(Ordering::SeqCst) {
                continue;
            }
            let job = current.lock().unwrap_or_else(|p| p.into_inner()).clone();
            if let Some(job) = job {
                if send(&ToCoordinator::Heartbeat { job }).is_err() {
                    return; // coordinator gone; main loop will see EOF
                }
            }
        });
    }

    send(&ToCoordinator::Claim).context("sending first claim")?;

    let mut assignment_count = 0usize;
    for line in lines {
        let line = line.context("reading coordinator frame")?;
        if line.trim().is_empty() {
            continue;
        }
        let (job, _attempt) = match ToWorker::parse(&line)? {
            ToWorker::Shutdown => break,
            ToWorker::Init(_) => continue, // duplicate init: ignore
            ToWorker::Assign { job, attempt } => (job, attempt),
        };
        assignment_count += 1;
        if let Some(f) = fault {
            if f.fires(index, assignment_count) {
                enact(f.kind, assignment_count, &hb_enabled);
            }
        }
        // `current` is set for exactly the duration of the job, so the
        // heartbeat thread renews this lease and no other.
        *current.lock().unwrap_or_else(|p| p.into_inner()) = Some(job.id.clone());
        let outcome = execute(&job, device.as_ref(), mock.as_ref());
        *current.lock().unwrap_or_else(|p| p.into_inner()) = None;
        let frame = match outcome {
            Ok(summary) => ToCoordinator::Done { job: job.id.clone(), summary },
            Err(e) => ToCoordinator::Failed { job: job.id.clone(), error: format!("{e:#}") },
        };
        send(&frame).context("sending job outcome")?;
        send(&ToCoordinator::Claim).context("sending claim")?;
    }
    Ok(())
}

/// Run one wire job on whichever executor this process has. Errors are
/// reported to the coordinator as a clean `failed` frame — the worker
/// itself stays up.
fn execute(
    job: &WireJob,
    device: Option<&DeviceRunner<'_>>,
    mock: Option<&MockJobRunner>,
) -> Result<Option<super::scheduler::JobSummary>> {
    let spec = job.to_spec();
    let out = match device {
        Some(d) => {
            let warm = match &job.warm {
                Some((cfg, steps)) => Some(d.warm_checkpoint(cfg, *steps)?),
                None => None,
            };
            d.run(&spec, warm, None)?
        }
        None => mock.expect("mock runner in mock mode").run(&spec, None, None)?,
    };
    Ok(out.summary)
}
