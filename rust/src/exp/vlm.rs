//! Tables 2, 3 & 5 + Figure 4b: the VLM experiments.
//!
//! Table 2: {FP, LoRA} × {base, +GradES} on the three VLM suites.
//! Table 3: vlm-nano ± GradES across six nanoVLM-style categories.
//! Table 5: time/FLOPs for the Table-2 runs.
//! Fig 4b: vision- vs language-tower mean |∇W| series.
//!
//! Jobs come from [`plan::vlm_plan`]: one pretrain per VLM config feeds
//! the fine-tuning jobs, and the Figure 4b tower series are precomputed
//! into each job's persisted summary (the scheduler knows the manifest's
//! tower split), so resumed runs render the chart without the in-memory
//! metrics log and nothing recompiles the bundle just to read components.

use anyhow::Result;

use super::{method_label, plan, scheduler, write_result, ExpOptions};
use crate::coordinator::trainer::StoppingMethod;
use crate::report::figures::ascii_chart;
use crate::report::table::{pct, sci, secs, speedup, Table};
use crate::util::csv::CsvWriter;

/// Run the VLM matrix and render Tables 2/3/5 + Figure 4b.
pub fn run(opts: &ExpOptions) -> Result<()> {
    let pre_steps = opts.steps_override.unwrap_or(300);
    let (graph, slots) = plan::vlm_plan(pre_steps)?;
    let runner = scheduler::DeviceRunner::new(opts);
    let mut report = scheduler::execute(&graph, &opts.scheduler(), &runner)?;
    report.require_ok(&graph)?;

    // ---- Fig 4b data: tower series of the FP base run (from the
    // persisted summary — exact on resume too) ----
    let fp_base_id = slots
        .main
        .iter()
        .find(|(am, id)| am == "fp" && graph.get(*id).method == StoppingMethod::None)
        .map(|(_, id)| *id)
        .expect("plan contains the FP base job");
    let (vis_pts, lang_pts) = report
        .summary(fp_base_id)?
        .tower_gabs
        .clone()
        .ok_or_else(|| anyhow::anyhow!("VLM job summary missing tower series"))?;

    // ---- Table 2 + Table 5: vlm-tiny {fp, lora} × {base, grades} ----
    let mut jobs = Vec::new();
    for (am, id) in &slots.main {
        jobs.push((am.clone(), report.take_result(*id)?));
    }
    let suite_names: Vec<String> = jobs[0].1.accuracies.iter().map(|a| a.0.clone()).collect();
    let mut header = vec!["Model".to_string(), "Method".to_string()];
    header.extend(suite_names);
    let mut t2 = Table::new(header);
    for (am, job) in &jobs {
        let mut row = vec!["vlm-tiny".to_string(), method_label(am, job.method)];
        row.extend(job.accuracies.iter().map(|a| pct(a.1)));
        t2.row(row);
    }
    let avg_col = t2.header.len() - 1;
    t2.bold_best_by(0, avg_col);
    let t2s = format!(
        "## Table 2 — VLM accuracy (%). ColorQA≈GQA, ShapeQA≈VQAv2, CapMatch≈COCO Cap\n\n{}",
        t2.render()
    );

    let mut t5 = Table::new(vec!["Model", "Method", "Time (s)", "Speedup", "FLOPs", "FLOPs Ratio"]);
    let base = jobs
        .iter()
        .find(|(am, j)| am == "fp" && j.method == StoppingMethod::None)
        .map(|(_, j)| (j.outcome.wall_secs, j.outcome.flops.total()))
        .unwrap();
    for (am, job) in &jobs {
        t5.row(vec![
            "vlm-tiny".to_string(),
            method_label(am, job.method),
            secs(job.outcome.wall_secs),
            speedup(base.0 / job.outcome.wall_secs),
            sci(job.outcome.flops.total()),
            format!("{:.2}x", job.outcome.flops.total() / base.1),
        ]);
    }
    let t5s = format!("## Table 5 — VLM training time & FLOPs\n\n{}", t5.render());

    // ---- Fig 4b: vision vs language tower ----
    let f4b = format!(
        "## Figure 4b — gradient-norm evolution: vision vs language towers\n\n```\n{}```\n",
        ascii_chart(
            "mean |grad|_1 per tower (FP, vlm-tiny)",
            &[("vision", vis_pts.clone()), ("language", lang_pts.clone())],
            70,
            14,
            true,
        )
    );
    std::fs::create_dir_all(&opts.out_dir)?;
    let mut w = CsvWriter::create(opts.out_dir.join("fig4b_towers.csv"),
                                   &["step", "vision_mean_gabs", "language_mean_gabs"])?;
    for ((s, v), (_, l)) in vis_pts.iter().zip(&lang_pts) {
        w.row(&[*s, *v, *l])?;
    }
    w.flush()?;

    // ---- Table 3: vlm-nano ± GradES on the six categories ----
    let nano_base = report.take_result(slots.nano_base)?;
    let nano_grades = report.take_result(slots.nano_grades)?;
    let mut t3 = Table::new(vec!["Benchmark", "Training", "Training+GradES"]);
    for (b, g) in nano_base.accuracies.iter().zip(&nano_grades.accuracies) {
        t3.row(vec![b.0.clone(), pct(b.1), pct(g.1)]);
    }
    let t3s = format!(
        "## Table 3 — nanoVLM-style training ± GradES across six categories\n\n{}",
        t3.render()
    );

    println!("\n{t2s}\n{t3s}\n{t5s}\n{f4b}");
    write_result(opts, "table2_vlm_accuracy.md", &t2s)?;
    write_result(opts, "table3_nanovlm.md", &t3s)?;
    write_result(opts, "table5_vlm_efficiency.md", &t5s)?;
    write_result(opts, "fig4b_towers.md", &f4b)?;
    Ok(())
}
