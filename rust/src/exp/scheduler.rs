//! The experiment-job scheduler: runs a [`JobGraph`] on a bounded worker
//! pool over one shared PJRT client, with resumable results.
//!
//! # Execution model
//!
//! Ready jobs (all dependencies resolved) are pulled from a queue by up
//! to `--jobs` workers. Each job has a **host phase** — dataset rows,
//! benchmark packing, config patching, all plain data — and a **device
//! phase** — compile/train/score through the client. Host phases of
//! different jobs run concurrently; device phases are serialized behind
//! one exclusive *device token* (a mutex around [`DeviceArena`]): the
//! `xla` binding's client handles carry non-atomic refcounts that every
//! upload, execution and buffer drop touches, so two threads may never
//! drive the same client at once (see `runtime::session`'s thread-safety
//! contract — this is the Send audit's conclusion). On the CPU backend
//! this costs little: a single train step already saturates the cores
//! through PJRT's own thread pool, so the scheduler's wins are overlap of
//! host-side work, shared compiles/datasets/suites, and resumability.
//!
//! Behind the token live the per-config caches: an [`EngineCache`]
//! (build/compile each config's backend once — the token doubles as the
//! compile lock) and the device-resident benchmark suites (upload once
//! per config). Outside it live the host caches: per-config dataset rows
//! and packed suites. Which backend an engine is (compiled XLA artifacts
//! or the pure-Rust host transformer) comes from `ExpOptions::backend`.
//!
//! # Determinism
//!
//! A job's trajectory depends only on its spec (config + patches + seed +
//! warm checkpoint), never on scheduling order, and drivers render tables
//! in *plan* order — so `--jobs 1` and `--jobs N` produce byte-identical
//! tables, and `--jobs 1` reproduces the pre-scheduler sequential loops.
//!
//! # Resume
//!
//! Every completed persistent job is summarized into a run-manifest JSON
//! under `--out` (atomic tmp+rename after each completion). A re-run
//! loads the manifest and skips finished jobs, reconstructing their table
//! rows from the summaries; pretrain jobs resume through the checkpoint
//! disk cache in `coordinator::warmstart` instead, and are elided
//! entirely when every dependent is already done.
//!
//! # Failure isolation
//!
//! Worker panics and job errors are caught per job: the job is marked
//! failed, its transitive dependents are skipped, and the rest of the
//! graph keeps running. Failed jobs are not persisted, so a re-run
//! retries exactly them.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::plan::{EvalKind, JobGraph, JobId, JobKind, JobSpec};
use super::{ExpOptions, JobResult};
use crate::config::RepoConfig;
use crate::coordinator::freeze::{FreezeReason, FreezeState};
use crate::coordinator::metrics::{MetricsLog, StepRecord};
use crate::coordinator::trainer::{self, StopCause, StoppingMethod, TrainOutcome, TrainerOptions};
use crate::coordinator::warmstart::{self, BaseCheckpoint};
use crate::data;
use crate::eval::benchmarks;
use crate::eval::harness::{self, DeviceSuite, PackedSuite};
use crate::runtime::artifact::Client;
use crate::runtime::backend::{manifest_for, Backend, BackendChoice, EngineCache};
use crate::runtime::manifest::Manifest;
use crate::runtime::pipeline::{FixedCycle, Prefetcher};
use crate::runtime::session::Session;
use crate::util::json::{self, Json};

// ---------------------------------------------------------------------------
// Options
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
/// Executor knobs, derived from `ExpOptions::scheduler`.
pub struct SchedulerOptions {
    /// Worker count (1 = run inline on the calling thread, in plan order).
    pub jobs: usize,
    /// Run-manifest path for persistence/resume (None = no persistence).
    pub manifest_path: Option<PathBuf>,
    /// Skip completed jobs found in the manifest. Off (`--fresh`), the
    /// manifest is still loaded and rewritten — entries from *other*
    /// targets sharing the file are preserved — it just never skips.
    pub resume: bool,
    /// Fingerprint of the run-wide settings that shape a job's numbers
    /// (steps override, question count, bench seed — see
    /// `ExpOptions::settings_fingerprint`). A manifest entry only resumes
    /// when its recorded fingerprint matches, so cells produced under
    /// `--quick`/`--steps` are never silently reused by a full run.
    pub settings: String,
    /// Backend selection policy — resolved *per config* into every job's
    /// fingerprint (see [`job_settings`]), so host-run and XLA-run cells
    /// never resume into each other, even under `auto` when artifacts
    /// appear between runs.
    pub backend: BackendChoice,
    /// Progress lines on stdout.
    pub verbose: bool,
    /// Worker *processes* (`--workers M`). 0 disables the
    /// coordinator/worker runtime and everything runs on the in-process
    /// pool; > 0 asks `execute` to dispatch distributable graphs through
    /// `exp::coordinator` (falling back to the pool when the graph or the
    /// environment can't support it — see [`super::coordinator::try_execute`]).
    pub workers: usize,
    /// Per-job retry budget and backoff, shared by the in-process pool
    /// and the coordinator.
    pub retry: RetryPolicy,
    /// Coordinator/worker runtime knobs (leases, heartbeats, fault
    /// injection, mock mode). Unused when `workers == 0`.
    pub grid: super::coordinator::GridOptions,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions {
            jobs: 1,
            manifest_path: None,
            resume: true,
            settings: String::new(),
            backend: BackendChoice::default(),
            verbose: false,
            workers: 0,
            retry: RetryPolicy::default(),
            grid: super::coordinator::GridOptions::default(),
        }
    }
}

/// Bounded per-job retry: a job gets `max_attempts` executions total,
/// with exponential backoff between them. Applies to both execution
/// runtimes — the in-process pool sleeps the backoff on the worker
/// thread; the coordinator holds the job in a `Backoff` state until the
/// deadline passes, so other jobs keep flowing meanwhile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total execution budget per job (1 = no retry). Never 0 — treated
    /// as 1.
    pub max_attempts: usize,
    /// Backoff before attempt 2, in milliseconds; doubles per attempt.
    pub backoff_base_ms: u64,
    /// Backoff ceiling, in milliseconds.
    pub backoff_max_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, backoff_base_ms: 100, backoff_max_ms: 2_000 }
    }
}

impl RetryPolicy {
    /// Backoff to wait after `attempt` (1-based) failed:
    /// `min(max, base · 2^(attempt-1))`.
    pub fn delay(&self, attempt: usize) -> std::time::Duration {
        let exp = attempt.saturating_sub(1).min(16) as u32;
        let ms = self.backoff_base_ms.saturating_mul(1u64 << exp).min(self.backoff_max_ms);
        std::time::Duration::from_millis(ms)
    }
}

/// The full settings fingerprint for one job: the run-wide part, the
/// spec's own overrides, and the backend the job's config *resolves* to
/// under `choice` (not the requested policy: under `auto`, building
/// artifacts changes the resolution, and the fingerprint must notice).
/// Must be identical between the run that wrote a summary and the run
/// trying to resume from it.
pub fn job_settings(spec: &JobSpec, global: &str, choice: BackendChoice) -> String {
    format!(
        "{global}|steps={:?}|probe={:?}|be={}|m={}",
        spec.steps,
        spec.probe_every,
        choice.resolve(&spec.config).label(),
        spec.method.label()
    )
}

/// Effective worker count: `--jobs` flag wins, then the `GRADES_JOBS`
/// environment value, then 1 (sequential). Always at least 1.
///
/// A malformed or zero `GRADES_JOBS` used to fall back to sequential
/// *silently* — an easy way to believe a grid ran concurrently when it
/// didn't. It still falls back (never fail a run over an env var), but
/// now warns once on stderr. Accepted values: a positive integer;
/// unset/empty means 1.
pub fn resolve_jobs(flag: Option<usize>, env: Option<&str>) -> usize {
    if let Some(n) = flag {
        if n == 0 {
            static WARNED_FLAG: std::sync::Once = std::sync::Once::new();
            WARNED_FLAG.call_once(|| {
                eprintln!(
                    "[scheduler] --jobs 0 is not a worker count; running \
                     sequentially (--jobs 1)"
                );
            });
        }
        return n.max(1);
    }
    match env.map(str::trim) {
        None | Some("") => 1,
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!(
                        "[scheduler] ignoring GRADES_JOBS={v:?}: expected a positive \
                         integer worker count; running sequentially (--jobs 1)"
                    );
                });
                1
            }
        },
    }
}

/// Effective worker-*process* count: `--workers` flag wins, then the
/// `GRADES_WORKERS` environment value, then 0 (in-process pool only).
/// Unlike [`resolve_jobs`], 0 is a meaningful value here — it means "no
/// coordinator runtime" — so only malformed env values warn.
pub fn resolve_workers(flag: Option<usize>, env: Option<&str>) -> usize {
    if let Some(n) = flag {
        return n;
    }
    match env.map(str::trim) {
        None | Some("") => 0,
        Some(v) => match v.parse::<usize>() {
            Ok(n) => n,
            Err(_) => {
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!(
                        "[scheduler] ignoring GRADES_WORKERS={v:?}: expected a \
                         non-negative integer process count; using the in-process pool"
                    );
                });
                0
            }
        },
    }
}

// ---------------------------------------------------------------------------
// Run manifest: persisted per-job summaries
// ---------------------------------------------------------------------------

/// Everything the drivers need to re-render a completed job's table cells
/// (and the small figure series) without re-running it.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSummary {
    /// Job id (the manifest key).
    pub id: String,
    /// Config the job ran.
    pub config: String,
    /// Settings fingerprint the job ran under (see [`job_settings`]).
    pub settings: String,
    /// Short id of the execution backend the job's config resolved to
    /// (`"host"`/`"xla"`) — recorded per job so a grid's mixed-backend
    /// provenance is inspectable from the manifest itself, not just
    /// implied by the resume fingerprint (ROADMAP PR-4 follow-up).
    pub backend: String,
    /// `StoppingMethod::label()` string.
    pub method: String,
    /// Steps the run executed.
    pub steps_run: usize,
    /// "budget" | "frozen" | "patience".
    pub stop_cause: String,
    /// Total wall seconds.
    pub wall_secs: f64,
    /// Seconds inside validation passes.
    pub validation_secs: f64,
    /// Seconds inside monitor probes.
    pub monitor_secs: f64,
    /// Final validation loss.
    pub final_val_loss: f64,
    /// Attn-frozen swap step, if any.
    pub variant_swap_step: Option<usize>,
    /// Theoretical frozen-aware FLOPs (ideal per-matrix plan).
    pub flops_spent: f64,
    /// Engine-realized FLOPs (what the lowered step plans actually
    /// skipped — ≥ `flops_spent`; see `FlopsCounter`).
    pub flops_realized: f64,
    /// Dense-equivalent FLOPs of the same steps.
    pub flops_dense: f64,
    /// FLOPs inside validation.
    pub flops_validation: f64,
    /// Steps the FLOPs counter recorded.
    pub flops_steps: usize,
    /// Monitored component count.
    pub n_components: usize,
    /// Component indices frozen at the end of the run.
    pub frozen: Vec<usize>,
    /// (suite name, accuracy %) pairs ending with ("Avg.", …).
    pub accuracies: Vec<(String, f64)>,
    /// (step, frozen fraction) — the Figure 3 series.
    pub frozen_series: Vec<(usize, f64)>,
    /// VLM only: (vision, language) mean |∇W|₁ series — the Figure 4b
    /// series, precomputed so a resumed run can still render the chart.
    pub tower_gabs: Option<(Vec<(f64, f64)>, Vec<(f64, f64)>)>,
    /// Validation passes the run issued (0 for every validation-free
    /// method — the stopping-zoo table's headline column).
    pub val_checks: usize,
    /// How many attempts the job took to complete (1 = first try; > 1
    /// means the bounded retry path re-ran it after failures).
    pub attempts: usize,
}

fn stop_cause_str(c: StopCause) -> &'static str {
    match c {
        StopCause::BudgetExhausted => "budget",
        StopCause::AllComponentsFrozen => "frozen",
        StopCause::ValidationPatience => "patience",
        StopCause::SamplesExhausted => "instances",
    }
}

fn parse_stop_cause(s: &str) -> Result<StopCause> {
    match s {
        "budget" => Ok(StopCause::BudgetExhausted),
        "frozen" => Ok(StopCause::AllComponentsFrozen),
        "patience" => Ok(StopCause::ValidationPatience),
        "instances" => Ok(StopCause::SamplesExhausted),
        other => bail!("unknown stop cause {other:?}"),
    }
}

/// Mean |∇W|₁ over a component subset per logged step.
fn tower_mean_series(log: &MetricsLog, idxs: &[usize]) -> Vec<(f64, f64)> {
    if idxs.is_empty() {
        return Vec::new();
    }
    log.records
        .iter()
        .filter(|r| !r.gabs.is_empty())
        .map(|r| {
            let sum: f64 =
                idxs.iter().map(|&i| r.gabs.get(i).copied().unwrap_or(0.0) as f64).sum();
            (r.step as f64, sum / idxs.len() as f64)
        })
        .collect()
}

/// NaN/±inf survive the JSON round trip as null.
fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

fn f64_or_nan(j: &Json) -> f64 {
    j.as_f64().unwrap_or(f64::NAN)
}

fn series_to_json(s: &[(f64, f64)]) -> Json {
    Json::Arr(s.iter().map(|&(a, b)| Json::Arr(vec![Json::Num(a), Json::Num(b)])).collect())
}

fn series_from_json(j: &Json) -> Result<Vec<(f64, f64)>> {
    j.as_arr()?
        .iter()
        .map(|p| {
            let p = p.as_arr()?;
            ensure!(p.len() == 2, "series point is not a pair");
            Ok((p[0].as_f64()?, p[1].as_f64()?))
        })
        .collect()
}

impl JobSummary {
    /// Summarize a live result (called right after the job completes).
    /// `settings` is the job's *full* fingerprint (see [`job_settings`]).
    pub fn from_result(
        spec: &JobSpec,
        r: &JobResult,
        manifest: &Manifest,
        settings: &str,
        backend: &str,
    ) -> Self {
        let o = &r.outcome;
        let frozen = (0..o.freeze.n()).filter(|&c| o.freeze.is_frozen(c)).collect();
        let frozen_series =
            o.log.records.iter().map(|rec| (rec.step, rec.frozen_fraction)).collect();
        let tower_gabs = if manifest.is_vlm() {
            let vis = manifest.components_where(|c| c.tower == "vision");
            let lang = manifest.components_where(|c| c.tower == "language");
            Some((tower_mean_series(&o.log, &vis), tower_mean_series(&o.log, &lang)))
        } else {
            None
        };
        JobSummary {
            id: spec.id.clone(),
            config: r.config.clone(),
            settings: settings.to_string(),
            backend: backend.to_string(),
            method: r.method.label().to_string(),
            steps_run: o.steps_run,
            stop_cause: stop_cause_str(o.stop_cause).to_string(),
            wall_secs: o.wall_secs,
            validation_secs: o.validation_secs,
            monitor_secs: o.monitor_secs,
            final_val_loss: o.final_val_loss,
            variant_swap_step: o.variant_swap_step,
            flops_spent: o.flops.spent,
            flops_realized: o.flops.realized_spent,
            flops_dense: o.flops.dense_equivalent,
            flops_validation: o.flops.validation,
            flops_steps: o.flops.steps,
            n_components: o.freeze.n(),
            frozen,
            accuracies: r.accuracies.clone(),
            frozen_series,
            tower_gabs,
            val_checks: o.async_eval.issued,
            attempts: 1,
        }
    }

    /// Rebuild the driver-facing [`JobResult`] a resumed run renders from.
    /// Table cells and figure series are exact; the full per-step metrics
    /// log and runtime timings are not persisted and come back empty.
    pub fn to_result(&self) -> Result<JobResult> {
        let method = StoppingMethod::parse(&self.method)
            .ok_or_else(|| anyhow!("unknown stopping method {:?}", self.method))?;
        let mut freeze = FreezeState::new(self.n_components);
        for &c in &self.frozen {
            ensure!(c < self.n_components, "frozen index {c} out of range");
            freeze.freeze(c, self.steps_run, FreezeReason::Converged, 0.0);
        }
        let mut log = MetricsLog::default();
        for &(step, frac) in &self.frozen_series {
            log.records.push(StepRecord {
                step,
                loss: f64::NAN,
                lr: f64::NAN,
                global_gnorm: f64::NAN,
                frozen_fraction: frac,
                gdiff: Vec::new(),
                gabs: Vec::new(),
            });
        }
        let outcome = TrainOutcome {
            steps_run: self.steps_run,
            stop_cause: parse_stop_cause(&self.stop_cause)?,
            wall_secs: self.wall_secs,
            validation_secs: self.validation_secs,
            monitor_secs: self.monitor_secs,
            flops: crate::coordinator::flops::FlopsCounter {
                spent: self.flops_spent,
                realized_spent: self.flops_realized,
                dense_equivalent: self.flops_dense,
                validation: self.flops_validation,
                steps: self.flops_steps,
            },
            log,
            freeze,
            final_val_loss: self.final_val_loss,
            variant_swap_step: self.variant_swap_step,
            // keep the two copies of the swap step consistent on resume
            // (full PlanStats are not persisted; the rest stays zeroed
            // like the timings)
            plan: crate::coordinator::scheduler::PlanStats {
                attn_swap_step: self.variant_swap_step,
                ..Default::default()
            },
            timings: Default::default(),
            async_eval: crate::runtime::async_eval::AsyncEvalStats {
                issued: self.val_checks,
                ..Default::default()
            },
        };
        Ok(JobResult {
            config: self.config.clone(),
            method,
            outcome,
            accuracies: self.accuracies.clone(),
        })
    }

    /// Serialize for the run manifest.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("id".to_string(), Json::Str(self.id.clone()));
        m.insert("config".to_string(), Json::Str(self.config.clone()));
        m.insert("settings".to_string(), Json::Str(self.settings.clone()));
        m.insert("backend".to_string(), Json::Str(self.backend.clone()));
        m.insert("method".to_string(), Json::Str(self.method.clone()));
        m.insert("steps_run".to_string(), Json::Num(self.steps_run as f64));
        m.insert("stop_cause".to_string(), Json::Str(self.stop_cause.clone()));
        m.insert("wall_secs".to_string(), num_or_null(self.wall_secs));
        m.insert("validation_secs".to_string(), num_or_null(self.validation_secs));
        m.insert("monitor_secs".to_string(), num_or_null(self.monitor_secs));
        m.insert("final_val_loss".to_string(), num_or_null(self.final_val_loss));
        if let Some(s) = self.variant_swap_step {
            m.insert("variant_swap_step".to_string(), Json::Num(s as f64));
        }
        m.insert("flops_spent".to_string(), num_or_null(self.flops_spent));
        m.insert("flops_realized".to_string(), num_or_null(self.flops_realized));
        m.insert("flops_dense".to_string(), num_or_null(self.flops_dense));
        m.insert("flops_validation".to_string(), num_or_null(self.flops_validation));
        m.insert("flops_steps".to_string(), Json::Num(self.flops_steps as f64));
        m.insert("n_components".to_string(), Json::Num(self.n_components as f64));
        m.insert(
            "frozen".to_string(),
            Json::Arr(self.frozen.iter().map(|&c| Json::Num(c as f64)).collect()),
        );
        m.insert(
            "accuracies".to_string(),
            Json::Arr(
                self.accuracies
                    .iter()
                    .map(|(n, v)| Json::Arr(vec![Json::Str(n.clone()), num_or_null(*v)]))
                    .collect(),
            ),
        );
        m.insert(
            "frozen_series".to_string(),
            Json::Arr(
                self.frozen_series
                    .iter()
                    .map(|&(s, f)| Json::Arr(vec![Json::Num(s as f64), num_or_null(f)]))
                    .collect(),
            ),
        );
        if let Some((vis, lang)) = &self.tower_gabs {
            let mut t = BTreeMap::new();
            t.insert("vision".to_string(), series_to_json(vis));
            t.insert("language".to_string(), series_to_json(lang));
            m.insert("tower_gabs".to_string(), Json::Obj(t));
        }
        m.insert("val_checks".to_string(), Json::Num(self.val_checks as f64));
        m.insert("attempts".to_string(), Json::Num(self.attempts as f64));
        Json::Obj(m)
    }

    /// Deserialize one manifest entry.
    pub fn from_json(j: &Json) -> Result<Self> {
        let accuracies = j
            .get("accuracies")?
            .as_arr()?
            .iter()
            .map(|p| {
                let p = p.as_arr()?;
                ensure!(p.len() == 2, "accuracy entry is not a pair");
                Ok((p[0].as_str()?.to_string(), f64_or_nan(&p[1])))
            })
            .collect::<Result<Vec<_>>>()?;
        let frozen = j
            .get("frozen")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let frozen_series = j
            .get("frozen_series")?
            .as_arr()?
            .iter()
            .map(|p| {
                let p = p.as_arr()?;
                ensure!(p.len() == 2, "frozen-series point is not a pair");
                Ok((p[0].as_usize()?, f64_or_nan(&p[1])))
            })
            .collect::<Result<Vec<_>>>()?;
        let tower_gabs = match j.opt("tower_gabs") {
            Some(t) => Some((
                series_from_json(t.get("vision")?)?,
                series_from_json(t.get("language")?)?,
            )),
            None => None,
        };
        Ok(JobSummary {
            id: j.get("id")?.as_str()?.to_string(),
            config: j.get("config")?.as_str()?.to_string(),
            // pre-fingerprint manifests deserialize to a value that can
            // never match a live fingerprint, so their entries just re-run
            settings: match j.opt("settings") {
                Some(v) => v.as_str()?.to_string(),
                None => "<unrecorded>".to_string(),
            },
            // pre-plan manifests lack the field; the placeholder keeps
            // them loadable (their fingerprint decides resumability)
            backend: match j.opt("backend") {
                Some(v) => v.as_str()?.to_string(),
                None => "<unrecorded>".to_string(),
            },
            method: j.get("method")?.as_str()?.to_string(),
            steps_run: j.get("steps_run")?.as_usize()?,
            stop_cause: j.get("stop_cause")?.as_str()?.to_string(),
            wall_secs: f64_or_nan(j.get("wall_secs")?),
            validation_secs: f64_or_nan(j.get("validation_secs")?),
            monitor_secs: f64_or_nan(j.get("monitor_secs")?),
            final_val_loss: f64_or_nan(j.get("final_val_loss")?),
            variant_swap_step: match j.opt("variant_swap_step") {
                Some(v) => Some(v.as_usize()?),
                None => None,
            },
            flops_spent: f64_or_nan(j.get("flops_spent")?),
            // pre-plan manifests lack the realized ledger; NaN marks it
            // unrecorded without blocking the load
            flops_realized: match j.opt("flops_realized") {
                Some(v) => f64_or_nan(v),
                None => f64::NAN,
            },
            flops_dense: f64_or_nan(j.get("flops_dense")?),
            flops_validation: f64_or_nan(j.get("flops_validation")?),
            flops_steps: j.get("flops_steps")?.as_usize()?,
            n_components: j.get("n_components")?.as_usize()?,
            frozen,
            accuracies,
            frozen_series,
            tower_gabs,
            // pre-zoo manifests lack the counter; 0 keeps them loadable
            // (their methods' tables never rendered it)
            val_checks: match j.opt("val_checks") {
                Some(v) => v.as_usize()?,
                None => 0,
            },
            // pre-retry manifests lack the field; one attempt is what
            // their jobs took
            attempts: match j.opt("attempts") {
                Some(v) => v.as_usize()?,
                None => 1,
            },
        })
    }
}

/// A job's failure ledger in the manifest: how many attempts have been
/// burned and what the last one died of. Written on every failure (also
/// by the coordinator when a worker holding the job's lease dies), and
/// cleared when the job finally completes — so an operator reading
/// `run_manifest.json` after a crashy grid sees exactly which cells
/// struggled and why.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultRecord {
    /// Attempts consumed so far.
    pub attempts: usize,
    /// Rendered error chain (or lease/worker post-mortem) of the most
    /// recent failure.
    pub last_error: String,
}

impl FaultRecord {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("attempts".to_string(), Json::Num(self.attempts as f64));
        m.insert("last_error".to_string(), Json::Str(self.last_error.clone()));
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(FaultRecord {
            attempts: j.get("attempts")?.as_usize()?,
            last_error: j.get("last_error")?.as_str()?.to_string(),
        })
    }
}

/// The on-disk record of completed jobs, keyed by job id. One file serves
/// every repro target (ids are namespaced: `lm/…`, `vlm/…`, `ablation/…`).
#[derive(Debug, Default)]
pub struct RunManifest {
    /// Completed-job summaries by job id.
    pub jobs: BTreeMap<String, JobSummary>,
    /// Failure ledger for jobs that have errored (see [`FaultRecord`]).
    pub faults: BTreeMap<String, FaultRecord>,
}

impl RunManifest {
    /// Load tolerantly: a missing, truncated or otherwise corrupt
    /// manifest degrades to an empty one — `--fresh` semantics — with a
    /// once-per-process warning instead of erroring the whole run (a
    /// grid must never be unstartable because its *resume cache* is
    /// damaged; the file is rewritten from scratch as jobs complete).
    pub fn load(path: &Path) -> Self {
        let src = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(_) => return RunManifest::default(),
        };
        match Self::parse(&src) {
            Ok(m) => m,
            Err(e) => {
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!(
                        "[scheduler] run manifest {path:?} is unreadable ({e:#}); \
                         starting fresh — completed jobs will re-run and the file \
                         will be rewritten"
                    );
                });
                RunManifest::default()
            }
        }
    }

    /// Parse a manifest document (bad entries are skipped with a warning).
    pub fn parse(src: &str) -> Result<Self> {
        let j = json::parse(src)?;
        ensure!(j.get("version")?.as_usize()? == 1, "unsupported run-manifest version");
        let mut jobs = BTreeMap::new();
        if let Json::Obj(entries) = j.get("jobs")? {
            for (id, entry) in entries {
                match JobSummary::from_json(entry) {
                    Ok(s) => {
                        jobs.insert(id.clone(), s);
                    }
                    Err(e) => eprintln!("[scheduler] skipping manifest entry {id:?}: {e:#}"),
                }
            }
        }
        let mut faults = BTreeMap::new();
        if let Some(Json::Obj(entries)) = j.opt("faults") {
            for (id, entry) in entries {
                match FaultRecord::from_json(entry) {
                    Ok(f) => {
                        faults.insert(id.clone(), f);
                    }
                    Err(e) => eprintln!("[scheduler] skipping fault entry {id:?}: {e:#}"),
                }
            }
        }
        Ok(RunManifest { jobs, faults })
    }

    /// Serialize the whole manifest to JSON text.
    pub fn render(&self) -> String {
        let mut jobs = BTreeMap::new();
        for (k, v) in &self.jobs {
            jobs.insert(k.clone(), v.to_json());
        }
        let mut root = BTreeMap::new();
        root.insert("version".to_string(), Json::Num(1.0));
        root.insert("jobs".to_string(), Json::Obj(jobs));
        if !self.faults.is_empty() {
            let mut faults = BTreeMap::new();
            for (k, v) in &self.faults {
                faults.insert(k.clone(), v.to_json());
            }
            root.insert("faults".to_string(), Json::Obj(faults));
        }
        json::write(&Json::Obj(root))
    }

    /// Atomic save: write a sibling tmp file, then rename over the target.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.render()).with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?}"))?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Executor
// ---------------------------------------------------------------------------

/// How a job ended up, in the report handed back to the driver.
#[derive(Debug)]
pub enum JobStatus {
    /// Ran (or was resumed/elided). Pretrain jobs carry no table result.
    Done { result: Option<JobResult>, summary: Option<JobSummary>, resumed: bool },
    /// The runner returned an error or panicked.
    Failed(String),
    /// A transitive dependency failed; the job never ran.
    Skipped(String),
}

/// Per-job statuses, indexed by [`JobId`] (plan order).
#[derive(Debug)]
pub struct RunReport {
    /// One status per job, in plan order.
    pub statuses: Vec<JobStatus>,
}

impl RunReport {
    /// The job's table result, or why it has none.
    pub fn result(&self, id: JobId) -> Result<&JobResult> {
        match &self.statuses[id] {
            JobStatus::Done { result: Some(r), .. } => Ok(r),
            JobStatus::Done { result: None, .. } => {
                bail!("job {id} carries no table result (pretrain job, or already taken)")
            }
            JobStatus::Failed(e) => bail!("job {id} failed: {e}"),
            JobStatus::Skipped(e) => bail!("job {id} skipped: {e}"),
        }
    }

    /// Move a result out of the report (drivers that build owned tables).
    pub fn take_result(&mut self, id: JobId) -> Result<JobResult> {
        match &mut self.statuses[id] {
            JobStatus::Done { result, .. } => result
                .take()
                .ok_or_else(|| anyhow!("job {id} carries no table result (pretrain or taken)")),
            JobStatus::Failed(e) => bail!("job {id} failed: {e}"),
            JobStatus::Skipped(e) => bail!("job {id} skipped: {e}"),
        }
    }

    /// The job's persisted summary, or why it has none.
    pub fn summary(&self, id: JobId) -> Result<&JobSummary> {
        match &self.statuses[id] {
            JobStatus::Done { summary: Some(s), .. } => Ok(s),
            JobStatus::Done { summary: None, .. } => bail!("job {id} has no summary"),
            JobStatus::Failed(e) => bail!("job {id} failed: {e}"),
            JobStatus::Skipped(e) => bail!("job {id} skipped: {e}"),
        }
    }

    /// Fail loudly (listing every broken job) if anything did not finish.
    pub fn require_ok(&self, graph: &JobGraph) -> Result<()> {
        let mut broken = Vec::new();
        for (i, s) in self.statuses.iter().enumerate() {
            match s {
                JobStatus::Done { .. } => {}
                JobStatus::Failed(e) => broken.push(format!("{}: FAILED: {e}", graph.get(i).id)),
                JobStatus::Skipped(e) => broken.push(format!("{}: skipped: {e}", graph.get(i).id)),
            }
        }
        if !broken.is_empty() {
            bail!(
                "{} of {} jobs did not complete (completed cells are saved in the run \
                 manifest; re-run to retry only the rest):\n  {}",
                broken.len(),
                self.statuses.len(),
                broken.join("\n  ")
            );
        }
        Ok(())
    }

    /// (ran, resumed, failed, skipped) tallies.
    pub fn counts(&self) -> (usize, usize, usize, usize) {
        let (mut ran, mut resumed, mut failed, mut skipped) = (0, 0, 0, 0);
        for s in &self.statuses {
            match s {
                JobStatus::Done { resumed: true, .. } => resumed += 1,
                JobStatus::Done { resumed: false, .. } => ran += 1,
                JobStatus::Failed(_) => failed += 1,
                JobStatus::Skipped(_) => skipped += 1,
            }
        }
        (ran, resumed, failed, skipped)
    }
}

/// A finished training job's weights in cross-thread form, handed to
/// dependent [`JobKind::Eval`] jobs.
///
/// Plain host data (`Send`): device snapshots can't cross workers — the
/// `xla` binding's handles carry non-atomic refcounts — so the runner
/// downloads the final state once and the eval job rehydrates it into a
/// fresh session under its own hold of the device token. This is what
/// lets an eval chunk outlive the training job that produced it.
pub struct EvalPayload {
    /// Config the weights belong to (must match the eval job's config).
    pub config: String,
    /// Full flat state (`manifest.state_len` f32s).
    pub state: Vec<f32>,
    /// Optimizer step the training run ended at.
    pub step: usize,
}

/// What a runner hands back for one executed job.
pub struct RunnerOutput {
    /// Table-facing result (None for pretrain jobs).
    pub result: Option<JobResult>,
    /// Persisted summary (None when the spec is ephemeral or pretrain).
    pub summary: Option<JobSummary>,
    /// Checkpoint for dependents (pretrain jobs).
    pub checkpoint: Option<Arc<BaseCheckpoint>>,
    /// Final weights for dependent eval jobs (train jobs with
    /// `export_state`).
    pub eval_payload: Option<Arc<EvalPayload>>,
}

/// Executes a single job. The executor isolates panics, so a runner may
/// panic without poisoning the pool. `Sync` because one runner instance
/// is shared by every worker.
pub trait JobRunner: Sync {
    /// Run `spec`. `warm` is the checkpoint from `spec.warm_from` (when
    /// set), `eval_src` the weights from `spec.eval_src` (eval jobs).
    fn run(
        &self,
        spec: &JobSpec,
        warm: Option<Arc<BaseCheckpoint>>,
        eval_src: Option<Arc<EvalPayload>>,
    ) -> Result<RunnerOutput>;
}

struct ExecState {
    statuses: Vec<Option<JobStatus>>,
    /// Unresolved-dependency count per job (resolved = any final status).
    waiting: Vec<usize>,
    ready: VecDeque<JobId>,
    checkpoints: HashMap<JobId, Arc<BaseCheckpoint>>,
    /// Exported final weights, keyed by the producing train job — what a
    /// dependent eval job consumes (host data, so freely `Send`). Entries
    /// are dropped once the last consumer has claimed (or forfeited) its
    /// copy, so full flat states don't accumulate across a grid run.
    payloads: HashMap<JobId, Arc<EvalPayload>>,
    /// Eval jobs still entitled to each train job's payload; at 0 the
    /// payload is removed from `payloads`.
    payload_consumers: Vec<usize>,
    /// Jobs without a final status yet (0 ⇒ the run is over).
    remaining: usize,
    manifest: RunManifest,
}

struct ExecCore<'g, 'o> {
    graph: &'g JobGraph,
    children: Vec<Vec<JobId>>,
    opts: &'o SchedulerOptions,
    state: Mutex<ExecState>,
    cv: Condvar,
}

/// Drop one consumer's claim on job `d`'s payload, freeing the shared
/// entry (a full flat state) once no claimant remains.
fn release_payload_claim(st: &mut ExecState, d: JobId) {
    st.payload_consumers[d] = st.payload_consumers[d].saturating_sub(1);
    if st.payload_consumers[d] == 0 {
        st.payloads.remove(&d);
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl ExecCore<'_, '_> {
    fn lock_state(&self) -> MutexGuard<'_, ExecState> {
        // A panicking job poisons nothing semantically: state mutations
        // are all single complete()/next_ready() critical sections.
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Block until a job is ready or the run is over.
    fn next_ready(&self) -> Option<JobId> {
        let mut st = self.lock_state();
        loop {
            if let Some(id) = st.ready.pop_front() {
                return Some(id);
            }
            if st.remaining == 0 {
                return None;
            }
            // Some unresolved job is running on another worker (every
            // unresolved, unready job waits on one) — completion notifies.
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    fn take_warm(&self, spec: &JobSpec) -> Result<Option<Arc<BaseCheckpoint>>> {
        match spec.warm_from {
            None => Ok(None),
            Some(d) => self
                .lock_state()
                .checkpoints
                .get(&d)
                .cloned()
                .map(Some)
                .ok_or_else(|| {
                    anyhow!(
                        "job {:?}: warm-start checkpoint from {:?} unavailable",
                        spec.id,
                        self.graph.get(d).id
                    )
                }),
        }
    }

    fn take_eval_src(&self, spec: &JobSpec) -> Result<Option<Arc<EvalPayload>>> {
        match spec.eval_src {
            None => Ok(None),
            Some(d) => {
                let mut st = self.lock_state();
                let p = st.payloads.get(&d).cloned().ok_or_else(|| {
                    anyhow!(
                        "job {:?}: final weights from {:?} unavailable (the source \
                         resumed from the manifest, or did not export its state)",
                        spec.id,
                        self.graph.get(d).id
                    )
                })?;
                // This consumer now holds its own Arc; once the last one
                // has claimed its copy the shared entry can be dropped —
                // payloads are full flat states and must not pile up.
                release_payload_claim(&mut st, d);
                Ok(Some(p))
            }
        }
    }

    /// Record a failed attempt in the manifest's fault ledger
    /// (best-effort: never fails the run), so a crash mid-backoff still
    /// leaves the struggle visible in `run_manifest.json`.
    fn record_fault(&self, spec: &JobSpec, attempts: usize, msg: &str) {
        let mut st = self.lock_state();
        st.manifest
            .faults
            .insert(spec.id.clone(), FaultRecord { attempts, last_error: msg.to_string() });
        if let Some(p) = &self.opts.manifest_path {
            let _ = st.manifest.save(p);
        }
    }

    /// Record a finished job, persist it, and unblock/skip dependents.
    /// `attempts` is how many executions the job consumed (recorded into
    /// the summary and the fault ledger).
    fn complete(&self, id: JobId, outcome: std::result::Result<RunnerOutput, String>, attempts: usize) {
        let spec = self.graph.get(id);
        let mut st = self.lock_state();
        debug_assert!(st.statuses[id].is_none(), "job resolved twice");
        match outcome {
            Ok(mut out) => {
                if let Some(sm) = &mut out.summary {
                    sm.attempts = attempts;
                }
                if let Some(ck) = out.checkpoint {
                    st.checkpoints.insert(id, ck);
                }
                if let Some(p) = out.eval_payload {
                    // A consumer may already have forfeited its claim (an
                    // eval job skipped via another failed dep): only keep
                    // the state while someone is still entitled to it.
                    if st.payload_consumers[id] > 0 {
                        st.payloads.insert(id, p);
                    }
                }
                let mut dirty = st.manifest.faults.remove(&spec.id).is_some();
                if spec.persist {
                    if let Some(sm) = &out.summary {
                        st.manifest.jobs.insert(spec.id.clone(), sm.clone());
                        dirty = true;
                    }
                }
                if dirty {
                    if let Some(p) = &self.opts.manifest_path {
                        if let Err(e) = st.manifest.save(p) {
                            eprintln!("[scheduler] run-manifest save failed: {e:#}");
                        }
                    }
                }
                st.statuses[id] =
                    Some(JobStatus::Done { result: out.result, summary: out.summary, resumed: false });
                st.remaining -= 1;
                for &c in &self.children[id] {
                    if st.statuses[c].is_none() {
                        st.waiting[c] -= 1;
                        if st.waiting[c] == 0 {
                            st.ready.push_back(c);
                        }
                    }
                }
            }
            Err(msg) => {
                eprintln!("[{}] FAILED after {attempts} attempt(s): {msg}", spec.id);
                st.manifest
                    .faults
                    .insert(spec.id.clone(), FaultRecord { attempts, last_error: msg.clone() });
                if let Some(p) = &self.opts.manifest_path {
                    let _ = st.manifest.save(p);
                }
                st.statuses[id] = Some(JobStatus::Failed(msg));
                st.remaining -= 1;
                // One failed job must not poison the pool: skip only its
                // transitive dependents, keep everything else running.
                let mut stack = self.children[id].clone();
                while let Some(c) = stack.pop() {
                    if st.statuses[c].is_none() {
                        st.statuses[c] = Some(JobStatus::Skipped(format!(
                            "dependency {:?} failed",
                            spec.id
                        )));
                        st.remaining -= 1;
                        // A skipped eval job will never claim its source's
                        // payload: forfeit its claim so the state can drop.
                        let cs = self.graph.get(c);
                        if cs.kind == JobKind::Eval {
                            if let Some(s) = cs.eval_src {
                                release_payload_claim(&mut st, s);
                            }
                        }
                        stack.extend(self.children[c].iter().copied());
                    }
                }
            }
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Run one job with panic isolation and the bounded retry budget:
    /// a failed or panicked attempt is retried (after backoff) until
    /// `opts.retry.max_attempts` executions are spent.
    fn run_one(&self, runner: &dyn JobRunner, id: JobId) {
        let spec = self.graph.get(id);
        let warm = match self.take_warm(spec) {
            Ok(w) => w,
            Err(e) => {
                self.complete(id, Err(format!("{e:#}")), 1);
                return;
            }
        };
        let eval_src = match self.take_eval_src(spec) {
            Ok(p) => p,
            Err(e) => {
                self.complete(id, Err(format!("{e:#}")), 1);
                return;
            }
        };
        let budget = self.opts.retry.max_attempts.max(1);
        let mut attempt = 0;
        let outcome = loop {
            attempt += 1;
            let caught = catch_unwind(AssertUnwindSafe(|| {
                runner.run(spec, warm.clone(), eval_src.clone())
            }));
            let res = match caught {
                Ok(Ok(out)) => Ok(out),
                Ok(Err(e)) => Err(format!("{e:#}")),
                Err(p) => Err(format!("job panicked: {}", panic_msg(p.as_ref()))),
            };
            match res {
                Ok(out) => break Ok(out),
                Err(msg) if attempt < budget => {
                    let delay = self.opts.retry.delay(attempt);
                    eprintln!(
                        "[{}] attempt {attempt}/{budget} failed: {msg}; retrying in {delay:?}",
                        spec.id
                    );
                    self.record_fault(spec, attempt, &msg);
                    std::thread::sleep(delay);
                }
                Err(msg) => break Err(msg),
            }
        };
        self.complete(id, outcome, attempt);
    }
}

/// Resume pre-pass output: the loaded manifest plus per-job initial
/// statuses, with resumable jobs already resolved. Shared by the
/// in-process executor and `exp::coordinator` so both runtimes make the
/// same resume decisions from the same `run_manifest.json`.
pub(crate) struct Prepass {
    /// `Some` for jobs resolved without running (resumed / elided).
    pub(crate) statuses: Vec<Option<JobStatus>>,
    /// The loaded manifest (entries from other targets preserved).
    pub(crate) manifest: RunManifest,
}

/// Resolve resumable jobs against the run manifest: completed persistent
/// train jobs come back from their summaries (when the settings
/// fingerprint matches); pretrain jobs whose dependents are all done are
/// elided (otherwise they run and hit the warmstart disk cache).
pub(crate) fn resume_prepass(
    graph: &JobGraph,
    children: &[Vec<JobId>],
    opts: &SchedulerOptions,
) -> Prepass {
    let n = graph.len();
    // Always load the existing manifest when one is configured: even with
    // resume off (`--fresh`), saves rewrite the whole file, and entries
    // belonging to *other* repro targets must survive. `opts.resume` only
    // controls whether entries may skip jobs.
    let manifest = match &opts.manifest_path {
        Some(p) => RunManifest::load(p),
        None => RunManifest::default(),
    };

    let mut statuses: Vec<Option<JobStatus>> = (0..n).map(|_| None).collect();
    for (i, spec) in graph.jobs.iter().enumerate() {
        // A train job feeding an eval job never resumes: the payload its
        // dependent needs (the final weights) is not persisted, and eval
        // jobs themselves are never in the manifest, so both re-run.
        let feeds_eval = children[i].iter().any(|&c| graph.get(c).kind == JobKind::Eval);
        if spec.kind == JobKind::Train && spec.persist && opts.resume && !feeds_eval {
            if let Some(s) = manifest.jobs.get(&spec.id) {
                let want = job_settings(spec, &opts.settings, opts.backend);
                if s.settings != want {
                    eprintln!(
                        "[scheduler] not resuming {:?}: recorded under different settings \
                         ({:?} vs {want:?}); re-running",
                        spec.id, s.settings
                    );
                    continue;
                }
                match s.to_result() {
                    Ok(r) => {
                        statuses[i] = Some(JobStatus::Done {
                            result: Some(r),
                            summary: Some(s.clone()),
                            resumed: true,
                        });
                    }
                    Err(e) => eprintln!(
                        "[scheduler] manifest entry {:?} unusable ({e:#}); re-running",
                        spec.id
                    ),
                }
            }
        }
    }
    for (i, spec) in graph.jobs.iter().enumerate() {
        if spec.kind == JobKind::Pretrain
            && !children[i].is_empty()
            && children[i].iter().all(|&c| statuses[c].is_some())
        {
            statuses[i] = Some(JobStatus::Done { result: None, summary: None, resumed: true });
        }
    }
    Prepass { statuses, manifest }
}

/// Execute a graph: resolve resumable jobs from the run manifest, then
/// drive the rest on `opts.jobs` workers (or inline, in plan order, for
/// `--jobs 1`). With `opts.workers > 0` and a distributable graph, the
/// run is dispatched to the coordinator/worker runtime instead; any
/// reason that runtime can't serve it degrades gracefully back here.
pub fn execute(
    graph: &JobGraph,
    opts: &SchedulerOptions,
    runner: &dyn JobRunner,
) -> Result<RunReport> {
    graph.validate()?;
    if opts.workers > 0 {
        match super::coordinator::try_execute(graph, opts)? {
            super::coordinator::Dispatch::Ran(report) => return Ok(report),
            super::coordinator::Dispatch::Fallback(reason) => {
                eprintln!(
                    "[scheduler] coordinator/worker runtime unavailable ({reason}); \
                     falling back to the in-process pool"
                );
            }
        }
    }
    let n = graph.len();
    let children = graph.children();
    let Prepass { statuses, manifest } = resume_prepass(graph, &children, opts);

    let resolved = statuses.iter().filter(|s| s.is_some()).count();
    let remaining = n - resolved;
    let mut payload_consumers = vec![0usize; n];
    for spec in &graph.jobs {
        if let Some(s) = spec.eval_src {
            payload_consumers[s] += 1;
        }
    }
    let mut waiting = vec![0usize; n];
    let mut ready = VecDeque::new();
    for (i, spec) in graph.jobs.iter().enumerate() {
        if statuses[i].is_some() {
            continue;
        }
        waiting[i] = spec.deps.iter().filter(|&&d| statuses[d].is_none()).count();
        if waiting[i] == 0 {
            ready.push_back(i);
        }
    }

    let workers = opts.jobs.max(1).min(remaining.max(1));
    if opts.verbose {
        println!(
            "[scheduler] {n} job(s): {remaining} to run, {resolved} resumed, {workers} worker(s)"
        );
    }

    let core = ExecCore {
        graph,
        children,
        opts,
        state: Mutex::new(ExecState {
            statuses,
            waiting,
            ready,
            checkpoints: HashMap::new(),
            payloads: HashMap::new(),
            payload_consumers,
            remaining,
            manifest,
        }),
        cv: Condvar::new(),
    };

    if workers <= 1 {
        // Strict plan order — today's sequential driver loops, exactly.
        for id in 0..n {
            if core.lock_state().statuses[id].is_some() {
                continue;
            }
            core.run_one(runner, id);
        }
    } else {
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    while let Some(id) = core.next_ready() {
                        core.run_one(runner, id);
                    }
                });
            }
        });
    }

    let st = core.state.into_inner().unwrap_or_else(|p| p.into_inner());
    let statuses: Vec<JobStatus> =
        st.statuses.into_iter().map(|s| s.expect("every job resolved")).collect();
    let report = RunReport { statuses };
    if opts.verbose {
        let (ran, resumed, failed, skipped) = report.counts();
        println!("[scheduler] done: {ran} ran, {resumed} resumed, {failed} failed, {skipped} skipped");
    }
    Ok(report)
}

// ---------------------------------------------------------------------------
// The real runner: jobs over one shared client
// ---------------------------------------------------------------------------

/// Host-side resources for one config — everything derived from the
/// config's `[data]` section and the manifest shapes. Built once per
/// config and shared by every grid cell (plain data, freely `Sync`).
struct HostRes {
    cfg: RepoConfig,
    manifest: Manifest,
    lm: Option<data::LmRows>,
    vlm: Option<data::VlmDataset>,
}

impl HostRes {
    /// `choice` decides where the manifest comes from: the artifact dir
    /// (XLA) or layout synthesis (host) — crucially with *no* client
    /// involved, so this stays a host-phase build outside the device
    /// token.
    fn build(cfg: RepoConfig, choice: BackendChoice) -> Result<Self> {
        let manifest = manifest_for(choice, &cfg)
            .with_context(|| format!("resolving backend for config {}", cfg.name))?;
        let (lm, vlm) = if manifest.is_vlm() {
            (None, Some(data::build_vlm(&cfg, &manifest)?))
        } else {
            (Some(data::build_lm_rows(&cfg, &manifest)?), None)
        };
        Ok(HostRes { cfg, manifest, lm, vlm })
    }
}

/// Device-side per-config caches. On the XLA path everything in here
/// holds PJRT handles with non-atomic refcounts, so access is serialized
/// by the mutex around [`DeviceShared`] — the scheduler's device token.
/// (Host engines are plain data but share the cache and the discipline.)
struct DeviceArena {
    engines: EngineCache,
    /// Device-resident benchmark suites, uploaded once per (config, kind).
    suites: HashMap<(String, EvalKind), Vec<DeviceSuite>>,
}

/// Move-permission wrapper for the device arena.
///
/// SAFETY CONTRACT: the arena's contents (client, compiled executables,
/// device buffers) are `!Send`/`!Sync` because the `xla` binding's
/// handles carry non-atomic refcounts. They are only ever dereferenced
/// while the owning `Mutex` is held, and every object created from them
/// during a job (sessions, uploads, caches) is dropped before that guard
/// is released — so no two threads ever touch the binding concurrently,
/// which is the only invariant the missing `Send` bound protects.
struct DeviceShared(DeviceArena);
unsafe impl Send for DeviceShared {}

/// [`JobRunner`] over real engines: per-config engine/dataset/suite
/// caches over one shared (lazily created) client, warmstart handoff via
/// `Arc`. Backend selection comes from `ExpOptions::backend` — XLA
/// artifacts, the pure-Rust host engine, or auto per config.
pub struct DeviceRunner<'a> {
    opts: &'a ExpOptions,
    device: Mutex<DeviceShared>,
    hosts: Mutex<HashMap<String, Arc<HostRes>>>,
    packed: Mutex<HashMap<(String, EvalKind), Arc<Vec<PackedSuite>>>>,
}

impl<'a> DeviceRunner<'a> {
    /// Runner with empty caches; XLA configs create the shared client on
    /// first use (host-only grids never pay for one).
    pub fn new(opts: &'a ExpOptions) -> Self {
        Self::with_cache(EngineCache::new(opts.backend), opts)
    }

    /// Runner reusing an existing client for XLA loads (benches that
    /// already own one).
    pub fn with_client(client: &Client, opts: &'a ExpOptions) -> Self {
        Self::with_cache(EngineCache::with_client(opts.backend, client.clone()), opts)
    }

    fn with_cache(engines: EngineCache, opts: &'a ExpOptions) -> Self {
        DeviceRunner {
            opts,
            device: Mutex::new(DeviceShared(DeviceArena { engines, suites: HashMap::new() })),
            hosts: Mutex::new(HashMap::new()),
            packed: Mutex::new(HashMap::new()),
        }
    }

    fn lock_device(&self) -> MutexGuard<'_, DeviceShared> {
        self.device.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Per-config host resources (datasets). The map lock is held across
    /// a build: concurrent first-touch of *different* configs serializes,
    /// which is fine — builds are short next to training and this keeps
    /// the cache trivially race-free.
    fn host_res(&self, config: &str) -> Result<Arc<HostRes>> {
        let mut map = self.hosts.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(h) = map.get(config) {
            return Ok(h.clone());
        }
        let h = Arc::new(HostRes::build(RepoConfig::by_name(config)?, self.opts.backend)?);
        map.insert(config.to_string(), h.clone());
        Ok(h)
    }

    /// Packed (host-side) benchmark suites per (config, kind).
    fn packed_suites(
        &self,
        config: &str,
        kind: EvalKind,
        host: &HostRes,
    ) -> Result<Arc<Vec<PackedSuite>>> {
        let key = (config.to_string(), kind);
        let mut map = self.packed.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(p) = map.get(&key) {
            return Ok(p.clone());
        }
        let suites = match kind {
            EvalKind::LmSuites => {
                let lm = host
                    .lm
                    .as_ref()
                    .ok_or_else(|| anyhow!("{config}: LM suites requested for a VLM artifact"))?;
                benchmarks::lm_suites(&lm.vocab, self.opts.bench_seed, self.opts.questions)
            }
            EvalKind::VlmMain | EvalKind::VlmNano => {
                let v = host
                    .vlm
                    .as_ref()
                    .ok_or_else(|| anyhow!("{config}: VLM suites requested for an LM artifact"))?;
                if kind == EvalKind::VlmMain {
                    benchmarks::vlm_suites(
                        &v.scene_cfg,
                        &v.vocab,
                        self.opts.bench_seed,
                        self.opts.questions,
                    )
                } else {
                    benchmarks::nanovlm_suites(
                        &v.scene_cfg,
                        &v.vocab,
                        self.opts.bench_seed,
                        self.opts.questions,
                    )
                }
            }
            EvalKind::None => Vec::new(),
        };
        let packed = Arc::new(
            suites
                .iter()
                .map(|s| PackedSuite::pack(&host.manifest, s))
                .collect::<Result<Vec<_>>>()?,
        );
        map.insert(key, packed.clone());
        Ok(packed)
    }

    /// Device-resident suites for `key`, uploading `packed` once through
    /// a stateless loader session on first use (the device token, held by
    /// the caller, doubles as the upload lock). Shared by train-job
    /// scoring and standalone eval jobs so the cache policy can't diverge.
    fn device_suites<'r>(
        arena: &'r mut DeviceArena,
        backend: &dyn Backend,
        key: (String, EvalKind),
        packed: &[PackedSuite],
    ) -> Result<&'r Vec<DeviceSuite>> {
        if !arena.suites.contains_key(&key) {
            let loader = Session::new(backend);
            let dev: Vec<DeviceSuite> =
                packed.iter().map(|p| p.upload(&loader)).collect::<Result<_>>()?;
            arena.suites.insert(key.clone(), dev);
        }
        Ok(&arena.suites[&key])
    }

    /// Produce the base checkpoint for `config` at `steps` (falling back
    /// to the run-wide override, then the config's own budget) through
    /// the warmstart disk cache. The cache is what lets checkpoints cross
    /// *process* boundaries: the coordinator only assigns a warm-started
    /// job after its pretrain dependency completed somewhere, so a
    /// worker's call here is a disk hit, not a re-train.
    pub fn warm_checkpoint(&self, config: &str, steps: Option<usize>) -> Result<Arc<BaseCheckpoint>> {
        let steps = match steps.or(self.opts.steps_override) {
            Some(s) => s,
            None => RepoConfig::by_name(config)?.run.total_steps,
        };
        let guard = self.lock_device();
        let arena = &guard.0;
        let engine = arena.engines.get(config)?;
        let ck = if engine.manifest().is_vlm() {
            warmstart::pretrain_vlm_checkpoint_with(&*engine, config, steps)?
        } else {
            warmstart::pretrain_checkpoint_with(&*engine, config, steps)?
        };
        Ok(Arc::new(ck))
    }

    fn run_pretrain(&self, spec: &JobSpec) -> Result<RunnerOutput> {
        let ck = self.warm_checkpoint(&spec.config, spec.steps)?;
        if self.opts.verbose {
            println!("[{}] base checkpoint ready ({})", spec.id, ck.source);
        }
        Ok(RunnerOutput { result: None, summary: None, checkpoint: Some(ck), eval_payload: None })
    }

    fn run_train(
        &self,
        spec: &JobSpec,
        warm: Option<Arc<BaseCheckpoint>>,
    ) -> Result<RunnerOutput> {
        // --- host phase: config, datasets, packed suites (no client) ---
        let mut cfg = RepoConfig::by_name(&spec.config)?;
        for p in &spec.patches {
            p.apply(&mut cfg);
        }
        let host = if spec.needs_fresh_data() {
            // A patch invalidated the shared dataset — build privately.
            Arc::new(HostRes::build(cfg.clone(), self.opts.backend)?)
        } else {
            self.host_res(&spec.config)?
        };
        let packed = match spec.eval {
            EvalKind::None => None,
            kind => Some(self.packed_suites(&spec.config, kind, &host)?),
        };

        // --- device phase: everything below holds the device token ---
        let mut guard = self.lock_device();
        let arena = &mut guard.0;
        let engine = arena.engines.get(&spec.config)?;
        let mut topts = TrainerOptions::from_config(&cfg, spec.method);
        topts.warm_start = warm;
        if let Some(s) = spec.steps.or(self.opts.steps_override) {
            topts.total_steps = s;
        }
        if let Some(p) = spec.probe_every {
            topts.probe_every = p;
        }
        let trained = if engine.manifest().is_vlm() {
            let v = host
                .vlm
                .as_ref()
                .ok_or_else(|| anyhow!("{}: VLM artifact without VLM dataset", spec.config))?;
            let mut source = Prefetcher::spawn(
                FixedCycle::new(v.train.clone()),
                topts.pipeline.prefetch_batches,
            );
            trainer::run_source_and_keep(&*engine, &cfg, &topts, &mut source, &v.val)?
        } else {
            let rows = host
                .lm
                .as_ref()
                .ok_or_else(|| anyhow!("{}: LM artifact without LM dataset", spec.config))?;
            let mut source = Prefetcher::spawn(
                data::lm_train_iter(rows, &cfg, engine.manifest()),
                topts.pipeline.prefetch_batches,
            );
            trainer::run_source_and_keep(&*engine, &cfg, &topts, &mut source, &rows.val)?
        };
        let accuracies = match spec.eval {
            EvalKind::None => Vec::new(),
            kind => {
                let key = (spec.config.clone(), kind);
                let packed = packed.as_ref().expect("packed suites built above");
                let suites = Self::device_suites(arena, &*engine, key, packed)?;
                harness::score_device_suites(&trained.session, suites)?
            }
        };
        if self.opts.verbose {
            let o = &trained.outcome;
            let avg = accuracies.last().map(|a| a.1).unwrap_or(f64::NAN);
            println!(
                "[{}] steps={} wall={:.2}s val_loss={:.4} frozen={}/{} avg_acc={avg:.2}%",
                spec.id,
                o.steps_run,
                o.wall_secs,
                o.final_val_loss,
                o.freeze.n_frozen(),
                o.freeze.n(),
            );
        }
        // Dependent eval jobs consume the final weights as host data —
        // downloaded once here, while we still hold the device token.
        let eval_payload = if spec.export_state {
            Some(Arc::new(EvalPayload {
                config: spec.config.clone(),
                step: trained.session.step,
                state: trained.session.state_to_host()?,
            }))
        } else {
            None
        };
        let result = JobResult {
            config: spec.config.clone(),
            method: spec.method,
            outcome: trained.outcome,
            accuracies,
        };
        let summary = spec.persist.then(|| {
            JobSummary::from_result(
                spec,
                &result,
                engine.manifest(),
                &job_settings(spec, &self.opts.settings_fingerprint(), self.opts.backend),
                engine.name(),
            )
        });
        Ok(RunnerOutput { result: Some(result), summary, checkpoint: None, eval_payload })
    }

    /// A [`JobKind::Eval`] job: rehydrate the source train job's final
    /// weights into a fresh session and score the benchmark suites. The
    /// device token is held only for the (cheap) scoring pass — training
    /// wall time and scoring wall time decouple on the worker pool.
    fn run_eval(
        &self,
        spec: &JobSpec,
        src: Option<Arc<EvalPayload>>,
    ) -> Result<RunnerOutput> {
        let payload =
            src.ok_or_else(|| anyhow!("{}: eval job without source weights", spec.id))?;
        ensure!(
            payload.config == spec.config,
            "{}: source weights are for config {:?}, not {:?}",
            spec.id,
            payload.config,
            spec.config
        );
        // --- host phase: packed suites (no client) ---
        let host = self.host_res(&spec.config)?;
        let packed = self.packed_suites(&spec.config, spec.eval, &host)?;

        // --- device phase ---
        let mut guard = self.lock_device();
        let arena = &mut guard.0;
        let engine = arena.engines.get(&spec.config)?;
        let mut session = Session::new(&*engine);
        session.state_from_host(&payload.state)?;
        session.step = payload.step;
        let key = (spec.config.clone(), spec.eval);
        let suites = Self::device_suites(arena, &*engine, key, &packed)?;
        let accuracies = harness::score_device_suites(&session, suites)?;
        if self.opts.verbose {
            let avg = accuracies.last().map(|a| a.1).unwrap_or(f64::NAN);
            println!("[{}] scored at step {}: avg_acc={avg:.2}%", spec.id, payload.step);
        }
        // A minimal outcome: eval jobs train nothing, so only the
        // accuracies (and the source step) carry information.
        let outcome = TrainOutcome {
            steps_run: payload.step,
            stop_cause: StopCause::BudgetExhausted,
            wall_secs: f64::NAN,
            validation_secs: 0.0,
            monitor_secs: 0.0,
            flops: crate::coordinator::flops::FlopsCounter::default(),
            log: MetricsLog::default(),
            freeze: FreezeState::new(0),
            final_val_loss: f64::NAN,
            variant_swap_step: None,
            plan: Default::default(),
            timings: Default::default(),
            async_eval: Default::default(),
        };
        let result = JobResult {
            config: spec.config.clone(),
            method: spec.method,
            outcome,
            accuracies,
        };
        Ok(RunnerOutput { result: Some(result), summary: None, checkpoint: None, eval_payload: None })
    }
}

impl JobRunner for DeviceRunner<'_> {
    fn run(
        &self,
        spec: &JobSpec,
        warm: Option<Arc<BaseCheckpoint>>,
        eval_src: Option<Arc<EvalPayload>>,
    ) -> Result<RunnerOutput> {
        match spec.kind {
            JobKind::Pretrain => self.run_pretrain(spec),
            JobKind::Train => self.run_train(spec, warm),
            JobKind::Eval => self.run_eval(spec, eval_src),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_summary() -> JobSummary {
        JobSummary {
            id: "ablation/x/tau=0.05,alpha=0.3".into(),
            config: "lm-tiny-fp".into(),
            settings: "g|steps=None|probe=None".into(),
            backend: "host".into(),
            method: "grades".into(),
            steps_run: 120,
            stop_cause: "frozen".into(),
            wall_secs: 3.25,
            validation_secs: 0.5,
            monitor_secs: 0.1,
            final_val_loss: 2.75,
            variant_swap_step: Some(80),
            flops_spent: 1.5e9,
            flops_realized: 1.7e9,
            flops_dense: 2.0e9,
            flops_validation: 1.0e8,
            flops_steps: 120,
            n_components: 14,
            frozen: vec![0, 3, 7],
            accuracies: vec![("AgreeDet".into(), 61.5), ("Avg.".into(), 58.25)],
            frozen_series: vec![(10, 0.0), (120, 0.9)],
            tower_gabs: None,
            val_checks: 2,
            attempts: 1,
        }
    }

    #[test]
    fn summary_json_round_trip() {
        let s = sample_summary();
        let back = JobSummary::from_json(&json::parse(&json::write(&s.to_json())).unwrap())
            .unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn summary_round_trip_with_nan_and_towers() {
        let mut s = sample_summary();
        s.final_val_loss = f64::NAN;
        s.variant_swap_step = None;
        s.tower_gabs = Some((vec![(1.0, 0.5)], vec![(1.0, 1.25)]));
        let back = JobSummary::from_json(&json::parse(&json::write(&s.to_json())).unwrap())
            .unwrap();
        assert!(back.final_val_loss.is_nan());
        assert_eq!(back.variant_swap_step, None);
        assert_eq!(back.tower_gabs, s.tower_gabs);
    }

    #[test]
    fn summary_reconstructs_result() {
        let s = sample_summary();
        let r = s.to_result().unwrap();
        assert_eq!(r.method, StoppingMethod::GradEs);
        assert_eq!(r.outcome.steps_run, 120);
        assert_eq!(r.outcome.stop_cause, StopCause::AllComponentsFrozen);
        assert_eq!(r.outcome.freeze.n_frozen(), 3);
        assert_eq!(r.outcome.freeze.n(), 14);
        assert!((r.outcome.flops.total() - 1.5e9).abs() < 1.0);
        assert_eq!(r.accuracies.last().unwrap().1, 58.25);
        // the fig3 series survives as log records
        let pts: Vec<(usize, f64)> =
            r.outcome.log.records.iter().map(|x| (x.step, x.frozen_fraction)).collect();
        assert_eq!(pts, vec![(10, 0.0), (120, 0.9)]);
    }

    #[test]
    fn manifest_parse_rejects_bad_version_and_tolerates_bad_entries() {
        assert!(RunManifest::parse(r#"{"version": 2, "jobs": {}}"#).is_err());
        // one broken entry is skipped, the good one survives
        let good = sample_summary();
        let mut m = RunManifest::default();
        m.jobs.insert(good.id.clone(), good.clone());
        let mut src = m.render();
        src = src.replace("\"jobs\":{", "\"jobs\":{\"broken\":{\"id\":\"broken\"},");
        let parsed = RunManifest::parse(&src).unwrap();
        assert_eq!(parsed.jobs.len(), 1);
        assert_eq!(parsed.jobs[&good.id], good);
    }

    #[test]
    fn manifest_load_missing_is_empty() {
        let m = RunManifest::load(Path::new("/nonexistent/definitely/run_manifest.json"));
        assert!(m.jobs.is_empty());
    }

    #[test]
    fn manifest_load_garbled_file_degrades_to_fresh() {
        let dir = std::env::temp_dir().join("grades_sched_garbled_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run_manifest.json");
        // a truncated save: valid prefix of a real document
        let full = {
            let mut m = RunManifest::default();
            let s = sample_summary();
            m.jobs.insert(s.id.clone(), s);
            m.render()
        };
        for garbled in [&full[..full.len() / 2], "{not json at all", ""] {
            std::fs::write(&path, garbled).unwrap();
            let m = RunManifest::load(&path);
            assert!(m.jobs.is_empty(), "corrupt manifest must load as empty: {garbled:?}");
            assert!(m.faults.is_empty());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_fault_ledger_round_trips_and_old_files_parse() {
        let mut m = RunManifest::default();
        let s = sample_summary();
        m.jobs.insert(s.id.clone(), s);
        m.faults.insert(
            "grid/b".into(),
            FaultRecord { attempts: 2, last_error: "worker 1 died: lease expired".into() },
        );
        let back = RunManifest::parse(&m.render()).unwrap();
        assert_eq!(back.faults, m.faults);
        assert_eq!(back.jobs.len(), 1);
        // a fault-free manifest omits the key entirely (old schema)
        let clean = RunManifest::default();
        assert!(!clean.render().contains("faults"));
        assert!(RunManifest::parse(&clean.render()).unwrap().faults.is_empty());
    }

    #[test]
    fn summary_without_attempts_field_defaults_to_one() {
        let mut s = sample_summary();
        s.attempts = 3;
        let mut j = s.to_json();
        let back = JobSummary::from_json(&j).unwrap();
        assert_eq!(back.attempts, 3);
        if let Json::Obj(m) = &mut j {
            m.remove("attempts");
        }
        assert_eq!(JobSummary::from_json(&j).unwrap().attempts, 1);
    }

    #[test]
    fn retry_policy_backoff_is_exponential_and_capped() {
        let p = RetryPolicy { max_attempts: 5, backoff_base_ms: 100, backoff_max_ms: 1_000 };
        assert_eq!(p.delay(1).as_millis(), 100);
        assert_eq!(p.delay(2).as_millis(), 200);
        assert_eq!(p.delay(3).as_millis(), 400);
        assert_eq!(p.delay(5).as_millis(), 1_000);
        assert_eq!(p.delay(60).as_millis(), 1_000); // no shift overflow
    }

    #[test]
    fn resolve_workers_precedence() {
        assert_eq!(resolve_workers(None, None), 0);
        assert_eq!(resolve_workers(None, Some("4")), 4);
        assert_eq!(resolve_workers(Some(2), Some("4")), 2);
        assert_eq!(resolve_workers(Some(0), Some("4")), 0);
        assert_eq!(resolve_workers(None, Some("junk")), 0);
    }

    #[test]
    fn manifest_save_then_load_round_trips() {
        let dir = std::env::temp_dir().join("grades_sched_manifest_test");
        let path = dir.join("run_manifest.json");
        let mut m = RunManifest::default();
        let s = sample_summary();
        m.jobs.insert(s.id.clone(), s.clone());
        m.save(&path).unwrap();
        let back = RunManifest::load(&path);
        assert_eq!(back.jobs[&s.id], s);
        // tmp file is gone after the atomic rename
        assert!(!path.with_extension("json.tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn summary_without_settings_field_cannot_match_a_fingerprint() {
        let s = sample_summary();
        let mut j = s.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("settings");
            // pre-plan manifests also lack the backend + realized-FLOPs
            // fields; both must come back as explicit placeholders
            m.remove("backend");
            m.remove("flops_realized");
        }
        let back = JobSummary::from_json(&j).unwrap();
        assert_eq!(back.settings, "<unrecorded>");
        assert_eq!(back.backend, "<unrecorded>");
        assert!(back.flops_realized.is_nan());
    }

    #[test]
    fn summary_records_the_resolved_backend() {
        let s = sample_summary();
        let back = JobSummary::from_json(&json::parse(&json::write(&s.to_json())).unwrap())
            .unwrap();
        assert_eq!(back.backend, "host");
        assert_eq!(back.flops_realized, 1.7e9);
    }

    #[test]
    fn job_settings_composes_global_spec_and_resolved_backend() {
        // explicit choices resolve to themselves regardless of what
        // artifacts exist on disk — keeps this test filesystem-free
        let spec =
            JobSpec::train("x", "c", StoppingMethod::GradEs, EvalKind::None).with_steps(40);
        assert_eq!(
            job_settings(&spec, "G", BackendChoice::Host),
            "G|steps=Some(40)|probe=None|be=host|m=grades"
        );
        let plain = JobSpec::train("y", "c", StoppingMethod::GradEs, EvalKind::None);
        assert_eq!(
            job_settings(&plain, "", BackendChoice::Xla),
            "|steps=None|probe=None|be=xla|m=grades"
        );
        // a host cell can never satisfy an xla run's expectation
        assert_ne!(
            job_settings(&plain, "", BackendChoice::Xla),
            job_settings(&plain, "", BackendChoice::Host)
        );
    }

    #[test]
    fn resolve_jobs_precedence() {
        assert_eq!(resolve_jobs(None, None), 1);
        assert_eq!(resolve_jobs(None, Some("6")), 6);
        assert_eq!(resolve_jobs(Some(3), Some("6")), 3);
        assert_eq!(resolve_jobs(Some(0), None), 1);
        assert_eq!(resolve_jobs(None, Some("junk")), 1);
    }

    #[test]
    fn stop_cause_round_trip() {
        for c in [
            StopCause::BudgetExhausted,
            StopCause::AllComponentsFrozen,
            StopCause::ValidationPatience,
        ] {
            assert_eq!(parse_stop_cause(stop_cause_str(c)).unwrap(), c);
        }
        assert!(parse_stop_cause("nope").is_err());
    }
}
