//! Declarative experiment plans: jobs as data.
//!
//! A [`JobSpec`] names *what* to run — config, stopping method, config
//! mutations (τ/α overrides, metric/granularity swaps), eval-suite kind —
//! and a [`JobGraph`] wires specs together with dependency edges (a
//! pretrain job feeding its `BaseCheckpoint` to the fine-tuning jobs that
//! consume it). The graph is pure host data: building and validating one
//! touches no client, which is what makes the scheduler's ordering,
//! resume and equality properties testable without artifacts.
//!
//! Invariant: a job's dependencies must already be in the graph when the
//! job is added, so `deps[i] < i` always holds — insertion order is a
//! topological order, cycles are unrepresentable, and the `--jobs 1`
//! executor can simply walk the vector.

use anyhow::{bail, ensure, Result};

use crate::config::RepoConfig;
use crate::coordinator::trainer::{StoppingMethod, ALL_METHODS};

/// Index of a job inside its [`JobGraph`].
pub type JobId = usize;

/// A single config mutation applied on top of the named config before a
/// job runs (the ablation grid's τ×α cells, the design-choice swaps).
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigPatch {
    /// Override `[grades].tau`.
    Tau(f64),
    /// Override `[grades].alpha` (grace fraction).
    Alpha(f64),
    /// Override the monitored metric (l1_diff / l1_abs / l1_diff_rel).
    Metric(String),
    /// Override freeze granularity ("matrix" / "layer").
    Granularity(String),
}

impl ConfigPatch {
    /// Apply the mutation to a loaded config.
    pub fn apply(&self, cfg: &mut RepoConfig) {
        match self {
            ConfigPatch::Tau(v) => cfg.grades.tau = *v,
            ConfigPatch::Alpha(v) => cfg.grades.alpha = *v,
            ConfigPatch::Metric(s) => cfg.grades.metric = s.clone(),
            ConfigPatch::Granularity(s) => cfg.grades.granularity = s.clone(),
        }
    }

    /// Stable key fragment for job ids ("tau=0.05").
    pub fn key(&self) -> String {
        match self {
            ConfigPatch::Tau(v) => format!("tau={v}"),
            ConfigPatch::Alpha(v) => format!("alpha={v}"),
            ConfigPatch::Metric(s) => format!("metric={s}"),
            ConfigPatch::Granularity(s) => format!("granularity={s}"),
        }
    }

    /// Does this patch change what the *dataset* looks like? Every patch
    /// today targets `[grades]`, so per-config datasets can be shared
    /// across all cells of a grid; any future patch touching `[data]` or
    /// the model shapes must return true here so the scheduler bypasses
    /// its row cache for that job.
    pub fn affects_data(&self) -> bool {
        match self {
            ConfigPatch::Tau(_)
            | ConfigPatch::Alpha(_)
            | ConfigPatch::Metric(_)
            | ConfigPatch::Granularity(_) => false,
        }
    }

    /// Inverse of [`ConfigPatch::key`] (`"tau=0.05"` → `Tau(0.05)`) — the
    /// form patches travel over the coordinator/worker wire in.
    pub fn parse_key(s: &str) -> Result<Self> {
        let (k, v) = match s.split_once('=') {
            Some(kv) => kv,
            None => bail!("config patch {s:?} is not key=value"),
        };
        match k {
            "tau" => Ok(ConfigPatch::Tau(v.parse()?)),
            "alpha" => Ok(ConfigPatch::Alpha(v.parse()?)),
            "metric" => Ok(ConfigPatch::Metric(v.to_string())),
            "granularity" => Ok(ConfigPatch::Granularity(v.to_string())),
            other => bail!("unknown config patch kind {other:?}"),
        }
    }
}

/// Which benchmark suites to score a trained job on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EvalKind {
    /// The 8 LM paper-benchmark analogues (Table 1 row shape).
    LmSuites,
    /// Table 2: GQA/VQAv2/COCO analogues.
    VlmMain,
    /// Table 3: six nanoVLM-style categories.
    VlmNano,
    /// No scoring (pretrain jobs, figure-only runs).
    None,
}

impl EvalKind {
    /// Stable wire label (the coordinator/worker protocol and the run
    /// manifest both speak strings, not enum discriminants).
    pub fn label(&self) -> &'static str {
        match self {
            EvalKind::LmSuites => "lm",
            EvalKind::VlmMain => "vlm_main",
            EvalKind::VlmNano => "vlm_nano",
            EvalKind::None => "none",
        }
    }

    /// Inverse of [`EvalKind::label`].
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "lm" => Some(EvalKind::LmSuites),
            "vlm_main" => Some(EvalKind::VlmMain),
            "vlm_nano" => Some(EvalKind::VlmNano),
            "none" => Some(EvalKind::None),
            _ => None,
        }
    }
}

/// What a job fundamentally does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    /// Produce a base checkpoint for dependents (LM or VLM is decided by
    /// the artifact's manifest at execution time).
    Pretrain,
    /// Fine-tune (optionally from a warm checkpoint) and score.
    Train,
    /// Score a finished [`JobKind::Train`] job's final weights on its
    /// benchmark suites, as a job of its own. Decouples scoring from
    /// training on the worker pool: the train job releases the device
    /// token as soon as training ends and hands its weights across
    /// threads as plain host data (the scheduler's `EvalPayload`), so
    /// the eval chunk can run — and even outlive — the training job on
    /// any worker (the async-eval runtime's scheduler-level half).
    Eval,
}

impl JobKind {
    /// Stable wire label.
    pub fn label(&self) -> &'static str {
        match self {
            JobKind::Pretrain => "pretrain",
            JobKind::Train => "train",
            JobKind::Eval => "eval",
        }
    }

    /// Inverse of [`JobKind::label`].
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "pretrain" => Some(JobKind::Pretrain),
            "train" => Some(JobKind::Train),
            "eval" => Some(JobKind::Eval),
            _ => None,
        }
    }
}

/// One experiment job, declared as data.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Unique, stable id — the run-manifest key a resumed run matches on.
    pub id: String,
    /// Config / artifact name (`configs/<name>.toml`, `artifacts/<name>/`).
    pub config: String,
    /// Stopping rule the job trains under.
    pub method: StoppingMethod,
    /// Config mutations applied before the run.
    pub patches: Vec<ConfigPatch>,
    /// Benchmark suites to score (None = skip scoring).
    pub eval: EvalKind,
    /// Pretrain / train / standalone eval.
    pub kind: JobKind,
    /// Jobs that must complete before this one starts.
    pub deps: Vec<JobId>,
    /// Dependency whose checkpoint warm-starts this job (must be in `deps`).
    pub warm_from: Option<JobId>,
    /// [`JobKind::Eval`] only: the train job whose final weights this
    /// job scores (must be in `deps`).
    pub eval_src: Option<JobId>,
    /// Export this job's final weights as an `EvalPayload` for dependent
    /// [`JobKind::Eval`] jobs. Set automatically by [`JobGraph::add`]
    /// when an eval job names this job as its source.
    pub export_state: bool,
    /// Per-job total-steps override; takes precedence over the global
    /// `ExpOptions::steps_override`.
    pub steps: Option<usize>,
    /// Probe-cadence override (figure jobs probe every step).
    pub probe_every: Option<usize>,
    /// Persist the result to the run manifest and skip the job when a
    /// resumed run already has it. Figure-series jobs opt out: their value
    /// is the full in-memory metrics log, which the manifest doesn't keep.
    pub persist: bool,
}

impl JobSpec {
    /// A base-checkpoint job feeding dependents (never persisted: resume
    /// goes through the warmstart disk cache instead).
    pub fn pretrain(id: impl Into<String>, config: impl Into<String>) -> Self {
        JobSpec {
            id: id.into(),
            config: config.into(),
            method: StoppingMethod::None,
            patches: Vec::new(),
            eval: EvalKind::None,
            kind: JobKind::Pretrain,
            deps: Vec::new(),
            warm_from: None,
            eval_src: None,
            export_state: false,
            steps: None,
            probe_every: None,
            persist: false,
        }
    }

    /// A fine-tune-and-score job (the grid-cell workhorse; persisted to
    /// the run manifest by default).
    pub fn train(
        id: impl Into<String>,
        config: impl Into<String>,
        method: StoppingMethod,
        eval: EvalKind,
    ) -> Self {
        JobSpec {
            id: id.into(),
            config: config.into(),
            method,
            patches: Vec::new(),
            eval,
            kind: JobKind::Train,
            deps: Vec::new(),
            warm_from: None,
            eval_src: None,
            export_state: false,
            steps: None,
            probe_every: None,
            persist: true,
        }
    }

    /// A standalone benchmark-evaluation job scoring `src`'s final
    /// weights on the `eval` suites (see [`JobKind::Eval`]). `src` must
    /// be a [`JobSpec::train`] job already in the graph; [`JobGraph::add`]
    /// marks it to export its weights. Not persisted: the scoring is
    /// cheap next to training and its inputs live only in memory.
    pub fn score(
        id: impl Into<String>,
        config: impl Into<String>,
        eval: EvalKind,
        src: JobId,
    ) -> Self {
        JobSpec {
            id: id.into(),
            config: config.into(),
            method: StoppingMethod::None,
            patches: Vec::new(),
            eval,
            kind: JobKind::Eval,
            deps: vec![src],
            warm_from: None,
            eval_src: Some(src),
            export_state: false,
            steps: None,
            probe_every: None,
            persist: false,
        }
    }

    /// Set the config mutations.
    pub fn with_patches(mut self, patches: Vec<ConfigPatch>) -> Self {
        self.patches = patches;
        self
    }

    /// Warm-start from `dep`'s checkpoint (also records the edge).
    pub fn warm(mut self, dep: JobId) -> Self {
        if !self.deps.contains(&dep) {
            self.deps.push(dep);
        }
        self.warm_from = Some(dep);
        self
    }

    /// Add a plain ordering dependency.
    pub fn after(mut self, dep: JobId) -> Self {
        if !self.deps.contains(&dep) {
            self.deps.push(dep);
        }
        self
    }

    /// Per-job total-steps override.
    pub fn with_steps(mut self, steps: usize) -> Self {
        self.steps = Some(steps);
        self
    }

    /// Probe-cadence override.
    pub fn with_probe_every(mut self, every: usize) -> Self {
        self.probe_every = Some(every);
        self
    }

    /// Never persist/resume this job (see [`JobSpec::persist`]).
    pub fn ephemeral(mut self) -> Self {
        self.persist = false;
        self
    }

    /// Do any of this job's patches invalidate a shared per-config dataset?
    pub fn needs_fresh_data(&self) -> bool {
        self.patches.iter().any(|p| p.affects_data())
    }
}

/// A dependency-ordered set of jobs.
///
/// ```
/// use grades::coordinator::trainer::StoppingMethod;
/// use grades::exp::plan::{EvalKind, JobGraph, JobSpec};
///
/// let mut g = JobGraph::new();
/// let pre = g.add(JobSpec::pretrain("pre", "lm-tiny-fp")).unwrap();
/// let ft = g
///     .add(
///         JobSpec::train("ft", "lm-tiny-fp", StoppingMethod::GradEs, EvalKind::LmSuites)
///             .warm(pre),
///     )
///     .unwrap();
/// let eval = g.add(JobSpec::score("ft/eval", "lm-tiny-fp", EvalKind::LmSuites, ft)).unwrap();
/// assert_eq!(g.children()[pre], vec![ft]);
/// assert_eq!(g.children()[ft], vec![eval]);
/// assert!(g.get(ft).export_state, "the eval job marked its source");
/// g.validate().unwrap();
/// ```
#[derive(Debug, Default)]
pub struct JobGraph {
    /// Specs in insertion (= topological) order.
    pub jobs: Vec<JobSpec>,
}

impl JobGraph {
    /// An empty graph.
    pub fn new() -> Self {
        JobGraph::default()
    }

    /// Add a spec; its deps must already be present (acyclic by
    /// construction) and its id unique. Adding a [`JobKind::Eval`] job
    /// flips `export_state` on its source train job so the runner knows
    /// to hand the final weights across.
    pub fn add(&mut self, spec: JobSpec) -> Result<JobId> {
        let idx = self.jobs.len();
        for &d in &spec.deps {
            ensure!(d < idx, "job {:?}: dependency {d} not yet in graph", spec.id);
        }
        if let Some(w) = spec.warm_from {
            ensure!(spec.deps.contains(&w), "job {:?}: warm_from {w} missing from deps", spec.id);
        }
        if spec.kind == JobKind::Eval {
            ensure!(spec.eval != EvalKind::None, "eval job {:?} scores no suites", spec.id);
            let s = match spec.eval_src {
                Some(s) => s,
                None => bail!("eval job {:?} names no source train job", spec.id),
            };
            ensure!(spec.deps.contains(&s), "job {:?}: eval_src {s} missing from deps", spec.id);
            ensure!(
                self.jobs[s].kind == JobKind::Train,
                "job {:?}: eval_src {:?} is not a train job",
                spec.id,
                self.jobs[s].id
            );
        }
        if self.jobs.iter().any(|j| j.id == spec.id) {
            bail!("duplicate job id {:?}", spec.id);
        }
        if let (JobKind::Eval, Some(s)) = (spec.kind, spec.eval_src) {
            self.jobs[s].export_state = true;
        }
        self.jobs.push(spec);
        Ok(idx)
    }

    /// The spec at `id`.
    pub fn get(&self, id: JobId) -> &JobSpec {
        &self.jobs[id]
    }

    /// Job count.
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    /// True when no jobs were added.
    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Direct dependents of each job.
    pub fn children(&self) -> Vec<Vec<JobId>> {
        let mut out = vec![Vec::new(); self.jobs.len()];
        for (i, j) in self.jobs.iter().enumerate() {
            for &d in &j.deps {
                out[d].push(i);
            }
        }
        out
    }

    /// Re-check the construction invariants (defense for hand-built specs).
    pub fn validate(&self) -> Result<()> {
        let mut seen = std::collections::BTreeSet::new();
        for (i, j) in self.jobs.iter().enumerate() {
            ensure!(seen.insert(&j.id), "duplicate job id {:?}", j.id);
            for &d in &j.deps {
                ensure!(d < i, "job {:?} depends forward on {d}", j.id);
            }
            if let Some(w) = j.warm_from {
                ensure!(j.deps.contains(&w), "job {:?}: warm_from not a dep", j.id);
            }
            if j.kind == JobKind::Eval {
                let s = j.eval_src;
                ensure!(s.is_some(), "eval job {:?} names no source", j.id);
                let s = s.unwrap();
                ensure!(j.deps.contains(&s), "job {:?}: eval_src not a dep", j.id);
                ensure!(
                    self.jobs[s].kind == JobKind::Train && self.jobs[s].export_state,
                    "job {:?}: eval_src does not export its weights",
                    j.id
                );
                ensure!(j.eval != EvalKind::None, "eval job {:?} scores no suites", j.id);
            }
        }
        Ok(())
    }

    /// Unique config names in first-use order.
    pub fn configs(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for j in &self.jobs {
            if !out.iter().any(|c| *c == j.config) {
                out.push(j.config.clone());
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Plan builders — one per experiment driver.
// ---------------------------------------------------------------------------

/// Slots mapping LM-matrix jobs back to their table positions.
pub struct MatrixSlots {
    /// (scale display name, artifact method "fp"/"lora", job).
    pub jobs: Vec<(String, String, JobId)>,
}

const MATRIX_METHODS: [StoppingMethod; 3] =
    [StoppingMethod::None, StoppingMethod::ClassicEs, StoppingMethod::GradEs];

/// Tables 1 & 4 + Figure 3: per scale, one pretrain feeding
/// {fp, lora} × {base, +ES, +GradES}.
pub fn lm_matrix_plan(scales: &[(&str, &str, &str)]) -> Result<(JobGraph, MatrixSlots)> {
    let mut g = JobGraph::new();
    let mut slots = MatrixSlots { jobs: Vec::new() };
    for (display, fp_cfg, lora_cfg) in scales {
        let pre = g.add(JobSpec::pretrain(format!("lm/{fp_cfg}/pretrain"), *fp_cfg))?;
        for (am, cfg_name) in [("fp", *fp_cfg), ("lora", *lora_cfg)] {
            for method in MATRIX_METHODS {
                let id = g.add(
                    JobSpec::train(
                        format!("lm/{cfg_name}/{}", method.label()),
                        cfg_name,
                        method,
                        EvalKind::LmSuites,
                    )
                    .warm(pre),
                )?;
                slots.jobs.push((display.to_string(), am.to_string(), id));
            }
        }
    }
    Ok((g, slots))
}

/// Slots for the VLM driver (Tables 2/3/5, Figure 4b).
pub struct VlmSlots {
    /// Table 2/5 jobs: (artifact method, job), in render order.
    pub main: Vec<(String, JobId)>,
    /// Table 3's vlm-nano baseline job.
    pub nano_base: JobId,
    /// Table 3's vlm-nano +GradES job.
    pub nano_grades: JobId,
}

/// Tables 2/5 on vlm-tiny {fp, lora} × {base, +GradES}, plus the
/// vlm-nano ± GradES pair for Table 3. `pre_steps` is the pretrain budget
/// (the driver passes `steps_override.unwrap_or(300)`, matching the
/// pre-scheduler behaviour).
pub fn vlm_plan(pre_steps: usize) -> Result<(JobGraph, VlmSlots)> {
    let mut g = JobGraph::new();
    let pre =
        g.add(JobSpec::pretrain("vlm/vlm-tiny-fp/pretrain", "vlm-tiny-fp").with_steps(pre_steps))?;
    let mut main = Vec::new();
    for (am, cfg_name) in [("fp", "vlm-tiny-fp"), ("lora", "vlm-tiny-lora")] {
        for method in [StoppingMethod::None, StoppingMethod::GradEs] {
            let id = g.add(
                JobSpec::train(
                    format!("vlm/{cfg_name}/{}", method.label()),
                    cfg_name,
                    method,
                    EvalKind::VlmMain,
                )
                .warm(pre),
            )?;
            main.push((am.to_string(), id));
        }
    }
    let nano_pre =
        g.add(JobSpec::pretrain("vlm/vlm-nano/pretrain", "vlm-nano").with_steps(pre_steps))?;
    let nano_base = g.add(
        JobSpec::train("vlm/vlm-nano/base", "vlm-nano", StoppingMethod::None, EvalKind::VlmNano)
            .warm(nano_pre),
    )?;
    let nano_grades = g.add(
        JobSpec::train("vlm/vlm-nano/grades", "vlm-nano", StoppingMethod::GradEs, EvalKind::VlmNano)
            .warm(nano_pre),
    )?;
    Ok((g, VlmSlots { main, nano_base, nano_grades }))
}

/// Slots for the ablation driver (Tables 6 & 7 + design-choice tables).
pub struct AblationSlots {
    /// Row-major τ×α grid job ids (τ outer, α inner).
    pub grid: Vec<JobId>,
    /// (metric name, job) pairs.
    pub metric: Vec<(String, JobId)>,
    /// (granularity name, job) pairs.
    pub granularity: Vec<(String, JobId)>,
    /// (method label, job) pairs — the stopping-method zoo, one job per
    /// [`StoppingMethod`] on the same config.
    pub zoo: Vec<(String, JobId)>,
}

/// The τ×α grid, the metric / granularity design ablations, and the
/// stopping-method zoo (every [`StoppingMethod`] head-to-head), all on
/// one config. Every cell shares the config's compiled bundle, dataset
/// rows and device-resident suites through the scheduler's per-config
/// caches.
pub fn ablation_plan(
    config_name: &str,
    taus: &[f64],
    alphas: &[f64],
) -> Result<(JobGraph, AblationSlots)> {
    let mut g = JobGraph::new();
    let mut grid = Vec::new();
    for &tau in taus {
        for &alpha in alphas {
            let patches = vec![ConfigPatch::Tau(tau), ConfigPatch::Alpha(alpha)];
            let id = format!(
                "ablation/{config_name}/{}",
                patches.iter().map(ConfigPatch::key).collect::<Vec<_>>().join(",")
            );
            grid.push(g.add(
                JobSpec::train(id, config_name, StoppingMethod::GradEs, EvalKind::LmSuites)
                    .with_patches(patches),
            )?);
        }
    }
    let mut metric = Vec::new();
    for m in ["l1_diff", "l1_abs"] {
        let patch = ConfigPatch::Metric(m.to_string());
        let id = format!("ablation/{config_name}/{}", patch.key());
        metric.push((
            m.to_string(),
            g.add(
                JobSpec::train(id, config_name, StoppingMethod::GradEs, EvalKind::LmSuites)
                    .with_patches(vec![patch]),
            )?,
        ));
    }
    let mut granularity = Vec::new();
    for gr in ["matrix", "layer"] {
        let patch = ConfigPatch::Granularity(gr.to_string());
        let id = format!("ablation/{config_name}/{}", patch.key());
        granularity.push((
            gr.to_string(),
            g.add(
                JobSpec::train(id, config_name, StoppingMethod::GradEs, EvalKind::LmSuites)
                    .with_patches(vec![patch]),
            )?,
        ));
    }
    let mut zoo = Vec::new();
    for method in ALL_METHODS {
        let id = format!("ablation/{config_name}/zoo/{}", method.label());
        zoo.push((
            method.label().to_string(),
            g.add(JobSpec::train(id, config_name, method, EvalKind::LmSuites))?,
        ));
    }
    Ok((g, AblationSlots { grid, metric, granularity, zoo }))
}

/// Figures 1 & 4a: a single monitor-off run probing every step. The job
/// is ephemeral — its value is the full per-step metrics log, which the
/// run manifest doesn't persist.
pub fn fig1_plan(config_name: &str) -> Result<(JobGraph, JobId)> {
    let mut g = JobGraph::new();
    let id = g.add(
        JobSpec::train(
            format!("fig1/{config_name}"),
            config_name,
            StoppingMethod::None,
            EvalKind::None,
        )
        .with_probe_every(1)
        .ephemeral(),
    )?;
    Ok((g, id))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn patches_apply_and_key() {
        let mut cfg = RepoConfig::by_name("lm-tiny-fp").unwrap();
        ConfigPatch::Tau(0.2).apply(&mut cfg);
        ConfigPatch::Alpha(0.6).apply(&mut cfg);
        ConfigPatch::Metric("l1_abs".into()).apply(&mut cfg);
        ConfigPatch::Granularity("layer".into()).apply(&mut cfg);
        assert!((cfg.grades.tau - 0.2).abs() < 1e-12);
        assert!((cfg.grades.alpha - 0.6).abs() < 1e-12);
        assert_eq!(cfg.grades.metric, "l1_abs");
        assert_eq!(cfg.grades.granularity, "layer");
        assert_eq!(ConfigPatch::Tau(0.05).key(), "tau=0.05");
        assert!(!ConfigPatch::Tau(0.05).affects_data());
    }

    #[test]
    fn patch_key_round_trips_and_rejects_junk() {
        let patches = [
            ConfigPatch::Tau(0.05),
            ConfigPatch::Alpha(0.6),
            ConfigPatch::Metric("l1_abs".into()),
            ConfigPatch::Granularity("layer".into()),
        ];
        for p in &patches {
            assert_eq!(&ConfigPatch::parse_key(&p.key()).unwrap(), p);
        }
        assert!(ConfigPatch::parse_key("tau").is_err());
        assert!(ConfigPatch::parse_key("widgets=3").is_err());
        assert!(ConfigPatch::parse_key("tau=notanumber").is_err());
    }

    #[test]
    fn kind_labels_round_trip() {
        for k in [JobKind::Pretrain, JobKind::Train, JobKind::Eval] {
            assert_eq!(JobKind::parse(k.label()), Some(k));
        }
        for e in [EvalKind::LmSuites, EvalKind::VlmMain, EvalKind::VlmNano, EvalKind::None] {
            assert_eq!(EvalKind::parse(e.label()), Some(e));
        }
        assert_eq!(JobKind::parse("nope"), None);
        assert_eq!(EvalKind::parse("nope"), None);
    }

    #[test]
    fn graph_rejects_forward_deps_and_dup_ids() {
        let mut g = JobGraph::new();
        let a = g.add(JobSpec::pretrain("pre", "lm-tiny-fp")).unwrap();
        assert!(g
            .add(JobSpec::train("t", "lm-tiny-fp", StoppingMethod::GradEs, EvalKind::LmSuites)
                .warm(5))
            .is_err());
        g.add(JobSpec::train("t", "lm-tiny-fp", StoppingMethod::GradEs, EvalKind::LmSuites)
            .warm(a))
            .unwrap();
        assert!(g.add(JobSpec::pretrain("pre", "lm-tiny-fp")).is_err());
        g.validate().unwrap();
    }

    #[test]
    fn children_mirror_deps() {
        let mut g = JobGraph::new();
        let pre = g.add(JobSpec::pretrain("pre", "c")).unwrap();
        let a = g
            .add(JobSpec::train("a", "c", StoppingMethod::None, EvalKind::None).warm(pre))
            .unwrap();
        let b = g
            .add(JobSpec::train("b", "c", StoppingMethod::None, EvalKind::None).warm(pre))
            .unwrap();
        assert_eq!(g.children()[pre], vec![a, b]);
        assert!(g.children()[a].is_empty());
    }

    #[test]
    fn lm_matrix_plan_shape() {
        let scales = [("tiny", "lm-tiny-fp", "lm-tiny-lora"), ("small", "lm-small-fp", "lm-small-lora")];
        let (g, slots) = lm_matrix_plan(&scales).unwrap();
        // per scale: 1 pretrain + 6 train jobs
        assert_eq!(g.len(), 2 * 7);
        assert_eq!(slots.jobs.len(), 2 * 6);
        g.validate().unwrap();
        for (_, _, id) in &slots.jobs {
            let spec = g.get(*id);
            assert_eq!(spec.kind, JobKind::Train);
            let w = spec.warm_from.expect("matrix jobs warm-start");
            assert_eq!(g.get(w).kind, JobKind::Pretrain);
        }
        // ids are unique and stable
        assert_eq!(g.get(slots.jobs[0].2).id, "lm/lm-tiny-fp/base");
    }

    #[test]
    fn ablation_plan_shape() {
        let taus = [0.01, 0.05];
        let alphas = [0.1, 0.3, 0.5];
        let (g, slots) = ablation_plan("lm-tiny-fp", &taus, &alphas).unwrap();
        assert_eq!(slots.grid.len(), 6);
        assert_eq!(slots.zoo.len(), ALL_METHODS.len());
        assert_eq!(g.len(), 6 + 2 + 2 + 6);
        g.validate().unwrap();
        assert_eq!(g.get(slots.grid[1]).id, "ablation/lm-tiny-fp/tau=0.01,alpha=0.3");
        assert_eq!(g.get(slots.zoo[3].1).id, "ablation/lm-tiny-fp/zoo/eb");
        // every method appears exactly once, in canonical order
        let labels: Vec<&str> = slots.zoo.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, vec!["base", "es", "grades", "eb", "spectral", "ies"]);
        // no dependencies anywhere: the whole grid is ready at once
        assert!(g.jobs.iter().all(|j| j.deps.is_empty()));
    }

    #[test]
    fn vlm_plan_shape() {
        let (g, slots) = vlm_plan(300).unwrap();
        assert_eq!(g.len(), 2 + 4 + 2);
        assert_eq!(slots.main.len(), 4);
        g.validate().unwrap();
        assert_eq!(g.get(slots.nano_base).eval, EvalKind::VlmNano);
        assert_eq!(g.get(g.get(slots.nano_base).warm_from.unwrap()).steps, Some(300));
    }

    #[test]
    fn eval_jobs_validate_and_mark_their_source() {
        let mut g = JobGraph::new();
        let t = g
            .add(JobSpec::train("t", "c", StoppingMethod::GradEs, EvalKind::None))
            .unwrap();
        assert!(!g.get(t).export_state);
        let e = g.add(JobSpec::score("t/eval", "c", EvalKind::LmSuites, t)).unwrap();
        assert!(g.get(t).export_state, "adding the eval job marks its source");
        assert_eq!(g.get(e).kind, JobKind::Eval);
        assert_eq!(g.get(e).eval_src, Some(t));
        assert!(!g.get(e).persist);
        assert_eq!(g.children()[t], vec![e]);
        g.validate().unwrap();
        // an eval job may not score nothing, nor a non-train source
        assert!(g.add(JobSpec::score("bad", "c", EvalKind::None, t)).is_err());
        let mut g2 = JobGraph::new();
        let pre = g2.add(JobSpec::pretrain("pre", "c")).unwrap();
        assert!(g2.add(JobSpec::score("bad2", "c", EvalKind::LmSuites, pre)).is_err());
    }

    #[test]
    fn fig1_plan_is_ephemeral_full_probe() {
        let (g, id) = fig1_plan("lm-tiny-fp").unwrap();
        assert_eq!(g.len(), 1);
        assert!(!g.get(id).persist);
        assert_eq!(g.get(id).probe_every, Some(1));
        assert_eq!(g.get(id).eval, EvalKind::None);
    }
}
