//! Tables 6 & 7 — the τ × α ablation grid (accuracy and training time),
//! plus two design-choice ablations DESIGN.md calls out: the convergence
//! metric (Eq. 1 l1_diff vs §3.1 l1_abs) and freeze granularity
//! (matrix-level GradES vs layer-level AutoFreeze-style).
//!
//! The grid shares one compiled bundle and one device-resident benchmark
//! set across all 20 runs: the artifact compiles once, the MC suites pack
//! and upload once, and each cell only pays training + pure-execution
//! scoring (`harness::DeviceSuite`).

use anyhow::Result;

use super::{write_result, ExpOptions};
use crate::config::RepoConfig;
use crate::coordinator::trainer::{self, StoppingMethod, TrainerOptions};
use crate::data;
use crate::eval::benchmarks::Suite;
use crate::eval::harness::{self, DeviceSuite, PackedSuite};
use crate::report::table::{pct, secs, Table};
use crate::runtime::artifact::{Bundle, Client};
use crate::runtime::pipeline::Prefetcher;

pub const TAUS: [f64; 4] = [0.01, 0.05, 0.1, 0.2];
pub const ALPHAS: [f64; 4] = [0.1, 0.3, 0.5, 0.6];

fn run_one(
    bundle: &Bundle,
    config_name: &str,
    device: &[DeviceSuite<'_>],
    opts: &ExpOptions,
    mutate: impl FnOnce(&mut RepoConfig),
) -> Result<(f64, f64, usize)> {
    let mut cfg = RepoConfig::by_name(config_name)?;
    mutate(&mut cfg);
    let dataset = data::build_lm(&cfg, &bundle.manifest)?;
    let mut topts = TrainerOptions::from_config(&cfg, StoppingMethod::GradEs);
    if let Some(s) = opts.steps_override {
        topts.total_steps = s;
    }
    let mut source = Prefetcher::spawn(dataset.train, topts.pipeline.prefetch_batches);
    let trained = trainer::run_source_and_keep(bundle, &cfg, &topts, &mut source, &dataset.val)?;
    let accs = harness::score_device_suites(&trained.session, device)?;
    let avg = accs.last().map(|a| a.1).unwrap_or(f64::NAN);
    Ok((avg, trained.outcome.wall_secs, trained.outcome.steps_run))
}

pub fn run(client: &Client, opts: &ExpOptions, config_name: &str) -> Result<()> {
    // one compile + one suite build for the whole grid
    let bundle = Bundle::by_name(client, config_name)?;
    let cfg = RepoConfig::by_name(config_name)?;
    let dataset = data::build_lm(&cfg, &bundle.manifest)?;
    let suites: Vec<Suite> =
        crate::eval::benchmarks::lm_suites(&dataset.vocab, opts.bench_seed, opts.questions);
    let packed: Vec<PackedSuite> =
        suites.iter().map(|s| PackedSuite::pack(&bundle.manifest, s)).collect::<Result<_>>()?;
    // upload once through a stateless loader session: the buffers belong
    // to the client and serve every trained session in the grid
    let loader = crate::runtime::session::Session::new(&bundle);
    let device: Vec<DeviceSuite> =
        packed.iter().map(|p| p.upload(&loader)).collect::<Result<_>>()?;

    // ---- Tables 6 & 7: τ × α grid ----
    let mut acc_t = Table::new(
        std::iter::once("tau \\ alpha".to_string())
            .chain(ALPHAS.iter().map(|a| format!("{a}")))
            .collect::<Vec<_>>(),
    );
    let mut time_t = acc_t.clone();
    for &tau in &TAUS {
        let mut acc_row = vec![format!("{tau}")];
        let mut time_row = vec![format!("{tau}")];
        for &alpha in &ALPHAS {
            let (avg, wall, steps) = run_one(&bundle, config_name, &device, opts, |c| {
                c.grades.tau = tau;
                c.grades.alpha = alpha;
            })?;
            if opts.verbose {
                println!("[ablation tau={tau} alpha={alpha}] acc={avg:.2}% wall={wall:.2}s steps={steps}");
            }
            acc_row.push(pct(avg));
            time_row.push(secs(wall));
        }
        acc_t.row(acc_row);
        time_t.row(time_row);
    }
    let t6 = format!(
        "## Table 6 — average accuracy (%) over the tau × alpha grid ({config_name})\n\n{}",
        acc_t.render()
    );
    let t7 = format!(
        "## Table 7 — fine-tuning time (s) over the tau × alpha grid ({config_name})\n\n{}",
        time_t.render()
    );

    // ---- metric ablation: Eq. 1 diff vs |grad| ----
    let mut metric_t = Table::new(vec!["Metric", "Avg. acc (%)", "Time (s)", "Steps"]);
    for metric in ["l1_diff", "l1_abs"] {
        let (avg, wall, steps) = run_one(&bundle, config_name, &device, opts, |c| {
            c.grades.metric = metric.to_string();
        })?;
        metric_t.row(vec![metric.to_string(), pct(avg), secs(wall), steps.to_string()]);
    }
    // ---- granularity ablation: matrix vs layer (AutoFreeze-style) ----
    let mut gran_t = Table::new(vec!["Granularity", "Avg. acc (%)", "Time (s)", "Steps"]);
    for gran in ["matrix", "layer"] {
        let (avg, wall, steps) = run_one(&bundle, config_name, &device, opts, |c| {
            c.grades.granularity = gran.to_string();
        })?;
        gran_t.row(vec![gran.to_string(), pct(avg), secs(wall), steps.to_string()]);
    }
    let extra = format!(
        "## Ablation — convergence metric (Eq. 1 vs §3.1)\n\n{}\n\
         ## Ablation — freeze granularity (GradES matrix-level vs AutoFreeze layer-level)\n\n{}",
        metric_t.render(),
        gran_t.render()
    );

    println!("\n{t6}\n{t7}\n{extra}");
    write_result(opts, "table6_ablation_accuracy.md", &t6)?;
    write_result(opts, "table7_ablation_time.md", &t7)?;
    write_result(opts, "ablation_design_choices.md", &extra)?;
    Ok(())
}
