//! Tables 6 & 7 — the τ × α ablation grid (accuracy and training time),
//! plus two design-choice ablations DESIGN.md calls out: the convergence
//! metric (Eq. 1 l1_diff vs §3.1 l1_abs) and freeze granularity
//! (matrix-level GradES vs layer-level AutoFreeze-style) — and the
//! stopping-method zoo, a head-to-head of every
//! [`StoppingMethod`](crate::coordinator::trainer::StoppingMethod) on
//! the same config (wall clock, accuracy, validation passes, freezing).
//!
//! The grid is a [`plan::ablation_plan`] job graph run by the scheduler:
//! all cells share one compiled bundle, one set of dataset rows and one
//! device-resident benchmark set through the scheduler's per-config
//! caches (the artifact compiles once, the data section builds once, the
//! MC suites pack and upload once), every completed cell lands in the run
//! manifest so an interrupted grid resumes where it stopped, and
//! `--jobs N` runs cells' host phases concurrently. Cells are rendered in
//! grid order, so the tables are identical for any job count.

use anyhow::Result;

use super::{method_label, plan, scheduler, write_result, ExpOptions, JobResult};
use crate::report::table::{pct, secs, Table};

/// τ grid of Tables 6/7.
pub const TAUS: [f64; 4] = [0.01, 0.05, 0.1, 0.2];
/// α grid of Tables 6/7.
pub const ALPHAS: [f64; 4] = [0.1, 0.3, 0.5, 0.6];

fn cell(r: &JobResult) -> (f64, f64, usize) {
    let avg = r.accuracies.last().map(|a| a.1).unwrap_or(f64::NAN);
    (avg, r.outcome.wall_secs, r.outcome.steps_run)
}

/// Run the τ×α grid + design ablations and render Tables 6/7.
pub fn run(opts: &ExpOptions, config_name: &str) -> Result<()> {
    let (graph, slots) = plan::ablation_plan(config_name, &TAUS, &ALPHAS)?;
    let runner = scheduler::DeviceRunner::new(opts);
    let report = scheduler::execute(&graph, &opts.scheduler(), &runner)?;
    report.require_ok(&graph)?;

    // ---- Tables 6 & 7: τ × α grid ----
    let mut acc_t = Table::new(
        std::iter::once("tau \\ alpha".to_string())
            .chain(ALPHAS.iter().map(|a| format!("{a}")))
            .collect::<Vec<_>>(),
    );
    let mut time_t = acc_t.clone();
    let mut k = 0;
    for &tau in &TAUS {
        let mut acc_row = vec![format!("{tau}")];
        let mut time_row = vec![format!("{tau}")];
        for &alpha in &ALPHAS {
            let (avg, wall, steps) = cell(report.result(slots.grid[k])?);
            if opts.verbose {
                println!("[ablation tau={tau} alpha={alpha}] acc={avg:.2}% wall={wall:.2}s steps={steps}");
            }
            acc_row.push(pct(avg));
            time_row.push(secs(wall));
            k += 1;
        }
        acc_t.row(acc_row);
        time_t.row(time_row);
    }
    let t6 = format!(
        "## Table 6 — average accuracy (%) over the tau × alpha grid ({config_name})\n\n{}",
        acc_t.render()
    );
    let t7 = format!(
        "## Table 7 — fine-tuning time (s) over the tau × alpha grid ({config_name})\n\n{}",
        time_t.render()
    );

    // ---- metric ablation: Eq. 1 diff vs |grad| ----
    let mut metric_t = Table::new(vec!["Metric", "Avg. acc (%)", "Time (s)", "Steps"]);
    for (metric, id) in &slots.metric {
        let (avg, wall, steps) = cell(report.result(*id)?);
        metric_t.row(vec![metric.clone(), pct(avg), secs(wall), steps.to_string()]);
    }
    // ---- granularity ablation: matrix vs layer (AutoFreeze-style) ----
    let mut gran_t = Table::new(vec!["Granularity", "Avg. acc (%)", "Time (s)", "Steps"]);
    for (gran, id) in &slots.granularity {
        let (avg, wall, steps) = cell(report.result(*id)?);
        gran_t.row(vec![gran.clone(), pct(avg), secs(wall), steps.to_string()]);
    }
    let extra = format!(
        "## Ablation — convergence metric (Eq. 1 vs §3.1)\n\n{}\n\
         ## Ablation — freeze granularity (GradES matrix-level vs AutoFreeze layer-level)\n\n{}",
        metric_t.render(),
        gran_t.render()
    );

    // ---- stopping-method zoo: every method head-to-head ----
    let mut zoo_t = zoo_table_header();
    for (_, id) in &slots.zoo {
        zoo_t.row(zoo_row(config_name, report.result(*id)?));
    }
    let zoo = format!(
        "## Stopping-method zoo — every method head-to-head ({config_name})\n\n{}",
        zoo_t.render()
    );

    println!("\n{t6}\n{t7}\n{extra}\n{zoo}");
    write_result(opts, "table6_ablation_accuracy.md", &t6)?;
    write_result(opts, "table7_ablation_time.md", &t7)?;
    write_result(opts, "ablation_design_choices.md", &extra)?;
    write_result(opts, "stopping_zoo.md", &zoo)?;
    Ok(())
}

/// Header of the zoo comparison table (shared with `bench_stopping_zoo`).
pub fn zoo_table_header() -> Table {
    Table::new(vec![
        "Method",
        "Avg. acc (%)",
        "Time (s)",
        "Val passes",
        "Steps",
        "Frozen",
        "Stop cause",
    ])
}

/// One zoo table row from a finished job (shared with
/// `bench_stopping_zoo`). Validation passes are the async-eval `issued`
/// counter — the column where GradES and the EB criterion read 0.
pub fn zoo_row(config_name: &str, r: &JobResult) -> Vec<String> {
    let (avg, wall, steps) = cell(r);
    let am = if config_name.contains("lora") { "lora" } else { "fp" };
    let cause = match r.outcome.stop_cause {
        crate::coordinator::trainer::StopCause::BudgetExhausted => "budget",
        crate::coordinator::trainer::StopCause::AllComponentsFrozen => "frozen",
        crate::coordinator::trainer::StopCause::ValidationPatience => "patience",
        crate::coordinator::trainer::StopCause::SamplesExhausted => "instances",
    };
    vec![
        method_label(am, r.method),
        pct(avg),
        secs(wall),
        r.outcome.async_eval.issued.to_string(),
        steps.to_string(),
        format!("{}/{}", r.outcome.freeze.n_frozen(), r.outcome.freeze.n()),
        cause.to_string(),
    ]
}
