//! Experiment drivers — one per paper table/figure family (DESIGN.md
//! experiment index). Each driver runs real training jobs through the
//! coordinator and renders the paper's table shape from our measurements.

pub mod ablation;
pub mod fig1;
pub mod lm_matrix;
pub mod vlm;

use std::path::PathBuf;

use anyhow::{Context, Result};

use std::sync::Arc;

use crate::config::RepoConfig;
use crate::coordinator::trainer::{self, StoppingMethod, TrainerOptions, TrainedModel};
use crate::coordinator::warmstart::BaseCheckpoint;
use crate::data;
use crate::eval::{benchmarks, harness};
use crate::runtime::artifact::{Bundle, Client};
use crate::runtime::pipeline::{FixedCycle, Prefetcher};

/// Common knobs for all drivers (scaled down in `cargo bench`).
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Override [run].total_steps (None = use config).
    pub steps_override: Option<usize>,
    /// Questions per benchmark suite.
    pub questions: usize,
    /// Benchmark-suite RNG seed.
    pub bench_seed: u64,
    pub out_dir: PathBuf,
    pub verbose: bool,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            steps_override: None,
            questions: 32,
            bench_seed: 0xbe9c,
            out_dir: crate::config::repo_root().join("results"),
            verbose: true,
        }
    }
}

impl ExpOptions {
    pub fn quick(steps: usize, questions: usize) -> Self {
        ExpOptions {
            steps_override: Some(steps),
            questions,
            verbose: false,
            ..Default::default()
        }
    }
}

/// Result of one (config, method) training + evaluation job.
pub struct JobResult {
    pub config: String,
    pub method: StoppingMethod,
    pub outcome: trainer::TrainOutcome,
    /// (suite name, accuracy %) pairs ending with ("Avg.", …).
    pub accuracies: Vec<(String, f64)>,
}

/// Train one LM config with one stopping method and score the 8 suites.
pub fn run_lm_job(
    client: &Client,
    config_name: &str,
    method: StoppingMethod,
    warm: Option<Arc<BaseCheckpoint>>,
    opts: &ExpOptions,
) -> Result<JobResult> {
    let cfg = RepoConfig::by_name(config_name)?;
    let bundle = Bundle::by_name(client, config_name)
        .with_context(|| format!("artifact {config_name} (run `make artifacts`)"))?;
    let dataset = data::build_lm(&cfg, &bundle.manifest)?;
    let mut topts = TrainerOptions::from_config(&cfg, method);
    topts.warm_start = warm;
    if let Some(s) = opts.steps_override {
        topts.total_steps = s;
    }
    // packing + epoch shuffling runs on the prefetch thread, overlapped
    // with device execution (same batch stream as draining inline)
    let mut source = Prefetcher::spawn(dataset.train, topts.pipeline.prefetch_batches);
    let trained: TrainedModel =
        trainer::run_source_and_keep(&bundle, &cfg, &topts, &mut source, &dataset.val)?;
    let suites = benchmarks::lm_suites(&dataset.vocab, opts.bench_seed, opts.questions);
    let accuracies = harness::score_suites(&trained.session, &suites)?;
    if opts.verbose {
        let avg = accuracies.last().map(|a| a.1).unwrap_or(f64::NAN);
        println!(
            "[{config_name}/{}] steps={} wall={:.2}s val_loss={:.4} frozen={}/{} avg_acc={avg:.2}%",
            method.label(),
            trained.outcome.steps_run,
            trained.outcome.wall_secs,
            trained.outcome.final_val_loss,
            trained.outcome.freeze.n_frozen(),
            trained.outcome.freeze.n(),
        );
    }
    Ok(JobResult { config: config_name.to_string(), method, outcome: trained.outcome, accuracies })
}

/// VLM job: train on scene/caption batches, score the requested suites.
pub enum VlmSuiteKind {
    /// Table 2: GQA/VQAv2/COCO analogues.
    Main,
    /// Table 3: six nanoVLM-style categories.
    Nano,
}

pub fn run_vlm_job(
    client: &Client,
    config_name: &str,
    method: StoppingMethod,
    kind: VlmSuiteKind,
    warm: Option<Arc<BaseCheckpoint>>,
    opts: &ExpOptions,
) -> Result<JobResult> {
    let cfg = RepoConfig::by_name(config_name)?;
    let bundle = Bundle::by_name(client, config_name)?;
    let dataset = data::build_vlm(&cfg, &bundle.manifest)?;
    let mut topts = TrainerOptions::from_config(&cfg, method);
    topts.warm_start = warm;
    if let Some(s) = opts.steps_override {
        topts.total_steps = s;
    }
    let mut source = Prefetcher::spawn(
        FixedCycle::new(dataset.train.clone()),
        topts.pipeline.prefetch_batches,
    );
    let trained = trainer::run_source_and_keep(&bundle, &cfg, &topts, &mut source, &dataset.val)?;
    let suites = match kind {
        VlmSuiteKind::Main => {
            benchmarks::vlm_suites(&dataset.scene_cfg, &dataset.vocab, opts.bench_seed, opts.questions)
        }
        VlmSuiteKind::Nano => benchmarks::nanovlm_suites(
            &dataset.scene_cfg,
            &dataset.vocab,
            opts.bench_seed,
            opts.questions,
        ),
    };
    let accuracies = harness::score_suites(&trained.session, &suites)?;
    if opts.verbose {
        let avg = accuracies.last().map(|a| a.1).unwrap_or(f64::NAN);
        println!(
            "[{config_name}/{}] steps={} wall={:.2}s avg_acc={avg:.2}%",
            method.label(),
            trained.outcome.steps_run,
            trained.outcome.wall_secs,
        );
    }
    Ok(JobResult { config: config_name.to_string(), method, outcome: trained.outcome, accuracies })
}

/// Paper-style method label for a (artifact-method, stopping) pair.
pub fn method_label(artifact_method: &str, stopping: StoppingMethod) -> String {
    let base = if artifact_method == "lora" { "LoRA" } else { "Full Parameter" };
    match stopping {
        StoppingMethod::None => base.to_string(),
        StoppingMethod::ClassicEs => format!("{}+ES", if artifact_method == "lora" { "LoRA" } else { "FP" }),
        StoppingMethod::GradEs => {
            format!("{}+GradES", if artifact_method == "lora" { "LoRA" } else { "FP" })
        }
    }
}

pub fn write_result(opts: &ExpOptions, name: &str, content: &str) -> Result<PathBuf> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let path = opts.out_dir.join(name);
    std::fs::write(&path, content)?;
    println!("wrote {}", path.display());
    Ok(path)
}
