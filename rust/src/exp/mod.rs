//! Experiment drivers — one per paper table/figure family (DESIGN.md
//! experiment index). Each driver *declares* its jobs as a
//! [`plan::JobGraph`] (configs, stopping methods, config patches,
//! dependency edges) and hands the graph to the [`scheduler`], which runs
//! ready jobs on a bounded worker pool over one shared client, persists
//! completed cells to a resumable run manifest under `--out`, and returns
//! per-job results the driver renders into the paper's table shapes.
//! Rendering iterates plan order, so tables are identical for any
//! `--jobs` value.

pub mod ablation;
pub mod coordinator;
pub mod fault;
pub mod fig1;
pub mod lm_matrix;
pub mod plan;
pub mod scheduler;
pub mod vlm;
pub mod wire;
pub mod worker;

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::trainer::{self, StoppingMethod};

/// Common knobs for all drivers (scaled down in `cargo bench`).
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Override `[run].total_steps` (None = use config).
    pub steps_override: Option<usize>,
    /// Questions per benchmark suite.
    pub questions: usize,
    /// Benchmark-suite RNG seed.
    pub bench_seed: u64,
    /// Directory tables/figures/manifest are written under.
    pub out_dir: PathBuf,
    /// Per-job progress lines on stdout.
    pub verbose: bool,
    /// Scheduler worker count (`--jobs` / `GRADES_JOBS`; 1 = sequential).
    pub jobs: usize,
    /// Resume from the run manifest under `out_dir` (`--fresh` disables).
    pub resume: bool,
    /// Execution backend (`--backend auto|host|xla`). `Auto` picks XLA
    /// per config when its artifacts exist, the host engine otherwise.
    pub backend: crate::runtime::backend::BackendChoice,
    /// Worker *processes* (`--workers` / `GRADES_WORKERS`; 0 = run
    /// everything on the in-process pool). When > 0, distributable
    /// graphs go through the fault-tolerant coordinator/worker runtime
    /// (see `exp::coordinator`).
    pub workers: usize,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            steps_override: None,
            questions: 32,
            bench_seed: 0xbe9c,
            out_dir: crate::config::repo_root().join("results"),
            verbose: true,
            jobs: 1,
            resume: true,
            backend: Default::default(),
            workers: 0,
        }
    }
}

impl ExpOptions {
    /// Scaled-down options for benches and smoke runs.
    pub fn quick(steps: usize, questions: usize) -> Self {
        ExpOptions {
            steps_override: Some(steps),
            questions,
            verbose: false,
            ..Default::default()
        }
    }

    /// Fingerprint of the run-wide settings that change a job's numbers.
    /// Recorded in every persisted job summary; a manifest entry resumes
    /// only when its fingerprint matches, so `--quick`/`--steps N` cells
    /// are never silently reused by a run with different settings.
    pub fn settings_fingerprint(&self) -> String {
        // The backend is NOT part of this run-wide string: it enters each
        // job's fingerprint per config, *resolved* (host vs xla), via
        // `scheduler::job_settings` — so host-run cells never resume into
        // an XLA run even when `auto`'s resolution changes because
        // artifacts were built between runs.
        format!(
            "steps_override={:?};questions={};bench_seed={:#x}",
            self.steps_override, self.questions, self.bench_seed
        )
    }

    /// Scheduler knobs derived from these options (the run manifest lives
    /// next to the rendered tables under `out_dir`).
    pub fn scheduler(&self) -> scheduler::SchedulerOptions {
        let mut grid = coordinator::GridOptions::default();
        grid.steps_override = self.steps_override;
        grid.questions = self.questions;
        grid.bench_seed = self.bench_seed;
        // Fault injection rides the environment so `grades repro` needs
        // no extra flag for it; the spec is forwarded verbatim to each
        // spawned worker (see `exp::fault`).
        grid.fault = std::env::var("GRADES_FAULT").ok().filter(|v| !v.trim().is_empty());
        scheduler::SchedulerOptions {
            jobs: self.jobs.max(1),
            manifest_path: Some(self.out_dir.join("run_manifest.json")),
            resume: self.resume,
            settings: self.settings_fingerprint(),
            backend: self.backend,
            verbose: self.verbose,
            workers: self.workers,
            retry: scheduler::RetryPolicy::default(),
            grid,
        }
    }
}

/// Result of one (config, method) training + evaluation job.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// Config the job ran.
    pub config: String,
    /// Stopping rule it trained under.
    pub method: StoppingMethod,
    /// The training run's report.
    pub outcome: trainer::TrainOutcome,
    /// (suite name, accuracy %) pairs ending with ("Avg.", …).
    pub accuracies: Vec<(String, f64)>,
}

/// Paper-style method label for a (artifact-method, stopping) pair.
pub fn method_label(artifact_method: &str, stopping: StoppingMethod) -> String {
    let base = if artifact_method == "lora" { "LoRA" } else { "Full Parameter" };
    let short = if artifact_method == "lora" { "LoRA" } else { "FP" };
    match stopping {
        StoppingMethod::None => base.to_string(),
        StoppingMethod::ClassicEs => format!("{short}+ES"),
        StoppingMethod::GradEs => format!("{short}+GradES"),
        StoppingMethod::EbCriterion => format!("{short}+EB"),
        StoppingMethod::SpectralEs => format!("{short}+SpectralES"),
        StoppingMethod::InstanceEs => format!("{short}+IES"),
    }
}

/// Write one rendered artifact under `out_dir` and echo its path.
pub fn write_result(opts: &ExpOptions, name: &str, content: &str) -> Result<PathBuf> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let path = opts.out_dir.join(name);
    std::fs::write(&path, content)?;
    println!("wrote {}", path.display());
    Ok(path)
}
