//! Coordinator ⇄ worker wire protocol: newline-delimited JSON frames over
//! the worker process's stdio (no serde offline — frames ride on
//! [`crate::util::json`], like the run manifest).
//!
//! One frame per line. The JSON writer never emits a raw newline (control
//! characters are escaped), so line framing is unambiguous. Both sides
//! treat an unparseable line as a protocol fault: the coordinator kills
//! the offending worker and reassigns its lease, a worker exits.
//!
//! Frames, coordinator → worker:
//! - `init` — run-wide settings (steps override, question count, bench
//!   seed, backend policy, settings fingerprint, heartbeat cadence).
//! - `assign` — one [`WireJob`] plus its attempt number.
//! - `shutdown` — drain and exit.
//!
//! Frames, worker → coordinator:
//! - `hello` — pid + worker index, sent once on startup.
//! - `claim` — ready for (more) work.
//! - `heartbeat` — lease renewal for the named running job.
//! - `done` — job finished; persistent train jobs attach their
//!   [`JobSummary`].
//! - `failed` — job errored cleanly (the worker itself stays up).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::plan::{ConfigPatch, EvalKind, JobGraph, JobId, JobKind, JobSpec};
use super::scheduler::JobSummary;
use crate::coordinator::trainer::StoppingMethod;
use crate::runtime::backend::BackendChoice;
use crate::util::json::{self, Json};

/// Run-wide settings the coordinator hands each worker in its `init`
/// frame — everything a worker needs to rebuild `ExpOptions` so its
/// summaries carry the same fingerprint the coordinator resumes on.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerInit {
    /// Global `[run].total_steps` override (`ExpOptions::steps_override`).
    pub steps_override: Option<usize>,
    /// Questions per benchmark suite.
    pub questions: usize,
    /// Benchmark-suite RNG seed.
    pub bench_seed: u64,
    /// Backend selection policy (resolved per config on the worker, same
    /// filesystem ⇒ same resolution as the coordinator).
    pub backend: BackendChoice,
    /// The run-wide settings fingerprint (`SchedulerOptions::settings`).
    pub settings: String,
    /// Heartbeat cadence the worker must hold while running a job.
    pub heartbeat_ms: u64,
}

/// A [`JobSpec`] flattened for the wire: graph indices are resolved into
/// names, and the warm-start edge becomes the (config, steps) pair the
/// worker feeds to the warmstart disk cache — checkpoints themselves
/// never cross the process boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct WireJob {
    /// Job id (manifest key).
    pub id: String,
    /// Config name.
    pub config: String,
    /// Pretrain / train (standalone eval jobs are not distributable).
    pub kind: JobKind,
    /// Stopping rule.
    pub method: StoppingMethod,
    /// Config patches, as their stable `key=value` strings.
    pub patches: Vec<ConfigPatch>,
    /// Benchmark suites to score.
    pub eval: EvalKind,
    /// Per-job total-steps override.
    pub steps: Option<usize>,
    /// Probe-cadence override.
    pub probe_every: Option<usize>,
    /// Whether the job's summary is persisted (and expected in `done`).
    pub persist: bool,
    /// Warm-start source: the pretrain dependency's (config, per-job
    /// steps override). The worker replays the pretrain through the
    /// warmstart disk cache — a hit, since the coordinator only assigns
    /// this job after the pretrain completed.
    pub warm: Option<(String, Option<usize>)>,
}

impl WireJob {
    /// Flatten a graph job for the wire.
    pub fn from_graph(graph: &JobGraph, id: JobId) -> Self {
        let spec = graph.get(id);
        let warm = spec.warm_from.map(|d| {
            let dep = graph.get(d);
            (dep.config.clone(), dep.steps)
        });
        WireJob {
            id: spec.id.clone(),
            config: spec.config.clone(),
            kind: spec.kind,
            method: spec.method,
            patches: spec.patches.clone(),
            eval: spec.eval,
            steps: spec.steps,
            probe_every: spec.probe_every,
            persist: spec.persist,
            warm,
        }
    }

    /// Rebuild a standalone [`JobSpec`] (no graph edges — the worker sees
    /// exactly one job at a time; the warm checkpoint is delivered
    /// separately through the disk cache).
    pub fn to_spec(&self) -> JobSpec {
        let mut spec = match self.kind {
            JobKind::Pretrain => JobSpec::pretrain(self.id.clone(), self.config.clone()),
            // Eval jobs are rejected before dispatch; mapping them to a
            // train spec here would be a coordinator bug, so keep the
            // constructor total and let the runner refuse the job.
            JobKind::Train | JobKind::Eval => JobSpec::train(
                self.id.clone(),
                self.config.clone(),
                self.method,
                self.eval,
            ),
        };
        spec.patches = self.patches.clone();
        spec.steps = self.steps;
        spec.probe_every = self.probe_every;
        spec.persist = self.persist;
        spec
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("id".to_string(), Json::Str(self.id.clone()));
        m.insert("config".to_string(), Json::Str(self.config.clone()));
        m.insert("kind".to_string(), Json::Str(self.kind.label().to_string()));
        m.insert("method".to_string(), Json::Str(self.method.label().to_string()));
        m.insert(
            "patches".to_string(),
            Json::Arr(self.patches.iter().map(|p| Json::Str(p.key())).collect()),
        );
        m.insert("eval".to_string(), Json::Str(self.eval.label().to_string()));
        if let Some(s) = self.steps {
            m.insert("steps".to_string(), Json::Num(s as f64));
        }
        if let Some(p) = self.probe_every {
            m.insert("probe_every".to_string(), Json::Num(p as f64));
        }
        m.insert("persist".to_string(), Json::Bool(self.persist));
        if let Some((cfg, steps)) = &self.warm {
            m.insert("warm_config".to_string(), Json::Str(cfg.clone()));
            if let Some(s) = steps {
                m.insert("warm_steps".to_string(), Json::Num(*s as f64));
            }
        }
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<Self> {
        let kind = j.get("kind")?.as_str()?;
        let kind = JobKind::parse(kind).ok_or_else(|| anyhow!("unknown job kind {kind:?}"))?;
        let method = j.get("method")?.as_str()?;
        let method = StoppingMethod::parse(method)
            .ok_or_else(|| anyhow!("unknown stopping method {method:?}"))?;
        let eval = j.get("eval")?.as_str()?;
        let eval = EvalKind::parse(eval).ok_or_else(|| anyhow!("unknown eval kind {eval:?}"))?;
        let patches = j
            .get("patches")?
            .as_arr()?
            .iter()
            .map(|p| ConfigPatch::parse_key(p.as_str()?))
            .collect::<Result<Vec<_>>>()?;
        let warm = match j.opt("warm_config") {
            Some(cfg) => Some((
                cfg.as_str()?.to_string(),
                match j.opt("warm_steps") {
                    Some(s) => Some(s.as_usize()?),
                    None => None,
                },
            )),
            None => None,
        };
        Ok(WireJob {
            id: j.get("id")?.as_str()?.to_string(),
            config: j.get("config")?.as_str()?.to_string(),
            kind,
            method,
            patches,
            eval,
            steps: match j.opt("steps") {
                Some(s) => Some(s.as_usize()?),
                None => None,
            },
            probe_every: match j.opt("probe_every") {
                Some(p) => Some(p.as_usize()?),
                None => None,
            },
            persist: j.get("persist")?.as_bool()?,
            warm,
        })
    }
}

/// A frame the coordinator sends to a worker.
#[derive(Debug, Clone, PartialEq)]
pub enum ToWorker {
    /// Run-wide settings, sent once right after spawn.
    Init(WorkerInit),
    /// Run this job (the worker holds its lease until `done`/`failed`).
    Assign {
        /// The job to execute.
        job: WireJob,
        /// 1-based attempt number (logging/diagnostics only).
        attempt: usize,
    },
    /// Finish up and exit.
    Shutdown,
}

/// A frame a worker sends to the coordinator.
#[derive(Debug, Clone, PartialEq)]
pub enum ToCoordinator {
    /// Sent once on startup.
    Hello {
        /// The worker process id.
        pid: u32,
        /// The worker's slot index (from `GRADES_WORKER_INDEX`).
        index: usize,
    },
    /// Ready for (more) work.
    Claim,
    /// Lease renewal for the named running job.
    Heartbeat {
        /// Id of the job the worker is still executing.
        job: String,
    },
    /// Job finished. Persistent train jobs attach their summary.
    Done {
        /// Id of the finished job.
        job: String,
        /// The persisted summary (None for pretrain/ephemeral jobs).
        summary: Option<JobSummary>,
    },
    /// Job errored cleanly; the worker stays up and claims again.
    Failed {
        /// Id of the failed job.
        job: String,
        /// The error chain, rendered.
        error: String,
    },
}

fn tag(m: &mut BTreeMap<String, Json>, t: &str) {
    m.insert("type".to_string(), Json::Str(t.to_string()));
}

impl ToWorker {
    /// Serialize to one JSON line (no trailing newline).
    pub fn render(&self) -> String {
        let mut m = BTreeMap::new();
        match self {
            ToWorker::Init(i) => {
                tag(&mut m, "init");
                if let Some(s) = i.steps_override {
                    m.insert("steps_override".to_string(), Json::Num(s as f64));
                }
                m.insert("questions".to_string(), Json::Num(i.questions as f64));
                // hex keeps 64-bit seeds lossless through the f64-backed
                // JSON number type
                m.insert("bench_seed".to_string(), Json::Str(format!("{:#x}", i.bench_seed)));
                m.insert("backend".to_string(), Json::Str(i.backend.label().to_string()));
                m.insert("settings".to_string(), Json::Str(i.settings.clone()));
                m.insert("heartbeat_ms".to_string(), Json::Num(i.heartbeat_ms as f64));
            }
            ToWorker::Assign { job, attempt } => {
                tag(&mut m, "assign");
                m.insert("attempt".to_string(), Json::Num(*attempt as f64));
                m.insert("job".to_string(), job.to_json());
            }
            ToWorker::Shutdown => tag(&mut m, "shutdown"),
        }
        json::write(&Json::Obj(m))
    }

    /// Parse one line.
    pub fn parse(line: &str) -> Result<Self> {
        let j = json::parse(line)?;
        match j.get("type")?.as_str()? {
            "init" => {
                let seed = j.get("bench_seed")?.as_str()?;
                let seed = seed
                    .strip_prefix("0x")
                    .ok_or_else(|| anyhow!("bench_seed {seed:?} is not hex"))
                    .and_then(|h| {
                        u64::from_str_radix(h, 16).map_err(|e| anyhow!("bench_seed: {e}"))
                    })?;
                let backend = j.get("backend")?.as_str()?;
                let backend = BackendChoice::parse(backend)
                    .ok_or_else(|| anyhow!("unknown backend {backend:?}"))?;
                Ok(ToWorker::Init(WorkerInit {
                    steps_override: match j.opt("steps_override") {
                        Some(s) => Some(s.as_usize()?),
                        None => None,
                    },
                    questions: j.get("questions")?.as_usize()?,
                    bench_seed: seed,
                    backend,
                    settings: j.get("settings")?.as_str()?.to_string(),
                    heartbeat_ms: j.get("heartbeat_ms")?.as_usize()? as u64,
                }))
            }
            "assign" => Ok(ToWorker::Assign {
                job: WireJob::from_json(j.get("job")?)?,
                attempt: j.get("attempt")?.as_usize()?,
            }),
            "shutdown" => Ok(ToWorker::Shutdown),
            other => bail!("unknown coordinator frame type {other:?}"),
        }
    }
}

impl ToCoordinator {
    /// Serialize to one JSON line (no trailing newline).
    pub fn render(&self) -> String {
        let mut m = BTreeMap::new();
        match self {
            ToCoordinator::Hello { pid, index } => {
                tag(&mut m, "hello");
                m.insert("pid".to_string(), Json::Num(*pid as f64));
                m.insert("index".to_string(), Json::Num(*index as f64));
            }
            ToCoordinator::Claim => tag(&mut m, "claim"),
            ToCoordinator::Heartbeat { job } => {
                tag(&mut m, "heartbeat");
                m.insert("job".to_string(), Json::Str(job.clone()));
            }
            ToCoordinator::Done { job, summary } => {
                tag(&mut m, "done");
                m.insert("job".to_string(), Json::Str(job.clone()));
                if let Some(s) = summary {
                    m.insert("summary".to_string(), s.to_json());
                }
            }
            ToCoordinator::Failed { job, error } => {
                tag(&mut m, "failed");
                m.insert("job".to_string(), Json::Str(job.clone()));
                m.insert("error".to_string(), Json::Str(error.clone()));
            }
        }
        json::write(&Json::Obj(m))
    }

    /// Parse one line.
    pub fn parse(line: &str) -> Result<Self> {
        let j = json::parse(line)?;
        match j.get("type")?.as_str()? {
            "hello" => Ok(ToCoordinator::Hello {
                pid: j.get("pid")?.as_usize()? as u32,
                index: j.get("index")?.as_usize()?,
            }),
            "claim" => Ok(ToCoordinator::Claim),
            "heartbeat" => {
                Ok(ToCoordinator::Heartbeat { job: j.get("job")?.as_str()?.to_string() })
            }
            "done" => Ok(ToCoordinator::Done {
                job: j.get("job")?.as_str()?.to_string(),
                summary: match j.opt("summary") {
                    Some(s) => Some(JobSummary::from_json(s)?),
                    None => None,
                },
            }),
            "failed" => Ok(ToCoordinator::Failed {
                job: j.get("job")?.as_str()?.to_string(),
                error: j.get("error")?.as_str()?.to_string(),
            }),
            other => bail!("unknown worker frame type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wire_job() -> WireJob {
        WireJob {
            id: "ablation/lm-tiny-fp/tau=0.05,alpha=0.3".into(),
            config: "lm-tiny-fp".into(),
            kind: JobKind::Train,
            method: StoppingMethod::GradEs,
            patches: vec![ConfigPatch::Tau(0.05), ConfigPatch::Alpha(0.3)],
            eval: EvalKind::LmSuites,
            steps: Some(40),
            probe_every: None,
            persist: true,
            warm: Some(("lm-tiny-fp".into(), Some(120))),
        }
    }

    #[test]
    fn to_worker_frames_round_trip() {
        let frames = [
            ToWorker::Init(WorkerInit {
                steps_override: Some(60),
                questions: 16,
                bench_seed: 0xbe9c_dead_beef_1234,
                backend: BackendChoice::Host,
                settings: "steps_override=Some(60);questions=16".into(),
                heartbeat_ms: 250,
            }),
            ToWorker::Assign { job: wire_job(), attempt: 2 },
            ToWorker::Shutdown,
        ];
        for f in &frames {
            let line = f.render();
            assert!(!line.contains('\n'), "frames are single lines");
            assert_eq!(&ToWorker::parse(&line).unwrap(), f);
        }
    }

    #[test]
    fn to_coordinator_frames_round_trip() {
        let frames = [
            ToCoordinator::Hello { pid: 4242, index: 1 },
            ToCoordinator::Claim,
            ToCoordinator::Heartbeat { job: "lm/lm-tiny-fp/base".into() },
            ToCoordinator::Done { job: "pre".into(), summary: None },
            ToCoordinator::Failed { job: "x".into(), error: "boom\nwith newline".into() },
        ];
        for f in &frames {
            let line = f.render();
            assert!(!line.contains('\n'), "frames are single lines");
            assert_eq!(&ToCoordinator::parse(&line).unwrap(), f);
        }
    }

    #[test]
    fn wire_job_flattens_the_warm_edge_and_rebuilds_a_spec() {
        let mut g = JobGraph::new();
        let pre = g.add(JobSpec::pretrain("pre", "lm-tiny-fp").with_steps(120)).unwrap();
        let ft = g
            .add(
                JobSpec::train("ft", "lm-tiny-fp", StoppingMethod::GradEs, EvalKind::LmSuites)
                    .warm(pre)
                    .with_steps(40),
            )
            .unwrap();
        let w = WireJob::from_graph(&g, ft);
        assert_eq!(w.warm, Some(("lm-tiny-fp".to_string(), Some(120))));
        let spec = w.to_spec();
        assert_eq!(spec.id, "ft");
        assert_eq!(spec.kind, JobKind::Train);
        assert_eq!(spec.steps, Some(40));
        assert!(spec.deps.is_empty() && spec.warm_from.is_none(), "edges stay behind");
        // pretrain jobs flatten without a warm edge
        let p = WireJob::from_graph(&g, pre);
        assert_eq!(p.kind, JobKind::Pretrain);
        assert!(p.warm.is_none());
        assert_eq!(p.to_spec().steps, Some(120));
    }

    #[test]
    fn garbled_lines_are_rejected() {
        assert!(ToWorker::parse("@@@ not json {").is_err());
        assert!(ToCoordinator::parse("@@@ not json {").is_err());
        assert!(ToCoordinator::parse(r#"{"type":"wat"}"#).is_err());
        assert!(ToCoordinator::parse(r#"{"type":"done"}"#).is_err(), "done needs a job id");
    }
}
