//! The execution-backend abstraction: one trait over the step programs
//! every training run drives (`init`, the `train_step` variant family,
//! `eval_step`, `eval_rows`, `probe`). Train steps are plan-driven: the
//! trainer derives a freeze-aware
//! [`StepPlan`](crate::coordinator::scheduler::StepPlan) and each engine
//! lowers it to what it can execute exactly ([`Backend::lower_plan`]).
//!
//! Two implementations exist:
//!
//! * **XLA** ([`crate::runtime::artifact::Bundle`]) — the production path:
//!   AOT-compiled HLO artifacts executed through PJRT, state resident on
//!   device between steps.
//! * **Host** ([`crate::runtime::host_backend::HostBackend`]) — a pure-Rust
//!   reference engine mirroring `python/compile/model.py` / `lora.py`
//!   across every `lm`/`vlm` × `fp`/`lora` config cell. No Python
//!   toolchain, no artifacts, no PJRT: full GradES trajectories (freeze
//!   decisions included) run in tier-1 `cargo test`, and the XLA path
//!   becomes something we differentially verify
//!   (`rust/tests/differential.rs`) instead of trust.
//!
//! [`Session`](crate::runtime::session::Session) is written against
//! `&dyn Backend`, so the trainer, the async-eval runtime, the experiment
//! scheduler and the benchmark harness are all backend-generic. State and
//! batch handles are type-erased ([`BackendState`], [`UploadedBatch`]):
//! a handle produced by one backend must only be fed back to that backend
//! (mixing backends is reported as an error, never UB).
//!
//! Selection: `grades … --backend host|xla|auto`. `auto` (the default)
//! picks XLA when `artifacts/<config>/manifest.json` exists and falls
//! back to the host backend otherwise — with a warn-once stderr note in
//! the style of the `GRADES_JOBS` validation.

use std::any::Any;
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use super::artifact::{Bundle, Client};
use super::host_backend::HostBackend;
use super::manifest::Manifest;
use super::session::Batch;
use crate::config::RepoConfig;
use crate::coordinator::scheduler::StepPlan;

// ---------------------------------------------------------------------------
// Erased handles
// ---------------------------------------------------------------------------

/// A backend's opaque training-state handle.
///
/// `Rc` so an [`EvalSnapshot`](crate::runtime::async_eval::EvalSnapshot)
/// can pin a past step's state at zero cost while training moves on —
/// train steps return a *new* state, nothing mutates one in place, on
/// either backend. The concrete payload is the backend's business
/// (`PjRtBuffer` for XLA, a flat `Vec<f32>` for the host backend).
#[derive(Clone)]
pub struct BackendState(Rc<dyn Any>);

impl BackendState {
    /// Wrap a backend-specific state value.
    pub fn new<T: 'static>(value: T) -> Self {
        BackendState(Rc::new(value))
    }

    /// Borrow the concrete state this handle wraps. Errors (instead of
    /// panicking) when a handle from another backend is passed in.
    pub fn downcast<T: 'static>(&self) -> Result<&T> {
        self.0
            .downcast_ref::<T>()
            .ok_or_else(|| anyhow!("backend state of the wrong type (handles from another backend?)"))
    }
}

/// A batch in a backend's execution-ready form (device-resident buffers
/// for XLA, a validated host copy for the host backend). Produced by
/// [`Backend::upload_batch`], consumed by the step/eval programs.
pub struct UploadedBatch {
    pub(crate) data: Box<dyn Any>,
    /// Host bytes the upload copied (what `StepTimings` accounts).
    pub bytes: usize,
}

impl UploadedBatch {
    /// Wrap a backend-specific batch payload.
    pub fn new<T: 'static>(data: T, bytes: usize) -> Self {
        UploadedBatch { data: Box::new(data), bytes }
    }

    /// Borrow the concrete payload (see [`BackendState::downcast`]).
    pub fn downcast<T: 'static>(&self) -> Result<&T> {
        self.data
            .downcast_ref::<T>()
            .ok_or_else(|| anyhow!("uploaded batch of the wrong type (handles from another backend?)"))
    }
}

/// A ctrl vector in execution-ready form: the host copy (what the
/// session's persistent-ctrl skip logic compares against) plus the
/// backend's own copy (a device buffer for XLA; nothing extra for the
/// host backend, which reads `host` directly).
pub struct CtrlBuf {
    /// The ctrl values this buffer holds.
    pub host: Vec<f32>,
    pub(crate) data: Box<dyn Any>,
}

impl CtrlBuf {
    /// Wrap a backend-specific ctrl payload alongside its host copy.
    pub fn new<T: 'static>(host: Vec<f32>, data: T) -> Self {
        CtrlBuf { host, data: Box::new(data) }
    }

    /// Borrow the concrete payload (see [`BackendState::downcast`]).
    pub fn downcast<T: 'static>(&self) -> Result<&T> {
        self.data
            .downcast_ref::<T>()
            .ok_or_else(|| anyhow!("ctrl buffer of the wrong type (handles from another backend?)"))
    }
}

// ---------------------------------------------------------------------------
// The trait
// ---------------------------------------------------------------------------

/// One execution engine for the six step programs of a config.
///
/// Implementations are *functional* over state: every mutating program
/// consumes a state handle and returns a fresh one, which is what makes
/// zero-copy eval snapshots work identically on both backends. All shape
/// validation against the manifest happens in
/// [`Session`](crate::runtime::session::Session) (backend-agnostic);
/// implementations may assume shapes are consistent.
pub trait Backend {
    /// The manifest describing shapes, components, and state layout.
    fn manifest(&self) -> &Manifest;

    /// Short backend id, `"xla"` or `"host"` (logs, bench reports).
    fn name(&self) -> &'static str;

    /// Wall seconds spent compiling/preparing the programs (0 when the
    /// backend has no compile phase).
    fn compile_secs(&self) -> f64 {
        0.0
    }

    /// The `init` program: fresh params + optimizer state from a seed.
    fn init_state(&self, seed: i32) -> Result<BackendState>;

    /// Stage one host batch into execution-ready form.
    fn upload_batch(&self, batch: &Batch) -> Result<UploadedBatch>;

    /// Stage one ctrl vector into execution-ready form.
    fn upload_ctrl(&self, ctrl: &[f32]) -> Result<CtrlBuf>;

    /// Lower a requested [`StepPlan`] to the plan this engine can
    /// execute *exactly*. Must return a subset of the requested omitted
    /// set (never elide more than asked — that is the soundness rule).
    /// The host engine honors any plan (identity); the XLA engine
    /// returns the nearest sound pre-compiled variant's omitted set.
    fn lower_plan(&self, plan: &StepPlan) -> StepPlan;

    /// One optimizer step under an **already-lowered** plan (an output
    /// of [`Backend::lower_plan`] — [`Session`](super::session::Session)
    /// guarantees this). Engines execute the plan exactly: every omitted
    /// component's dW matmul, Eq. 1 statistics, prev-grad carry and
    /// optimizer slot update are skipped.
    fn train_step(
        &self,
        state: &BackendState,
        io: &UploadedBatch,
        ctrl: &CtrlBuf,
        plan: &StepPlan,
    ) -> Result<BackendState>;

    /// The `probe` program: the metrics prefix the last step wrote.
    fn probe(&self, state: &BackendState) -> Result<Vec<f32>>;

    /// The `eval_step` program: forward-only (loss_sum, token_count).
    fn eval_step(&self, state: &BackendState, io: &UploadedBatch) -> Result<(f64, f64)>;

    /// The `eval_rows` program: per-row (loss_sum, count) pairs.
    fn eval_rows(&self, state: &BackendState, io: &UploadedBatch) -> Result<Vec<(f64, f64)>>;

    /// Download the full flat state (checkpointing / cross-thread eval).
    fn state_to_host(&self, state: &BackendState) -> Result<Vec<f32>>;

    /// Rehydrate a previously downloaded flat state.
    fn state_from_host(&self, host: &[f32]) -> Result<BackendState>;
}

// ---------------------------------------------------------------------------
// Selection
// ---------------------------------------------------------------------------

/// Which backend a run asks for (`--backend` / driver options).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendChoice {
    /// XLA when the config's artifacts exist, host otherwise (default).
    Auto,
    /// The pure-Rust reference backend (no artifacts needed).
    Host,
    /// The compiled-artifact PJRT backend (requires `make artifacts`).
    Xla,
}

impl BackendChoice {
    /// Parse a `--backend` value. Accepted: `auto`, `host`, `xla`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(BackendChoice::Auto),
            "host" => Some(BackendChoice::Host),
            "xla" => Some(BackendChoice::Xla),
            _ => None,
        }
    }

    /// The short id recorded in fingerprints and bench reports.
    pub fn label(&self) -> &'static str {
        match self {
            BackendChoice::Auto => "auto",
            BackendChoice::Host => "host",
            BackendChoice::Xla => "xla",
        }
    }

    /// Resolve `Auto` against the filesystem: XLA iff the config's
    /// artifact manifest exists. Deterministic, so every caller (engine
    /// cache, host-phase manifest loads, drivers) agrees on the answer.
    pub fn resolve(&self, config_name: &str) -> BackendChoice {
        match self {
            BackendChoice::Auto => {
                let have = crate::config::repo_root()
                    .join("artifacts")
                    .join(config_name)
                    .join("manifest.json")
                    .exists();
                if have {
                    BackendChoice::Xla
                } else {
                    warn_auto_host(config_name);
                    BackendChoice::Host
                }
            }
            other => *other,
        }
    }
}

impl Default for BackendChoice {
    fn default() -> Self {
        BackendChoice::Auto
    }
}

/// Warn once per process when `auto` falls back to the host backend —
/// same style as the `GRADES_JOBS` / `GRADES_SERIAL_COMPILE` validation:
/// never fail the run, never stay silent about a changed execution path.
fn warn_auto_host(config_name: &str) {
    static WARNED: std::sync::Once = std::sync::Once::new();
    WARNED.call_once(|| {
        eprintln!(
            "[backend] artifacts/{config_name} missing; using the pure-Rust host \
             backend. Build artifacts with the Python compile step (`make \
             artifacts`) or pass --backend xla to require the compiled path."
        );
    });
}

/// The manifest a config resolves to without touching any client: loaded
/// from the artifact dir on the XLA path, synthesized from the config on
/// the host path. This is what the scheduler's *host phase* uses to build
/// datasets while another job holds the device token.
pub fn manifest_for(choice: BackendChoice, cfg: &RepoConfig) -> Result<Manifest> {
    match choice.resolve(&cfg.name) {
        BackendChoice::Xla => Manifest::load(&cfg.artifact_dir().join("manifest.json")),
        _ => Ok(HostBackend::for_config(cfg)?.into_manifest()),
    }
}

thread_local! {
    /// Per-thread PJRT client singleton. `TfrtCpuClient` construction is
    /// expensive, and one `grades repro all` runs four drivers with four
    /// engine caches in sequence — before the backend trait they shared
    /// the single client `main` created. Thread-local (not process-global)
    /// because client handles carry non-atomic refcounts: a client may
    /// only be *used* by one thread at a time (the device-token
    /// contract), and caching per thread never hands the same fresh
    /// client to two threads racing to create one.
    static SHARED_CLIENT: RefCell<Option<Client>> = const { RefCell::new(None) };
}

/// This thread's shared PJRT client, created on first use.
fn shared_client() -> Result<Client> {
    SHARED_CLIENT.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            *slot = Some(Client::cpu()?);
        }
        Ok(slot.as_ref().expect("client created above").clone())
    })
}

// ---------------------------------------------------------------------------
// Engine cache
// ---------------------------------------------------------------------------

/// Per-config backend cache over one (lazily created) shared client:
/// each config builds its engine at most once per process and shares it
/// (`Rc`) across every job that trains or evaluates it — the
/// backend-generic successor of the scheduler's `BundleCache`.
///
/// Not thread-safe by itself (XLA engines hold handles with non-atomic
/// refcounts; the host backend is plain data but shares the cache). The
/// experiment scheduler wraps the cache in its exclusive device-token
/// mutex, which doubles as the compile lock — exactly as before.
///
/// Ownership is strictly **per process**: under `repro --workers M`,
/// the coordinator never builds an engine and each `grades worker`
/// process owns its own `EngineCache` (and thus its own PJRT client) —
/// neither clients, engines nor device buffers ever cross the process
/// boundary (warm starts replay through the warmstart *disk* cache
/// instead; see `exp::coordinator`). A worker crash can therefore only
/// ever tear down its own engines.
pub struct EngineCache {
    choice: BackendChoice,
    /// Created on first XLA load; host-only runs never pay for a client.
    client: RefCell<Option<Client>>,
    map: RefCell<HashMap<String, Rc<dyn Backend>>>,
}

impl EngineCache {
    /// Empty cache resolving configs under `choice`.
    pub fn new(choice: BackendChoice) -> Self {
        EngineCache { choice, client: RefCell::new(None), map: RefCell::new(HashMap::new()) }
    }

    /// Cache that reuses an existing client for XLA loads (benches and
    /// tests that already own one).
    pub fn with_client(choice: BackendChoice, client: Client) -> Self {
        EngineCache {
            choice,
            client: RefCell::new(Some(client)),
            map: RefCell::new(HashMap::new()),
        }
    }

    /// The requested selection policy.
    pub fn choice(&self) -> BackendChoice {
        self.choice
    }

    /// The engine for `name`, building (and for XLA, compiling) on first
    /// use.
    pub fn get(&self, name: &str) -> Result<Rc<dyn Backend>> {
        if let Some(b) = self.map.borrow().get(name) {
            return Ok(b.clone());
        }
        let engine: Rc<dyn Backend> = match self.choice.resolve(name) {
            BackendChoice::Xla => {
                let mut slot = self.client.borrow_mut();
                if slot.is_none() {
                    *slot = Some(shared_client()?);
                }
                let client = slot.as_ref().expect("client created above");
                Rc::new(Bundle::by_name(client, name)?)
            }
            _ => Rc::new(HostBackend::for_config(&RepoConfig::by_name(name)?)?),
        };
        self.map.borrow_mut().insert(name.to_string(), engine.clone());
        Ok(engine)
    }

    /// Number of configs with a built engine.
    pub fn len(&self) -> usize {
        self.map.borrow().len()
    }

    /// True before the first build.
    pub fn is_empty(&self) -> bool {
        self.map.borrow().is_empty()
    }
}

/// Build one engine outside any cache (CLI one-shots, tests). XLA loads
/// reuse this thread's shared client.
pub fn load_backend(choice: BackendChoice, name: &str) -> Result<Rc<dyn Backend>> {
    match choice.resolve(name) {
        BackendChoice::Xla => {
            let client = shared_client()?;
            Ok(Rc::new(Bundle::by_name(&client, name)?))
        }
        BackendChoice::Host => {
            Ok(Rc::new(HostBackend::for_config(&RepoConfig::by_name(name)?)?))
        }
        BackendChoice::Auto => bail!("resolve() never returns Auto"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parse_and_label_round_trip() {
        for (s, c) in [
            ("auto", BackendChoice::Auto),
            ("host", BackendChoice::Host),
            ("xla", BackendChoice::Xla),
        ] {
            assert_eq!(BackendChoice::parse(s), Some(c));
            assert_eq!(c.label(), s);
        }
        assert_eq!(BackendChoice::parse("tpu"), None);
        assert_eq!(BackendChoice::default(), BackendChoice::Auto);
    }

    #[test]
    fn explicit_choices_resolve_to_themselves() {
        assert_eq!(BackendChoice::Host.resolve("lm-tiny-fp"), BackendChoice::Host);
        assert_eq!(BackendChoice::Xla.resolve("no-such-config"), BackendChoice::Xla);
    }

    #[test]
    fn auto_resolves_host_for_missing_artifacts() {
        assert_eq!(
            BackendChoice::Auto.resolve("definitely-no-such-config"),
            BackendChoice::Host
        );
    }

    #[test]
    fn erased_handles_downcast_or_error() {
        let s = BackendState::new(vec![1f32, 2.0]);
        assert_eq!(s.downcast::<Vec<f32>>().unwrap(), &vec![1f32, 2.0]);
        assert!(s.downcast::<String>().is_err());
        let b = UploadedBatch::new(7usize, 4);
        assert_eq!(*b.downcast::<usize>().unwrap(), 7);
        assert_eq!(b.bytes, 4);
        assert!(b.downcast::<Vec<f32>>().is_err());
        let c = CtrlBuf::new(vec![1.0], ());
        assert!(c.downcast::<()>().is_ok());
        assert!(c.downcast::<usize>().is_err());
    }

    #[test]
    fn state_handles_share_via_rc() {
        let s = BackendState::new(vec![3f32]);
        let s2 = s.clone();
        assert_eq!(
            s.downcast::<Vec<f32>>().unwrap().as_ptr(),
            s2.downcast::<Vec<f32>>().unwrap().as_ptr()
        );
    }
}
