//! Pipelined training runtime: overlap host-side batch production with
//! device execution, and keep fixed evaluation data resident on device.
//!
//! Three independent optimizations compose here (ISSUE 1 tentpole):
//!
//! 1. **Batch prefetch** — [`Prefetcher`] runs any `BatchSource + Send`
//!    (packing, shuffling, RNG) on a background thread and hands finished
//!    [`Batch`]es to the train loop through a bounded channel, so host-side
//!    data work overlaps the previous step's device execution. Order and
//!    epoch semantics are identical to draining the source inline: one
//!    producer, FIFO channel.
//! 2. **Upload-ahead** — the trainer stages the *next* step's device
//!    buffers right after dispatching the current step (PJRT dispatch is
//!    asynchronous; the copy overlaps execution). See
//!    `Session::upload_batch` / `train_step_uploaded`.
//! 3. **Device-resident eval** — [`DeviceBatchCache`] uploads the fixed
//!    validation set once per session and reuses the buffers across every
//!    classic-ES check and the final validation pass, turning the
//!    per-check cost from O(val_set · upload) into pure execution.
//!
//! [`StepTimings`] instruments all of it (bytes uploaded, seconds in
//! upload / exec / probe / eval) so the wins stay measurable.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::Result;

use super::session::{Batch, Session};
use crate::data::batcher::BatchIter;

/// Anything that can yield training batches in a defined order.
///
/// This unifies the LM [`BatchIter`] (shuffled epochs), fixed VLM batch
/// vectors ([`FixedCycle`]) and ad-hoc closures ([`FnSource`]), and is what
/// [`Prefetcher`] moves onto its worker thread.
pub trait BatchSource {
    /// Produce the next batch (sources are infinite: epoch wrap-around
    /// is the source's own business).
    fn next_batch(&mut self) -> Batch;
}

impl BatchSource for BatchIter {
    fn next_batch(&mut self) -> Batch {
        BatchIter::next_batch(self)
    }
}

/// Adapter: any `FnMut() -> Batch` closure as a [`BatchSource`].
///
/// (A blanket `impl<F: FnMut() -> Batch> BatchSource for F` would collide
/// with the concrete impls under coherence, hence the newtype.)
pub struct FnSource<F: FnMut() -> Batch>(pub F);

impl<F: FnMut() -> Batch> BatchSource for FnSource<F> {
    fn next_batch(&mut self) -> Batch {
        (self.0)()
    }
}

/// Cycle through a fixed batch vector forever (the VLM training set is
/// pre-packed; "epoch" = one pass over the vector, in order).
pub struct FixedCycle {
    batches: Vec<Batch>,
    pos: usize,
    /// Completed passes over the batch vector.
    pub epoch: usize,
}

impl FixedCycle {
    /// Cycle over a non-empty batch vector.
    pub fn new(batches: Vec<Batch>) -> Self {
        assert!(!batches.is_empty(), "no batches to cycle");
        FixedCycle { batches, pos: 0, epoch: 0 }
    }

    /// Batches per epoch.
    pub fn len(&self) -> usize {
        self.batches.len()
    }

    /// Always false (construction rejects empty vectors).
    pub fn is_empty(&self) -> bool {
        self.batches.is_empty()
    }
}

impl BatchSource for FixedCycle {
    fn next_batch(&mut self) -> Batch {
        let b = self.batches[self.pos].clone();
        self.pos += 1;
        if self.pos == self.batches.len() {
            self.pos = 0;
            self.epoch += 1;
        }
        b
    }
}

/// Pipeline knobs, threaded through `TrainerOptions`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PipelineOptions {
    /// Bounded prefetch depth used when wrapping a source in a
    /// [`Prefetcher`] (2 = classic double buffering). 0 disables the
    /// background thread (the source is drained inline).
    pub prefetch_batches: usize,
    /// Stage the next step's device buffers while the current step
    /// executes. Off ⇒ upload sits on the critical path, as the seed
    /// runtime did. Trajectories are bitwise-identical either way: the
    /// batch consumed at step `t` is the same in both modes.
    pub upload_ahead: bool,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        PipelineOptions { prefetch_batches: 2, upload_ahead: true }
    }
}

impl PipelineOptions {
    /// The seed runtime's synchronous behaviour (baseline / A-B tests).
    ///
    /// ```
    /// use grades::runtime::pipeline::PipelineOptions;
    /// let off = PipelineOptions::off();
    /// assert_eq!(off.prefetch_batches, 0);
    /// assert!(!off.upload_ahead);
    /// // the default is the pipelined double-buffered configuration
    /// assert_eq!(PipelineOptions::default().prefetch_batches, 2);
    /// ```
    pub fn off() -> Self {
        PipelineOptions { prefetch_batches: 0, upload_ahead: false }
    }
}

/// Background batch producer: drains a `BatchSource` on a worker thread
/// through a bounded channel (double-buffered by default).
///
/// `Batch` is plain host data (`Vec<i32>`/`Vec<f32>`), so only the
/// *source* crosses the thread boundary — nothing PJRT-owned does. The
/// worker blocks once `depth` batches are waiting; dropping the
/// `Prefetcher` closes the channel and joins the worker.
pub struct Prefetcher {
    rx: mpsc::Receiver<Batch>,
    worker: Option<JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn a worker draining `source` with a bound of `depth` staged
    /// batches (`depth` is clamped to ≥ 1; use the source directly if you
    /// want no pipelining).
    pub fn spawn<S: BatchSource + Send + 'static>(mut source: S, depth: usize) -> Self {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        let worker = std::thread::Builder::new()
            .name("grades-prefetch".into())
            .spawn(move || {
                // SendError means the trainer dropped the receiver: done.
                while tx.send(source.next_batch()).is_ok() {}
            })
            .expect("spawning prefetch thread");
        Prefetcher { rx, worker: Some(worker) }
    }
}

impl BatchSource for Prefetcher {
    fn next_batch(&mut self) -> Batch {
        self.rx.recv().expect("prefetch worker died")
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Close the channel first so a worker blocked in send() unblocks.
        let (tx, rx) = mpsc::channel();
        drop(tx);
        let _ = std::mem::replace(&mut self.rx, rx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Cumulative runtime instrumentation for one session / training run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StepTimings {
    /// Host→device batch/ctrl bytes copied.
    pub upload_bytes: u64,
    /// Seconds inside host→device copies.
    pub upload_secs: f64,
    /// Individual upload calls.
    pub uploads: usize,
    /// Uploads that were staged ahead of their step (overlapped).
    pub staged_uploads: usize,
    /// Per-step ctrl uploads skipped because the device-resident ctrl
    /// buffer was still valid (see `Session`'s persistent ctrl cache).
    pub ctrl_skips: usize,
    /// Parameter snapshots pinned for asynchronous evaluation (see
    /// `runtime::async_eval` — zero-copy for device snapshots, one
    /// upload for rehydrated host snapshots).
    pub snapshots: usize,
    /// Train-step dispatch+execute seconds (as observed by the host).
    pub exec_secs: f64,
    /// Train-step executions.
    pub execs: usize,
    /// Component dW matmuls the executed (engine-lowered) step plans
    /// omitted, summed over steps — the *realized* side of the
    /// freeze-savings accounting (`FlopsCounter` prices it in FLOPs).
    pub dw_elided: usize,
    /// Metrics-probe seconds (device round trip for the GradES monitor).
    pub probe_secs: f64,
    /// Probe executions.
    pub probes: usize,
    /// Forward-only eval seconds (classic-ES validation + harness).
    pub eval_secs: f64,
    /// Forward-only eval executions.
    pub evals: usize,
    /// Host-engine workspace bytes served from the arena's free lists
    /// during train steps (see `runtime::host_arena`). Zero on the XLA
    /// engine and with `GRADES_HOST_ARENA=0`.
    pub arena_carved_bytes: u64,
    /// Host-engine workspace bytes freshly allocated during train
    /// steps. After the first step this stays flat — steady-state steps
    /// carve everything (a host test pins the delta to zero).
    pub arena_fresh_bytes: u64,
}

impl StepTimings {
    /// Accumulate another run's counters into this one.
    pub fn merge(&mut self, o: &StepTimings) {
        self.upload_bytes += o.upload_bytes;
        self.upload_secs += o.upload_secs;
        self.uploads += o.uploads;
        self.staged_uploads += o.staged_uploads;
        self.ctrl_skips += o.ctrl_skips;
        self.snapshots += o.snapshots;
        self.exec_secs += o.exec_secs;
        self.execs += o.execs;
        self.dw_elided += o.dw_elided;
        self.probe_secs += o.probe_secs;
        self.probes += o.probes;
        self.eval_secs += o.eval_secs;
        self.evals += o.evals;
        self.arena_carved_bytes += o.arena_carved_bytes;
        self.arena_fresh_bytes += o.arena_fresh_bytes;
    }

    /// Mean host→device bandwidth (GB/s); NaN when nothing was uploaded.
    pub fn upload_gbps(&self) -> f64 {
        self.upload_bytes as f64 / 1e9 / self.upload_secs
    }

    /// Serialize for timing reports.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("upload_bytes".into(), Json::Num(self.upload_bytes as f64));
        m.insert("upload_secs".into(), Json::Num(self.upload_secs));
        m.insert("uploads".into(), Json::Num(self.uploads as f64));
        m.insert("staged_uploads".into(), Json::Num(self.staged_uploads as f64));
        m.insert("ctrl_skips".into(), Json::Num(self.ctrl_skips as f64));
        m.insert("snapshots".into(), Json::Num(self.snapshots as f64));
        m.insert("exec_secs".into(), Json::Num(self.exec_secs));
        m.insert("execs".into(), Json::Num(self.execs as f64));
        m.insert("dw_elided".into(), Json::Num(self.dw_elided as f64));
        m.insert("probe_secs".into(), Json::Num(self.probe_secs));
        m.insert("probes".into(), Json::Num(self.probes as f64));
        m.insert("eval_secs".into(), Json::Num(self.eval_secs));
        m.insert("evals".into(), Json::Num(self.evals as f64));
        m.insert("arena_carved_bytes".into(), Json::Num(self.arena_carved_bytes as f64));
        m.insert("arena_fresh_bytes".into(), Json::Num(self.arena_fresh_bytes as f64));
        Json::Obj(m)
    }
}

/// The fixed validation set, uploaded once and kept device-resident.
///
/// Buffers live on the session's client; the session's *state* is a
/// separate executable argument, so one cache serves every validation
/// pass of a run (and even multiple sessions on the same client).
pub struct DeviceBatchCache {
    batches: Vec<super::session::UploadedBatch>,
    /// Total bytes the cache uploaded.
    pub bytes: u64,
}

impl DeviceBatchCache {
    /// Upload `batches` through `session`'s client (shape-checked against
    /// its manifest). Cost is paid once, not per validation check.
    pub fn upload(session: &Session, batches: &[Batch]) -> Result<Self> {
        let mut out = Vec::with_capacity(batches.len());
        let mut bytes = 0u64;
        for b in batches {
            let ub = session.upload_batch(b)?;
            bytes += ub.bytes as u64;
            out.push(ub);
        }
        Ok(DeviceBatchCache { batches: out, bytes })
    }

    /// Number of cached batches (one chunked-eval slice evaluates some
    /// prefix of `0..len()` per train step).
    pub fn len(&self) -> usize {
        self.batches.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.batches.is_empty()
    }

    /// The `i`-th cached batch, in upload order — the async validator's
    /// chunks index the cache directly so a pass sums losses in exactly
    /// the order `Session::eval_mean_loss_cached` does.
    pub(crate) fn get(&self, i: usize) -> &super::session::UploadedBatch {
        &self.batches[i]
    }

    pub(crate) fn iter(&self) -> impl Iterator<Item = &super::session::UploadedBatch> {
        self.batches.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_rows(n: usize, t: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
        (0..n).map(|i| (vec![i as i32; t], vec![i as i32; t])).collect()
    }

    #[test]
    fn prefetcher_preserves_order_and_epochs() {
        // Same rows, same seed: the prefetched stream must equal the
        // inline stream batch-for-batch, across an epoch boundary.
        let (n, t, bs) = (10, 8, 4);
        let mut inline = BatchIter::new(tiny_rows(n, t), bs, 77);
        let mut pre = Prefetcher::spawn(BatchIter::new(tiny_rows(n, t), bs, 77), 2);
        for step in 0..3 * n {
            let a = inline.next_batch();
            let b = pre.next_batch();
            assert_eq!(a.tokens, b.tokens, "tokens diverge at step {step}");
            assert_eq!(a.targets, b.targets, "targets diverge at step {step}");
        }
        assert!(inline.epoch >= 2, "test must cross epoch boundaries");
    }

    #[test]
    fn prefetcher_with_depth_one_still_matches() {
        let mut inline = BatchIter::new(tiny_rows(7, 4), 3, 5);
        let mut pre = Prefetcher::spawn(BatchIter::new(tiny_rows(7, 4), 3, 5), 1);
        for _ in 0..10 {
            assert_eq!(inline.next_batch().tokens, pre.next_batch().tokens);
        }
    }

    #[test]
    fn prefetcher_drop_joins_worker() {
        // Worker is blocked in send() with a full channel; drop must not
        // hang or panic.
        let pre = Prefetcher::spawn(BatchIter::new(tiny_rows(6, 4), 2, 1), 2);
        std::thread::sleep(std::time::Duration::from_millis(10));
        drop(pre);
    }

    #[test]
    fn fixed_cycle_wraps_in_order() {
        let batches: Vec<Batch> = (0..3)
            .map(|i| Batch { tokens: vec![i], targets: vec![i], patches: Vec::new() })
            .collect();
        let mut c = FixedCycle::new(batches);
        let seen: Vec<i32> = (0..7).map(|_| c.next_batch().tokens[0]).collect();
        assert_eq!(seen, vec![0, 1, 2, 0, 1, 2, 0]);
        assert_eq!(c.epoch, 2);
    }

    #[test]
    fn fn_source_wraps_closures() {
        let mut k = 0;
        let mut s = FnSource(move || {
            k += 1;
            Batch { tokens: vec![k], targets: vec![k], patches: Vec::new() }
        });
        assert_eq!(s.next_batch().tokens, vec![1]);
        assert_eq!(s.next_batch().tokens, vec![2]);
    }

    #[test]
    fn timings_merge_accumulates() {
        let mut a =
            StepTimings { upload_bytes: 10, upload_secs: 0.5, uploads: 2, ..Default::default() };
        let b = StepTimings {
            upload_bytes: 6,
            upload_secs: 0.25,
            uploads: 1,
            exec_secs: 1.0,
            execs: 3,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.upload_bytes, 16);
        assert_eq!(a.uploads, 3);
        assert_eq!(a.execs, 3);
        assert!((a.upload_secs - 0.75).abs() < 1e-12);
        assert!((a.upload_gbps() - 16.0 / 1e9 / 0.75).abs() < 1e-18);
    }

    #[test]
    fn pipeline_options_default_and_off() {
        let d = PipelineOptions::default();
        assert!(d.upload_ahead && d.prefetch_batches == 2);
        let off = PipelineOptions::off();
        assert!(!off.upload_ahead && off.prefetch_batches == 0);
    }
}
