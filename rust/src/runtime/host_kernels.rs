//! The host engine's math core: one cache-blocked, 8-wide-lane
//! microkernel behind every matmul variant and hot reduction, with
//! runtime-dispatched SIMD (SSE2/AVX2 via `std::arch`) and a scalar
//! fallback that emulates the exact same lane-split order.
//!
//! # The lane-split determinism contract
//!
//! Every reduction of length-`n` f32 streams accumulates element `j`
//! into f64 lane `j mod 8` and collapses the eight lanes in one fixed
//! tree — [`reduce8`]: `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))`. The
//! AVX2 kernel holds the lanes in two `__m256d` accumulators, the SSE2
//! kernel in four `__m128d`, and the scalar fallback in a `[f64; 8]` —
//! all three perform the identical sequence of IEEE f64 operations per
//! lane (widen-then-multiply-then-add, never fused), so **scalar, SSE2
//! and AVX2 results are bitwise identical**. Threading partitions work
//! at whole-output-element granularity, so **every
//! `GRADES_HOST_THREADS` count is bitwise identical** too. The property
//! suite (`rust/tests/properties.rs`) and the in-module tests pin both
//! invariants.
//!
//! This fixed lane order is a *different* (faster) reduction order than
//! the pre-kernel serial loops — a one-time, intentional trajectory
//! change (see `artifacts/golden/README.md`).
//!
//! # Dispatch
//!
//! | `GRADES_HOST_SIMD` | x86_64 + AVX2 | x86_64 (no AVX2) | other |
//! |---|---|---|---|
//! | unset / `auto` / `1` | AVX2 | SSE2 | scalar |
//! | `0` | scalar | scalar | scalar |
//!
//! Thread count comes from `GRADES_HOST_THREADS` (default 1) and only
//! engages above a work floor (`threads_for`); both knobs have
//! process-global test/bench overrides ([`set_simd_override`],
//! [`set_thread_override`]) that never exceed what the CPU supports.
//!
//! ```
//! use grades::runtime::host_kernels::{matmul, matmul_with, SimdLevel};
//! let a = vec![1.0f32, 2.0, 3.0, 4.0]; // [2,2] row-major
//! let b = vec![5.0f32, 6.0, 7.0, 8.0]; // [2,2]
//! let c = matmul(&a, &b, 2, 2, 2);
//! assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
//! // the scalar fallback is bitwise identical to the dispatched path
//! assert_eq!(c, matmul_with(SimdLevel::Scalar, 1, &a, &b, 2, 2, 2));
//! ```

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Accumulator lanes per reduction: element `j` lands in lane `j % 8`.
pub const LANES: usize = 8;

// ---------------------------------------------------------------------------
// SIMD level selection
// ---------------------------------------------------------------------------

/// A SIMD dispatch level. Ordered: `Scalar < Sse2 < Avx2`, so clamping
/// a requested level to [`best_available`] is a `min`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SimdLevel {
    /// Portable fallback emulating the 8-lane split in plain Rust.
    Scalar,
    /// 128-bit `std::arch` kernels (x86_64 baseline — always available there).
    Sse2,
    /// 256-bit `std::arch` kernels (runtime-detected).
    Avx2,
}

impl SimdLevel {
    /// Lower-case name for logs and bench reports.
    pub fn as_str(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Sse2 => "sse2",
            SimdLevel::Avx2 => "avx2",
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn best_available_impl() -> SimdLevel {
    if std::arch::is_x86_feature_detected!("avx2") {
        SimdLevel::Avx2
    } else {
        SimdLevel::Sse2
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn best_available_impl() -> SimdLevel {
    SimdLevel::Scalar
}

/// The widest kernel this CPU can run (cached; the detection itself is
/// a one-time CPUID behind `is_x86_feature_detected`).
pub fn best_available() -> SimdLevel {
    static L: OnceLock<SimdLevel> = OnceLock::new();
    *L.get_or_init(best_available_impl)
}

/// Every level runnable on this CPU, narrowest first — what the
/// determinism property tests sweep.
pub fn available_levels() -> Vec<SimdLevel> {
    let mut levels = vec![SimdLevel::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        levels.push(SimdLevel::Sse2);
        if best_available() == SimdLevel::Avx2 {
            levels.push(SimdLevel::Avx2);
        }
    }
    levels
}

/// `GRADES_HOST_SIMD` with the `GRADES_HOST_THREADS`-style warn-once
/// validation: `0` forces the scalar fallback, `1`/`auto`/unset pick
/// the best detected level, anything else warns once and auto-detects.
fn env_simd() -> SimdLevel {
    static L: OnceLock<SimdLevel> = OnceLock::new();
    *L.get_or_init(|| match std::env::var("GRADES_HOST_SIMD") {
        Err(_) => best_available(),
        Ok(v) => match v.trim() {
            "" | "auto" => best_available(),
            "0" => SimdLevel::Scalar,
            "1" => {
                if best_available() == SimdLevel::Scalar {
                    eprintln!(
                        "[host] GRADES_HOST_SIMD=1: no SIMD kernels for this target; \
                         using the scalar fallback (results are bitwise identical)"
                    );
                }
                best_available()
            }
            other => {
                eprintln!(
                    "[host] ignoring GRADES_HOST_SIMD={other:?}: expected 0, 1 or auto; \
                     using the auto-detected SIMD level"
                );
                best_available()
            }
        },
    })
}

/// Process-global override slot: 0 = none, else `SimdLevel as u8 + 1`.
static SIMD_OVERRIDE: AtomicU8 = AtomicU8::new(0);
/// Process-global thread override: 0 = none.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Force a dispatch level for this process (benches A/B the scalar
/// fallback against the SIMD path with this), or `None` to restore the
/// `GRADES_HOST_SIMD` behavior. Requests wider than the CPU supports
/// are clamped to [`best_available`] — never an illegal instruction.
/// Purely a wall-clock knob: results are bitwise identical either way.
pub fn set_simd_override(level: Option<SimdLevel>) {
    let v = match level {
        None => 0,
        Some(l) => l.min(best_available()) as u8 + 1,
    };
    SIMD_OVERRIDE.store(v, Ordering::Relaxed);
}

/// The level the dispatched entry points (`matmul`, `dot8`, …) run at:
/// the [`set_simd_override`] value if set, else `GRADES_HOST_SIMD`.
pub fn simd_level() -> SimdLevel {
    match SIMD_OVERRIDE.load(Ordering::Relaxed) {
        1 => SimdLevel::Scalar,
        2 => SimdLevel::Sse2,
        3 => SimdLevel::Avx2,
        _ => env_simd(),
    }
}

// ---------------------------------------------------------------------------
// Threading
// ---------------------------------------------------------------------------

/// Worker count for the blocked kernels: `GRADES_HOST_THREADS`, with
/// the `GRADES_JOBS`-style warn-once validation. Accepted values: a
/// positive integer; unset/empty means 1 (serial — the host engine is a
/// correctness oracle first, and tiny configs lose more to per-call
/// spawn overhead than they gain). Results are bitwise identical for
/// every value, so this is purely a wall-clock knob.
pub fn host_threads() -> usize {
    match THREAD_OVERRIDE.load(Ordering::Relaxed) {
        0 => env_threads(),
        n => n,
    }
}

/// Force the worker count for this process (`None` restores the
/// `GRADES_HOST_THREADS` behavior; `Some(0)` is treated as `Some(1)`).
pub fn set_thread_override(threads: Option<usize>) {
    THREAD_OVERRIDE.store(threads.map(|n| n.max(1)).unwrap_or(0), Ordering::Relaxed);
}

fn env_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| match std::env::var("GRADES_HOST_THREADS") {
        Err(_) => 1,
        Ok(v) if v.trim().is_empty() => 1,
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                eprintln!(
                    "[host] ignoring GRADES_HOST_THREADS={v:?}: expected a positive \
                     integer worker count; using the serial kernel loops"
                );
                1
            }
        },
    })
}

/// Below this many fused multiply-adds a kernel stays serial even with
/// threads configured: scoped-thread spawn overhead (~tens of µs) would
/// eat the win on micro shapes.
const PAR_MIN_FMAS: usize = 1 << 18;

/// [`host_threads`] gated on the work size: serial under the
/// `PAR_MIN_FMAS = 2^18` floor.
pub fn threads_for(work: usize) -> usize {
    if work < PAR_MIN_FMAS {
        1
    } else {
        host_threads()
    }
}

/// Split `out` into contiguous row chunks and run `body(first_row, chunk)`
/// on up to `threads` scoped workers. Every output element is written by
/// exactly one worker running the same per-element computation as the
/// serial path, so results are bitwise identical for every thread count.
fn par_row_chunks<T: Send, F>(out: &mut [T], row_len: usize, threads: usize, body: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { out.len() / row_len };
    let t = threads.min(rows).max(1);
    if t <= 1 {
        body(0, out);
        return;
    }
    let chunk_rows = rows.div_ceil(t);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut row0 = 0usize;
        while !rest.is_empty() {
            let take = (chunk_rows * row_len).min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            let body = &body;
            let r0 = row0;
            s.spawn(move || body(r0, head));
            row0 += take / row_len;
        }
    });
}

// ---------------------------------------------------------------------------
// Lane kernels: scalar fallback
// ---------------------------------------------------------------------------

/// Collapse the 8 lane accumulators in the one fixed tree every kernel
/// and every thread count shares: `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))`.
#[inline(always)]
pub fn reduce8(l: &[f64; LANES]) -> f64 {
    ((l[0] + l[4]) + (l[2] + l[6])) + ((l[1] + l[5]) + (l[3] + l[7]))
}

fn dot8_lanes_scalar(a: &[f32], b: &[f32]) -> [f64; LANES] {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0f64; LANES];
    let main = a.len() - a.len() % LANES;
    let (am, at) = a.split_at(main);
    let (bm, bt) = b.split_at(main);
    for (ac, bc) in am.chunks_exact(LANES).zip(bm.chunks_exact(LANES)) {
        for l in 0..LANES {
            lanes[l] += ac[l] as f64 * bc[l] as f64;
        }
    }
    for (l, (&av, &bv)) in at.iter().zip(bt.iter()).enumerate() {
        lanes[l] += av as f64 * bv as f64;
    }
    lanes
}

fn dot3_lanes_scalar(a: &[f32], b: &[f32], c: &[f32]) -> [f64; LANES] {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), c.len());
    let mut lanes = [0f64; LANES];
    let main = a.len() - a.len() % LANES;
    for j in (0..main).step_by(LANES) {
        for l in 0..LANES {
            lanes[l] += (a[j + l] as f64 * b[j + l] as f64) * c[j + l] as f64;
        }
    }
    for j in main..a.len() {
        lanes[j % LANES] += (a[j] as f64 * b[j] as f64) * c[j] as f64;
    }
    lanes
}

fn abs_lanes_scalar(a: &[f32]) -> [f64; LANES] {
    let mut lanes = [0f64; LANES];
    let main = a.len() - a.len() % LANES;
    let (am, at) = a.split_at(main);
    for ac in am.chunks_exact(LANES) {
        for l in 0..LANES {
            lanes[l] += ac[l].abs() as f64;
        }
    }
    for (l, &av) in at.iter().enumerate() {
        lanes[l] += av.abs() as f64;
    }
    lanes
}

fn absdiff_lanes_scalar(a: &[f32], b: &[f32]) -> [f64; LANES] {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0f64; LANES];
    let main = a.len() - a.len() % LANES;
    let (am, at) = a.split_at(main);
    let (bm, bt) = b.split_at(main);
    for (ac, bc) in am.chunks_exact(LANES).zip(bm.chunks_exact(LANES)) {
        for l in 0..LANES {
            // f32 subtract first (exact |a-b| in f32), then widen —
            // the SIMD kernels do the identical op order
            lanes[l] += (ac[l] - bc[l]).abs() as f64;
        }
    }
    for (l, (&av, &bv)) in at.iter().zip(bt.iter()).enumerate() {
        lanes[l] += (av - bv).abs() as f64;
    }
    lanes
}

// ---------------------------------------------------------------------------
// Lane kernels: SSE2 (x86_64 baseline)
// ---------------------------------------------------------------------------
//
// Lane layout per 8-element step `j`: acc01 = lanes {0,1}, acc23 =
// {2,3}, acc45 = {4,5}, acc67 = {6,7}; widen with cvtps_pd (low pair)
// and movehl (high pair), multiply, then add — never an FMA, matching
// the scalar fallback op-for-op.

#[cfg(target_arch = "x86_64")]
fn dot8_lanes_sse2(a: &[f32], b: &[f32]) -> [f64; LANES] {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let main = n - n % LANES;
    let mut lanes = [0f64; LANES];
    unsafe {
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc01 = _mm_setzero_pd();
        let mut acc23 = _mm_setzero_pd();
        let mut acc45 = _mm_setzero_pd();
        let mut acc67 = _mm_setzero_pd();
        let mut j = 0usize;
        while j < main {
            let av0 = _mm_loadu_ps(ap.add(j));
            let av1 = _mm_loadu_ps(ap.add(j + 4));
            let bv0 = _mm_loadu_ps(bp.add(j));
            let bv1 = _mm_loadu_ps(bp.add(j + 4));
            acc01 = _mm_add_pd(acc01, _mm_mul_pd(_mm_cvtps_pd(av0), _mm_cvtps_pd(bv0)));
            acc23 = _mm_add_pd(
                acc23,
                _mm_mul_pd(
                    _mm_cvtps_pd(_mm_movehl_ps(av0, av0)),
                    _mm_cvtps_pd(_mm_movehl_ps(bv0, bv0)),
                ),
            );
            acc45 = _mm_add_pd(acc45, _mm_mul_pd(_mm_cvtps_pd(av1), _mm_cvtps_pd(bv1)));
            acc67 = _mm_add_pd(
                acc67,
                _mm_mul_pd(
                    _mm_cvtps_pd(_mm_movehl_ps(av1, av1)),
                    _mm_cvtps_pd(_mm_movehl_ps(bv1, bv1)),
                ),
            );
            j += LANES;
        }
        _mm_storeu_pd(lanes.as_mut_ptr(), acc01);
        _mm_storeu_pd(lanes.as_mut_ptr().add(2), acc23);
        _mm_storeu_pd(lanes.as_mut_ptr().add(4), acc45);
        _mm_storeu_pd(lanes.as_mut_ptr().add(6), acc67);
    }
    for (l, j) in (main..n).enumerate() {
        lanes[l] += a[j] as f64 * b[j] as f64;
    }
    lanes
}

#[cfg(target_arch = "x86_64")]
fn dot3_lanes_sse2(a: &[f32], b: &[f32], c: &[f32]) -> [f64; LANES] {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), c.len());
    let n = a.len();
    let main = n - n % LANES;
    let mut lanes = [0f64; LANES];
    unsafe {
        let (ap, bp, cp) = (a.as_ptr(), b.as_ptr(), c.as_ptr());
        let mut acc01 = _mm_setzero_pd();
        let mut acc23 = _mm_setzero_pd();
        let mut acc45 = _mm_setzero_pd();
        let mut acc67 = _mm_setzero_pd();
        let mut j = 0usize;
        while j < main {
            for (acc, off, hi) in [
                (&mut acc01, 0usize, false),
                (&mut acc23, 0, true),
                (&mut acc45, 4, false),
                (&mut acc67, 4, true),
            ] {
                let av = _mm_loadu_ps(ap.add(j + off));
                let bv = _mm_loadu_ps(bp.add(j + off));
                let cv = _mm_loadu_ps(cp.add(j + off));
                let (aw, bw, cw) = if hi {
                    (
                        _mm_cvtps_pd(_mm_movehl_ps(av, av)),
                        _mm_cvtps_pd(_mm_movehl_ps(bv, bv)),
                        _mm_cvtps_pd(_mm_movehl_ps(cv, cv)),
                    )
                } else {
                    (_mm_cvtps_pd(av), _mm_cvtps_pd(bv), _mm_cvtps_pd(cv))
                };
                *acc = _mm_add_pd(*acc, _mm_mul_pd(_mm_mul_pd(aw, bw), cw));
            }
            j += LANES;
        }
        _mm_storeu_pd(lanes.as_mut_ptr(), acc01);
        _mm_storeu_pd(lanes.as_mut_ptr().add(2), acc23);
        _mm_storeu_pd(lanes.as_mut_ptr().add(4), acc45);
        _mm_storeu_pd(lanes.as_mut_ptr().add(6), acc67);
    }
    for (l, j) in (main..n).enumerate() {
        lanes[l] += (a[j] as f64 * b[j] as f64) * c[j] as f64;
    }
    lanes
}

#[cfg(target_arch = "x86_64")]
fn abs_lanes_sse2(a: &[f32]) -> [f64; LANES] {
    use std::arch::x86_64::*;
    let n = a.len();
    let main = n - n % LANES;
    let mut lanes = [0f64; LANES];
    unsafe {
        let ap = a.as_ptr();
        let sign = _mm_set1_ps(-0.0);
        let mut acc01 = _mm_setzero_pd();
        let mut acc23 = _mm_setzero_pd();
        let mut acc45 = _mm_setzero_pd();
        let mut acc67 = _mm_setzero_pd();
        let mut j = 0usize;
        while j < main {
            let av0 = _mm_andnot_ps(sign, _mm_loadu_ps(ap.add(j)));
            let av1 = _mm_andnot_ps(sign, _mm_loadu_ps(ap.add(j + 4)));
            acc01 = _mm_add_pd(acc01, _mm_cvtps_pd(av0));
            acc23 = _mm_add_pd(acc23, _mm_cvtps_pd(_mm_movehl_ps(av0, av0)));
            acc45 = _mm_add_pd(acc45, _mm_cvtps_pd(av1));
            acc67 = _mm_add_pd(acc67, _mm_cvtps_pd(_mm_movehl_ps(av1, av1)));
            j += LANES;
        }
        _mm_storeu_pd(lanes.as_mut_ptr(), acc01);
        _mm_storeu_pd(lanes.as_mut_ptr().add(2), acc23);
        _mm_storeu_pd(lanes.as_mut_ptr().add(4), acc45);
        _mm_storeu_pd(lanes.as_mut_ptr().add(6), acc67);
    }
    for (l, j) in (main..n).enumerate() {
        lanes[l] += a[j].abs() as f64;
    }
    lanes
}

#[cfg(target_arch = "x86_64")]
fn absdiff_lanes_sse2(a: &[f32], b: &[f32]) -> [f64; LANES] {
    use std::arch::x86_64::*;
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let main = n - n % LANES;
    let mut lanes = [0f64; LANES];
    unsafe {
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let sign = _mm_set1_ps(-0.0);
        let mut acc01 = _mm_setzero_pd();
        let mut acc23 = _mm_setzero_pd();
        let mut acc45 = _mm_setzero_pd();
        let mut acc67 = _mm_setzero_pd();
        let mut j = 0usize;
        while j < main {
            let d0 = _mm_andnot_ps(
                sign,
                _mm_sub_ps(_mm_loadu_ps(ap.add(j)), _mm_loadu_ps(bp.add(j))),
            );
            let d1 = _mm_andnot_ps(
                sign,
                _mm_sub_ps(_mm_loadu_ps(ap.add(j + 4)), _mm_loadu_ps(bp.add(j + 4))),
            );
            acc01 = _mm_add_pd(acc01, _mm_cvtps_pd(d0));
            acc23 = _mm_add_pd(acc23, _mm_cvtps_pd(_mm_movehl_ps(d0, d0)));
            acc45 = _mm_add_pd(acc45, _mm_cvtps_pd(d1));
            acc67 = _mm_add_pd(acc67, _mm_cvtps_pd(_mm_movehl_ps(d1, d1)));
            j += LANES;
        }
        _mm_storeu_pd(lanes.as_mut_ptr(), acc01);
        _mm_storeu_pd(lanes.as_mut_ptr().add(2), acc23);
        _mm_storeu_pd(lanes.as_mut_ptr().add(4), acc45);
        _mm_storeu_pd(lanes.as_mut_ptr().add(6), acc67);
    }
    for (l, j) in (main..n).enumerate() {
        lanes[l] += (a[j] - b[j]).abs() as f64;
    }
    lanes
}

// ---------------------------------------------------------------------------
// Lane kernels: AVX2
// ---------------------------------------------------------------------------
//
// Lane layout per 8-element step: acc_lo = lanes {0..3} (the low 128
// bits of the f32 load), acc_hi = lanes {4..7}. Widen → multiply → add,
// never an FMA — identical IEEE op sequence per lane as scalar/SSE2.

/// # Safety
/// Requires AVX2 (callers go through the [`best_available`]-clamped
/// dispatch, which only selects this after runtime detection).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot8_lanes_avx2_impl(a: &[f32], b: &[f32]) -> [f64; LANES] {
    use std::arch::x86_64::*;
    let n = a.len();
    let main = n - n % LANES;
    let mut lanes = [0f64; LANES];
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let mut acc_lo = _mm256_setzero_pd();
    let mut acc_hi = _mm256_setzero_pd();
    let mut j = 0usize;
    while j < main {
        let av = _mm256_loadu_ps(ap.add(j));
        let bv = _mm256_loadu_ps(bp.add(j));
        let alo = _mm256_cvtps_pd(_mm256_castps256_ps128(av));
        let blo = _mm256_cvtps_pd(_mm256_castps256_ps128(bv));
        let ahi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(av));
        let bhi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(bv));
        acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(alo, blo));
        acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(ahi, bhi));
        j += LANES;
    }
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc_lo);
    _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc_hi);
    for (l, jj) in (main..n).enumerate() {
        lanes[l] += a[jj] as f64 * b[jj] as f64;
    }
    lanes
}

/// # Safety
/// Requires AVX2 (dispatch-gated, see [`dot8_lanes_avx2_impl`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot3_lanes_avx2_impl(a: &[f32], b: &[f32], c: &[f32]) -> [f64; LANES] {
    use std::arch::x86_64::*;
    let n = a.len();
    let main = n - n % LANES;
    let mut lanes = [0f64; LANES];
    let (ap, bp, cp) = (a.as_ptr(), b.as_ptr(), c.as_ptr());
    let mut acc_lo = _mm256_setzero_pd();
    let mut acc_hi = _mm256_setzero_pd();
    let mut j = 0usize;
    while j < main {
        let av = _mm256_loadu_ps(ap.add(j));
        let bv = _mm256_loadu_ps(bp.add(j));
        let cv = _mm256_loadu_ps(cp.add(j));
        let alo = _mm256_cvtps_pd(_mm256_castps256_ps128(av));
        let blo = _mm256_cvtps_pd(_mm256_castps256_ps128(bv));
        let clo = _mm256_cvtps_pd(_mm256_castps256_ps128(cv));
        let ahi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(av));
        let bhi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(bv));
        let chi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(cv));
        acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(_mm256_mul_pd(alo, blo), clo));
        acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(_mm256_mul_pd(ahi, bhi), chi));
        j += LANES;
    }
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc_lo);
    _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc_hi);
    for (l, jj) in (main..n).enumerate() {
        lanes[l] += (a[jj] as f64 * b[jj] as f64) * c[jj] as f64;
    }
    lanes
}

/// # Safety
/// Requires AVX2 (dispatch-gated, see [`dot8_lanes_avx2_impl`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn abs_lanes_avx2_impl(a: &[f32]) -> [f64; LANES] {
    use std::arch::x86_64::*;
    let n = a.len();
    let main = n - n % LANES;
    let mut lanes = [0f64; LANES];
    let ap = a.as_ptr();
    let sign = _mm256_set1_ps(-0.0);
    let mut acc_lo = _mm256_setzero_pd();
    let mut acc_hi = _mm256_setzero_pd();
    let mut j = 0usize;
    while j < main {
        let av = _mm256_andnot_ps(sign, _mm256_loadu_ps(ap.add(j)));
        acc_lo = _mm256_add_pd(acc_lo, _mm256_cvtps_pd(_mm256_castps256_ps128(av)));
        acc_hi = _mm256_add_pd(acc_hi, _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(av)));
        j += LANES;
    }
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc_lo);
    _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc_hi);
    for (l, jj) in (main..n).enumerate() {
        lanes[l] += a[jj].abs() as f64;
    }
    lanes
}

/// # Safety
/// Requires AVX2 (dispatch-gated, see [`dot8_lanes_avx2_impl`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn absdiff_lanes_avx2_impl(a: &[f32], b: &[f32]) -> [f64; LANES] {
    use std::arch::x86_64::*;
    let n = a.len();
    let main = n - n % LANES;
    let mut lanes = [0f64; LANES];
    let (ap, bp) = (a.as_ptr(), b.as_ptr());
    let sign = _mm256_set1_ps(-0.0);
    let mut acc_lo = _mm256_setzero_pd();
    let mut acc_hi = _mm256_setzero_pd();
    let mut j = 0usize;
    while j < main {
        let dv = _mm256_andnot_ps(
            sign,
            _mm256_sub_ps(_mm256_loadu_ps(ap.add(j)), _mm256_loadu_ps(bp.add(j))),
        );
        acc_lo = _mm256_add_pd(acc_lo, _mm256_cvtps_pd(_mm256_castps256_ps128(dv)));
        acc_hi = _mm256_add_pd(acc_hi, _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(dv)));
        j += LANES;
    }
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc_lo);
    _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc_hi);
    for (l, jj) in (main..n).enumerate() {
        lanes[l] += (a[jj] - b[jj]).abs() as f64;
    }
    lanes
}

// safe wrappers (the dispatch guarantees the feature is present)
#[cfg(target_arch = "x86_64")]
fn dot8_lanes_avx2(a: &[f32], b: &[f32]) -> [f64; LANES] {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(best_available() == SimdLevel::Avx2);
    unsafe { dot8_lanes_avx2_impl(a, b) }
}

#[cfg(target_arch = "x86_64")]
fn dot3_lanes_avx2(a: &[f32], b: &[f32], c: &[f32]) -> [f64; LANES] {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), c.len());
    debug_assert!(best_available() == SimdLevel::Avx2);
    unsafe { dot3_lanes_avx2_impl(a, b, c) }
}

#[cfg(target_arch = "x86_64")]
fn abs_lanes_avx2(a: &[f32]) -> [f64; LANES] {
    debug_assert!(best_available() == SimdLevel::Avx2);
    unsafe { abs_lanes_avx2_impl(a) }
}

#[cfg(target_arch = "x86_64")]
fn absdiff_lanes_avx2(a: &[f32], b: &[f32]) -> [f64; LANES] {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(best_available() == SimdLevel::Avx2);
    unsafe { absdiff_lanes_avx2_impl(a, b) }
}

// ---------------------------------------------------------------------------
// Reduction entry points
// ---------------------------------------------------------------------------

fn dot8_lanes(level: SimdLevel, a: &[f32], b: &[f32]) -> [f64; LANES] {
    match level {
        SimdLevel::Scalar => dot8_lanes_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => dot8_lanes_sse2(a, b),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => dot8_lanes_avx2(a, b),
        #[cfg(not(target_arch = "x86_64"))]
        _ => dot8_lanes_scalar(a, b),
    }
}

fn dot3_lanes(level: SimdLevel, a: &[f32], b: &[f32], c: &[f32]) -> [f64; LANES] {
    match level {
        SimdLevel::Scalar => dot3_lanes_scalar(a, b, c),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => dot3_lanes_sse2(a, b, c),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => dot3_lanes_avx2(a, b, c),
        #[cfg(not(target_arch = "x86_64"))]
        _ => dot3_lanes_scalar(a, b, c),
    }
}

fn abs_lanes(level: SimdLevel, a: &[f32]) -> [f64; LANES] {
    match level {
        SimdLevel::Scalar => abs_lanes_scalar(a),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => abs_lanes_sse2(a),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => abs_lanes_avx2(a),
        #[cfg(not(target_arch = "x86_64"))]
        _ => abs_lanes_scalar(a),
    }
}

fn absdiff_lanes(level: SimdLevel, a: &[f32], b: &[f32]) -> [f64; LANES] {
    match level {
        SimdLevel::Scalar => absdiff_lanes_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => absdiff_lanes_sse2(a, b),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => absdiff_lanes_avx2(a, b),
        #[cfg(not(target_arch = "x86_64"))]
        _ => absdiff_lanes_scalar(a, b),
    }
}

/// Lane-split dot product `Σ aⱼ·bⱼ` in f64 (the microkernel's reduction,
/// dispatched at [`simd_level`]).
pub fn dot8(a: &[f32], b: &[f32]) -> f64 {
    dot8_with(simd_level(), a, b)
}

/// [`dot8`] at an explicit level (the determinism tests sweep these).
pub fn dot8_with(level: SimdLevel, a: &[f32], b: &[f32]) -> f64 {
    reduce8(&dot8_lanes(level, a, b))
}

/// Lane-split triple product `Σ (aⱼ·bⱼ)·cⱼ` in f64 (RMSNorm backward's
/// `Σ dy·scale·x`).
pub fn dot3_8(a: &[f32], b: &[f32], c: &[f32]) -> f64 {
    dot3_8_with(simd_level(), a, b, c)
}

/// [`dot3_8`] at an explicit level.
pub fn dot3_8_with(level: SimdLevel, a: &[f32], b: &[f32], c: &[f32]) -> f64 {
    reduce8(&dot3_lanes(level, a, b, c))
}

/// Lane-split L1 norm `Σ |aⱼ|` in f64 (Eq. 1 `‖∇W‖₁` and the global
/// gradient norm).
pub fn abs_sum8(a: &[f32]) -> f64 {
    abs_sum8_with(simd_level(), a)
}

/// [`abs_sum8`] at an explicit level.
pub fn abs_sum8_with(level: SimdLevel, a: &[f32]) -> f64 {
    reduce8(&abs_lanes(level, a))
}

/// Lane-split L1 distance `Σ |aⱼ − bⱼ|` in f64, subtracting in f32
/// first like the compiled graphs (Eq. 1 `‖∇Wₜ − ∇Wₜ₋₁‖₁`).
pub fn abs_diff_sum8(a: &[f32], b: &[f32]) -> f64 {
    abs_diff_sum8_with(simd_level(), a, b)
}

/// [`abs_diff_sum8`] at an explicit level.
pub fn abs_diff_sum8_with(level: SimdLevel, a: &[f32], b: &[f32]) -> f64 {
    reduce8(&absdiff_lanes(level, a, b))
}

// ---------------------------------------------------------------------------
// The gemm microkernel + packing
// ---------------------------------------------------------------------------

/// Output rows per cache block: one block of packed right-hand rows
/// (`J_BLOCK · kdim` f32s — ≤48 KiB at the tiny configs' largest kdim)
/// stays L1/L2-hot while the left-hand rows stream past it.
const J_BLOCK: usize = 32;

/// Exact (no FP ops) tiled transpose of a row-major `[rows, cols]`
/// matrix into `[cols, rows]` — the packing step that turns every
/// matmul variant into the one row·row microkernel.
pub fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0f32; rows * cols];
    transpose_into(src, rows, cols, &mut out);
    out
}

/// [`transpose`] into a caller-provided buffer (the host backend's
/// workspace arena carves packing scratch through this — every element
/// of `out` is written).
pub fn transpose_into(src: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert_eq!(out.len(), rows * cols);
    const TILE: usize = 32;
    for r0 in (0..rows).step_by(TILE) {
        for c0 in (0..cols).step_by(TILE) {
            for r in r0..(r0 + TILE).min(rows) {
                for c in c0..(c0 + TILE).min(cols) {
                    out[c * rows + r] = src[r * cols + c];
                }
            }
        }
    }
}

/// `out[i, j] = Σₓ l[i, x]·r[j, x]` for `l: [rows_l, kdim]`,
/// `r: [rows_r, kdim]` — the single shared microkernel every matmul
/// variant reduces to after packing. Blocked over `J_BLOCK` right-hand
/// rows; threaded over left-hand rows; each output element is one
/// lane-split [`dot8_with`], so blocking and threading never change a
/// bit.
fn gemm(
    level: SimdLevel,
    threads: usize,
    l: &[f32],
    r: &[f32],
    rows_l: usize,
    rows_r: usize,
    kdim: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; rows_l * rows_r];
    gemm_into(level, threads, l, r, rows_l, rows_r, kdim, &mut out);
    out
}

/// The packed microkernel (`out[i, j] = Σₓ l[i, x]·r[j, x]`) into a
/// caller-provided buffer — what the host backend's arena-carved matmul
/// wrappers call. Every element of `out` is written; results are
/// bitwise identical for every level/thread count, as with [`matmul`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_into(
    level: SimdLevel,
    threads: usize,
    l: &[f32],
    r: &[f32],
    rows_l: usize,
    rows_r: usize,
    kdim: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(l.len(), rows_l * kdim);
    debug_assert_eq!(r.len(), rows_r * kdim);
    debug_assert_eq!(out.len(), rows_l * rows_r);
    par_row_chunks(out, rows_r, threads, |row0, chunk| match level {
        SimdLevel::Scalar => gemm_block(dot8_lanes_scalar, l, r, rows_r, kdim, row0, chunk),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => gemm_block(dot8_lanes_sse2, l, r, rows_r, kdim, row0, chunk),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => gemm_block(dot8_lanes_avx2, l, r, rows_r, kdim, row0, chunk),
        #[cfg(not(target_arch = "x86_64"))]
        _ => gemm_block(dot8_lanes_scalar, l, r, rows_r, kdim, row0, chunk),
    });
}

#[inline(always)]
fn gemm_block<F>(dot: F, l: &[f32], r: &[f32], rows_r: usize, kdim: usize, row0: usize, chunk: &mut [f32])
where
    F: Fn(&[f32], &[f32]) -> [f64; LANES],
{
    for jb in (0..rows_r).step_by(J_BLOCK) {
        let jend = (jb + J_BLOCK).min(rows_r);
        for (il, orow) in chunk.chunks_mut(rows_r).enumerate() {
            let i = row0 + il;
            let lrow = &l[i * kdim..(i + 1) * kdim];
            for (j, o) in orow[jb..jend].iter_mut().enumerate() {
                let rrow = &r[(jb + j) * kdim..(jb + j + 1) * kdim];
                *o = reduce8(&dot(lrow, rrow)) as f32;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Matmul entry points (the six variants, one microkernel)
// ---------------------------------------------------------------------------

/// `out[m,n] = a[m,k] @ b[k,n]` (dispatched level + work-gated threads).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_with(simd_level(), threads_for(m * k * n), a, b, m, k, n)
}

/// [`matmul`] with an explicit worker count (tests assert bitwise
/// thread-count invariance through the `_t` entry points).
pub fn matmul_t(threads: usize, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_with(simd_level(), threads, a, b, m, k, n)
}

/// [`matmul`] with an explicit level and worker count: packs `bᵀ`
/// (exactly — transposition performs no FP math) and runs the shared
/// row·row microkernel.
pub fn matmul_with(
    level: SimdLevel,
    threads: usize,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let bt = transpose(b, k, n);
    gemm(level, threads, a, &bt, m, n, k)
}

/// `out[k,n] = aᵀ[k,m] @ b[m,n]` for `a: [m,k]` — weight gradients.
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_tn_with(simd_level(), threads_for(m * k * n), a, b, m, k, n)
}

/// [`matmul_tn`] with an explicit worker count.
pub fn matmul_tn_t(threads: usize, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_tn_with(simd_level(), threads, a, b, m, k, n)
}

/// [`matmul_tn`] with an explicit level and worker count: packs both
/// `aᵀ` and `bᵀ`, then the shared microkernel contracts over `m`.
pub fn matmul_tn_with(
    level: SimdLevel,
    threads: usize,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let at = transpose(a, m, k);
    let bt = transpose(b, m, n);
    gemm(level, threads, &at, &bt, k, n, m)
}

/// `out[m,k] = a[m,n] @ bᵀ[n,k]` for `b: [k,n]` — input gradients.
/// Both operands are already row-major over the contraction axis, so no
/// packing at all: the microkernel runs on them directly.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    matmul_nt_with(simd_level(), threads_for(m * n * k), a, b, m, n, k)
}

/// [`matmul_nt`] with an explicit worker count.
pub fn matmul_nt_t(threads: usize, a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    matmul_nt_with(simd_level(), threads, a, b, m, n, k)
}

/// [`matmul_nt`] with an explicit level and worker count.
pub fn matmul_nt_with(
    level: SimdLevel,
    threads: usize,
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
) -> Vec<f32> {
    gemm(level, threads, a, b, m, k, n)
}

// ---------------------------------------------------------------------------
// Elementwise kernels: deterministic exp, sigmoid, SwiGLU, softmax
// ---------------------------------------------------------------------------
//
// These are *elementwise*, so the determinism argument is simpler than
// the reductions': every lane runs the identical IEEE op sequence
// (min/max clamp → magic-number round → Cody–Waite reduce → Horner
// polynomial → exponent-bits scale), and elementwise IEEE ops have no
// ordering freedom — scalar, SSE2 and AVX2 agree bit-for-bit by
// construction. The SIMD paths exist purely for speed.
//
// `vexp` replaces libm's `exp` in the attention softmax and the SwiGLU
// sigmoid (a one-time, intentional trajectory change — see
// `artifacts/golden/README.md`). The loss path (`log_sum_exp`, the
// fused loss/softmax in `loss_grad`) stays on libm f64 `exp`.

/// Clamp ceiling: keeps `round(x·log₂e) ≤ 127`, so the exponent-bits
/// scale below never overflows into inf/NaN territory.
const EXP_HI: f32 = 88.02;
/// Clamp floor: results below this saturate near the smallest normal.
const EXP_LO: f32 = -87.336_54;
/// log₂(e), f32-rounded.
const EXP_LOG2EF: f32 = 1.442_695;
/// Cody–Waite ln2 split, high part (exactly representable).
const EXP_C1: f32 = 0.693_359_375;
/// Cody–Waite ln2 split, low part.
const EXP_C2: f32 = -2.121_944_4e-4;
/// Degree-5 minimax polynomial for expᵣ on the reduced range.
const EXP_P: [f32; 6] = [
    1.987_569_1e-4,
    1.398_199_9e-3,
    8.333_452e-3,
    4.166_579_6e-2,
    1.666_666_5e-1,
    5.000_000_1e-1,
];
/// 1.5·2²³ — adding and subtracting this rounds `|x| < 2²²` to the
/// nearest integer in f32 (round-to-nearest-even), with the integer
/// also recoverable from the low mantissa bits.
const EXP_MAGIC: f32 = 12_582_912.0;

/// One element of [`vexp_inplace`] — the scalar twin of the SIMD
/// kernels, op-for-op: SIMD-semantics min/max (`a < b ? a : b`), the
/// magic-number rounding, two-term Cody–Waite reduction, Horner
/// evaluation and the `(n+127) << 23` exponent-bits scale.
#[inline(always)]
fn vexp1(x: f32) -> f32 {
    let x = if x < EXP_HI { x } else { EXP_HI };
    let x = if x > EXP_LO { x } else { EXP_LO };
    let z = x * EXP_LOG2EF + EXP_MAGIC;
    let n = z - EXP_MAGIC;
    let r = x - n * EXP_C1;
    let r = r - n * EXP_C2;
    let mut y = EXP_P[0];
    y = y * r + EXP_P[1];
    y = y * r + EXP_P[2];
    y = y * r + EXP_P[3];
    y = y * r + EXP_P[4];
    y = y * r + EXP_P[5];
    let r2 = r * r;
    y = y * r2 + r + 1.0;
    let ni = n as i32;
    let pow2 = f32::from_bits(((ni + 127) << 23) as u32);
    y * pow2
}

#[cfg(target_arch = "x86_64")]
fn vexp_sse2(xs: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = xs.len();
    let main = n - n % 4;
    unsafe {
        let hi = _mm_set1_ps(EXP_HI);
        let lo = _mm_set1_ps(EXP_LO);
        let log2ef = _mm_set1_ps(EXP_LOG2EF);
        let magic = _mm_set1_ps(EXP_MAGIC);
        let c1 = _mm_set1_ps(EXP_C1);
        let c2 = _mm_set1_ps(EXP_C2);
        let one = _mm_set1_ps(1.0);
        let bias = _mm_set1_epi32(127);
        let p = xs.as_mut_ptr();
        let mut j = 0usize;
        while j < main {
            let mut x = _mm_loadu_ps(p.add(j));
            x = _mm_min_ps(x, hi);
            x = _mm_max_ps(x, lo);
            let z = _mm_add_ps(_mm_mul_ps(x, log2ef), magic);
            let nf = _mm_sub_ps(z, magic);
            let mut r = _mm_sub_ps(x, _mm_mul_ps(nf, c1));
            r = _mm_sub_ps(r, _mm_mul_ps(nf, c2));
            let mut y = _mm_set1_ps(EXP_P[0]);
            y = _mm_add_ps(_mm_mul_ps(y, r), _mm_set1_ps(EXP_P[1]));
            y = _mm_add_ps(_mm_mul_ps(y, r), _mm_set1_ps(EXP_P[2]));
            y = _mm_add_ps(_mm_mul_ps(y, r), _mm_set1_ps(EXP_P[3]));
            y = _mm_add_ps(_mm_mul_ps(y, r), _mm_set1_ps(EXP_P[4]));
            y = _mm_add_ps(_mm_mul_ps(y, r), _mm_set1_ps(EXP_P[5]));
            let r2 = _mm_mul_ps(r, r);
            y = _mm_add_ps(_mm_add_ps(_mm_mul_ps(y, r2), r), one);
            let ni = _mm_cvttps_epi32(nf);
            let pow2 = _mm_castsi128_ps(_mm_slli_epi32::<23>(_mm_add_epi32(ni, bias)));
            _mm_storeu_ps(p.add(j), _mm_mul_ps(y, pow2));
            j += 4;
        }
    }
    for v in &mut xs[main..] {
        *v = vexp1(*v);
    }
}

/// # Safety
/// Requires AVX2 (callers go through the [`best_available`]-clamped
/// dispatch, which only selects this after runtime detection).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn vexp_avx2_impl(xs: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = xs.len();
    let main = n - n % 8;
    let hi = _mm256_set1_ps(EXP_HI);
    let lo = _mm256_set1_ps(EXP_LO);
    let log2ef = _mm256_set1_ps(EXP_LOG2EF);
    let magic = _mm256_set1_ps(EXP_MAGIC);
    let c1 = _mm256_set1_ps(EXP_C1);
    let c2 = _mm256_set1_ps(EXP_C2);
    let one = _mm256_set1_ps(1.0);
    let bias = _mm256_set1_epi32(127);
    let p = xs.as_mut_ptr();
    let mut j = 0usize;
    while j < main {
        let mut x = _mm256_loadu_ps(p.add(j));
        x = _mm256_min_ps(x, hi);
        x = _mm256_max_ps(x, lo);
        let z = _mm256_add_ps(_mm256_mul_ps(x, log2ef), magic);
        let nf = _mm256_sub_ps(z, magic);
        let mut r = _mm256_sub_ps(x, _mm256_mul_ps(nf, c1));
        r = _mm256_sub_ps(r, _mm256_mul_ps(nf, c2));
        let mut y = _mm256_set1_ps(EXP_P[0]);
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P[1]));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P[2]));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P[3]));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P[4]));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P[5]));
        let r2 = _mm256_mul_ps(r, r);
        y = _mm256_add_ps(_mm256_add_ps(_mm256_mul_ps(y, r2), r), one);
        let ni = _mm256_cvttps_epi32(nf);
        let pow2 = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(ni, bias)));
        _mm256_storeu_ps(p.add(j), _mm256_mul_ps(y, pow2));
        j += 8;
    }
    for v in &mut xs[main..] {
        *v = vexp1(*v);
    }
}

#[cfg(target_arch = "x86_64")]
fn vexp_avx2(xs: &mut [f32]) {
    debug_assert!(best_available() == SimdLevel::Avx2);
    unsafe { vexp_avx2_impl(xs) }
}

/// Elementwise `exp` over a slice, in place, at the dispatched level.
/// ~1 ulp polynomial accuracy on the softmax/sigmoid range; clamps to
/// `[-87.34, 88.02]`. Bitwise identical across every level (elementwise
/// IEEE ops have no ordering freedom).
pub fn vexp_inplace(xs: &mut [f32]) {
    vexp_inplace_with(simd_level(), xs)
}

/// [`vexp_inplace`] at an explicit level (the determinism tests sweep
/// these).
pub fn vexp_inplace_with(level: SimdLevel, xs: &mut [f32]) {
    match level {
        SimdLevel::Scalar => {
            for v in xs.iter_mut() {
                *v = vexp1(*v);
            }
        }
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => vexp_sse2(xs),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => vexp_avx2(xs),
        #[cfg(not(target_arch = "x86_64"))]
        _ => {
            for v in xs.iter_mut() {
                *v = vexp1(*v);
            }
        }
    }
}

/// Elementwise logistic sigmoid `σ(z) = 1/(1 + exp(−z))`, in place.
/// The negate/add/divide steps are single IEEE ops (level-independent);
/// the exp runs on [`vexp_inplace_with`].
pub fn sigmoid_inplace_with(level: SimdLevel, zs: &mut [f32]) {
    for z in zs.iter_mut() {
        *z = -*z;
    }
    vexp_inplace_with(level, zs);
    for z in zs.iter_mut() {
        *z = 1.0 / (1.0 + *z);
    }
}

/// SwiGLU forward at the dispatched level — see [`swiglu_fwd_with`].
pub fn swiglu_fwd(gate_pre: &[f32], up: &[f32], sig: &mut [f32], act: &mut [f32]) {
    swiglu_fwd_with(simd_level(), gate_pre, up, sig, act)
}

/// SwiGLU gate product: `sig = σ(gate_pre)`, `act = (gate_pre · sig) ·
/// up` (silu(g) ⊙ up, the forward's exact association). `sig` is
/// returned so backward never recomputes the sigmoid.
pub fn swiglu_fwd_with(
    level: SimdLevel,
    gate_pre: &[f32],
    up: &[f32],
    sig: &mut [f32],
    act: &mut [f32],
) {
    debug_assert_eq!(gate_pre.len(), up.len());
    debug_assert_eq!(gate_pre.len(), sig.len());
    debug_assert_eq!(gate_pre.len(), act.len());
    sig.copy_from_slice(gate_pre);
    sigmoid_inplace_with(level, sig);
    for i in 0..gate_pre.len() {
        act[i] = gate_pre[i] * sig[i] * up[i];
    }
}

/// SwiGLU backward from the stashed forward sigmoid: `d_up = d_act·z·σ`
/// and `d_gp = d_act·up·σ·(1 + z·(1−σ))`. Purely single-op f32
/// elementwise math — no level parameter because no op here has a
/// SIMD-vs-scalar degree of freedom.
pub fn swiglu_bwd(
    d_act: &[f32],
    gate_pre: &[f32],
    up: &[f32],
    sig: &[f32],
    d_gp: &mut [f32],
    d_up: &mut [f32],
) {
    debug_assert_eq!(d_act.len(), gate_pre.len());
    debug_assert_eq!(d_act.len(), sig.len());
    for i in 0..d_act.len() {
        let z = gate_pre[i];
        let sg = sig[i];
        d_up[i] = d_act[i] * z * sg; // silu(z) = z·σ(z)
        d_gp[i] = d_act[i] * up[i] * sg * (1.0 + z * (1.0 - sg));
    }
}

/// In-place softmax over one score row: exact f32 max, `vexp(x − max)`,
/// serial ascending f64 sum (block-size invariant by construction), and
/// an `inv = (1/Σ) as f32` normalize at the dispatched level. Returns
/// `(max, inv)` — the two floats the fused attention stashes per query
/// row so backward can replay the identical probabilities.
pub fn softmax_row_with(level: SimdLevel, row: &mut [f32]) -> (f32, f32) {
    let mut max = f32::NEG_INFINITY;
    for &x in row.iter() {
        if x > max {
            max = x;
        }
    }
    for x in row.iter_mut() {
        *x -= max;
    }
    vexp_inplace_with(level, row);
    let mut sum = 0f64;
    for &e in row.iter() {
        sum += e as f64;
    }
    let inv = (1.0 / sum) as f32;
    for x in row.iter_mut() {
        *x *= inv;
    }
    (max, inv)
}

// ---------------------------------------------------------------------------
// Fused row-blocked attention
// ---------------------------------------------------------------------------
//
// qkᵀ → masked softmax → ·v in one pass per query row: the score buffer
// is one row of `limit ≤ T` floats (`limit = t1+1` causal, `T` for the
// vision tower), so the `T×T` per-head score matrix is never
// materialized — masked positions are never *computed* rather than
// computed-then-zeroed. Forward stashes `(max, 1/Σ)` per query row
// (2 floats instead of `T` probabilities); backward replays the exact
// forward op sequence from those stats, so the recomputed probabilities
// are bit-identical to what forward used.
//
// Work is threaded over (batch, head) pairs: each pair owns a disjoint
// head-major slice of every output/scratch buffer, and every row is
// computed by the identical per-row op sequence regardless of which
// worker runs it — bitwise identical for every thread count, like the
// gemm. The caller carves all buffers (the backend's workspace arena);
// workers never allocate pool-visible memory.

/// Resolve the lane-dot kernel once per call site (the fused attention
/// loops dispatch per row pair, not per element).
fn dot8_fn(level: SimdLevel) -> fn(&[f32], &[f32]) -> [f64; LANES] {
    match level {
        SimdLevel::Scalar => dot8_lanes_scalar,
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse2 => dot8_lanes_sse2,
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => dot8_lanes_avx2,
        #[cfg(not(target_arch = "x86_64"))]
        _ => dot8_lanes_scalar,
    }
}

/// Split several parallel buffers into per-worker chunks of whole
/// (batch, head) pairs and run `body(first_pair, n_pairs, chunks)` on
/// scoped workers. `bufs[i]` holds `pairs` rows of `row_lens[i]`
/// elements; every pair is processed by the same per-pair computation,
/// so the partition never changes a bit.
fn par_pairs<F>(pairs: usize, threads: usize, bufs: Vec<&mut [f32]>, row_lens: &[usize], body: F)
where
    F: Fn(usize, usize, Vec<&mut [f32]>) + Sync,
{
    debug_assert_eq!(bufs.len(), row_lens.len());
    let t = threads.min(pairs).max(1);
    if t <= 1 {
        body(0, pairs, bufs);
        return;
    }
    let chunk = pairs.div_ceil(t);
    std::thread::scope(|s| {
        let mut rest = bufs;
        let mut p0 = 0usize;
        while p0 < pairs {
            let take = chunk.min(pairs - p0);
            let mut heads = Vec::with_capacity(rest.len());
            let mut tails = Vec::with_capacity(rest.len());
            for (bi, buf) in rest.into_iter().enumerate() {
                let (head, tail) = buf.split_at_mut(take * row_lens[bi]);
                heads.push(head);
                tails.push(tail);
            }
            rest = tails;
            let body = &body;
            let first = p0;
            s.spawn(move || body(first, take, heads));
            p0 += take;
        }
    });
}

/// Fused attention forward at the dispatched level and work-gated
/// thread count — see [`fused_attention_fwd_with`].
#[allow(clippy::too_many_arguments)]
pub fn fused_attention_fwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    t: usize,
    h: usize,
    hd: usize,
    causal: bool,
    ctx_hm: &mut [f32],
    stats: &mut [f32],
    scratch: &mut [f32],
) {
    let threads = threads_for(b * h * t * t * hd);
    fused_attention_fwd_with(simd_level(), threads, q, k, v, b, t, h, hd, causal, ctx_hm, stats, scratch)
}

/// Fused attention forward over already-projected `q`/`k`/`v`
/// (`[B·T, H·hd]`, heads interleaved). Writes:
///
/// * `ctx_hm: [B·H, T·hd]` — the context rows, **head-major** (use
///   [`gather_heads`] to interleave for the output projection),
/// * `stats: [B·H, 2·T]` — per query row `(max, inv)`, the softmax
///   replay stats backward consumes,
/// * `scratch: [B·H, T]` — per-pair score-row workspace (contents
///   unspecified on return).
///
/// Scores are lane-split [`dot8_with`] products × `1/√hd`; the softmax
/// runs [`softmax_row_with`] over the `limit` unmasked positions only;
/// the `·v` contraction accumulates one f64 lane per head dim. Bitwise
/// identical across every SIMD level and thread count.
#[allow(clippy::too_many_arguments)]
pub fn fused_attention_fwd_with(
    level: SimdLevel,
    threads: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    t: usize,
    h: usize,
    hd: usize,
    causal: bool,
    ctx_hm: &mut [f32],
    stats: &mut [f32],
    scratch: &mut [f32],
) {
    let d = h * hd;
    debug_assert_eq!(q.len(), b * t * d);
    debug_assert_eq!(k.len(), b * t * d);
    debug_assert_eq!(v.len(), b * t * d);
    debug_assert_eq!(ctx_hm.len(), b * h * t * hd);
    debug_assert_eq!(stats.len(), b * h * 2 * t);
    debug_assert_eq!(scratch.len(), b * h * t);
    let pairs = b * h;
    let inv_sqrt = 1.0 / (hd as f64).sqrt();
    let dotf = dot8_fn(level);
    par_pairs(
        pairs,
        threads,
        vec![ctx_hm, stats, scratch],
        &[t * hd, 2 * t, t],
        |first, take, bufs| {
            let [ctx_c, st_c, sc_c]: [&mut [f32]; 3] = bufs.try_into().unwrap();
            let mut crow = vec![0f64; hd];
            for local in 0..take {
                let pair = first + local;
                let (bi, hh) = (pair / h, pair % h);
                let ctx_rows = &mut ctx_c[local * t * hd..(local + 1) * t * hd];
                let st_rows = &mut st_c[local * 2 * t..(local + 1) * 2 * t];
                let srow_full = &mut sc_c[local * t..(local + 1) * t];
                for t1 in 0..t {
                    let limit = if causal { t1 + 1 } else { t };
                    let qrow = &q[(bi * t + t1) * d + hh * hd..][..hd];
                    let srow = &mut srow_full[..limit];
                    for (t2, sc) in srow.iter_mut().enumerate() {
                        let krow = &k[(bi * t + t2) * d + hh * hd..][..hd];
                        *sc = (reduce8(&dotf(qrow, krow)) * inv_sqrt) as f32;
                    }
                    let (mx, inv) = softmax_row_with(level, srow);
                    st_rows[2 * t1] = mx;
                    st_rows[2 * t1 + 1] = inv;
                    crow.fill(0.0);
                    for (t2, &p) in srow.iter().enumerate() {
                        let p = p as f64;
                        let vrow = &v[(bi * t + t2) * d + hh * hd..][..hd];
                        for (c, &vv) in crow.iter_mut().zip(vrow.iter()) {
                            *c += p * vv as f64;
                        }
                    }
                    let out = &mut ctx_rows[t1 * hd..(t1 + 1) * hd];
                    for (o, &c) in out.iter_mut().zip(crow.iter()) {
                        *o = c as f32;
                    }
                }
            }
        },
    );
}

/// Fused attention backward at the dispatched level and work-gated
/// thread count — see [`fused_attention_bwd_with`].
#[allow(clippy::too_many_arguments)]
pub fn fused_attention_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    stats: &[f32],
    dctx: &[f32],
    b: usize,
    t: usize,
    h: usize,
    hd: usize,
    causal: bool,
    dq_hm: &mut [f32],
    dk_hm: &mut [f32],
    dv_hm: &mut [f32],
    scratch: &mut [f32],
) {
    let threads = threads_for(b * h * t * t * hd);
    fused_attention_bwd_with(
        simd_level(), threads, q, k, v, stats, dctx, b, t, h, hd, causal, dq_hm, dk_hm, dv_hm,
        scratch,
    )
}

/// Fused attention backward: recomputes each query row's probabilities
/// by replaying the forward's exact op sequence (scores → subtract the
/// stashed `max` → [`vexp_inplace_with`] → scale by the stashed `inv`),
/// then applies the softmax/score chain rule. Inputs `q`/`k`/`v`/`dctx`
/// are interleaved `[B·T, H·hd]`; outputs `dq_hm`/`dk_hm`/`dv_hm` are
/// head-major `[B·H, T·hd]` accumulation buffers the **caller zeroes**;
/// `scratch` is `[B·H, 2·T]` per-pair workspace (probability row +
/// dprobs row, contents unspecified on return). Bitwise identical
/// across every SIMD level and thread count.
#[allow(clippy::too_many_arguments)]
pub fn fused_attention_bwd_with(
    level: SimdLevel,
    threads: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    stats: &[f32],
    dctx: &[f32],
    b: usize,
    t: usize,
    h: usize,
    hd: usize,
    causal: bool,
    dq_hm: &mut [f32],
    dk_hm: &mut [f32],
    dv_hm: &mut [f32],
    scratch: &mut [f32],
) {
    let d = h * hd;
    debug_assert_eq!(q.len(), b * t * d);
    debug_assert_eq!(dctx.len(), b * t * d);
    debug_assert_eq!(stats.len(), b * h * 2 * t);
    debug_assert_eq!(dq_hm.len(), b * h * t * hd);
    debug_assert_eq!(dk_hm.len(), b * h * t * hd);
    debug_assert_eq!(dv_hm.len(), b * h * t * hd);
    debug_assert_eq!(scratch.len(), b * h * 2 * t);
    let pairs = b * h;
    let inv_sqrt = 1.0 / (hd as f64).sqrt();
    let dotf = dot8_fn(level);
    par_pairs(
        pairs,
        threads,
        vec![dq_hm, dk_hm, dv_hm, scratch],
        &[t * hd, t * hd, t * hd, 2 * t],
        |first, take, bufs| {
            let [dq_c, dk_c, dv_c, sc_c]: [&mut [f32]; 4] = bufs.try_into().unwrap();
            for local in 0..take {
                let pair = first + local;
                let (bi, hh) = (pair / h, pair % h);
                let st = &stats[pair * 2 * t..][..2 * t];
                let (srow_full, drow_full) =
                    sc_c[local * 2 * t..(local + 1) * 2 * t].split_at_mut(t);
                for t1 in 0..t {
                    let limit = if causal { t1 + 1 } else { t };
                    let qrow = &q[(bi * t + t1) * d + hh * hd..][..hd];
                    // replay the forward probabilities bit-exactly
                    let srow = &mut srow_full[..limit];
                    for (t2, sc) in srow.iter_mut().enumerate() {
                        let krow = &k[(bi * t + t2) * d + hh * hd..][..hd];
                        *sc = (reduce8(&dotf(qrow, krow)) * inv_sqrt) as f32;
                    }
                    let (mx, inv) = (st[2 * t1], st[2 * t1 + 1]);
                    for x in srow.iter_mut() {
                        *x -= mx;
                    }
                    vexp_inplace_with(level, srow);
                    for x in srow.iter_mut() {
                        *x *= inv;
                    }
                    // dprobs[t2] = dctx · v[t2]; dv[t2] += probs · dctx
                    let dcrow = &dctx[(bi * t + t1) * d + hh * hd..][..hd];
                    let drow = &mut drow_full[..limit];
                    let mut dot = 0f64; // Σ dprobs·probs (softmax backward)
                    for t2 in 0..limit {
                        let vrow = &v[(bi * t + t2) * d + hh * hd..][..hd];
                        let acc = reduce8(&dotf(dcrow, vrow));
                        drow[t2] = acc as f32;
                        dot += acc * srow[t2] as f64;
                        let p = srow[t2];
                        let dvrow = &mut dv_c[local * t * hd + t2 * hd..][..hd];
                        for (dvv, &dc) in dvrow.iter_mut().zip(dcrow.iter()) {
                            *dvv += p * dc;
                        }
                    }
                    // dscores = probs ⊙ (dprobs − Σ dprobs·probs), then
                    // the 1/√hd chain into q and k
                    for t2 in 0..limit {
                        let ds = srow[t2] as f64 * (drow[t2] as f64 - dot) * inv_sqrt;
                        let krow = &k[(bi * t + t2) * d + hh * hd..][..hd];
                        let dkrow = &mut dk_c[local * t * hd + t2 * hd..][..hd];
                        let dqrow = &mut dq_c[local * t * hd + t1 * hd..][..hd];
                        for di in 0..hd {
                            dqrow[di] = (dqrow[di] as f64 + ds * krow[di] as f64) as f32;
                            dkrow[di] = (dkrow[di] as f64 + ds * qrow[di] as f64) as f32;
                        }
                    }
                }
            }
        },
    );
}

/// Regather a head-major `[B·H, T·hd]` buffer (what the fused attention
/// kernels write) into the interleaved `[B·T, H·hd]` layout the
/// projection matmuls consume. Pure copy — no FP ops.
pub fn gather_heads(hm: &[f32], b: usize, t: usize, h: usize, hd: usize, out: &mut [f32]) {
    let d = h * hd;
    debug_assert_eq!(hm.len(), b * h * t * hd);
    debug_assert_eq!(out.len(), b * t * d);
    for bi in 0..b {
        for hh in 0..h {
            let base = (bi * h + hh) * t * hd;
            for t1 in 0..t {
                let src = base + t1 * hd;
                let dst = (bi * t + t1) * d + hh * hd;
                out[dst..dst + hd].copy_from_slice(&hm[src..src + hd]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.gauss() as f32).collect()
    }

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut out = vec![0f64; m * n];
        for i in 0..m {
            for x in 0..k {
                for j in 0..n {
                    out[i * n + j] += a[i * k + x] as f64 * b[x * n + j] as f64;
                }
            }
        }
        out
    }

    fn assert_close(got: &[f32], want: &[f64]) {
        for (g, w) in got.iter().zip(want.iter()) {
            let rel = (*g as f64 - w).abs() / w.abs().max(1.0);
            assert!(rel < 1e-5, "kernel vs naive: {g} vs {w}");
        }
    }

    #[test]
    fn microkernel_matches_naive_f64_matmul() {
        let mut rng = Rng::new(41);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (8, 16, 8), (13, 33, 11)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            assert_close(&matmul(&a, &b, m, k, n), &naive_matmul(&a, &b, m, k, n));
            // nt: out[m,k'] = a'[m,n'] @ b'ᵀ for b' [k', n'] equals
            // naive a' @ (b'ᵀ) — reuse naive via explicit transpose
            let bt = transpose(&b, k, n); // [n, k]
            assert_close(&matmul_nt(&a, &bt, m, k, n), &naive_matmul(&a, &b, m, k, n));
            // tn: aᵀ @ c for c [m, n]
            let c = randv(&mut rng, m * n);
            let at = transpose(&a, m, k); // [k, m]
            assert_close(&matmul_tn(&a, &c, m, k, n), &naive_matmul(&at, &c, k, m, n));
        }
    }

    #[test]
    fn all_levels_and_thread_counts_are_bitwise_identical() {
        let mut rng = Rng::new(77);
        let levels = available_levels();
        for &(m, k, n) in &[(1, 1, 1), (2, 7, 3), (8, 8, 8), (13, 9, 11), (5, 33, 17)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            let bnt = randv(&mut rng, n * k); // [n, k] view for nt
            let btn = randv(&mut rng, m * n); // [m, n] view for tn
            let base = matmul_with(SimdLevel::Scalar, 1, &a, &b, m, k, n);
            let base_tn = matmul_tn_with(SimdLevel::Scalar, 1, &a, &btn, m, k, n);
            let base_nt = matmul_nt_with(SimdLevel::Scalar, 1, &a, &bnt, m, k, n);
            for &level in &levels {
                for threads in [1, 2, 4] {
                    let bits = |x: &[f32], y: &[f32]| {
                        x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
                    };
                    assert!(
                        bits(&base, &matmul_with(level, threads, &a, &b, m, k, n)),
                        "matmul {level:?} x{threads} diverged"
                    );
                    assert!(
                        bits(&base_tn, &matmul_tn_with(level, threads, &a, &btn, m, k, n)),
                        "matmul_tn {level:?} x{threads} diverged"
                    );
                    assert!(
                        bits(&base_nt, &matmul_nt_with(level, threads, &a, &bnt, m, k, n)),
                        "matmul_nt {level:?} x{threads} diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn reductions_are_bitwise_identical_across_levels() {
        let mut rng = Rng::new(91);
        for n in [0usize, 1, 7, 8, 9, 64, 129] {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            let c = randv(&mut rng, n);
            for &level in &available_levels() {
                assert_eq!(
                    dot8_with(SimdLevel::Scalar, &a, &b).to_bits(),
                    dot8_with(level, &a, &b).to_bits(),
                    "dot8 {level:?} n={n}"
                );
                assert_eq!(
                    dot3_8_with(SimdLevel::Scalar, &a, &b, &c).to_bits(),
                    dot3_8_with(level, &a, &b, &c).to_bits(),
                    "dot3_8 {level:?} n={n}"
                );
                assert_eq!(
                    abs_sum8_with(SimdLevel::Scalar, &a).to_bits(),
                    abs_sum8_with(level, &a).to_bits(),
                    "abs_sum8 {level:?} n={n}"
                );
                assert_eq!(
                    abs_diff_sum8_with(SimdLevel::Scalar, &a, &b).to_bits(),
                    abs_diff_sum8_with(level, &a, &b).to_bits(),
                    "abs_diff_sum8 {level:?} n={n}"
                );
            }
            // sanity anchors against plain f64 loops (order-insensitive
            // tolerance — the lane split reorders the sum)
            let naive: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            assert!((dot8(&a, &b) - naive).abs() <= 1e-9 * naive.abs().max(1.0));
            let nabs: f64 = a.iter().map(|&x| x.abs() as f64).sum();
            assert!((abs_sum8(&a) - nabs).abs() <= 1e-9 * nabs.max(1.0));
        }
    }

    #[test]
    fn transpose_round_trips_exactly() {
        let mut rng = Rng::new(13);
        for &(r, c) in &[(1, 1), (3, 5), (32, 32), (33, 65), (7, 100)] {
            let x = randv(&mut rng, r * c);
            let t = transpose(&x, r, c);
            assert_eq!(transpose(&t, c, r), x);
            assert_eq!(t[0], x[0]);
            if r > 1 && c > 1 {
                assert_eq!(t[1 * r + 0], x[0 * c + 1]);
            }
        }
    }

    #[test]
    fn overrides_clamp_and_restore() {
        // requesting a wider level than the CPU has must clamp, never trap
        set_simd_override(Some(SimdLevel::Avx2));
        assert!(simd_level() <= best_available());
        set_simd_override(Some(SimdLevel::Scalar));
        assert_eq!(simd_level(), SimdLevel::Scalar);
        set_simd_override(None);
        set_thread_override(Some(0));
        assert_eq!(host_threads(), 1);
        set_thread_override(None);
    }

    #[test]
    fn vexp_matches_libm_exp_and_is_bitwise_identical_across_levels() {
        let mut rng = Rng::new(433);
        // sweep the softmax/sigmoid working range plus the clamp edges
        // and awkward lengths (SIMD main loop + scalar tail)
        for &n in &[1usize, 3, 8, 9, 31, 257] {
            let mut base: Vec<f32> =
                (0..n).map(|_| (rng.gauss() * 20.0) as f32).collect();
            base[0] = 0.0;
            if n > 4 {
                base[1] = 100.0; // above EXP_HI: clamps, stays finite
                base[2] = -100.0; // below EXP_LO: tiny, not zero/NaN
                base[3] = 88.0;
                base[4] = -87.0;
            }
            let mut scalar = base.clone();
            vexp_inplace_with(SimdLevel::Scalar, &mut scalar);
            assert_eq!(scalar[0], 1.0, "vexp(0) must be exactly 1");
            for (x, e) in base.iter().zip(scalar.iter()) {
                assert!(e.is_finite() && *e > 0.0, "vexp({x}) = {e}");
                if *x >= -80.0 && *x <= 80.0 {
                    let want = (*x as f64).exp();
                    let rel = (*e as f64 - want).abs() / want;
                    assert!(rel < 1e-5, "vexp({x}) = {e}, libm {want}");
                }
            }
            for level in available_levels() {
                let mut got = base.clone();
                vexp_inplace_with(level, &mut got);
                for (s, g) in scalar.iter().zip(got.iter()) {
                    assert_eq!(s.to_bits(), g.to_bits(), "level {level:?}");
                }
            }
        }
    }

    #[test]
    fn softmax_and_swiglu_are_bitwise_identical_across_levels() {
        let mut rng = Rng::new(577);
        for &n in &[1usize, 7, 64, 129] {
            let row = randv(&mut rng, n);
            let g = randv(&mut rng, n);
            let u = randv(&mut rng, n);
            let mut srow = row.clone();
            let (mx, inv) = softmax_row_with(SimdLevel::Scalar, &mut srow);
            let sum: f64 = srow.iter().map(|&p| p as f64).sum();
            assert!((sum - 1.0).abs() < 1e-4, "softmax sums to 1, got {sum}");
            let mut sig0 = vec![0f32; n];
            let mut act0 = vec![0f32; n];
            swiglu_fwd_with(SimdLevel::Scalar, &g, &u, &mut sig0, &mut act0);
            for i in 0..n {
                let want = {
                    let z = g[i] as f64;
                    z / (1.0 + (-z).exp()) * u[i] as f64
                };
                let rel = (act0[i] as f64 - want).abs() / want.abs().max(1.0);
                assert!(rel < 1e-5, "swiglu: {} vs {want}", act0[i]);
            }
            for level in available_levels() {
                let mut s2 = row.clone();
                let (m2, i2) = softmax_row_with(level, &mut s2);
                assert_eq!(mx.to_bits(), m2.to_bits());
                assert_eq!(inv.to_bits(), i2.to_bits());
                assert!(srow.iter().zip(s2.iter()).all(|(a, b)| a.to_bits() == b.to_bits()));
                let mut sig = vec![0f32; n];
                let mut act = vec![0f32; n];
                swiglu_fwd_with(level, &g, &u, &mut sig, &mut act);
                assert!(sig0.iter().zip(sig.iter()).all(|(a, b)| a.to_bits() == b.to_bits()));
                assert!(act0.iter().zip(act.iter()).all(|(a, b)| a.to_bits() == b.to_bits()));
            }
        }
    }

    /// f64 reference attention in the interleaved `[B·T, H·hd]` layout.
    #[allow(clippy::too_many_arguments)]
    fn naive_attention_f64(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        b: usize,
        t: usize,
        h: usize,
        hd: usize,
        causal: bool,
    ) -> Vec<f64> {
        let d = h * hd;
        let mut out = vec![0f64; b * t * d];
        let inv_sqrt = 1.0 / (hd as f64).sqrt();
        for bi in 0..b {
            for hh in 0..h {
                for t1 in 0..t {
                    let limit = if causal { t1 + 1 } else { t };
                    let mut scores = vec![0f64; limit];
                    for (t2, s) in scores.iter_mut().enumerate() {
                        for di in 0..hd {
                            *s += q[(bi * t + t1) * d + hh * hd + di] as f64
                                * k[(bi * t + t2) * d + hh * hd + di] as f64;
                        }
                        *s *= inv_sqrt;
                    }
                    let max = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                    let mut sum = 0f64;
                    for s in scores.iter_mut() {
                        *s = (*s - max).exp();
                        sum += *s;
                    }
                    for s in scores.iter_mut() {
                        *s /= sum;
                    }
                    for (t2, p) in scores.iter().enumerate() {
                        for di in 0..hd {
                            out[(bi * t + t1) * d + hh * hd + di] +=
                                p * v[(bi * t + t2) * d + hh * hd + di] as f64;
                        }
                    }
                }
            }
        }
        out
    }

    /// Run the fused forward and gather into the interleaved layout.
    #[allow(clippy::too_many_arguments)]
    fn fused_fwd(
        level: SimdLevel,
        threads: usize,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        b: usize,
        t: usize,
        h: usize,
        hd: usize,
        causal: bool,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut ctx_hm = vec![0f32; b * h * t * hd];
        let mut stats = vec![0f32; b * h * 2 * t];
        let mut scratch = vec![0f32; b * h * t];
        fused_attention_fwd_with(
            level, threads, q, k, v, b, t, h, hd, causal, &mut ctx_hm, &mut stats, &mut scratch,
        );
        let mut ctx = vec![0f32; b * t * h * hd];
        gather_heads(&ctx_hm, b, t, h, hd, &mut ctx);
        (ctx, stats)
    }

    #[test]
    fn fused_attention_matches_naive_f64_attention() {
        let mut rng = Rng::new(691);
        for &(b, t, h, hd) in &[(1, 1, 1, 4), (2, 5, 2, 3), (1, 9, 3, 8)] {
            for &causal in &[true, false] {
                let d = h * hd;
                let q = randv(&mut rng, b * t * d);
                let k = randv(&mut rng, b * t * d);
                let v = randv(&mut rng, b * t * d);
                let (ctx, _) = fused_fwd(simd_level(), 1, &q, &k, &v, b, t, h, hd, causal);
                let want = naive_attention_f64(&q, &k, &v, b, t, h, hd, causal);
                for (g, w) in ctx.iter().zip(want.iter()) {
                    let rel = (*g as f64 - w).abs() / w.abs().max(1.0);
                    assert!(rel < 1e-4, "fused vs naive: {g} vs {w} (causal={causal})");
                }
            }
        }
    }

    #[test]
    fn fused_attention_is_bitwise_identical_across_levels_and_threads() {
        let mut rng = Rng::new(733);
        let (b, t, h, hd) = (2, 7, 3, 5);
        let d = h * hd;
        let q = randv(&mut rng, b * t * d);
        let k = randv(&mut rng, b * t * d);
        let v = randv(&mut rng, b * t * d);
        for &causal in &[true, false] {
            let (ctx0, st0) = fused_fwd(SimdLevel::Scalar, 1, &q, &k, &v, b, t, h, hd, causal);
            let dctx = randv(&mut rng, b * t * d);
            let mut dq0 = vec![0f32; b * h * t * hd];
            let mut dk0 = vec![0f32; b * h * t * hd];
            let mut dv0 = vec![0f32; b * h * t * hd];
            let mut sc = vec![0f32; b * h * 2 * t];
            fused_attention_bwd_with(
                SimdLevel::Scalar, 1, &q, &k, &v, &st0, &dctx, b, t, h, hd, causal, &mut dq0,
                &mut dk0, &mut dv0, &mut sc,
            );
            for level in available_levels() {
                for threads in [1usize, 2, 4] {
                    let (ctx, st) = fused_fwd(level, threads, &q, &k, &v, b, t, h, hd, causal);
                    assert!(
                        ctx0.iter().zip(ctx.iter()).all(|(a, x)| a.to_bits() == x.to_bits()),
                        "fwd ctx diverged at {level:?}/{threads}t"
                    );
                    assert!(
                        st0.iter().zip(st.iter()).all(|(a, x)| a.to_bits() == x.to_bits()),
                        "fwd stats diverged at {level:?}/{threads}t"
                    );
                    let mut dq = vec![0f32; b * h * t * hd];
                    let mut dk = vec![0f32; b * h * t * hd];
                    let mut dv = vec![0f32; b * h * t * hd];
                    fused_attention_bwd_with(
                        level, threads, &q, &k, &v, &st, &dctx, b, t, h, hd, causal, &mut dq,
                        &mut dk, &mut dv, &mut sc,
                    );
                    for (name, a0, a) in [("dq", &dq0, &dq), ("dk", &dk0, &dk), ("dv", &dv0, &dv)]
                    {
                        assert!(
                            a0.iter().zip(a.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                            "bwd {name} diverged at {level:?}/{threads}t"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fused_attention_backward_passes_finite_differences() {
        // loss = Σ ctx ⊙ W for fixed random W, so dctx = W; check dq/dk/dv
        // against central differences through the fused forward.
        let mut rng = Rng::new(797);
        let (b, t, h, hd) = (1, 4, 2, 3);
        let d = h * hd;
        let q = randv(&mut rng, b * t * d);
        let k = randv(&mut rng, b * t * d);
        let v = randv(&mut rng, b * t * d);
        let w = randv(&mut rng, b * t * d);
        for &causal in &[true, false] {
            let (_, stats) = fused_fwd(SimdLevel::Scalar, 1, &q, &k, &v, b, t, h, hd, causal);
            let mut dq_hm = vec![0f32; b * h * t * hd];
            let mut dk_hm = vec![0f32; b * h * t * hd];
            let mut dv_hm = vec![0f32; b * h * t * hd];
            let mut sc = vec![0f32; b * h * 2 * t];
            fused_attention_bwd_with(
                SimdLevel::Scalar, 1, &q, &k, &v, &stats, &w, b, t, h, hd, causal, &mut dq_hm,
                &mut dk_hm, &mut dv_hm, &mut sc,
            );
            let mut dq = vec![0f32; b * t * d];
            let mut dk = vec![0f32; b * t * d];
            let mut dv = vec![0f32; b * t * d];
            gather_heads(&dq_hm, b, t, h, hd, &mut dq);
            gather_heads(&dk_hm, b, t, h, hd, &mut dk);
            gather_heads(&dv_hm, b, t, h, hd, &mut dv);
            let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f64 {
                let (ctx, _) = fused_fwd(SimdLevel::Scalar, 1, q, k, v, b, t, h, hd, causal);
                ctx.iter().zip(w.iter()).map(|(c, wi)| *c as f64 * *wi as f64).sum()
            };
            let eps = 1e-2f32;
            for (name, xs, grad) in
                [("dq", &q, &dq), ("dk", &k, &dk), ("dv", &v, &dv)]
            {
                for i in 0..xs.len() {
                    let mut plus = xs.to_vec();
                    let mut minus = xs.to_vec();
                    plus[i] += eps;
                    minus[i] -= eps;
                    let fd = match name {
                        "dq" => (loss(&plus, &k, &v) - loss(&minus, &k, &v)) / (2.0 * eps as f64),
                        "dk" => (loss(&q, &plus, &v) - loss(&q, &minus, &v)) / (2.0 * eps as f64),
                        _ => (loss(&q, &k, &plus) - loss(&q, &k, &minus)) / (2.0 * eps as f64),
                    };
                    let an = grad[i] as f64;
                    assert!(
                        (fd - an).abs() <= 2e-2 * fd.abs().max(1.0),
                        "{name}[{i}]: fd {fd} vs analytic {an} (causal={causal})"
                    );
                }
            }
        }
    }

    #[test]
    fn gather_heads_inverts_the_head_major_layout() {
        let (b, t, h, hd) = (2, 3, 4, 5);
        let d = h * hd;
        let inter: Vec<f32> = (0..b * t * d).map(|i| i as f32).collect();
        // scatter to head-major by the documented index map, then gather
        let mut hm = vec![0f32; b * h * t * hd];
        for bi in 0..b {
            for hh in 0..h {
                for t1 in 0..t {
                    for di in 0..hd {
                        hm[((bi * h + hh) * t + t1) * hd + di] =
                            inter[(bi * t + t1) * d + hh * hd + di];
                    }
                }
            }
        }
        let mut back = vec![0f32; b * t * d];
        gather_heads(&hm, b, t, h, hd, &mut back);
        assert_eq!(inter, back);
    }
}
