//! Artifact bundle: one compiled PJRT executable per step function plus the
//! manifest, all loaded from `artifacts/<config>/`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::Manifest;
use super::xerr;

/// Shared PJRT CPU client. Creating a TfrtCpuClient is expensive; share one
/// per process.
#[derive(Clone)]
pub struct Client(pub Arc<PjRtClient>);

impl Client {
    pub fn cpu() -> Result<Self> {
        Ok(Client(Arc::new(PjRtClient::cpu().map_err(xerr)?)))
    }

    pub fn compile_file(&self, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = HloModuleProto::from_text_file(path)
            .map_err(xerr)
            .with_context(|| format!("loading HLO text {path:?}"))?;
        self.0
            .compile(&XlaComputation::from_proto(&proto))
            .map_err(xerr)
            .with_context(|| format!("compiling {path:?}"))
    }
}

/// All executables for one config.
pub struct Bundle {
    pub manifest: Manifest,
    pub dir: PathBuf,
    pub client: Client,
    pub init: PjRtLoadedExecutable,
    pub train_step: PjRtLoadedExecutable,
    /// Variant with attention dW matmuls removed from the backward graph —
    /// the scheduler hot-swaps to this once GradES froze all attention.
    pub train_step_attn_frozen: PjRtLoadedExecutable,
    pub eval_step: PjRtLoadedExecutable,
    /// Per-row losses for multiple-choice scoring → f32[2B].
    pub eval_rows: PjRtLoadedExecutable,
    pub probe: PjRtLoadedExecutable,
}

impl Bundle {
    pub fn load(client: &Client, dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let exe = |key: &str| -> Result<PjRtLoadedExecutable> {
            let fname = manifest
                .executables
                .get(key)
                .ok_or_else(|| anyhow!("manifest has no executable {key:?}"))?;
            client.compile_file(&dir.join(fname))
        };
        Ok(Bundle {
            init: exe("init")?,
            train_step: exe("train_step")?,
            train_step_attn_frozen: exe("train_step_attn_frozen")?,
            eval_step: exe("eval_step")?,
            eval_rows: exe("eval_rows")?,
            probe: exe("probe")?,
            manifest,
            dir: dir.to_path_buf(),
            client: client.clone(),
        })
    }

    /// Load by config name from the repo's `artifacts/` dir.
    pub fn by_name(client: &Client, name: &str) -> Result<Self> {
        let dir = crate::config::repo_root().join("artifacts").join(name);
        Self::load(client, &dir)
    }

    /// Compilation timings for all executables (perf diagnostics).
    pub fn compile_times(client: &Client, dir: &Path) -> Result<BTreeMap<String, f64>> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let mut out = BTreeMap::new();
        for (key, fname) in &manifest.executables {
            let t = std::time::Instant::now();
            client.compile_file(&dir.join(fname))?;
            out.insert(key.clone(), t.elapsed().as_secs_f64());
        }
        Ok(out)
    }
}
