//! Artifact bundle: one compiled PJRT executable per step function plus the
//! manifest, all loaded from `artifacts/<config>/`.
//!
//! `Bundle::load` pipelines the six executables' load: scoped worker
//! threads read + parse the HLO text into protos in parallel while the
//! loader thread compiles each proto as soon as it is ready (artifact
//! load is the startup hot path: every bench/experiment binary pays it
//! per config). Backend compilation itself stays on the loader thread —
//! the binding's client handles hold non-atomic refcounts and must not
//! be touched concurrently. Set `GRADES_SERIAL_COMPILE=1` to fall back
//! to the seed's fully sequential loop.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::backend::{Backend, BackendState, CtrlBuf, UploadedBatch};
use super::manifest::Manifest;
use super::session::Batch;
use super::xerr;
use crate::coordinator::scheduler::{StepPlan, VariantLattice};

/// Shared PJRT CPU client. Creating a TfrtCpuClient is expensive; share one
/// per process. "Per process" is load-bearing: a client's handles hold
/// non-atomic refcounts and are meaningless outside the process that
/// created them, so the coordinator/worker runtime (`exp::coordinator`)
/// never ships clients, executables or buffers over its wire — each
/// worker process builds its own client on first XLA load.
#[derive(Clone)]
pub struct Client(pub Arc<PjRtClient>);

impl Client {
    /// Create the shared CPU client.
    pub fn cpu() -> Result<Self> {
        Ok(Client(Arc::new(PjRtClient::cpu().map_err(xerr)?)))
    }

    /// Parse + compile one HLO text file.
    pub fn compile_file(&self, path: &Path) -> Result<PjRtLoadedExecutable> {
        self.compile_proto(&load_proto(path)?, path)
    }

    fn compile_proto(&self, proto: &HloModuleProto, path: &Path) -> Result<PjRtLoadedExecutable> {
        self.0
            .compile(&XlaComputation::from_proto(proto))
            .map_err(xerr)
            .with_context(|| format!("compiling {path:?}"))
    }
}

/// Read + parse one HLO text file (no client involved: a proto is plain
/// parsed data, exclusively owned by whoever holds it).
fn load_proto(path: &Path) -> Result<HloModuleProto> {
    HloModuleProto::from_text_file(path)
        .map_err(xerr)
        .with_context(|| format!("loading HLO text {path:?}"))
}

/// Move-only cell for handing an exclusively-owned value across threads.
///
/// SAFETY CONTRACT (pipelined artifact load only): `HloModuleProto` is
/// `!Send` because the binding marks all its FFI handles so, but a proto
/// is standalone parsed data with no shared internals — it is constructed
/// on one worker thread, moved exactly once to the loader thread, and
/// only used and dropped there, so no state is ever accessed from two
/// threads. The PJRT client (which *does* hold non-atomic refcounts that
/// `compile` clones) never crosses a thread boundary.
struct SendCell<T>(T);
unsafe impl<T> Send for SendCell<T> {}

/// Accepted `GRADES_SERIAL_COMPILE` values: `1` forces the sequential
/// compile loop; `0`, empty or unset keep the pipelined default. Anything
/// else used to silently mean "pipelined" — now it warns once on stderr.
fn serial_compile_forced() -> bool {
    match std::env::var("GRADES_SERIAL_COMPILE") {
        Err(_) => false,
        Ok(v) if v == "1" => true,
        Ok(v) if v.is_empty() || v == "0" => false,
        Ok(v) => {
            static WARNED: std::sync::Once = std::sync::Once::new();
            WARNED.call_once(|| {
                eprintln!(
                    "[artifact] ignoring GRADES_SERIAL_COMPILE={v:?}: expected 1 \
                     (serial) or 0/unset (pipelined); using the pipelined load"
                );
            });
            false
        }
    }
}

/// All executables for one config.
pub struct Bundle {
    /// The artifact's manifest.
    pub manifest: Manifest,
    /// Artifact directory the bundle was loaded from.
    pub dir: PathBuf,
    /// The client every executable was compiled on.
    pub client: Client,
    /// Parameter/optimizer-state initializer (seed → state).
    pub init: PjRtLoadedExecutable,
    /// Train-step graph variants, index-aligned with `lattice.variants`
    /// (index 0 is always the full fwd+bwd+update graph; the shipped
    /// artifacts add `train_step_attn_frozen`, whose backward omits all
    /// attention dW matmuls). A step plan is lowered to the variant with
    /// the largest omitted set still ⊆ the plan's.
    pub train_variants: Vec<PjRtLoadedExecutable>,
    /// The variant lattice (omitted set per train-step executable),
    /// derived from manifest data.
    pub lattice: VariantLattice,
    /// Forward-only loss → (loss_sum, count).
    pub eval_step: PjRtLoadedExecutable,
    /// Per-row losses for multiple-choice scoring → f32[2B].
    pub eval_rows: PjRtLoadedExecutable,
    /// Metrics-prefix reader (no state change).
    pub probe: PjRtLoadedExecutable,
    /// Wall seconds the compile phase took (parallel or sequential).
    pub compile_secs: f64,
}

/// The non-variant executables every artifact dir ships (the train-step
/// variant family is discovered from the manifest — see
/// [`VariantLattice::from_manifest`]).
const FIXED_EXE_KEYS: [&str; 4] = ["init", "eval_step", "eval_rows", "probe"];

impl Bundle {
    /// Load + compile every executable of an artifact dir.
    pub fn load(client: &Client, dir: &Path) -> Result<Self> {
        Self::load_with(client, dir, !serial_compile_forced())
    }

    /// Load with an explicit compile strategy (`parallel = false` is the
    /// seed's sequential loop; results are identical, only startup wall
    /// time differs).
    pub fn load_with(client: &Client, dir: &Path, parallel: bool) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let lattice = VariantLattice::from_manifest(&manifest)?;
        let path_of = |key: &str| -> Result<PathBuf> {
            let fname = manifest
                .executables
                .get(key)
                .ok_or_else(|| anyhow!("manifest has no executable {key:?}"))?;
            Ok(dir.join(fname))
        };
        // fixed programs first, then the variants in lattice order
        let mut paths: Vec<PathBuf> =
            FIXED_EXE_KEYS.iter().map(|&k| path_of(k)).collect::<Result<_>>()?;
        for v in &lattice.variants {
            paths.push(path_of(&v.key)?);
        }
        let t = std::time::Instant::now();
        let mut exes = if parallel && paths.len() > 1 {
            compile_parallel(client, &paths)?
        } else {
            paths.iter().map(|p| client.compile_file(p)).collect::<Result<Vec<_>>>()?
        };
        let compile_secs = t.elapsed().as_secs_f64();
        let train_variants: Vec<PjRtLoadedExecutable> =
            exes.split_off(FIXED_EXE_KEYS.len());
        // pop in reverse of FIXED_EXE_KEYS order
        let probe = exes.pop().unwrap();
        let eval_rows = exes.pop().unwrap();
        let eval_step = exes.pop().unwrap();
        let init = exes.pop().unwrap();
        Ok(Bundle {
            init,
            train_variants,
            lattice,
            eval_step,
            eval_rows,
            probe,
            manifest,
            dir: dir.to_path_buf(),
            client: client.clone(),
            compile_secs,
        })
    }

    /// Load by config name from the repo's `artifacts/` dir.
    ///
    /// A missing artifact dir used to surface as an opaque
    /// "reading manifest … No such file" chain; it now names the two
    /// ways out (the Python compile step, or the artifact-free host
    /// backend) up front.
    pub fn by_name(client: &Client, name: &str) -> Result<Self> {
        let dir = crate::config::repo_root().join("artifacts").join(name);
        if !dir.join("manifest.json").exists() {
            return Err(anyhow!(
                "no compiled artifacts for config {name:?} (expected {dir:?}/manifest.json). \
                 Build them with the Python compile step (`make artifacts`), or run with \
                 --backend host to use the artifact-free pure-Rust backend."
            ));
        }
        Self::load(client, &dir)
    }

    /// Compilation timings for all executables (perf diagnostics).
    pub fn compile_times(client: &Client, dir: &Path) -> Result<BTreeMap<String, f64>> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let mut out = BTreeMap::new();
        for (key, fname) in &manifest.executables {
            let t = std::time::Instant::now();
            client.compile_file(&dir.join(fname))?;
            out.insert(key.clone(), t.elapsed().as_secs_f64());
        }
        Ok(out)
    }
}

/// Per-config compiled-bundle cache over one shared client: each config
/// compiles at most once per process and the resulting [`Bundle`] is
/// shared (`Rc`) by every job that trains or evaluates it.
///
/// Not thread-safe by itself — like everything client-owned, the bundles
/// hold handles with non-atomic refcounts. The experiment scheduler wraps
/// the cache in its exclusive device-token mutex, which doubles as the
/// **compile lock**: backend compilation stays single-threaded behind the
/// cache while other workers run host-side stages.
pub struct BundleCache {
    client: Client,
    map: RefCell<HashMap<String, Rc<Bundle>>>,
}

impl BundleCache {
    /// Empty cache over `client`.
    pub fn new(client: &Client) -> Self {
        BundleCache { client: client.clone(), map: RefCell::new(HashMap::new()) }
    }

    /// The compiled bundle for `name`, compiling on first use.
    pub fn get(&self, name: &str) -> Result<Rc<Bundle>> {
        if let Some(b) = self.map.borrow().get(name) {
            return Ok(b.clone());
        }
        let bundle = Rc::new(Bundle::by_name(&self.client, name)?);
        self.map.borrow_mut().insert(name.to_string(), bundle.clone());
        Ok(bundle)
    }

    /// Number of configs compiled so far.
    pub fn len(&self) -> usize {
        self.map.borrow().len()
    }

    /// True before the first compile.
    pub fn is_empty(&self) -> bool {
        self.map.borrow().is_empty()
    }

    /// The shared client the cache compiles on.
    pub fn client(&self) -> &Client {
        &self.client
    }
}

/// The XLA execution backend: a compiled [`Bundle`] *is* a
/// [`Backend`] — state handles wrap device-resident `PjRtBuffer`s,
/// uploads copy host batches onto the client, and every program runs the
/// matching AOT executable.
impl Backend for Bundle {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn name(&self) -> &'static str {
        "xla"
    }

    fn compile_secs(&self) -> f64 {
        self.compile_secs
    }

    fn init_state(&self, seed: i32) -> Result<BackendState> {
        let seed_buf = self
            .client
            .0
            .buffer_from_host_buffer::<i32>(&[seed], &[1], None)
            .map_err(xerr)?;
        let mut out = self.init.execute_b(&[&seed_buf]).map_err(xerr)?;
        Ok(BackendState::new(out.remove(0).remove(0)))
    }

    fn upload_batch(&self, batch: &Batch) -> Result<UploadedBatch> {
        let m = &self.manifest;
        let b = m.batch_size;
        let t = m.seq_len;
        let client = &self.client.0;
        let mut bufs = vec![
            client
                .buffer_from_host_buffer::<i32>(&batch.tokens, &[b, t], None)
                .map_err(xerr)?,
            client
                .buffer_from_host_buffer::<i32>(&batch.targets, &[b, t], None)
                .map_err(xerr)?,
        ];
        if m.is_vlm() {
            bufs.push(
                client
                    .buffer_from_host_buffer::<f32>(
                        &batch.patches,
                        &[b, m.n_patches, m.patch_dim],
                        None,
                    )
                    .map_err(xerr)?,
            );
        }
        Ok(UploadedBatch::new(bufs, batch.nbytes()))
    }

    fn upload_ctrl(&self, ctrl: &[f32]) -> Result<CtrlBuf> {
        let buf = self
            .client
            .0
            .buffer_from_host_buffer::<f32>(ctrl, &[ctrl.len()], None)
            .map_err(xerr)?;
        Ok(CtrlBuf::new(ctrl.to_vec(), buf))
    }

    fn lower_plan(&self, plan: &StepPlan) -> StepPlan {
        // nearest sound variant: largest omitted set ⊆ the plan's
        let v = self.lattice.lower(plan);
        StepPlan::omitting(plan.n(), &v.omit)
    }

    fn train_step(
        &self,
        state: &BackendState,
        io: &UploadedBatch,
        ctrl: &CtrlBuf,
        plan: &StepPlan,
    ) -> Result<BackendState> {
        let state = state.downcast::<PjRtBuffer>()?;
        let bufs = io.downcast::<Vec<PjRtBuffer>>()?;
        let ctrl_buf = ctrl.downcast::<PjRtBuffer>()?;
        // `plan` must be one of this bundle's variants — Session passes
        // `lower_plan` output through, so a miss means a caller skipped
        // lowering (or mixed engines) and would silently get the wrong
        // graph; refuse instead.
        let idx = self.lattice.exact_index(plan).ok_or_else(|| {
            anyhow!(
                "no compiled train-step variant omits exactly {:?}; lower the plan \
                 with Backend::lower_plan (Session does this) before executing",
                plan.omitted()
            )
        })?;
        let exe = &self.train_variants[idx];
        let mut args: Vec<&PjRtBuffer> = vec![state];
        args.extend(bufs.iter());
        args.push(ctrl_buf);
        let mut out = exe.execute_b(&args).map_err(xerr)?;
        Ok(BackendState::new(out.remove(0).remove(0)))
    }

    fn probe(&self, state: &BackendState) -> Result<Vec<f32>> {
        let state = state.downcast::<PjRtBuffer>()?;
        let out = self.probe.execute_b(&[state]).map_err(xerr)?;
        out[0][0].to_literal_sync().map_err(xerr)?.to_vec::<f32>().map_err(xerr)
    }

    fn eval_step(&self, state: &BackendState, io: &UploadedBatch) -> Result<(f64, f64)> {
        let state = state.downcast::<PjRtBuffer>()?;
        let bufs = io.downcast::<Vec<PjRtBuffer>>()?;
        let mut args: Vec<&PjRtBuffer> = vec![state];
        args.extend(bufs.iter());
        let out = self.eval_step.execute_b(&args).map_err(xerr)?;
        let v = out[0][0].to_literal_sync().map_err(xerr)?.to_vec::<f32>().map_err(xerr)?;
        Ok((v[0] as f64, v[1] as f64))
    }

    fn eval_rows(&self, state: &BackendState, io: &UploadedBatch) -> Result<Vec<(f64, f64)>> {
        let state = state.downcast::<PjRtBuffer>()?;
        let bufs = io.downcast::<Vec<PjRtBuffer>>()?;
        let mut args: Vec<&PjRtBuffer> = vec![state];
        args.extend(bufs.iter());
        let out = self.eval_rows.execute_b(&args).map_err(xerr)?;
        let v = out[0][0].to_literal_sync().map_err(xerr)?.to_vec::<f32>().map_err(xerr)?;
        let b = v.len() / 2;
        Ok((0..b).map(|i| (v[i] as f64, v[b + i] as f64)).collect())
    }

    fn state_to_host(&self, state: &BackendState) -> Result<Vec<f32>> {
        let state = state.downcast::<PjRtBuffer>()?;
        state.to_literal_sync().map_err(xerr)?.to_vec::<f32>().map_err(xerr)
    }

    fn state_from_host(&self, host: &[f32]) -> Result<BackendState> {
        Ok(BackendState::new(
            self.client
                .0
                .buffer_from_host_buffer::<f32>(host, &[host.len()], None)
                .map_err(xerr)?,
        ))
    }
}

/// Pipelined load: every path's read+parse runs on its own scoped worker
/// while the loader thread compiles the protos in input order as they
/// become ready — parse of executable k+1…n overlaps compile of k. Only
/// exclusively-owned protos cross threads (see `SendCell`); the client
/// stays on this thread.
fn compile_parallel(client: &Client, paths: &[PathBuf]) -> Result<Vec<PjRtLoadedExecutable>> {
    std::thread::scope(|scope| {
        let handles: Vec<_> =
            paths.iter().map(|path| scope.spawn(move || SendCell(load_proto(path)))).collect();
        handles
            .into_iter()
            .zip(paths)
            .map(|(h, path)| {
                let proto = h.join().map_err(|_| anyhow!("HLO parse worker panicked"))?.0?;
                client.compile_proto(&proto, path)
            })
            .collect::<Result<Vec<_>>>()
    })
}
