//! The pure-Rust reference backend: a small decoder-only transformer
//! (the paper's 7-matrix layer anatomy) with full forward + backward,
//! freeze-masked AdamW/SGD updates, the ctrl-vector protocol and the
//! per-matrix gradient-statistics metrics prefix — mirroring
//! `python/compile/model.py` / `steps.py` / `layout.py` for the tiny
//! full-parameter LM configs.
//!
//! Purpose: make the GradES freeze/stop logic executable *everywhere*.
//! With this backend, `cargo test -q` runs complete training
//! trajectories — freeze decisions, variant swaps, classic-ES checks —
//! with no Python toolchain and no compiled artifacts, and the XLA path
//! becomes something tier-1 differentially verifies
//! (`rust/tests/differential.rs`) instead of trusts.
//!
//! # What matches the compiled graphs
//!
//! * The **state layout** (`layout.py`): `[metrics | params | opt slots |
//!   prev grads]`, bit-for-bit the same offsets — `state_from_host` of an
//!   XLA-produced state is a valid host state and vice versa.
//! * The **step semantics** (`steps.py` + `kernels/ref.py`): loss =
//!   `Σ CE / max(count, 1)`, Eq. 1 per-component `‖∇Wₜ − ∇Wₜ₋₁‖₁` /
//!   `‖∇Wₜ‖₁` statistics, freeze-masked updates that keep frozen p/m/v
//!   bit-identical, and the prev-grad carry.
//! * The **ctrl protocol**: `[step, lr, wd_scale, pad, mask…]`.
//!
//! # Freeze-aware execution
//!
//! Where the XLA engine lowers a
//! [`StepPlan`](crate::coordinator::scheduler::StepPlan) to the nearest
//! pre-compiled graph variant, this engine honors the plan **exactly**:
//! every omitted component skips its dW matmul, its Eq. 1 gdiff/gabs
//! contribution (the stats report 0, like the compiled attn-frozen
//! graph does for attention), its prev-grad carry and its optimizer
//! slot update — bitwise-equivalent to the masked full graph on the
//! params/opt/prev regions, cheaper by the omitted matmuls. Plans that
//! additionally carry the **truncation grant**
//! (`StepPlan::with_truncation`, opt-in via
//! `TrainerOptions::truncate_frozen_prefix`) stop the backward sweep
//! below a fully-omitted layer *prefix* (AutoFreeze-style whole-layer
//! rule): the truncated layers' norm scales and the embeddings receive
//! no gradient and are held bit-identical for the step — a documented
//! trajectory-changing choice, which is why it is never granted by
//! default. An all-active plan reproduces the dense path bitwise.
//!
//! All matmuls, the Eq. 1 L1 reductions and the hot dot products run on
//! the SIMD microkernel layer in
//! [`host_kernels`](super::host_kernels): one cache-blocked, 8-lane
//! f64-accumulating row·row kernel, runtime-dispatched over
//! scalar/SSE2/AVX2 (`GRADES_HOST_SIMD`) and fanned out over
//! `GRADES_HOST_THREADS` scoped workers. The lane-split reduction order
//! is fixed, so results are **bitwise identical for every SIMD level
//! and every thread count** (asserted here and in
//! `rust/tests/properties.rs`). The freeze-masked optimizer update and
//! gdiff/gabs statistics thread over the same pool, partitioned at
//! whole-tensor granularity.
//!
//! # Where it may diverge numerically
//!
//! Reductions here accumulate in f64 lanes and round to f32 once, while
//! XLA uses f32 tree reductions in an unspecified order; elementwise
//! math is f32 on both sides. Expected per-step loss agreement is ~1e-4
//! relative on the tiny configs — the differential harness asserts
//! losses within tolerance and freeze steps *identical*. Init draws
//! come from the repo's own deterministic RNG, not JAX's threefry, so
//! cross-backend comparisons start from an XLA-initialized state
//! shipped through `state_to_host`/`state_from_host`.
//!
//! LoRA and VLM configs are not implemented here (the XLA path covers
//! them); `HostBackend::for_config` reports that explicitly.

use anyhow::{ensure, Result};

use super::backend::{Backend, BackendState, CtrlBuf, UploadedBatch};
use super::host_kernels::{self as kernels, matmul, matmul_nt, matmul_tn};
use super::manifest::{Component, FlopsInfo, Manifest, ParamInfo};
use super::session::Batch;
use crate::config::{ModelConfig, RepoConfig, TrainConfig};
use crate::coordinator::scheduler::StepPlan;
use crate::util::rng::Rng;

/// `[loss_sum, token_count, global_gnorm, reserved]` (layout.py METRIC_PAD).
const METRIC_PAD: usize = 4;
/// `[step, lr, wd_scale, reserved]` (layout.py CTRL_PAD).
const CTRL_PAD: usize = 4;

/// Init family per tensor (layout.py `ParamSpec.init`; the LoRA kinds
/// never occur in the host backend's fp-only layouts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Init {
    /// 0.02 · N(0,1) — embeddings.
    Embed,
    /// N(0,1) / √fan_in — projection matrices.
    Matrix,
    /// All ones — RMSNorm scales.
    Ones,
    /// 0.02 · N(0,1) — the untied LM head.
    Head,
}

/// One flat-state tensor: its slice of the state plus optimizer/prev
/// bookkeeping offsets.
struct HostSpec {
    name: String,
    shape: Vec<usize>,
    size: usize,
    /// Offset of the parameter values in the flat state.
    offset: usize,
    component: Option<usize>,
    init: Init,
    /// AdamW: `[m, v]` offsets; SGD: `[mom]`.
    opt_offsets: Vec<usize>,
    /// Prev-grad slot (monitored tensors only — the Eq. 1 carry).
    prev_offset: Option<usize>,
}

/// Spec indices of one transformer layer's nine tensors.
struct LayerIdx {
    ln1: usize,
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    ln2: usize,
    wg: usize,
    wu: usize,
    wd: usize,
}

/// Model dimensions, denormalized from the config for hot-loop use.
#[derive(Clone, Copy)]
struct Dims {
    /// Batch size B.
    b: usize,
    /// Sequence length T.
    t: usize,
    /// Residual width D.
    d: usize,
    /// Head count H.
    h: usize,
    /// Head dim D/H.
    hd: usize,
    /// SwiGLU hidden width F.
    f: usize,
    /// Layer count L.
    l: usize,
    /// Vocab size V.
    v: usize,
    /// Positional-table length (max_seq).
    s: usize,
}

/// Optimizer family + constants (f32, matching the compiled kernels).
enum Opt {
    /// AdamW with bias correction driven by `ctrl[0]`.
    AdamW { b1: f32, b2: f32, eps: f32 },
    /// SGD with momentum (step-insensitive: never reads `ctrl[0]`).
    Sgd { momentum: f32 },
}

/// The pure-Rust engine for one fp LM config. Stateless across calls:
/// every program is a function from (state, inputs) to outputs, exactly
/// like the compiled executables.
pub struct HostBackend {
    manifest: Manifest,
    specs: Vec<HostSpec>,
    dims: Dims,
    opt: Opt,
    weight_decay: f32,
    tok_emb: usize,
    pos_emb: usize,
    ln_f: usize,
    lm_head: usize,
    layers: Vec<LayerIdx>,
}

impl HostBackend {
    /// Build the engine for a `configs/*.toml` config. Only `kind = "lm"`
    /// + `method = "fp"` layouts exist in pure Rust; LoRA/VLM configs get
    /// a pointer at the XLA path.
    pub fn for_config(cfg: &RepoConfig) -> Result<Self> {
        Self::from_parts(&cfg.name, &cfg.model, &cfg.train)
    }

    /// Build from raw `[model]`/`[train]` tables (tests and benches use
    /// this to make micro-sized engines without a config file).
    pub fn from_parts(name: &str, model: &ModelConfig, train: &TrainConfig) -> Result<Self> {
        ensure!(
            model.kind == "lm",
            "host backend supports kind=\"lm\" only; config {name:?} is {:?} — build \
             artifacts (`make artifacts`) and use --backend xla",
            model.kind
        );
        ensure!(
            train.method == "fp",
            "host backend supports method=\"fp\" only; config {name:?} is {:?} — build \
             artifacts (`make artifacts`) and use --backend xla",
            train.method
        );
        ensure!(
            model.d_model > 0 && model.n_layers > 0 && model.d_ff > 0 && model.vocab_size > 0,
            "config {name:?} has no usable [model] table (d_model/n_layers/d_ff/vocab_size)"
        );
        ensure!(model.n_heads > 0 && model.d_model % model.n_heads == 0, "d_model % n_heads != 0");
        ensure!(train.batch_size > 0 && train.seq_len > 0, "[train] batch_size/seq_len missing");
        ensure!(train.seq_len <= model.max_seq, "seq_len exceeds max_seq");
        ensure!(
            train.optimizer == "adamw" || train.optimizer == "sgd",
            "unknown optimizer {:?}",
            train.optimizer
        );

        let (d, ff) = (model.d_model, model.d_ff);
        // --- specs + components in layout.py order ---
        let mut specs: Vec<(String, Vec<usize>, Init, Option<usize>)> = Vec::new();
        let mut components = Vec::new();
        specs.push(("tok_emb".into(), vec![model.vocab_size, d], Init::Embed, None));
        specs.push(("pos_emb".into(), vec![model.max_seq, d], Init::Embed, None));
        for layer in 0..model.n_layers {
            specs.push((format!("lang.{layer}.ln1"), vec![d], Init::Ones, None));
            for kind in ["q", "k", "v", "o"] {
                let cidx = components.len();
                let name = format!("lang.{layer}.attn.{kind}");
                components.push(Component {
                    idx: cidx,
                    name: format!("language.{layer}.{kind}"),
                    layer,
                    kind: kind.to_string(),
                    group: "attention".into(),
                    tower: "language".into(),
                    n_params: d * d,
                    tensors: vec![name.clone()],
                });
                specs.push((name, vec![d, d], Init::Matrix, Some(cidx)));
            }
            specs.push((format!("lang.{layer}.ln2"), vec![d], Init::Ones, None));
            for kind in ["gate", "up", "down"] {
                let cidx = components.len();
                let name = format!("lang.{layer}.mlp.{kind}");
                let shape = if kind == "down" { vec![ff, d] } else { vec![d, ff] };
                components.push(Component {
                    idx: cidx,
                    name: format!("language.{layer}.{kind}"),
                    layer,
                    kind: kind.to_string(),
                    group: "mlp".into(),
                    tower: "language".into(),
                    n_params: d * ff,
                    tensors: vec![name.clone()],
                });
                specs.push((name, shape, Init::Matrix, Some(cidx)));
            }
        }
        specs.push(("ln_f".into(), vec![d], Init::Ones, None));
        specs.push(("lm_head".into(), vec![d, model.vocab_size], Init::Head, None));

        // --- offsets: [metrics | params | opt slot(s) | prev grads] ---
        let n_c = components.len();
        let metrics_len = METRIC_PAD + 2 * n_c;
        let ctrl_len = CTRL_PAD + n_c;
        let mut off = metrics_len;
        let mut host_specs: Vec<HostSpec> = specs
            .iter()
            .map(|(name, shape, init, comp)| {
                let size: usize = shape.iter().product();
                let s = HostSpec {
                    name: name.clone(),
                    shape: shape.clone(),
                    size,
                    offset: off,
                    component: *comp,
                    init: *init,
                    opt_offsets: Vec::new(),
                    prev_offset: None,
                };
                off += size;
                s
            })
            .collect();
        let n_opt_slots = if train.optimizer == "adamw" { 2 } else { 1 };
        for _slot in 0..n_opt_slots {
            for s in host_specs.iter_mut() {
                s.opt_offsets.push(off);
                off += s.size;
            }
        }
        for s in host_specs.iter_mut() {
            if s.component.is_some() {
                s.prev_offset = Some(off);
                off += s.size;
            }
        }
        let state_len = off;

        // --- analytic FLOPs (flops_summary port) ---
        let mut per_component_fwd = std::collections::BTreeMap::new();
        for c in &components {
            per_component_fwd.insert(c.name.clone(), 2.0 * c.n_params as f64);
        }
        let comp_total: f64 = per_component_fwd.values().sum();
        let attn_quad = 4.0 * (train.seq_len * d * model.n_layers) as f64;
        let head = 2.0 * (d * model.vocab_size) as f64;
        let fwd_per_token = comp_total + attn_quad + head;

        let params: Vec<ParamInfo> = host_specs
            .iter()
            .map(|s| ParamInfo {
                name: s.name.clone(),
                shape: s.shape.clone(),
                offset: s.offset,
                trainable: true,
                component: s.component,
            })
            .collect();
        let n_params_total: usize = host_specs.iter().map(|s| s.size).sum();
        let manifest = Manifest {
            name: name.to_string(),
            kind: "lm".into(),
            method: "fp".into(),
            optimizer: train.optimizer.clone(),
            kernel_impl: "host".into(),
            batch_size: train.batch_size,
            seq_len: train.seq_len,
            vocab_size: model.vocab_size,
            n_patches: 0,
            patch_dim: 0,
            state_len,
            metrics_len,
            ctrl_len,
            n_components: n_c,
            gdiff_offset: METRIC_PAD,
            gabs_offset: METRIC_PAD + n_c,
            ctrl_mask_offset: CTRL_PAD,
            components,
            params,
            n_params_total,
            n_params_trainable: n_params_total,
            flops: FlopsInfo {
                fwd_per_token,
                bwd_dx_per_token: fwd_per_token,
                per_component_fwd,
                attn_quadratic_per_token: attn_quad,
                head_per_token: head,
            },
            executables: std::collections::BTreeMap::new(),
            variants: std::collections::BTreeMap::new(),
        };

        // spec-index lookups for the hot loops (resolved before the
        // struct literal so the borrow of `host_specs` ends first)
        let idx_of = |n: &str| host_specs.iter().position(|s| s.name == n).expect("spec");
        let layers: Vec<LayerIdx> = (0..model.n_layers)
            .map(|l| LayerIdx {
                ln1: idx_of(&format!("lang.{l}.ln1")),
                wq: idx_of(&format!("lang.{l}.attn.q")),
                wk: idx_of(&format!("lang.{l}.attn.k")),
                wv: idx_of(&format!("lang.{l}.attn.v")),
                wo: idx_of(&format!("lang.{l}.attn.o")),
                ln2: idx_of(&format!("lang.{l}.ln2")),
                wg: idx_of(&format!("lang.{l}.mlp.gate")),
                wu: idx_of(&format!("lang.{l}.mlp.up")),
                wd: idx_of(&format!("lang.{l}.mlp.down")),
            })
            .collect();
        let tok_emb = idx_of("tok_emb");
        let pos_emb = idx_of("pos_emb");
        let ln_f = idx_of("ln_f");
        let lm_head = idx_of("lm_head");
        drop(idx_of);
        let opt = if train.optimizer == "adamw" {
            Opt::AdamW {
                b1: train.beta1 as f32,
                b2: train.beta2 as f32,
                eps: train.eps as f32,
            }
        } else {
            Opt::Sgd { momentum: train.momentum as f32 }
        };
        Ok(HostBackend {
            tok_emb,
            pos_emb,
            ln_f,
            lm_head,
            layers,
            dims: Dims {
                b: train.batch_size,
                t: train.seq_len,
                d,
                h: model.n_heads,
                hd: d / model.n_heads,
                f: ff,
                l: model.n_layers,
                v: model.vocab_size,
                s: model.max_seq,
            },
            opt,
            weight_decay: train.weight_decay as f32,
            specs: host_specs,
            manifest,
        })
    }

    /// Hand the synthesized manifest out by value (the scheduler's host
    /// phase builds datasets from it without keeping the engine).
    pub fn into_manifest(self) -> Manifest {
        self.manifest
    }

    fn param<'s>(&self, state: &'s [f32], idx: usize) -> &'s [f32] {
        let s = &self.specs[idx];
        &state[s.offset..s.offset + s.size]
    }

    // -- forward ----------------------------------------------------------

    fn forward(&self, state: &[f32], tokens: &[i32]) -> Fwd {
        let Dims { b, t, d, h, hd, f, l, v, .. } = self.dims;
        let m = b * t;
        // embeddings
        let tok = self.param(state, self.tok_emb);
        let pos = self.param(state, self.pos_emb);
        let mut x = vec![0f32; m * d];
        for bi in 0..b {
            for ti in 0..t {
                let row = bi * t + ti;
                let id = tokens[row] as usize;
                for di in 0..d {
                    x[row * d + di] = tok[id * d + di] + pos[ti * d + di];
                }
            }
        }
        let mut xs = Vec::with_capacity(l + 1);
        let mut layers = Vec::with_capacity(l);
        for li in 0..l {
            let lr = &self.layers[li];
            let (h1, r1) = rms_norm(&x, self.param(state, lr.ln1), m, d);
            let q = matmul(&h1, self.param(state, lr.wq), m, d, d);
            let k = matmul(&h1, self.param(state, lr.wk), m, d, d);
            let vv = matmul(&h1, self.param(state, lr.wv), m, d, d);
            let (probs, ctx) = attention_fwd(&q, &k, &vv, b, t, h, hd);
            let attn_out = matmul(&ctx, self.param(state, lr.wo), m, d, d);
            let mut x_mid = x.clone();
            for i in 0..m * d {
                x_mid[i] += attn_out[i];
            }
            let (h2, r2) = rms_norm(&x_mid, self.param(state, lr.ln2), m, d);
            let gate_pre = matmul(&h2, self.param(state, lr.wg), m, d, f);
            let up = matmul(&h2, self.param(state, lr.wu), m, d, f);
            let mut act = vec![0f32; m * f];
            for i in 0..m * f {
                act[i] = silu(gate_pre[i]) * up[i];
            }
            let mlp_out = matmul(&act, self.param(state, lr.wd), m, f, d);
            let mut x_out = x_mid.clone();
            for i in 0..m * d {
                x_out[i] += mlp_out[i];
            }
            xs.push(std::mem::replace(&mut x, x_out));
            layers.push(LayerFwd { h1, r1, q, k, v: vv, probs, ctx, x_mid, h2, r2, gate_pre, up, act });
        }
        let (hf, rf) = rms_norm(&x, self.param(state, self.ln_f), m, d);
        let logits = matmul(&hf, self.param(state, self.lm_head), m, d, v);
        xs.push(x);
        Fwd { xs, layers, hf, rf, logits }
    }

    /// `(loss_sum, count)` over one batch, the `eval_step` reduction.
    fn loss_of(&self, logits: &[f32], targets: &[i32]) -> (f32, f32) {
        let v = self.dims.v;
        let mut loss = 0f64;
        let mut count = 0usize;
        for (row, &tgt) in targets.iter().enumerate() {
            if tgt < 0 {
                continue;
            }
            let lrow = &logits[row * v..(row + 1) * v];
            loss += nll(lrow, tgt as usize);
            count += 1;
        }
        (loss as f32, count as f32)
    }

    // -- backward ---------------------------------------------------------

    /// d(mean loss)/d(logits), plus the loss reduction itself.
    ///
    /// The `log_sum_exp` and probability passes are fused: one exp
    /// traversal per row feeds both the loss (`max + ln Σe`) and the
    /// softmax (`e / Σe`) — half the `exp` calls of the two-pass form.
    /// The loss value is bit-identical to `nll`'s (same max, same
    /// ascending summation), which `eval_step_matches_probe_loss…`
    /// pins.
    fn loss_grad(&self, logits: &[f32], targets: &[i32]) -> (f32, f32, Vec<f32>) {
        let v = self.dims.v;
        let m = targets.len();
        let count = targets.iter().filter(|&&t| t >= 0).count() as f32;
        let denom = count.max(1.0) as f64;
        let mut dlogits = vec![0f32; m * v];
        let mut loss = 0f64;
        let mut exps = vec![0f64; v];
        for (row, &tgt) in targets.iter().enumerate() {
            if tgt < 0 {
                continue;
            }
            let lrow = &logits[row * v..(row + 1) * v];
            let max = lrow.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
            let mut sum = 0f64;
            for (e, &lv) in exps.iter_mut().zip(lrow.iter()) {
                *e = (lv as f64 - max).exp();
                sum += *e;
            }
            loss += max + sum.ln() - lrow[tgt as usize] as f64;
            let inv = 1.0 / sum;
            let drow = &mut dlogits[row * v..(row + 1) * v];
            for (vi, (&e, dv)) in exps.iter().zip(drow.iter_mut()).enumerate() {
                let ind = if vi == tgt as usize { 1.0 } else { 0.0 };
                *dv = ((e * inv - ind) / denom) as f32;
            }
        }
        (loss as f32, count, dlogits)
    }

    /// Partition the spec list into up to `threads` contiguous runs of
    /// roughly equal parameter count (greedy fill to `⌈total/threads⌉`).
    /// Whole-spec granularity keeps every per-element loop identical to
    /// the serial order, so the partition never changes bits.
    fn spec_chunks(&self, threads: usize) -> Vec<std::ops::Range<usize>> {
        let total: usize = self.specs.iter().map(|sp| sp.size).sum();
        let target = total.div_ceil(threads.max(1)).max(1);
        let mut out = Vec::new();
        let mut begin = 0usize;
        let mut acc = 0usize;
        for (i, spec) in self.specs.iter().enumerate() {
            acc += spec.size;
            if acc >= target {
                out.push(begin..i + 1);
                begin = i + 1;
                acc = 0;
            }
        }
        if begin < self.specs.len() {
            out.push(begin..self.specs.len());
        }
        out
    }

    /// The masked optimizer update + Eq. 1 statistics for every spec with
    /// a gradient, fanned out over up to `threads` scoped workers. `ns`
    /// starts as a copy of `s`; each worker owns one contiguous run of
    /// specs and writes its disjoint windows of every state region.
    /// Returns `(gnorm, gdiff, gabs)` folded in spec order on the calling
    /// thread — bitwise identical for every thread count.
    #[allow(clippy::too_many_arguments)]
    fn apply_updates(
        &self,
        threads: usize,
        ns: &mut [f32],
        s: &[f32],
        grads: &[Option<Vec<f32>>],
        mask: &[f32],
        t_step: f32,
        lr: f32,
        wd: f32,
    ) -> (f64, Vec<f32>, Vec<f32>) {
        let n_c = self.manifest.n_components;
        let chunks = self.spec_chunks(threads);
        let nch = chunks.len();
        let n_slots = self.specs[0].opt_offsets.len();

        // Window geometry per chunk. Each state region ([params | opt
        // slot(s) | prev]) is laid out in spec order, so a contiguous
        // spec run owns one contiguous window per region, and the slot
        // windows mirror the param window's local coordinates exactly.
        let geom: Vec<(usize, usize, usize, usize)> = chunks
            .iter()
            .map(|r| {
                let first = &self.specs[r.start];
                let last = &self.specs[r.end - 1];
                let p0 = first.offset;
                let plen = last.offset + last.size - p0;
                let mut prev0 = 0usize;
                let mut prevlen = 0usize;
                for sp in &self.specs[r.start..r.end] {
                    if let Some(po) = sp.prev_offset {
                        if prevlen == 0 {
                            prev0 = po;
                        }
                        prevlen = po + sp.size - prev0;
                    }
                }
                (p0, plen, prev0, prevlen)
            })
            .collect();
        let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(nch * (2 + n_slots));
        for &(p0, plen, _, _) in &geom {
            ranges.push((p0, plen));
        }
        for slot in 0..n_slots {
            for (r, &(p0, plen, _, _)) in chunks.iter().zip(geom.iter()) {
                let off0 = self.specs[r.start].opt_offsets[slot];
                debug_assert_eq!(off0 - self.specs[r.start].offset, off0 - p0);
                ranges.push((off0, plen));
            }
        }
        for &(_, _, prev0, prevlen) in &geom {
            ranges.push((prev0, prevlen));
        }

        // Carve `ns` into those disjoint windows (ascending order: the
        // regions themselves are ordered, and chunks are ordered within
        // each region), then regroup them per chunk.
        let mut wins = carve(ns, &ranges);
        let prev_w = wins.split_off(wins.len() - nch);
        let v_w: Vec<Option<&mut [f32]>> = if n_slots == 2 {
            wins.split_off(wins.len() - nch).into_iter().map(Some).collect()
        } else {
            (0..nch).map(|_| None).collect()
        };
        let m_w = wins.split_off(wins.len() - nch);
        let params_w = wins;

        let mut outs: Vec<ChunkOut<'_>> = Vec::with_capacity(nch);
        for (i, (((pw, mw), vw), prw)) in params_w
            .into_iter()
            .zip(m_w)
            .zip(v_w)
            .zip(prev_w)
            .enumerate()
        {
            outs.push(ChunkOut {
                specs: chunks[i].clone(),
                p0: geom[i].0,
                prev0: geom[i].2,
                params: pw,
                m: mw,
                v: vw,
                prev: prw,
            });
        }

        let stats: Vec<Vec<(usize, SpecStats)>> = if outs.len() <= 1 {
            outs.into_iter()
                .map(|mut o| self.update_chunk(&mut o, s, grads, mask, t_step, lr, wd))
                .collect()
        } else {
            std::thread::scope(|scope| {
                let handles: Vec<_> = outs
                    .into_iter()
                    .map(|mut o| {
                        scope.spawn(move || {
                            self.update_chunk(&mut o, s, grads, mask, t_step, lr, wd)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            })
        };

        // Fold in spec order on one thread: the reduction order (and so
        // every metric bit) is independent of the partition.
        let mut gnorm = 0f64;
        let mut gdiff = vec![0f32; n_c];
        let mut gabs = vec![0f32; n_c];
        for (idx, st) in stats.into_iter().flatten() {
            let spec = &self.specs[idx];
            gnorm += st.gnorm;
            if let (Some(_), Some(ci)) = (spec.prev_offset, spec.component) {
                gdiff[ci] += st.dsum as f32;
                gabs[ci] += st.gnorm as f32;
            }
        }
        (gnorm, gdiff, gabs)
    }

    /// One worker's share of [`Self::apply_updates`]: the same
    /// per-element f32 arithmetic as the old serial loop, writing through
    /// the chunk's windows, with the Σ|g| and Σ|g−prev| reductions on the
    /// lane-split kernels.
    #[allow(clippy::too_many_arguments)]
    fn update_chunk(
        &self,
        out: &mut ChunkOut<'_>,
        s: &[f32],
        grads: &[Option<Vec<f32>>],
        mask: &[f32],
        t_step: f32,
        lr: f32,
        wd: f32,
    ) -> Vec<(usize, SpecStats)> {
        let mut stats = Vec::new();
        for idx in out.specs.clone() {
            let spec = &self.specs[idx];
            let Some(g) = &grads[idx] else { continue };
            let mval = spec.component.map_or(1.0, |ci| mask[ci]);
            let lo = spec.offset - out.p0;
            let mut st = SpecStats { gnorm: kernels::abs_sum8(g), dsum: 0.0 };
            // Eq. 1 statistics + prev-grad carry (frozen components keep
            // their stale prev, exactly like the compiled graph)
            if let Some(poff) = spec.prev_offset {
                let prev = &s[poff..poff + spec.size];
                st.dsum = kernels::abs_diff_sum8(g, prev);
                let plo = poff - out.prev0;
                let nprev = &mut out.prev[plo..plo + spec.size];
                for (i, (&gi, &pi)) in g.iter().zip(prev.iter()).enumerate() {
                    nprev[i] = mval * gi + (1.0 - mval) * pi;
                }
            }
            // freeze-masked optimizer update (kernels/ref.py semantics:
            // frozen tensors keep p/m/v bit-identical)
            match &self.opt {
                Opt::AdamW { b1, b2, eps } => {
                    let bc1 = 1.0 - b1.powf(t_step);
                    let bc2 = 1.0 - b2.powf(t_step);
                    let moff = spec.opt_offsets[0];
                    let voff = spec.opt_offsets[1];
                    let vwin = out.v.as_deref_mut().expect("AdamW layout carries slot 1");
                    for i in 0..spec.size {
                        let p = s[spec.offset + i];
                        let gi = g[i];
                        let m0 = s[moff + i];
                        let v0 = s[voff + i];
                        let mn = b1 * m0 + (1.0 - b1) * gi;
                        let vn = b2 * v0 + (1.0 - b2) * gi * gi;
                        let m_hat = mn / bc1;
                        let v_hat = vn / bc2;
                        let pn = p - lr * (m_hat / (v_hat.sqrt() + eps) + wd * p);
                        out.params[lo + i] = mval * pn + (1.0 - mval) * p;
                        out.m[lo + i] = mval * mn + (1.0 - mval) * m0;
                        vwin[lo + i] = mval * vn + (1.0 - mval) * v0;
                    }
                }
                Opt::Sgd { momentum } => {
                    let momoff = spec.opt_offsets[0];
                    for i in 0..spec.size {
                        let p = s[spec.offset + i];
                        let gi = g[i];
                        let mom0 = s[momoff + i];
                        let momn = momentum * mom0 + gi;
                        let pn = p - lr * (momn + wd * p);
                        out.params[lo + i] = mval * pn + (1.0 - mval) * p;
                        out.m[lo + i] = mval * momn + (1.0 - mval) * mom0;
                    }
                }
            }
            stats.push((idx, st));
        }
        stats
    }

    /// Full backward pass. Returns per-spec gradients of the *mean* loss.
    /// The plan's omitted components skip their dW matmul (their entry
    /// stays `None`; gradients still flow *through* the weights, as with
    /// `stop_gradient`). When the plan grants truncation, a fully
    /// omitted layer *prefix* additionally truncates the sweep: its norm
    /// scales and the embeddings get no gradient (the AutoFreeze-style
    /// whole-layer rule — see the module docs).
    fn backward(
        &self,
        state: &[f32],
        fwd: &Fwd,
        dlogits: Vec<f32>,
        tokens: &[i32],
        plan: &StepPlan,
    ) -> Vec<Option<Vec<f32>>> {
        let Dims { b, t, d, h, hd, f, l, v, s, .. } = self.dims;
        let m = b * t;
        let mut grads: Vec<Option<Vec<f32>>> = (0..self.specs.len()).map(|_| None).collect();
        let omits =
            |spec_idx: usize| self.specs[spec_idx].component.map_or(false, |c| plan.omits(c));
        // Sweep truncation (opt-in capability on the plan): layers
        // 0..trunc have all seven components omitted, so no *component*
        // below layer `trunc` needs a gradient and the sweep stops above
        // them — holding their norm scales and the embeddings for the
        // step, the documented rider semantics.
        let trunc = if plan.truncates() {
            self.layers
                .iter()
                .take_while(|lr| {
                    [lr.wq, lr.wk, lr.wv, lr.wo, lr.wg, lr.wu, lr.wd]
                        .iter()
                        .all(|&ix| omits(ix))
                })
                .count()
        } else {
            0
        };

        // head + final norm
        grads[self.lm_head] = Some(matmul_tn(&fwd.hf, &dlogits, m, d, v));
        let dhf = matmul_nt(&dlogits, self.param(state, self.lm_head), m, v, d);
        let (g_lnf, mut dx) =
            rms_backward(&fwd.xs[l], &fwd.rf, self.param(state, self.ln_f), &dhf, m, d);
        grads[self.ln_f] = Some(g_lnf);

        for li in (trunc..l).rev() {
            let lr = &self.layers[li];
            let lf = &fwd.layers[li];
            // SwiGLU MLP: x_out = x_mid + (silu(h2·Wg) ⊙ (h2·Wu))·Wd
            let d_mlp_out = &dx;
            if !omits(lr.wd) {
                grads[lr.wd] = Some(matmul_tn(&lf.act, d_mlp_out, m, f, d));
            }
            let d_act = matmul_nt(d_mlp_out, self.param(state, lr.wd), m, d, f);
            let mut d_gp = vec![0f32; m * f];
            let mut d_up = vec![0f32; m * f];
            for i in 0..m * f {
                let z = lf.gate_pre[i];
                let sg = sigmoid(z);
                d_up[i] = d_act[i] * z * sg; // silu(z) = z·σ(z)
                d_gp[i] = d_act[i] * lf.up[i] * sg * (1.0 + z * (1.0 - sg));
            }
            if !omits(lr.wg) {
                grads[lr.wg] = Some(matmul_tn(&lf.h2, &d_gp, m, d, f));
            }
            if !omits(lr.wu) {
                grads[lr.wu] = Some(matmul_tn(&lf.h2, &d_up, m, d, f));
            }
            let mut dh2 = matmul_nt(&d_gp, self.param(state, lr.wg), m, f, d);
            let dh2b = matmul_nt(&d_up, self.param(state, lr.wu), m, f, d);
            for i in 0..m * d {
                dh2[i] += dh2b[i];
            }
            let (g_ln2, dxm_norm) =
                rms_backward(&lf.x_mid, &lf.r2, self.param(state, lr.ln2), &dh2, m, d);
            grads[lr.ln2] = Some(g_ln2);
            let mut dx_mid = dx; // residual branch
            for i in 0..m * d {
                dx_mid[i] += dxm_norm[i];
            }

            // attention: x_mid = x_in + (softmax(qkᵀ/√hd)·v)·Wo
            let d_attn_out = &dx_mid;
            if !omits(lr.wo) {
                grads[lr.wo] = Some(matmul_tn(&lf.ctx, d_attn_out, m, d, d));
            }
            let dctx = matmul_nt(d_attn_out, self.param(state, lr.wo), m, d, d);
            let (dq, dk, dv) = attention_bwd(&lf.q, &lf.k, &lf.v, &lf.probs, &dctx, b, t, h, hd);
            if !omits(lr.wq) {
                grads[lr.wq] = Some(matmul_tn(&lf.h1, &dq, m, d, d));
            }
            if !omits(lr.wk) {
                grads[lr.wk] = Some(matmul_tn(&lf.h1, &dk, m, d, d));
            }
            if !omits(lr.wv) {
                grads[lr.wv] = Some(matmul_tn(&lf.h1, &dv, m, d, d));
            }
            let mut dh1 = matmul_nt(&dq, self.param(state, lr.wq), m, d, d);
            let dh1b = matmul_nt(&dk, self.param(state, lr.wk), m, d, d);
            let dh1c = matmul_nt(&dv, self.param(state, lr.wv), m, d, d);
            for i in 0..m * d {
                dh1[i] += dh1b[i] + dh1c[i];
            }
            let (g_ln1, dxin_norm) =
                rms_backward(&fwd.xs[li], &lf.r1, self.param(state, lr.ln1), &dh1, m, d);
            grads[lr.ln1] = Some(g_ln1);
            for i in 0..m * d {
                dx_mid[i] += dxin_norm[i];
            }
            dx = dx_mid;
        }

        // embeddings (rows past T in pos_emb get zero gradient; the
        // optimizer still visits them — weight decay applies, as on XLA).
        // A truncated sweep never reaches them: they ride along held.
        if trunc == 0 {
            let mut g_tok = vec![0f32; self.specs[self.tok_emb].size];
            let mut g_pos = vec![0f32; self.specs[self.pos_emb].size];
            debug_assert_eq!(g_pos.len(), s * d);
            for bi in 0..b {
                for ti in 0..t {
                    let row = bi * t + ti;
                    let id = tokens[row] as usize;
                    for di in 0..d {
                        g_tok[id * d + di] += dx[row * d + di];
                        g_pos[ti * d + di] += dx[row * d + di];
                    }
                }
            }
            grads[self.tok_emb] = Some(g_tok);
            grads[self.pos_emb] = Some(g_pos);
        }
        grads
    }
}

/// One layer's cached forward activations (what backward consumes).
struct LayerFwd {
    h1: Vec<f32>,
    r1: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    probs: Vec<f32>,
    ctx: Vec<f32>,
    x_mid: Vec<f32>,
    h2: Vec<f32>,
    r2: Vec<f32>,
    gate_pre: Vec<f32>,
    up: Vec<f32>,
    act: Vec<f32>,
}

/// Whole-network forward cache. `xs[l]` is layer `l`'s input; `xs[L]` the
/// final residual stream.
struct Fwd {
    xs: Vec<Vec<f32>>,
    layers: Vec<LayerFwd>,
    hf: Vec<f32>,
    rf: Vec<f32>,
    logits: Vec<f32>,
}

// ---------------------------------------------------------------------------
// Threaded optimizer/stats plumbing
// ---------------------------------------------------------------------------

/// Per-spec statistics produced by one update worker. `gnorm` doubles as
/// the component's Eq. 1 `gabs` contribution — the serial loop computed
/// both with the same Σ|g| reduction.
struct SpecStats {
    /// Σ|g| over the spec (lane-split order).
    gnorm: f64,
    /// Σ|g − prev| over the spec (monitored specs only; 0 otherwise).
    dsum: f64,
}

/// One update worker's write windows into the next state: a contiguous
/// run of specs plus a mutable window into each state region. Slot
/// offsets mirror param offsets region-relatively, so a single local
/// coordinate (`spec.offset - p0`) indexes `params`, `m` and `v` alike;
/// `prev` uses its own `poff - prev0` base.
struct ChunkOut<'a> {
    /// Spec indices this worker owns.
    specs: std::ops::Range<usize>,
    /// Absolute state offset of `params[0]`.
    p0: usize,
    /// Absolute state offset of `prev[0]` (meaningless when `prev` is empty).
    prev0: usize,
    params: &'a mut [f32],
    /// Optimizer slot 0: AdamW first moment / SGD momentum.
    m: &'a mut [f32],
    /// Optimizer slot 1: AdamW second moment (`None` under SGD).
    v: Option<&'a mut [f32]>,
    /// Eq. 1 prev-grad carry window (empty when no spec is monitored).
    prev: &'a mut [f32],
}

/// Split `buf` into the given `(start, len)` windows — absolute offsets,
/// ascending and disjoint among the non-empty ones. Zero-length entries
/// yield empty slices (and their `start` is ignored).
fn carve<'a>(buf: &'a mut [f32], ranges: &[(usize, usize)]) -> Vec<&'a mut [f32]> {
    let mut out: Vec<&'a mut [f32]> = Vec::with_capacity(ranges.len());
    let mut rest = buf;
    let mut pos = 0usize;
    for &(start, len) in ranges {
        if len == 0 {
            out.push(Default::default());
            continue;
        }
        let tail = std::mem::take(&mut rest);
        let (_, tail) = tail.split_at_mut(start - pos);
        let (win, tail) = tail.split_at_mut(len);
        out.push(win);
        rest = tail;
        pos = start + len;
    }
    out
}

// ---------------------------------------------------------------------------
// Math helpers (f32 storage, f64 accumulation)
// ---------------------------------------------------------------------------
// The matmuls, thread-pool plumbing and L1 reductions live in
// `host_kernels`; what stays here is the transformer-shaped glue.

fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

fn silu(z: f32) -> f32 {
    z * sigmoid(z)
}

fn log_sum_exp(row: &[f32]) -> f64 {
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let sum: f64 = row.iter().map(|&x| (x as f64 - max).exp()).sum();
    max + sum.ln()
}

fn nll(row: &[f32], target: usize) -> f64 {
    log_sum_exp(row) - row[target] as f64
}

/// Pre-RMSNorm: `y = x · rsqrt(mean(x²) + 1e-6) · scale`. Returns the
/// normalized rows and the per-row rsqrt (cached for backward).
fn rms_norm(x: &[f32], scale: &[f32], m: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0f32; m * d];
    let mut r = vec![0f32; m];
    for i in 0..m {
        let row = &x[i * d..(i + 1) * d];
        let ms: f64 = kernels::dot8(row, row) / d as f64;
        let ri = (1.0 / (ms + 1e-6).sqrt()) as f32;
        r[i] = ri;
        let yrow = &mut y[i * d..(i + 1) * d];
        for ((yo, &xv), &sv) in yrow.iter_mut().zip(row.iter()).zip(scale.iter()) {
            *yo = xv * ri * sv;
        }
    }
    (y, r)
}

/// RMSNorm backward: `(dscale, dx)` for upstream `dy`.
fn rms_backward(
    x: &[f32],
    r: &[f32],
    scale: &[f32],
    dy: &[f32],
    m: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut dscale = vec![0f64; d];
    let mut dx = vec![0f32; m * d];
    for i in 0..m {
        let xrow = &x[i * d..(i + 1) * d];
        let dyrow = &dy[i * d..(i + 1) * d];
        let ri = r[i] as f64;
        let dot = kernels::dot3_8(dyrow, scale, xrow); // Σ dy·scale·x
        for di in 0..d {
            dscale[di] += dyrow[di] as f64 * xrow[di] as f64 * ri;
        }
        let c = ri * ri * ri * dot / d as f64;
        let dxrow = &mut dx[i * d..(i + 1) * d];
        for di in 0..d {
            dxrow[di] = (ri * scale[di] as f64 * dyrow[di] as f64 - c * xrow[di] as f64) as f32;
        }
    }
    (dscale.into_iter().map(|v| v as f32).collect(), dx)
}

/// Causal multi-head attention forward over already-projected q/k/v
/// (`[B·T, D]`, heads interleaved). Returns `(probs [B,H,T,T], ctx
/// [B·T, D])`; masked scores are exactly the python graph's `-1e9`.
fn attention_fwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    t: usize,
    h: usize,
    hd: usize,
) -> (Vec<f32>, Vec<f32>) {
    let d = h * hd;
    let inv_sqrt = 1.0 / (hd as f64).sqrt();
    let mut probs = vec![0f32; b * h * t * t];
    let mut ctx = vec![0f32; b * t * d];
    let mut scores = vec![0f32; t];
    let mut crow = vec![0f64; hd];
    for bi in 0..b {
        for hh in 0..h {
            let base = (bi * h + hh) * t * t;
            for t1 in 0..t {
                let qrow = &q[(bi * t + t1) * d + hh * hd..(bi * t + t1) * d + (hh + 1) * hd];
                for (t2, sc) in scores.iter_mut().enumerate() {
                    if t2 > t1 {
                        *sc = -1e9;
                        continue;
                    }
                    let krow = &k[(bi * t + t2) * d + hh * hd..(bi * t + t2) * d + (hh + 1) * hd];
                    *sc = (kernels::dot8(qrow, krow) * inv_sqrt) as f32;
                }
                // softmax over the full row (masked entries underflow to 0)
                let max = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let mut sum = 0f64;
                let prow = &mut probs[base + t1 * t..base + (t1 + 1) * t];
                for (p, &sc) in prow.iter_mut().zip(scores.iter()) {
                    let e = (sc - max).exp();
                    *p = e;
                    sum += e as f64;
                }
                let inv = (1.0 / sum) as f32;
                for p in prow.iter_mut() {
                    *p *= inv;
                }
                crow.fill(0.0);
                for t2 in 0..=t1 {
                    let p = prow[t2] as f64;
                    if p == 0.0 {
                        continue;
                    }
                    let vrow = &v[(bi * t + t2) * d + hh * hd..(bi * t + t2) * d + (hh + 1) * hd];
                    for (c, &vv) in crow.iter_mut().zip(vrow.iter()) {
                        *c += p * vv as f64;
                    }
                }
                let out =
                    &mut ctx[(bi * t + t1) * d + hh * hd..(bi * t + t1) * d + (hh + 1) * hd];
                for (o, &c) in out.iter_mut().zip(crow.iter()) {
                    *o = c as f32;
                }
            }
        }
    }
    (probs, ctx)
}

/// Attention backward: `(dq, dk, dv)` from the context gradient.
fn attention_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    dctx: &[f32],
    b: usize,
    t: usize,
    h: usize,
    hd: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let d = h * hd;
    let inv_sqrt = 1.0 / (hd as f64).sqrt();
    let mut dq = vec![0f32; b * t * d];
    let mut dk = vec![0f32; b * t * d];
    let mut dv = vec![0f32; b * t * d];
    let mut dprobs = vec![0f64; t];
    for bi in 0..b {
        for hh in 0..h {
            let base = (bi * h + hh) * t * t;
            for t1 in 0..t {
                let prow = &probs[base + t1 * t..base + (t1 + 1) * t];
                let dcrow =
                    &dctx[(bi * t + t1) * d + hh * hd..(bi * t + t1) * d + (hh + 1) * hd];
                // dprobs[t2] = dctx · v[t2]; dv[t2] += probs · dctx
                let mut dot = 0f64; // Σ dprobs·probs (softmax backward)
                for t2 in 0..=t1 {
                    let vrow = &v[(bi * t + t2) * d + hh * hd..(bi * t + t2) * d + (hh + 1) * hd];
                    let acc = kernels::dot8(dcrow, vrow);
                    dprobs[t2] = acc;
                    dot += acc * prow[t2] as f64;
                    let p = prow[t2];
                    if p != 0.0 {
                        let dvrow = &mut dv
                            [(bi * t + t2) * d + hh * hd..(bi * t + t2) * d + (hh + 1) * hd];
                        for (dvv, &dc) in dvrow.iter_mut().zip(dcrow.iter()) {
                            *dvv += p * dc;
                        }
                    }
                }
                // dscores = probs ⊙ (dprobs − Σ dprobs·probs), then the
                // 1/√hd chain into q and k
                let qrow_base = (bi * t + t1) * d + hh * hd;
                for t2 in 0..=t1 {
                    let ds = prow[t2] as f64 * (dprobs[t2] - dot) * inv_sqrt;
                    if ds == 0.0 {
                        continue;
                    }
                    let krow_base = (bi * t + t2) * d + hh * hd;
                    for di in 0..hd {
                        dq[qrow_base + di] =
                            (dq[qrow_base + di] as f64 + ds * k[krow_base + di] as f64) as f32;
                        dk[krow_base + di] =
                            (dk[krow_base + di] as f64 + ds * q[qrow_base + di] as f64) as f32;
                    }
                }
            }
        }
    }
    (dq, dk, dv)
}

// ---------------------------------------------------------------------------
// Backend impl
// ---------------------------------------------------------------------------

impl Backend for HostBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn name(&self) -> &'static str {
        "host"
    }

    fn init_state(&self, seed: i32) -> Result<BackendState> {
        // One fused noise stream in spec order — the same protocol as the
        // compiled init (steps.py), over the repo's deterministic RNG
        // instead of JAX threefry. Draws are consumed even for ones/zeros
        // specs so layout changes never silently shift downstream draws.
        let mut rng = Rng::new(seed as i64 as u64);
        let mut state = vec![0f32; self.manifest.state_len];
        for spec in &self.specs {
            let out = &mut state[spec.offset..spec.offset + spec.size];
            match spec.init {
                Init::Embed | Init::Head => {
                    for o in out.iter_mut() {
                        *o = 0.02 * rng.gauss() as f32;
                    }
                }
                Init::Matrix => {
                    let scale = 1.0 / (spec.shape[0] as f32).sqrt();
                    for o in out.iter_mut() {
                        *o = rng.gauss() as f32 * scale;
                    }
                }
                Init::Ones => {
                    for _ in 0..spec.size {
                        rng.gauss();
                    }
                    out.fill(1.0);
                }
            }
        }
        Ok(BackendState::new(state))
    }

    fn upload_batch(&self, batch: &Batch) -> Result<UploadedBatch> {
        let v = self.dims.v as i32;
        for &tok in &batch.tokens {
            ensure!((0..v).contains(&tok), "token id {tok} outside vocab 0..{v}");
        }
        for &tgt in &batch.targets {
            ensure!(tgt < v, "target id {tgt} outside vocab 0..{v} (use < 0 for masked)");
        }
        let bytes = batch.nbytes();
        Ok(UploadedBatch::new(batch.clone(), bytes))
    }

    fn upload_ctrl(&self, ctrl: &[f32]) -> Result<CtrlBuf> {
        // The host backend reads `CtrlBuf::host` directly — no second copy.
        Ok(CtrlBuf::new(ctrl.to_vec(), ()))
    }

    fn lower_plan(&self, plan: &StepPlan) -> StepPlan {
        // the host engine executes any sound plan exactly
        plan.clone()
    }

    fn train_step(
        &self,
        state: &BackendState,
        io: &UploadedBatch,
        ctrl: &CtrlBuf,
        plan: &StepPlan,
    ) -> Result<BackendState> {
        let s = state.downcast::<Vec<f32>>()?;
        let batch = io.downcast::<Batch>()?;
        let c = &ctrl.host;
        let m = &self.manifest;
        let n_c = m.n_components;
        ensure!(
            plan.n() == n_c,
            "step plan covers {} components, layout has {n_c}",
            plan.n()
        );
        let t_step = c[0];
        let lr = c[1];
        let wd = self.weight_decay * c[2];
        let mask = &c[m.ctrl_mask_offset..m.ctrl_mask_offset + n_c];

        let fwd = self.forward(s, &batch.tokens);
        let (loss_sum, count, dlogits) = self.loss_grad(&fwd.logits, &batch.targets);
        // Omitted components come back as `None` gradients, so the
        // stats/carry/update loop below skips them wholesale — their
        // state bits stay identical, exactly like the masked update.
        let grads = self.backward(s, &fwd, dlogits, &batch.tokens, plan);

        let mut ns = s.clone();
        // Thread the optimizer + Eq. 1 stats over the same pool as the
        // matmuls; `threads_for` keeps micro configs serial. The work
        // estimate is ~4 state-sized passes (g, prev, slot reads+writes).
        let active: usize = self
            .specs
            .iter()
            .enumerate()
            .filter(|&(i, _)| grads[i].is_some())
            .map(|(_, sp)| sp.size)
            .sum();
        let threads = kernels::threads_for(active * 4);
        let (gnorm, gdiff, gabs) =
            self.apply_updates(threads, &mut ns, s, &grads, mask, t_step, lr, wd);
        // metrics prefix, rebuilt from zeros every step like steps.py
        ns[0] = loss_sum;
        ns[1] = count;
        ns[2] = gnorm as f32;
        ns[3] = 0.0;
        ns[m.gdiff_offset..m.gdiff_offset + n_c].copy_from_slice(&gdiff);
        ns[m.gabs_offset..m.gabs_offset + n_c].copy_from_slice(&gabs);
        Ok(BackendState::new(ns))
    }

    fn probe(&self, state: &BackendState) -> Result<Vec<f32>> {
        let s = state.downcast::<Vec<f32>>()?;
        Ok(s[..self.manifest.metrics_len].to_vec())
    }

    fn eval_step(&self, state: &BackendState, io: &UploadedBatch) -> Result<(f64, f64)> {
        let s = state.downcast::<Vec<f32>>()?;
        let batch = io.downcast::<Batch>()?;
        let fwd = self.forward(s, &batch.tokens);
        let (loss, count) = self.loss_of(&fwd.logits, &batch.targets);
        Ok((loss as f64, count as f64))
    }

    fn eval_rows(&self, state: &BackendState, io: &UploadedBatch) -> Result<Vec<(f64, f64)>> {
        let s = state.downcast::<Vec<f32>>()?;
        let batch = io.downcast::<Batch>()?;
        let fwd = self.forward(s, &batch.tokens);
        let Dims { b, t, v, .. } = self.dims;
        let mut out = Vec::with_capacity(b);
        for bi in 0..b {
            let mut loss = 0f64;
            let mut count = 0usize;
            for ti in 0..t {
                let row = bi * t + ti;
                let tgt = batch.targets[row];
                if tgt < 0 {
                    continue;
                }
                loss += nll(&fwd.logits[row * v..(row + 1) * v], tgt as usize);
                count += 1;
            }
            out.push((loss as f32 as f64, count as f64));
        }
        Ok(out)
    }

    fn state_to_host(&self, state: &BackendState) -> Result<Vec<f32>> {
        Ok(state.downcast::<Vec<f32>>()?.clone())
    }

    fn state_from_host(&self, host: &[f32]) -> Result<BackendState> {
        ensure!(
            host.len() == self.manifest.state_len,
            "state len {} != {}",
            host.len(),
            self.manifest.state_len
        );
        Ok(BackendState::new(host.to_vec()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RepoConfig;

    fn tiny() -> HostBackend {
        HostBackend::for_config(&RepoConfig::by_name("lm-tiny-fp").unwrap()).unwrap()
    }

    /// A micro config small enough for finite-difference gradchecks.
    fn micro(optimizer: &str) -> HostBackend {
        micro_layers(optimizer, 1)
    }

    fn micro_layers(optimizer: &str, n_layers: usize) -> HostBackend {
        let model = ModelConfig {
            kind: "lm".into(),
            vocab_size: 16,
            d_model: 8,
            n_layers,
            n_heads: 2,
            d_ff: 12,
            max_seq: 6,
        };
        let train = TrainConfig {
            batch_size: 2,
            seq_len: 4,
            optimizer: optimizer.into(),
            method: "fp".into(),
            weight_decay: 0.01,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            momentum: 0.9,
        };
        HostBackend::from_parts("lm-micro", &model, &train).unwrap()
    }

    fn all_active(be: &HostBackend) -> StepPlan {
        StepPlan::all_active(be.manifest().n_components)
    }

    fn attn_plan(be: &HostBackend) -> StepPlan {
        let m = be.manifest();
        StepPlan::omitting(m.n_components, &m.components_where(|c| c.group == "attention"))
    }

    fn micro_batch(be: &HostBackend, seed: u64) -> Batch {
        let m = be.manifest();
        let mut rng = Rng::new(seed);
        let n = m.batch_size * m.seq_len;
        Batch {
            tokens: (0..n).map(|_| rng.below(m.vocab_size) as i32).collect(),
            targets: (0..n).map(|_| rng.below(m.vocab_size) as i32).collect(),
            patches: Vec::new(),
        }
    }

    fn full_ctrl(m: &Manifest, t: f32, lr: f32) -> Vec<f32> {
        let mut c = vec![0f32; m.ctrl_len];
        c[0] = t;
        c[1] = lr;
        c[2] = 1.0;
        for x in c.iter_mut().skip(m.ctrl_mask_offset) {
            *x = 1.0;
        }
        c
    }

    #[test]
    fn layout_matches_the_compiled_artifact_numbers() {
        // Cross-checked against artifacts/lm-tiny-fp/manifest.json — the
        // contract that makes host and XLA states interchangeable.
        let m = tiny().into_manifest();
        assert_eq!(m.state_len, 436192);
        assert_eq!(m.metrics_len, 32);
        assert_eq!(m.ctrl_len, 18);
        assert_eq!(m.n_components, 14);
        assert_eq!((m.gdiff_offset, m.gabs_offset, m.ctrl_mask_offset), (4, 18, 4));
        assert_eq!(m.params.len(), 22);
        assert_eq!(m.n_params_total, 118080);
        assert_eq!(m.param("tok_emb").unwrap().offset, 32);
        assert_eq!(m.param("lm_head").unwrap().shape, vec![64, 256]);
        assert_eq!(m.components[0].name, "language.0.q");
        assert_eq!(m.components[13].name, "language.1.down");
        assert_eq!(m.components[4].group, "mlp");
        assert!(m.flops.fwd_per_token > 0.0);
    }

    #[test]
    fn sgd_layout_has_one_opt_slot() {
        let be = HostBackend::for_config(&RepoConfig::by_name("lm-tiny-sgd").unwrap()).unwrap();
        // params 118080, momentum slot 118080, prev 81920, metrics 32
        assert_eq!(be.manifest().state_len, 32 + 118080 + 118080 + 81920);
        assert!(matches!(be.opt, Opt::Sgd { .. }));
    }

    #[test]
    fn lora_and_vlm_configs_are_rejected_with_a_hint() {
        let lora = RepoConfig::by_name("lm-tiny-lora").unwrap();
        let err = HostBackend::for_config(&lora).unwrap_err().to_string();
        assert!(err.contains("--backend xla"), "{err}");
        let vlm = RepoConfig::by_name("vlm-tiny-fp").unwrap();
        let err = HostBackend::for_config(&vlm).unwrap_err().to_string();
        assert!(err.contains("--backend xla"), "{err}");
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let be = micro("adamw");
        let a = be.state_to_host(&be.init_state(7).unwrap()).unwrap();
        let b = be.state_to_host(&be.init_state(7).unwrap()).unwrap();
        assert_eq!(a, b);
        let c = be.state_to_host(&be.init_state(8).unwrap()).unwrap();
        assert_ne!(a, c);
        // metrics prefix + opt + prev regions start zeroed; ln scales = 1
        let m = be.manifest();
        assert!(a[..m.metrics_len].iter().all(|&x| x == 0.0));
        let ln1 = m.param("lang.0.ln1").unwrap();
        assert!(a[ln1.offset..ln1.offset + ln1.size()].iter().all(|&x| x == 1.0));
        let first_opt = be.specs[0].opt_offsets[0];
        assert!(a[first_opt..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn gradients_match_finite_differences() {
        // Central finite differences on a sample of entries of every
        // tensor family. f64 loss accumulation keeps FD noise ≈1e-6; the
        // analytic/FD agreement required here is ~1%.
        let be = micro("adamw");
        let state = be.state_to_host(&be.init_state(3).unwrap()).unwrap();
        let batch = micro_batch(&be, 99);
        let loss_of = |s: &[f32]| -> f64 {
            let fwd = be.forward(s, &batch.tokens);
            let (l, c, _) = be.loss_grad(&fwd.logits, &batch.targets);
            l as f64 / (c as f64).max(1.0)
        };
        let fwd = be.forward(&state, &batch.tokens);
        let (_, _, dlogits) = be.loss_grad(&fwd.logits, &batch.targets);
        let grads = be.backward(&state, &fwd, dlogits, &batch.tokens, &all_active(&be));
        let mut rng = Rng::new(5);
        let mut checked = 0usize;
        for (idx, spec) in be.specs.iter().enumerate() {
            let g = grads[idx].as_ref().expect("all tensors have grads in the full graph");
            for _ in 0..4 {
                let i = rng.below(spec.size);
                let eps = 2e-3f32;
                let mut sp = state.clone();
                sp[spec.offset + i] += eps;
                let mut sm = state.clone();
                sm[spec.offset + i] -= eps;
                // the realized (f32-rounded) step, not the nominal eps
                let h = (sp[spec.offset + i] - sm[spec.offset + i]) as f64;
                let fd = (loss_of(&sp) - loss_of(&sm)) / h;
                let an = g[i] as f64;
                // only test entries with signal above the FD noise floor
                if fd.abs() < 1e-3 && an.abs() < 1e-3 {
                    continue;
                }
                let rel = (fd - an).abs() / fd.abs().max(an.abs()).max(1e-6);
                assert!(
                    rel < 0.1,
                    "grad mismatch {}[{i}]: analytic {an:.6e} vs fd {fd:.6e} (rel {rel:.3})",
                    spec.name
                );
                checked += 1;
            }
        }
        assert!(checked >= 12, "gradcheck sampled too few informative entries ({checked})");
    }

    #[test]
    fn train_step_writes_metrics_and_reduces_loss() {
        let be = micro("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 1);
        let io = be.upload_batch(&batch).unwrap();
        let mut state = be.init_state(1).unwrap();
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for t in 1..=30 {
            let ctrl = be.upload_ctrl(&full_ctrl(m, t as f32, 1e-2)).unwrap();
            state = be.train_step(&state, &io, &ctrl, &all_active(&be)).unwrap();
            let metrics = be.probe(&state).unwrap();
            let loss = metrics[0] / metrics[1].max(1.0);
            assert!(loss.is_finite());
            assert!(metrics[2] > 0.0, "global gnorm recorded");
            assert!(metrics[m.gdiff_offset] > 0.0, "gdiff recorded");
            if t == 1 {
                first = loss;
            }
            last = loss;
        }
        assert!(last < first - 0.3, "loss must fall on a repeated batch: {first} -> {last}");
    }

    #[test]
    fn freeze_mask_keeps_component_bits_identical() {
        let be = micro("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 2);
        let io = be.upload_batch(&batch).unwrap();
        let s0 = be.init_state(5).unwrap();
        let before = be.state_to_host(&s0).unwrap();
        let mut ctrl = full_ctrl(m, 1.0, 1e-3);
        ctrl[m.ctrl_mask_offset] = 0.0; // freeze component 0 (layer-0 q)
        let ctrl = be.upload_ctrl(&ctrl).unwrap();
        let s1 = be.train_step(&s0, &io, &ctrl, &all_active(&be)).unwrap();
        let after = be.state_to_host(&s1).unwrap();
        let frozen = &be.specs[be.layers[0].wq];
        assert_eq!(
            before[frozen.offset..frozen.offset + frozen.size],
            after[frozen.offset..frozen.offset + frozen.size],
            "frozen params moved"
        );
        for &o in &frozen.opt_offsets {
            assert_eq!(before[o..o + frozen.size], after[o..o + frozen.size], "opt state moved");
        }
        let p = frozen.prev_offset.unwrap();
        assert_eq!(before[p..p + frozen.size], after[p..p + frozen.size], "prev-grad carry moved");
        // but its gdiff/gabs are still measured (mask ≠ stop_gradient)
        assert!(after[m.gdiff_offset] > 0.0);
        // and an unfrozen component moved
        let other = &be.specs[be.layers[0].wk];
        assert_ne!(
            before[other.offset..other.offset + other.size],
            after[other.offset..other.offset + other.size]
        );
    }

    #[test]
    fn attn_plan_equals_masked_full_graph_bitwise() {
        // Stronger than the XLA integration test (which tolerates graph
        // fusion drift): the planned step skips exactly the omitted dW
        // math and nothing else, so states past the metrics prefix match
        // bit-for-bit.
        let be = micro("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 3);
        let io = be.upload_batch(&batch).unwrap();
        let s0 = be.init_state(11).unwrap();

        let mut masked = full_ctrl(m, 1.0, 1e-3);
        for c in &m.components {
            if c.group == "attention" {
                masked[m.ctrl_mask_offset + c.idx] = 0.0;
            }
        }
        let a = be
            .train_step(&s0, &io, &be.upload_ctrl(&masked).unwrap(), &all_active(&be))
            .unwrap();
        let b = be
            .train_step(
                &s0,
                &io,
                &be.upload_ctrl(&full_ctrl(m, 1.0, 1e-3)).unwrap(),
                &attn_plan(&be),
            )
            .unwrap();
        let ha = be.state_to_host(&a).unwrap();
        let hb = be.state_to_host(&b).unwrap();
        assert_eq!(ha[m.metrics_len..], hb[m.metrics_len..]);
        // the plan reports omitted stats as zero, the masked graph
        // still measures them
        let attn0 = m.gdiff_offset; // component 0 is attention
        assert!(ha[attn0] > 0.0);
        assert_eq!(hb[attn0], 0.0);
    }

    #[test]
    fn per_matrix_plan_equals_masked_full_graph_bitwise() {
        // The generalized elision: omit an arbitrary mix of components
        // (one attention, one mlp) — params/opt/prev must match the
        // masked dense step bit-for-bit, only the omitted components'
        // logged statistics differ.
        let be = micro("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 17);
        let io = be.upload_batch(&batch).unwrap();
        let s0 = be.init_state(23).unwrap();
        let omitted = [1usize, 5]; // layer-0 k (attention) + layer-0 up (mlp)
        let mut masked = full_ctrl(m, 1.0, 1e-3);
        for &c in &omitted {
            masked[m.ctrl_mask_offset + c] = 0.0;
        }
        let a = be
            .train_step(&s0, &io, &be.upload_ctrl(&masked).unwrap(), &all_active(&be))
            .unwrap();
        let b = be
            .train_step(
                &s0,
                &io,
                &be.upload_ctrl(&masked).unwrap(),
                &StepPlan::omitting(m.n_components, &omitted),
            )
            .unwrap();
        let ha = be.state_to_host(&a).unwrap();
        let hb = be.state_to_host(&b).unwrap();
        assert_eq!(ha[m.metrics_len..], hb[m.metrics_len..]);
        for &c in &omitted {
            assert!(ha[m.gdiff_offset + c] > 0.0);
            assert_eq!(hb[m.gdiff_offset + c], 0.0);
            assert_eq!(hb[m.gabs_offset + c], 0.0);
        }
        // a kept component's stats are identical in both runs
        assert_eq!(ha[m.gdiff_offset].to_bits(), hb[m.gdiff_offset].to_bits());
    }

    #[test]
    fn fully_omitted_layer_prefix_truncates_backward_and_holds_riders() {
        let be = micro_layers("adamw", 2);
        let m = be.manifest();
        assert_eq!(m.n_components, 14);
        let batch = micro_batch(&be, 31);
        let io = be.upload_batch(&batch).unwrap();
        let s0 = be.init_state(5).unwrap();
        let before = be.state_to_host(&s0).unwrap();
        // freeze + omit all of layer 0 (components 0..7); layer 1 active
        let mut ctrl = full_ctrl(m, 1.0, 1e-3);
        for c in 0..7 {
            ctrl[m.ctrl_mask_offset + c] = 0.0;
        }
        let prefix: Vec<usize> = (0..7).collect();
        // without the truncation grant the same omitted set must stay
        // bitwise-equal to the masked dense step (riders keep moving)
        let ungranted = be
            .train_step(
                &s0,
                &io,
                &be.upload_ctrl(&ctrl).unwrap(),
                &StepPlan::omitting(m.n_components, &prefix),
            )
            .unwrap();
        let plan = StepPlan::omitting(m.n_components, &prefix).with_truncation();
        let planned = be
            .train_step(&s0, &io, &be.upload_ctrl(&ctrl).unwrap(), &plan)
            .unwrap();
        let masked = be
            .train_step(&s0, &io, &be.upload_ctrl(&ctrl).unwrap(), &all_active(&be))
            .unwrap();
        let hp = be.state_to_host(&planned).unwrap();
        let hm = be.state_to_host(&masked).unwrap();
        let hu = be.state_to_host(&ungranted).unwrap();
        assert_eq!(hu[m.metrics_len..], hm[m.metrics_len..], "ungranted plan must not truncate");
        // riders of the truncated prefix are held bit-identical…
        for name in ["tok_emb", "pos_emb", "lang.0.ln1", "lang.0.ln2"] {
            let p = m.param(name).unwrap();
            assert_eq!(
                before[p.offset..p.offset + p.size()],
                hp[p.offset..p.offset + p.size()],
                "truncated rider {name} moved"
            );
            // …which is the documented divergence from the masked path
            // (there, weight decay still moves them)
            assert_ne!(
                hm[p.offset..p.offset + p.size()],
                hp[p.offset..p.offset + p.size()],
                "masked path should have updated rider {name}"
            );
        }
        // everything at or above the lowest active layer is bitwise
        // identical to the masked dense step
        for name in ["lang.1.ln1", "lang.1.attn.q", "lang.1.mlp.down", "ln_f", "lm_head"] {
            let p = m.param(name).unwrap();
            assert_eq!(
                hm[p.offset..p.offset + p.size()],
                hp[p.offset..p.offset + p.size()],
                "active-region tensor {name} diverged"
            );
        }
        // a *non-prefix* fully-frozen layer must not truncate: omit all
        // of layer 1 instead, and layer-0 riders plus embeddings move
        let plan_top =
            StepPlan::omitting(m.n_components, &(7..14).collect::<Vec<_>>()).with_truncation();
        let top = be
            .train_step(
                &s0,
                &io,
                &be.upload_ctrl(&full_ctrl(m, 1.0, 1e-3)).unwrap(),
                &plan_top,
            )
            .unwrap();
        let ht = be.state_to_host(&top).unwrap();
        for name in ["tok_emb", "lang.0.ln1", "lang.1.ln2"] {
            let p = m.param(name).unwrap();
            assert_ne!(
                before[p.offset..p.offset + p.size()],
                ht[p.offset..p.offset + p.size()],
                "non-prefix omission must not hold {name}"
            );
        }
    }

    #[test]
    fn unfreeze_downgrades_the_plan_and_resumes_updates() {
        // The dynamic-unfreezing regression: a component that froze (and
        // was elided) then unfroze must re-enter the plan and move again
        // — and the whole planned trajectory must match the masked dense
        // path bit-for-bit on the state.
        use crate::coordinator::freeze::{FreezeReason, FreezeState};
        use crate::coordinator::scheduler::StepPlanner;
        let be = micro("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 41);
        let io = be.upload_batch(&batch).unwrap();
        let mut planner = StepPlanner::new(m, true);
        let mut freeze = FreezeState::new(m.n_components);

        let mut planned = be.init_state(3).unwrap();
        let mut dense = be.init_state(3).unwrap();
        let comp = 2usize;
        for t in 1..=6 {
            match t {
                2 => freeze.freeze(comp, t, FreezeReason::Manual, 0.0),
                4 => freeze.unfreeze(comp, t, FreezeReason::Manual, 1.0),
                _ => {}
            }
            let mut ctrl = full_ctrl(m, t as f32, 1e-3);
            ctrl[m.ctrl_mask_offset..m.ctrl_mask_offset + m.n_components]
                .copy_from_slice(freeze.mask());
            let ctrl = be.upload_ctrl(&ctrl).unwrap();
            let plan = planner.plan(t, &freeze);
            assert!(plan.is_sound(&freeze));
            assert_eq!(plan.omits(comp), freeze.is_frozen(comp), "plan lags freeze at t={t}");
            let before = be.state_to_host(&planned).unwrap();
            planned = be.train_step(&planned, &io, &ctrl, &plan).unwrap();
            dense = be.train_step(&dense, &io, &ctrl, &all_active(&be)).unwrap();
            let after = be.state_to_host(&planned).unwrap();
            let p = m.param(&m.components[comp].tensors[0]).unwrap();
            let moved = before[p.offset..p.offset + p.size()]
                != after[p.offset..p.offset + p.size()];
            assert_eq!(moved, !freeze.is_frozen(comp), "component motion wrong at t={t}");
        }
        assert_eq!(planner.stats.downgrades, 1);
        let hp = be.state_to_host(&planned).unwrap();
        let hd = be.state_to_host(&dense).unwrap();
        assert_eq!(hp[m.metrics_len..], hd[m.metrics_len..], "planned != masked trajectory");
    }

    #[test]
    fn threaded_update_is_bitwise_identical_across_thread_counts() {
        // Drives `apply_updates` with explicit worker counts — micro
        // configs fall below `threads_for`'s work floor, so an env-driven
        // test would silently stay serial. Partial freezing exercises the
        // masked path; both optimizer families are covered. (Matmul
        // thread/SIMD invariance lives in `host_kernels::tests` and
        // `tests/properties.rs`.)
        for optimizer in ["adamw", "sgd"] {
            let be = micro(optimizer);
            let m = be.manifest();
            let batch = micro_batch(&be, 9);
            let s0 = be.init_state(5).unwrap();
            let s = be.state_to_host(&s0).unwrap();
            let mut ctrl = full_ctrl(m, 1.0, 1e-2);
            ctrl[m.ctrl_mask_offset] = 0.0; // freeze component 0
            let mask = &ctrl[m.ctrl_mask_offset..m.ctrl_mask_offset + m.n_components];
            let fwd = be.forward(&s, &batch.tokens);
            let (_, _, dlogits) = be.loss_grad(&fwd.logits, &batch.targets);
            let grads = be.backward(&s, &fwd, dlogits, &batch.tokens, &all_active(&be));

            let mut base = s.clone();
            let (gn1, gd1, ga1) =
                be.apply_updates(1, &mut base, &s, &grads, mask, 1.0, 1e-2, 1e-2);
            for threads in [2, 3, 8] {
                let mut ns = s.clone();
                let (gn, gd, ga) =
                    be.apply_updates(threads, &mut ns, &s, &grads, mask, 1.0, 1e-2, 1e-2);
                assert_eq!(gn.to_bits(), gn1.to_bits(), "{optimizer}/{threads} gnorm");
                assert!(gd.iter().zip(&gd1).all(|(x, y)| x.to_bits() == y.to_bits()));
                assert!(ga.iter().zip(&ga1).all(|(x, y)| x.to_bits() == y.to_bits()));
                assert!(
                    ns.iter().zip(&base).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{optimizer}/{threads}: threaded state differs from serial"
                );
            }
        }
    }

    #[test]
    fn sgd_step_moves_params_and_momentum() {
        let be = micro("sgd");
        let m = be.manifest();
        let batch = micro_batch(&be, 4);
        let io = be.upload_batch(&batch).unwrap();
        let s0 = be.init_state(2).unwrap();
        let before = be.state_to_host(&s0).unwrap();
        let ctrl = be.upload_ctrl(&full_ctrl(m, 1.0, 1e-2)).unwrap();
        let s1 = be.train_step(&s0, &io, &ctrl, &all_active(&be)).unwrap();
        let after = be.state_to_host(&s1).unwrap();
        let wq = &be.specs[be.layers[0].wq];
        assert_ne!(before[wq.offset..wq.offset + wq.size], after[wq.offset..wq.offset + wq.size]);
        let mom = wq.opt_offsets[0];
        assert!(after[mom..mom + wq.size].iter().any(|&x| x != 0.0), "momentum accumulated");
    }

    #[test]
    fn eval_step_matches_probe_loss_before_any_update() {
        // eval on the state a step *started* from equals the loss that
        // step recorded in the metrics prefix (train loss is pre-update).
        let be = micro("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 6);
        let io = be.upload_batch(&batch).unwrap();
        let s0 = be.init_state(21).unwrap();
        let (eval_loss, eval_count) = be.eval_step(&s0, &io).unwrap();
        let ctrl = be.upload_ctrl(&full_ctrl(m, 1.0, 1e-3)).unwrap();
        let s1 = be.train_step(&s0, &io, &ctrl, &all_active(&be)).unwrap();
        let metrics = be.probe(&s1).unwrap();
        assert_eq!(metrics[0].to_bits(), (eval_loss as f32).to_bits());
        assert_eq!(metrics[1], eval_count as f32);
    }

    #[test]
    fn eval_rows_sum_to_eval_step() {
        let be = micro("adamw");
        let mut batch = micro_batch(&be, 7);
        // mask a few targets so per-row counts differ
        batch.targets[1] = -1;
        batch.targets[5] = -1;
        let io = be.upload_batch(&batch).unwrap();
        let s = be.init_state(9).unwrap();
        let rows = be.eval_rows(&s, &io).unwrap();
        assert_eq!(rows.len(), be.manifest().batch_size);
        let (loss, count) = be.eval_step(&s, &io).unwrap();
        let row_loss: f64 = rows.iter().map(|r| r.0).sum();
        let row_count: f64 = rows.iter().map(|r| r.1).sum();
        assert!((row_loss - loss).abs() < 1e-3 * loss.abs().max(1.0));
        assert_eq!(row_count, count);
    }

    #[test]
    fn upload_batch_rejects_out_of_vocab_tokens() {
        let be = micro("adamw");
        let mut batch = micro_batch(&be, 8);
        batch.tokens[0] = 999;
        assert!(be.upload_batch(&batch).is_err());
    }

    #[test]
    fn state_round_trips_and_rejects_bad_lengths() {
        let be = micro("adamw");
        let s = be.init_state(1).unwrap();
        let host = be.state_to_host(&s).unwrap();
        let back = be.state_from_host(&host).unwrap();
        assert_eq!(be.state_to_host(&back).unwrap(), host);
        assert!(be.state_from_host(&host[1..]).is_err());
    }
}
