//! The pure-Rust reference backend: a small decoder-only transformer
//! (the paper's 7-matrix layer anatomy) with full forward + backward,
//! freeze-masked AdamW/SGD updates, the ctrl-vector protocol and the
//! per-matrix gradient-statistics metrics prefix — mirroring
//! `python/compile/model.py` / `lora.py` / `steps.py` / `layout.py` for
//! every config family the paper trains:
//!
//! * **LM fp** — full-parameter decoder-only LM (`lm_logits`).
//! * **LM LoRA** — frozen base weights with trainable rank-`r` (A, B)
//!   adapter pairs per monitored matrix; the forward/backward graph runs
//!   on the merged weight `W + (α/r)·A·B` (`merge_lora`), Eq. 1 stats
//!   sum over the (A, B) pair, and the masked optimizer touches adapters
//!   only — base weights carry *no* gradient entry at all (`jax.grad`
//!   over the trainable dict), not a zero one.
//! * **VLM** (fp or LoRA) — the LLaVA-style two-tower graph
//!   (`vlm_logits`): patch-embed + *non-causal* vision tower, RMS-norm +
//!   projection into `P` prefix rows, then the causal language tower
//!   over `P+T` rows with the loss on the `T` text positions. Vision
//!   components come first in the registry and carry `tower="vision"`,
//!   exactly like the compiled manifest, so `GradesMonitor` freeze keys
//!   line up across backends.
//!
//! Purpose: make the GradES freeze/stop logic executable *everywhere*.
//! With this backend, `cargo test -q` runs complete training
//! trajectories — freeze decisions, variant swaps, classic-ES checks —
//! with no Python toolchain and no compiled artifacts, and the XLA path
//! becomes something tier-1 differentially verifies
//! (`rust/tests/differential.rs`) instead of trusts.
//!
//! # What matches the compiled graphs
//!
//! * The **state layout** (`layout.py`): `[metrics | params | opt slots |
//!   prev grads]`, bit-for-bit the same offsets — `state_from_host` of an
//!   XLA-produced state is a valid host state and vice versa.
//! * The **step semantics** (`steps.py` + `kernels/ref.py`): loss =
//!   `Σ CE / max(count, 1)`, Eq. 1 per-component `‖∇Wₜ − ∇Wₜ₋₁‖₁` /
//!   `‖∇Wₜ‖₁` statistics, freeze-masked updates that keep frozen p/m/v
//!   bit-identical, and the prev-grad carry.
//! * The **ctrl protocol**: `[step, lr, wd_scale, pad, mask…]`.
//!
//! # Freeze-aware execution
//!
//! Where the XLA engine lowers a
//! [`StepPlan`](crate::coordinator::scheduler::StepPlan) to the nearest
//! pre-compiled graph variant, this engine honors the plan **exactly**:
//! every omitted component skips its dW matmul, its Eq. 1 gdiff/gabs
//! contribution (the stats report 0, like the compiled attn-frozen
//! graph does for attention), its prev-grad carry and its optimizer
//! slot update — bitwise-equivalent to the masked full graph on the
//! params/opt/prev regions, cheaper by the omitted matmuls. Plans that
//! additionally carry the **truncation grant**
//! (`StepPlan::with_truncation`, opt-in via
//! `TrainerOptions::truncate_frozen_prefix`) stop the backward sweep
//! below a fully-omitted layer *prefix* (AutoFreeze-style whole-layer
//! rule): the truncated layers' norm scales and the embeddings receive
//! no gradient and are held bit-identical for the step — a documented
//! trajectory-changing choice, which is why it is never granted by
//! default. An all-active plan reproduces the dense path bitwise.
//!
//! All matmuls, the fused attention passes, the SwiGLU/softmax
//! elementwise math, the Eq. 1 L1 reductions and the hot dot products
//! run on the SIMD microkernel layer in
//! [`host_kernels`](super::host_kernels): a cache-blocked, 8-lane
//! f64-accumulating row·row kernel plus the row-blocked fused-attention
//! and vectorized-exp family, runtime-dispatched over scalar/SSE2/AVX2
//! (`GRADES_HOST_SIMD`) and fanned out over `GRADES_HOST_THREADS`
//! scoped workers (attention over `(batch, head)` pairs). The
//! lane-split reduction order is fixed, so results are **bitwise
//! identical for every SIMD level and every thread count** (asserted
//! here and in `rust/tests/properties.rs`). The freeze-masked optimizer
//! update and gdiff/gabs statistics thread over the same pool,
//! partitioned at whole-tensor granularity. Every activation, gradient
//! and packing buffer is carved from the step-scoped workspace arena in
//! [`host_arena`](super::host_arena) (`GRADES_HOST_ARENA=0` opt-out),
//! so the steady-state training loop performs zero per-step heap
//! growth — with no effect on results, bitwise.
//!
//! # Where it may diverge numerically
//!
//! Reductions here accumulate in f64 lanes and round to f32 once, while
//! XLA uses f32 tree reductions in an unspecified order; elementwise
//! math is f32 on both sides. Expected per-step loss agreement is ~1e-4
//! relative on the tiny configs — the differential harness asserts
//! losses within tolerance and freeze steps *identical*. Init draws
//! come from the repo's own deterministic RNG, not JAX's threefry, so
//! cross-backend comparisons start from an XLA-initialized state
//! shipped through `state_to_host`/`state_from_host`.

use anyhow::{ensure, Result};

use super::backend::{Backend, BackendState, CtrlBuf, UploadedBatch};
use super::host_arena::{buf_raw, buf_zeroed, Buf};
use super::host_kernels::{self as kernels};
use super::manifest::{Component, FlopsInfo, Manifest, ParamInfo};
use super::session::Batch;
use crate::config::{ModelConfig, RepoConfig, TrainConfig};
use crate::coordinator::scheduler::StepPlan;
use crate::util::rng::Rng;

/// `[loss_sum, token_count, global_gnorm, reserved]` (layout.py METRIC_PAD).
const METRIC_PAD: usize = 4;
/// `[step, lr, wd_scale, reserved]` (layout.py CTRL_PAD).
const CTRL_PAD: usize = 4;

/// Init family per tensor (layout.py `ParamSpec.init`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Init {
    /// 0.02 · N(0,1) — embeddings.
    Embed,
    /// N(0,1) / √fan_in — projection matrices.
    Matrix,
    /// All ones — RMSNorm scales.
    Ones,
    /// 0.02 · N(0,1) — the untied LM head.
    Head,
    /// 0.05 · N(0,1) — LoRA A adapters.
    LoraA,
    /// All zeros — LoRA B adapters (draws still burned, like `Ones`).
    LoraB,
}

/// One flat-state tensor: its slice of the state plus optimizer/prev
/// bookkeeping offsets.
struct HostSpec {
    name: String,
    shape: Vec<usize>,
    size: usize,
    /// Offset of the parameter values in the flat state.
    offset: usize,
    component: Option<usize>,
    init: Init,
    /// Whether the optimizer touches this tensor (layout.py: LoRA base
    /// weights are frozen — no opt slots, no gradients, ever).
    trainable: bool,
    /// AdamW: `[m, v]` offsets; SGD: `[mom]`. Empty when untrainable.
    opt_offsets: Vec<usize>,
    /// Prev-grad slot (monitored tensors only — the Eq. 1 carry).
    prev_offset: Option<usize>,
}

/// Spec indices of one transformer layer's nine tensors.
struct LayerIdx {
    ln1: usize,
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    ln2: usize,
    wg: usize,
    wu: usize,
    wd: usize,
}

/// Model dimensions, denormalized from the config for hot-loop use.
#[derive(Clone, Copy)]
struct Dims {
    /// Batch size B.
    b: usize,
    /// Sequence length T.
    t: usize,
    /// Residual width D.
    d: usize,
    /// Head count H.
    h: usize,
    /// Head dim D/H.
    hd: usize,
    /// SwiGLU hidden width F.
    f: usize,
    /// Layer count L.
    l: usize,
    /// Vocab size V.
    v: usize,
    /// Positional-table length (max_seq; VLMs: max_seq + n_patches).
    s: usize,
}

/// Vision-tower dimensions (VLM configs only).
#[derive(Clone, Copy)]
struct VisDims {
    /// Patches per example P.
    p: usize,
    /// Flattened patch feature width.
    pd: usize,
    /// Vision residual width D_v.
    dv: usize,
    /// Vision head count.
    vh: usize,
    /// Vision SwiGLU hidden width.
    vf: usize,
    /// Vision layer count.
    vl: usize,
}

/// Spec indices of the VLM-only tensors plus the vision tower's layers.
struct VlmIdx {
    vis_in: usize,
    vis_pos: usize,
    vis_ln_f: usize,
    vis_proj: usize,
    layers: Vec<LayerIdx>,
    dims: VisDims,
}

/// One LoRA-adapted matmul site: the frozen base weight and its
/// trainable adapter pair, all as spec indices.
struct LoraSite {
    base: usize,
    a: usize,
    b: usize,
}

/// LoRA bookkeeping: `sites[c]` adapts component `c`'s base matrix with
/// `W + scale·A@B` (lora.py `merge_lora`; scale = α/r).
struct Lora {
    rank: usize,
    scale: f32,
    sites: Vec<LoraSite>,
}

/// Optimizer family + constants (f32, matching the compiled kernels).
enum Opt {
    /// AdamW with bias correction driven by `ctrl[0]`.
    AdamW { b1: f32, b2: f32, eps: f32 },
    /// SGD with momentum (step-insensitive: never reads `ctrl[0]`).
    Sgd { momentum: f32 },
}

/// The pure-Rust engine for one config — any `kind` (`lm`/`vlm`) ×
/// `method` (`fp`/`lora`) cell. Stateless across calls: every program is
/// a function from (state, inputs) to outputs, exactly like the
/// compiled executables.
pub struct HostBackend {
    manifest: Manifest,
    specs: Vec<HostSpec>,
    dims: Dims,
    opt: Opt,
    weight_decay: f32,
    tok_emb: usize,
    pos_emb: usize,
    ln_f: usize,
    lm_head: usize,
    layers: Vec<LayerIdx>,
    /// Spec idx → the component owning that matmul site. Equals
    /// `spec.component` for fp layouts; under LoRA it additionally maps
    /// each *base* weight (whose own `component` is `None`) to the
    /// component of its adapter pair, so plan/freeze lookups work from
    /// the forward graph's weight indices in both methods.
    wcomp: Vec<Option<usize>>,
    /// LoRA adapter bookkeeping (`None` for fp).
    lora: Option<Lora>,
    /// Vision tower (`None` for pure LMs).
    vlm: Option<VlmIdx>,
}

/// A spec before offsets are assigned: `(name, shape, init, component)`.
type SpecSeed = (String, Vec<usize>, Init, Option<usize>);

/// Append one transformer tower's per-layer specs + components in
/// layout.py `_tower_specs` order (ln1, q/k/v/o, ln2, gate/up/down).
fn push_tower(
    prefix: &str,
    tower: &str,
    n_layers: usize,
    d: usize,
    d_ff: usize,
    specs: &mut Vec<SpecSeed>,
    components: &mut Vec<Component>,
) {
    for layer in 0..n_layers {
        specs.push((format!("{prefix}.{layer}.ln1"), vec![d], Init::Ones, None));
        for kind in ["q", "k", "v", "o"] {
            let cidx = components.len();
            let name = format!("{prefix}.{layer}.attn.{kind}");
            components.push(Component {
                idx: cidx,
                name: format!("{tower}.{layer}.{kind}"),
                layer,
                kind: kind.to_string(),
                group: "attention".into(),
                tower: tower.into(),
                n_params: d * d,
                tensors: vec![name.clone()],
            });
            specs.push((name, vec![d, d], Init::Matrix, Some(cidx)));
        }
        specs.push((format!("{prefix}.{layer}.ln2"), vec![d], Init::Ones, None));
        for kind in ["gate", "up", "down"] {
            let cidx = components.len();
            let name = format!("{prefix}.{layer}.mlp.{kind}");
            let shape = if kind == "down" { vec![d_ff, d] } else { vec![d, d_ff] };
            components.push(Component {
                idx: cidx,
                name: format!("{tower}.{layer}.{kind}"),
                layer,
                kind: kind.to_string(),
                group: "mlp".into(),
                tower: tower.into(),
                n_params: d * d_ff,
                tensors: vec![name.clone()],
            });
            specs.push((name, shape, Init::Matrix, Some(cidx)));
        }
    }
}

impl HostBackend {
    /// Build the engine for a `configs/*.toml` config — any
    /// `lm`/`vlm` × `fp`/`lora` cell.
    pub fn for_config(cfg: &RepoConfig) -> Result<Self> {
        Self::from_parts_gvar(&cfg.name, &cfg.model, &cfg.train, cfg.eb.gvar)
    }

    /// Build from raw `[model]`/`[train]` tables (tests and benches use
    /// this to make micro-sized engines without a config file). The
    /// layout carries no gradient-variance block — byte-identical to
    /// every pre-zoo engine.
    pub fn from_parts(name: &str, model: &ModelConfig, train: &TrainConfig) -> Result<Self> {
        Self::from_parts_gvar(name, model, train, false)
    }

    /// [`HostBackend::from_parts`] with an optional per-component
    /// gradient-variance (`gvar`) block appended to the metrics prefix —
    /// the exact EB-criterion statistic (`[eb] gvar = true`). Off, the
    /// layout is bitwise-identical to `from_parts`.
    pub fn from_parts_gvar(
        name: &str,
        model: &ModelConfig,
        train: &TrainConfig,
        gvar: bool,
    ) -> Result<Self> {
        ensure!(
            model.kind == "lm" || model.kind == "vlm",
            "unknown model kind {:?} in config {name:?} (expected \"lm\" or \"vlm\")",
            model.kind
        );
        ensure!(
            train.method == "fp" || train.method == "lora",
            "unknown train method {:?} in config {name:?} (expected \"fp\" or \"lora\")",
            train.method
        );
        ensure!(
            model.d_model > 0 && model.n_layers > 0 && model.d_ff > 0 && model.vocab_size > 0,
            "config {name:?} has no usable [model] table (d_model/n_layers/d_ff/vocab_size)"
        );
        ensure!(model.n_heads > 0 && model.d_model % model.n_heads == 0, "d_model % n_heads != 0");
        ensure!(train.batch_size > 0 && train.seq_len > 0, "[train] batch_size/seq_len missing");
        ensure!(train.seq_len <= model.max_seq, "seq_len exceeds max_seq");
        ensure!(
            train.optimizer == "adamw" || train.optimizer == "sgd",
            "unknown optimizer {:?}",
            train.optimizer
        );
        let is_vlm = model.kind == "vlm";
        let is_lora = train.method == "lora";
        if is_vlm {
            ensure!(
                model.n_patches > 0
                    && model.patch_dim > 0
                    && model.d_vision > 0
                    && model.n_vision_layers > 0
                    && model.d_vision_ff > 0,
                "config {name:?} is kind=\"vlm\" but its [vlm] table is incomplete \
                 (n_patches/patch_dim/d_vision/n_vision_layers/d_vision_ff)"
            );
            ensure!(
                model.n_vision_heads > 0 && model.d_vision % model.n_vision_heads == 0,
                "d_vision % n_vision_heads != 0"
            );
        }
        if is_lora {
            ensure!(train.lora_rank > 0, "config {name:?}: lora_rank must be positive");
        }

        let (d, ff) = (model.d_model, model.d_ff);
        // --- specs + components in layout.py order (vision tower first) ---
        let mut specs: Vec<SpecSeed> = Vec::new();
        let mut components = Vec::new();
        if is_vlm {
            specs.push(("vis_in".into(), vec![model.patch_dim, model.d_vision], Init::Matrix, None));
            specs.push(("vis_pos".into(), vec![model.n_patches, model.d_vision], Init::Embed, None));
            push_tower(
                "vis",
                "vision",
                model.n_vision_layers,
                model.d_vision,
                model.d_vision_ff,
                &mut specs,
                &mut components,
            );
            specs.push(("vis_ln_f".into(), vec![model.d_vision], Init::Ones, None));
            specs.push(("vis_proj".into(), vec![model.d_vision, d], Init::Matrix, None));
        }
        specs.push(("tok_emb".into(), vec![model.vocab_size, d], Init::Embed, None));
        let total_seq = model.max_seq + if is_vlm { model.n_patches } else { 0 };
        specs.push(("pos_emb".into(), vec![total_seq, d], Init::Embed, None));
        push_tower("lang", "language", model.n_layers, d, ff, &mut specs, &mut components);
        specs.push(("ln_f".into(), vec![d], Init::Ones, None));
        specs.push(("lm_head".into(), vec![d, model.vocab_size], Init::Head, None));

        // --- LoRA: base specs lose trainability + monitoring; adapter
        // pairs append in component order (layout.py lora_param_specs) ---
        let mut trainable = vec![!is_lora; specs.len()];
        if is_lora {
            let r = train.lora_rank;
            for seed in specs.iter_mut() {
                seed.3 = None;
            }
            for c in components.iter_mut() {
                let wname = c.tensors[0].clone();
                let shape = &specs.iter().find(|s| s.0 == wname).expect("base spec").1;
                let (d_in, d_out) = (shape[0], shape[1]);
                c.tensors = vec![format!("{wname}.lora_a"), format!("{wname}.lora_b")];
                c.n_params = r * (d_in + d_out);
                specs.push((format!("{wname}.lora_a"), vec![d_in, r], Init::LoraA, Some(c.idx)));
                specs.push((format!("{wname}.lora_b"), vec![r, d_out], Init::LoraB, Some(c.idx)));
                trainable.push(true);
                trainable.push(true);
            }
        }

        // --- offsets: [metrics | params (all) | opt slot(s) (trainable)
        //               | prev grads (trainable ∧ monitored)] ---
        let n_c = components.len();
        let metrics_len = METRIC_PAD + 2 * n_c + if gvar { n_c } else { 0 };
        let ctrl_len = CTRL_PAD + n_c;
        let mut off = metrics_len;
        let mut host_specs: Vec<HostSpec> = specs
            .iter()
            .zip(trainable.iter())
            .map(|((name, shape, init, comp), &tr)| {
                let size: usize = shape.iter().product();
                let s = HostSpec {
                    name: name.clone(),
                    shape: shape.clone(),
                    size,
                    offset: off,
                    component: *comp,
                    init: *init,
                    trainable: tr,
                    opt_offsets: Vec::new(),
                    prev_offset: None,
                };
                off += size;
                s
            })
            .collect();
        let n_opt_slots = if train.optimizer == "adamw" { 2 } else { 1 };
        for _slot in 0..n_opt_slots {
            for s in host_specs.iter_mut() {
                if s.trainable {
                    s.opt_offsets.push(off);
                    off += s.size;
                }
            }
        }
        for s in host_specs.iter_mut() {
            if s.trainable && s.component.is_some() {
                s.prev_offset = Some(off);
                off += s.size;
            }
        }
        let state_len = off;

        // --- analytic FLOPs (flops_summary port) ---
        let mut per_component_fwd = std::collections::BTreeMap::new();
        for c in &components {
            per_component_fwd.insert(c.name.clone(), 2.0 * c.n_params as f64);
        }
        let comp_total: f64 = per_component_fwd.values().sum();
        let lang_attn_quad = 4.0 * (train.seq_len * d * model.n_layers) as f64;
        let vis_attn_quad = if is_vlm {
            4.0 * (model.n_patches * model.d_vision * model.n_vision_layers) as f64
        } else {
            0.0
        };
        let head = 2.0 * (d * model.vocab_size) as f64;
        let embed_proj = if is_vlm {
            2.0 * (model.patch_dim * model.d_vision) as f64 + 2.0 * (model.d_vision * d) as f64
        } else {
            0.0
        };
        let fwd_per_token = comp_total + lang_attn_quad + vis_attn_quad + head + embed_proj;

        // spec-index lookups for the hot loops (resolved before the
        // manifest literal takes ownership of `components`)
        let idx_of = |n: &str| host_specs.iter().position(|s| s.name == n).expect("spec");
        let layer_idx = |prefix: &str, l: usize| LayerIdx {
            ln1: idx_of(&format!("{prefix}.{l}.ln1")),
            wq: idx_of(&format!("{prefix}.{l}.attn.q")),
            wk: idx_of(&format!("{prefix}.{l}.attn.k")),
            wv: idx_of(&format!("{prefix}.{l}.attn.v")),
            wo: idx_of(&format!("{prefix}.{l}.attn.o")),
            ln2: idx_of(&format!("{prefix}.{l}.ln2")),
            wg: idx_of(&format!("{prefix}.{l}.mlp.gate")),
            wu: idx_of(&format!("{prefix}.{l}.mlp.up")),
            wd: idx_of(&format!("{prefix}.{l}.mlp.down")),
        };
        let layers: Vec<LayerIdx> = (0..model.n_layers).map(|l| layer_idx("lang", l)).collect();
        let vlm = if is_vlm {
            Some(VlmIdx {
                vis_in: idx_of("vis_in"),
                vis_pos: idx_of("vis_pos"),
                vis_ln_f: idx_of("vis_ln_f"),
                vis_proj: idx_of("vis_proj"),
                layers: (0..model.n_vision_layers).map(|l| layer_idx("vis", l)).collect(),
                dims: VisDims {
                    p: model.n_patches,
                    pd: model.patch_dim,
                    dv: model.d_vision,
                    vh: model.n_vision_heads,
                    vf: model.d_vision_ff,
                    vl: model.n_vision_layers,
                },
            })
        } else {
            None
        };
        let mut wcomp: Vec<Option<usize>> = host_specs.iter().map(|s| s.component).collect();
        let lora = if is_lora {
            let sites: Vec<LoraSite> = components
                .iter()
                .map(|c| {
                    let a = idx_of(&c.tensors[0]);
                    let b = idx_of(&c.tensors[1]);
                    let wname = c.tensors[0].trim_end_matches(".lora_a");
                    let base = idx_of(wname);
                    wcomp[base] = Some(c.idx);
                    LoraSite { base, a, b }
                })
                .collect();
            Some(Lora {
                rank: train.lora_rank,
                scale: (train.lora_alpha / train.lora_rank as f64) as f32,
                sites,
            })
        } else {
            None
        };
        let tok_emb = idx_of("tok_emb");
        let pos_emb = idx_of("pos_emb");
        let ln_f = idx_of("ln_f");
        let lm_head = idx_of("lm_head");

        let params: Vec<ParamInfo> = host_specs
            .iter()
            .map(|s| ParamInfo {
                name: s.name.clone(),
                shape: s.shape.clone(),
                offset: s.offset,
                trainable: s.trainable,
                component: s.component,
            })
            .collect();
        let n_params_total: usize = host_specs.iter().map(|s| s.size).sum();
        let n_params_trainable: usize =
            host_specs.iter().filter(|s| s.trainable).map(|s| s.size).sum();
        let manifest = Manifest {
            name: name.to_string(),
            kind: model.kind.clone(),
            method: train.method.clone(),
            optimizer: train.optimizer.clone(),
            kernel_impl: "host".into(),
            batch_size: train.batch_size,
            seq_len: train.seq_len,
            vocab_size: model.vocab_size,
            n_patches: model.n_patches,
            patch_dim: model.patch_dim,
            state_len,
            metrics_len,
            ctrl_len,
            n_components: n_c,
            gdiff_offset: METRIC_PAD,
            gabs_offset: METRIC_PAD + n_c,
            gvar_offset: gvar.then_some(METRIC_PAD + 2 * n_c),
            ctrl_mask_offset: CTRL_PAD,
            components,
            params,
            n_params_total,
            n_params_trainable,
            flops: FlopsInfo {
                fwd_per_token,
                bwd_dx_per_token: fwd_per_token,
                per_component_fwd,
                attn_quadratic_per_token: lang_attn_quad + vis_attn_quad,
                head_per_token: head,
            },
            executables: std::collections::BTreeMap::new(),
            variants: std::collections::BTreeMap::new(),
        };

        let opt = if train.optimizer == "adamw" {
            Opt::AdamW {
                b1: train.beta1 as f32,
                b2: train.beta2 as f32,
                eps: train.eps as f32,
            }
        } else {
            Opt::Sgd { momentum: train.momentum as f32 }
        };
        Ok(HostBackend {
            tok_emb,
            pos_emb,
            ln_f,
            lm_head,
            layers,
            dims: Dims {
                b: train.batch_size,
                t: train.seq_len,
                d,
                h: model.n_heads,
                hd: d / model.n_heads,
                f: ff,
                l: model.n_layers,
                v: model.vocab_size,
                s: total_seq,
            },
            opt,
            weight_decay: train.weight_decay as f32,
            specs: host_specs,
            manifest,
            wcomp,
            lora,
            vlm,
        })
    }

    /// Hand the synthesized manifest out by value (the scheduler's host
    /// phase builds datasets from it without keeping the engine).
    pub fn into_manifest(self) -> Manifest {
        self.manifest
    }

    fn param<'s>(&self, state: &'s [f32], idx: usize) -> &'s [f32] {
        let s = &self.specs[idx];
        &state[s.offset..s.offset + s.size]
    }

    // -- forward ----------------------------------------------------------

    /// `lora.py merge_lora`: one merged `W + (α/r)·A@B` per component.
    /// Empty for fp layouts (every weight reads straight from state).
    fn merged_weights(&self, state: &[f32]) -> Vec<Buf> {
        let Some(lora) = &self.lora else { return Vec::new() };
        lora.sites
            .iter()
            .map(|site| {
                let base = &self.specs[site.base];
                let (din, dout) = (base.shape[0], base.shape[1]);
                let ab =
                    mm(self.param(state, site.a), self.param(state, site.b), din, lora.rank, dout);
                let w = self.param(state, site.base);
                let mut out = buf_raw(w.len());
                for ((o, &wi), &abi) in out.iter_mut().zip(w.iter()).zip(ab.iter()) {
                    *o = wi + lora.scale * abi;
                }
                out
            })
            .collect()
    }

    /// The weight the forward/backward graph multiplies by for spec
    /// `idx`: the merged adapter form when LoRA owns it, else the raw
    /// parameter slice.
    fn weight<'s>(&self, state: &'s [f32], merged: &'s [Buf], idx: usize) -> &'s [f32] {
        if !merged.is_empty() {
            if let Some(ci) = self.wcomp[idx] {
                return &merged[ci][..];
            }
        }
        self.param(state, idx)
    }

    /// One transformer tower (pre-norm attention + SwiGLU blocks) over
    /// `x: [b·t, d]`. Returns `(xs, layers)` with `xs[i]` = layer `i`'s
    /// input and `xs[n]` the tower output.
    #[allow(clippy::too_many_arguments)]
    fn tower_fwd(
        &self,
        state: &[f32],
        merged: &[Buf],
        layers_idx: &[LayerIdx],
        mut x: Buf,
        b: usize,
        t: usize,
        d: usize,
        h: usize,
        f: usize,
        causal: bool,
    ) -> (Vec<Buf>, Vec<LayerFwd>) {
        let m = b * t;
        let hd = d / h;
        let l = layers_idx.len();
        let mut xs = Vec::with_capacity(l + 1);
        let mut layers = Vec::with_capacity(l);
        for lr in layers_idx {
            let (h1, r1) = rms_norm(&x, self.param(state, lr.ln1), m, d);
            let q = mm(&h1, self.weight(state, merged, lr.wq), m, d, d);
            let k = mm(&h1, self.weight(state, merged, lr.wk), m, d, d);
            let vv = mm(&h1, self.weight(state, merged, lr.wv), m, d, d);
            // fused attention: head-major context + per-row (max, 1/Σ)
            // stats — backward replays the probabilities from these, so
            // no T×T probability matrix is ever stored
            let mut ctx_hm = buf_raw(b * h * t * hd);
            let mut att_stats = buf_raw(b * h * 2 * t);
            let mut scratch = buf_raw(b * h * t);
            kernels::fused_attention_fwd(
                &q, &k, &vv, b, t, h, hd, causal, &mut ctx_hm, &mut att_stats, &mut scratch,
            );
            let mut ctx = buf_raw(m * d);
            kernels::gather_heads(&ctx_hm, b, t, h, hd, &mut ctx);
            let attn_out = mm(&ctx, self.weight(state, merged, lr.wo), m, d, d);
            let mut x_mid = x.clone();
            for i in 0..m * d {
                x_mid[i] += attn_out[i];
            }
            let (h2, r2) = rms_norm(&x_mid, self.param(state, lr.ln2), m, d);
            let gate_pre = mm(&h2, self.weight(state, merged, lr.wg), m, d, f);
            let up = mm(&h2, self.weight(state, merged, lr.wu), m, d, f);
            // SwiGLU with the sigmoid stashed for backward
            let mut sig = buf_raw(m * f);
            let mut act = buf_raw(m * f);
            kernels::swiglu_fwd(&gate_pre, &up, &mut sig, &mut act);
            let mlp_out = mm(&act, self.weight(state, merged, lr.wd), m, f, d);
            let mut x_out = x_mid.clone();
            for i in 0..m * d {
                x_out[i] += mlp_out[i];
            }
            xs.push(std::mem::replace(&mut x, x_out));
            layers.push(LayerFwd {
                h1,
                r1,
                q,
                k,
                v: vv,
                att_stats,
                ctx,
                x_mid,
                h2,
                r2,
                gate_pre,
                up,
                sig,
                act,
            });
        }
        xs.push(x);
        (xs, layers)
    }

    fn forward(&self, state: &[f32], tokens: &[i32], patches: &[f32]) -> Fwd {
        let Dims { b, t, d, h, f, v, .. } = self.dims;
        let merged = self.merged_weights(state);
        let tok = self.param(state, self.tok_emb);
        let pos = self.param(state, self.pos_emb);

        if let Some(vlm) = &self.vlm {
            // model.py vlm_logits: patch embed → non-causal vision tower
            // → final norm → projection → prefix rows before the text
            // embeddings in one causal language stream.
            let VisDims { p, pd, dv, vh, vf, .. } = vlm.dims;
            let mv = b * p;
            let mut xv = mm(patches, self.weight(state, &merged, vlm.vis_in), mv, pd, dv);
            let vpos = self.param(state, vlm.vis_pos);
            for bi in 0..b {
                for pi in 0..p {
                    let row = bi * p + pi;
                    for di in 0..dv {
                        xv[row * dv + di] += vpos[pi * dv + di];
                    }
                }
            }
            let (vxs, vlayers) =
                self.tower_fwd(state, &merged, &vlm.layers, xv, b, p, dv, vh, vf, false);
            let (hv, rv) =
                rms_norm(vxs.last().unwrap(), self.param(state, vlm.vis_ln_f), mv, dv);
            let prefix = mm(&hv, self.weight(state, &merged, vlm.vis_proj), mv, dv, d);

            // concat([prefix, tok_emb[tokens]]) + pos_emb[:p+t] — every
            // row is written below, so the carve can stay raw
            let pt = p + t;
            let mut x = buf_raw(b * pt * d);
            for bi in 0..b {
                for ri in 0..pt {
                    let row = bi * pt + ri;
                    for di in 0..d {
                        let src = if ri < p {
                            prefix[(bi * p + ri) * d + di]
                        } else {
                            let id = tokens[bi * t + (ri - p)] as usize;
                            tok[id * d + di]
                        };
                        x[row * d + di] = src + pos[ri * d + di];
                    }
                }
            }
            let (xs, layers) = self.tower_fwd(state, &merged, &self.layers, x, b, pt, d, h, f, true);
            let (hf, rf) = rms_norm(xs.last().unwrap(), self.param(state, self.ln_f), b * pt, d);
            // logits over the text rows only (every row written → raw)
            let mut hft = buf_raw(b * t * d);
            for bi in 0..b {
                for ti in 0..t {
                    let src = (bi * pt + p + ti) * d;
                    let dst = (bi * t + ti) * d;
                    hft[dst..dst + d].copy_from_slice(&hf[src..src + d]);
                }
            }
            let logits = mm(&hft, self.weight(state, &merged, self.lm_head), b * t, d, v);
            return Fwd {
                xs,
                layers,
                hf,
                rf,
                hft: Some(hft),
                logits,
                vis: Some(VisFwd { xs: vxs, layers: vlayers, hv, rv }),
                merged,
            };
        }

        let m = b * t;
        let mut x = buf_raw(m * d);
        for bi in 0..b {
            for ti in 0..t {
                let row = bi * t + ti;
                let id = tokens[row] as usize;
                for di in 0..d {
                    x[row * d + di] = tok[id * d + di] + pos[ti * d + di];
                }
            }
        }
        let (xs, layers) = self.tower_fwd(state, &merged, &self.layers, x, b, t, d, h, f, true);
        let (hf, rf) = rms_norm(xs.last().unwrap(), self.param(state, self.ln_f), m, d);
        let logits = mm(&hf, self.weight(state, &merged, self.lm_head), m, d, v);
        Fwd { xs, layers, hf, rf, hft: None, logits, vis: None, merged }
    }

    /// `(loss_sum, count)` over one batch, the `eval_step` reduction.
    fn loss_of(&self, logits: &[f32], targets: &[i32]) -> (f32, f32) {
        let v = self.dims.v;
        let mut loss = 0f64;
        let mut count = 0usize;
        for (row, &tgt) in targets.iter().enumerate() {
            if tgt < 0 {
                continue;
            }
            let lrow = &logits[row * v..(row + 1) * v];
            loss += nll(lrow, tgt as usize);
            count += 1;
        }
        (loss as f32, count as f32)
    }

    // -- backward ---------------------------------------------------------

    /// d(mean loss)/d(logits), plus the loss reduction itself.
    ///
    /// The `log_sum_exp` and probability passes are fused: one exp
    /// traversal per row feeds both the loss (`max + ln Σe`) and the
    /// softmax (`e / Σe`) — half the `exp` calls of the two-pass form.
    /// The loss value is bit-identical to `nll`'s (same max, same
    /// ascending summation), which `eval_step_matches_probe_loss…`
    /// pins.
    fn loss_grad(&self, logits: &[f32], targets: &[i32]) -> (f32, f32, Buf) {
        let v = self.dims.v;
        let m = targets.len();
        let count = targets.iter().filter(|&&t| t >= 0).count() as f32;
        let denom = count.max(1.0) as f64;
        // masked rows never get written, so the carve must be zeroed
        let mut dlogits = buf_zeroed(m * v);
        let mut loss = 0f64;
        let mut exps = vec![0f64; v];
        for (row, &tgt) in targets.iter().enumerate() {
            if tgt < 0 {
                continue;
            }
            let lrow = &logits[row * v..(row + 1) * v];
            let max = lrow.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
            let mut sum = 0f64;
            for (e, &lv) in exps.iter_mut().zip(lrow.iter()) {
                *e = (lv as f64 - max).exp();
                sum += *e;
            }
            loss += max + sum.ln() - lrow[tgt as usize] as f64;
            let inv = 1.0 / sum;
            let drow = &mut dlogits[row * v..(row + 1) * v];
            for (vi, (&e, dv)) in exps.iter().zip(drow.iter_mut()).enumerate() {
                let ind = if vi == tgt as usize { 1.0 } else { 0.0 };
                *dv = ((e * inv - ind) / denom) as f32;
            }
        }
        (loss as f32, count, dlogits)
    }

    /// Partition the spec list into up to `threads` contiguous runs of
    /// roughly equal parameter count (greedy fill to `⌈total/threads⌉`).
    /// Whole-spec granularity keeps every per-element loop identical to
    /// the serial order, so the partition never changes bits.
    fn spec_chunks(&self, threads: usize) -> Vec<std::ops::Range<usize>> {
        let total: usize = self.specs.iter().map(|sp| sp.size).sum();
        let target = total.div_ceil(threads.max(1)).max(1);
        let mut out = Vec::new();
        let mut begin = 0usize;
        let mut acc = 0usize;
        for (i, spec) in self.specs.iter().enumerate() {
            acc += spec.size;
            if acc >= target {
                out.push(begin..i + 1);
                begin = i + 1;
                acc = 0;
            }
        }
        if begin < self.specs.len() {
            out.push(begin..self.specs.len());
        }
        out
    }

    /// The masked optimizer update + Eq. 1 statistics for every spec with
    /// a gradient, fanned out over up to `threads` scoped workers. `ns`
    /// starts as a copy of `s`; each worker owns one contiguous run of
    /// specs and writes its disjoint windows of every state region.
    /// Returns `(gnorm, gdiff, gabs, gvar)` folded in spec order on the
    /// calling thread — bitwise identical for every thread count (`gvar`
    /// is all-zero unless the layout carries a gvar block).
    #[allow(clippy::too_many_arguments)]
    fn apply_updates(
        &self,
        threads: usize,
        ns: &mut [f32],
        s: &[f32],
        grads: &[Option<Buf>],
        mask: &[f32],
        t_step: f32,
        lr: f32,
        wd: f32,
    ) -> (f64, Vec<f32>, Vec<f32>, Vec<f32>) {
        let n_c = self.manifest.n_components;
        let chunks = self.spec_chunks(threads);
        let nch = chunks.len();
        let n_slots = match self.opt {
            Opt::AdamW { .. } => 2,
            Opt::Sgd { .. } => 1,
        };

        // Window geometry per chunk. Each state region ([params | opt
        // slot(s) | prev]) is laid out in spec order, so a contiguous
        // spec run owns one contiguous window per region. Opt slots only
        // cover *trainable* specs (LoRA base weights have none), so the
        // slot windows get their own start/len — and because every slot
        // repeats the same trainable layout, one slot-relative
        // coordinate indexes both `m` and `v`.
        struct Geom {
            p0: usize,
            plen: usize,
            o0: usize,
            olen: usize,
            prev0: usize,
            prevlen: usize,
        }
        let geom: Vec<Geom> = chunks
            .iter()
            .map(|r| {
                let first = &self.specs[r.start];
                let last = &self.specs[r.end - 1];
                let p0 = first.offset;
                let plen = last.offset + last.size - p0;
                let mut o0 = 0usize;
                let mut olen = 0usize;
                let mut prev0 = 0usize;
                let mut prevlen = 0usize;
                for sp in &self.specs[r.start..r.end] {
                    if let Some(&oo) = sp.opt_offsets.first() {
                        if olen == 0 {
                            o0 = oo;
                        }
                        olen = oo + sp.size - o0;
                    }
                    if let Some(po) = sp.prev_offset {
                        if prevlen == 0 {
                            prev0 = po;
                        }
                        prevlen = po + sp.size - prev0;
                    }
                }
                Geom { p0, plen, o0, olen, prev0, prevlen }
            })
            .collect();
        let slot_stride = self.specs.iter().map(|sp| if sp.trainable { sp.size } else { 0 }).sum::<usize>();
        let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(nch * (2 + n_slots));
        for g in &geom {
            ranges.push((g.p0, g.plen));
        }
        for slot in 0..n_slots {
            for g in &geom {
                ranges.push((g.o0 + slot * slot_stride, g.olen));
            }
        }
        for g in &geom {
            ranges.push((g.prev0, g.prevlen));
        }

        // Carve `ns` into those disjoint windows (ascending order: the
        // regions themselves are ordered, and chunks are ordered within
        // each region), then regroup them per chunk.
        let mut wins = carve(ns, &ranges);
        let prev_w = wins.split_off(wins.len() - nch);
        let v_w: Vec<Option<&mut [f32]>> = if n_slots == 2 {
            wins.split_off(wins.len() - nch).into_iter().map(Some).collect()
        } else {
            (0..nch).map(|_| None).collect()
        };
        let m_w = wins.split_off(wins.len() - nch);
        let params_w = wins;

        let mut outs: Vec<ChunkOut<'_>> = Vec::with_capacity(nch);
        for (i, (((pw, mw), vw), prw)) in params_w
            .into_iter()
            .zip(m_w)
            .zip(v_w)
            .zip(prev_w)
            .enumerate()
        {
            outs.push(ChunkOut {
                specs: chunks[i].clone(),
                p0: geom[i].p0,
                o0: geom[i].o0,
                prev0: geom[i].prev0,
                params: pw,
                m: mw,
                v: vw,
                prev: prw,
            });
        }

        let stats: Vec<Vec<(usize, SpecStats)>> = if outs.len() <= 1 {
            outs.into_iter()
                .map(|mut o| self.update_chunk(&mut o, s, grads, mask, t_step, lr, wd))
                .collect()
        } else {
            std::thread::scope(|scope| {
                let handles: Vec<_> = outs
                    .into_iter()
                    .map(|mut o| {
                        scope.spawn(move || {
                            self.update_chunk(&mut o, s, grads, mask, t_step, lr, wd)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            })
        };

        // Fold in spec order on one thread: the reduction order (and so
        // every metric bit) is independent of the partition.
        let mut gnorm = 0f64;
        let mut gdiff = vec![0f32; n_c];
        let mut gabs = vec![0f32; n_c];
        let mut gvar = vec![0f32; n_c];
        for (idx, st) in stats.into_iter().flatten() {
            let spec = &self.specs[idx];
            gnorm += st.gnorm;
            if let (Some(_), Some(ci)) = (spec.prev_offset, spec.component) {
                gdiff[ci] += st.dsum as f32;
                gabs[ci] += st.gnorm as f32;
                gvar[ci] += st.vsum as f32;
            }
        }
        (gnorm, gdiff, gabs, gvar)
    }

    /// One worker's share of [`Self::apply_updates`]: the same
    /// per-element f32 arithmetic as the old serial loop, writing through
    /// the chunk's windows, with the Σ|g| and Σ|g−prev| reductions on the
    /// lane-split kernels.
    #[allow(clippy::too_many_arguments)]
    fn update_chunk(
        &self,
        out: &mut ChunkOut<'_>,
        s: &[f32],
        grads: &[Option<Buf>],
        mask: &[f32],
        t_step: f32,
        lr: f32,
        wd: f32,
    ) -> Vec<(usize, SpecStats)> {
        let mut stats = Vec::new();
        for idx in out.specs.clone() {
            let spec = &self.specs[idx];
            let Some(g) = &grads[idx] else { continue };
            debug_assert!(spec.trainable, "gradient for an untrainable spec");
            let mval = spec.component.map_or(1.0, |ci| mask[ci]);
            let lo = spec.offset - out.p0;
            let olo = spec.opt_offsets[0] - out.o0;
            let mut st = SpecStats { gnorm: kernels::abs_sum8(g), dsum: 0.0, vsum: 0.0 };
            // Eq. 1 statistics + prev-grad carry (frozen components keep
            // their stale prev, exactly like the compiled graph)
            if let Some(poff) = spec.prev_offset {
                let prev = &s[poff..poff + spec.size];
                st.dsum = kernels::abs_diff_sum8(g, prev);
                if self.manifest.gvar_offset.is_some() {
                    const EPS: f64 = 1e-12;
                    let mut v = 0f64;
                    for (&gi, &pi) in g.iter().zip(prev.iter()) {
                        let (gi, di) = (gi as f64, (gi - pi) as f64);
                        v += gi * gi / (0.5 * di * di + EPS);
                    }
                    st.vsum = v;
                }
                let plo = poff - out.prev0;
                let nprev = &mut out.prev[plo..plo + spec.size];
                for (i, (&gi, &pi)) in g.iter().zip(prev.iter()).enumerate() {
                    nprev[i] = mval * gi + (1.0 - mval) * pi;
                }
            }
            // freeze-masked optimizer update (kernels/ref.py semantics:
            // frozen tensors keep p/m/v bit-identical)
            match &self.opt {
                Opt::AdamW { b1, b2, eps } => {
                    let bc1 = 1.0 - b1.powf(t_step);
                    let bc2 = 1.0 - b2.powf(t_step);
                    let moff = spec.opt_offsets[0];
                    let voff = spec.opt_offsets[1];
                    let vwin = out.v.as_deref_mut().expect("AdamW layout carries slot 1");
                    for i in 0..spec.size {
                        let p = s[spec.offset + i];
                        let gi = g[i];
                        let m0 = s[moff + i];
                        let v0 = s[voff + i];
                        let mn = b1 * m0 + (1.0 - b1) * gi;
                        let vn = b2 * v0 + (1.0 - b2) * gi * gi;
                        let m_hat = mn / bc1;
                        let v_hat = vn / bc2;
                        let pn = p - lr * (m_hat / (v_hat.sqrt() + eps) + wd * p);
                        out.params[lo + i] = mval * pn + (1.0 - mval) * p;
                        out.m[olo + i] = mval * mn + (1.0 - mval) * m0;
                        vwin[olo + i] = mval * vn + (1.0 - mval) * v0;
                    }
                }
                Opt::Sgd { momentum } => {
                    let momoff = spec.opt_offsets[0];
                    for i in 0..spec.size {
                        let p = s[spec.offset + i];
                        let gi = g[i];
                        let mom0 = s[momoff + i];
                        let momn = momentum * mom0 + gi;
                        let pn = p - lr * (momn + wd * p);
                        out.params[lo + i] = mval * pn + (1.0 - mval) * p;
                        out.m[olo + i] = mval * momn + (1.0 - mval) * mom0;
                    }
                }
            }
            stats.push((idx, st));
        }
        stats
    }

    /// dW for the matrix weight at spec `widx` given its input
    /// `x: [m, din]` and output gradient `dy: [m, dout]`. Under fp the
    /// gradient lands on the weight itself; under LoRA it lands on the
    /// site's A/B adapters (`d(x·(W + s·A·B))`: dA = s·xᵀ(dy·Bᵀ),
    /// dB = s·(x·A)ᵀ·dy — the r-sized intermediate order, never forming
    /// a d_in×d_out product) and the base weight stays `None`. Omitted
    /// components and untrainable weights get nothing.
    #[allow(clippy::too_many_arguments)]
    fn dw_site(
        &self,
        state: &[f32],
        grads: &mut [Option<Buf>],
        plan: &StepPlan,
        widx: usize,
        x: &[f32],
        dy: &[f32],
        m: usize,
        din: usize,
        dout: usize,
    ) {
        if let Some(lora) = &self.lora {
            let Some(ci) = self.wcomp[widx] else { return };
            if plan.omits(ci) {
                return;
            }
            let site = &lora.sites[ci];
            let (r, sc) = (lora.rank, lora.scale);
            let tmp = mm_nt(dy, self.param(state, site.b), m, dout, r);
            let mut da = mm_tn(x, &tmp, m, din, r);
            for g in da.iter_mut() {
                *g *= sc;
            }
            let xa = mm(x, self.param(state, site.a), m, din, r);
            let mut db = mm_tn(&xa, dy, m, r, dout);
            for g in db.iter_mut() {
                *g *= sc;
            }
            grads[site.a] = Some(da);
            grads[site.b] = Some(db);
            return;
        }
        let spec = &self.specs[widx];
        if !spec.trainable || spec.component.map_or(false, |c| plan.omits(c)) {
            return;
        }
        grads[widx] = Some(mm_tn(x, dy, m, din, dout));
    }

    /// One tower's backward sweep (layers `trunc..` in reverse), writing
    /// per-site gradients via [`Self::dw_site`] and returning the
    /// gradient at the tower input.
    #[allow(clippy::too_many_arguments)]
    fn tower_bwd(
        &self,
        state: &[f32],
        merged: &[Buf],
        layers_idx: &[LayerIdx],
        xs: &[Buf],
        lfs: &[LayerFwd],
        mut dx: Buf,
        grads: &mut [Option<Buf>],
        plan: &StepPlan,
        trunc: usize,
        b: usize,
        t: usize,
        d: usize,
        h: usize,
        f: usize,
        causal: bool,
    ) -> Buf {
        let m = b * t;
        let hd = d / h;
        for li in (trunc..layers_idx.len()).rev() {
            let lr = &layers_idx[li];
            let lf = &lfs[li];
            // SwiGLU MLP: x_out = x_mid + (silu(h2·Wg) ⊙ (h2·Wu))·Wd,
            // with σ(gate_pre) read back from the forward's stash
            self.dw_site(state, grads, plan, lr.wd, &lf.act, &dx, m, f, d);
            let d_act = mm_nt(&dx, self.weight(state, merged, lr.wd), m, d, f);
            let mut d_gp = buf_raw(m * f);
            let mut d_up = buf_raw(m * f);
            kernels::swiglu_bwd(&d_act, &lf.gate_pre, &lf.up, &lf.sig, &mut d_gp, &mut d_up);
            self.dw_site(state, grads, plan, lr.wg, &lf.h2, &d_gp, m, d, f);
            self.dw_site(state, grads, plan, lr.wu, &lf.h2, &d_up, m, d, f);
            let mut dh2 = mm_nt(&d_gp, self.weight(state, merged, lr.wg), m, f, d);
            let dh2b = mm_nt(&d_up, self.weight(state, merged, lr.wu), m, f, d);
            for i in 0..m * d {
                dh2[i] += dh2b[i];
            }
            let (g_ln2, dxm_norm) =
                rms_backward(&lf.x_mid, &lf.r2, self.param(state, lr.ln2), &dh2, m, d);
            if self.specs[lr.ln2].trainable {
                grads[lr.ln2] = Some(g_ln2);
            }
            let mut dx_mid = dx; // residual branch
            for i in 0..m * d {
                dx_mid[i] += dxm_norm[i];
            }

            // attention: x_mid = x_in + (softmax(qkᵀ/√hd)·v)·Wo; the
            // fused backward replays the probabilities from the stashed
            // per-row stats and accumulates head-major (zeroed carves)
            self.dw_site(state, grads, plan, lr.wo, &lf.ctx, &dx_mid, m, d, d);
            let dctx = mm_nt(&dx_mid, self.weight(state, merged, lr.wo), m, d, d);
            let mut dq_hm = buf_zeroed(b * h * t * hd);
            let mut dk_hm = buf_zeroed(b * h * t * hd);
            let mut dv_hm = buf_zeroed(b * h * t * hd);
            let mut scratch = buf_raw(b * h * 2 * t);
            kernels::fused_attention_bwd(
                &lf.q,
                &lf.k,
                &lf.v,
                &lf.att_stats,
                &dctx,
                b,
                t,
                h,
                hd,
                causal,
                &mut dq_hm,
                &mut dk_hm,
                &mut dv_hm,
                &mut scratch,
            );
            let mut dq = buf_raw(m * d);
            let mut dk = buf_raw(m * d);
            let mut dv = buf_raw(m * d);
            kernels::gather_heads(&dq_hm, b, t, h, hd, &mut dq);
            kernels::gather_heads(&dk_hm, b, t, h, hd, &mut dk);
            kernels::gather_heads(&dv_hm, b, t, h, hd, &mut dv);
            self.dw_site(state, grads, plan, lr.wq, &lf.h1, &dq, m, d, d);
            self.dw_site(state, grads, plan, lr.wk, &lf.h1, &dk, m, d, d);
            self.dw_site(state, grads, plan, lr.wv, &lf.h1, &dv, m, d, d);
            let mut dh1 = mm_nt(&dq, self.weight(state, merged, lr.wq), m, d, d);
            let dh1b = mm_nt(&dk, self.weight(state, merged, lr.wk), m, d, d);
            let dh1c = mm_nt(&dv, self.weight(state, merged, lr.wv), m, d, d);
            for i in 0..m * d {
                dh1[i] += dh1b[i] + dh1c[i];
            }
            let (g_ln1, dxin_norm) =
                rms_backward(&xs[li], &lf.r1, self.param(state, lr.ln1), &dh1, m, d);
            if self.specs[lr.ln1].trainable {
                grads[lr.ln1] = Some(g_ln1);
            }
            for i in 0..m * d {
                dx_mid[i] += dxin_norm[i];
            }
            dx = dx_mid;
        }
        dx
    }

    /// Full backward pass. Returns per-spec gradients of the *mean* loss.
    /// The plan's omitted components skip their dW matmul (their entry
    /// stays `None`; gradients still flow *through* the weights, as with
    /// `stop_gradient`). When the plan grants truncation, a fully
    /// omitted layer *prefix* additionally truncates the sweep: its norm
    /// scales and the embeddings get no gradient (the AutoFreeze-style
    /// whole-layer rule — see the module docs). A VLM truncates only if
    /// the *whole vision tower* is also omitted: the vision gradients
    /// enter through the language tower's prefix rows, so any live
    /// vision component needs the sweep to reach the bottom.
    fn backward(
        &self,
        state: &[f32],
        fwd: &Fwd,
        dlogits: Buf,
        tokens: &[i32],
        patches: &[f32],
        plan: &StepPlan,
    ) -> Vec<Option<Buf>> {
        let Dims { b, t, d, h, f, l, v, s, .. } = self.dims;
        let merged = &fwd.merged;
        let mut grads: Vec<Option<Buf>> = (0..self.specs.len()).map(|_| None).collect();
        let omits = |spec_idx: usize| self.wcomp[spec_idx].map_or(false, |c| plan.omits(c));
        let all_omitted = |lr: &LayerIdx| {
            [lr.wq, lr.wk, lr.wv, lr.wo, lr.wg, lr.wu, lr.wd].iter().all(|&ix| omits(ix))
        };
        // Sweep truncation (opt-in capability on the plan): layers
        // 0..trunc have all seven components omitted, so no *component*
        // below layer `trunc` needs a gradient and the sweep stops above
        // them — holding their norm scales and the embeddings (and, for
        // a VLM, the vision tower) for the step, the documented rider
        // semantics.
        let trunc = if plan.truncates()
            && self.vlm.as_ref().map_or(true, |vlm| vlm.layers.iter().all(&all_omitted))
        {
            self.layers.iter().take_while(|lr| all_omitted(lr)).count()
        } else {
            0
        };

        // head + final norm (VLM: logits cover the text rows only; the
        // prefix rows reach ln_f with zero gradient from the head)
        let p = self.vlm.as_ref().map_or(0, |vlm| vlm.dims.p);
        let pt = p + t;
        let hft = fwd.hft.as_deref().unwrap_or(&fwd.hf);
        self.dw_site(state, &mut grads, plan, self.lm_head, hft, &dlogits, b * t, d, v);
        let dhft = mm_nt(&dlogits, self.weight(state, merged, self.lm_head), b * t, v, d);
        let dhf = if p > 0 {
            // only the text rows are written; prefix rows must read zero
            let mut full = buf_zeroed(b * pt * d);
            for bi in 0..b {
                for ti in 0..t {
                    let src = (bi * t + ti) * d;
                    let dst = (bi * pt + p + ti) * d;
                    full[dst..dst + d].copy_from_slice(&dhft[src..src + d]);
                }
            }
            full
        } else {
            dhft
        };
        let (g_lnf, dx) =
            rms_backward(&fwd.xs[l], &fwd.rf, self.param(state, self.ln_f), &dhf, b * pt, d);
        if self.specs[self.ln_f].trainable {
            grads[self.ln_f] = Some(g_lnf);
        }

        let dx = self.tower_bwd(
            state, merged, &self.layers, &fwd.xs, &fwd.layers, dx, &mut grads, plan, trunc, b,
            pt, d, h, f, true,
        );
        if trunc > 0 {
            return grads;
        }

        // embeddings (rows past the batch's sequence in pos_emb get zero
        // gradient; the optimizer still visits them — weight decay
        // applies, as on XLA). Under LoRA they are frozen base weights.
        if self.specs[self.tok_emb].trainable {
            let mut g_tok = buf_zeroed(self.specs[self.tok_emb].size);
            let mut g_pos = buf_zeroed(self.specs[self.pos_emb].size);
            debug_assert_eq!(g_pos.len(), s * d);
            for bi in 0..b {
                for ri in 0..pt {
                    let row = bi * pt + ri;
                    for di in 0..d {
                        let g = dx[row * d + di];
                        if ri >= p {
                            let id = tokens[bi * t + (ri - p)] as usize;
                            g_tok[id * d + di] += g;
                        }
                        g_pos[ri * d + di] += g;
                    }
                }
            }
            grads[self.tok_emb] = Some(g_tok);
            grads[self.pos_emb] = Some(g_pos);
        }

        // vision chain: prefix-row gradients → projection → final norm
        // → tower → patch embed (model.py vlm_logits, reversed)
        if let Some(vlm) = &self.vlm {
            let vis = fwd.vis.as_ref().expect("vlm forward cache");
            let VisDims { p, pd, dv, vh, vf, vl } = vlm.dims;
            let mv = b * p;
            // every prefix row is copied below → raw carve
            let mut dprefix = buf_raw(mv * d);
            for bi in 0..b {
                for pi in 0..p {
                    let src = (bi * pt + pi) * d;
                    let dst = (bi * p + pi) * d;
                    dprefix[dst..dst + d].copy_from_slice(&dx[src..src + d]);
                }
            }
            self.dw_site(state, &mut grads, plan, vlm.vis_proj, &vis.hv, &dprefix, mv, dv, d);
            let dhv = mm_nt(&dprefix, self.weight(state, merged, vlm.vis_proj), mv, d, dv);
            let (g_vlnf, dxv) =
                rms_backward(&vis.xs[vl], &vis.rv, self.param(state, vlm.vis_ln_f), &dhv, mv, dv);
            if self.specs[vlm.vis_ln_f].trainable {
                grads[vlm.vis_ln_f] = Some(g_vlnf);
            }
            let dxv = self.tower_bwd(
                state, merged, &vlm.layers, &vis.xs, &vis.layers, dxv, &mut grads, plan, 0, b, p,
                dv, vh, vf, false,
            );
            self.dw_site(state, &mut grads, plan, vlm.vis_in, patches, &dxv, mv, pd, dv);
            if self.specs[vlm.vis_pos].trainable {
                let mut g_vpos = buf_zeroed(self.specs[vlm.vis_pos].size);
                for bi in 0..b {
                    for pi in 0..p {
                        let row = bi * p + pi;
                        for di in 0..dv {
                            g_vpos[pi * dv + di] += dxv[row * dv + di];
                        }
                    }
                }
                grads[vlm.vis_pos] = Some(g_vpos);
            }
        }
        grads
    }
}

/// One layer's cached forward activations (what backward consumes).
/// Every buffer is an arena carve; instead of the old `[B,H,T,T]`
/// probability matrix, `att_stats` stores two floats per query row —
/// the softmax `(max, 1/Σ)` the fused backward replays from.
struct LayerFwd {
    h1: Buf,
    r1: Buf,
    q: Buf,
    k: Buf,
    v: Buf,
    att_stats: Buf,
    ctx: Buf,
    x_mid: Buf,
    h2: Buf,
    r2: Buf,
    gate_pre: Buf,
    up: Buf,
    /// σ(gate_pre), stashed so backward never recomputes the sigmoid.
    sig: Buf,
    act: Buf,
}

/// Whole-network forward cache. `xs[l]` is language layer `l`'s input;
/// `xs[L]` the final residual stream (over `P+T` rows for a VLM).
struct Fwd {
    xs: Vec<Buf>,
    layers: Vec<LayerFwd>,
    hf: Buf,
    rf: Buf,
    /// VLM only: the text rows of `hf`, regathered to `[B·T, D]` — the
    /// head's actual input.
    hft: Option<Buf>,
    logits: Buf,
    /// VLM only: the vision tower's forward cache.
    vis: Option<VisFwd>,
    /// LoRA only: per-component merged `W + (α/r)·A·B` (else empty).
    merged: Vec<Buf>,
}

/// The vision tower's forward cache (`xs`/`layers` as in [`Fwd`], plus
/// the post-norm activations feeding the projection).
struct VisFwd {
    xs: Vec<Buf>,
    layers: Vec<LayerFwd>,
    hv: Buf,
    rv: Buf,
}

// ---------------------------------------------------------------------------
// Threaded optimizer/stats plumbing
// ---------------------------------------------------------------------------

/// Per-spec statistics produced by one update worker. `gnorm` doubles as
/// the component's Eq. 1 `gabs` contribution — the serial loop computed
/// both with the same Σ|g| reduction.
struct SpecStats {
    /// Σ|g| over the spec (lane-split order).
    gnorm: f64,
    /// Σ|g − prev| over the spec (monitored specs only; 0 otherwise).
    dsum: f64,
    /// EB-criterion statistic Σ g²/(½(g−prev)² + ε) — the per-parameter
    /// signal-to-variance ratio with ½(g−prev)² as the step-local
    /// batch-variance proxy. Computed only when the layout carries a
    /// gvar block; 0 otherwise.
    vsum: f64,
}

/// One update worker's write windows into the next state: a contiguous
/// run of specs plus a mutable window into each state region. The opt
/// slots repeat one trainable-spec layout, so a single slot-relative
/// coordinate (`spec.opt_offsets[0] - o0`) indexes `m` and `v` alike;
/// `params` uses `spec.offset - p0` and `prev` its own `poff - prev0`.
struct ChunkOut<'a> {
    /// Spec indices this worker owns.
    specs: std::ops::Range<usize>,
    /// Absolute state offset of `params[0]`.
    p0: usize,
    /// Absolute state offset of `m[0]` (meaningless when `m` is empty).
    o0: usize,
    /// Absolute state offset of `prev[0]` (meaningless when `prev` is empty).
    prev0: usize,
    params: &'a mut [f32],
    /// Optimizer slot 0: AdamW first moment / SGD momentum.
    m: &'a mut [f32],
    /// Optimizer slot 1: AdamW second moment (`None` under SGD).
    v: Option<&'a mut [f32]>,
    /// Eq. 1 prev-grad carry window (empty when no spec is monitored).
    prev: &'a mut [f32],
}

/// Split `buf` into the given `(start, len)` windows — absolute offsets,
/// ascending and disjoint among the non-empty ones. Zero-length entries
/// yield empty slices (and their `start` is ignored).
fn carve<'a>(buf: &'a mut [f32], ranges: &[(usize, usize)]) -> Vec<&'a mut [f32]> {
    let mut out: Vec<&'a mut [f32]> = Vec::with_capacity(ranges.len());
    let mut rest = buf;
    let mut pos = 0usize;
    for &(start, len) in ranges {
        if len == 0 {
            out.push(Default::default());
            continue;
        }
        let tail = std::mem::take(&mut rest);
        let (_, tail) = tail.split_at_mut(start - pos);
        let (win, tail) = tail.split_at_mut(len);
        out.push(win);
        rest = tail;
        pos = start + len;
    }
    out
}

// ---------------------------------------------------------------------------
// Math helpers (f32 storage, f64 accumulation)
// ---------------------------------------------------------------------------
// The matmuls, fused attention, SwiGLU elementwise kernels, thread-pool
// plumbing and L1 reductions live in `host_kernels`; what stays here is
// the transformer-shaped glue. The `mm*` wrappers below are the
// `matmul*` entry points with every pack buffer and output carved from
// the workspace arena instead of freshly allocated.

/// `a[m,k] @ b[k,n]`, arena-carved (see [`kernels::matmul`]).
fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Buf {
    let level = kernels::simd_level();
    let threads = kernels::threads_for(m * k * n);
    let mut bt = buf_raw(k * n);
    kernels::transpose_into(b, k, n, &mut bt);
    let mut out = buf_raw(m * n);
    kernels::gemm_into(level, threads, a, &bt, m, n, k, &mut out);
    out
}

/// `aᵀ[k,m] @ b[m,n]` for `a: [m,k]` — weight gradients, arena-carved
/// (see [`kernels::matmul_tn`]).
fn mm_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Buf {
    let level = kernels::simd_level();
    let threads = kernels::threads_for(m * k * n);
    let mut at = buf_raw(m * k);
    kernels::transpose_into(a, m, k, &mut at);
    let mut bt = buf_raw(m * n);
    kernels::transpose_into(b, m, n, &mut bt);
    let mut out = buf_raw(k * n);
    kernels::gemm_into(level, threads, &at, &bt, k, n, m, &mut out);
    out
}

/// `a[m,n] @ bᵀ[n,k]` for `b: [k,n]` — input gradients, arena-carved
/// (see [`kernels::matmul_nt`]; no packing at all).
fn mm_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Buf {
    let level = kernels::simd_level();
    let threads = kernels::threads_for(m * n * k);
    let mut out = buf_raw(m * k);
    kernels::gemm_into(level, threads, a, b, m, k, n, &mut out);
    out
}

fn log_sum_exp(row: &[f32]) -> f64 {
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let sum: f64 = row.iter().map(|&x| (x as f64 - max).exp()).sum();
    max + sum.ln()
}

fn nll(row: &[f32], target: usize) -> f64 {
    log_sum_exp(row) - row[target] as f64
}

/// Pre-RMSNorm: `y = x · rsqrt(mean(x²) + 1e-6) · scale`. Returns the
/// normalized rows and the per-row rsqrt (cached for backward), both
/// carved from the arena (every element is written below).
fn rms_norm(x: &[f32], scale: &[f32], m: usize, d: usize) -> (Buf, Buf) {
    let mut y = buf_raw(m * d);
    let mut r = buf_raw(m);
    for i in 0..m {
        let row = &x[i * d..(i + 1) * d];
        let ms: f64 = kernels::dot8(row, row) / d as f64;
        let ri = (1.0 / (ms + 1e-6).sqrt()) as f32;
        r[i] = ri;
        let yrow = &mut y[i * d..(i + 1) * d];
        for ((yo, &xv), &sv) in yrow.iter_mut().zip(row.iter()).zip(scale.iter()) {
            *yo = xv * ri * sv;
        }
    }
    (y, r)
}

/// RMSNorm backward: `(dscale, dx)` for upstream `dy`. The f64 dscale
/// accumulator is a (small) fresh vector; the f32 outputs are carved.
fn rms_backward(
    x: &[f32],
    r: &[f32],
    scale: &[f32],
    dy: &[f32],
    m: usize,
    d: usize,
) -> (Buf, Buf) {
    let mut dscale = vec![0f64; d];
    let mut dx = buf_raw(m * d);
    for i in 0..m {
        let xrow = &x[i * d..(i + 1) * d];
        let dyrow = &dy[i * d..(i + 1) * d];
        let ri = r[i] as f64;
        let dot = kernels::dot3_8(dyrow, scale, xrow); // Σ dy·scale·x
        for di in 0..d {
            dscale[di] += dyrow[di] as f64 * xrow[di] as f64 * ri;
        }
        let c = ri * ri * ri * dot / d as f64;
        let dxrow = &mut dx[i * d..(i + 1) * d];
        for di in 0..d {
            dxrow[di] = (ri * scale[di] as f64 * dyrow[di] as f64 - c * xrow[di] as f64) as f32;
        }
    }
    let mut ds = buf_raw(d);
    for (o, &v) in ds.iter_mut().zip(dscale.iter()) {
        *o = v as f32;
    }
    (ds, dx)
}

// ---------------------------------------------------------------------------
// Backend impl
// ---------------------------------------------------------------------------

impl Backend for HostBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn name(&self) -> &'static str {
        "host"
    }

    fn init_state(&self, seed: i32) -> Result<BackendState> {
        // One fused noise stream in spec order — the same protocol as the
        // compiled init (steps.py), over the repo's deterministic RNG
        // instead of JAX threefry. Draws are consumed even for ones/zeros
        // specs so layout changes never silently shift downstream draws.
        let mut rng = Rng::new(seed as i64 as u64);
        let mut state = vec![0f32; self.manifest.state_len];
        for spec in &self.specs {
            let out = &mut state[spec.offset..spec.offset + spec.size];
            match spec.init {
                Init::Embed | Init::Head => {
                    for o in out.iter_mut() {
                        *o = 0.02 * rng.gauss() as f32;
                    }
                }
                Init::Matrix => {
                    let scale = 1.0 / (spec.shape[0] as f32).sqrt();
                    for o in out.iter_mut() {
                        *o = rng.gauss() as f32 * scale;
                    }
                }
                Init::Ones => {
                    for _ in 0..spec.size {
                        rng.gauss();
                    }
                    out.fill(1.0);
                }
                Init::LoraA => {
                    for o in out.iter_mut() {
                        *o = 0.05 * rng.gauss() as f32;
                    }
                }
                Init::LoraB => {
                    for _ in 0..spec.size {
                        rng.gauss();
                    }
                    out.fill(0.0);
                }
            }
        }
        Ok(BackendState::new(Buf::from_vec(state)))
    }

    fn upload_batch(&self, batch: &Batch) -> Result<UploadedBatch> {
        let v = self.dims.v as i32;
        for &tok in &batch.tokens {
            ensure!((0..v).contains(&tok), "token id {tok} outside vocab 0..{v}");
        }
        for &tgt in &batch.targets {
            ensure!(tgt < v, "target id {tgt} outside vocab 0..{v} (use < 0 for masked)");
        }
        if let Some(vlm) = &self.vlm {
            let want = self.dims.b * vlm.dims.p * vlm.dims.pd;
            ensure!(
                batch.patches.len() == want,
                "vlm batch carries {} patch floats, layout wants {want} (B·P·patch_dim)",
                batch.patches.len()
            );
        }
        let bytes = batch.nbytes();
        Ok(UploadedBatch::new(batch.clone(), bytes))
    }

    fn upload_ctrl(&self, ctrl: &[f32]) -> Result<CtrlBuf> {
        // The host backend reads `CtrlBuf::host` directly — no second copy.
        Ok(CtrlBuf::new(ctrl.to_vec(), ()))
    }

    fn lower_plan(&self, plan: &StepPlan) -> StepPlan {
        // the host engine executes any sound plan exactly
        plan.clone()
    }

    fn train_step(
        &self,
        state: &BackendState,
        io: &UploadedBatch,
        ctrl: &CtrlBuf,
        plan: &StepPlan,
    ) -> Result<BackendState> {
        let s = state.downcast::<Buf>()?;
        let batch = io.downcast::<Batch>()?;
        let c = &ctrl.host;
        let m = &self.manifest;
        let n_c = m.n_components;
        ensure!(
            plan.n() == n_c,
            "step plan covers {} components, layout has {n_c}",
            plan.n()
        );
        let t_step = c[0];
        let lr = c[1];
        let wd = self.weight_decay * c[2];
        let mask = &c[m.ctrl_mask_offset..m.ctrl_mask_offset + n_c];

        let fwd = self.forward(s, &batch.tokens, &batch.patches);
        let (loss_sum, count, dlogits) = self.loss_grad(&fwd.logits, &batch.targets);
        // Omitted components come back as `None` gradients, so the
        // stats/carry/update loop below skips them wholesale — their
        // state bits stay identical, exactly like the masked update.
        let grads = self.backward(s, &fwd, dlogits, &batch.tokens, &batch.patches, plan);

        let mut ns = s.clone();
        // Thread the optimizer + Eq. 1 stats over the same pool as the
        // matmuls; `threads_for` keeps micro configs serial. The work
        // estimate is ~4 state-sized passes (g, prev, slot reads+writes).
        let active: usize = self
            .specs
            .iter()
            .enumerate()
            .filter(|&(i, _)| grads[i].is_some())
            .map(|(_, sp)| sp.size)
            .sum();
        let threads = kernels::threads_for(active * 4);
        let (gnorm, gdiff, gabs, gvar) =
            self.apply_updates(threads, &mut ns, s, &grads, mask, t_step, lr, wd);
        // metrics prefix, rebuilt from zeros every step like steps.py
        ns[0] = loss_sum;
        ns[1] = count;
        ns[2] = gnorm as f32;
        ns[3] = 0.0;
        ns[m.gdiff_offset..m.gdiff_offset + n_c].copy_from_slice(&gdiff);
        ns[m.gabs_offset..m.gabs_offset + n_c].copy_from_slice(&gabs);
        if let Some(go) = m.gvar_offset {
            ns[go..go + n_c].copy_from_slice(&gvar);
        }
        Ok(BackendState::new(ns))
    }

    fn probe(&self, state: &BackendState) -> Result<Vec<f32>> {
        let s = state.downcast::<Buf>()?;
        Ok(s[..self.manifest.metrics_len].to_vec())
    }

    fn eval_step(&self, state: &BackendState, io: &UploadedBatch) -> Result<(f64, f64)> {
        let s = state.downcast::<Buf>()?;
        let batch = io.downcast::<Batch>()?;
        let fwd = self.forward(s, &batch.tokens, &batch.patches);
        let (loss, count) = self.loss_of(&fwd.logits, &batch.targets);
        Ok((loss as f64, count as f64))
    }

    fn eval_rows(&self, state: &BackendState, io: &UploadedBatch) -> Result<Vec<(f64, f64)>> {
        let s = state.downcast::<Buf>()?;
        let batch = io.downcast::<Batch>()?;
        let fwd = self.forward(s, &batch.tokens, &batch.patches);
        let Dims { b, t, v, .. } = self.dims;
        let mut out = Vec::with_capacity(b);
        for bi in 0..b {
            let mut loss = 0f64;
            let mut count = 0usize;
            for ti in 0..t {
                let row = bi * t + ti;
                let tgt = batch.targets[row];
                if tgt < 0 {
                    continue;
                }
                loss += nll(&fwd.logits[row * v..(row + 1) * v], tgt as usize);
                count += 1;
            }
            out.push((loss as f32 as f64, count as f64));
        }
        Ok(out)
    }

    fn state_to_host(&self, state: &BackendState) -> Result<Vec<f32>> {
        Ok(state.downcast::<Buf>()?.to_vec())
    }

    fn state_from_host(&self, host: &[f32]) -> Result<BackendState> {
        ensure!(
            host.len() == self.manifest.state_len,
            "state len {} != {}",
            host.len(),
            self.manifest.state_len
        );
        Ok(BackendState::new(Buf::from_slice(host)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RepoConfig;

    fn tiny() -> HostBackend {
        HostBackend::for_config(&RepoConfig::by_name("lm-tiny-fp").unwrap()).unwrap()
    }

    /// A micro config small enough for finite-difference gradchecks.
    fn micro(optimizer: &str) -> HostBackend {
        micro_layers(optimizer, 1)
    }

    fn micro_model(kind: &str, n_layers: usize) -> ModelConfig {
        ModelConfig {
            kind: kind.into(),
            vocab_size: 16,
            d_model: 8,
            n_layers,
            n_heads: 2,
            d_ff: 12,
            max_seq: 6,
            n_patches: 0,
            patch_dim: 0,
            d_vision: 0,
            n_vision_layers: 0,
            n_vision_heads: 1,
            d_vision_ff: 0,
        }
    }

    fn micro_train(optimizer: &str, method: &str) -> TrainConfig {
        TrainConfig {
            batch_size: 2,
            seq_len: 4,
            optimizer: optimizer.into(),
            method: method.into(),
            weight_decay: 0.01,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            momentum: 0.9,
            lora_rank: 3,
            lora_alpha: 6.0,
        }
    }

    fn micro_layers(optimizer: &str, n_layers: usize) -> HostBackend {
        let model = micro_model("lm", n_layers);
        let train = micro_train(optimizer, "fp");
        HostBackend::from_parts("lm-micro", &model, &train).unwrap()
    }

    /// Micro LoRA engine: the lm micro shapes with rank-3 adapters.
    fn micro_lora(optimizer: &str) -> HostBackend {
        let model = micro_model("lm", 1);
        let train = micro_train(optimizer, "lora");
        HostBackend::from_parts("lm-micro-lora", &model, &train).unwrap()
    }

    /// Micro two-tower VLM: 3 patches of width 5 through a 1-layer
    /// vision tower (D_v=6) feeding the 1-layer language micro.
    fn micro_vlm(optimizer: &str) -> HostBackend {
        let mut model = micro_model("vlm", 1);
        model.n_patches = 3;
        model.patch_dim = 5;
        model.d_vision = 6;
        model.n_vision_layers = 1;
        model.n_vision_heads = 2;
        model.d_vision_ff = 8;
        let train = micro_train(optimizer, "fp");
        HostBackend::from_parts("vlm-micro", &model, &train).unwrap()
    }

    fn all_active(be: &HostBackend) -> StepPlan {
        StepPlan::all_active(be.manifest().n_components)
    }

    fn attn_plan(be: &HostBackend) -> StepPlan {
        let m = be.manifest();
        StepPlan::omitting(m.n_components, &m.components_where(|c| c.group == "attention"))
    }

    fn micro_batch(be: &HostBackend, seed: u64) -> Batch {
        let m = be.manifest();
        let mut rng = Rng::new(seed);
        let n = m.batch_size * m.seq_len;
        let np = m.batch_size * m.n_patches * m.patch_dim;
        Batch {
            tokens: (0..n).map(|_| rng.below(m.vocab_size) as i32).collect(),
            targets: (0..n).map(|_| rng.below(m.vocab_size) as i32).collect(),
            patches: (0..np).map(|_| rng.gauss() as f32 * 0.5).collect(),
        }
    }

    fn full_ctrl(m: &Manifest, t: f32, lr: f32) -> Vec<f32> {
        let mut c = vec![0f32; m.ctrl_len];
        c[0] = t;
        c[1] = lr;
        c[2] = 1.0;
        for x in c.iter_mut().skip(m.ctrl_mask_offset) {
            *x = 1.0;
        }
        c
    }

    #[test]
    fn layout_matches_the_compiled_artifact_numbers() {
        // Cross-checked against artifacts/lm-tiny-fp/manifest.json — the
        // contract that makes host and XLA states interchangeable.
        let m = tiny().into_manifest();
        assert_eq!(m.state_len, 436192);
        assert_eq!(m.metrics_len, 32);
        assert_eq!(m.ctrl_len, 18);
        assert_eq!(m.n_components, 14);
        assert_eq!((m.gdiff_offset, m.gabs_offset, m.ctrl_mask_offset), (4, 18, 4));
        assert_eq!(m.params.len(), 22);
        assert_eq!(m.n_params_total, 118080);
        assert_eq!(m.param("tok_emb").unwrap().offset, 32);
        assert_eq!(m.param("lm_head").unwrap().shape, vec![64, 256]);
        assert_eq!(m.components[0].name, "language.0.q");
        assert_eq!(m.components[13].name, "language.1.down");
        assert_eq!(m.components[4].group, "mlp");
        assert!(m.flops.fwd_per_token > 0.0);
    }

    #[test]
    fn sgd_layout_has_one_opt_slot() {
        let be = HostBackend::for_config(&RepoConfig::by_name("lm-tiny-sgd").unwrap()).unwrap();
        // params 118080, momentum slot 118080, prev 81920, metrics 32
        assert_eq!(be.manifest().state_len, 32 + 118080 + 118080 + 81920);
        assert!(matches!(be.opt, Opt::Sgd { .. }));
    }

    #[test]
    fn lora_layout_matches_the_compiled_artifact_numbers() {
        // layout.py lora_param_specs over lm-tiny-fp's shapes, rank 4:
        // adapters 8·4·(64+64) + 6·4·(64+128) = 8704; base weights keep
        // their offsets but lose opt slots and monitoring.
        let be = HostBackend::for_config(&RepoConfig::by_name("lm-tiny-lora").unwrap()).unwrap();
        let m = be.manifest();
        assert_eq!((m.kind.as_str(), m.method.as_str()), ("lm", "lora"));
        assert_eq!(m.n_components, 14);
        assert_eq!(m.metrics_len, 32);
        assert_eq!(m.n_params_total, 118080 + 8704);
        assert_eq!(m.n_params_trainable, 8704);
        // [metrics | params | adamw m+v over adapters | prev over adapters]
        assert_eq!(m.state_len, 32 + 126784 + 2 * 8704 + 8704);
        assert_eq!(m.params.len(), 22 + 28);
        // components monitor the (A, B) pair, not the base weight
        let c0 = &m.components[0];
        assert_eq!(c0.name, "language.0.q");
        assert_eq!(c0.tensors, vec!["lang.0.attn.q.lora_a", "lang.0.attn.q.lora_b"]);
        assert_eq!(c0.n_params, 4 * (64 + 64));
        // base weights: no component, no opt slots, no prev carry
        let base = &be.specs[be.layers[0].wq];
        assert!(!base.trainable && base.component.is_none());
        assert!(base.opt_offsets.is_empty() && base.prev_offset.is_none());
        assert!(m.param("lang.0.attn.q").unwrap().component.is_none());
        // adapters: trainable, component-tagged, monitored
        let a = &be.specs[be.lora.as_ref().unwrap().sites[0].a];
        assert_eq!(a.shape, vec![64, 4]);
        assert!(a.trainable && a.component == Some(0) && a.prev_offset.is_some());
        assert_eq!(be.lora.as_ref().unwrap().scale, 2.0); // α=8 / r=4
    }

    #[test]
    fn vlm_layout_matches_the_compiled_artifact_numbers() {
        // layout.py base_param_specs for vlm-tiny-fp: the vision tower's
        // specs precede the language tower's, components count both.
        let be = HostBackend::for_config(&RepoConfig::by_name("vlm-tiny-fp").unwrap()).unwrap();
        let m = be.manifest();
        assert_eq!((m.kind.as_str(), m.method.as_str()), ("vlm", "fp"));
        assert_eq!(m.n_components, 28);
        assert_eq!(m.metrics_len, 4 + 2 * 28);
        assert_eq!(m.ctrl_len, 4 + 28);
        assert_eq!((m.n_patches, m.patch_dim), (16, 12));
        assert_eq!(m.n_params_total, 168816);
        assert_eq!(m.state_len, 60 + 168816 + 2 * 168816 + 128000);
        assert_eq!(m.components[0].name, "vision.0.q");
        assert_eq!(m.components[0].tower, "vision");
        assert_eq!(m.components[14].name, "language.0.q");
        assert_eq!(m.components[27].name, "language.1.down");
        assert_eq!(m.param("vis_in").unwrap().shape, vec![12, 48]);
        assert_eq!(m.param("vis_proj").unwrap().shape, vec![48, 64]);
        // pos_emb covers prefix + text rows
        assert_eq!(m.param("pos_emb").unwrap().shape, vec![32 + 16, 64]);
        // spec order: all vision specs before tok_emb (layout.py)
        let vis_proj = m.param("vis_proj").unwrap().offset;
        assert!(vis_proj < m.param("tok_emb").unwrap().offset);
    }

    #[test]
    fn vlm_lora_layout_adapts_both_towers() {
        let model = {
            let mut mc = micro_model("vlm", 1);
            mc.n_patches = 3;
            mc.patch_dim = 5;
            mc.d_vision = 6;
            mc.n_vision_layers = 1;
            mc.n_vision_heads = 2;
            mc.d_vision_ff = 8;
            mc
        };
        let be =
            HostBackend::from_parts("vlm-micro-lora", &model, &micro_train("adamw", "lora"))
                .unwrap();
        let m = be.manifest();
        assert_eq!(m.n_components, 14);
        // vision q site: A (6×3), B (3×6); language q site: A (8×3), B (3×8)
        assert_eq!(m.components[0].n_params, 3 * (6 + 6));
        assert_eq!(m.components[7].n_params, 3 * (8 + 8));
        let lora = be.lora.as_ref().unwrap();
        assert_eq!(lora.sites.len(), 14);
        assert!(m.params.iter().all(|p| p.trainable == p.name.contains(".lora_")));
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let be = micro("adamw");
        let a = be.state_to_host(&be.init_state(7).unwrap()).unwrap();
        let b = be.state_to_host(&be.init_state(7).unwrap()).unwrap();
        assert_eq!(a, b);
        let c = be.state_to_host(&be.init_state(8).unwrap()).unwrap();
        assert_ne!(a, c);
        // metrics prefix + opt + prev regions start zeroed; ln scales = 1
        let m = be.manifest();
        assert!(a[..m.metrics_len].iter().all(|&x| x == 0.0));
        let ln1 = m.param("lang.0.ln1").unwrap();
        assert!(a[ln1.offset..ln1.offset + ln1.size()].iter().all(|&x| x == 1.0));
        let first_opt = be.specs[0].opt_offsets[0];
        assert!(a[first_opt..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn gradients_match_finite_differences() {
        // Central finite differences on a sample of entries of every
        // tensor family. f64 loss accumulation keeps FD noise ≈1e-6; the
        // analytic/FD agreement required here is ~1%.
        let be = micro("adamw");
        let checked = fd_gradcheck(&be, 3, 99);
        assert!(checked >= 12, "gradcheck sampled too few informative entries ({checked})");
    }

    /// Central finite differences against the analytic gradient for every
    /// spec that has one, from a fresh seed-`seed` init; returns how many
    /// informative entries were checked.
    fn fd_gradcheck(be: &HostBackend, seed: i32, batch_seed: u64) -> usize {
        let state = be.state_to_host(&be.init_state(seed).unwrap()).unwrap();
        fd_gradcheck_from(be, &state, batch_seed)
    }

    #[test]
    fn lora_gradients_match_finite_differences_and_base_grads_are_absent() {
        // The adapter chain rule (dA = s·xᵀ(dy·Bᵀ), dB = s·(x·A)ᵀ·dy)
        // against FD on the *full* loss; every base weight's grad entry
        // must be exactly `None` — layout.py gives them no state either.
        let be = micro_lora("adamw");
        // B initializes to zero, which would zero every dA signal — take
        // a few real steps first so the adapters are in general position.
        let m = be.manifest();
        let batch = micro_batch(&be, 55);
        let io = be.upload_batch(&batch).unwrap();
        let mut s = be.init_state(13).unwrap();
        for t in 1..=3 {
            let ctrl = be.upload_ctrl(&full_ctrl(m, t as f32, 5e-2)).unwrap();
            s = be.train_step(&s, &io, &ctrl, &all_active(&be)).unwrap();
        }
        let warm = be.state_to_host(&s).unwrap();
        let checked = fd_gradcheck_from(&be, &warm, 55);
        assert!(checked >= 12, "lora gradcheck sampled too few informative entries ({checked})");
    }

    /// [`fd_gradcheck`] from an explicit state. Specs with no gradient
    /// (frozen LoRA base weights) assert exact absence instead — a `None`
    /// entry, never a zero tensor.
    fn fd_gradcheck_from(be: &HostBackend, state: &[f32], batch_seed: u64) -> usize {
        let batch = micro_batch(be, batch_seed);
        let loss_of = |s: &[f32]| -> f64 {
            let fwd = be.forward(s, &batch.tokens, &batch.patches);
            let (l, c, _) = be.loss_grad(&fwd.logits, &batch.targets);
            l as f64 / (c as f64).max(1.0)
        };
        let fwd = be.forward(state, &batch.tokens, &batch.patches);
        let (_, _, dlogits) = be.loss_grad(&fwd.logits, &batch.targets);
        let grads = be.backward(state, &fwd, dlogits, &batch.tokens, &batch.patches, &all_active(be));
        let mut rng = Rng::new(5);
        let mut checked = 0usize;
        for (idx, spec) in be.specs.iter().enumerate() {
            let Some(g) = grads[idx].as_ref() else {
                assert!(
                    !spec.trainable,
                    "trainable spec {} missing its gradient in the full graph",
                    spec.name
                );
                continue;
            };
            assert!(spec.trainable, "untrainable spec {} got a gradient", spec.name);
            for _ in 0..4 {
                let i = rng.below(spec.size);
                let eps = 2e-3f32;
                let mut sp = state.to_vec();
                sp[spec.offset + i] += eps;
                let mut sm = state.to_vec();
                sm[spec.offset + i] -= eps;
                let h = (sp[spec.offset + i] - sm[spec.offset + i]) as f64;
                let fd = (loss_of(&sp) - loss_of(&sm)) / h;
                let an = g[i] as f64;
                if fd.abs() < 1e-3 && an.abs() < 1e-3 {
                    continue;
                }
                let rel = (fd - an).abs() / fd.abs().max(an.abs()).max(1e-6);
                assert!(
                    rel < 0.1,
                    "grad mismatch {}[{i}]: analytic {an:.6e} vs fd {fd:.6e} (rel {rel:.3})",
                    spec.name
                );
                checked += 1;
            }
        }
        checked
    }

    #[test]
    fn vlm_gradients_match_finite_differences_across_the_tower_boundary() {
        // Covers the patch embed (vis_in/vis_pos), the non-causal vision
        // tower, the vis_proj cross-tower boundary and the language side
        // in one sweep — every spec is trainable under fp, so every one
        // must carry a gradient.
        let be = micro_vlm("adamw");
        let checked = fd_gradcheck(&be, 19, 77);
        assert!(checked >= 12, "vlm gradcheck sampled too few informative entries ({checked})");
        // and the boundary tensors specifically made the cut
        let state = be.state_to_host(&be.init_state(19).unwrap()).unwrap();
        let batch = micro_batch(&be, 77);
        let fwd = be.forward(&state, &batch.tokens, &batch.patches);
        let (_, _, dlogits) = be.loss_grad(&fwd.logits, &batch.targets);
        let grads =
            be.backward(&state, &fwd, dlogits, &batch.tokens, &batch.patches, &all_active(&be));
        let vlm = be.vlm.as_ref().unwrap();
        for idx in [vlm.vis_in, vlm.vis_pos, vlm.vis_proj, vlm.vis_ln_f, vlm.layers[0].wq] {
            assert!(grads[idx].is_some(), "vlm spec {} has no gradient", be.specs[idx].name);
        }
    }

    #[test]
    fn train_step_writes_metrics_and_reduces_loss() {
        let be = micro("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 1);
        let io = be.upload_batch(&batch).unwrap();
        let mut state = be.init_state(1).unwrap();
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for t in 1..=30 {
            let ctrl = be.upload_ctrl(&full_ctrl(m, t as f32, 1e-2)).unwrap();
            state = be.train_step(&state, &io, &ctrl, &all_active(&be)).unwrap();
            let metrics = be.probe(&state).unwrap();
            let loss = metrics[0] / metrics[1].max(1.0);
            assert!(loss.is_finite());
            assert!(metrics[2] > 0.0, "global gnorm recorded");
            assert!(metrics[m.gdiff_offset] > 0.0, "gdiff recorded");
            if t == 1 {
                first = loss;
            }
            last = loss;
        }
        assert!(last < first - 0.3, "loss must fall on a repeated batch: {first} -> {last}");
    }

    #[test]
    fn gvar_layout_is_opt_in_and_fills_per_component() {
        let model = micro_model("lm", 1);
        let train = micro_train("adamw", "fp");
        let be = HostBackend::from_parts_gvar("lm-micro-gvar", &model, &train, true).unwrap();
        let m = be.manifest();
        let n_c = m.n_components;
        assert_eq!(m.gvar_offset, Some(METRIC_PAD + 2 * n_c));
        assert_eq!(m.metrics_len, METRIC_PAD + 3 * n_c);
        // without the flag the layout is bitwise-unchanged — `[eb] gvar`
        // is an explicit upgrade, not a default migration
        let plain = HostBackend::from_parts("lm-micro", &model, &train).unwrap();
        assert_eq!(plain.manifest().gvar_offset, None);
        assert_eq!(plain.manifest().metrics_len, METRIC_PAD + 2 * n_c);

        let batch = micro_batch(&be, 4);
        let io = be.upload_batch(&batch).unwrap();
        let mut state = be.init_state(3).unwrap();
        for t in 1..=2 {
            let ctrl = be.upload_ctrl(&full_ctrl(m, t as f32, 1e-2)).unwrap();
            state = be.train_step(&state, &io, &ctrl, &all_active(&be)).unwrap();
        }
        let metrics = be.probe(&state).unwrap();
        let go = m.gvar_offset.unwrap();
        for c in 0..n_c {
            let v = metrics[go + c];
            assert!(v.is_finite() && v > 0.0, "component {c}: gvar = {v}");
        }
    }

    #[test]
    fn freeze_mask_keeps_component_bits_identical() {
        let be = micro("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 2);
        let io = be.upload_batch(&batch).unwrap();
        let s0 = be.init_state(5).unwrap();
        let before = be.state_to_host(&s0).unwrap();
        let mut ctrl = full_ctrl(m, 1.0, 1e-3);
        ctrl[m.ctrl_mask_offset] = 0.0; // freeze component 0 (layer-0 q)
        let ctrl = be.upload_ctrl(&ctrl).unwrap();
        let s1 = be.train_step(&s0, &io, &ctrl, &all_active(&be)).unwrap();
        let after = be.state_to_host(&s1).unwrap();
        let frozen = &be.specs[be.layers[0].wq];
        assert_eq!(
            before[frozen.offset..frozen.offset + frozen.size],
            after[frozen.offset..frozen.offset + frozen.size],
            "frozen params moved"
        );
        for &o in &frozen.opt_offsets {
            assert_eq!(before[o..o + frozen.size], after[o..o + frozen.size], "opt state moved");
        }
        let p = frozen.prev_offset.unwrap();
        assert_eq!(before[p..p + frozen.size], after[p..p + frozen.size], "prev-grad carry moved");
        // but its gdiff/gabs are still measured (mask ≠ stop_gradient)
        assert!(after[m.gdiff_offset] > 0.0);
        // and an unfrozen component moved
        let other = &be.specs[be.layers[0].wk];
        assert_ne!(
            before[other.offset..other.offset + other.size],
            after[other.offset..other.offset + other.size]
        );
    }

    #[test]
    fn attn_plan_equals_masked_full_graph_bitwise() {
        // Stronger than the XLA integration test (which tolerates graph
        // fusion drift): the planned step skips exactly the omitted dW
        // math and nothing else, so states past the metrics prefix match
        // bit-for-bit.
        let be = micro("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 3);
        let io = be.upload_batch(&batch).unwrap();
        let s0 = be.init_state(11).unwrap();

        let mut masked = full_ctrl(m, 1.0, 1e-3);
        for c in &m.components {
            if c.group == "attention" {
                masked[m.ctrl_mask_offset + c.idx] = 0.0;
            }
        }
        let a = be
            .train_step(&s0, &io, &be.upload_ctrl(&masked).unwrap(), &all_active(&be))
            .unwrap();
        let b = be
            .train_step(
                &s0,
                &io,
                &be.upload_ctrl(&full_ctrl(m, 1.0, 1e-3)).unwrap(),
                &attn_plan(&be),
            )
            .unwrap();
        let ha = be.state_to_host(&a).unwrap();
        let hb = be.state_to_host(&b).unwrap();
        assert_eq!(ha[m.metrics_len..], hb[m.metrics_len..]);
        // the plan reports omitted stats as zero, the masked graph
        // still measures them
        let attn0 = m.gdiff_offset; // component 0 is attention
        assert!(ha[attn0] > 0.0);
        assert_eq!(hb[attn0], 0.0);
    }

    #[test]
    fn per_matrix_plan_equals_masked_full_graph_bitwise() {
        // The generalized elision: omit an arbitrary mix of components
        // (one attention, one mlp) — params/opt/prev must match the
        // masked dense step bit-for-bit, only the omitted components'
        // logged statistics differ.
        let be = micro("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 17);
        let io = be.upload_batch(&batch).unwrap();
        let s0 = be.init_state(23).unwrap();
        let omitted = [1usize, 5]; // layer-0 k (attention) + layer-0 up (mlp)
        let mut masked = full_ctrl(m, 1.0, 1e-3);
        for &c in &omitted {
            masked[m.ctrl_mask_offset + c] = 0.0;
        }
        let a = be
            .train_step(&s0, &io, &be.upload_ctrl(&masked).unwrap(), &all_active(&be))
            .unwrap();
        let b = be
            .train_step(
                &s0,
                &io,
                &be.upload_ctrl(&masked).unwrap(),
                &StepPlan::omitting(m.n_components, &omitted),
            )
            .unwrap();
        let ha = be.state_to_host(&a).unwrap();
        let hb = be.state_to_host(&b).unwrap();
        assert_eq!(ha[m.metrics_len..], hb[m.metrics_len..]);
        for &c in &omitted {
            assert!(ha[m.gdiff_offset + c] > 0.0);
            assert_eq!(hb[m.gdiff_offset + c], 0.0);
            assert_eq!(hb[m.gabs_offset + c], 0.0);
        }
        // a kept component's stats are identical in both runs
        assert_eq!(ha[m.gdiff_offset].to_bits(), hb[m.gdiff_offset].to_bits());
    }

    #[test]
    fn fully_omitted_layer_prefix_truncates_backward_and_holds_riders() {
        let be = micro_layers("adamw", 2);
        let m = be.manifest();
        assert_eq!(m.n_components, 14);
        let batch = micro_batch(&be, 31);
        let io = be.upload_batch(&batch).unwrap();
        let s0 = be.init_state(5).unwrap();
        let before = be.state_to_host(&s0).unwrap();
        // freeze + omit all of layer 0 (components 0..7); layer 1 active
        let mut ctrl = full_ctrl(m, 1.0, 1e-3);
        for c in 0..7 {
            ctrl[m.ctrl_mask_offset + c] = 0.0;
        }
        let prefix: Vec<usize> = (0..7).collect();
        // without the truncation grant the same omitted set must stay
        // bitwise-equal to the masked dense step (riders keep moving)
        let ungranted = be
            .train_step(
                &s0,
                &io,
                &be.upload_ctrl(&ctrl).unwrap(),
                &StepPlan::omitting(m.n_components, &prefix),
            )
            .unwrap();
        let plan = StepPlan::omitting(m.n_components, &prefix).with_truncation();
        let planned = be
            .train_step(&s0, &io, &be.upload_ctrl(&ctrl).unwrap(), &plan)
            .unwrap();
        let masked = be
            .train_step(&s0, &io, &be.upload_ctrl(&ctrl).unwrap(), &all_active(&be))
            .unwrap();
        let hp = be.state_to_host(&planned).unwrap();
        let hm = be.state_to_host(&masked).unwrap();
        let hu = be.state_to_host(&ungranted).unwrap();
        assert_eq!(hu[m.metrics_len..], hm[m.metrics_len..], "ungranted plan must not truncate");
        // riders of the truncated prefix are held bit-identical…
        for name in ["tok_emb", "pos_emb", "lang.0.ln1", "lang.0.ln2"] {
            let p = m.param(name).unwrap();
            assert_eq!(
                before[p.offset..p.offset + p.size()],
                hp[p.offset..p.offset + p.size()],
                "truncated rider {name} moved"
            );
            // …which is the documented divergence from the masked path
            // (there, weight decay still moves them)
            assert_ne!(
                hm[p.offset..p.offset + p.size()],
                hp[p.offset..p.offset + p.size()],
                "masked path should have updated rider {name}"
            );
        }
        // everything at or above the lowest active layer is bitwise
        // identical to the masked dense step
        for name in ["lang.1.ln1", "lang.1.attn.q", "lang.1.mlp.down", "ln_f", "lm_head"] {
            let p = m.param(name).unwrap();
            assert_eq!(
                hm[p.offset..p.offset + p.size()],
                hp[p.offset..p.offset + p.size()],
                "active-region tensor {name} diverged"
            );
        }
        // a *non-prefix* fully-frozen layer must not truncate: omit all
        // of layer 1 instead, and layer-0 riders plus embeddings move
        let plan_top =
            StepPlan::omitting(m.n_components, &(7..14).collect::<Vec<_>>()).with_truncation();
        let top = be
            .train_step(
                &s0,
                &io,
                &be.upload_ctrl(&full_ctrl(m, 1.0, 1e-3)).unwrap(),
                &plan_top,
            )
            .unwrap();
        let ht = be.state_to_host(&top).unwrap();
        for name in ["tok_emb", "lang.0.ln1", "lang.1.ln2"] {
            let p = m.param(name).unwrap();
            assert_ne!(
                before[p.offset..p.offset + p.size()],
                ht[p.offset..p.offset + p.size()],
                "non-prefix omission must not hold {name}"
            );
        }
    }

    #[test]
    fn unfreeze_downgrades_the_plan_and_resumes_updates() {
        // The dynamic-unfreezing regression: a component that froze (and
        // was elided) then unfroze must re-enter the plan and move again
        // — and the whole planned trajectory must match the masked dense
        // path bit-for-bit on the state.
        use crate::coordinator::freeze::{FreezeReason, FreezeState};
        use crate::coordinator::scheduler::StepPlanner;
        let be = micro("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 41);
        let io = be.upload_batch(&batch).unwrap();
        let mut planner = StepPlanner::new(m, true);
        let mut freeze = FreezeState::new(m.n_components);

        let mut planned = be.init_state(3).unwrap();
        let mut dense = be.init_state(3).unwrap();
        let comp = 2usize;
        for t in 1..=6 {
            match t {
                2 => freeze.freeze(comp, t, FreezeReason::Manual, 0.0),
                4 => freeze.unfreeze(comp, t, FreezeReason::Manual, 1.0),
                _ => {}
            }
            let mut ctrl = full_ctrl(m, t as f32, 1e-3);
            ctrl[m.ctrl_mask_offset..m.ctrl_mask_offset + m.n_components]
                .copy_from_slice(freeze.mask());
            let ctrl = be.upload_ctrl(&ctrl).unwrap();
            let plan = planner.plan(t, &freeze);
            assert!(plan.is_sound(&freeze));
            assert_eq!(plan.omits(comp), freeze.is_frozen(comp), "plan lags freeze at t={t}");
            let before = be.state_to_host(&planned).unwrap();
            planned = be.train_step(&planned, &io, &ctrl, &plan).unwrap();
            dense = be.train_step(&dense, &io, &ctrl, &all_active(&be)).unwrap();
            let after = be.state_to_host(&planned).unwrap();
            let p = m.param(&m.components[comp].tensors[0]).unwrap();
            let moved = before[p.offset..p.offset + p.size()]
                != after[p.offset..p.offset + p.size()];
            assert_eq!(moved, !freeze.is_frozen(comp), "component motion wrong at t={t}");
        }
        assert_eq!(planner.stats.downgrades, 1);
        let hp = be.state_to_host(&planned).unwrap();
        let hd = be.state_to_host(&dense).unwrap();
        assert_eq!(hp[m.metrics_len..], hd[m.metrics_len..], "planned != masked trajectory");
    }

    #[test]
    fn threaded_update_is_bitwise_identical_across_thread_counts() {
        // Drives `apply_updates` with explicit worker counts — micro
        // configs fall below `threads_for`'s work floor, so an env-driven
        // test would silently stay serial. Partial freezing exercises the
        // masked path; both optimizer families are covered. (Matmul
        // thread/SIMD invariance lives in `host_kernels::tests` and
        // `tests/properties.rs`.)
        let engines: Vec<(&str, HostBackend)> = vec![
            ("adamw", micro("adamw")),
            ("sgd", micro("sgd")),
            // LoRA's opt/prev regions skip the untrainable base specs, so
            // its chunk windows exercise the trainable-aware geometry
            ("lora-adamw", micro_lora("adamw")),
            ("lora-sgd", micro_lora("sgd")),
            ("vlm-adamw", micro_vlm("adamw")),
        ];
        for (optimizer, be) in &engines {
            let m = be.manifest();
            let batch = micro_batch(&be, 9);
            let s0 = be.init_state(5).unwrap();
            let s = be.state_to_host(&s0).unwrap();
            let mut ctrl = full_ctrl(m, 1.0, 1e-2);
            ctrl[m.ctrl_mask_offset] = 0.0; // freeze component 0
            let mask = &ctrl[m.ctrl_mask_offset..m.ctrl_mask_offset + m.n_components];
            let fwd = be.forward(&s, &batch.tokens, &batch.patches);
            let (_, _, dlogits) = be.loss_grad(&fwd.logits, &batch.targets);
            let grads =
                be.backward(&s, &fwd, dlogits, &batch.tokens, &batch.patches, &all_active(&be));

            let mut base = s.clone();
            let (gn1, gd1, ga1, _) =
                be.apply_updates(1, &mut base, &s, &grads, mask, 1.0, 1e-2, 1e-2);
            for threads in [2, 3, 8] {
                let mut ns = s.clone();
                let (gn, gd, ga, _) =
                    be.apply_updates(threads, &mut ns, &s, &grads, mask, 1.0, 1e-2, 1e-2);
                assert_eq!(gn.to_bits(), gn1.to_bits(), "{optimizer}/{threads} gnorm");
                assert!(gd.iter().zip(&gd1).all(|(x, y)| x.to_bits() == y.to_bits()));
                assert!(ga.iter().zip(&ga1).all(|(x, y)| x.to_bits() == y.to_bits()));
                assert!(
                    ns.iter().zip(&base).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{optimizer}/{threads}: threaded state differs from serial"
                );
            }
        }
    }

    #[test]
    fn sgd_step_moves_params_and_momentum() {
        let be = micro("sgd");
        let m = be.manifest();
        let batch = micro_batch(&be, 4);
        let io = be.upload_batch(&batch).unwrap();
        let s0 = be.init_state(2).unwrap();
        let before = be.state_to_host(&s0).unwrap();
        let ctrl = be.upload_ctrl(&full_ctrl(m, 1.0, 1e-2)).unwrap();
        let s1 = be.train_step(&s0, &io, &ctrl, &all_active(&be)).unwrap();
        let after = be.state_to_host(&s1).unwrap();
        let wq = &be.specs[be.layers[0].wq];
        assert_ne!(before[wq.offset..wq.offset + wq.size], after[wq.offset..wq.offset + wq.size]);
        let mom = wq.opt_offsets[0];
        assert!(after[mom..mom + wq.size].iter().any(|&x| x != 0.0), "momentum accumulated");
    }

    #[test]
    fn eval_step_matches_probe_loss_before_any_update() {
        // eval on the state a step *started* from equals the loss that
        // step recorded in the metrics prefix (train loss is pre-update).
        let be = micro("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 6);
        let io = be.upload_batch(&batch).unwrap();
        let s0 = be.init_state(21).unwrap();
        let (eval_loss, eval_count) = be.eval_step(&s0, &io).unwrap();
        let ctrl = be.upload_ctrl(&full_ctrl(m, 1.0, 1e-3)).unwrap();
        let s1 = be.train_step(&s0, &io, &ctrl, &all_active(&be)).unwrap();
        let metrics = be.probe(&s1).unwrap();
        assert_eq!(metrics[0].to_bits(), (eval_loss as f32).to_bits());
        assert_eq!(metrics[1], eval_count as f32);
    }

    #[test]
    fn eval_rows_sum_to_eval_step() {
        let be = micro("adamw");
        let mut batch = micro_batch(&be, 7);
        // mask a few targets so per-row counts differ
        batch.targets[1] = -1;
        batch.targets[5] = -1;
        let io = be.upload_batch(&batch).unwrap();
        let s = be.init_state(9).unwrap();
        let rows = be.eval_rows(&s, &io).unwrap();
        assert_eq!(rows.len(), be.manifest().batch_size);
        let (loss, count) = be.eval_step(&s, &io).unwrap();
        let row_loss: f64 = rows.iter().map(|r| r.0).sum();
        let row_count: f64 = rows.iter().map(|r| r.1).sum();
        assert!((row_loss - loss).abs() < 1e-3 * loss.abs().max(1.0));
        assert_eq!(row_count, count);
    }

    #[test]
    fn upload_batch_rejects_out_of_vocab_tokens() {
        let be = micro("adamw");
        let mut batch = micro_batch(&be, 8);
        batch.tokens[0] = 999;
        assert!(be.upload_batch(&batch).is_err());
    }

    #[test]
    fn state_round_trips_and_rejects_bad_lengths() {
        let be = micro("adamw");
        let s = be.init_state(1).unwrap();
        let host = be.state_to_host(&s).unwrap();
        let back = be.state_from_host(&host).unwrap();
        assert_eq!(be.state_to_host(&back).unwrap(), host);
        assert!(be.state_from_host(&host[1..]).is_err());
    }

    #[test]
    fn lora_training_moves_only_adapters_and_reduces_loss() {
        let be = micro_lora("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 12);
        let io = be.upload_batch(&batch).unwrap();
        let s0 = be.init_state(4).unwrap();
        let init = be.state_to_host(&s0).unwrap();
        let mut state = s0;
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for t in 1..=40 {
            let ctrl = be.upload_ctrl(&full_ctrl(m, t as f32, 5e-2)).unwrap();
            state = be.train_step(&state, &io, &ctrl, &all_active(&be)).unwrap();
            let metrics = be.probe(&state).unwrap();
            let loss = metrics[0] / metrics[1].max(1.0);
            assert!(loss.is_finite());
            if t == 1 {
                first = loss;
            }
            last = loss;
        }
        assert!(last < first - 0.2, "lora loss must fall on a repeated batch: {first} -> {last}");
        let after = be.state_to_host(&state).unwrap();
        // lora.py: the frozen base never moves — bit-identical to init
        for spec in be.specs.iter().filter(|sp| !sp.trainable) {
            assert_eq!(
                init[spec.offset..spec.offset + spec.size],
                after[spec.offset..spec.offset + spec.size],
                "frozen base weight {} moved",
                spec.name
            );
        }
        // every adapter moved (B leaves zero on the first step)
        for site in &be.lora.as_ref().unwrap().sites {
            for &idx in &[site.a, site.b] {
                let sp = &be.specs[idx];
                assert_ne!(
                    init[sp.offset..sp.offset + sp.size],
                    after[sp.offset..sp.offset + sp.size],
                    "adapter {} never moved",
                    sp.name
                );
            }
        }
    }

    #[test]
    fn vlm_train_step_writes_metrics_and_reduces_loss() {
        let be = micro_vlm("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 14);
        let io = be.upload_batch(&batch).unwrap();
        let mut state = be.init_state(6).unwrap();
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for t in 1..=30 {
            let ctrl = be.upload_ctrl(&full_ctrl(m, t as f32, 1e-2)).unwrap();
            state = be.train_step(&state, &io, &ctrl, &all_active(&be)).unwrap();
            let metrics = be.probe(&state).unwrap();
            let loss = metrics[0] / metrics[1].max(1.0);
            assert!(loss.is_finite());
            assert!(metrics[2] > 0.0, "global gnorm recorded");
            if t == 1 {
                first = loss;
            }
            last = loss;
        }
        assert!(last < first - 0.3, "vlm loss must fall on a repeated batch: {first} -> {last}");
    }

    #[test]
    fn vlm_upload_batch_rejects_wrong_patch_count() {
        let be = micro_vlm("adamw");
        let mut batch = micro_batch(&be, 3);
        batch.patches.pop();
        let err = be.upload_batch(&batch).unwrap_err().to_string();
        assert!(err.contains("patch"), "{err}");
    }

    #[test]
    fn new_family_plan_elision_equals_masked_dense_bitwise() {
        // PR 5's central elision guarantee, extended to the new layouts:
        // omitting frozen components skips exactly their dW/update math,
        // so the planned state matches the masked dense state bit-for-bit
        // past the metrics prefix. For LoRA the omission must gate the
        // *adapter pair*; for the VLM it must reach the vision tower.
        for (label, be, warmup) in [
            ("lora", micro_lora("adamw"), 2usize),
            ("vlm", micro_vlm("adamw"), 0usize),
        ] {
            let m = be.manifest();
            let batch = micro_batch(&be, 21);
            let io = be.upload_batch(&batch).unwrap();
            let mut s0 = be.init_state(8).unwrap();
            // LoRA: step off the all-zero B init so omission has bits to
            // wrongly move if the gating were broken
            for t in 0..warmup {
                let ctrl = be.upload_ctrl(&full_ctrl(m, (t + 1) as f32, 5e-2)).unwrap();
                s0 = be.train_step(&s0, &io, &ctrl, &all_active(&be)).unwrap();
            }
            // one component from each tower/group that exists
            let omitted: Vec<usize> = vec![0, m.n_components - 1];
            let mut masked = full_ctrl(m, 9.0, 1e-3);
            for &c in &omitted {
                masked[m.ctrl_mask_offset + c] = 0.0;
            }
            let ctrl = be.upload_ctrl(&masked).unwrap();
            let dense = be.train_step(&s0, &io, &ctrl, &all_active(&be)).unwrap();
            let planned = be
                .train_step(&s0, &io, &ctrl, &StepPlan::omitting(m.n_components, &omitted))
                .unwrap();
            let hd = be.state_to_host(&dense).unwrap();
            let hp = be.state_to_host(&planned).unwrap();
            assert_eq!(
                hd[m.metrics_len..],
                hp[m.metrics_len..],
                "{label}: planned state diverged from masked dense"
            );
            for &c in &omitted {
                assert!(hd[m.gdiff_offset + c] > 0.0, "{label}: dense stats missing");
                assert_eq!(hp[m.gdiff_offset + c], 0.0, "{label}: planned stats leaked");
            }
        }
    }

    #[test]
    fn merged_weights_match_the_adapter_graph() {
        // merge_lora semantics: W + (α/r)·A·B. Spot-check one site in
        // f64 against the engine's merged buffer.
        let be = micro_lora("adamw");
        let m = be.manifest();
        let batch = micro_batch(&be, 33);
        let io = be.upload_batch(&batch).unwrap();
        let mut s = be.init_state(17).unwrap();
        for t in 1..=2 {
            let ctrl = be.upload_ctrl(&full_ctrl(m, t as f32, 5e-2)).unwrap();
            s = be.train_step(&s, &io, &ctrl, &all_active(&be)).unwrap();
        }
        let host = be.state_to_host(&s).unwrap();
        let merged = be.merged_weights(&host);
        let lora = be.lora.as_ref().unwrap();
        assert_eq!(merged.len(), lora.sites.len());
        let site = &lora.sites[0];
        let (base, a, b) = (&be.specs[site.base], &be.specs[site.a], &be.specs[site.b]);
        let (din, r, dout) = (base.shape[0], lora.rank, base.shape[1]);
        for i in 0..din {
            for j in 0..dout {
                let mut acc = 0f64;
                for k in 0..r {
                    acc += host[a.offset + i * r + k] as f64 * host[b.offset + k * dout + j] as f64;
                }
                let want = host[base.offset + i * dout + j] as f64 + lora.scale as f64 * acc;
                let got = merged[0][i * dout + j] as f64;
                assert!(
                    (want - got).abs() <= 1e-5 * want.abs().max(1.0),
                    "merged[{i},{j}]: {got} vs {want}"
                );
            }
        }
    }
}
