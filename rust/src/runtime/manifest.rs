//! Typed view of `artifacts/<config>/manifest.json` — the contract between
//! the python compile path and the rust coordinator.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// One GradES-monitored component (a projection matrix, or its LoRA pair).
#[derive(Debug, Clone)]
pub struct Component {
    /// Index in manifest order (= ctrl-mask / metrics slot).
    pub idx: usize,
    /// Stable name, e.g. `language.3.q`.
    pub name: String,
    /// Transformer block index.
    pub layer: usize,
    /// q|k|v|o|gate|up|down
    pub kind: String,
    /// "attention" | "mlp"
    pub group: String,
    /// "language" | "vision"
    pub tower: String,
    /// Parameters this component owns.
    pub n_params: usize,
    /// Underlying tensor names (two for a LoRA pair).
    pub tensors: Vec<String>,
}

#[derive(Debug, Clone)]
/// One flat-state tensor's location and metadata.
pub struct ParamInfo {
    /// Tensor name.
    pub name: String,
    /// Logical shape.
    pub shape: Vec<usize>,
    /// Offset (in f32s) into the flat state buffer.
    pub offset: usize,
    /// False for buffers the optimizer never updates.
    pub trainable: bool,
    /// Owning monitored component, if any.
    pub component: Option<usize>,
}

impl ParamInfo {
    /// Element count (product of the shape).
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Analytic per-token FLOPs (python-side `flops_summary`).
#[derive(Debug, Clone)]
pub struct FlopsInfo {
    /// Forward matmul FLOPs per token.
    pub fwd_per_token: f64,
    /// Backward input-gradient (dX) FLOPs per token.
    pub bwd_dx_per_token: f64,
    /// Per-component forward FLOPs (≈ its dW backward cost).
    pub per_component_fwd: BTreeMap<String, f64>,
    /// Sequence-quadratic attention term per token.
    pub attn_quadratic_per_token: f64,
    /// LM-head matmul FLOPs per token.
    pub head_per_token: f64,
}

#[derive(Debug, Clone)]
/// Everything the coordinator needs to know about one compiled
/// artifact: shapes, components, buffer layouts, FLOPs, executables.
pub struct Manifest {
    /// Config/artifact name.
    pub name: String,
    /// "lm" or "vlm".
    pub kind: String, // "lm" | "vlm"
    /// "fp" (full parameter) or "lora".
    pub method: String,
    /// "adamw" or "sgd" (decides ctrl[0] step-sensitivity).
    pub optimizer: String,
    /// Kernel backend the graphs were lowered with ("xla"/"pallas").
    pub kernel_impl: String,
    /// Fixed batch size B every executable was compiled for.
    pub batch_size: usize,
    /// Fixed sequence length T.
    pub seq_len: usize,
    /// Tokenizer vocabulary size.
    pub vocab_size: usize,
    /// VLM: image patches per example (0 for LMs).
    pub n_patches: usize,
    /// VLM: flattened patch feature size (0 for LMs).
    pub patch_dim: usize,
    /// Flat device-state length in f32s (params + opt state + metrics).
    pub state_len: usize,
    /// Length of the probe's metrics prefix.
    pub metrics_len: usize,
    /// Length of the per-step ctrl vector.
    pub ctrl_len: usize,
    /// Monitored component count.
    pub n_components: usize,
    /// Offset of the Gdiff block inside the metrics prefix.
    pub gdiff_offset: usize,
    /// Offset of the Gabs block inside the metrics prefix.
    pub gabs_offset: usize,
    /// Offset of the per-component gradient-variance block (EB criterion
    /// statistic), when the layout carries one (`[eb] gvar = true`).
    /// `None` on every pre-existing artifact — the EB monitor then falls
    /// back to its Gdiff/Gabs evidence estimate.
    pub gvar_offset: Option<usize>,
    /// Offset of the freeze mask inside the ctrl vector.
    pub ctrl_mask_offset: usize,
    /// Monitored components, in index order.
    pub components: Vec<Component>,
    /// Flat-state layout.
    pub params: Vec<ParamInfo>,
    /// Total parameter count.
    pub n_params_total: usize,
    /// Trainable parameter count (≠ total under LoRA).
    pub n_params_trainable: usize,
    /// Analytic per-token FLOPs.
    pub flops: FlopsInfo,
    /// Executable key → HLO file name.
    pub executables: BTreeMap<String, String>,
    /// Optional train-step variant declarations: executable key → the
    /// component *names* whose dW matmuls that graph omits. The two
    /// shipped keys (`train_step`, `train_step_attn_frozen`) have
    /// built-in definitions; any other `train_step*` executable must be
    /// declared here (see `coordinator::scheduler::VariantLattice`).
    pub variants: BTreeMap<String, Vec<String>>,
}

impl Manifest {
    /// Load and validate `manifest.json`.
    pub fn load(path: &Path) -> Result<Self> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`?)"))?;
        let j = json::parse(&src).with_context(|| format!("parsing {path:?}"))?;
        Self::from_json(&j)
    }

    /// Typed view of an already-parsed manifest document.
    pub fn from_json(j: &Json) -> Result<Self> {
        let components = j
            .get("components")?
            .as_arr()?
            .iter()
            .map(|c| {
                Ok(Component {
                    idx: c.get("idx")?.as_usize()?,
                    name: c.get("name")?.as_str()?.to_string(),
                    layer: c.get("layer")?.as_usize()?,
                    kind: c.get("kind")?.as_str()?.to_string(),
                    group: c.get("group")?.as_str()?.to_string(),
                    tower: c.get("tower")?.as_str()?.to_string(),
                    n_params: c.get("n_params")?.as_usize()?,
                    tensors: c
                        .get("tensors")?
                        .as_arr()?
                        .iter()
                        .map(|t| Ok(t.as_str()?.to_string()))
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        for (i, c) in components.iter().enumerate() {
            if c.idx != i {
                bail!("component idx mismatch at {i}");
            }
        }
        let params = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamInfo {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p
                        .get("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<_>>()?,
                    offset: p.get("offset")?.as_usize()?,
                    trainable: p.get("trainable")?.as_bool()?,
                    component: match p.get("component")? {
                        Json::Null => None,
                        v => Some(v.as_usize()?),
                    },
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let f = j.get("flops")?;
        let per_component_fwd = match f.get("per_component_fwd")? {
            Json::Obj(m) => m
                .iter()
                .map(|(k, v)| Ok((k.clone(), v.as_f64()?)))
                .collect::<Result<BTreeMap<_, _>>>()?,
            _ => bail!("per_component_fwd not an object"),
        };
        let model = j.get("model")?;
        let metrics = j.get("metrics")?;
        Ok(Manifest {
            name: j.get("name")?.as_str()?.to_string(),
            kind: j.get("kind")?.as_str()?.to_string(),
            method: j.get("method")?.as_str()?.to_string(),
            optimizer: j.get("optimizer")?.as_str()?.to_string(),
            kernel_impl: j.get("kernel_impl")?.as_str()?.to_string(),
            batch_size: j.get("batch_size")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            vocab_size: j.get("vocab_size")?.as_usize()?,
            n_patches: model.get("n_patches")?.as_usize()?,
            patch_dim: model.get("patch_dim")?.as_usize()?,
            state_len: j.get("state_len")?.as_usize()?,
            metrics_len: j.get("metrics_len")?.as_usize()?,
            ctrl_len: j.get("ctrl_len")?.as_usize()?,
            n_components: j.get("n_components")?.as_usize()?,
            gdiff_offset: metrics.get("gdiff_offset")?.as_usize()?,
            gabs_offset: metrics.get("gabs_offset")?.as_usize()?,
            gvar_offset: metrics.opt("gvar_offset").map(|v| v.as_usize()).transpose()?,
            ctrl_mask_offset: j.get("ctrl")?.get("mask_offset")?.as_usize()?,
            components,
            params,
            n_params_total: j.get("n_params_total")?.as_usize()?,
            n_params_trainable: j.get("n_params_trainable")?.as_usize()?,
            flops: FlopsInfo {
                fwd_per_token: f.get("fwd_per_token")?.as_f64()?,
                bwd_dx_per_token: f.get("bwd_dx_per_token")?.as_f64()?,
                per_component_fwd,
                attn_quadratic_per_token: f.get("attn_quadratic_per_token")?.as_f64()?,
                head_per_token: f.get("head_per_token")?.as_f64()?,
            },
            executables: match j.get("executables")? {
                Json::Obj(m) => m
                    .iter()
                    .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
                    .collect::<Result<_>>()?,
                _ => bail!("executables not an object"),
            },
            variants: match j.opt("variants") {
                None => BTreeMap::new(),
                Some(Json::Obj(m)) => m
                    .iter()
                    .map(|(k, v)| {
                        let names = v
                            .as_arr()?
                            .iter()
                            .map(|n| Ok(n.as_str()?.to_string()))
                            .collect::<Result<Vec<_>>>()?;
                        Ok((k.clone(), names))
                    })
                    .collect::<Result<_>>()?,
                Some(_) => bail!("variants not an object"),
            },
        })
    }

    /// Is this a two-tower VLM artifact?
    pub fn is_vlm(&self) -> bool {
        self.kind == "vlm"
    }

    /// Look up a tensor by name.
    pub fn param(&self, name: &str) -> Option<&ParamInfo> {
        self.params.iter().find(|p| p.name == name)
    }

    /// Component indices belonging to a group ("attention"/"mlp") or tower.
    pub fn components_where<F: Fn(&Component) -> bool>(&self, f: F) -> Vec<usize> {
        self.components.iter().filter(|c| f(c)).map(|c| c.idx).collect()
    }
}
