//! PJRT runtime: load AOT HLO-text artifacts and run them from the rust
//! hot path (adapted from /opt/xla-example/load_hlo/).

pub mod artifact;
pub mod async_eval;
pub mod manifest;
pub mod pipeline;
pub mod session;

use anyhow::anyhow;

/// The `xla` crate's error doesn't implement `std::error::Error`; wrap it.
pub fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}
