//! Execution runtime: the [`backend::Backend`] abstraction over the six
//! step programs, with two engines — AOT HLO artifacts through PJRT
//! (`artifact`, adapted from /opt/xla-example/load_hlo/) and the pure-Rust
//! reference transformer (`host_backend`) that runs full GradES
//! trajectories with no artifacts at all, on the SIMD microkernel layer
//! in `host_kernels`.

pub mod artifact;
pub mod async_eval;
pub mod backend;
pub mod host_arena;
pub mod host_backend;
pub mod host_kernels;
pub mod manifest;
pub mod pipeline;
pub mod session;

use anyhow::anyhow;

/// The `xla` crate's error doesn't implement `std::error::Error`; wrap it.
pub fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}
