//! Asynchronous chunked evaluation: validation passes that time-slice
//! between train steps instead of stalling the loop.
//!
//! Classic early stopping pays for full validation passes on the step
//! loop's critical path — the overhead that makes FP+ES *slower* than the
//! no-ES baseline in the paper's Table 4, and the cost GradES's whole
//! pitch is about avoiding. This module decouples the stopping signal
//! from synchronous full-set inference (ISSUE 3 tentpole):
//!
//! * An [`EvalSnapshot`] pins the parameters a check evaluates: a
//!   zero-copy `Rc` handle to the device-resident state buffer, taken at
//!   the check step. The train loop keeps updating the *current* state —
//!   every train step produces a fresh buffer — while the pinned buffer
//!   stays alive for the in-flight pass.
//! * An [`AsyncValidator`] runs the pass in *chunks*: each train step
//!   advances the pass by [`AsyncEvalOptions::chunk`] batches of the
//!   device-resident validation set ([`DeviceBatchCache`]), interleaving
//!   eval executions between train steps so the prefetch / upload-ahead
//!   pipeline never drains.
//! * A [`StalenessBound`] makes the resulting lag explicit: the check
//!   issued at step *t* must be applied by step *t + k*. `k = 0` drains
//!   the pass at the issue step and reproduces today's synchronous
//!   trajectories bitwise (the same batches, evaluated in the same order,
//!   summed in the same order — see `must_drain`). `k > 0` lets the
//!   decision land late in exchange for an unblocked step loop.
//!
//! The validator is generic over the snapshot type and evaluates through
//! caller-supplied closures, so all of its scheduling policy is testable
//! host-only (`rust/tests/async_eval.rs`); the trainer instantiates it
//! with [`EvalSnapshot`] and [`Session::eval_batch_snapshot`].
//!
//! Threading: nothing here spawns a thread. "Background" means *behind
//! the step loop*, not *on another thread* — the `xla` binding's client
//! handles carry non-atomic refcounts, so all device work stays
//! serialized on the thread that holds the device token (see the
//! thread-safety contract in [`crate::runtime::session`] and
//! `docs/ARCHITECTURE.md`). Chunked interleaving is what an exclusive
//! device gives us instead of true overlap; host-resident weight copies
//! (`Session::snapshot_to_host`, the scheduler's `EvalPayload`) are how
//! evaluation crosses threads when it must.
//!
//! [`DeviceBatchCache`]: crate::runtime::pipeline::DeviceBatchCache
//! [`Session::eval_batch_snapshot`]: crate::runtime::session::Session::eval_batch_snapshot

use anyhow::Result;

use super::backend::BackendState;

// ---------------------------------------------------------------------------
// Policy types
// ---------------------------------------------------------------------------

/// How stale an asynchronous stopping decision may be.
///
/// A validation pass issued at step `t` must have its result applied —
/// recorded by the stopping rule, possibly ending training — no later
/// than step `t + max_steps`. The validator force-drains an unfinished
/// pass when the bound is hit.
///
/// ```
/// use grades::runtime::async_eval::StalenessBound;
/// let k = StalenessBound { max_steps: 3 };
/// assert!(!k.must_drain(10, 12)); // 2 steps old: may keep chunking
/// assert!(k.must_drain(10, 13));  // 3 steps old: drain and apply now
/// assert!(StalenessBound::sync().must_drain(10, 10)); // k = 0 ⇒ synchronous
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StalenessBound {
    /// Maximum steps between issuing a check and applying its result.
    /// `0` reproduces the synchronous (blocked) behaviour exactly.
    pub max_steps: usize,
}

impl StalenessBound {
    /// `k = 0`: every pass drains at its issue step (the blocked
    /// baseline; trajectories are bitwise-identical to the pre-async
    /// trainer).
    pub fn sync() -> Self {
        StalenessBound { max_steps: 0 }
    }

    /// No forced drain: a pass completes at its natural chunked pace
    /// (⌈n_batches / chunk⌉ steps), bounded only by the next check
    /// displacing it.
    pub fn unbounded() -> Self {
        StalenessBound { max_steps: usize::MAX }
    }

    /// Must a pass issued at `issued_at` be fully drained at step `now`?
    pub fn must_drain(&self, issued_at: usize, now: usize) -> bool {
        now.saturating_sub(issued_at) >= self.max_steps
    }
}

/// Knobs for the asynchronous evaluation runtime, threaded through
/// `TrainerOptions::async_eval`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AsyncEvalOptions {
    /// Validation batches evaluated per train step while a pass is in
    /// flight (clamped to ≥ 1). `usize::MAX` evaluates the whole set in
    /// one slice.
    pub chunk: usize,
    /// When the pass's result must be applied (see [`StalenessBound`]).
    pub staleness: StalenessBound,
}

impl AsyncEvalOptions {
    /// The blocked baseline: the whole pass runs at the check step.
    /// This is the default, and reproduces the pre-async trainer's
    /// trajectories bitwise.
    pub fn synchronous() -> Self {
        AsyncEvalOptions { chunk: usize::MAX, staleness: StalenessBound::sync() }
    }

    /// Chunked background validation: `chunk` batches per step, result
    /// applied within `max_steps` of the check (`--async-eval`).
    pub fn overlapped(chunk: usize, max_steps: usize) -> Self {
        AsyncEvalOptions {
            chunk: chunk.max(1),
            staleness: StalenessBound { max_steps },
        }
    }

    /// Does this configuration ever leave a pass in flight?
    pub fn is_synchronous(&self) -> bool {
        self.staleness.max_steps == 0
    }
}

impl Default for AsyncEvalOptions {
    fn default() -> Self {
        AsyncEvalOptions::synchronous()
    }
}

// ---------------------------------------------------------------------------
// Snapshots
// ---------------------------------------------------------------------------

/// Parameters pinned at a past step for asynchronous evaluation.
///
/// Backend-resident and zero-copy: train steps never mutate a state
/// handle in place (each step returns a *new* one, on every backend), so
/// pinning the weights a check evaluates is just keeping the old handle's
/// `Rc` alive while `Session::state` moves on. For the cross-thread /
/// host-resident path — an eval job scoring a finished training job on
/// another scheduler worker — downgrade to plain host data with
/// [`Session::snapshot_to_host`] and rehydrate with
/// [`Session::upload_snapshot`].
///
/// [`Session::snapshot_to_host`]: crate::runtime::session::Session::snapshot_to_host
/// [`Session::upload_snapshot`]: crate::runtime::session::Session::upload_snapshot
pub struct EvalSnapshot {
    pub(crate) state: BackendState,
    /// Optimizer step the snapshot pins (1-based, like `Session::step`).
    pub step: usize,
}

impl EvalSnapshot {
    pub(crate) fn new(state: BackendState, step: usize) -> Self {
        EvalSnapshot { state, step }
    }
}

// ---------------------------------------------------------------------------
// Results + instrumentation
// ---------------------------------------------------------------------------

/// The outcome of one (possibly chunked) validation pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    /// Step the check was issued at — the step whose parameters the
    /// loss describes (and the step `MetricsLog::record_val` logs it at).
    pub issued_at: usize,
    /// Step the result is applied at; `applied_at - issued_at ≤ k`.
    pub applied_at: usize,
    /// Mean validation loss over the full pass (NaN for an empty set),
    /// summed in cache order — bitwise-identical to
    /// `Session::eval_mean_loss_cached` on the same batches.
    pub val_loss: f64,
    /// Batches evaluated (the full cache length).
    pub batches: usize,
    /// True when the pass was drained early — the staleness bound was
    /// hit, or a newer check displaced it — rather than finishing at
    /// its natural chunked pace.
    pub forced: bool,
}

/// Counters describing how the asynchronous runtime behaved in one run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AsyncEvalStats {
    /// Validation passes issued (= checks that came due).
    pub issued: usize,
    /// Passes whose result was applied.
    pub completed: usize,
    /// Passes drained early because the staleness bound was hit.
    pub forced_drains: usize,
    /// Passes drained because a newer check displaced them.
    pub displaced: usize,
    /// Passes abandoned because training ended for another reason (e.g.
    /// the monitored matrix froze before the stop signal arrived).
    pub abandoned: usize,
    /// Individual batch evaluations executed across all passes.
    pub chunk_evals: usize,
}

/// Progress of the pass currently in flight (see
/// [`AsyncValidator::in_flight`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InFlight {
    /// Step the pass was issued at.
    pub issued_at: usize,
    /// Batches already evaluated.
    pub batches_done: usize,
    /// Batches in the full pass.
    pub batches_total: usize,
}

// ---------------------------------------------------------------------------
// The validator
// ---------------------------------------------------------------------------

/// One in-flight chunked pass: the pinned snapshot plus partial sums.
struct PendingPass<S> {
    snapshot: S,
    issued_at: usize,
    cursor: usize,
    loss_sum: f64,
    count_sum: f64,
}

impl<S> PendingPass<S> {
    fn new(snapshot: S, issued_at: usize) -> Self {
        PendingPass { snapshot, issued_at, cursor: 0, loss_sum: 0.0, count_sum: 0.0 }
    }

    fn finish(self, applied_at: usize, forced: bool) -> EvalResult {
        EvalResult {
            issued_at: self.issued_at,
            applied_at,
            // Same reduction as `eval_mean_loss_cached`: sum in cache
            // order, divide once — bitwise-equal for equal inputs.
            val_loss: if self.count_sum > 0.0 {
                self.loss_sum / self.count_sum
            } else {
                f64::NAN
            },
            batches: self.cursor,
            forced,
        }
    }
}

/// Drives chunked validation passes against pinned snapshots.
///
/// Generic over the snapshot type `S` and fed by closures, so the whole
/// scheduling policy — chunk pacing, forced drains, displacement, k = 0
/// equivalence — is testable without a device. The trainer instantiates
/// `AsyncValidator<EvalSnapshot>` with `Session::snapshot` /
/// `Session::eval_batch_snapshot` over the device-resident val cache.
///
/// Results come back in issue order; at most two per step (an in-flight
/// pass displaced by a new check, then the new check's own k = 0 drain).
pub struct AsyncValidator<S> {
    opts: AsyncEvalOptions,
    n_batches: usize,
    pass: Option<PendingPass<S>>,
    /// Runtime counters (reported through `TrainOutcome::async_eval`).
    pub stats: AsyncEvalStats,
}

impl<S> AsyncValidator<S> {
    /// A validator over a fixed validation set of `n_batches` batches.
    pub fn new(opts: AsyncEvalOptions, n_batches: usize) -> Self {
        AsyncValidator { opts, n_batches, pass: None, stats: AsyncEvalStats::default() }
    }

    /// The pass currently in flight, if any.
    pub fn in_flight(&self) -> Option<InFlight> {
        self.pass.as_ref().map(|p| InFlight {
            issued_at: p.issued_at,
            batches_done: p.cursor,
            batches_total: self.n_batches,
        })
    }

    /// Run `eval` over `p`'s batches up to `target` (the single place
    /// chunks execute and accumulate, so the k = 0 and chunked paths
    /// cannot diverge). `target = n_batches` drains the pass fully.
    fn advance_to<E>(&mut self, p: &mut PendingPass<S>, target: usize, eval: &mut E) -> Result<()>
    where
        E: FnMut(&S, usize) -> Result<(f64, f64)>,
    {
        while p.cursor < target {
            let (l, c) = eval(&p.snapshot, p.cursor)?;
            p.loss_sum += l;
            p.count_sum += c;
            p.cursor += 1;
            self.stats.chunk_evals += 1;
        }
        Ok(())
    }

    /// Advance the runtime at train step `t`.
    ///
    /// `due` says whether the stopping rule wants a new check issued at
    /// this step (`ClassicEs::due`). `snap` pins the current parameters
    /// (called at most once, only when `due`); `eval` evaluates one
    /// validation batch against a pinned snapshot, returning the batch's
    /// `(loss_sum, token_count)` exactly like `Session::eval_batch`.
    ///
    /// Returns the results that became applicable at this step, in issue
    /// order. The caller records each into the stopping rule — and, if
    /// one triggers a stop, training ends at step `t` = `applied_at`,
    /// which the staleness bound keeps within `k` of `issued_at`.
    pub fn on_step<F, E>(
        &mut self,
        t: usize,
        due: bool,
        snap: F,
        mut eval: E,
    ) -> Result<Vec<EvalResult>>
    where
        F: FnOnce() -> Result<S>,
        E: FnMut(&S, usize) -> Result<(f64, f64)>,
    {
        let mut out = Vec::new();

        // 1. Advance the in-flight pass by one chunk; complete it if it
        //    reaches the end, or force-drain when the bound is hit.
        if let Some(mut p) = self.pass.take() {
            let forced = self.opts.staleness.must_drain(p.issued_at, t);
            let budget = self.opts.chunk.max(1);
            // saturating: `chunk` may be usize::MAX (whole set per slice)
            let natural_finish = p.cursor.saturating_add(budget) >= self.n_batches;
            let target = if forced {
                self.n_batches
            } else {
                p.cursor.saturating_add(budget).min(self.n_batches)
            };
            self.advance_to(&mut p, target, &mut eval)?;
            if p.cursor >= self.n_batches {
                let was_forced = forced && !natural_finish;
                if was_forced {
                    self.stats.forced_drains += 1;
                }
                self.stats.completed += 1;
                out.push(p.finish(t, was_forced));
            } else {
                self.pass = Some(p);
            }
        }

        // 2. A new check came due. A still-unfinished older pass is
        //    displaced: drained now so results apply in issue order.
        if due {
            if let Some(mut p) = self.pass.take() {
                self.advance_to(&mut p, self.n_batches, &mut eval)?;
                self.stats.displaced += 1;
                self.stats.completed += 1;
                out.push(p.finish(t, true));
            }
            let mut p = PendingPass::new(snap()?, t);
            self.stats.issued += 1;
            if self.opts.staleness.max_steps == 0 || self.n_batches == 0 {
                // k = 0 (or an empty set): the synchronous path — evaluate
                // the whole pass at the issue step, exactly like the
                // blocked baseline.
                self.advance_to(&mut p, self.n_batches, &mut eval)?;
                self.stats.completed += 1;
                out.push(p.finish(t, false));
            } else {
                self.pass = Some(p);
            }
        }

        Ok(out)
    }

    /// Discard the in-flight pass: training ended for another reason
    /// (budget exhausted, or the GradES monitor froze the whole matrix
    /// before the stop signal arrived). Returns the abandoned pass's
    /// issue step.
    pub fn abandon(&mut self) -> Option<usize> {
        self.pass.take().map(|p| {
            self.stats.abandoned += 1;
            p.issued_at
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic per-batch losses; snapshots are just the issue step, and
    /// eval checks the pinned step to prove chunks use the snapshot, not
    /// the advancing step counter.
    fn losses(n: usize) -> Vec<(f64, f64)> {
        (0..n).map(|i| (1.0 + (i as f64) * 0.5, 2.0)).collect()
    }

    #[test]
    fn k0_drains_at_issue_step_with_cache_order_sum() {
        let data = losses(4);
        let mut v = AsyncValidator::new(AsyncEvalOptions::synchronous(), data.len());
        let results = v
            .on_step(10, true, || Ok(10usize), |&s, i| {
                assert_eq!(s, 10);
                Ok(data[i])
            })
            .unwrap();
        assert_eq!(results.len(), 1);
        let r = results[0];
        assert_eq!((r.issued_at, r.applied_at, r.batches, r.forced), (10, 10, 4, false));
        // same reduction as the inline loop
        let (mut ls, mut cs) = (0.0, 0.0);
        for &(l, c) in &data {
            ls += l;
            cs += c;
        }
        assert_eq!(r.val_loss.to_bits(), (ls / cs).to_bits());
        assert!(v.in_flight().is_none());
        assert_eq!(v.stats.issued, 1);
        assert_eq!(v.stats.completed, 1);
        assert_eq!(v.stats.forced_drains, 0);
    }

    #[test]
    fn chunked_pass_completes_at_natural_pace() {
        let data = losses(5);
        let mut v = AsyncValidator::new(AsyncEvalOptions::overlapped(2, usize::MAX), data.len());
        let mut eval_calls = 0usize;
        let mut run = |v: &mut AsyncValidator<usize>, t: usize, due: bool| {
            v.on_step(t, due, || Ok(t), |_, i| {
                eval_calls += 1;
                Ok(data[i])
            })
            .unwrap()
        };
        assert!(run(&mut v, 10, true).is_empty()); // issued, 0 evaluated
        assert_eq!(v.in_flight().unwrap().batches_done, 0);
        assert!(run(&mut v, 11, false).is_empty()); // 2 evaluated
        assert_eq!(v.in_flight().unwrap().batches_done, 2);
        assert!(run(&mut v, 12, false).is_empty()); // 4 evaluated
        let done = run(&mut v, 13, false); // 5th evaluated → complete
        assert_eq!(done.len(), 1);
        assert_eq!((done[0].issued_at, done[0].applied_at), (10, 13));
        assert!(!done[0].forced);
        assert_eq!(eval_calls, 5);
        assert_eq!(v.stats.chunk_evals, 5);
    }

    #[test]
    fn staleness_bound_forces_the_drain() {
        let data = losses(8);
        // chunk 1, k = 2: issued at 10, advances at 11 and 12; at 12 the
        // bound hits and the remaining 6 batches drain in one slice.
        let mut v = AsyncValidator::new(AsyncEvalOptions::overlapped(1, 2), data.len());
        let mut run = |v: &mut AsyncValidator<usize>, t: usize, due: bool| {
            v.on_step(t, due, || Ok(t), |_, i| Ok(data[i])).unwrap()
        };
        assert!(run(&mut v, 10, true).is_empty());
        assert!(run(&mut v, 11, false).is_empty());
        let done = run(&mut v, 12, false);
        assert_eq!(done.len(), 1);
        assert_eq!((done[0].issued_at, done[0].applied_at), (10, 12));
        assert!(done[0].forced);
        assert_eq!(done[0].batches, 8);
        assert_eq!(v.stats.forced_drains, 1);
    }

    #[test]
    fn new_check_displaces_the_inflight_pass_in_issue_order() {
        let data = losses(6);
        let mut v = AsyncValidator::new(AsyncEvalOptions::overlapped(1, usize::MAX), data.len());
        let mut run = |v: &mut AsyncValidator<usize>, t: usize, due: bool| {
            v.on_step(t, due, || Ok(t), |_, i| Ok(data[i])).unwrap()
        };
        assert!(run(&mut v, 10, true).is_empty());
        assert!(run(&mut v, 11, false).is_empty());
        // check due at 12 while the pass from 10 has 1/6 done: the old
        // pass drains first, then the new one starts chunking.
        let done = run(&mut v, 12, true);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].issued_at, 10);
        assert!(done[0].forced);
        assert_eq!(v.stats.displaced, 1);
        let inflight = v.in_flight().unwrap();
        assert_eq!(inflight.issued_at, 12);
        assert_eq!(inflight.batches_done, 0);
    }

    #[test]
    fn empty_validation_set_completes_immediately_with_nan() {
        let mut v: AsyncValidator<usize> =
            AsyncValidator::new(AsyncEvalOptions::overlapped(1, 5), 0);
        let done = v.on_step(3, true, || Ok(3), |_, _| unreachable!("no batches")).unwrap();
        assert_eq!(done.len(), 1);
        assert!(done[0].val_loss.is_nan());
        assert_eq!(done[0].batches, 0);
    }

    #[test]
    fn abandon_discards_the_pass_and_counts_it() {
        let data = losses(4);
        let mut v = AsyncValidator::new(AsyncEvalOptions::overlapped(1, usize::MAX), data.len());
        v.on_step(5, true, || Ok(5usize), |_, i| Ok(data[i])).unwrap();
        assert!(v.in_flight().is_some());
        assert_eq!(v.abandon(), Some(5));
        assert!(v.in_flight().is_none());
        assert_eq!(v.abandon(), None);
        assert_eq!(v.stats.abandoned, 1);
        assert_eq!(v.stats.completed, 0);
    }

    #[test]
    fn snapshot_is_pinned_across_chunks() {
        // Each eval sees the snapshot from the *issue* step even though
        // the step counter keeps advancing — the whole point of pinning.
        let data = losses(3);
        let mut v = AsyncValidator::new(AsyncEvalOptions::overlapped(1, usize::MAX), data.len());
        for t in 10..=13 {
            let due = t == 10;
            v.on_step(t, due, || Ok(10usize), |&s, i| {
                assert_eq!(s, 10, "chunk at t={} must see the pinned snapshot", i);
                Ok(data[i])
            })
            .unwrap();
        }
        assert_eq!(v.stats.completed, 1);
    }

    #[test]
    fn options_defaults_are_synchronous() {
        let d = AsyncEvalOptions::default();
        assert!(d.is_synchronous());
        assert_eq!(d, AsyncEvalOptions::synchronous());
        assert!(!AsyncEvalOptions::overlapped(1, 8).is_synchronous());
        // chunk is clamped to ≥ 1
        assert_eq!(AsyncEvalOptions::overlapped(0, 8).chunk, 1);
    }
}
