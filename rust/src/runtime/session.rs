//! Training session: owns the backend-resident flat state handle and
//! drives the step/probe/eval programs of any [`Backend`] — the compiled
//! XLA artifacts or the pure-Rust host engine. The state never
//! round-trips to host between steps (the probe output is `metrics_len`
//! floats).
//!
//! Uploads are split from execution (`upload_batch` → `train_step_uploaded`
//! / `eval_batch_uploaded`) so the pipelined trainer can stage the next
//! step's buffers while the current step runs, and so the fixed validation
//! set can live on device (`runtime::pipeline::DeviceBatchCache`). Every
//! host↔device interaction is accounted in [`StepTimings`].
//!
//! The ctrl vector is also backend-resident: the last uploaded ctrl buffer
//! is cached and reused when a step's ctrl is equivalent to it (see
//! [`ctrl_upload_skippable`]), skipping the per-step 4·`ctrl_len` copy.
//! Skips are counted in `StepTimings::ctrl_skips`.
//!
//! All manifest shape validation happens here, once, for every backend —
//! the backends themselves assume validated inputs.
//!
//! # Thread-safety contract (Send audit for the experiment scheduler)
//!
//! `Session` is `!Send` and must stay that way: on the XLA backend every
//! PJRT object reachable from it (the state handle, the cached ctrl
//! buffer) holds a handle whose refcount in the `xla` binding is
//! **non-atomic** and is cloned/dropped by uploads, executions and buffer
//! drops. Two threads touching objects of the same client concurrently —
//! even *different* sessions — race those refcounts. The experiment
//! scheduler (`exp::scheduler`) therefore never runs two sessions of one
//! client at the same time: all device work is serialized behind a single
//! exclusive "device token" mutex, and sessions cross threads only while
//! that token is held (jobs overlap in their host-side stages — data
//! generation, packing, rendering — which touch no PJRT state). Code
//! outside the scheduler keeps the simpler rule: a client and everything
//! created from it live and die on one thread. The host backend has no
//! such constraint of its own but flows through the same discipline.

use std::cell::RefCell;
use std::io::Write as _;

use anyhow::{ensure, Context, Result};

use super::backend::{Backend, BackendState, CtrlBuf};
use super::async_eval::EvalSnapshot;
use super::pipeline::{DeviceBatchCache, StepTimings};
use crate::coordinator::scheduler::StepPlan;
use crate::util::timer::Timer;

pub use super::backend::UploadedBatch;

/// One training run's backend-side state: the flat parameter/optimizer
/// state handle plus the programs that read and write it.
pub struct Session<'b> {
    /// The execution backend this session runs on.
    pub backend: &'b dyn Backend,
    /// The current state handle. Handles are `Rc`-shared so an
    /// [`EvalSnapshot`] can pin a past step's state at zero cost while
    /// training moves on (train steps return a *new* handle; nothing
    /// mutates one in place, on either backend).
    state: Option<BackendState>,
    /// 1-based optimizer step (AdamW bias correction).
    pub step: usize,
    /// Cumulative runtime instrumentation (RefCell: eval/probe take &self).
    timings: RefCell<StepTimings>,
    /// Backend-resident ctrl vector from the last train step, reused when
    /// the next step's ctrl is equivalent (see [`ctrl_upload_skippable`]).
    ctrl_cache: RefCell<Option<CtrlBuf>>,
}

/// Can a cached device ctrl buffer stand in for `next` without changing
/// the trajectory?
///
/// * Bitwise-equal vectors are always reusable.
/// * If the compiled graph never reads `ctrl[0]` (`step_sensitive ==
///   false` — the SGD update takes no step input, unlike AdamW whose bias
///   correction consumes it), a vector differing *only* at `ctrl[0]` is
///   also reusable: the stale step on device is dead data.
///
/// AdamW graphs can therefore only skip when lr and mask both repeat
/// exactly; under a cosine schedule that makes skips rare, which is why
/// the count is surfaced in `StepTimings::ctrl_skips` rather than assumed.
pub fn ctrl_upload_skippable(cached: &[f32], next: &[f32], step_sensitive: bool) -> bool {
    if cached.len() != next.len() || cached.is_empty() {
        return false;
    }
    if cached == next {
        return true;
    }
    !step_sensitive && cached[1..] == next[1..]
}

/// One training batch already flattened row-major.
#[derive(Debug, Clone, Default)]
pub struct Batch {
    /// `[B, T]` input token ids, row-major.
    pub tokens: Vec<i32>,
    /// `[B, T]` next-token targets (-1 = masked).
    pub targets: Vec<i32>,
    /// VLM only: `[B, n_patches, patch_dim]` flattened.
    pub patches: Vec<f32>,
}

impl Batch {
    /// Host bytes this batch occupies (== bytes a device upload copies).
    pub fn nbytes(&self) -> usize {
        4 * (self.tokens.len() + self.targets.len() + self.patches.len())
    }
}

impl<'b> Session<'b> {
    /// Uninitialized session over a backend (call [`Session::init`]).
    pub fn new(backend: &'b dyn Backend) -> Self {
        Session {
            backend,
            state: None,
            step: 0,
            timings: RefCell::new(StepTimings::default()),
            ctrl_cache: RefCell::new(None),
        }
    }

    /// The backend's manifest (shapes, components, state layout). Tied to
    /// the backend's lifetime, not the session borrow, so callers can
    /// keep it across mutating session calls.
    pub fn manifest(&self) -> &'b crate::runtime::manifest::Manifest {
        self.backend.manifest()
    }

    /// Snapshot of the cumulative upload/exec/probe/eval instrumentation.
    pub fn timings(&self) -> StepTimings {
        *self.timings.borrow()
    }

    /// Count an already-performed upload as staged (overlapped with the
    /// previous step's execution) — called by the pipelined trainer.
    pub fn note_staged_upload(&self) {
        self.timings.borrow_mut().staged_uploads += 1;
    }

    /// Run the init program, placing fresh params/opt state on the backend.
    pub fn init(&mut self, seed: i32) -> Result<()> {
        self.state = Some(self.backend.init_state(seed)?);
        self.step = 0;
        *self.ctrl_cache.borrow_mut() = None;
        Ok(())
    }

    /// Stage one host batch into execution-ready form (shape-checked
    /// against the manifest). Separated from execution so uploads can be
    /// staged ahead of their step and so fixed eval sets upload once.
    pub fn upload_batch(&self, batch: &Batch) -> Result<UploadedBatch> {
        let m = self.backend.manifest();
        let b = m.batch_size;
        let t = m.seq_len;
        ensure!(batch.tokens.len() == b * t, "tokens len {} != {}", batch.tokens.len(), b * t);
        ensure!(batch.targets.len() == b * t, "targets len mismatch");
        if m.is_vlm() {
            let want = b * m.n_patches * m.patch_dim;
            ensure!(batch.patches.len() == want, "patches len {} != {want}", batch.patches.len());
        }
        let timer = Timer::new();
        let io = self.backend.upload_batch(batch)?;
        let mut tm = self.timings.borrow_mut();
        tm.upload_secs += timer.secs();
        tm.upload_bytes += io.bytes as u64;
        tm.uploads += 1;
        Ok(io)
    }

    /// One optimizer step. `ctrl` is the full control vector (step, lr,
    /// wd_scale, mask…); `plan` names the component dW matmuls to omit
    /// (`StepPlan::all_active` reproduces the dense graph bitwise).
    /// Returns the plan the backend actually executed after lowering —
    /// identical to `plan` on the host engine, the nearest sound
    /// pre-compiled variant on XLA.
    pub fn train_step(
        &mut self,
        batch: &Batch,
        ctrl: &[f32],
        plan: &StepPlan,
    ) -> Result<StepPlan> {
        let io = self.upload_batch(batch)?;
        self.train_step_uploaded(io, ctrl, plan)
    }

    /// One optimizer step over already-staged buffers (the pipelined
    /// path: the upload happened while the previous step executed).
    /// Returns the realized (engine-lowered) plan — see
    /// [`Session::train_step`].
    pub fn train_step_uploaded(
        &mut self,
        io: UploadedBatch,
        ctrl: &[f32],
        plan: &StepPlan,
    ) -> Result<StepPlan> {
        let m = self.backend.manifest();
        ensure!(ctrl.len() == m.ctrl_len, "ctrl len {} != {}", ctrl.len(), m.ctrl_len);
        ensure!(
            plan.n() == m.n_components,
            "step plan covers {} components, manifest has {}",
            plan.n(),
            m.n_components
        );
        // Per-engine lowering. The subset check is the soundness rule:
        // an engine may realize *less* elision than asked, never more.
        let realized = self.backend.lower_plan(plan);
        ensure!(
            realized.is_subset_of(plan),
            "backend {} lowered a plan omitting components the request kept active",
            self.backend.name()
        );
        let state = self.state.as_ref().context("session not initialized")?;
        // Persistent ctrl buffer: reuse the backend copy when this step's
        // ctrl is equivalent to it. AdamW graphs read ctrl[0] for bias
        // correction, so only an exact repeat may skip there; SGD graphs
        // never read the step and may skip whenever lr+mask repeat.
        let step_sensitive = m.optimizer == "adamw";
        let mut cache = self.ctrl_cache.borrow_mut();
        let reuse = cache
            .as_ref()
            .map_or(false, |c| ctrl_upload_skippable(&c.host, ctrl, step_sensitive));
        if reuse {
            self.timings.borrow_mut().ctrl_skips += 1;
        } else {
            let ct = Timer::new();
            let buf = self.backend.upload_ctrl(ctrl)?;
            {
                let mut tm = self.timings.borrow_mut();
                tm.upload_secs += ct.secs();
                tm.upload_bytes += 4 * ctrl.len() as u64;
            }
            *cache = Some(buf);
        }
        let ctrl_buf = cache.as_ref().expect("ctrl cache populated above");
        let (carved0, fresh0) = super::host_arena::arena_counters();
        let et = Timer::new();
        let next = self.backend.train_step(state, &io, ctrl_buf, &realized)?;
        let (carved1, fresh1) = super::host_arena::arena_counters();
        {
            let mut tm = self.timings.borrow_mut();
            tm.exec_secs += et.secs();
            tm.execs += 1;
            tm.dw_elided += realized.n_omitted();
            tm.arena_carved_bytes += carved1 - carved0;
            tm.arena_fresh_bytes += fresh1 - fresh0;
        }
        drop(cache);
        self.state = Some(next);
        self.step += 1;
        Ok(realized)
    }

    /// Read the metrics prefix the last train step wrote into the state.
    pub fn probe(&self) -> Result<Vec<f32>> {
        let state = self.state.as_ref().context("session not initialized")?;
        let t = Timer::new();
        let v = self.backend.probe(state);
        let mut tm = self.timings.borrow_mut();
        tm.probe_secs += t.secs();
        tm.probes += 1;
        v
    }

    /// Forward-only loss on one batch → (loss_sum, token_count).
    pub fn eval_batch(&self, batch: &Batch) -> Result<(f64, f64)> {
        let io = self.upload_batch(batch)?;
        self.eval_batch_uploaded(&io)
    }

    /// Forward-only loss over staged buffers (the cached path —
    /// numerically identical to `eval_batch`, same program + data).
    pub fn eval_batch_uploaded(&self, io: &UploadedBatch) -> Result<(f64, f64)> {
        let state = self.state.as_ref().context("session not initialized")?;
        self.eval_uploaded_with(state, io)
    }

    /// Forward-only loss of an explicit state handle over staged
    /// buffers — the shared core of the current-state and snapshot paths
    /// (same program, same data ⇒ same value for the same state).
    fn eval_uploaded_with(&self, state: &BackendState, io: &UploadedBatch) -> Result<(f64, f64)> {
        let t = Timer::new();
        let v = self.backend.eval_step(state, io);
        let mut tm = self.timings.borrow_mut();
        tm.eval_secs += t.secs();
        tm.evals += 1;
        v
    }

    /// Pin the current parameters for asynchronous evaluation: a
    /// zero-copy [`EvalSnapshot`] that stays valid while training
    /// advances (see `runtime::async_eval`).
    pub fn snapshot(&self) -> Result<EvalSnapshot> {
        let state = self.state.as_ref().context("session not initialized")?;
        self.timings.borrow_mut().snapshots += 1;
        Ok(EvalSnapshot::new(state.clone(), self.step))
    }

    /// Download a snapshot's pinned state (plain `Send` data — the only
    /// form in which evaluation state may cross threads).
    pub fn snapshot_to_host(&self, snap: &EvalSnapshot) -> Result<Vec<f32>> {
        self.backend.state_to_host(&snap.state)
    }

    /// Rehydrate a host-resident weight copy into a pinned snapshot (the
    /// cross-thread path: an eval job scoring another job's final
    /// weights — host vectors are the only `Send` form of a snapshot).
    pub fn upload_snapshot(&self, host: &[f32], step: usize) -> Result<EvalSnapshot> {
        let m = self.backend.manifest();
        ensure!(host.len() == m.state_len, "state len {} != {}", host.len(), m.state_len);
        let timer = Timer::new();
        let state = self.backend.state_from_host(host)?;
        {
            let mut tm = self.timings.borrow_mut();
            tm.upload_secs += timer.secs();
            tm.upload_bytes += 4 * host.len() as u64;
            tm.uploads += 1;
            tm.snapshots += 1;
        }
        Ok(EvalSnapshot::new(state, step))
    }

    /// Forward-only loss of a pinned snapshot on one staged batch — what
    /// the async validator's chunks execute. Identical to
    /// [`Session::eval_batch_uploaded`] when the snapshot pins the
    /// current step.
    pub fn eval_batch_snapshot(
        &self,
        snap: &EvalSnapshot,
        io: &UploadedBatch,
    ) -> Result<(f64, f64)> {
        self.eval_uploaded_with(&snap.state, io)
    }

    /// Per-row (loss_sum, count) pairs — multiple-choice scoring.
    pub fn eval_rows(&self, batch: &Batch) -> Result<Vec<(f64, f64)>> {
        let io = self.upload_batch(batch)?;
        self.eval_rows_uploaded(&io)
    }

    /// Per-row scoring over staged buffers (cached MC harness).
    pub fn eval_rows_uploaded(&self, io: &UploadedBatch) -> Result<Vec<(f64, f64)>> {
        let state = self.state.as_ref().context("session not initialized")?;
        let t = Timer::new();
        let v = self.backend.eval_rows(state, io);
        let mut tm = self.timings.borrow_mut();
        tm.eval_secs += t.secs();
        tm.evals += 1;
        v
    }

    /// Mean validation loss over many host batches, uploading each call
    /// (the classic-ES hot cost the device cache removes).
    pub fn eval_mean_loss(&self, batches: &[Batch]) -> Result<f64> {
        let mut loss = 0.0;
        let mut count = 0.0;
        for b in batches {
            let (l, c) = self.eval_batch(b)?;
            loss += l;
            count += c;
        }
        Ok(if count > 0.0 { loss / count } else { f64::NAN })
    }

    /// Mean validation loss over a staged cache: pure execution, zero
    /// upload. Returns the same value as `eval_mean_loss` on the batches
    /// the cache was built from.
    pub fn eval_mean_loss_cached(&self, cache: &DeviceBatchCache) -> Result<f64> {
        let mut loss = 0.0;
        let mut count = 0.0;
        for io in cache.iter() {
            let (l, c) = self.eval_batch_uploaded(io)?;
            loss += l;
            count += c;
        }
        Ok(if count > 0.0 { loss / count } else { f64::NAN })
    }

    /// Download the full state (checkpointing / inspection).
    pub fn state_to_host(&self) -> Result<Vec<f32>> {
        let state = self.state.as_ref().context("session not initialized")?;
        self.backend.state_to_host(state)
    }

    /// Restore a previously downloaded state.
    pub fn state_from_host(&mut self, host: &[f32]) -> Result<()> {
        let m = self.backend.manifest();
        ensure!(host.len() == m.state_len, "state len {} != {}", host.len(), m.state_len);
        self.state = Some(self.backend.state_from_host(host)?);
        Ok(())
    }

    /// Save a binary checkpoint (u64-LE step header + f32-LE state),
    /// streamed through a buffered writer in fixed-size chunks.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        let host = self.state_to_host()?;
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        let file = std::fs::File::create(path)?;
        let mut w = std::io::BufWriter::with_capacity(1 << 20, file);
        write_checkpoint(&mut w, self.step as u64, &host)?;
        w.flush()?;
        Ok(())
    }

    /// Restore a checkpoint written by [`Session::save_checkpoint`].
    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let bytes = std::fs::read(path)?;
        let (step, host) = decode_checkpoint(&bytes)?;
        self.step = step as usize;
        self.state_from_host(&host)
    }
}

/// Floats converted per encode chunk (256 KiB of output at a time keeps
/// the scratch buffer cache-resident while amortizing writer calls).
const CKPT_CHUNK: usize = 64 * 1024;

/// Stream `step` + `state` in the checkpoint wire format. Chunked
/// little-endian encode: the seed implementation pushed 4 bytes per float
/// through `extend_from_slice`, which bottlenecked multi-MB states.
pub fn write_checkpoint<W: std::io::Write>(w: &mut W, step: u64, state: &[f32]) -> Result<()> {
    w.write_all(&step.to_le_bytes())?;
    let mut scratch = vec![0u8; CKPT_CHUNK * 4];
    for chunk in state.chunks(CKPT_CHUNK) {
        for (i, x) in chunk.iter().enumerate() {
            scratch[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
        }
        w.write_all(&scratch[..chunk.len() * 4])?;
    }
    Ok(())
}

/// Encode to an in-memory byte vector (tests / golden files).
pub fn encode_checkpoint(step: u64, state: &[f32]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(8 + state.len() * 4);
    write_checkpoint(&mut bytes, step, state).expect("Vec write is infallible");
    bytes
}

/// Inverse of [`write_checkpoint`]. Validates the header + alignment.
pub fn decode_checkpoint(bytes: &[u8]) -> Result<(u64, Vec<f32>)> {
    ensure!(bytes.len() >= 8 && (bytes.len() - 8) % 4 == 0, "corrupt checkpoint");
    let step = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let host: Vec<f32> = bytes[8..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((step, host))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip_exact() {
        let state: Vec<f32> = (0..CKPT_CHUNK + 137)
            .map(|i| (i as f32).sin() * 1e3 + f32::MIN_POSITIVE)
            .collect();
        let bytes = encode_checkpoint(42, &state);
        assert_eq!(bytes.len(), 8 + state.len() * 4);
        let (step, back) = decode_checkpoint(&bytes).unwrap();
        assert_eq!(step, 42);
        // bitwise round trip, including non-finite values
        assert_eq!(back.len(), state.len());
        for (a, b) in state.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn checkpoint_roundtrip_specials() {
        let state = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 0.0];
        let (step, back) = decode_checkpoint(&encode_checkpoint(7, &state)).unwrap();
        assert_eq!(step, 7);
        for (a, b) in state.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn checkpoint_wire_format_is_stable() {
        // Seed-format compatibility: u64-LE step, then f32-LE values.
        let bytes = encode_checkpoint(3, &[1.0]);
        let mut want = 3u64.to_le_bytes().to_vec();
        want.extend_from_slice(&1.0f32.to_le_bytes());
        assert_eq!(bytes, want);
    }

    #[test]
    fn decode_rejects_corrupt() {
        assert!(decode_checkpoint(&[1, 2, 3]).is_err()); // short header
        assert!(decode_checkpoint(&[0; 10]).is_err()); // misaligned body
        assert!(decode_checkpoint(&[0; 8]).is_ok()); // empty state is fine
    }

    #[test]
    fn batch_nbytes_counts_all_fields() {
        let b = Batch { tokens: vec![0; 6], targets: vec![0; 6], patches: vec![0.0; 5] };
        assert_eq!(b.nbytes(), 4 * 17);
    }

    #[test]
    fn ctrl_skip_exact_repeat_always_allowed() {
        let a = vec![3.0, 1e-3, 1.0, 1.0, 0.0];
        assert!(ctrl_upload_skippable(&a, &a.clone(), true));
        assert!(ctrl_upload_skippable(&a, &a.clone(), false));
    }

    #[test]
    fn ctrl_skip_step_only_change_needs_step_insensitive_graph() {
        let cached = vec![3.0, 1e-3, 1.0, 1.0, 0.0];
        let next = vec![4.0, 1e-3, 1.0, 1.0, 0.0];
        // SGD never reads ctrl[0]: the stale device step is dead data.
        assert!(ctrl_upload_skippable(&cached, &next, false));
        // AdamW bias correction consumes ctrl[0]: must re-upload.
        assert!(!ctrl_upload_skippable(&cached, &next, true));
    }

    #[test]
    fn ctrl_skip_rejects_lr_mask_or_shape_changes() {
        let cached = vec![3.0, 1e-3, 1.0, 1.0, 0.0];
        let lr = vec![4.0, 2e-3, 1.0, 1.0, 0.0];
        let mask = vec![4.0, 1e-3, 1.0, 0.0, 0.0];
        assert!(!ctrl_upload_skippable(&cached, &lr, false));
        assert!(!ctrl_upload_skippable(&cached, &mask, false));
        assert!(!ctrl_upload_skippable(&cached, &cached[..4], false));
        assert!(!ctrl_upload_skippable(&[], &[], false));
    }
}
