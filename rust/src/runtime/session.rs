//! Training session: owns the on-device flat state buffer and drives the
//! step/probe/eval executables. The state never round-trips to host between
//! steps (the probe output is `metrics_len` floats).

use anyhow::{ensure, Context, Result};
use xla::PjRtBuffer;

use super::artifact::Bundle;
use super::xerr;

pub struct Session<'b> {
    pub bundle: &'b Bundle,
    state: Option<PjRtBuffer>,
    /// 1-based optimizer step (AdamW bias correction).
    pub step: usize,
}

/// One training batch already flattened row-major.
#[derive(Debug, Clone, Default)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    /// VLM only: `[B, n_patches, patch_dim]` flattened.
    pub patches: Vec<f32>,
}

impl<'b> Session<'b> {
    pub fn new(bundle: &'b Bundle) -> Self {
        Session { bundle, state: None, step: 0 }
    }

    fn client(&self) -> &xla::PjRtClient {
        &self.bundle.client.0
    }

    /// Run the init executable, placing fresh params/opt state on device.
    pub fn init(&mut self, seed: i32) -> Result<()> {
        let seed_buf = self
            .client()
            .buffer_from_host_buffer::<i32>(&[seed], &[1], None)
            .map_err(xerr)?;
        let mut out = self.bundle.init.execute_b(&[&seed_buf]).map_err(xerr)?;
        self.state = Some(out.remove(0).remove(0));
        self.step = 0;
        Ok(())
    }

    fn upload_batch(&self, batch: &Batch) -> Result<Vec<PjRtBuffer>> {
        let m = &self.bundle.manifest;
        let b = m.batch_size;
        let t = m.seq_len;
        ensure!(batch.tokens.len() == b * t, "tokens len {} != {}", batch.tokens.len(), b * t);
        ensure!(batch.targets.len() == b * t, "targets len mismatch");
        let mut bufs = vec![
            self.client()
                .buffer_from_host_buffer::<i32>(&batch.tokens, &[b, t], None)
                .map_err(xerr)?,
            self.client()
                .buffer_from_host_buffer::<i32>(&batch.targets, &[b, t], None)
                .map_err(xerr)?,
        ];
        if m.is_vlm() {
            let want = b * m.n_patches * m.patch_dim;
            ensure!(batch.patches.len() == want, "patches len {} != {want}", batch.patches.len());
            bufs.push(
                self.client()
                    .buffer_from_host_buffer::<f32>(
                        &batch.patches,
                        &[b, m.n_patches, m.patch_dim],
                        None,
                    )
                    .map_err(xerr)?,
            );
        }
        Ok(bufs)
    }

    /// One optimizer step. `ctrl` is the full control vector (step, lr,
    /// wd_scale, mask…); `attn_frozen` selects the reduced-backward variant.
    pub fn train_step(&mut self, batch: &Batch, ctrl: &[f32], attn_frozen: bool) -> Result<()> {
        let m = &self.bundle.manifest;
        ensure!(ctrl.len() == m.ctrl_len, "ctrl len {} != {}", ctrl.len(), m.ctrl_len);
        let state = self.state.as_ref().context("session not initialized")?;
        let io = self.upload_batch(batch)?;
        let ctrl_buf = self
            .client()
            .buffer_from_host_buffer::<f32>(ctrl, &[ctrl.len()], None)
            .map_err(xerr)?;
        let exe = if attn_frozen {
            &self.bundle.train_step_attn_frozen
        } else {
            &self.bundle.train_step
        };
        let mut args: Vec<&PjRtBuffer> = vec![state];
        args.extend(io.iter());
        args.push(&ctrl_buf);
        let mut out = exe.execute_b(&args).map_err(xerr)?;
        self.state = Some(out.remove(0).remove(0));
        self.step += 1;
        Ok(())
    }

    /// Read the metrics prefix the last train step wrote into the state.
    pub fn probe(&self) -> Result<Vec<f32>> {
        let state = self.state.as_ref().context("session not initialized")?;
        let out = self.bundle.probe.execute_b(&[state]).map_err(xerr)?;
        out[0][0]
            .to_literal_sync()
            .map_err(xerr)?
            .to_vec::<f32>()
            .map_err(xerr)
    }

    /// Forward-only loss on one batch → (loss_sum, token_count).
    pub fn eval_batch(&self, batch: &Batch) -> Result<(f64, f64)> {
        let state = self.state.as_ref().context("session not initialized")?;
        let io = self.upload_batch(batch)?;
        let mut args: Vec<&PjRtBuffer> = vec![state];
        args.extend(io.iter());
        let out = self.bundle.eval_step.execute_b(&args).map_err(xerr)?;
        let v = out[0][0]
            .to_literal_sync()
            .map_err(xerr)?
            .to_vec::<f32>()
            .map_err(xerr)?;
        Ok((v[0] as f64, v[1] as f64))
    }

    /// Per-row (loss_sum, count) pairs — multiple-choice scoring.
    pub fn eval_rows(&self, batch: &Batch) -> Result<Vec<(f64, f64)>> {
        let state = self.state.as_ref().context("session not initialized")?;
        let io = self.upload_batch(batch)?;
        let mut args: Vec<&PjRtBuffer> = vec![state];
        args.extend(io.iter());
        let out = self.bundle.eval_rows.execute_b(&args).map_err(xerr)?;
        let v = out[0][0]
            .to_literal_sync()
            .map_err(xerr)?
            .to_vec::<f32>()
            .map_err(xerr)?;
        let b = v.len() / 2;
        Ok((0..b).map(|i| (v[i] as f64, v[b + i] as f64)).collect())
    }

    /// Mean validation loss over many batches (the classic-ES hot cost).
    pub fn eval_mean_loss(&self, batches: &[Batch]) -> Result<f64> {
        let mut loss = 0.0;
        let mut count = 0.0;
        for b in batches {
            let (l, c) = self.eval_batch(b)?;
            loss += l;
            count += c;
        }
        Ok(if count > 0.0 { loss / count } else { f64::NAN })
    }

    /// Download the full state (checkpointing / inspection).
    pub fn state_to_host(&self) -> Result<Vec<f32>> {
        let state = self.state.as_ref().context("session not initialized")?;
        state.to_literal_sync().map_err(xerr)?.to_vec::<f32>().map_err(xerr)
    }

    /// Restore a previously downloaded state.
    pub fn state_from_host(&mut self, host: &[f32]) -> Result<()> {
        let m = &self.bundle.manifest;
        ensure!(host.len() == m.state_len, "state len {} != {}", host.len(), m.state_len);
        self.state = Some(
            self.client()
                .buffer_from_host_buffer::<f32>(host, &[host.len()], None)
                .map_err(xerr)?,
        );
        Ok(())
    }

    /// Save / load binary checkpoints (f32 little-endian + step header).
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        let host = self.state_to_host()?;
        let mut bytes = Vec::with_capacity(8 + host.len() * 4);
        bytes.extend_from_slice(&(self.step as u64).to_le_bytes());
        for x in &host {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let bytes = std::fs::read(path)?;
        ensure!(bytes.len() >= 8 && (bytes.len() - 8) % 4 == 0, "corrupt checkpoint");
        self.step = u64::from_le_bytes(bytes[..8].try_into().unwrap()) as usize;
        let host: Vec<f32> = bytes[8..]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        self.state_from_host(&host)
    }
}
