//! The host engine's step-scoped workspace arena: reusable `f32`
//! buffers ([`Buf`]) carved out of per-thread free lists, so the
//! steady-state training loop performs **zero per-step heap growth** —
//! every activation, gradient and packing buffer a step needs was
//! already allocated by an earlier step and is recycled here.
//!
//! # Design
//!
//! A [`Buf`] wraps a plain `Vec<f32>` and derefs to `[f32]`, so the
//! kernel layer and the backend math never know whether a buffer came
//! from the arena or from the system allocator. On drop, the vector's
//! storage returns to a thread-local pool keyed by *exact* length;
//! [`buf_raw`]/[`buf_zeroed`] pop from that pool first and only fall
//! back to a fresh allocation on a miss. Pools are per-thread (the
//! backend's scoped kernel workers never own a `Buf` — callers carve
//! every worker-visible scratch slice *before* fanning out), so there
//! is no locking on the hot path.
//!
//! Because a training step's buffer demand is shape-stable, each pool
//! converges after the first step: inventory per size equals that
//! size's peak live count, and from then on every request is a carve.
//! The cumulative [`arena_counters`] (bytes carved vs. freshly
//! allocated) make that visible — `StepTimings` reports the per-step
//! deltas, and a host test pins "fresh bytes per steady-state step
//! == 0" under a counting allocator.
//!
//! # Determinism
//!
//! The arena never changes a single arithmetic operation — it only
//! changes where the bytes live. Trajectories are therefore bitwise
//! identical with the arena on or off (`GRADES_HOST_ARENA=0`), which
//! the property suite asserts alongside the SIMD-level and
//! thread-count invariances.
//!
//! # Knobs
//!
//! | `GRADES_HOST_ARENA` | behavior |
//! |---|---|
//! | unset / `1` / `auto` | pool and recycle (default) |
//! | `0` | every buffer is a fresh allocation, drops free immediately |
//!
//! plus a process-global test/bench override ([`set_arena_override`]).

use std::cell::RefCell;
use std::collections::HashMap;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::OnceLock;

/// Process-global override slot: 0 = none, 1 = force off, 2 = force on.
static ARENA_OVERRIDE: AtomicU8 = AtomicU8::new(0);
/// Cumulative bytes served from a pool free list.
static CARVED_BYTES: AtomicU64 = AtomicU64::new(0);
/// Cumulative bytes served by fresh allocations.
static FRESH_BYTES: AtomicU64 = AtomicU64::new(0);

/// `GRADES_HOST_ARENA` with the `GRADES_HOST_SIMD`-style warn-once
/// validation: `0` disables pooling, unset/`1`/`auto` enable it,
/// anything else warns once and stays enabled.
fn env_arena() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| match std::env::var("GRADES_HOST_ARENA") {
        Err(_) => true,
        Ok(v) => match v.trim() {
            "" | "1" | "auto" => true,
            "0" => false,
            other => {
                eprintln!(
                    "[host] ignoring GRADES_HOST_ARENA={other:?}: expected 0, 1 or auto; \
                     keeping the workspace arena enabled"
                );
                true
            }
        },
    })
}

/// Whether buffers recycle through the pool. Purely a wall-clock and
/// allocator-traffic knob: results are bitwise identical either way.
pub fn arena_enabled() -> bool {
    match ARENA_OVERRIDE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => env_arena(),
    }
}

/// Force the arena on or off for this process (`None` restores the
/// `GRADES_HOST_ARENA` behavior) — the property tests A/B both modes in
/// one process with this.
pub fn set_arena_override(enabled: Option<bool>) {
    let v = match enabled {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    };
    ARENA_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Cumulative `(carved_bytes, fresh_bytes)` across the process: bytes
/// served from a pool free list vs. freshly allocated. `Session`
/// records per-step deltas of these into `StepTimings`.
pub fn arena_counters() -> (u64, u64) {
    (CARVED_BYTES.load(Ordering::Relaxed), FRESH_BYTES.load(Ordering::Relaxed))
}

thread_local! {
    /// Exact-size free lists. Keyed by element count; every entry's
    /// `len == capacity == key`.
    static POOL: RefCell<HashMap<usize, Vec<Vec<f32>>>> = RefCell::new(HashMap::new());
}

/// A pooled `f32` workspace buffer. Derefs to `[f32]`; dropping it
/// returns the storage to the current thread's free list (when the
/// arena is enabled), and [`Clone`] carves the copy's storage from the
/// pool too. Not `Send` by policy: buffers live on the thread that
/// carved them, and scoped kernel workers only ever see `&mut [f32]`
/// slices of a caller-owned `Buf`.
pub struct Buf {
    v: Vec<f32>,
}

impl Buf {
    /// Wrap an already-built vector (counted as fresh bytes). The
    /// storage still recycles through the pool on drop.
    pub fn from_vec(v: Vec<f32>) -> Buf {
        FRESH_BYTES.fetch_add((v.len() * 4) as u64, Ordering::Relaxed);
        Buf { v: exact(v) }
    }

    /// Carve a buffer and copy `src` into it.
    pub fn from_slice(src: &[f32]) -> Buf {
        let mut b = buf_raw(src.len());
        b.v.copy_from_slice(src);
        b
    }
}

/// Shrink so `len == capacity` — the pool's free lists are keyed by
/// exact length, and a capacity ≠ len vector would leak capacity bytes
/// out of the accounting.
fn exact(mut v: Vec<f32>) -> Vec<f32> {
    if v.capacity() != v.len() {
        v.shrink_to_fit();
    }
    v
}

/// Carve an `n`-element buffer with **unspecified contents** (possibly
/// stale data from a previous step). Use only where every element is
/// written before it is read — kernel outputs, packing buffers,
/// worker scratch.
pub fn buf_raw(n: usize) -> Buf {
    if arena_enabled() {
        let hit = POOL.with(|p| p.borrow_mut().get_mut(&n).and_then(|list| list.pop()));
        if let Some(v) = hit {
            CARVED_BYTES.fetch_add((n * 4) as u64, Ordering::Relaxed);
            debug_assert_eq!(v.len(), n);
            return Buf { v };
        }
    }
    FRESH_BYTES.fetch_add((n * 4) as u64, Ordering::Relaxed);
    Buf { v: exact(vec![0f32; n]) }
}

/// Carve an `n`-element buffer filled with zeros (accumulation
/// targets: gradients, scatter outputs).
pub fn buf_zeroed(n: usize) -> Buf {
    let mut b = buf_raw(n);
    b.v.fill(0.0);
    b
}

impl Deref for Buf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.v
    }
}

impl DerefMut for Buf {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.v
    }
}

impl Clone for Buf {
    fn clone(&self) -> Buf {
        Buf::from_slice(&self.v)
    }
}

impl std::fmt::Debug for Buf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Buf[{}]", self.v.len())
    }
}

impl Drop for Buf {
    fn drop(&mut self) {
        if !arena_enabled() || self.v.is_empty() {
            return;
        }
        let v = std::mem::take(&mut self.v);
        // `try_with`: during thread teardown the pool may already be
        // gone — fall back to a plain free.
        let _ = POOL.try_with(|p| {
            let mut p = p.borrow_mut();
            p.entry(v.len()).or_default().push(v);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// The override slot is process-global, so these tests must not
    /// interleave with each other (other unit tests tolerate any
    /// override value — the arena never changes results). Counter
    /// *deltas* stay polluted by concurrently running unit tests even
    /// under this lock, so the assertions below use the thread-local
    /// pool and `>=` bounds; the exact per-step accounting is pinned by
    /// the dedicated single-test `host_arena_alloc` binary instead.
    static LOCK: Mutex<()> = Mutex::new(());

    /// Free-list depth for size `n` on this thread.
    fn pooled(n: usize) -> usize {
        POOL.with(|p| p.borrow().get(&n).map_or(0, |l| l.len()))
    }

    #[test]
    fn carve_recycles_exact_sizes_and_counts_bytes() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_arena_override(Some(true));
        let (c0, f0) = arena_counters();
        // unique length so no other call site pools this size
        let n = 1_031;
        let a = buf_raw(n);
        let ptr = a.as_ptr() as usize;
        let (_, f1) = arena_counters();
        assert!(f1 - f0 >= (n * 4) as u64, "first carve is fresh");
        drop(a);
        assert_eq!(pooled(n), 1, "drop returns the storage to this thread's pool");
        let b = buf_zeroed(n);
        assert_eq!(b.as_ptr() as usize, ptr, "storage recycled");
        assert!(b.iter().all(|&x| x == 0.0), "buf_zeroed clears stale data");
        let (c2, _) = arena_counters();
        assert!(c2 - c0 >= (n * 4) as u64, "the pool hit is counted as carved bytes");
        set_arena_override(None);
    }

    #[test]
    fn disabled_arena_always_allocates_fresh() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_arena_override(Some(false));
        let n = 2_063;
        let a = buf_raw(n);
        drop(a);
        assert_eq!(pooled(n), 0, "disabled arena never pools dropped storage");
        let (_, f0) = arena_counters();
        let b = buf_raw(n);
        let (_, f1) = arena_counters();
        assert!(f1 - f0 >= (n * 4) as u64, "disabled arena allocates fresh");
        drop(b);
        set_arena_override(None);
    }

    #[test]
    fn clone_copies_contents_through_the_pool() {
        let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_arena_override(Some(true));
        let mut a = buf_raw(97);
        for (i, x) in a.iter_mut().enumerate() {
            *x = i as f32;
        }
        let b = a.clone();
        assert!(a.iter().zip(b.iter()).all(|(x, y)| x == y));
        set_arena_override(None);
    }
}
