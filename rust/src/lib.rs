//! # GradES — gradient-based component-level early stopping
//!
//! A full-system reproduction of *GradES: Significantly Faster Training in
//! Transformers with Gradient-Based Early Stopping* as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: the GradES monitor (Alg. 1),
//!   classic validation-ES baseline, executable-variant scheduler, LR
//!   schedules, FLOPs accounting, synthetic data substrates, benchmark
//!   harness and experiment drivers.
//! * **L2 (`python/compile`)** — the transformer / LoRA / VLM compute
//!   graphs, AOT-lowered once to HLO text.
//! * **L1 (`python/compile/kernels`)** — Pallas kernels for the GradES
//!   gradient statistics and the freeze-masked optimizer update.
//!
//! Python never runs at training time: the rust binary loads
//! `artifacts/<config>/*.hlo.txt` through PJRT and keeps all training
//! state on device between steps (see `runtime::session`). Execution is
//! backend-generic (`runtime::backend`): the same trainer also runs on a
//! pure-Rust reference transformer (`runtime::host_backend`) with no
//! artifacts at all — `--backend auto|host|xla`.
//!
//! Quickstart: `make artifacts && cargo run --release --example quickstart`.
//! The full onboarding story lives in the repo's `README.md`; the module
//! map, the pipelined runtime, the experiment scheduler and the
//! async-eval design are documented in `docs/ARCHITECTURE.md`.

#![warn(missing_docs)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exp;
pub mod report;
pub mod runtime;
pub mod util;
