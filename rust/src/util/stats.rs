//! Small statistics helpers used by monitors and reports.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Fixed-capacity rolling window with O(1) mean.
#[derive(Debug, Clone)]
pub struct Rolling {
    buf: Vec<f64>,
    cap: usize,
    next: usize,
    sum: f64,
}

impl Rolling {
    /// Window of capacity `cap` (> 0).
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Self { buf: Vec::with_capacity(cap), cap, next: 0, sum: 0.0 }
    }

    /// Append, evicting the oldest value once full.
    pub fn push(&mut self, x: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(x);
            self.sum += x;
        } else {
            self.sum += x - self.buf[self.next];
            self.buf[self.next] = x;
            self.next = (self.next + 1) % self.cap;
        }
    }

    /// Mean of the current window (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            0.0
        } else {
            self.sum / self.buf.len() as f64
        }
    }

    /// Has the window reached capacity?
    pub fn full(&self) -> bool {
        self.buf.len() == self.cap
    }

    /// Values currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True before the first push.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rolling_mean_window() {
        let mut r = Rolling::new(3);
        for x in [1.0, 2.0, 3.0, 4.0] {
            r.push(x);
        }
        assert!((r.mean() - 3.0).abs() < 1e-12); // window = [2,3,4]
        assert!(r.full());
    }

    #[test]
    fn basic_stats() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 3.0]) - 2.0).abs() < 1e-12);
        assert!((std_dev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }
}
