//! Minimal JSON parser + writer (no serde offline — see DESIGN.md).
//!
//! Supports the full JSON value grammar; numbers are kept as f64 which is
//! lossless for every integer the manifests contain (< 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (f64-backed).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with sorted keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Required object member.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    /// Optional object member.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric view.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// Non-negative integer view.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    /// Integer view (truncating).
    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    /// String view.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// Boolean view.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    /// Array view.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// Is this `null`?
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

/// Parse a complete JSON document.
pub fn parse(src: &str) -> Result<Json> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected , or ] at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                _ => {
                    // copy raw utf-8 bytes through
                    let start = self.i - 1;
                    while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

/// Serialize with stable key order (BTreeMap) — good for golden tests.
pub fn write(v: &Json) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => write_str(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": true, "d": null}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\nthere");
        let re = parse(&write(&v)).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"x": {"y": {"z": [{"w": 1}]}}}"#).unwrap();
        let w = v.get("x").unwrap().get("y").unwrap().get("z").unwrap().as_arr().unwrap()[0]
            .get("w")
            .unwrap()
            .as_usize()
            .unwrap();
        assert_eq!(w, 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""éx""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éx");
    }
}
