//! Deterministic RNG: splitmix64 seeding + xoshiro256** core.
//!
//! Every data generator and sampler in the repo derives from this so runs
//! are bit-reproducible from a config seed.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically (same seed ⇒ same stream).
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        Self { s: [splitmix64(&mut x), splitmix64(&mut x), splitmix64(&mut x), splitmix64(&mut x)] }
    }

    /// Derive an independent stream (for per-task/per-split generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// True with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Uniform element of a non-empty slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Index sampled proportional to `weights` (must be non-negative).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_biased() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[1.0, 0.0, 3.0])] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forks_independent() {
        let mut base = Rng::new(1);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
