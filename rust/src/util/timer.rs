//! Wall-clock timing helpers for the bench harness and trainer metrics.

use std::time::Instant;

/// Accumulating named timer: `let _g = t.scope();` style sections.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    /// Start timing now.
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    /// Seconds elapsed since construction/reset.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds elapsed.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    /// Restart the clock.
    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

/// Measure a closure `iters` times, returning per-iteration stats in secs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchStats::from_samples(samples)
}

#[derive(Debug, Clone)]
/// Per-iteration timing distribution from [`bench`].
pub struct BenchStats {
    /// Mean seconds per iteration.
    pub mean: f64,
    /// Fastest iteration.
    pub min: f64,
    /// Slowest iteration.
    pub max: f64,
    /// Median.
    pub p50: f64,
    /// 90th percentile.
    pub p90: f64,
    /// Sample count.
    pub n: usize,
}

impl BenchStats {
    /// Summarize raw per-iteration samples (seconds).
    pub fn from_samples(mut s: Vec<f64>) -> Self {
        assert!(!s.is_empty());
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let q = |p: f64| s[((n as f64 - 1.0) * p).round() as usize];
        BenchStats { mean, min: s[0], max: s[n - 1], p50: q(0.5), p90: q(0.9), n }
    }

    /// Human-readable one-liner in milliseconds.
    pub fn fmt_ms(&self) -> String {
        format!(
            "mean {:.3} ms  p50 {:.3}  p90 {:.3}  min {:.3}  max {:.3}  (n={})",
            self.mean * 1e3,
            self.p50 * 1e3,
            self.p90 * 1e3,
            self.min * 1e3,
            self.max * 1e3,
            self.n
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_stats_ordering() {
        let s = BenchStats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }
}
