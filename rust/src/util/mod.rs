//! Hand-rolled substrates (no external deps available offline).

pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;
