//! Tiny CSV writer for metrics logs and figure series.

use std::fmt::Display;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::Result;

/// Buffered CSV writer with a fixed, arity-checked column count.
pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create (directories included) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(Self { w, cols: header.len() })
    }

    /// Write one row; panics if the arity differs from the header.
    pub fn row<D: Display>(&mut self, values: &[D]) -> Result<()> {
        assert_eq!(values.len(), self.cols, "csv row arity mismatch");
        let line: Vec<String> = values.iter().map(|v| v.to_string()).collect();
        writeln!(self.w, "{}", line.join(","))?;
        Ok(())
    }

    /// Flush buffered rows to disk.
    pub fn flush(&mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_rows() {
        let dir = std::env::temp_dir().join("grades_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.csv");
        {
            let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
            w.row(&[1.5, 2.0]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1.5,2\n");
    }
}
