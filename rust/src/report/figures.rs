//! ASCII line plots for figure drivers (the CSV twins are written by
//! `coordinator::metrics`; these give an at-a-glance view in the terminal).

/// Render multiple named series as an ascii chart (log-y optional).
pub fn ascii_chart(
    title: &str,
    series: &[(&str, Vec<(f64, f64)>)],
    width: usize,
    height: usize,
    log_y: bool,
) -> String {
    let marks = ['*', '+', 'o', 'x', '#', '@', '%', '&'];
    let mut xs: Vec<f64> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    for (_, pts) in series {
        for (x, y) in pts {
            if y.is_finite() && (!log_y || *y > 0.0) {
                xs.push(*x);
                ys.push(if log_y { y.log10() } else { *y });
            }
        }
    }
    if xs.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (x0, x1) = (fmin(&xs), fmax(&xs));
    let (y0, y1) = (fmin(&ys), fmax(&ys));
    let xspan = (x1 - x0).max(1e-12);
    let yspan = (y1 - y0).max(1e-12);
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        for (x, y) in pts {
            if !y.is_finite() || (log_y && *y <= 0.0) {
                continue;
            }
            let yy = if log_y { y.log10() } else { *y };
            let col = (((x - x0) / xspan) * (width - 1) as f64).round() as usize;
            let row = (((yy - y0) / yspan) * (height - 1) as f64).round() as usize;
            grid[height - 1 - row][col.min(width - 1)] = marks[si % marks.len()];
        }
    }
    let mut out = format!("{title}\n");
    let ylab = |v: f64| if log_y { format!("1e{v:.1}") } else { format!("{v:.3}") };
    out.push_str(&format!("{:>8} ┤\n", ylab(y1)));
    for row in &grid {
        out.push_str(&format!("{:8} │{}\n", "", row.iter().collect::<String>()));
    }
    out.push_str(&format!("{:>8} └{}\n", ylab(y0), "─".repeat(width)));
    out.push_str(&format!("{:8}  {:<10} {:>w$.0}\n", "", x0, x1, w = width - 10));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (n, _))| format!("{} {}", marks[i % marks.len()], n))
        .collect();
    out.push_str(&format!("          {}\n", legend.join("   ")));
    out
}

fn fmin(v: &[f64]) -> f64 {
    v.iter().copied().fold(f64::INFINITY, f64::min)
}

fn fmax(v: &[f64]) -> f64 {
    v.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chart_renders() {
        let s = ascii_chart(
            "test",
            &[("a", vec![(0.0, 1.0), (1.0, 2.0)]), ("b", vec![(0.0, 2.0), (1.0, 1.0)])],
            20,
            5,
            false,
        );
        assert!(s.contains("test"));
        assert!(s.contains('*'));
        assert!(s.contains('+'));
    }

    #[test]
    fn empty_series_ok() {
        let s = ascii_chart("x", &[("a", vec![])], 10, 4, true);
        assert!(s.contains("no data"));
    }
}
