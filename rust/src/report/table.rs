//! Markdown-ish table rendering with aligned columns and bold-best marks.

/// A simple table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    /// Column headers.
    pub header: Vec<String>,
    /// Body rows (header arity each).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given header.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row; panics on arity mismatch.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Bold the max numeric value in `col` among rows where `key_col`
    /// matches each distinct key (paper-style per-category best marks).
    pub fn bold_best_by(&mut self, key_col: usize, col: usize) {
        use std::collections::BTreeMap;
        let mut best: BTreeMap<String, (f64, usize)> = BTreeMap::new();
        for (i, r) in self.rows.iter().enumerate() {
            if let Ok(v) = r[col].parse::<f64>() {
                let e = best.entry(r[key_col].clone()).or_insert((f64::NEG_INFINITY, i));
                if v > e.0 {
                    *e = (v, i);
                }
            }
        }
        for (_, (_, i)) in best {
            let cell = &mut self.rows[i][col];
            *cell = format!("**{cell}**");
        }
    }

    /// Render as an aligned markdown table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for c in 0..ncol {
            width[c] = self.header[c].len();
            for r in &self.rows {
                width[c] = width[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let parts: Vec<String> = cells
                .iter()
                .zip(width)
                .map(|(c, w)| format!("{c:<w$}", w = *w))
                .collect();
            format!("| {} |\n", parts.join(" | "))
        };
        out.push_str(&fmt_row(&self.header, &width));
        let sep: Vec<String> = width.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
        }
        out
    }
}

/// Format helpers matching the paper's precision conventions.
pub fn pct(x: f64) -> String {
    format!("{x:.2}")
}

/// "1.57x"-style ratio.
pub fn speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Scientific notation with 2 decimals.
pub fn sci(x: f64) -> String {
    format!("{x:.2e}")
}

/// Seconds with 1 decimal.
pub fn secs(x: f64) -> String {
    format!("{x:.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["a", "method"]);
        t.row(vec!["1", "x"]);
        t.row(vec!["22", "longer"]);
        let s = t.render();
        assert!(s.contains("| a  | method |"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn bold_best_per_key() {
        let mut t = Table::new(vec!["model", "acc"]);
        t.row(vec!["m1", "90.0"]);
        t.row(vec!["m1", "91.5"]);
        t.row(vec!["m2", "80.0"]);
        t.bold_best_by(0, 1);
        assert_eq!(t.rows[1][1], "**91.5**");
        assert_eq!(t.rows[2][1], "**80.0**");
        assert_eq!(t.rows[0][1], "90.0");
    }
}
