//! Rendering: markdown tables and ascii figures for experiment drivers.

pub mod figures;
pub mod table;
