//! `grades` — CLI launcher for the GradES reproduction.
//!
//! Subcommands:
//!   train     one training job: --config lm-tiny-fp --method grades
//!   repro     regenerate paper tables/figures: lm | vlm | ablation | fig1 | all
//!   info      print an artifact's manifest summary
//!   list      list available configs
//!
//! (Arg parsing is hand-rolled: no clap offline — see DESIGN.md.)

use anyhow::{anyhow, bail, Result};

use grades::config::{repo_root, RepoConfig};
use grades::coordinator::trainer::{self, StoppingMethod, TrainerOptions};
use grades::data;
use grades::eval::{benchmarks, harness};
use grades::exp::{self, ExpOptions};
use grades::runtime::async_eval::{AsyncEvalOptions, StalenessBound};
use grades::runtime::backend::{load_backend, Backend, BackendChoice};
use grades::runtime::pipeline::{BatchSource, FixedCycle, PipelineOptions, Prefetcher};

struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::BTreeMap::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Args { positional, flags }
}

impl Args {
    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(String::as_str)
    }

    fn usize_flag(&self, k: &str) -> Result<Option<usize>> {
        self.get(k).map(|v| v.parse().map_err(|e| anyhow!("--{k}: {e}"))).transpose()
    }
}

fn backend_choice(args: &Args) -> Result<BackendChoice> {
    match args.get("backend") {
        None => Ok(BackendChoice::Auto),
        Some(v) => BackendChoice::parse(v)
            .ok_or_else(|| anyhow!("--backend must be auto|host|xla, got {v:?}")),
    }
}

fn exp_options(args: &Args) -> Result<ExpOptions> {
    let mut opts = ExpOptions::default();
    if args.get("quick").is_some() {
        opts = ExpOptions::quick(60, 16);
        opts.verbose = true;
    }
    opts.backend = backend_choice(args)?;
    if let Some(s) = args.usize_flag("steps")? {
        opts.steps_override = Some(s);
    }
    if let Some(q) = args.usize_flag("questions")? {
        opts.questions = q;
    }
    if let Some(o) = args.get("out") {
        opts.out_dir = o.into();
    }
    // Scheduler knobs: --jobs beats GRADES_JOBS beats sequential; --fresh
    // ignores the run manifest (completed cells re-run and are rewritten).
    let env_jobs = std::env::var("GRADES_JOBS").ok();
    opts.jobs = grades::exp::scheduler::resolve_jobs(args.usize_flag("jobs")?, env_jobs.as_deref());
    // --workers beats GRADES_WORKERS beats 0 (no worker processes):
    // > 0 runs distributable graphs on the fault-tolerant
    // coordinator/worker runtime, each worker owning its own engines.
    let env_workers = std::env::var("GRADES_WORKERS").ok();
    opts.workers = grades::exp::scheduler::resolve_workers(
        args.usize_flag("workers")?,
        env_workers.as_deref(),
    );
    opts.resume = args.get("fresh").is_none();
    Ok(opts)
}

fn cmd_train(args: &Args) -> Result<()> {
    let config = args.get("config").ok_or_else(|| anyhow!("--config required"))?;
    let method = StoppingMethod::parse(args.get("method").unwrap_or("grades"))
        .ok_or_else(|| anyhow!("--method must be base|es|grades|eb|spectral|ies"))?;
    let cfg = RepoConfig::by_name(config)?;
    // `auto` (the default) runs the compiled artifacts when they exist
    // and the pure-Rust host backend otherwise; `--backend host|xla`
    // forces one side.
    let backend = load_backend(backend_choice(args)?, config)?;
    let backend = &*backend;
    let mut topts = TrainerOptions::from_config(&cfg, method);
    if let Some(s) = args.usize_flag("steps")? {
        topts.total_steps = s;
    }
    if args.get("no-pipeline").is_some() {
        topts.pipeline = PipelineOptions::off();
    }
    // AutoFreeze-style backward truncation below a fully-frozen layer
    // prefix (host engine; trajectory-changing once it engages).
    if args.get("truncate-bwd").is_some() {
        topts.truncate_frozen_prefix = true;
    }
    // Async chunked validation: --async-eval turns it on; --eval-chunk
    // sets batches per train step (default 1); --staleness bounds how
    // many steps late the stopping decision may land (default: whenever
    // the chunked pass finishes; 0 = synchronous, bitwise-identical).
    if args.get("async-eval").is_some()
        || args.get("eval-chunk").is_some()
        || args.get("staleness").is_some()
    {
        let chunk = args.usize_flag("eval-chunk")?.unwrap_or(1);
        let staleness = match args.usize_flag("staleness")? {
            Some(k) => StalenessBound { max_steps: k },
            None => StalenessBound::unbounded(),
        };
        topts.async_eval = AsyncEvalOptions { chunk: chunk.max(1), staleness };
    }
    let manifest = backend.manifest();
    let is_vlm = manifest.is_vlm();
    let depth = topts.pipeline.prefetch_batches;
    let trained = if is_vlm {
        let ds = data::build_vlm(&cfg, manifest)?;
        let mut source: Box<dyn BatchSource> = if depth > 0 {
            Box::new(Prefetcher::spawn(FixedCycle::new(ds.train), depth))
        } else {
            Box::new(FixedCycle::new(ds.train))
        };
        trainer::run_source_and_keep(backend, &cfg, &topts, &mut *source, &ds.val)?
    } else {
        let ds = data::build_lm(&cfg, manifest)?;
        let mut source: Box<dyn BatchSource> = if depth > 0 {
            Box::new(Prefetcher::spawn(ds.train, depth))
        } else {
            Box::new(ds.train)
        };
        trainer::run_source_and_keep(backend, &cfg, &topts, &mut *source, &ds.val)?
    };
    let o = &trained.outcome;
    println!(
        "\nrun complete: steps={} stop={:?} wall={:.2}s (val {:.2}s, monitor {:.3}s)",
        o.steps_run, o.stop_cause, o.wall_secs, o.validation_secs, o.monitor_secs
    );
    println!(
        "final train loss={:.4} val loss={:.4} frozen={}/{} flops={:.3e}",
        o.log.final_train_loss(),
        o.final_val_loss,
        o.freeze.n_frozen(),
        o.freeze.n(),
        o.flops.total()
    );
    let tm = &o.timings;
    println!(
        "runtime: backend {} | compile {:.2}s | upload {:.1} MB in {:.3}s ({} copies, {} staged, {} ctrl skips) | exec {:.2}s | probe {:.2}s | eval {:.2}s",
        backend.name(),
        backend.compile_secs(),
        tm.upload_bytes as f64 / 1e6,
        tm.upload_secs,
        tm.uploads,
        tm.staged_uploads,
        tm.ctrl_skips,
        tm.exec_secs,
        tm.probe_secs,
        tm.eval_secs,
    );
    let ae = &o.async_eval;
    if ae.issued > 0 {
        println!(
            "async eval: {} check(s) issued, {} applied ({} forced drains, {} displaced, {} abandoned) over {} chunk evals / {} snapshots",
            ae.issued,
            ae.completed,
            ae.forced_drains,
            ae.displaced,
            ae.abandoned,
            ae.chunk_evals,
            tm.snapshots,
        );
    }
    if o.plan.elided_steps > 0 {
        println!(
            "step planner: {} elided step(s) from step {} (max {} components omitted, {} downgrade(s)); {} dW matmuls skipped on the {} engine — flops realized {:.3e} of {:.3e} theoretical savings",
            o.plan.elided_steps,
            // guaranteed Some whenever elided_steps > 0
            o.plan.first_elision_step.unwrap_or(0),
            o.plan.max_omitted,
            o.plan.downgrades,
            tm.dw_elided,
            backend.name(),
            o.flops.realized_savings(),
            o.flops.theoretical_savings(),
        );
    }
    if let Some(s) = o.variant_swap_step {
        println!("step planner: plan omits all attention from step {s} (XLA attn-frozen graph reachable)");
    }
    for e in &o.freeze.events {
        println!(
            "  step {:>5}: {} component {} ({}) [{}] metric={:.4e}",
            e.step,
            if e.frozen { "froze " } else { "unfroze" },
            e.component,
            manifest.components[e.component].name,
            e.reason.label(),
            e.metric_value
        );
    }
    if args.get("bench").is_some() && !is_vlm {
        let vocab = grades::data::vocab::Vocab::build(manifest.vocab_size)?;
        let suites = benchmarks::lm_suites(&vocab, 0xbe9c, 32);
        let accs = harness::score_suites(&trained.session, &suites)?;
        for (name, acc) in accs {
            println!("  {name:<12} {acc:.2}%");
        }
    }
    if let Some(dir) = args.get("log-dir") {
        let dir = std::path::Path::new(dir);
        o.log.write_loss_csv(&dir.join(format!("{config}_{}_loss.csv", method.label())))?;
        o.log.write_frozen_csv(&dir.join(format!("{config}_{}_frozen.csv", method.label())))?;
        o.log.write_timings_json(&dir.join(format!("{config}_{}_timings.json", method.label())))?;
        println!("logs written to {}", dir.display());
    }
    if let Some(ckpt) = args.get("save") {
        trained.session.save_checkpoint(std::path::Path::new(ckpt))?;
        println!("checkpoint saved to {ckpt}");
    }
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let what = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let opts = exp_options(args)?;
    // No client here: the runner's engine cache creates one lazily when a
    // config resolves to the XLA backend (host-only runs never pay it).
    match what {
        "lm" | "table1" | "table4" | "fig3" => {
            exp::lm_matrix::run(&opts, &exp::lm_matrix::SCALES)?;
        }
        "vlm" | "table2" | "table3" | "table5" | "fig4b" => {
            exp::vlm::run(&opts)?;
        }
        "ablation" | "table6" | "table7" => {
            let cfg = args.get("config").unwrap_or("lm-tiny-fp");
            exp::ablation::run(&opts, cfg)?;
        }
        "fig1" | "fig4a" => {
            let cfg = args.get("config").unwrap_or("lm-tiny-fp");
            let layer = args.usize_flag("layer")?.unwrap_or(1);
            exp::fig1::run(&opts, cfg, layer)?;
        }
        "all" => {
            exp::fig1::run(&opts, "lm-tiny-fp", 1)?;
            exp::lm_matrix::run(&opts, &exp::lm_matrix::SCALES)?;
            exp::vlm::run(&opts)?;
            exp::ablation::run(&opts, "lm-tiny-fp")?;
        }
        other => bail!("unknown repro target {other:?} (lm|vlm|ablation|fig1|all)"),
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let config = args.get("config").ok_or_else(|| anyhow!("--config required"))?;
    let m = grades::runtime::manifest::Manifest::load(
        &repo_root().join("artifacts").join(config).join("manifest.json"),
    )?;
    println!("name        {}", m.name);
    println!(
        "kind        {} method={} optimizer={} kernels={}",
        m.kind, m.method, m.optimizer, m.kernel_impl
    );
    println!("batch/seq   {} x {}   vocab {}", m.batch_size, m.seq_len, m.vocab_size);
    println!("params      {} total, {} trainable", m.n_params_total, m.n_params_trainable);
    println!("state_len   {} f32 ({:.1} MB)", m.state_len, m.state_len as f64 * 4.0 / 1e6);
    println!("components  {} monitored", m.n_components);
    println!("flops/tok   fwd {:.3e}", m.flops.fwd_per_token);
    for (k, v) in &m.executables {
        println!("  exe {k:<24} {v}");
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    let dir = repo_root().join("configs");
    for entry in std::fs::read_dir(dir)? {
        let p = entry?.path();
        if p.extension().and_then(|e| e.to_str()) == Some("toml") {
            let cfg = RepoConfig::load(&p)?;
            let art = cfg.artifact_dir().join("manifest.json").exists();
            println!(
                "{:<16} steps={:<5} tau={:<8} alpha={:<4} artifacts={}",
                cfg.name,
                cfg.run.total_steps,
                cfg.grades.tau,
                cfg.grades.alpha,
                if art { "yes" } else { "NO (run make artifacts)" }
            );
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = parse_args(&argv);
    match args.positional.first().map(String::as_str) {
        Some("train") => cmd_train(&args),
        Some("repro") => cmd_repro(&args),
        // Internal: spawned by `grades repro --workers M` as a child
        // process speaking the stdio protocol. Harmless to run by hand —
        // it exits on stdin EOF.
        Some("worker") => grades::exp::worker::run_worker(),
        Some("info") => cmd_info(&args),
        Some("list") => cmd_list(),
        _ => {
            eprintln!(
                "usage: grades <train|repro|info|list> [flags]\n\
                 \n\
                 grades train --config lm-tiny-fp --method grades [--steps N] [--bench] [--log-dir D] [--save ckpt] [--no-pipeline]\n\
                 \x20            [--backend auto|host|xla] [--async-eval] [--eval-chunk B] [--staleness K] [--truncate-bwd]\n\
                 \x20   --backend B     execution engine: compiled XLA artifacts, the pure-Rust host\n\
                 \x20                   transformer, or auto (host when artifacts are missing; default)\n\
                 \x20   --async-eval    chunk classic-ES validation between train steps instead of blocking\n\
                 \x20   --eval-chunk B  val batches evaluated per train step while a pass is in flight (default 1)\n\
                 \x20   --staleness K   apply a check's stop decision at most K steps late (0 = synchronous)\n\
                 \x20   --truncate-bwd  stop the host backward sweep below a fully-frozen layer prefix\n\
                 \x20                   (AutoFreeze-style; holds that prefix's norms + embeddings)\n\
                 grades repro <lm|vlm|ablation|fig1|all> [--quick] [--steps N] [--questions Q] [--out D] [--jobs N] [--workers M] [--fresh] [--backend B]\n\
                 \x20   --jobs N      run experiment jobs on N in-process workers (or GRADES_JOBS=N); 1 = sequential\n\
                 \x20   --workers M   run jobs on M worker *processes* (or GRADES_WORKERS=M) with job leases,\n\
                 \x20                 heartbeats, and bounded retry; 0 = in-process pool only (default).\n\
                 \x20                 Falls back to --jobs when the graph or environment can't distribute.\n\
                 \x20   --fresh       ignore the resumable run manifest under --out and re-run every job\n\
                 grades worker    (internal: spawned per worker process by repro --workers;\n\
                 \x20                GRADES_FAULT=<worker>:<panic|hang|sigkill|garble>@<nth> injects faults)\n\
                 grades info --config lm-tiny-fp\n\
                 grades list"
            );
            std::process::exit(2);
        }
    }
}
