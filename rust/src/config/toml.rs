//! Minimal TOML subset parser — enough for `configs/*.toml`.
//!
//! Supported: `[table]` headers (one level of nesting via dotted headers is
//! not needed), `key = value` with strings, integers, floats, booleans and
//! homogeneous arrays, `#` comments, blank lines.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
/// One parsed TOML value.
pub enum TomlValue {
    /// A quoted string.
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A `[...]` array.
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    /// Numeric view (ints widen to f64).
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Int(i) => Ok(*i as f64),
            TomlValue::Float(f) => Ok(*f),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// Integer view.
    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            _ => bail!("not an integer: {self:?}"),
        }
    }

    /// Non-negative integer view.
    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        if i < 0 {
            bail!("negative where usize expected: {i}");
        }
        Ok(i as usize)
    }

    /// String view.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// Boolean view.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }
}

/// One `[table]`'s key → value map.
pub type Table = BTreeMap<String, TomlValue>;

/// Parsed document: top-level keys + named tables.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    /// Keys above the first table header.
    pub root: Table,
    /// Named `[table]` sections, in name order.
    pub tables: BTreeMap<String, Table>,
}

impl TomlDoc {
    /// The named table, or an error when absent.
    pub fn table(&self, name: &str) -> Result<&Table> {
        self.tables.get(name).ok_or_else(|| anyhow!("missing [{name}] table"))
    }

    /// The named table, or an empty one (defaults apply).
    pub fn table_or_empty(&self, name: &str) -> Table {
        self.tables.get(name).cloned().unwrap_or_default()
    }
}

/// Parse a TOML document (the supported subset above).
pub fn parse(src: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut current: Option<String> = None;
    for (lineno, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: malformed table header", lineno + 1))?
                .trim()
                .to_string();
            doc.tables.entry(name.clone()).or_default();
            current = Some(name);
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = k.trim().to_string();
        let val = parse_value(v.trim())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        match &current {
            Some(t) => {
                doc.tables.get_mut(t).unwrap().insert(key, val);
            }
            None => {
                doc.root.insert(key, val);
            }
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(TomlValue::Str(unescape(inner)));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| anyhow!("unterminated array"))?;
        let mut out = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                out.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Arr(out));
    }
    // number: int if it parses as i64 and has no float syntax
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

fn unescape(s: &str) -> String {
    let mut out = String::new();
    let mut it = s.chars();
    while let Some(c) = it.next() {
        if c == '\\' {
            match it.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_config_shape() {
        let doc = parse(
            r#"
name = "x" # comment
[model]
d_model = 64
lr = 2e-5
flag = true
arr = [1, 2, 3]
[train]
opt = "adamw"
"#,
        )
        .unwrap();
        assert_eq!(doc.root["name"].as_str().unwrap(), "x");
        assert_eq!(doc.table("model").unwrap()["d_model"].as_usize().unwrap(), 64);
        assert!((doc.table("model").unwrap()["lr"].as_f64().unwrap() - 2e-5).abs() < 1e-12);
        assert!(doc.table("model").unwrap()["flag"].as_bool().unwrap());
        assert_eq!(
            doc.table("model").unwrap()["arr"],
            TomlValue::Arr(vec![TomlValue::Int(1), TomlValue::Int(2), TomlValue::Int(3)])
        );
        assert_eq!(doc.table("train").unwrap()["opt"].as_str().unwrap(), "adamw");
    }

    #[test]
    fn hash_inside_string() {
        let doc = parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.root["k"].as_str().unwrap(), "a#b");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("[oops").is_err());
        assert!(parse("novalue").is_err());
    }

    #[test]
    fn parses_real_config_files() {
        for entry in std::fs::read_dir(concat!(env!("CARGO_MANIFEST_DIR"), "/configs")).unwrap() {
            let p = entry.unwrap().path();
            if p.extension().and_then(|e| e.to_str()) == Some("toml") {
                let src = std::fs::read_to_string(&p).unwrap();
                parse(&src).unwrap_or_else(|e| panic!("{p:?}: {e}"));
            }
        }
    }
}
