//! Typed run configuration, loaded from the same `configs/*.toml` files the
//! AOT exporter reads (python consumes `[model]`/`[train]`/`[vlm]`; rust consumes
//! those plus `[run]`/`[grades]`/`[eb]`/`[spectral]`/`[ies]`/`[es]`/`[data]`).

pub mod toml;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use self::toml::{Table, TomlDoc};

fn get_f64(t: &Table, k: &str, default: f64) -> f64 {
    t.get(k).and_then(|v| v.as_f64().ok()).unwrap_or(default)
}

fn get_usize(t: &Table, k: &str, default: usize) -> usize {
    t.get(k).and_then(|v| v.as_usize().ok()).unwrap_or(default)
}

fn get_str(t: &Table, k: &str, default: &str) -> String {
    t.get(k).and_then(|v| v.as_str().ok()).unwrap_or(default).to_string()
}

fn get_bool(t: &Table, k: &str, default: bool) -> bool {
    t.get(k).and_then(|v| v.as_bool().ok()).unwrap_or(default)
}

/// Model shapes (`[model]`) — previously consumed only by the Python
/// AOT exporter; the pure-Rust host backend reads the same table to
/// synthesize its layout/manifest without any artifacts on disk.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// "lm" or "vlm".
    pub kind: String,
    /// Tokenizer vocabulary size.
    pub vocab_size: usize,
    /// Residual-stream width D.
    pub d_model: usize,
    /// Transformer block count.
    pub n_layers: usize,
    /// Attention head count (D must divide evenly).
    pub n_heads: usize,
    /// SwiGLU hidden width.
    pub d_ff: usize,
    /// Maximum (== compiled) sequence length.
    pub max_seq: usize,
    /// (`[vlm]`) Image patches per example P (0 for pure LMs).
    pub n_patches: usize,
    /// (`[vlm]`) Flattened per-patch feature width.
    pub patch_dim: usize,
    /// (`[vlm]`) Vision-tower residual width D_v.
    pub d_vision: usize,
    /// (`[vlm]`) Vision-tower block count.
    pub n_vision_layers: usize,
    /// (`[vlm]`) Vision-tower head count (D_v must divide evenly).
    pub n_vision_heads: usize,
    /// (`[vlm]`) Vision-tower SwiGLU hidden width.
    pub d_vision_ff: usize,
}

/// Training hyperparameters (`[train]`) — batch shape, optimizer and its
/// constants. Defaults mirror `python/compile/configs.py::TrainConfig`.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Fixed batch size B.
    pub batch_size: usize,
    /// Fixed sequence length T.
    pub seq_len: usize,
    /// "adamw" or "sgd".
    pub optimizer: String,
    /// "fp" (full parameter) or "lora".
    pub method: String,
    /// Decoupled weight decay (scaled per step by `ctrl[2]`).
    pub weight_decay: f64,
    /// AdamW β₁.
    pub beta1: f64,
    /// AdamW β₂.
    pub beta2: f64,
    /// AdamW ε.
    pub eps: f64,
    /// SGD momentum.
    pub momentum: f64,
    /// LoRA rank r (adapters are A∈R^{d_in×r}, B∈R^{r×d_out}).
    pub lora_rank: usize,
    /// LoRA α; merged weight is W + (α/r)·A·B.
    pub lora_alpha: f64,
}

/// Training-run hyperparameters (`[run]`).
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Total optimizer-step budget T.
    pub total_steps: usize,
    /// Peak learning rate of the cosine schedule.
    pub lr: f64,
    /// Linear-warmup fraction of the budget.
    pub warmup_frac: f64,
    /// Parameter-init RNG seed.
    pub seed: u64,
}

/// GradES monitor settings (`[grades]`, paper Alg. 1 + App. C).
#[derive(Debug, Clone)]
pub struct GradesConfig {
    /// "l1_diff" (Eq. 1) or "l1_abs" (§3.1 alternative).
    pub metric: String,
    /// Grace-period fraction α: monitoring starts at ⌈αT⌉.
    pub alpha: f64,
    /// Convergence threshold τ.
    pub tau: f64,
    /// Component-specific thresholds for VLM towers (paper Table 10);
    /// NaN = fall back to `tau`.
    pub tau_vision: f64,
    /// Language-tower τ override (VLMs; NaN = fall back to `tau`).
    pub tau_language: f64,
    /// Consecutive sub-τ steps required before freezing (0 = freeze
    /// immediately, the paper's "static freezing"; >0 = the patience
    /// extension from §8 future work).
    pub patience: usize,
    /// Allow unfreezing when a frozen component's *observed* gradient
    /// magnitude rebounds above `unfreeze_factor · τ` (§8 dynamic
    /// freezing extension; 0.0 disables).
    pub unfreeze_factor: f64,
    /// Freeze granularity: "matrix" (GradES) or "layer" (AutoFreeze-style
    /// ablation baseline — a layer freezes only when all 7 matrices agree).
    pub granularity: String,
}

/// Evidence-based stopping criterion settings (`[eb]`, Mahsereci & Lassner
/// arXiv:1703.09580 adapted to per-component freezing).
#[derive(Debug, Clone)]
pub struct EbConfig {
    /// Carry an exact per-component gradient-variance slot in the host
    /// layout (`gvar`). Off by default: the layout (and every golden
    /// trajectory pinned to it) stays byte-identical, and the EB monitor
    /// estimates evidence from the Gdiff/Gabs scalars instead.
    pub gvar: bool,
    /// Grace-period fraction: no freeze decisions before ⌈alpha·T⌉.
    pub alpha: f64,
    /// Freeze component `c` once its evidence `e[c]` exceeds this margin
    /// (the EB criterion's threshold; 0.0 = the paper's stopping point).
    pub margin: f64,
    /// Consecutive above-margin observations required before freezing.
    pub patience: usize,
}

/// Spectral stopping settings (`[spectral]`, Marchenko–Pastur edge test on
/// per-component weight spectra, arXiv:2510.16074).
#[derive(Debug, Clone)]
pub struct SpectralConfig {
    /// Grace-period fraction: no spectrum scans before ⌈alpha·T⌉.
    pub alpha: f64,
    /// Scan every ⌈interval_frac·T⌉ steps (spectra need a weight pull,
    /// so the cadence is coarser than the gradient-probe cadence).
    pub interval_frac: f64,
    /// Freeze when the relative spectral drift between consecutive scans
    /// falls below this threshold.
    pub tau: f64,
    /// Consecutive sub-τ scans required before freezing.
    pub patience: usize,
}

/// Instance-dependent early stopping settings (`[ies]`, per-sample
/// loss-rank exclusion, arXiv:2502.07547).
#[derive(Debug, Clone)]
pub struct IesConfig {
    /// Grace-period fraction: no exclusions before ⌈alpha·T⌉.
    pub alpha: f64,
    /// Check every ⌈check_interval_frac·T⌉ steps.
    pub check_interval_frac: f64,
    /// Fraction of active rows (lowest per-token loss first) that become
    /// exclusion candidates at each check.
    pub drop_frac: f64,
    /// Consecutive candidacies required before a row is excluded.
    pub patience: usize,
    /// Stop training once this fraction of all distinct rows seen has
    /// been excluded.
    pub stop_frac: f64,
}

/// Classic validation-loss early stopping (`[es]`, the paper's +ES baseline).
#[derive(Debug, Clone)]
pub struct EsConfig {
    /// Validate every `check_interval_frac · T` steps (paper: 5%).
    pub check_interval_frac: f64,
    /// Consecutive non-improving checks before stopping.
    pub patience: usize,
    /// Required improvement over the best loss to reset patience.
    pub min_delta: f64,
}

/// Synthetic-data settings (`[data]`).
#[derive(Debug, Clone)]
pub struct DataConfig {
    /// Corpus family (only "grammar" is implemented).
    pub corpus: String,
    /// Data-generation RNG seed.
    pub seed: u64,
    /// Sentences generated for the training split.
    pub train_sentences: usize,
    /// Sentences generated for the fixed validation split.
    pub val_sentences: usize,
}

#[derive(Debug, Clone)]
/// One config file's complete typed contents — everything a run,
/// an artifact load and a dataset build need.
pub struct RepoConfig {
    /// Config/artifact name (`configs/<name>.toml`, `artifacts/<name>/`).
    pub name: String,
    /// Path the config was loaded from.
    pub path: PathBuf,
    /// `[model]` — transformer shapes (host backend + info).
    pub model: ModelConfig,
    /// `[train]` — batch shape, optimizer constants.
    pub train: TrainConfig,
    /// `[run]` — step budget, LR schedule, seed.
    pub run: RunConfig,
    /// `[grades]` — monitor thresholds and extensions.
    pub grades: GradesConfig,
    /// `[eb]` — evidence-based stopping settings.
    pub eb: EbConfig,
    /// `[spectral]` — spectral stopping settings.
    pub spectral: SpectralConfig,
    /// `[ies]` — instance-dependent early-stopping settings.
    pub ies: IesConfig,
    /// `[es]` — classic early-stopping baseline settings.
    pub es: EsConfig,
    /// `[data]` — synthetic-corpus settings.
    pub data: DataConfig,
}

impl RepoConfig {
    /// Load and type a config file; missing tables/keys get the
    /// documented defaults.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let doc: TomlDoc = toml::parse(&src).with_context(|| format!("parsing {path:?}"))?;
        let name = doc
            .root
            .get("name")
            .and_then(|v| v.as_str().ok())
            .map(str::to_string)
            .or_else(|| {
                path.file_stem().and_then(|s| s.to_str()).map(str::to_string)
            })
            .ok_or_else(|| anyhow!("config has no name"))?;

        let run = doc.table_or_empty("run");
        let grades = doc.table_or_empty("grades");
        let eb = doc.table_or_empty("eb");
        let spectral = doc.table_or_empty("spectral");
        let ies = doc.table_or_empty("ies");
        let es = doc.table_or_empty("es");
        let data = doc.table_or_empty("data");
        let model = doc.table_or_empty("model");
        let train = doc.table_or_empty("train");
        let vlm = doc.table_or_empty("vlm");
        Ok(RepoConfig {
            name,
            path,
            model: ModelConfig {
                kind: get_str(&model, "kind", "lm"),
                vocab_size: get_usize(&model, "vocab_size", 0),
                d_model: get_usize(&model, "d_model", 0),
                n_layers: get_usize(&model, "n_layers", 0),
                n_heads: get_usize(&model, "n_heads", 1),
                d_ff: get_usize(&model, "d_ff", 0),
                max_seq: get_usize(&model, "max_seq", 0),
                // [vlm] defaults mirror python/compile/configs.py
                n_patches: get_usize(&vlm, "n_patches", 0),
                patch_dim: get_usize(&vlm, "patch_dim", 0),
                d_vision: get_usize(&vlm, "d_vision", 0),
                n_vision_layers: get_usize(&vlm, "n_vision_layers", 0),
                n_vision_heads: get_usize(&vlm, "n_vision_heads", 1),
                d_vision_ff: get_usize(&vlm, "d_vision_ff", 0),
            },
            train: TrainConfig {
                batch_size: get_usize(&train, "batch_size", 0),
                seq_len: get_usize(&train, "seq_len", 0),
                optimizer: get_str(&train, "optimizer", "adamw"),
                method: get_str(&train, "method", "fp"),
                weight_decay: get_f64(&train, "weight_decay", 0.01),
                beta1: get_f64(&train, "beta1", 0.9),
                beta2: get_f64(&train, "beta2", 0.999),
                eps: get_f64(&train, "eps", 1e-8),
                momentum: get_f64(&train, "momentum", 0.9),
                lora_rank: get_usize(&train, "lora_rank", 4),
                lora_alpha: get_f64(&train, "lora_alpha", 8.0),
            },
            run: RunConfig {
                total_steps: get_usize(&run, "total_steps", 200),
                lr: get_f64(&run, "lr", 1e-3),
                warmup_frac: get_f64(&run, "warmup_frac", 0.05),
                seed: get_usize(&run, "seed", 42) as u64,
            },
            grades: GradesConfig {
                metric: get_str(&grades, "metric", "l1_diff"),
                alpha: get_f64(&grades, "alpha", 0.5),
                tau: get_f64(&grades, "tau", 0.05),
                tau_vision: get_f64(&grades, "tau_vision", f64::NAN),
                tau_language: get_f64(&grades, "tau_language", f64::NAN),
                patience: get_usize(&grades, "patience", 0),
                unfreeze_factor: get_f64(&grades, "unfreeze_factor", 0.0),
                granularity: get_str(&grades, "granularity", "matrix"),
            },
            eb: EbConfig {
                gvar: get_bool(&eb, "gvar", false),
                alpha: get_f64(&eb, "alpha", 0.25),
                margin: get_f64(&eb, "margin", 0.0),
                patience: get_usize(&eb, "patience", 2),
            },
            spectral: SpectralConfig {
                alpha: get_f64(&spectral, "alpha", 0.25),
                interval_frac: get_f64(&spectral, "interval_frac", 0.05),
                tau: get_f64(&spectral, "tau", 0.05),
                patience: get_usize(&spectral, "patience", 1),
            },
            ies: IesConfig {
                alpha: get_f64(&ies, "alpha", 0.25),
                check_interval_frac: get_f64(&ies, "check_interval_frac", 0.05),
                drop_frac: get_f64(&ies, "drop_frac", 0.25),
                patience: get_usize(&ies, "patience", 1),
                stop_frac: get_f64(&ies, "stop_frac", 0.9),
            },
            es: EsConfig {
                check_interval_frac: get_f64(&es, "check_interval_frac", 0.05),
                patience: get_usize(&es, "patience", 3),
                min_delta: get_f64(&es, "min_delta", 0.0005),
            },
            data: DataConfig {
                corpus: get_str(&data, "corpus", "grammar"),
                seed: get_usize(&data, "seed", 1234) as u64,
                train_sentences: get_usize(&data, "train_sentences", 512),
                val_sentences: get_usize(&data, "val_sentences", 128),
            },
        })
    }

    /// Load `configs/<name>.toml` relative to the repo root.
    pub fn by_name(name: &str) -> Result<Self> {
        Self::load(repo_root().join("configs").join(format!("{name}.toml")))
    }

    /// `artifacts/<name>/` under the repo root.
    pub fn artifact_dir(&self) -> PathBuf {
        repo_root().join("artifacts").join(&self.name)
    }
}

/// Repo root: compiled-in manifest dir (this crate lives at the root).
pub fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_tiny_config() {
        let c = RepoConfig::by_name("lm-tiny-fp").unwrap();
        assert_eq!(c.name, "lm-tiny-fp");
        assert_eq!(c.run.total_steps, 300);
        assert!((c.grades.alpha - 0.5).abs() < 1e-12);
        assert_eq!(c.es.patience, 3);
        assert_eq!(c.data.corpus, "grammar");
        // [model]/[train] tables, shared with the python exporter
        assert_eq!(c.model.kind, "lm");
        assert_eq!((c.model.d_model, c.model.n_layers, c.model.n_heads), (64, 2, 4));
        assert_eq!((c.model.d_ff, c.model.max_seq, c.model.vocab_size), (128, 48, 256));
        assert_eq!((c.train.batch_size, c.train.seq_len), (8, 48));
        assert_eq!(c.train.optimizer, "adamw");
        assert_eq!(c.train.method, "fp");
        assert!((c.train.weight_decay - 0.01).abs() < 1e-12);
    }

    #[test]
    fn sgd_config_reads_momentum() {
        let c = RepoConfig::by_name("lm-tiny-sgd").unwrap();
        assert_eq!(c.train.optimizer, "sgd");
        assert!((c.train.momentum - 0.9).abs() < 1e-12);
    }

    #[test]
    fn vlm_config_has_tower_taus() {
        let c = RepoConfig::by_name("vlm-tiny-fp").unwrap();
        assert!(!c.grades.tau_vision.is_nan());
        assert!(c.grades.tau_vision < c.grades.tau_language + 1.0);
    }

    #[test]
    fn vlm_table_is_typed() {
        let c = RepoConfig::by_name("vlm-tiny-fp").unwrap();
        assert_eq!(c.model.kind, "vlm");
        assert_eq!((c.model.n_patches, c.model.patch_dim), (16, 12));
        assert_eq!((c.model.d_vision, c.model.n_vision_layers), (48, 2));
        assert_eq!((c.model.n_vision_heads, c.model.d_vision_ff), (4, 96));
        // LM configs keep the zero/one defaults
        let lm = RepoConfig::by_name("lm-tiny-fp").unwrap();
        assert_eq!(lm.model.n_patches, 0);
        assert_eq!(lm.model.n_vision_heads, 1);
    }

    #[test]
    fn lora_config_reads_rank_and_alpha() {
        let c = RepoConfig::by_name("lm-tiny-lora").unwrap();
        assert_eq!(c.train.method, "lora");
        assert_eq!(c.train.lora_rank, 4);
        assert!((c.train.lora_alpha - 8.0).abs() < 1e-12);
    }

    #[test]
    fn defaults_for_missing_tables() {
        let dir = std::env::temp_dir().join("grades_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("min.toml");
        std::fs::write(&p, "name = \"min\"\n").unwrap();
        let c = RepoConfig::load(&p).unwrap();
        assert_eq!(c.grades.granularity, "matrix");
        assert_eq!(c.run.total_steps, 200);
        // stopping-zoo tables default sensibly when absent
        assert!(!c.eb.gvar);
        assert_eq!(c.eb.patience, 2);
        assert!((c.spectral.tau - 0.05).abs() < 1e-12);
        assert!((c.ies.drop_frac - 0.25).abs() < 1e-12);
        assert!((c.ies.stop_frac - 0.9).abs() < 1e-12);
    }

    #[test]
    fn zoo_tables_are_typed() {
        let dir = std::env::temp_dir().join("grades_cfg_zoo_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("zoo.toml");
        std::fs::write(
            &p,
            "name = \"zoo\"\n[eb]\ngvar = true\nmargin = 0.1\n[spectral]\ntau = 0.02\n\
             patience = 3\n[ies]\ndrop_frac = 0.5\nstop_frac = 0.8\n",
        )
        .unwrap();
        let c = RepoConfig::load(&p).unwrap();
        assert!(c.eb.gvar);
        assert!((c.eb.margin - 0.1).abs() < 1e-12);
        assert!((c.spectral.tau - 0.02).abs() < 1e-12);
        assert_eq!(c.spectral.patience, 3);
        assert!((c.ies.drop_frac - 0.5).abs() < 1e-12);
        assert!((c.ies.stop_frac - 0.8).abs() < 1e-12);
    }
}
