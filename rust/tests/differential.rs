//! Differential verification of the two execution backends.
//!
//! The host backend exists so the XLA path can be *checked* instead of
//! trusted: the same seed + config must produce the same physics on both
//! engines. These tests run the same trajectory — identical initial
//! parameters (shipped from the XLA init through the state codec),
//! identical batch streams, identical ctrl protocol — on the compiled
//! artifacts and on the pure-Rust transformer, and assert:
//!
//! * per-step training losses agree within a float tolerance (the
//!   backends differ only in reduction order/precision, not math),
//! * per-matrix **freeze steps are identical** — the GradES decisions,
//!   the paper's actual subject, must not depend on the engine,
//! * single-step state updates agree elementwise.
//!
//! Every test sweeps the model-family grid: full-parameter LM, LoRA
//! adapters, and the two-tower VLM. Artifact-gated like
//! `integration.rs`: set `GRADES_ARTIFACTS=1` after `make artifacts`.
//! Without artifacts every test skips, and a family whose artifact
//! directory is missing is skipped individually, so tier-1 stays green
//! (the host-only trajectory coverage lives in
//! `rust/tests/host_backend.rs`).

use std::sync::Arc;

use grades::config::RepoConfig;
use grades::coordinator::scheduler::StepPlan;
use grades::coordinator::trainer::{self, StoppingMethod, TrainOutcome, TrainerOptions};
use grades::coordinator::warmstart::BaseCheckpoint;
use grades::data;
use grades::runtime::artifact::{Bundle, Client};
use grades::runtime::backend::Backend;
use grades::runtime::host_backend::HostBackend;
use grades::runtime::manifest::Manifest;
use grades::runtime::session::{Batch, Session};

/// One config per engine family: full-parameter LM, LoRA adapters on a
/// frozen base, and the two-tower VLM. The freeze-step identity must
/// hold on all three — GradES monitors different component sets
/// (adapters; per-tower matrices) in each.
const FAMILIES: &[&str] = &["lm-tiny-fp", "lm-tiny-lora", "vlm-tiny-fp"];

fn artifacts_enabled() -> bool {
    matches!(std::env::var("GRADES_ARTIFACTS"), Ok(v) if !v.is_empty() && v != "0")
}

/// (bundle, host engine) for one config, or None when gated off or this
/// family's artifact was not compiled.
fn engines(config: &str) -> Option<(Bundle, HostBackend)> {
    if !artifacts_enabled() {
        eprintln!("skipping: set GRADES_ARTIFACTS=1 (after `make artifacts`) to run differential tests");
        return None;
    }
    let dir = grades::config::repo_root().join("artifacts").join(config);
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping {config}: artifacts/{config} missing (run `make artifacts`)");
        return None;
    }
    let client = Client::cpu().expect("PJRT CPU client");
    let bundle = Bundle::load(&client, &dir).expect("bundle");
    let cfg = RepoConfig::by_name(config).expect("config");
    let host = HostBackend::for_config(&cfg).expect("host backend");
    // the layout contract that makes states interchangeable
    assert_eq!(host.manifest().state_len, bundle.manifest.state_len);
    assert_eq!(host.manifest().metrics_len, bundle.manifest.metrics_len);
    assert_eq!(host.manifest().ctrl_len, bundle.manifest.ctrl_len);
    assert_eq!(host.manifest().n_components, bundle.manifest.n_components);
    for (h, x) in host.manifest().components.iter().zip(&bundle.manifest.components) {
        assert_eq!((h.name.as_str(), h.tower.as_str()), (x.name.as_str(), x.tower.as_str()));
    }
    for (h, x) in host.manifest().params.iter().zip(&bundle.manifest.params) {
        assert_eq!((h.name.as_str(), h.offset), (x.name.as_str(), x.offset), "layout drift");
        assert_eq!(h.trainable, x.trainable, "trainability drift on {}", h.name);
    }
    Some((bundle, host))
}

fn rel_close(a: f64, b: f64, rtol: f64) -> bool {
    (a - b).abs() <= rtol * a.abs().max(b.abs()).max(1e-8)
}

/// A deterministic batch stream both backends replay identically: the
/// LM path materialises batches from the seeded iterator, the VLM path
/// uses the packed scene batches directly.
fn batch_pool(cfg: &RepoConfig, m: &Manifest, n: usize) -> (Vec<Batch>, Vec<Batch>) {
    if m.is_vlm() {
        let ds = data::build_vlm(cfg, m).unwrap();
        (ds.train, ds.val)
    } else {
        let mut ds = data::build_lm(cfg, m).unwrap();
        let train = (0..n.max(1)).map(|_| ds.train.next_batch()).collect();
        (train, ds.val)
    }
}

/// Shared-parameter warm start: both backends start from the *XLA*
/// init's parameters (init RNGs differ across backends by design; the
/// paper's subject is the trajectory from shared weights). Mapping by
/// tensor name also covers LoRA adapters and both VLM towers.
fn shared_start(bundle: &Bundle) -> Arc<BaseCheckpoint> {
    let mut s = Session::new(bundle);
    s.init(42).unwrap();
    Arc::new(BaseCheckpoint::from_state(&bundle.manifest, &s.state_to_host().unwrap()).unwrap())
}

fn run_grades(
    backend: &dyn Backend,
    cfg: &RepoConfig,
    steps: usize,
    warm: Arc<BaseCheckpoint>,
) -> TrainOutcome {
    let (train, val) = batch_pool(cfg, backend.manifest(), steps);
    let val: Vec<_> = val.iter().take(2).cloned().collect();
    let mut opts = TrainerOptions::from_config(cfg, StoppingMethod::GradEs);
    opts.total_steps = steps;
    opts.probe_every = 1;
    opts.warm_start = Some(warm);
    let mut i = 0usize;
    let next = || {
        let b = train[i % train.len()].clone();
        i += 1;
        b
    };
    trainer::run(backend, cfg, &opts, next, &val).unwrap()
}

fn assert_trajectories_agree(x: &TrainOutcome, h: &TrainOutcome, rtol: f64, label: &str) {
    assert_eq!(x.steps_run, h.steps_run, "{label}: step counts diverge");
    assert_eq!(x.stop_cause, h.stop_cause, "{label}: stop causes diverge");
    assert_eq!(x.log.records.len(), h.log.records.len());
    for (rx, rh) in x.log.records.iter().zip(&h.log.records) {
        assert_eq!(rx.step, rh.step);
        assert!(
            rel_close(rx.loss, rh.loss, rtol),
            "{label}: loss diverges at step {} (xla {} vs host {})",
            rx.step,
            rx.loss,
            rh.loss
        );
    }
    // the headline assert: identical per-matrix freeze steps
    let ev = |o: &TrainOutcome| -> Vec<(usize, usize, bool)> {
        o.freeze.events.iter().map(|e| (e.step, e.component, e.frozen)).collect()
    };
    assert_eq!(ev(x), ev(h), "{label}: freeze decisions diverge across backends");
    if x.final_val_loss.is_finite() || h.final_val_loss.is_finite() {
        assert!(
            rel_close(x.final_val_loss, h.final_val_loss, rtol),
            "{label}: final val loss diverges ({} vs {})",
            x.final_val_loss,
            h.final_val_loss
        );
    }
}

#[test]
fn single_step_state_updates_agree_elementwise() {
    for config in FAMILIES {
        let Some((bundle, host)) = engines(config) else { continue };
        let cfg = RepoConfig::by_name(config).unwrap();
        let m = &bundle.manifest;
        let mut xs = Session::new(&bundle);
        xs.init(7).unwrap();
        let start = xs.state_to_host().unwrap();
        let mut hs = Session::new(&host);
        hs.state_from_host(&start).unwrap();

        let (train, _) = batch_pool(&cfg, m, 1);
        let batch = train[0].clone();
        let mut ctrl = vec![0f32; m.ctrl_len];
        ctrl[0] = 1.0;
        ctrl[1] = 1e-3;
        ctrl[2] = 1.0;
        for c in ctrl.iter_mut().skip(m.ctrl_mask_offset) {
            *c = 1.0;
        }
        let full = StepPlan::all_active(m.n_components);
        xs.train_step(&batch, &ctrl, &full).unwrap();
        hs.train_step(&batch, &ctrl, &full).unwrap();
        let sx = xs.state_to_host().unwrap();
        let sh = hs.state_to_host().unwrap();

        // loss / count / gnorm / gdiff in the metrics prefix
        assert!(
            rel_close(sx[0] as f64, sh[0] as f64, 1e-3),
            "{config}: loss_sum {} vs {}",
            sx[0],
            sh[0]
        );
        assert_eq!(sx[1], sh[1], "{config}: token counts are exact on both backends");
        assert!(
            rel_close(sx[2] as f64, sh[2] as f64, 1e-2),
            "{config}: gnorm {} vs {}",
            sx[2],
            sh[2]
        );
        for c in 0..m.n_components {
            let (a, b) = (sx[m.gdiff_offset + c] as f64, sh[m.gdiff_offset + c] as f64);
            assert!(rel_close(a, b, 2e-2), "{config}: gdiff[{c}] {a} vs {b}");
        }
        // params + opt state + prev grads, elementwise
        let mut max_dev = 0f32;
        for (a, b) in sx[m.metrics_len..].iter().zip(&sh[m.metrics_len..]) {
            max_dev = max_dev.max((a - b).abs());
        }
        assert!(max_dev < 2e-3, "{config}: state deviates elementwise by {max_dev}");
    }
}

#[test]
fn grades_trajectory_losses_close_and_freeze_steps_identical() {
    for config in FAMILIES {
        let Some((bundle, host)) = engines(config) else { continue };
        let mut cfg = RepoConfig::by_name(config).unwrap();
        // generous τ after a short grace: every component converges right
        // after ⌈αT⌉ on *both* backends (metric values sit far below τ, so
        // the crossing step can't flip on float noise) — freezing and the
        // frozen-component elision swap exercised end to end
        cfg.grades.alpha = 0.2;
        cfg.grades.tau = 5.0;
        cfg.grades.tau_vision = f64::NAN;
        cfg.grades.tau_language = f64::NAN;
        let warm = shared_start(&bundle);
        let x = run_grades(&bundle, &cfg, 30, warm.clone());
        let h = run_grades(&host, &cfg, 30, warm);
        assert_trajectories_agree(&x, &h, 5e-3, &format!("{config} tau=5.0"));
        assert!(x.freeze.all_frozen(), "{config}: generous tau must freeze everything");
    }
}

#[test]
fn grades_trajectory_with_config_tau_agrees() {
    // The config's own τ (realistic: little-to-no freezing in 30 steps;
    // the VLM config adds per-tower thresholds); freeze sets must still
    // match exactly — typically both empty, and any disagreement means
    // the gradient statistics diverged.
    for config in FAMILIES {
        let Some((bundle, host)) = engines(config) else { continue };
        let cfg = RepoConfig::by_name(config).unwrap();
        let warm = shared_start(&bundle);
        let x = run_grades(&bundle, &cfg, 30, warm.clone());
        let h = run_grades(&host, &cfg, 30, warm);
        assert_trajectories_agree(&x, &h, 5e-3, &format!("{config} config tau"));
    }
}

#[test]
fn eval_agrees_on_identical_states() {
    for config in FAMILIES {
        let Some((bundle, host)) = engines(config) else { continue };
        let cfg = RepoConfig::by_name(config).unwrap();
        let mut xs = Session::new(&bundle);
        xs.init(21).unwrap();
        let state = xs.state_to_host().unwrap();
        let mut hs = Session::new(&host);
        hs.state_from_host(&state).unwrap();
        let (_, val) = batch_pool(&cfg, &bundle.manifest, 1);
        for b in val.iter().take(3) {
            let (lx, cx) = xs.eval_batch(b).unwrap();
            let (lh, ch) = hs.eval_batch(b).unwrap();
            assert_eq!(cx, ch);
            assert!(rel_close(lx, lh, 1e-3), "{config}: eval loss {lx} vs {lh}");
            // per-row scoring path too
            let rx = xs.eval_rows(b).unwrap();
            let rh = hs.eval_rows(b).unwrap();
            for ((la, ca), (lb, cb)) in rx.iter().zip(&rh) {
                assert_eq!(ca, cb);
                assert!(rel_close(*la, *lb, 2e-3), "{config}: row loss {la} vs {lb}");
            }
        }
    }
}
